"""The environment pipeline f(x̂(p)) of the 1-D proxy app (Fig 7).

Translates a batch of predicted parameter vectors into synthetic events that
are format-compatible with the reference data: for each of the B parameter
samples the inverse-CDF sampler draws E events of two observables
(y0, y1). The output is flattened to (B*E, 2) — the discriminator batch.

The whole pipeline is differentiable (a hard requirement of the paper:
"Each SAGIPS module needs to be differentiable, otherwise we would not be
able to train a GAN via backpropagation").

True parameters of the loop-closure test. Monotonicity of the quantile
(p1 + 2*p2*u > 0 and p4 + 2*p5*u > 0 on u in [0,1]) holds, so these define
valid distributions.
"""

import jax.numpy as jnp

from .kernels import quantile, ref

TRUE_PARAMS = [1.0, 0.5, 0.3, -0.5, 1.2, 0.4]


def pipeline_apply(params, u):
    """(B, 6) params + (B, E, 2) uniforms -> (B*E, 2) events (Pallas)."""
    events = quantile.quantile_sample(params, u)
    b, e, _ = events.shape
    return events.reshape(b * e, 2)


def pipeline_apply_ref(params, u):
    """Pure-jnp oracle of ``pipeline_apply``."""
    events = ref.quantile_eval(params, u)
    b, e, _ = events.shape
    return events.reshape(b * e, 2)
