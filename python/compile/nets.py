"""MLP definitions over *flat* parameter vectors.

The Rust coordinator owns model parameters as flat f32 buffers (that is what
the ring-all-reduce, RMA mailboxes and optimizers operate on), so every
exported computation takes a flat vector and unflattens it inside the traced
function. The layer layout (offsets/shapes) is emitted into the artifact
manifest so the Rust side can initialize parameters (Kaiming-normal, like
the paper) and slice weight-vs-bias gradients (the paper excludes bias
gradients from the ring transfer).
"""

import jax.numpy as jnp

from .kernels import fused_mlp

LEAKY_SLOPE = 0.2


def mlp_dims(sizes):
    """[(in, out), ...] from a [d0, d1, ..., dk] size list."""
    return list(zip(sizes[:-1], sizes[1:]))


def param_count(dims):
    return sum(i * o + o for i, o in dims)


def layer_layout(dims):
    """Flat-vector layout: per layer, weight offset/shape + bias offset.

    Layout order is [W0, b0, W1, b1, ...] with W stored row-major (In, Out).
    """
    layout = []
    off = 0
    for d_in, d_out in dims:
        w_off = off
        off += d_in * d_out
        b_off = off
        off += d_out
        layout.append(
            {
                "w_offset": w_off,
                "w_shape": [d_in, d_out],
                "b_offset": b_off,
                "b_len": d_out,
            }
        )
    return layout


def unflatten(flat, dims):
    """Slice a flat f32 vector into [(W, b), ...] per ``layer_layout``."""
    layers = []
    off = 0
    for d_in, d_out in dims:
        w = flat[off : off + d_in * d_out].reshape(d_in, d_out)
        off += d_in * d_out
        b = flat[off : off + d_out]
        off += d_out
        layers.append((w, b))
    return layers


def mlp_apply(flat, dims, x, slope=LEAKY_SLOPE):
    """Forward an MLP with LeakyReLU hidden layers and a linear output
    layer. Every layer runs through the fused Pallas kernel."""
    layers = unflatten(flat, dims)
    h = x
    for i, (w, b) in enumerate(layers):
        activate = i < len(layers) - 1
        h = fused_mlp.fused_linear_act(h, w, b, slope, activate)
    return h


def mlp_apply_ref(flat, dims, x, slope=LEAKY_SLOPE):
    """Pure-jnp forward (oracle for tests)."""
    from .kernels import ref

    layers = unflatten(flat, dims)
    h = x
    for i, (w, b) in enumerate(layers):
        activate = i < len(layers) - 1
        h = ref.fused_linear_act(h, w, b, slope, activate)
    return h
