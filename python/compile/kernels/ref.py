"""Pure-jnp reference oracles for the Pallas kernels.

These are the correctness ground truth: pytest (with hypothesis sweeps over
shapes) asserts the Pallas kernels in ``fused_mlp.py`` and ``quantile.py``
match these to float tolerance. They are also used as the backward pass of
the kernels' ``custom_vjp`` rules, so the exported GAN step stays fully
differentiable while the forward hot path runs through Pallas.
"""

import jax.numpy as jnp


def leaky_relu(x, slope):
    """LeakyReLU: x for x>=0, slope*x otherwise."""
    return jnp.where(x >= 0, x, slope * x)


def fused_linear_act(x, w, b, slope, activate):
    """Reference for the fused Linear(+LeakyReLU) layer.

    Args:
      x: (B, In) activations.
      w: (In, Out) weights.
      b: (Out,) bias.
      slope: LeakyReLU negative slope.
      activate: if False the layer is purely linear (output layers).
    Returns:
      (B, Out) activations.
    """
    h = jnp.dot(x, w, preferred_element_type=jnp.float32) + b
    return leaky_relu(h, slope) if activate else h


def quantile_eval(params, u):
    """Reference for the inverse-CDF (quantile) event sampler.

    The 1-D proxy app's sampler: a polynomial quantile function per
    observable,

        y0 = p0 + p1*u0 + p2*u0^2
        y1 = p3 + p4*u1 + p5*u1^2

    which is a valid inverse CDF (monotone in u for the parameter ranges
    used) and differentiable in both ``params`` and ``u`` — the two
    properties the paper requires of its sampler (Sec. V-A).

    Args:
      params: (B, 6) parameter predictions, one row per parameter sample.
      u: (B, E, 2) uniform draws — E events per parameter sample, 2
         observables per event.
    Returns:
      (B, E, 2) events.
    """
    p = params[:, None, :]  # (B, 1, 6)
    u0 = u[..., 0]
    u1 = u[..., 1]
    y0 = p[..., 0] + p[..., 1] * u0 + p[..., 2] * jnp.square(u0)
    y1 = p[..., 3] + p[..., 4] * u1 + p[..., 5] * jnp.square(u1)
    return jnp.stack([y0, y1], axis=-1)
