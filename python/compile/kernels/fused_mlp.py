"""Pallas kernel: fused Linear + LeakyReLU layer (the GAN's dense hot path).

This is the Layer-1 compute hot-spot of SAGIPS: every generator and
discriminator layer is one fused ``y = leaky_relu(x @ W + b)`` kernel, so the
matmul, bias add and activation stay in VMEM instead of round-tripping
through HBM between three separate ops.

TPU adaptation (DESIGN.md §Hardware-Adaptation): the paper runs these layers
as cuBLAS GEMMs on A100s. On TPU the natural unit is the MXU (128x128
systolic array): we tile the batch dimension into blocks that are multiples
of the (8, 128) f32 tile, keep the full (In, Out) weight panel resident in
VMEM (the GAN layers are at most 157x157 — a few hundred KB), and express
the HBM->VMEM schedule with a 1-D grid over batch blocks, which is what the
paper's threadblock tiling expressed on GPU.

The kernel must lower with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, and the whole point of the AOT path is that the
Rust coordinator runs these artifacts on the request path.

Differentiability: ``pallas_call`` is not reliably differentiable, so the
layer is wrapped in ``jax.custom_vjp`` with a pure-jnp backward derived from
``ref.py``. Forward = Pallas, backward = jnp; both lower into the same HLO
artifact.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Max batch rows per grid step. 512 rows x 157 cols x 4 B ~= 320 KB of
# activations per block — comfortably inside a 16 MB VMEM budget together
# with the weight panel, and a multiple of the 8-row f32 sublane tile.
_MAX_BLOCK_B = 512


def _pick_block(b):
    """Largest divisor of the batch that is <= _MAX_BLOCK_B.

    Larger blocks mean fewer grid steps (less per-step overhead, better
    MXU occupancy along the batch dimension); a divisor keeps the grid
    exact so no masking is needed. Falls back to the whole batch as a
    single block for small/awkward batches (e.g. the weak-scaling sizes
    51/17 from eq. (10) of the paper) — still fused, just a 1-step grid.
    """
    if b <= _MAX_BLOCK_B:
        return b
    for cand in range(_MAX_BLOCK_B, 0, -1):
        if b % cand == 0:
            return cand
    return b


def _fused_kernel(x_ref, w_ref, b_ref, o_ref, *, slope, activate):
    """One grid step: compute a (block_b, Out) tile of the output."""
    x = x_ref[...]
    w = w_ref[...]
    bias = b_ref[...]
    h = jnp.dot(x, w, preferred_element_type=jnp.float32) + bias[None, :]
    if activate:
        h = jnp.where(h >= 0, h, slope * h)
    o_ref[...] = h


def _forward_pallas(x, w, b, slope, activate):
    batch, d_in = x.shape
    d_out = w.shape[1]
    blk = _pick_block(batch)
    grid = (batch // blk,)
    kern = functools.partial(_fused_kernel, slope=slope, activate=activate)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk, d_in), lambda i: (i, 0)),
            pl.BlockSpec((d_in, d_out), lambda i: (0, 0)),
            pl.BlockSpec((d_out,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((blk, d_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, d_out), jnp.float32),
        interpret=True,
    )(x, w, b)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def fused_linear_act(x, w, b, slope, activate):
    """Fused ``leaky_relu(x @ w + b)`` (or linear when ``activate=False``).

    Forward runs the Pallas kernel; the VJP is a hand-written jnp backward
    so the exported GAN step is differentiable end to end.
    """
    return _forward_pallas(x, w, b, slope, activate)


def _fwd(x, w, b, slope, activate):
    y = _forward_pallas(x, w, b, slope, activate)
    # Save the pre-activation sign through the cheap jnp recompute of h's
    # sign: for LeakyReLU, sign(h) == sign(y) (slope > 0), so y itself is a
    # sufficient residual — no extra buffer.
    return y, (x, w, y)


def _bwd(slope, activate, res, g):
    x, w, y = res
    if activate:
        # d LeakyReLU: 1 where pre-activation >= 0 else slope; sign(y)
        # carries the same information because slope > 0.
        dh = jnp.where(y >= 0, g, slope * g)
    else:
        dh = g
    dx = jnp.dot(dh, w.T, preferred_element_type=jnp.float32)
    dw = jnp.dot(x.T, dh, preferred_element_type=jnp.float32)
    db = jnp.sum(dh, axis=0)
    return dx, dw, db


fused_linear_act.defvjp(_fwd, _bwd)


def vmem_footprint_bytes(batch, d_in, d_out):
    """Estimated VMEM bytes held by one grid step of the fused kernel.

    Used by the §Perf analysis (DESIGN.md): activations block + weight
    panel + bias + output block, f32.
    """
    blk = _pick_block(batch)
    return 4 * (blk * d_in + d_in * d_out + d_out + blk * d_out)


def mxu_tile_utilization(d_in, d_out):
    """Fraction of the padded (128, 128) MXU tiles actually used by the
    weight panel — the §Perf occupancy metric for the GAN layer shapes."""
    pad = lambda n: ((n + 127) // 128) * 128
    return (d_in * d_out) / float(pad(d_in) * pad(d_out))
