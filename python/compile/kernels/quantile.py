"""Pallas kernel: the inverse-CDF (quantile) event sampler.

The paper's environment pipeline turns each predicted parameter vector into
``E`` stochastic events via the inverse-CDF method (Sec. V-A: "the sampler
used within the 1D proxy app relies on the inverse CDF method, i.e. we use
the inverse of a differentiable function to sample events"). This is the
stochastic event sampler the introduction flags as the dominant compute
cost at production scale, so it is a Layer-1 kernel.

The quantile model (see ``ref.quantile_eval``) is a per-observable
polynomial ``q(u; a, b, c) = a + b*u + c*u^2`` — differentiable in the
parameters (needed for GAN backprop through the pipeline) and monotone in
``u`` for the parameter ranges of the loop-closure test.

TPU adaptation: pure VPU work (elementwise FMA over (8, 128) lanes); the
six per-sample parameters are broadcast from a small VMEM-resident block.
Grid is 1-D over parameter-sample blocks, mirroring the per-threadblock
event batch the paper's CUDA sampler would use.

Like ``fused_mlp``, lowered with ``interpret=True`` and wrapped in
``custom_vjp`` (jnp backward) so the GAN step differentiates through it.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Parameter samples per grid step. E is typically 25-100, so a block of 64
# samples is <= 64*100*2*4 B = 50 KB of uniforms — trivially VMEM resident.
_MAX_BLOCK_B = 64


def _pick_block(b):
    blk = _MAX_BLOCK_B
    while blk > 1:
        if b % blk == 0:
            return blk
        blk //= 2
    return b


def _quantile_kernel(p_ref, u_ref, o_ref):
    p = p_ref[...]  # (blk, 6)
    u = u_ref[...]  # (blk, E, 2)
    u0 = u[..., 0]
    u1 = u[..., 1]
    y0 = p[:, None, 0] + p[:, None, 1] * u0 + p[:, None, 2] * (u0 * u0)
    y1 = p[:, None, 3] + p[:, None, 4] * u1 + p[:, None, 5] * (u1 * u1)
    o_ref[...] = jnp.stack([y0, y1], axis=-1)


def _forward_pallas(params, u):
    batch, n_p = params.shape
    events = u.shape[1]
    blk = _pick_block(batch)
    grid = (batch // blk,)
    return pl.pallas_call(
        _quantile_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk, n_p), lambda i: (i, 0)),
            pl.BlockSpec((blk, events, 2), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((blk, events, 2), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, events, 2), jnp.float32),
        interpret=True,
    )(params, u)


@jax.custom_vjp
def quantile_sample(params, u):
    """Inverse-CDF sampler: ``(B,6)`` params + ``(B,E,2)`` uniforms ->
    ``(B,E,2)`` events. Pallas forward, jnp backward."""
    return _forward_pallas(params, u)


def _fwd(params, u):
    return _forward_pallas(params, u), (params, u)


def _bwd(res, g):
    params, u = res
    p = params[:, None, :]
    u0, u1 = u[..., 0], u[..., 1]
    g0, g1 = g[..., 0], g[..., 1]
    # dy0/d(p0,p1,p2) = (1, u0, u0^2); dy1/d(p3,p4,p5) = (1, u1, u1^2)
    dp = jnp.stack(
        [
            jnp.sum(g0, axis=1),
            jnp.sum(g0 * u0, axis=1),
            jnp.sum(g0 * u0 * u0, axis=1),
            jnp.sum(g1, axis=1),
            jnp.sum(g1 * u1, axis=1),
            jnp.sum(g1 * u1 * u1, axis=1),
        ],
        axis=-1,
    )
    # dy0/du0 = p1 + 2*p2*u0 ; dy1/du1 = p4 + 2*p5*u1
    du0 = g0 * (p[..., 1] + 2.0 * p[..., 2] * u0)
    du1 = g1 * (p[..., 4] + 2.0 * p[..., 5] * u1)
    du = jnp.stack([du0, du1], axis=-1)
    return dp, du


quantile_sample.defvjp(_fwd, _bwd)


def vmem_footprint_bytes(batch, events):
    """Estimated VMEM bytes per grid step (§Perf metric)."""
    blk = _pick_block(batch)
    return 4 * (blk * 6 + 2 * (blk * events * 2))
