"""GAN losses (non-saturating / BCE-with-logits).

The discriminator is the paper's objective function F: it is trained to
label reference events as 1 and synthetic events as 0, and — unlike an MSE
objective — never compares events index-to-index, which is exactly why the
paper uses it (the sampled synthetic events arrive in random order).
"""

import jax.numpy as jnp


def softplus(x):
    # Numerically stable softplus: log(1 + exp(x)).
    return jnp.logaddexp(x, 0.0)


def disc_loss(real_logits, fake_logits):
    """BCE with logits: real -> 1, fake -> 0."""
    return jnp.mean(softplus(-real_logits)) + jnp.mean(softplus(fake_logits))


def gen_loss(fake_logits):
    """Non-saturating generator loss: maximize log D(fake)."""
    return jnp.mean(softplus(-fake_logits))
