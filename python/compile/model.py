"""Layer-2: the SAGIPS GAN computations exported to the Rust coordinator.

Everything here is a pure function of flat parameter vectors + explicit
randomness (noise ``z`` and uniforms ``u`` are *inputs*, drawn by the Rust
coordinator's PRNG), so the lowered HLO artifacts are deterministic and the
coordinator fully owns the stochasticity — a requirement for reproducible
distributed runs and for the bootstrap sub-sampling the paper describes.

Exported computations (see ``aot.py`` for the artifact grid):

* ``gan_step``     — one training step: generator forward -> pipeline ->
                     discriminator; returns generator gradients,
                     discriminator gradients and both losses.
* ``gen_predict``  — generator forward only (parameter predictions for the
                     residual diagnostics / ensemble response, eqs 6-8).
* ``pipeline_fn``  — the environment pipeline alone (used by Rust to build
                     the loop-closure toy data set from the true params).
* ``disc_forward`` — discriminator logits (diagnostics).
"""

import jax
import jax.numpy as jnp

from . import losses, nets, pipeline

LATENT_DIM = 16

# Model size variants used by the ensemble study (Fig 8 trains GANs "with
# different number of model parameters"). "paper" matches the paper's
# counts within 0.2% (51,288 vs 51,206 generator / 50,241 vs 50,049
# discriminator — exact architecture undisclosed).
MODEL_SIZES = {
    "small": {"gen_hidden": [32, 32], "disc_hidden": [32, 32]},
    "medium": {"gen_hidden": [80, 80, 80], "disc_hidden": [80, 80, 80]},
    "paper": {"gen_hidden": [154, 154, 154], "disc_hidden": [157, 157, 157]},
}


def model_dims(size):
    """(gen_dims, disc_dims) for a model size variant."""
    spec = MODEL_SIZES[size]
    gen_sizes = [LATENT_DIM] + spec["gen_hidden"] + [6]
    disc_sizes = [2] + spec["disc_hidden"] + [1]
    return nets.mlp_dims(gen_sizes), nets.mlp_dims(disc_sizes)


def generator_apply(gen_flat, gen_dims, z):
    """Generator forward: noise (B, LATENT_DIM) -> parameters (B, 6)."""
    return nets.mlp_apply(gen_flat, gen_dims, z)


def discriminator_apply(disc_flat, disc_dims, events):
    """Discriminator forward: events (N, 2) -> logits (N,)."""
    return nets.mlp_apply(disc_flat, disc_dims, events)[:, 0]


def gan_step_naive(gen_flat, disc_flat, z, u, real, *, gen_dims, disc_dims):
    """Reference (unoptimized) GAN step: two independent `value_and_grad`s.

    Kept as the numerical oracle and the §Perf ablation baseline: XLA CSE
    does *not* merge the duplicated generator-forward + pipeline between
    the two loss graphs (the lowered HLO contains 22 Pallas grid loops vs
    13 for `gan_step`), so the optimized version below shares them
    explicitly. See EXPERIMENTS.md §Perf.
    """

    def g_loss_fn(gf):
        params = generator_apply(gf, gen_dims, z)
        fake = pipeline.pipeline_apply(params, u)
        fake_logits = discriminator_apply(disc_flat, disc_dims, fake)
        return losses.gen_loss(fake_logits)

    def d_loss_fn(df):
        params = jax.lax.stop_gradient(generator_apply(gen_flat, gen_dims, z))
        fake = pipeline.pipeline_apply(params, u)
        fake_logits = discriminator_apply(df, disc_dims, fake)
        real_logits = discriminator_apply(df, disc_dims, real)
        return losses.disc_loss(real_logits, fake_logits)

    g_loss, g_grads = jax.value_and_grad(g_loss_fn)(gen_flat)
    d_loss, d_grads = jax.value_and_grad(d_loss_fn)(disc_flat)
    return g_grads, d_grads, g_loss, d_loss


def gan_step(gen_flat, disc_flat, z, u, real, *, gen_dims, disc_dims):
    """One GAN training step of the loop-closure workflow (optimized).

    Shares every forward pass between the generator and discriminator
    losses via explicit `jax.vjp` plumbing — the generator forward, the
    pipeline, and the discriminator's fake-batch forward each appear once
    in the lowered HLO (the naive two-`grad` version duplicates them and
    XLA CSE does not recover it).

    Args:
      gen_flat:  (Pg,) flat generator parameters.
      disc_flat: (Pd,) flat discriminator parameters.
      z:         (B, LATENT_DIM) noise.
      u:         (B, E, 2) uniforms for the event sampler.
      real:      (B*E, 2) reference events (bootstrap sub-sample drawn by
                 the coordinator; batch matched to the synthetic batch as
                 the paper requires).
    Returns:
      (g_grads (Pg,), d_grads (Pd,), g_loss (), d_loss ())
    """
    # Shared forward: generator -> pipeline (one evaluation, with VJP).
    def synth(gf):
        params = generator_apply(gf, gen_dims, z)
        return pipeline.pipeline_apply(params, u)

    fake, synth_vjp = jax.vjp(synth, gen_flat)

    # Shared discriminator forward on the fake batch, differentiable in
    # both the discriminator parameters and the events.
    def disc_fake(df, fk):
        return discriminator_apply(df, disc_dims, fk)

    fake_logits, disc_fake_vjp = jax.vjp(disc_fake, disc_flat, fake)

    # Discriminator forward on the real batch (df only).
    def disc_real(df):
        return discriminator_apply(df, disc_dims, real)

    real_logits, disc_real_vjp = jax.vjp(disc_real, disc_flat)

    # Generator loss + gradient: dL/dlogits -> dL/dfake -> dL/dgen.
    g_loss, gl_vjp = jax.vjp(losses.gen_loss, fake_logits)
    (dlogits_g,) = gl_vjp(jnp.ones(()))
    _, dfake = disc_fake_vjp(dlogits_g)
    (g_grads,) = synth_vjp(dfake)

    # Discriminator loss + gradient through both logits branches; the
    # fake batch is a constant here (the naive version's stop_gradient).
    d_loss, dl_vjp = jax.vjp(losses.disc_loss, real_logits, fake_logits)
    dreal_logits, dfake_logits = dl_vjp(jnp.ones(()))
    (d_grads_real,) = disc_real_vjp(dreal_logits)
    d_grads_fake, _ = disc_fake_vjp(dfake_logits)
    d_grads = d_grads_real + d_grads_fake

    return g_grads, d_grads, g_loss, d_loss


def gen_predict(gen_flat, z, *, gen_dims):
    """Generator predictions for the residual / ensemble diagnostics."""
    return generator_apply(gen_flat, gen_dims, z)


def pipeline_fn(params, u):
    """The environment pipeline alone: (B,6) + (B,E,2) -> (B*E, 2)."""
    return pipeline.pipeline_apply(params, u)


def disc_forward(disc_flat, events, *, disc_dims):
    """Discriminator logits over an event batch (diagnostics)."""
    return discriminator_apply(disc_flat, disc_dims, events)
