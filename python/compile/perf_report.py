"""§Perf analysis for L1 (Pallas kernels) and L2 (lowered HLO).

L1: interpret-mode wall-clock is CPU-numpy timing, NOT a TPU proxy — so the
optimization currency is *structural*: VMEM footprint per grid step and MXU
tile occupancy, derived from the BlockSpecs (DESIGN.md §Perf).

L2: audits the lowered gan_step HLO: counts dot/while/fusion-relevant ops
and — critically — whether XLA CSE merged the two pipeline evaluations
(generator loss and discriminator loss both forward the pipeline; if CSE
works, the quantile polynomial appears once per uniform tensor, not twice).

Usage: cd python && python -m compile.perf_report
"""

import re

import jax

from . import aot, model, nets
from .kernels import fused_mlp, quantile

VMEM_BUDGET = 16 * 2**20  # 16 MiB/core


def l1_report():
    print("=== L1 Pallas kernels: VMEM footprint + MXU occupancy ===")
    print(f"{'kernel / layer':<40} {'VMEM/step':>12} {'of budget':>10} {'MXU occ':>8}")
    for size in ("small", "medium", "paper"):
        gen_dims, disc_dims = model.model_dims(size)
        for net, dims in (("gen", gen_dims), ("disc", disc_dims)):
            for d_in, d_out in dims:
                vmem = fused_mlp.vmem_footprint_bytes(1024, d_in, d_out)
                occ = fused_mlp.mxu_tile_utilization(d_in, d_out)
                name = f"fused_mlp {size}.{net} {d_in}x{d_out} (B=1024)"
                print(
                    f"{name:<40} {vmem/1024:>10.1f}Ki {vmem/VMEM_BUDGET:>9.1%} {occ:>8.1%}"
                )
    for b, e in ((1024, 100), (64, 25)):
        vmem = quantile.vmem_footprint_bytes(b, e)
        name = f"quantile_sampler B={b} E={e}"
        print(f"{name:<40} {vmem/1024:>10.1f}Ki {vmem/VMEM_BUDGET:>9.1%} {'VPU':>8}")


def l2_report(size="paper", batch=64, events=25):
    print(f"\n=== L2 lowered HLO audit: gan_step {size} b{batch} e{events} ===")
    fn, shapes, _ = aot.gan_step_export(size, batch, events)
    specs = [aot._spec(s) for _, s in shapes]
    lowered = jax.jit(fn).lower(*specs)
    hlo = aot.to_hlo_text(lowered)
    dots = len(re.findall(r"= f32\[[^\]]*\] dot\(", hlo))
    whiles = hlo.count(" while(")
    multiplies = len(re.findall(r" multiply\(", hlo))
    lines = hlo.count("\n")
    gen_dims, disc_dims = model.model_dims(size)
    # dot ops expected: G fwd (len(gen_dims)) + G bwd (2 per layer) +
    # D fwd on fake+real (2x) + D bwd (2 per layer) + D fwd in G-loss ...
    print(f"HLO lines:             {lines}")
    print(f"dot ops:               {dots}")
    print(f"while loops (pallas):  {whiles}")
    print(f"multiplies:            {multiplies}")
    # CSE check: the quantile forward evaluates u*u once per observable per
    # pipeline evaluation. Count the distinctive fused quantile pattern by
    # counting pallas-interpret while loops attributable to the sampler:
    # each quantile_sample forward lowers to one grid loop (or inline ops
    # for single-step grids). We check the total op budget instead:
    dup_ratio = dots / max(1, (len(gen_dims) * 3 + len(disc_dims) * 3 * 2))
    print(f"dot count vs single-pipeline estimate: {dup_ratio:.2f}x")
    print("(~1x means XLA CSE merged the G-loss and D-loss pipeline forwards)")


def l3_note():
    print(
        "\nL3 perf is measured in rust: `cargo bench --bench micro_collective`"
        " / `micro_runtime`, and per-phase timers recorded by every run"
        " (step_s / comm_s / optim_s in the metrics)."
    )


if __name__ == "__main__":
    l1_report()
    l2_report()
    l3_note()
