"""AOT exporter: lower every SAGIPS computation to HLO *text* artifacts.

This is the only place Python runs — once, at build time (`make artifacts`).
The Rust coordinator loads the emitted ``artifacts/*.hlo.txt`` through
``HloModuleProto::from_text_file`` and executes them on the PJRT CPU client.

HLO **text** (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProtos with 64-bit instruction ids which xla_extension 0.5.1
rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md.

``manifest.json`` is the single source of truth the Rust runtime reads:
artifact names, input/output shapes, model layer layouts (for Kaiming init
and weight-vs-bias gradient slicing), parameter counts and the loop-closure
true parameters.
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model, nets, pipeline

F32 = "f32"


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


def _io(name, shape):
    return {"name": name, "shape": list(shape), "dtype": F32}


def gan_step_export(size, batch, events):
    """Build the (fn, input specs, io meta) triple for one gan_step variant."""
    gen_dims, disc_dims = model.model_dims(size)
    pg = nets.param_count(gen_dims)
    pd = nets.param_count(disc_dims)
    fn = functools.partial(model.gan_step, gen_dims=gen_dims, disc_dims=disc_dims)
    shapes = [
        ("gen_params", (pg,)),
        ("disc_params", (pd,)),
        ("z", (batch, model.LATENT_DIM)),
        ("u", (batch, events, 2)),
        ("real", (batch * events, 2)),
    ]
    outputs = [
        _io("gen_grads", (pg,)),
        _io("disc_grads", (pd,)),
        _io("gen_loss", ()),
        _io("disc_loss", ()),
    ]
    return fn, shapes, outputs


def gen_predict_export(size, batch):
    gen_dims, _ = model.model_dims(size)
    pg = nets.param_count(gen_dims)
    fn = functools.partial(model.gen_predict, gen_dims=gen_dims)
    shapes = [("gen_params", (pg,)), ("z", (batch, model.LATENT_DIM))]
    outputs = [_io("params", (batch, 6))]
    return fn, shapes, outputs


def pipeline_export(batch, events):
    fn = model.pipeline_fn
    shapes = [("params", (batch, 6)), ("u", (batch, events, 2))]
    outputs = [_io("events", (batch * events, 2))]
    return fn, shapes, outputs


def disc_forward_export(size, n):
    _, disc_dims = model.model_dims(size)
    pd = nets.param_count(disc_dims)
    fn = functools.partial(model.disc_forward, disc_dims=disc_dims)
    shapes = [("disc_params", (pd,)), ("events", (n, 2))]
    outputs = [_io("logits", (n,))]
    return fn, shapes, outputs


def default_exports(paper_scale=False):
    """The artifact grid. Keys are artifact names (also the file stems).

    * ``gan_step_paper_b{4..64}_e25`` — weak-scaling grid, eq (10) with a
      scaled-down base batch of 64 (N in {1,2,4,8,16}).
    * ``gan_step_{small,medium,paper}_b{16,64}_e25`` — Fig 8 model-size x
      data-size grid.
    * ``gen_predict_*`` — residual / ensemble diagnostics (K = 256 noise
      vectors).
    * ``pipeline_*`` — toy-data generation and tests.
    * ``disc_forward_paper_n1600`` — diagnostics.

    ``paper_scale`` adds the full Table III configuration (B=1024, E=100 —
    a 102,400-event discriminator batch).
    """
    exports = {}
    for b in (4, 8, 16, 32, 64):
        exports[f"gan_step_paper_b{b}_e25"] = (
            gan_step_export("paper", b, 25),
            {"fn": "gan_step", "model": "paper", "batch": b, "events": 25},
        )
    for size in ("small", "medium"):
        for b in (16, 64):
            exports[f"gan_step_{size}_b{b}_e25"] = (
                gan_step_export(size, b, 25),
                {"fn": "gan_step", "model": size, "batch": b, "events": 25},
            )
    for size in ("small", "medium", "paper"):
        exports[f"gen_predict_{size}_k256"] = (
            gen_predict_export(size, 256),
            {"fn": "gen_predict", "model": size, "batch": 256},
        )
    exports["pipeline_b256_e25"] = (
        pipeline_export(256, 25),
        {"fn": "pipeline", "batch": 256, "events": 25},
    )
    exports["pipeline_b64_e25"] = (
        pipeline_export(64, 25),
        {"fn": "pipeline", "batch": 64, "events": 25},
    )
    exports["disc_forward_paper_n1600"] = (
        disc_forward_export("paper", 1600),
        {"fn": "disc_forward", "model": "paper", "n": 1600},
    )
    if paper_scale:
        exports["gan_step_paper_b1024_e100"] = (
            gan_step_export("paper", 1024, 100),
            {"fn": "gan_step", "model": "paper", "batch": 1024, "events": 100},
        )
        exports["pipeline_b1024_e100"] = (
            pipeline_export(1024, 100),
            {"fn": "pipeline", "batch": 1024, "events": 100},
        )
    return exports


def models_meta():
    meta = {}
    for size in model.MODEL_SIZES:
        gen_dims, disc_dims = model.model_dims(size)
        meta[size] = {
            "gen_dims": [list(d) for d in gen_dims],
            "disc_dims": [list(d) for d in disc_dims],
            "gen_param_count": nets.param_count(gen_dims),
            "disc_param_count": nets.param_count(disc_dims),
            "gen_layout": nets.layer_layout(gen_dims),
            "disc_layout": nets.layer_layout(disc_dims),
        }
    return meta


def export_all(out_dir, paper_scale=False, only=None):
    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        "version": 1,
        # The JAX/Pallas export covers the paper's proxy app only; the
        # Rust side defaults missing keys to "quantile" for back-compat.
        "scenario": "quantile",
        "latent_dim": model.LATENT_DIM,
        "leaky_slope": nets.LEAKY_SLOPE,
        "true_params": pipeline.TRUE_PARAMS,
        "models": models_meta(),
        "artifacts": {},
    }
    exports = default_exports(paper_scale)
    for name, ((fn, shapes, outputs), meta) in exports.items():
        if only and name not in only:
            continue
        specs = [_spec(s) for _, s in shapes]
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        entry = dict(meta)
        entry["file"] = fname
        entry["inputs"] = [_io(n, s) for n, s in shapes]
        entry["outputs"] = outputs
        manifest["artifacts"][name] = entry
        print(f"  exported {fname} ({len(text) / 1024:.0f} KiB)")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote {out_dir}/manifest.json ({len(manifest['artifacts'])} artifacts)")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument(
        "--paper-scale",
        action="store_true",
        default=os.environ.get("SAGIPS_PAPER_SCALE") == "1",
        help="also export the full Table III configuration (B=1024, E=100)",
    )
    ap.add_argument("--only", nargs="*", help="subset of artifact names")
    args = ap.parse_args()
    export_all(args.out, paper_scale=args.paper_scale, only=args.only)


if __name__ == "__main__":
    main()
