"""AOT export tests: manifest schema, HLO text validity, shape agreement.

These protect the Python->Rust interface: the Rust runtime trusts
manifest.json blindly, so the manifest must describe exactly what the HLO
artifacts compute.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model, nets, pipeline

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def exported(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    only = ["gan_step_small_b16_e25", "gen_predict_small_k256", "pipeline_b64_e25"]
    aot.export_all(str(out), only=only)
    with open(out / "manifest.json") as f:
        manifest = json.load(f)
    return out, manifest


def test_manifest_schema(exported):
    out, m = exported
    assert m["version"] == 1
    assert m["latent_dim"] == model.LATENT_DIM
    assert m["true_params"] == pipeline.TRUE_PARAMS
    for size, meta in m["models"].items():
        gen_dims, disc_dims = model.model_dims(size)
        assert meta["gen_param_count"] == nets.param_count(gen_dims)
        assert meta["disc_param_count"] == nets.param_count(disc_dims)
        assert len(meta["gen_layout"]) == len(gen_dims)
    for name, art in m["artifacts"].items():
        path = out / art["file"]
        assert path.exists(), name
        assert art["inputs"] and art["outputs"]


def test_hlo_text_is_parseable_entry_module(exported):
    out, m = exported
    for art in m["artifacts"].values():
        text = (out / art["file"]).read_text()
        assert text.startswith("HloModule"), art["file"]
        assert "ENTRY" in text


def test_manifest_shapes_match_artifact_parameters(exported):
    """Every manifest input appears as a parameter of matching shape in the
    HLO entry computation."""
    out, m = exported
    for art in m["artifacts"].values():
        text = (out / art["file"]).read_text()
        entry = text[text.index("ENTRY") :]
        for i, inp in enumerate(art["inputs"]):
            dims = ",".join(str(d) for d in inp["shape"])
            token = f"f32[{dims}]" if inp["shape"] else "f32[]"
            line = next(l for l in entry.splitlines() if f"parameter({i})" in l)
            assert token in line, (art["file"], inp, line)


def test_exported_gan_step_matches_eager(exported):
    """Run the lowered computation via jax and compare against eager."""
    out, m = exported
    art = m["artifacts"]["gan_step_small_b16_e25"]
    gen_dims, disc_dims = model.model_dims("small")
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    args = [
        jax.random.normal(ks[0], (m["models"]["small"]["gen_param_count"],)) * 0.3,
        jax.random.normal(ks[1], (m["models"]["small"]["disc_param_count"],)) * 0.3,
        jax.random.normal(ks[2], (16, model.LATENT_DIM)),
        jax.random.uniform(ks[3], (16, 25, 2)),
        jax.random.normal(ks[4], (400, 2)),
    ]
    import functools

    fn = functools.partial(model.gan_step, gen_dims=gen_dims, disc_dims=disc_dims)
    eager = fn(*args)
    jitted = jax.jit(fn)(*args)
    for a, b in zip(eager, jitted):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=5e-4, atol=5e-5)


def test_default_export_grid_contents():
    exports = aot.default_exports(paper_scale=False)
    # weak-scaling grid present
    for b in (4, 8, 16, 32, 64):
        assert f"gan_step_paper_b{b}_e25" in exports
    # fig8 grid present
    for size in ("small", "medium"):
        for b in (16, 64):
            assert f"gan_step_{size}_b{b}_e25" in exports
    assert "pipeline_b256_e25" in exports
    # paper scale adds Table III config
    full = aot.default_exports(paper_scale=True)
    assert "gan_step_paper_b1024_e100" in full
