"""Kernel vs oracle: the core L1 correctness signal.

Hypothesis sweeps shapes (including the awkward weak-scaling batch sizes
from eq (10) of the paper: 51, 17, ...) and dtypes; every case asserts the
Pallas kernel matches the pure-jnp reference to float tolerance, for both
the forward value and the custom_vjp gradients.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import nets
from compile.kernels import fused_mlp, quantile, ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# fused_mlp
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    b=st.sampled_from([1, 2, 5, 8, 16, 17, 51, 64, 100, 128, 257]),
    d_in=st.sampled_from([1, 2, 6, 16, 32, 154]),
    d_out=st.sampled_from([1, 6, 32, 154, 157]),
    activate=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_mlp_matches_ref(b, d_in, d_out, activate, seed):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = rand(k1, (b, d_in))
    w = rand(k2, (d_in, d_out), scale=0.5)
    bias = rand(k3, (d_out,))
    got = fused_mlp.fused_linear_act(x, w, bias, 0.2, activate)
    want = ref.fused_linear_act(x, w, bias, 0.2, activate)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(
    b=st.sampled_from([2, 8, 17, 64]),
    d_in=st.sampled_from([4, 16]),
    d_out=st.sampled_from([3, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_mlp_grads_match_ref(b, d_in, d_out, seed):
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = rand(k1, (b, d_in))
    w = rand(k2, (d_in, d_out), scale=0.5)
    bias = rand(k3, (d_out,))
    ct = rand(k4, (b, d_out))

    def loss_kernel(x, w, bias):
        return jnp.sum(fused_mlp.fused_linear_act(x, w, bias, 0.2, True) * ct)

    def loss_ref(x, w, bias):
        return jnp.sum(ref.fused_linear_act(x, w, bias, 0.2, True) * ct)

    g_k = jax.grad(loss_kernel, argnums=(0, 1, 2))(x, w, bias)
    g_r = jax.grad(loss_ref, argnums=(0, 1, 2))(x, w, bias)
    for a, b_ in zip(g_k, g_r):
        np.testing.assert_allclose(a, b_, rtol=1e-4, atol=1e-4)


def test_fused_mlp_gradcheck_finite_differences():
    """custom_vjp backward vs central finite differences."""
    key = jax.random.PRNGKey(7)
    k1, k2, k3 = jax.random.split(key, 3)
    x = rand(k1, (4, 5), dtype=jnp.float32)
    w = rand(k2, (5, 3), scale=0.5)
    bias = rand(k3, (3,))

    def f(w):
        return jnp.sum(jnp.tanh(fused_mlp.fused_linear_act(x, w, bias, 0.2, True)))

    g = np.asarray(jax.grad(f)(w))
    eps = 1e-3
    w_np = np.asarray(w, dtype=np.float64)
    for idx in [(0, 0), (2, 1), (4, 2)]:
        wp = w_np.copy()
        wp[idx] += eps
        wm = w_np.copy()
        wm[idx] -= eps
        fd = (float(f(jnp.asarray(wp, jnp.float32))) - float(f(jnp.asarray(wm, jnp.float32)))) / (2 * eps)
        assert abs(fd - g[idx]) < 5e-2, (idx, fd, g[idx])


def test_fused_mlp_block_picker():
    # Largest divisor <= _MAX_BLOCK_B; small batches are a single block.
    assert fused_mlp._pick_block(1024) == 512
    assert fused_mlp._pick_block(1600) == 400
    assert fused_mlp._pick_block(64) == 64
    assert fused_mlp._pick_block(17) == 17  # prime batch -> single block
    assert fused_mlp._pick_block(51) == 51
    for b in (1024, 1600, 102400, 1275):
        blk = fused_mlp._pick_block(b)
        assert b % blk == 0 and blk <= max(b, fused_mlp._MAX_BLOCK_B)


def test_fused_mlp_vmem_and_mxu_metrics():
    # §Perf metrics are sane: paper-size layer fits VMEM, utilization known.
    assert fused_mlp.vmem_footprint_bytes(1024, 154, 154) < 16 * 2**20
    util = fused_mlp.mxu_tile_utilization(154, 154)
    assert 0 < util <= 1
    assert abs(util - (154 * 154) / (256 * 256)) < 1e-9


# ---------------------------------------------------------------------------
# quantile sampler
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    b=st.sampled_from([1, 3, 8, 17, 51, 64, 100]),
    e=st.sampled_from([1, 4, 25, 100]),
    seed=st.integers(0, 2**31 - 1),
)
def test_quantile_matches_ref(b, e, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    p = rand(k1, (b, 6))
    u = jax.random.uniform(k2, (b, e, 2))
    got = quantile.quantile_sample(p, u)
    want = ref.quantile_eval(p, u)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(
    b=st.sampled_from([2, 8, 17]),
    e=st.sampled_from([4, 25]),
    seed=st.integers(0, 2**31 - 1),
)
def test_quantile_grads_match_ref(b, e, seed):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    p = rand(k1, (b, 6))
    u = jax.random.uniform(k2, (b, e, 2))
    ct = rand(k3, (b, e, 2))

    def loss_kernel(p, u):
        return jnp.sum(quantile.quantile_sample(p, u) * ct)

    def loss_ref(p, u):
        return jnp.sum(ref.quantile_eval(p, u) * ct)

    g_k = jax.grad(loss_kernel, argnums=(0, 1))(p, u)
    g_r = jax.grad(loss_ref, argnums=(0, 1))(p, u)
    for a, b_ in zip(g_k, g_r):
        np.testing.assert_allclose(a, b_, rtol=1e-4, atol=1e-4)


def test_quantile_monotone_for_true_params():
    """The quantile must be a valid inverse CDF at the loop-closure truth."""
    from compile.pipeline import TRUE_PARAMS

    u = jnp.linspace(0.0, 1.0, 101)[None, :, None].repeat(2, axis=2)
    p = jnp.asarray([TRUE_PARAMS])
    y = ref.quantile_eval(p, u)
    d0 = jnp.diff(y[0, :, 0])
    d1 = jnp.diff(y[0, :, 1])
    assert bool(jnp.all(d0 > 0)) and bool(jnp.all(d1 > 0))


def test_quantile_dtype_bf16_close():
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    p = rand(k1, (8, 6)).astype(jnp.bfloat16).astype(jnp.float32)
    u = jax.random.uniform(k2, (8, 25, 2))
    got = quantile.quantile_sample(p, u)
    want = ref.quantile_eval(p, u)
    np.testing.assert_allclose(got, want, rtol=1e-2, atol=1e-2)
