"""L2 model tests: MLP plumbing, pipeline, losses, full gan_step numerics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import losses, model, nets, pipeline

jax.config.update("jax_platform_name", "cpu")


def _flat_params(key, dims, scale=0.3):
    n = nets.param_count(dims)
    return jax.random.normal(key, (n,)) * scale


# ---------------------------------------------------------------------------
# nets
# ---------------------------------------------------------------------------


def test_param_counts_match_paper_within_tolerance():
    gen_dims, disc_dims = model.model_dims("paper")
    pg = nets.param_count(gen_dims)
    pd = nets.param_count(disc_dims)
    # Paper: 51,206 generator / 50,049 discriminator parameters.
    assert abs(pg - 51206) / 51206 < 0.005, pg
    assert abs(pd - 50049) / 50049 < 0.005, pd


def test_layer_layout_covers_flat_vector_exactly():
    for size in model.MODEL_SIZES:
        gen_dims, disc_dims = model.model_dims(size)
        for dims in (gen_dims, disc_dims):
            layout = nets.layer_layout(dims)
            off = 0
            for d, lay in zip(dims, layout):
                assert lay["w_offset"] == off
                assert lay["w_shape"] == [d[0], d[1]]
                off += d[0] * d[1]
                assert lay["b_offset"] == off
                off += d[1]
                assert lay["b_len"] == d[1]
            assert off == nets.param_count(dims)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), b=st.sampled_from([1, 7, 32]))
def test_mlp_apply_pallas_matches_jnp(seed, b):
    dims = nets.mlp_dims([16, 20, 12, 6])
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    flat = _flat_params(k1, dims)
    x = jax.random.normal(k2, (b, 16))
    got = nets.mlp_apply(flat, dims, x)
    want = nets.mlp_apply_ref(flat, dims, x)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_unflatten_roundtrip():
    dims = nets.mlp_dims([4, 3, 2])
    flat = jnp.arange(nets.param_count(dims), dtype=jnp.float32)
    layers = nets.unflatten(flat, dims)
    rebuilt = jnp.concatenate([jnp.concatenate([w.ravel(), b]) for w, b in layers])
    np.testing.assert_array_equal(flat, rebuilt)


# ---------------------------------------------------------------------------
# pipeline
# ---------------------------------------------------------------------------


def test_pipeline_shapes_and_flatten_order():
    p = jnp.tile(jnp.asarray([pipeline.TRUE_PARAMS]), (3, 1))
    u = jnp.zeros((3, 5, 2))
    ev = pipeline.pipeline_apply_ref(p, u)
    assert ev.shape == (15, 2)
    # u=0 -> y = (p0, p3) for every event
    np.testing.assert_allclose(ev[:, 0], 1.0, atol=1e-6)
    np.testing.assert_allclose(ev[:, 1], -0.5, atol=1e-6)


def test_pipeline_pallas_matches_ref():
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    p = jax.random.normal(k1, (16, 6))
    u = jax.random.uniform(k2, (16, 25, 2))
    np.testing.assert_allclose(
        pipeline.pipeline_apply(p, u),
        pipeline.pipeline_apply_ref(p, u),
        rtol=1e-5,
        atol=1e-6,
    )


def test_pipeline_event_statistics_at_truth():
    """Closed-form moments of the quantile distribution at p*.

    For y = a + b*u + c*u^2 with u ~ U(0,1): E[y] = a + b/2 + c/3.
    """
    key = jax.random.PRNGKey(1)
    p = jnp.tile(jnp.asarray([pipeline.TRUE_PARAMS]), (64, 1))
    u = jax.random.uniform(key, (64, 400, 2))
    ev = pipeline.pipeline_apply_ref(p, u)
    a, b, c = pipeline.TRUE_PARAMS[0:3]
    assert abs(float(ev[:, 0].mean()) - (a + b / 2 + c / 3)) < 2e-2
    a, b, c = pipeline.TRUE_PARAMS[3:6]
    assert abs(float(ev[:, 1].mean()) - (a + b / 2 + c / 3)) < 2e-2


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------


def test_losses_at_uninformative_discriminator():
    zeros = jnp.zeros((10,))
    # D(x) = 0 logits -> both losses are log(2) (and 2*log(2) for disc).
    assert abs(float(losses.gen_loss(zeros)) - np.log(2)) < 1e-6
    assert abs(float(losses.disc_loss(zeros, zeros)) - 2 * np.log(2)) < 1e-6


def test_disc_loss_rewards_separation():
    good = losses.disc_loss(jnp.full((8,), 5.0), jnp.full((8,), -5.0))
    bad = losses.disc_loss(jnp.full((8,), -5.0), jnp.full((8,), 5.0))
    assert float(good) < float(bad)


def test_softplus_stability():
    big = losses.softplus(jnp.asarray([100.0, -100.0]))
    assert np.isfinite(np.asarray(big)).all()
    assert abs(float(big[0]) - 100.0) < 1e-4
    assert float(big[1]) < 1e-6


# ---------------------------------------------------------------------------
# gan_step
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def small_setup():
    gen_dims, disc_dims = model.model_dims("small")
    key = jax.random.PRNGKey(42)
    ks = jax.random.split(key, 5)
    gen = _flat_params(ks[0], gen_dims)
    disc = _flat_params(ks[1], disc_dims)
    z = jax.random.normal(ks[2], (8, model.LATENT_DIM))
    u = jax.random.uniform(ks[3], (8, 25, 2))
    real = jax.random.normal(ks[4], (200, 2))
    return gen_dims, disc_dims, gen, disc, z, u, real


def test_gan_step_shapes_and_finite(small_setup):
    gen_dims, disc_dims, gen, disc, z, u, real = small_setup
    gg, dg, gl, dl = model.gan_step(
        gen, disc, z, u, real, gen_dims=gen_dims, disc_dims=disc_dims
    )
    assert gg.shape == gen.shape and dg.shape == disc.shape
    for t in (gg, dg, gl, dl):
        assert np.isfinite(np.asarray(t)).all()


def test_gan_step_grads_nonzero(small_setup):
    gen_dims, disc_dims, gen, disc, z, u, real = small_setup
    gg, dg, _, _ = model.gan_step(
        gen, disc, z, u, real, gen_dims=gen_dims, disc_dims=disc_dims
    )
    assert float(jnp.abs(gg).max()) > 0
    assert float(jnp.abs(dg).max()) > 0


def test_gan_step_gen_grads_do_not_touch_disc(small_setup):
    """Generator loss differentiates only generator params: perturbing the
    discriminator's flat vector changes g_grads only through D's forward."""
    gen_dims, disc_dims, gen, disc, z, u, real = small_setup
    gg1, _, gl1, _ = model.gan_step(
        gen, disc, z, u, real, gen_dims=gen_dims, disc_dims=disc_dims
    )
    # same inputs -> deterministic
    gg2, _, gl2, _ = model.gan_step(
        gen, disc, z, u, real, gen_dims=gen_dims, disc_dims=disc_dims
    )
    np.testing.assert_array_equal(np.asarray(gg1), np.asarray(gg2))
    assert float(gl1) == float(gl2)


def test_gan_step_descent_direction(small_setup):
    """A small step against the generator gradient reduces the generator
    loss — end-to-end differentiability through pipeline + discriminator."""
    gen_dims, disc_dims, gen, disc, z, u, real = small_setup
    gg, _, gl0, _ = model.gan_step(
        gen, disc, z, u, real, gen_dims=gen_dims, disc_dims=disc_dims
    )
    gen2 = gen - 1e-2 * gg / (jnp.linalg.norm(gg) + 1e-12)
    _, _, gl1, _ = model.gan_step(
        gen2, disc, z, u, real, gen_dims=gen_dims, disc_dims=disc_dims
    )
    assert float(gl1) < float(gl0)


def test_gan_step_disc_descent_direction(small_setup):
    gen_dims, disc_dims, gen, disc, z, u, real = small_setup
    _, dg, _, dl0 = model.gan_step(
        gen, disc, z, u, real, gen_dims=gen_dims, disc_dims=disc_dims
    )
    disc2 = disc - 1e-2 * dg / (jnp.linalg.norm(dg) + 1e-12)
    _, _, _, dl1 = model.gan_step(
        gen, disc, z, u, real, gen_dims=gen_dims, disc_dims=disc_dims
    )
    _, _, _, dl1 = model.gan_step(
        gen, disc2, z, u, real, gen_dims=gen_dims, disc_dims=disc_dims
    )
    assert float(dl1) < float(dl0)
