"""The optimized (shared-forward) gan_step vs the naive two-grad oracle.

The §Perf L2 change rewires the step through explicit `jax.vjp` sharing;
these tests pin (a) numerical equivalence and (b) the structural win (the
lowered HLO contains strictly fewer Pallas grid loops).
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import aot, model, nets

jax.config.update("jax_platform_name", "cpu")


def _setup(size, b, e, seed):
    gen_dims, disc_dims = model.model_dims(size)
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    gen = jax.random.normal(ks[0], (nets.param_count(gen_dims),)) * 0.3
    disc = jax.random.normal(ks[1], (nets.param_count(disc_dims),)) * 0.3
    z = jax.random.normal(ks[2], (b, model.LATENT_DIM))
    u = jax.random.uniform(ks[3], (b, e, 2))
    real = jax.random.normal(ks[4], (b * e, 2))
    return gen_dims, disc_dims, (gen, disc, z, u, real)


@settings(max_examples=8, deadline=None)
@given(
    b=st.sampled_from([4, 16, 17]),
    e=st.sampled_from([5, 25]),
    seed=st.integers(0, 2**31 - 1),
)
def test_optimized_matches_naive(b, e, seed):
    gen_dims, disc_dims, args = _setup("small", b, e, seed)
    kw = dict(gen_dims=gen_dims, disc_dims=disc_dims)
    a = model.gan_step_naive(*args, **kw)
    o = model.gan_step(*args, **kw)
    for x, y in zip(a, o):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=5e-4, atol=1e-5)


def test_optimized_lowered_hlo_has_fewer_grid_loops():
    gen_dims, disc_dims, args = _setup("small", 16, 25, 0)
    kw = dict(gen_dims=gen_dims, disc_dims=disc_dims)
    specs = [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in args]

    def count_whiles(fn):
        lowered = jax.jit(functools.partial(fn, **kw)).lower(*specs)
        return aot.to_hlo_text(lowered).count(" while(")

    naive = count_whiles(model.gan_step_naive)
    opt = count_whiles(model.gan_step)
    # Shared forwards: gen fwd (x1 instead of x2), pipeline (x1 instead of
    # x2), disc-fake fwd (x1 instead of x2).
    assert opt < naive, f"optimized {opt} vs naive {naive}"
    n_layers = len(gen_dims) + 1 + len(disc_dims) * 2  # one epoch of fwds
    assert opt <= n_layers + len(disc_dims), (opt, n_layers)


def test_exported_artifact_uses_optimized_step():
    """aot exports `model.gan_step` (the optimized one)."""
    fn, _, _ = aot.gan_step_export("small", 8, 5)
    assert fn.func is model.gan_step
