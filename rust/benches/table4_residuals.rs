//! Bench: regenerate Table IV — final normalized residuals ±1σ for
//! horovod / RMA-ARAR / ARAR / conventional ARAR ensembles (8 ranks),
//! printed next to the paper's reported numbers.

use std::path::Path;

use sagips::report::experiments::{fig13_tab4, Scale};
use sagips::report::{format_table4, table4_paper_reference, Table4Row};
use sagips::runtime::RuntimePool;

fn main() {
    sagips::util::logging::init_from_env();
    let scale = Scale::from_env(Scale::smoke());
    let pool = RuntimePool::from_dir(Path::new("artifacts"), 3).expect("run `make artifacts`");
    let t0 = std::time::Instant::now();
    let rows = fig13_tab4(&pool.handle(), &scale).expect("tab4");
    let mut table: Vec<Table4Row> = rows
        .iter()
        .map(|(mode, _, raw)| Table4Row::from_raw(&format!("{} (ours)", mode.name()), raw))
        .collect();
    table.extend(table4_paper_reference());
    println!("\n{}", format_table4(&table));
    println!(
        "table4 regenerated in {:.1}s (scale: {} members x {} epochs; \
         SAGIPS_SCALE=paper for the full 20 x 100k configuration)",
        t0.elapsed().as_secs_f64(),
        scale.ensemble_m,
        scale.epochs
    );
    pool.shutdown();
}
