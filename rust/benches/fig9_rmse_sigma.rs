//! Bench: regenerate Fig 9 — RMSE vs spread contours over ensemble sizes.

use std::path::Path;

use sagips::report::experiments::{fig9, Scale};
use sagips::runtime::RuntimePool;

fn main() {
    sagips::util::logging::init_from_env();
    let scale = Scale::from_env(Scale::smoke());
    let pool = RuntimePool::from_dir(Path::new("artifacts"), 3).expect("run `make artifacts`");
    let t0 = std::time::Instant::now();
    let out = fig9(&pool.handle(), &scale).expect("fig9");
    println!("\nfig9 regenerated in {:.1}s", t0.elapsed().as_secs_f64());
    // Paper shape: the largest M has tighter dispersion than the smallest.
    let first = out.first().unwrap();
    let last = out.last().unwrap();
    println!(
        "M={}: semi_rmse={:.4}  ->  M={}: semi_rmse={:.4} (should shrink)",
        first.m, first.semi_rmse, last.m, last.semi_rmse
    );
    pool.shutdown();
}
