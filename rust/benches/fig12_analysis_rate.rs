//! Bench: regenerate Fig 12 — the analysis rate (eq 9) vs ranks, with the
//! single-GPU reference line and the 4->400 gain factors.

use sagips::report::experiments::fig12;
use sagips::sim::ComputeModel;

fn main() {
    sagips::util::logging::init_from_env();
    let compute = ComputeModel::with_jitter(0.035, 0.15);
    let out = fig12(compute);

    let conv_gain = out[0].2;
    let grp_gain = out[1].2;
    let rma_gain = out[2].2;
    println!("\nmeasured gains 4->400: conv {conv_gain:.0}x, grouped {grp_gain:.0}x, rma {rma_gain:.0}x");
    println!("paper: conv ≈ 40x; grouping doubles the gain (≈ 80x)");
    assert!(
        (10.0..100.0).contains(&conv_gain),
        "conventional gain out of band: {conv_gain}"
    );
    assert!(grp_gain > 1.5 * conv_gain, "grouping must ~double the gain");
    assert!(rma_gain > 1.5 * conv_gain);
}
