//! Micro-bench: the bounded-staleness exchange window.
//!
//! Sweeps the window depth k ∈ {0, 1, 2, 4} over ranks × chunk policies
//! on a real in-process ring (zero-latency links, spin compute standing
//! in for `gan_step`): k = 0 is the paper's blocking exchange, k = 1 the
//! classic overlap, deeper windows the Async-RED-style bounded-staleness
//! pipeline. Reports per-epoch wall time and the hot-path comm the
//! trainer actually blocked on (the acceptance metric: hot comm must
//! shrink as k grows), and emits `BENCH_overlap.json` so the perf
//! trajectory has an overlap row next to BENCH_runtime.json.

use std::time::{Duration, Instant};

use sagips::collective::engine::CollectiveEngine;
use sagips::collective::ring::ConvArar;
use sagips::collective::Collective;
use sagips::comm::{LinkModel, LocalNetwork, Topology};
use sagips::config::ChunkPolicy;
use sagips::util::bench::fmt_dur;
use sagips::util::json::{arr, num, obj, s, Value};

/// Paper-sized gradient payload (~51k weight gradients).
const GRAD: usize = 51_206;
/// Spin-compute per epoch standing in for a gan_step execution.
const COMPUTE_US: u64 = 300;

fn fake_compute(us: u64) {
    let t0 = Instant::now();
    while t0.elapsed() < Duration::from_micros(us) {
        std::hint::black_box((0..64).sum::<u64>());
    }
}

/// One sweep point: n ranks, one chunk policy, window depth k. Returns
/// rank 0's (per-epoch wall, per-epoch hot comm).
fn bench_window(n: usize, policy: ChunkPolicy, k: usize, iters: usize) -> (Duration, Duration) {
    let topo = Topology::new(n, 4);
    let eps = LocalNetwork::build(&topo, LinkModel::zero());
    let mut handles = Vec::new();
    for ep in eps {
        handles.push(std::thread::spawn(move || {
            let rank = ep.rank;
            let mut grads = vec![1.0f32; GRAD];
            let mut hot = Duration::ZERO;
            let total;
            if k == 0 {
                // Blocking: compute then reduce, serially.
                let mut coll = ConvArar::with_policy(ep, policy);
                let t0 = Instant::now();
                for e in 0..iters {
                    fake_compute(COMPUTE_US);
                    let tc = Instant::now();
                    coll.epoch_reduce(e as u64, &mut grads).unwrap();
                    hot += tc.elapsed();
                }
                total = t0.elapsed();
            } else {
                // k-deep window: keep up to k reduces in flight, collect
                // FIFO when full, drain at the end — the rank pipeline's
                // schedule.
                let inner = Box::new(ConvArar::with_policy(ep, policy));
                let mut eng = CollectiveEngine::spawn_windowed(inner, k).unwrap();
                let t0 = Instant::now();
                for e in 0..iters {
                    fake_compute(COMPUTE_US);
                    let tc = Instant::now();
                    while eng.in_flight() >= k {
                        let (buf, _) = eng.wait_reduce().unwrap();
                        grads.copy_from_slice(&buf);
                    }
                    eng.start_reduce(e as u64, grads.clone()).unwrap();
                    hot += tc.elapsed();
                }
                let tc = Instant::now();
                for (buf, _) in eng.drain().unwrap() {
                    grads.copy_from_slice(&buf);
                }
                hot += tc.elapsed();
                total = t0.elapsed();
            }
            if rank == 0 {
                Some((total / iters as u32, hot / iters as u32))
            } else {
                None
            }
        }));
    }
    let mut out = None;
    for h in handles {
        if let Some(pair) = h.join().unwrap() {
            out = Some(pair);
        }
    }
    out.expect("rank 0 reports")
}

fn main() {
    println!("\n=== overlap micro-bench — staleness sweep (51k f32, {COMPUTE_US}µs compute) ===");
    println!(
        "{:<40} {:>12} {:>14}",
        "configuration", "epoch", "hot comm"
    );
    println!("{}", "-".repeat(68));

    let mut rows: Vec<Value> = Vec::new();
    for n in [4usize, 8] {
        for policy in [ChunkPolicy::Unchunked, ChunkPolicy::Auto] {
            for k in [0usize, 1, 2, 4] {
                let iters = if n >= 8 { 80 } else { 150 };
                let (epoch_d, hot_d) = bench_window(n, policy, k, iters);
                println!(
                    "{:<40} {:>12} {:>14}",
                    format!("n={n} {} k={k}", policy.label()),
                    fmt_dur(epoch_d),
                    fmt_dur(hot_d)
                );
                rows.push(obj(vec![
                    ("ranks", num(n as f64)),
                    ("chunking", s(policy.label())),
                    ("staleness", num(k as f64)),
                    ("epoch_us", num(epoch_d.as_secs_f64() * 1e6)),
                    ("hot_comm_us", num(hot_d.as_secs_f64() * 1e6)),
                ]));
            }
            println!();
        }
    }

    let doc = obj(vec![
        ("bench", s("micro_overlap")),
        ("grad_elems", num(GRAD as f64)),
        ("compute_us", num(COMPUTE_US as f64)),
        ("rows", arr(rows)),
    ]);
    std::fs::write("BENCH_overlap.json", doc.to_json_pretty()).expect("write BENCH_overlap.json");
    println!("wrote BENCH_overlap.json");
}
