//! Micro-bench: runtime execution costs per artifact — the `gan_step`
//! hot path at each batch size on the **native** backend (always) and the
//! PJRT pool (when artifacts exist) — plus gen_predict and the pipeline.
//! These calibrate the simulator's compute model and are the L2/L3 §Perf
//! baseline in EXPERIMENTS.md.
//!
//! Emits `BENCH_runtime.json` next to the working directory: one row per
//! (backend, artifact) with p50/p90/mean micros, GEMM GFLOP/s (counted by
//! [`sagips::runtime::kernels::gan_step_flops`]), generated events/sec,
//! and, for the native backend, the measured steady-state allocations per
//! call — the zero-copy claim (`inputs borrowed, outputs reused`) as a
//! number. The headline rows are the kernel/parallelism trajectory:
//! serial scalar kernels vs blocked kernels vs blocked + 4 intra-rank
//! worker threads, on the quantile and deconv scenarios.
//!
//! `SAGIPS_BENCH_BUDGET_MS` shrinks the per-bench time budget so CI smoke
//! runs finish in milliseconds while still exercising every row.

use std::alloc::{GlobalAlloc, Layout, System};
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use sagips::model::gan::GanState;
use sagips::runtime::kernels::gan_step_flops;
use sagips::runtime::{Kernels, Manifest, NativeOptions, NativeRuntime, RuntimeHandle, RuntimePool};
use sagips::util::bench::{bench_for, fmt_dur, header, BenchResult};
use sagips::util::json::Value;
use sagips::util::rng::Rng;

/// Counting allocator: lets the bench *prove* the native hot path is
/// allocation-free instead of asserting it in prose.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
    unsafe fn realloc(&self, p: *mut u8, l: Layout, n: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(p, l, n)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

/// Per-bench time budget: `SAGIPS_BENCH_BUDGET_MS` overrides the 2 s
/// default so CI smoke runs finish in milliseconds.
fn budget_from_env() -> Duration {
    let ms = std::env::var("SAGIPS_BENCH_BUDGET_MS")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(2000);
    Duration::from_millis(ms.max(1))
}

/// The three execution modes the perf trajectory compares.
fn modes() -> [(&'static str, NativeOptions); 3] {
    let scalar = NativeOptions { kernels: Kernels::Scalar, ..NativeOptions::default() };
    let intra4 = NativeOptions { intra_threads: 4, ..NativeOptions::default() };
    [("serial-scalar", scalar), ("serial-blocked", NativeOptions::default()), ("intra4", intra4)]
}

/// Seeded `gan_step` inputs shaped by the manifest's scenario.
fn gan_inputs(
    m: &Manifest,
    batch: usize,
    events: usize,
) -> (GanState, Vec<f32>, Vec<f32>, Vec<f32>) {
    let sc = m.scenario_impl().expect("registered scenario");
    let meta = m.model("paper").unwrap();
    let mut rng = Rng::new(7);
    let state = GanState::init(meta, m.leaky_slope, &mut rng);
    let mut z = vec![0.0f32; batch * m.latent_dim];
    let mut u = vec![0.0f32; batch * events * sc.noise_dim()];
    let real = vec![0.3f32; batch * events * sc.event_dim()];
    rng.fill_normal(&mut z);
    rng.fill_uniform(&mut u);
    (state, z, u, real)
}

/// The shared timing columns of one JSON row; callers append throughput
/// or allocation columns before wrapping in `Value::Object`.
fn base_row(
    backend: &str,
    artifact: &str,
    batch: usize,
    r: &BenchResult,
) -> BTreeMap<String, Value> {
    let mut m = BTreeMap::new();
    m.insert("backend".into(), Value::String(backend.into()));
    m.insert("artifact".into(), Value::String(artifact.into()));
    m.insert("batch".into(), Value::Number(batch as f64));
    m.insert("p50_us".into(), Value::Number(r.p50.as_secs_f64() * 1e6));
    m.insert("p90_us".into(), Value::Number(r.p90.as_secs_f64() * 1e6));
    m.insert("mean_us".into(), Value::Number(r.mean.as_secs_f64() * 1e6));
    m.insert("iters".into(), Value::Number(r.iters as f64));
    m
}

/// Bench the zero-copy gan_step path on one handle; appends a JSON row.
fn bench_gan_step(
    h: &RuntimeHandle,
    backend: &str,
    batch: usize,
    budget: Duration,
    rows: &mut Vec<Value>,
) {
    let name = format!("gan_step_paper_b{batch}_e25");
    if h.manifest().artifact(&name).is_err() {
        return;
    }
    let m = h.manifest();
    let flops = gan_step_flops(m.model("paper").unwrap(), batch, 25);
    let (state, z, u, real) = gan_inputs(m, batch, 25);
    let inputs: [&[f32]; 5] = [&state.gen, &state.disc, &z, &u, &real];
    let mut outputs: Vec<Vec<f32>> = Vec::new();
    // Warm: first call compiles (PJRT) / sizes the scratch (native).
    h.execute_into(&name, &inputs, &mut outputs).unwrap();
    h.execute_into(&name, &inputs, &mut outputs).unwrap();

    // Steady-state allocation count over 10 calls (meaningful on the
    // native backend; the PJRT path stages channel copies by design).
    let before = allocs();
    for _ in 0..10 {
        h.execute_into(&name, &inputs, &mut outputs).unwrap();
    }
    let per_call = (allocs() - before) / 10;

    let r = bench_for(
        &format!("[{backend}] gan_step b={batch} (disc batch {})", batch * 25),
        2,
        budget,
        || {
            h.execute_into(&name, &inputs, &mut outputs).unwrap();
            std::hint::black_box(&outputs);
        },
    );
    println!("{}", r.row());
    if backend == "native" {
        println!("    steady-state allocations/call: {per_call}");
    }
    let secs = r.p50.as_secs_f64();
    let mut row = base_row(backend, &name, batch, &r);
    row.insert("gflops".into(), Value::Number(flops / secs / 1e9));
    row.insert("events_per_s".into(), Value::Number((batch * 25) as f64 / secs));
    if backend == "native" {
        row.insert("allocs_per_call".into(), Value::Number(per_call as f64));
    }
    rows.push(Value::Object(row));
}

/// The headline perf trajectory: scalar vs blocked kernels vs blocked
/// with 4 intra-rank worker threads, on the paper-sized `gan_step`.
/// GFLOP/s counts GEMM work only; events/s is generated events per
/// second of wall time (the paper's throughput unit).
fn bench_kernel_trajectory(scenario: &str, budget: Duration, rows: &mut Vec<Value>) {
    let m = Manifest::synthetic_for(scenario).expect("registered scenario");
    let (batch, events) = (64usize, 25usize);
    let name = format!("gan_step_paper_b{batch}_e{events}");
    let flops = gan_step_flops(m.model("paper").unwrap(), batch, events);
    let (state, z, u, real) = gan_inputs(&m, batch, events);
    let inputs: [&[f32]; 5] = [&state.gen, &state.disc, &z, &u, &real];

    header(&format!("gan_step kernel/parallelism trajectory — {scenario}"));
    let mut summary: Vec<(&'static str, Duration, f64, f64)> = Vec::new();
    for (mode, opts) in modes() {
        let rt = NativeRuntime::with_options(m.clone(), opts);
        let h = rt.handle();
        let mut outputs: Vec<Vec<f32>> = Vec::new();
        h.execute_into(&name, &inputs, &mut outputs).unwrap();
        let before = allocs();
        for _ in 0..10 {
            h.execute_into(&name, &inputs, &mut outputs).unwrap();
        }
        let per_call = (allocs() - before) / 10;
        let r = bench_for(&format!("[{scenario}] {mode} b={batch}"), 2, budget, || {
            h.execute_into(&name, &inputs, &mut outputs).unwrap();
            std::hint::black_box(&outputs);
        });
        println!("{}", r.row());
        let secs = r.p50.as_secs_f64();
        let gflops = flops / secs / 1e9;
        let eps = (batch * events) as f64 / secs;
        summary.push((mode, r.p50, gflops, eps));
        let mut row = base_row("native", &name, batch, &r);
        row.insert("scenario".into(), Value::String(scenario.into()));
        row.insert("mode".into(), Value::String(mode.into()));
        row.insert("gflops".into(), Value::Number(gflops));
        row.insert("events_per_s".into(), Value::Number(eps));
        row.insert("allocs_per_call".into(), Value::Number(per_call as f64));
        rows.push(Value::Object(row));
    }

    let base = summary[0].1.as_secs_f64();
    println!("\n--- {scenario}: gan_step paper b={batch} e={events} — mode comparison ---");
    println!("{:<16} {:>10} {:>12} {:>14} {:>9}", "mode", "p50", "GFLOP/s", "events/s", "speedup");
    for (mode, p50, gflops, eps) in &summary {
        let speedup = base / p50.as_secs_f64();
        println!("{mode:<16} {:>10} {gflops:>12.2} {eps:>14.0} {speedup:>8.2}x", fmt_dur(*p50));
    }
}

fn bench_forward_paths(h: &RuntimeHandle, backend: &str, budget: Duration, rows: &mut Vec<Value>) {
    let m = h.manifest();
    // gen_predict (the residual evaluator's cost).
    if m.artifact("gen_predict_paper_k256").is_ok() {
        let meta = m.model("paper").unwrap().clone();
        let mut rng = Rng::new(7);
        let state = GanState::init(&meta, m.leaky_slope, &mut rng);
        let mut z = vec![0.0f32; 256 * m.latent_dim];
        rng.fill_normal(&mut z);
        let inputs: [&[f32]; 2] = [&state.gen, &z];
        let mut outputs: Vec<Vec<f32>> = Vec::new();
        h.execute_into("gen_predict_paper_k256", &inputs, &mut outputs)
            .unwrap();
        let r = bench_for(&format!("[{backend}] gen_predict k=256"), 2, budget, || {
            h.execute_into("gen_predict_paper_k256", &inputs, &mut outputs)
                .unwrap();
            std::hint::black_box(&outputs);
        });
        println!("{}", r.row());
        rows.push(Value::Object(base_row(backend, "gen_predict_paper_k256", 256, &r)));
    }

    // pipeline alone (the sampler's cost).
    if m.artifact("pipeline_b256_e25").is_ok() {
        let params: Vec<f32> = (0..256).flat_map(|_| m.true_params.clone()).collect();
        let mut u = vec![0.0f32; 256 * 25 * 2];
        Rng::new(7).fill_uniform(&mut u);
        let inputs: [&[f32]; 2] = [&params, &u];
        let mut outputs: Vec<Vec<f32>> = Vec::new();
        h.execute_into("pipeline_b256_e25", &inputs, &mut outputs)
            .unwrap();
        let r = bench_for(
            &format!("[{backend}] pipeline b=256 e=25 (6400 events)"),
            2,
            budget,
            || {
                h.execute_into("pipeline_b256_e25", &inputs, &mut outputs)
                    .unwrap();
                std::hint::black_box(&outputs);
            },
        );
        println!("{}", r.row());
        rows.push(Value::Object(base_row(backend, "pipeline_b256_e25", 256, &r)));
    }
}

fn main() {
    sagips::util::logging::init_from_env();
    let budget = budget_from_env();
    let mut rows: Vec<Value> = Vec::new();

    // --- native backend: always available, no artifacts needed ---
    header("runtime micro-benches — native CPU backend (zero-copy)");
    let native = NativeRuntime::new(Manifest::synthetic());
    let nh = native.handle();
    for b in [4usize, 16, 64] {
        bench_gan_step(&nh, "native", b, budget, &mut rows);
    }
    bench_forward_paths(&nh, "native", budget / 2, &mut rows);

    // --- kernel/parallelism trajectory: the serial-scalar vs
    // serial-blocked vs intra4 comparison on two scenarios ---
    for scenario in ["quantile", "deconv"] {
        bench_kernel_trajectory(scenario, budget, &mut rows);
    }

    // --- PJRT pool: only when the artifact set has been exported ---
    let pjrt_available = Path::new("artifacts").join("manifest.json").exists();
    if pjrt_available {
        header("runtime micro-benches — PJRT pool (channel dispatch)");
        let pool = RuntimePool::from_dir(Path::new("artifacts"), 2).expect("pool start");
        let h = pool.handle();
        for b in [4usize, 16, 64] {
            bench_gan_step(&h, "pjrt", b, budget, &mut rows);
        }
        bench_forward_paths(&h, "pjrt", budget / 2, &mut rows);
        pool.shutdown();
    } else {
        println!("\n(PJRT rows skipped: artifacts/manifest.json not present)");
    }

    // --- BENCH_runtime.json: the perf trajectory artifact ---
    let mut doc = BTreeMap::new();
    doc.insert("bench".into(), Value::String("micro_runtime".into()));
    doc.insert("pjrt_available".into(), Value::Bool(pjrt_available));
    doc.insert("rows".into(), Value::Array(rows));
    let json = Value::Object(doc).to_json_pretty();
    std::fs::write("BENCH_runtime.json", &json).expect("write BENCH_runtime.json");
    println!("\nwrote BENCH_runtime.json");
}
