//! Micro-bench: PJRT runtime execution costs per artifact — gan_step at
//! each batch size, gen_predict, pipeline — plus pool dispatch overhead.
//! These calibrate the simulator's compute model and are the L2/L3 §Perf
//! baseline in EXPERIMENTS.md.

use std::path::Path;
use std::time::Duration;

use sagips::model::gan::GanState;
use sagips::runtime::RuntimePool;
use sagips::util::bench::{bench_for, header};
use sagips::util::rng::Rng;

fn main() {
    sagips::util::logging::init_from_env();
    let pool = RuntimePool::from_dir(Path::new("artifacts"), 2).expect("run `make artifacts`");
    let h = pool.handle();
    let m = h.manifest().clone();
    let meta = m.model("paper").unwrap().clone();
    let mut rng = Rng::new(7);
    let state = GanState::init(&meta, m.leaky_slope, &mut rng);

    header("runtime micro-benches (PJRT execute, CPU)");

    for b in [4usize, 16, 64] {
        let name = format!("gan_step_paper_b{b}_e25");
        if m.artifact(&name).is_err() {
            continue;
        }
        let mut z = vec![0.0f32; b * m.latent_dim];
        let mut u = vec![0.0f32; b * 25 * 2];
        let real = vec![0.3f32; b * 25 * 2];
        rng.fill_normal(&mut z);
        rng.fill_uniform(&mut u);
        // warm: first call compiles
        h.execute(
            &name,
            vec![state.gen.clone(), state.disc.clone(), z.clone(), u.clone(), real.clone()],
        )
        .unwrap();
        let r = bench_for(&format!("gan_step b={b} (disc batch {})", b * 25), 2, Duration::from_secs(2), || {
            std::hint::black_box(
                h.execute(
                    &name,
                    vec![
                        state.gen.clone(),
                        state.disc.clone(),
                        z.clone(),
                        u.clone(),
                        real.clone(),
                    ],
                )
                .unwrap(),
            );
        });
        println!("{}", r.row());
    }

    // gen_predict (the residual evaluator's cost).
    {
        let mut z = vec![0.0f32; 256 * m.latent_dim];
        rng.fill_normal(&mut z);
        h.execute("gen_predict_paper_k256", vec![state.gen.clone(), z.clone()])
            .unwrap();
        let r = bench_for("gen_predict k=256", 2, Duration::from_secs(1), || {
            std::hint::black_box(
                h.execute("gen_predict_paper_k256", vec![state.gen.clone(), z.clone()])
                    .unwrap(),
            );
        });
        println!("{}", r.row());
    }

    // pipeline alone (the sampler's cost).
    {
        let params: Vec<f32> = (0..256).flat_map(|_| m.true_params.clone()).collect();
        let mut u = vec![0.0f32; 256 * 25 * 2];
        rng.fill_uniform(&mut u);
        h.execute("pipeline_b256_e25", vec![params.clone(), u.clone()])
            .unwrap();
        let r = bench_for("pipeline b=256 e=25 (6400 events)", 2, Duration::from_secs(1), || {
            std::hint::black_box(
                h.execute("pipeline_b256_e25", vec![params.clone(), u.clone()])
                    .unwrap(),
            );
        });
        println!("{}", r.row());
    }

    pool.shutdown();
}
