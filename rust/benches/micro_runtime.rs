//! Micro-bench: runtime execution costs per artifact — the `gan_step`
//! hot path at each batch size on the **native** backend (always) and the
//! PJRT pool (when artifacts exist) — plus gen_predict and the pipeline.
//! These calibrate the simulator's compute model and are the L2/L3 §Perf
//! baseline in EXPERIMENTS.md.
//!
//! Emits `BENCH_runtime.json` next to the working directory: one row per
//! (backend, artifact) with p50/p90/mean micros and, for the native
//! backend, the measured steady-state allocations per call — the
//! zero-copy claim (`inputs borrowed, outputs reused`) as a number. The
//! file starts the native-vs-PJRT perf trajectory across PRs.

use std::alloc::{GlobalAlloc, Layout, System};
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use sagips::model::gan::GanState;
use sagips::runtime::{Manifest, NativeRuntime, RuntimeHandle, RuntimePool};
use sagips::util::bench::{bench_for, header, BenchResult};
use sagips::util::json::Value;
use sagips::util::rng::Rng;

/// Counting allocator: lets the bench *prove* the native hot path is
/// allocation-free instead of asserting it in prose.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
    unsafe fn realloc(&self, p: *mut u8, l: Layout, n: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(p, l, n)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

fn row_json(
    backend: &str,
    artifact: &str,
    batch: usize,
    r: &BenchResult,
    allocs_per_call: Option<u64>,
) -> Value {
    let mut m = BTreeMap::new();
    m.insert("backend".into(), Value::String(backend.into()));
    m.insert("artifact".into(), Value::String(artifact.into()));
    m.insert("batch".into(), Value::Number(batch as f64));
    m.insert("p50_us".into(), Value::Number(r.p50.as_secs_f64() * 1e6));
    m.insert("p90_us".into(), Value::Number(r.p90.as_secs_f64() * 1e6));
    m.insert("mean_us".into(), Value::Number(r.mean.as_secs_f64() * 1e6));
    m.insert("iters".into(), Value::Number(r.iters as f64));
    if let Some(a) = allocs_per_call {
        m.insert("allocs_per_call".into(), Value::Number(a as f64));
    }
    Value::Object(m)
}

/// Bench the zero-copy gan_step path on one handle; appends a JSON row.
fn bench_gan_step(
    h: &RuntimeHandle,
    backend: &str,
    batch: usize,
    budget: Duration,
    rows: &mut Vec<Value>,
) {
    let name = format!("gan_step_paper_b{batch}_e25");
    if h.manifest().artifact(&name).is_err() {
        return;
    }
    let m = h.manifest();
    let meta = m.model("paper").unwrap().clone();
    let mut rng = Rng::new(7);
    let state = GanState::init(&meta, m.leaky_slope, &mut rng);
    let mut z = vec![0.0f32; batch * m.latent_dim];
    let mut u = vec![0.0f32; batch * 25 * 2];
    let real = vec![0.3f32; batch * 25 * 2];
    rng.fill_normal(&mut z);
    rng.fill_uniform(&mut u);
    let inputs: [&[f32]; 5] = [&state.gen, &state.disc, &z, &u, &real];
    let mut outputs: Vec<Vec<f32>> = Vec::new();
    // Warm: first call compiles (PJRT) / sizes the scratch (native).
    h.execute_into(&name, &inputs, &mut outputs).unwrap();
    h.execute_into(&name, &inputs, &mut outputs).unwrap();

    // Steady-state allocation count over 10 calls (meaningful on the
    // native backend; the PJRT path stages channel copies by design).
    let before = allocs();
    for _ in 0..10 {
        h.execute_into(&name, &inputs, &mut outputs).unwrap();
    }
    let per_call = (allocs() - before) / 10;

    let r = bench_for(
        &format!("[{backend}] gan_step b={batch} (disc batch {})", batch * 25),
        2,
        budget,
        || {
            h.execute_into(&name, &inputs, &mut outputs).unwrap();
            std::hint::black_box(&outputs);
        },
    );
    println!("{}", r.row());
    if backend == "native" {
        println!("    steady-state allocations/call: {per_call}");
    }
    rows.push(row_json(
        backend,
        &name,
        batch,
        &r,
        (backend == "native").then_some(per_call),
    ));
}

fn bench_forward_paths(h: &RuntimeHandle, backend: &str, rows: &mut Vec<Value>) {
    let m = h.manifest();
    // gen_predict (the residual evaluator's cost).
    if m.artifact("gen_predict_paper_k256").is_ok() {
        let meta = m.model("paper").unwrap().clone();
        let mut rng = Rng::new(7);
        let state = GanState::init(&meta, m.leaky_slope, &mut rng);
        let mut z = vec![0.0f32; 256 * m.latent_dim];
        rng.fill_normal(&mut z);
        let inputs: [&[f32]; 2] = [&state.gen, &z];
        let mut outputs: Vec<Vec<f32>> = Vec::new();
        h.execute_into("gen_predict_paper_k256", &inputs, &mut outputs)
            .unwrap();
        let r = bench_for(
            &format!("[{backend}] gen_predict k=256"),
            2,
            Duration::from_secs(1),
            || {
                h.execute_into("gen_predict_paper_k256", &inputs, &mut outputs)
                    .unwrap();
                std::hint::black_box(&outputs);
            },
        );
        println!("{}", r.row());
        rows.push(row_json(backend, "gen_predict_paper_k256", 256, &r, None));
    }

    // pipeline alone (the sampler's cost).
    if m.artifact("pipeline_b256_e25").is_ok() {
        let params: Vec<f32> = (0..256).flat_map(|_| m.true_params.clone()).collect();
        let mut u = vec![0.0f32; 256 * 25 * 2];
        Rng::new(7).fill_uniform(&mut u);
        let inputs: [&[f32]; 2] = [&params, &u];
        let mut outputs: Vec<Vec<f32>> = Vec::new();
        h.execute_into("pipeline_b256_e25", &inputs, &mut outputs)
            .unwrap();
        let r = bench_for(
            &format!("[{backend}] pipeline b=256 e=25 (6400 events)"),
            2,
            Duration::from_secs(1),
            || {
                h.execute_into("pipeline_b256_e25", &inputs, &mut outputs)
                    .unwrap();
                std::hint::black_box(&outputs);
            },
        );
        println!("{}", r.row());
        rows.push(row_json(backend, "pipeline_b256_e25", 256, &r, None));
    }
}

fn main() {
    sagips::util::logging::init_from_env();
    let mut rows: Vec<Value> = Vec::new();

    // --- native backend: always available, no artifacts needed ---
    header("runtime micro-benches — native CPU backend (zero-copy)");
    let native = NativeRuntime::new(Manifest::synthetic());
    let nh = native.handle();
    for b in [4usize, 16, 64] {
        bench_gan_step(&nh, "native", b, Duration::from_secs(2), &mut rows);
    }
    bench_forward_paths(&nh, "native", &mut rows);

    // --- PJRT pool: only when the artifact set has been exported ---
    let pjrt_available = Path::new("artifacts").join("manifest.json").exists();
    if pjrt_available {
        header("runtime micro-benches — PJRT pool (channel dispatch)");
        let pool = RuntimePool::from_dir(Path::new("artifacts"), 2).expect("pool start");
        let h = pool.handle();
        for b in [4usize, 16, 64] {
            bench_gan_step(&h, "pjrt", b, Duration::from_secs(2), &mut rows);
        }
        bench_forward_paths(&h, "pjrt", &mut rows);
        pool.shutdown();
    } else {
        println!("\n(PJRT rows skipped: artifacts/manifest.json not present)");
    }

    // --- BENCH_runtime.json: the perf trajectory artifact ---
    let mut doc = BTreeMap::new();
    doc.insert("bench".into(), Value::String("micro_runtime".into()));
    doc.insert("pjrt_available".into(), Value::Bool(pjrt_available));
    doc.insert("rows".into(), Value::Array(rows));
    let json = Value::Object(doc).to_json_pretty();
    std::fs::write("BENCH_runtime.json", &json).expect("write BENCH_runtime.json");
    println!("\nwrote BENCH_runtime.json");
}
