//! Bench: regenerate Fig 13 — residual-vs-time curves for ensembles of
//! 8-rank runs under horovod vs RMA-ARAR vs ARAR (+ conventional ARAR).

use std::path::Path;

use sagips::report::experiments::{fig13_tab4, Scale};
use sagips::runtime::RuntimePool;

fn main() {
    sagips::util::logging::init_from_env();
    let scale = Scale::from_env(Scale::smoke());
    let pool = RuntimePool::from_dir(Path::new("artifacts"), 3).expect("run `make artifacts`");
    let t0 = std::time::Instant::now();
    let rows = fig13_tab4(&pool.handle(), &scale).expect("fig13");
    println!("\nfig13 regenerated in {:.1}s", t0.elapsed().as_secs_f64());
    for (mode, curve, _) in &rows {
        let first = curve.first().map(|&(_, m, _)| m).unwrap_or(f64::NAN);
        let last = curve.last().map(|&(_, m, _)| m).unwrap_or(f64::NAN);
        println!(
            "{:<14} mean|r̂| {first:.3} -> {last:.3} over {} checkpoints",
            mode.name(),
            curve.len()
        );
    }
    println!("paper shape: all methods descend; (RMA-)ARAR ends lower than hvd at full scale");
    pool.shutdown();
}
