//! Bench: regenerate Fig 8 — ensemble residual mean/σ across the
//! (model size x batch size) grid.
//!
//! Scaled-down by default; `SAGIPS_SCALE=ci|paper` for larger runs.

use std::path::Path;

use sagips::report::experiments::{fig8, Scale};
use sagips::runtime::RuntimePool;

fn main() {
    sagips::util::logging::init_from_env();
    let scale = Scale::from_env(Scale::smoke());
    let pool = RuntimePool::from_dir(Path::new("artifacts"), 3).expect("run `make artifacts`");
    let t0 = std::time::Instant::now();
    let rows = fig8(&pool.handle(), &scale).expect("fig8");
    println!("\nfig8 regenerated in {:.1}s", t0.elapsed().as_secs_f64());
    // Shape check mirroring the paper's claim: the best (paper-size,
    // batch-64) config beats the worst (small, batch-16) on spread.
    let small16 = rows
        .iter()
        .find(|r| r.model == "small" && r.batch == 16)
        .unwrap();
    let paper64 = rows
        .iter()
        .find(|r| r.model == "paper" && r.batch == 64)
        .unwrap();
    println!(
        "small/b16: |r0|={:.3} σ={:.3}   paper/b64: |r0|={:.3} σ={:.3}",
        small16.mean_r0.abs(),
        small16.sigma_r0,
        paper64.mean_r0.abs(),
        paper64.sigma_r0
    );
    pool.shutdown();
}
