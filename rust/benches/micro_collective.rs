//! Micro-bench: collective hot paths — ring pass latency per mode/size,
//! RMA window put/get, fusion pack/unpack. These are the L3 §Perf
//! numbers (EXPERIMENTS.md).

use std::time::Duration;

use sagips::collective::rma_ring::RmaRing;
use sagips::comm::{GradMsg, LinkModel, LocalNetwork, RmaRegion, RmaWindow, Topology};
use sagips::tensor::fusion::{segments_from_layout, FusionPlan};
use sagips::util::bench::{bench, bench_for, header};

/// Paper-sized gradient payload (~51k weight gradients).
const GRAD: usize = 51_206;

fn bench_ring_pass(n: usize) {
    // n threads run one collective epoch repeatedly; measure on rank 0.
    let topo = Topology::new(n, 4);
    let eps = LocalNetwork::build(&topo, LinkModel::zero());
    let members: Vec<usize> = (0..n).collect();
    let iters = 300usize;
    let mut handles = Vec::new();
    for ep in eps {
        let members = members.clone();
        handles.push(std::thread::spawn(move || {
            let mut grads = vec![1.0f32; GRAD];
            let rank = ep.rank;
            let t0 = std::time::Instant::now();
            for e in 0..iters {
                sagips::collective::ring::ring_pass(&ep, &members, e as u64, &mut grads)
                    .unwrap();
            }
            if rank == 0 {
                Some(t0.elapsed() / iters as u32)
            } else {
                None
            }
        }));
    }
    for h in handles {
        if let Some(d) = h.join().unwrap() {
            println!(
                "{:<44} {:>10}",
                format!("ring_pass n={n} ({GRAD} f32, unchunked)"),
                sagips::util::bench::fmt_dur(d)
            );
        }
    }
}

fn main() {
    header("collective micro-benches (L3 hot path)");

    // RMA window put/get on paper-sized payloads.
    let w = RmaWindow::new(4);
    let payload = vec![0.5f32; GRAD];
    let r = bench_for("rma_window put+get 51k f32", 100, Duration::from_millis(400), || {
        w.put(GradMsg::new(0, 0, 0, payload.clone()));
        std::hint::black_box(w.get());
    });
    println!("{}", r.row());

    // RMA ring pass, 4 ranks on threads.
    {
        let region = RmaRegion::with_capacity(4, 4);
        let rings: Vec<RmaRing> = (0..4)
            .map(|r| RmaRing::new(&region, vec![0, 1, 2, 3], r).unwrap())
            .collect();
        let iters = 300;
        let handles: Vec<_> = rings
            .into_iter()
            .map(|ring| {
                std::thread::spawn(move || {
                    let mut grads = vec![1.0f32; GRAD];
                    let t0 = std::time::Instant::now();
                    for e in 0..iters {
                        ring.pass(e, &mut grads).unwrap();
                    }
                    (ring.rank, t0.elapsed() / iters as u32)
                })
            })
            .collect();
        for h in handles {
            let (rank, d) = h.join().unwrap();
            if rank == 0 {
                println!(
                    "{:<44} {:>10}",
                    "rma_ring pass n=4 (51k f32)",
                    sagips::util::bench::fmt_dur(d)
                );
            }
        }
    }

    // Transport ring passes at paper-relevant ring sizes.
    for n in [2, 4, 8, 16] {
        bench_ring_pass(n);
    }

    // Fusion pack/unpack over a paper-shaped layer layout.
    let segs = segments_from_layout(&[
        (0, 16 * 154, 2464, 154),
        (2618, 154 * 154, 26334, 154),
        (26488, 154 * 154, 50204, 154),
        (50358, 154 * 6, 51282, 6),
    ]);
    let plan = FusionPlan::build(segs, 0, false);
    let grads = vec![1.0f32; 51_288];
    let mut packed = Vec::new();
    let r = bench("fusion pack (weights-only, paper layout)", 50, 2000, || {
        plan.pack(&grads, &mut packed).unwrap();
    });
    println!("{}", r.row());
    let mut out = vec![0.0f32; 51_288];
    let r = bench("fusion unpack", 50, 2000, || {
        plan.unpack(&packed, &mut out).unwrap();
    });
    println!("{}", r.row());
}
