//! Micro-bench: collective hot paths — ring pass latency per mode/size,
//! chunked vs unchunked rings, overlap vs blocking trainer integration,
//! RMA window put/get, fusion pack/unpack. These are the L3 §Perf
//! numbers (DESIGN.md §Collective engine) tracked by BENCH_*.json
//! snapshots.
//!
//! Runs under a counting `#[global_allocator]` so the pooled exchange
//! path's memory discipline (DESIGN.md §Memory discipline) is a measured
//! number, not prose: each pass row reports *allocations per steady-state
//! epoch across all ranks* — warmup epochs size the shared
//! [`BufferPool`] and the transport queues, after which the column must
//! read zero. Emits `BENCH_collective.json` with one row per (mode, n)
//! including the `allocs_per_epoch` column CI asserts on.
//!
//! `SAGIPS_BENCH_BUDGET_MS` scales the iteration counts down so CI smoke
//! runs finish in milliseconds while still exercising every row.

use std::alloc::{GlobalAlloc, Layout, System};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

use sagips::collective::engine::CollectiveEngine;
use sagips::collective::ring::{chunked_pass_bytes, chunked_ring_pass, ring_pass, ConvArar};
use sagips::collective::rma_ring::RmaRing;
use sagips::collective::Collective;
use sagips::comm::{
    BufferPool, GradMsg, LinkModel, LocalNetwork, RmaRegion, RmaWindow, Topology,
};
use sagips::tensor::fusion::{segments_from_layout, FusionPlan};
use sagips::util::bench::{bench, bench_for, fmt_dur, header};
use sagips::util::json::Value;

/// Counting allocator: proves the steady-state exchange path is
/// allocation-free instead of asserting it in prose.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
    unsafe fn realloc(&self, p: *mut u8, l: Layout, n: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(p, l, n)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::SeqCst)
}

/// Paper-sized gradient payload (~51k weight gradients).
const GRAD: usize = 51_206;

/// Warmup epochs before the allocation window opens: enough for the pool
/// and the transport queues to reach their high-water marks.
const WARMUP: usize = 8;

/// Scale an iteration count to the `SAGIPS_BENCH_BUDGET_MS` budget
/// (default 2000 ms keeps the full counts).
fn scaled(default: usize) -> usize {
    let ms = std::env::var("SAGIPS_BENCH_BUDGET_MS")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(2000);
    if ms >= 2000 {
        default
    } else {
        ((default as u64 * ms.max(1) / 2000).max(8)) as usize
    }
}

/// One measured pass configuration: rank-0 per-epoch latency, the final
/// epoch's per-rank wire bytes, and process-wide allocations per
/// steady-state epoch (all ranks, warmup excluded).
struct PassReport {
    per_epoch: Duration,
    bytes_per_rank: usize,
    allocs_per_epoch: f64,
    iters: usize,
}

/// Drive `steppers[rank]` for `WARMUP + iters` epochs each on its own
/// thread, with barriers fencing an allocation-count window around the
/// measured epochs. Thread spawn/join and result boxing allocate, so the
/// window closes strictly before any thread exits.
fn measure_pass(
    steppers: Vec<Box<dyn FnMut(u64) -> usize + Send>>,
    iters: usize,
) -> PassReport {
    let n = steppers.len();
    let barrier = Arc::new(Barrier::new(n));
    let start = Arc::new(AtomicU64::new(0));
    let end = Arc::new(AtomicU64::new(0));
    let handles: Vec<_> = steppers
        .into_iter()
        .enumerate()
        .map(|(rank, mut step)| {
            let barrier = Arc::clone(&barrier);
            let start = Arc::clone(&start);
            let end = Arc::clone(&end);
            std::thread::spawn(move || {
                let mut epoch = 0u64;
                let mut bytes = 0usize;
                for _ in 0..WARMUP {
                    bytes = step(epoch);
                    epoch += 1;
                }
                barrier.wait();
                if rank == 0 {
                    start.store(allocs(), Ordering::SeqCst);
                }
                barrier.wait();
                let t0 = Instant::now();
                for _ in 0..iters {
                    bytes = step(epoch);
                    epoch += 1;
                }
                let took = t0.elapsed();
                barrier.wait();
                if rank == 0 {
                    end.store(allocs(), Ordering::SeqCst);
                }
                // Hold every thread until the window is closed: exits
                // allocate (JoinHandle packaging) and must not leak into
                // the measured count.
                barrier.wait();
                (rank, took, bytes)
            })
        })
        .collect();
    let mut per_epoch = Duration::ZERO;
    let mut bytes_per_rank = 0usize;
    for h in handles {
        let (rank, took, bytes) = h.join().unwrap();
        if rank == 0 {
            per_epoch = took / iters as u32;
            bytes_per_rank = bytes;
        }
    }
    let delta = end.load(Ordering::SeqCst) - start.load(Ordering::SeqCst);
    PassReport {
        per_epoch,
        bytes_per_rank,
        allocs_per_epoch: delta as f64 / iters as f64,
        iters,
    }
}

fn report_row(label: &str, r: &PassReport) {
    println!(
        "{:<44} {:>10}   {:>9} B/rank/epoch   {:>6.1} allocs/epoch",
        label,
        fmt_dur(r.per_epoch),
        r.bytes_per_rank,
        r.allocs_per_epoch
    );
}

fn json_row(mode: &str, n: usize, chunked: bool, r: &PassReport) -> Value {
    let mut m = BTreeMap::new();
    m.insert("mode".into(), Value::String(mode.into()));
    m.insert("n".into(), Value::Number(n as f64));
    m.insert("chunked".into(), Value::Bool(chunked));
    m.insert("grad_elems".into(), Value::Number(GRAD as f64));
    m.insert(
        "per_epoch_us".into(),
        Value::Number(r.per_epoch.as_secs_f64() * 1e6),
    );
    m.insert(
        "bytes_per_rank_epoch".into(),
        Value::Number(r.bytes_per_rank as f64),
    );
    m.insert(
        "allocs_per_epoch".into(),
        Value::Number(r.allocs_per_epoch),
    );
    m.insert("iters".into(), Value::Number(r.iters as f64));
    Value::Object(m)
}

/// Transport ring pass (chunked or not) over one shared pool — the
/// production wiring (`build_with_policy` shares a pool run-wide).
fn bench_ring(n: usize, chunked: bool, iters: usize, rows: &mut Vec<Value>) {
    let topo = Topology::new(n, 4);
    let eps = LocalNetwork::build(&topo, LinkModel::zero());
    let members: Vec<usize> = (0..n).collect();
    let pool = BufferPool::new();
    let steppers: Vec<Box<dyn FnMut(u64) -> usize + Send>> = eps
        .into_iter()
        .map(|ep| {
            let members = members.clone();
            let pool = pool.clone();
            let mut grads = vec![1.0f32; GRAD];
            Box::new(move |e: u64| {
                let s = if chunked {
                    chunked_ring_pass(&ep, &members, e, &mut grads, &pool, 0).unwrap()
                } else {
                    ring_pass(&ep, &members, e, &mut grads, &pool).unwrap()
                };
                s.bytes_sent
            }) as Box<dyn FnMut(u64) -> usize + Send>
        })
        .collect();
    let r = measure_pass(steppers, iters);
    let label = if chunked { "chunked" } else { "unchunked" };
    report_row(&format!("ring n={n} {label} ({GRAD} f32)"), &r);
    rows.push(json_row("ring", n, chunked, &r));
}

/// RMA ring pass: windows + pooled deposits, one shared pool.
fn bench_rma_ring(n: usize, iters: usize, rows: &mut Vec<Value>) {
    let region = RmaRegion::with_capacity(n, 4);
    let pool = BufferPool::new();
    let steppers: Vec<Box<dyn FnMut(u64) -> usize + Send>> = (0..n)
        .map(|rank| {
            let mut ring = RmaRing::new(&region, (0..n).collect(), rank).unwrap();
            ring.pool = pool.clone();
            let mut grads = vec![1.0f32; GRAD];
            Box::new(move |e: u64| ring.pass(e, &mut grads).unwrap().bytes_sent)
                as Box<dyn FnMut(u64) -> usize + Send>
        })
        .collect();
    let r = measure_pass(steppers, iters);
    report_row(&format!("rma_ring n={n} ({GRAD} f32)"), &r);
    rows.push(json_row("rma", n, false, &r));
}

/// Synthetic compute load standing in for a gan_step execution.
fn fake_compute(us: u64) {
    let t0 = Instant::now();
    while t0.elapsed() < Duration::from_micros(us) {
        std::hint::black_box((0..64).sum::<u64>());
    }
}

/// Overlap vs blocking: each rank alternates "compute" (spin) with one
/// epoch's reduce. Blocking pays compute + comm serially; overlap starts
/// the reduce, computes, then collects — reporting the hot-path comm time
/// (the acceptance metric: `comm_s` on the rank hot path must drop).
fn bench_overlap_vs_blocking(n: usize) {
    const COMPUTE_US: u64 = 400;
    let iters = scaled(120);
    for overlap in [false, true] {
        let topo = Topology::new(n, 4);
        let eps = LocalNetwork::build(&topo, LinkModel::zero());
        let mut handles = Vec::new();
        for ep in eps {
            handles.push(std::thread::spawn(move || {
                let rank = ep.rank;
                let mut grads = vec![1.0f32; GRAD];
                let total;
                let mut hot_comm = Duration::ZERO;
                if overlap {
                    let mut eng = CollectiveEngine::spawn(Box::new(ConvArar::new(ep))).unwrap();
                    let t0 = Instant::now();
                    let mut pending = false;
                    for e in 0..iters {
                        fake_compute(COMPUTE_US);
                        let tc = Instant::now();
                        if pending {
                            let (buf, _) = eng.wait_reduce().unwrap();
                            grads.copy_from_slice(&buf);
                        }
                        eng.start_reduce(e as u64, grads.clone()).unwrap();
                        pending = true;
                        hot_comm += tc.elapsed();
                    }
                    let tc = Instant::now();
                    let _ = eng.wait_reduce().unwrap();
                    hot_comm += tc.elapsed();
                    total = t0.elapsed();
                } else {
                    let mut coll = ConvArar::new(ep);
                    let t0 = Instant::now();
                    for e in 0..iters {
                        fake_compute(COMPUTE_US);
                        let tc = Instant::now();
                        coll.epoch_reduce(e as u64, &mut grads).unwrap();
                        hot_comm += tc.elapsed();
                    }
                    total = t0.elapsed();
                }
                if rank == 0 {
                    Some((total / iters as u32, hot_comm / iters as u32))
                } else {
                    None
                }
            }));
        }
        for h in handles {
            if let Some((epoch_d, comm_d)) = h.join().unwrap() {
                let label = if overlap { "overlap" } else { "blocking" };
                println!(
                    "{:<44} {:>10}   hot comm_s {:>10}",
                    format!("trainer n={n} {label} (compute {COMPUTE_US}µs)"),
                    fmt_dur(epoch_d),
                    fmt_dur(comm_d)
                );
            }
        }
    }
}

fn main() {
    header("collective micro-benches (L3 hot path)");
    let mut rows: Vec<Value> = Vec::new();

    // RMA window put/get on paper-sized payloads.
    let w = RmaWindow::new(4);
    let payload = vec![0.5f32; GRAD];
    let r = bench_for("rma_window put+get 51k f32", 100, Duration::from_millis(400), || {
        w.put(GradMsg::new(0, 0, 0, payload.clone()));
        std::hint::black_box(w.get());
    });
    println!("{}", r.row());

    // Transport ring passes at paper-relevant ring sizes, unchunked and
    // chunked, each reporting wire bytes and steady-state allocations.
    println!();
    for n in [2, 4, 8, 16] {
        bench_ring(n, false, scaled(300), &mut rows);
    }
    println!();
    for n in [8, 16, 32] {
        bench_ring(n, true, scaled(100), &mut rows);
        println!(
            "{:<44} {:>10}   {:>9} B (2(N-1)/N law)",
            format!("ring n={n} chunked expected bytes"),
            "",
            chunked_pass_bytes(GRAD, n)
        );
    }

    // RMA ring pass over pooled window deposits.
    println!();
    bench_rma_ring(4, scaled(300), &mut rows);

    println!();
    for n in [8, 16, 32] {
        bench_overlap_vs_blocking(n);
    }

    // Fusion pack/unpack over a paper-shaped layer layout.
    let segs = segments_from_layout(&[
        (0, 16 * 154, 2464, 154),
        (2618, 154 * 154, 26334, 154),
        (26488, 154 * 154, 50204, 154),
        (50358, 154 * 6, 51282, 6),
    ]);
    let plan = FusionPlan::build(segs, 0, false);
    let grads = vec![1.0f32; 51_288];
    let mut packed = Vec::new();
    let r = bench("fusion pack (weights-only, paper layout)", 50, 2000, || {
        plan.pack(&grads, &mut packed).unwrap();
    });
    println!("{}", r.row());
    let mut out = vec![0.0f32; 51_288];
    let r = bench("fusion unpack", 50, 2000, || {
        plan.unpack(&packed, &mut out).unwrap();
    });
    println!("{}", r.row());

    // --- BENCH_collective.json: the memory-discipline artifact ---
    let mut doc = BTreeMap::new();
    doc.insert("bench".into(), Value::String("micro_collective".into()));
    doc.insert("grad_elems".into(), Value::Number(GRAD as f64));
    doc.insert("warmup_epochs".into(), Value::Number(WARMUP as f64));
    doc.insert("rows".into(), Value::Array(rows));
    let json = Value::Object(doc).to_json_pretty();
    std::fs::write("BENCH_collective.json", &json).expect("write BENCH_collective.json");
    println!("\nwrote BENCH_collective.json");
}
