//! Micro-bench: collective hot paths — ring pass latency per mode/size,
//! chunked vs unchunked rings, overlap vs blocking trainer integration,
//! RMA window put/get, fusion pack/unpack. These are the L3 §Perf
//! numbers (DESIGN.md §Collective engine) tracked by BENCH_*.json
//! snapshots.

use std::time::{Duration, Instant};

use sagips::collective::engine::CollectiveEngine;
use sagips::collective::ring::{chunked_pass_bytes, chunked_ring_pass, ring_pass, ConvArar};
use sagips::collective::rma_ring::RmaRing;
use sagips::collective::Collective;
use sagips::comm::{GradMsg, LinkModel, LocalNetwork, RmaRegion, RmaWindow, Topology};
use sagips::tensor::fusion::{segments_from_layout, FusionPlan};
use sagips::util::bench::{bench, bench_for, header};

/// Paper-sized gradient payload (~51k weight gradients).
const GRAD: usize = 51_206;

fn bench_ring_pass(n: usize) {
    // n threads run one collective epoch repeatedly; measure on rank 0.
    let topo = Topology::new(n, 4);
    let eps = LocalNetwork::build(&topo, LinkModel::zero());
    let members: Vec<usize> = (0..n).collect();
    let iters = 300usize;
    let mut handles = Vec::new();
    for ep in eps {
        let members = members.clone();
        handles.push(std::thread::spawn(move || {
            let mut grads = vec![1.0f32; GRAD];
            let mut scratch = Vec::new();
            let rank = ep.rank;
            let t0 = Instant::now();
            for e in 0..iters {
                ring_pass(&ep, &members, e as u64, &mut grads, &mut scratch).unwrap();
            }
            if rank == 0 {
                Some(t0.elapsed() / iters as u32)
            } else {
                None
            }
        }));
    }
    for h in handles {
        if let Some(d) = h.join().unwrap() {
            println!(
                "{:<44} {:>10}",
                format!("ring_pass n={n} ({GRAD} f32, unchunked)"),
                sagips::util::bench::fmt_dur(d)
            );
        }
    }
}

/// Chunked vs unchunked ring pass at a given size: latency on rank 0 plus
/// the per-rank byte counts (the 2·(N-1)/N vs N-1 law made concrete).
fn bench_chunked_vs_unchunked(n: usize) {
    let iters = if n >= 32 { 60usize } else { 150usize };
    for chunked in [false, true] {
        let topo = Topology::new(n, 4);
        let eps = LocalNetwork::build(&topo, LinkModel::zero());
        let members: Vec<usize> = (0..n).collect();
        let mut handles = Vec::new();
        for ep in eps {
            let members = members.clone();
            handles.push(std::thread::spawn(move || {
                let mut grads = vec![1.0f32; GRAD];
                let mut scratch = Vec::new();
                let mut pool = Vec::new();
                let rank = ep.rank;
                let mut bytes = 0usize;
                let t0 = Instant::now();
                for e in 0..iters {
                    let s = if chunked {
                        chunked_ring_pass(&ep, &members, e as u64, &mut grads, &mut pool, 0)
                            .unwrap()
                    } else {
                        ring_pass(&ep, &members, e as u64, &mut grads, &mut scratch).unwrap()
                    };
                    bytes = s.bytes_sent;
                }
                if rank == 0 {
                    Some((t0.elapsed() / iters as u32, bytes))
                } else {
                    None
                }
            }));
        }
        for h in handles {
            if let Some((d, bytes)) = h.join().unwrap() {
                let label = if chunked { "chunked" } else { "unchunked" };
                println!(
                    "{:<44} {:>10}   {:>9} B/rank/epoch",
                    format!("ring n={n} {label}"),
                    sagips::util::bench::fmt_dur(d),
                    bytes
                );
            }
        }
    }
    println!(
        "{:<44} {:>10}   {:>9} B (2(N-1)/N law)",
        format!("ring n={n} chunked expected bytes"),
        "",
        chunked_pass_bytes(GRAD, n)
    );
}

/// Synthetic compute load standing in for a gan_step execution.
fn fake_compute(us: u64) {
    let t0 = Instant::now();
    while t0.elapsed() < Duration::from_micros(us) {
        std::hint::black_box((0..64).sum::<u64>());
    }
}

/// Overlap vs blocking: each rank alternates "compute" (spin) with one
/// epoch's reduce. Blocking pays compute + comm serially; overlap starts
/// the reduce, computes, then collects — reporting the hot-path comm time
/// (the acceptance metric: `comm_s` on the rank hot path must drop).
fn bench_overlap_vs_blocking(n: usize) {
    const COMPUTE_US: u64 = 400;
    let iters = 120usize;
    for overlap in [false, true] {
        let topo = Topology::new(n, 4);
        let eps = LocalNetwork::build(&topo, LinkModel::zero());
        let mut handles = Vec::new();
        for ep in eps {
            handles.push(std::thread::spawn(move || {
                let rank = ep.rank;
                let mut grads = vec![1.0f32; GRAD];
                let total;
                let mut hot_comm = Duration::ZERO;
                if overlap {
                    let mut eng = CollectiveEngine::spawn(Box::new(ConvArar::new(ep))).unwrap();
                    let t0 = Instant::now();
                    let mut pending = false;
                    for e in 0..iters {
                        fake_compute(COMPUTE_US);
                        let tc = Instant::now();
                        if pending {
                            let (buf, _) = eng.wait_reduce().unwrap();
                            grads.copy_from_slice(&buf);
                        }
                        eng.start_reduce(e as u64, grads.clone()).unwrap();
                        pending = true;
                        hot_comm += tc.elapsed();
                    }
                    let tc = Instant::now();
                    let _ = eng.wait_reduce().unwrap();
                    hot_comm += tc.elapsed();
                    total = t0.elapsed();
                } else {
                    let mut coll = ConvArar::new(ep);
                    let t0 = Instant::now();
                    for e in 0..iters {
                        fake_compute(COMPUTE_US);
                        let tc = Instant::now();
                        coll.epoch_reduce(e as u64, &mut grads).unwrap();
                        hot_comm += tc.elapsed();
                    }
                    total = t0.elapsed();
                }
                if rank == 0 {
                    Some((total / iters as u32, hot_comm / iters as u32))
                } else {
                    None
                }
            }));
        }
        for h in handles {
            if let Some((epoch_d, comm_d)) = h.join().unwrap() {
                let label = if overlap { "overlap" } else { "blocking" };
                println!(
                    "{:<44} {:>10}   hot comm_s {:>10}",
                    format!("trainer n={n} {label} (compute {COMPUTE_US}µs)"),
                    sagips::util::bench::fmt_dur(epoch_d),
                    sagips::util::bench::fmt_dur(comm_d)
                );
            }
        }
    }
}

fn main() {
    header("collective micro-benches (L3 hot path)");

    // RMA window put/get on paper-sized payloads.
    let w = RmaWindow::new(4);
    let payload = vec![0.5f32; GRAD];
    let r = bench_for("rma_window put+get 51k f32", 100, Duration::from_millis(400), || {
        w.put(GradMsg::new(0, 0, 0, payload.clone()));
        std::hint::black_box(w.get());
    });
    println!("{}", r.row());

    // RMA ring pass, 4 ranks on threads.
    {
        let region = RmaRegion::with_capacity(4, 4);
        let rings: Vec<RmaRing> = (0..4)
            .map(|r| RmaRing::new(&region, vec![0, 1, 2, 3], r).unwrap())
            .collect();
        let iters = 300;
        let handles: Vec<_> = rings
            .into_iter()
            .map(|mut ring| {
                std::thread::spawn(move || {
                    let mut grads = vec![1.0f32; GRAD];
                    let t0 = std::time::Instant::now();
                    for e in 0..iters {
                        ring.pass(e, &mut grads).unwrap();
                    }
                    (ring.rank, t0.elapsed() / iters as u32)
                })
            })
            .collect();
        for h in handles {
            let (rank, d) = h.join().unwrap();
            if rank == 0 {
                println!(
                    "{:<44} {:>10}",
                    "rma_ring pass n=4 (51k f32)",
                    sagips::util::bench::fmt_dur(d)
                );
            }
        }
    }

    // Transport ring passes at paper-relevant ring sizes.
    for n in [2, 4, 8, 16] {
        bench_ring_pass(n);
    }

    // Chunked vs unchunked and overlap vs blocking at 8/16/32 simulated
    // ranks — the collective-engine comparison rows.
    println!();
    for n in [8, 16, 32] {
        bench_chunked_vs_unchunked(n);
    }
    println!();
    for n in [8, 16, 32] {
        bench_overlap_vs_blocking(n);
    }

    // Fusion pack/unpack over a paper-shaped layer layout.
    let segs = segments_from_layout(&[
        (0, 16 * 154, 2464, 154),
        (2618, 154 * 154, 26334, 154),
        (26488, 154 * 154, 50204, 154),
        (50358, 154 * 6, 51282, 6),
    ]);
    let plan = FusionPlan::build(segs, 0, false);
    let grads = vec![1.0f32; 51_288];
    let mut packed = Vec::new();
    let r = bench("fusion pack (weights-only, paper layout)", 50, 2000, || {
        plan.pack(&grads, &mut packed).unwrap();
    });
    println!("{}", r.row());
    let mut out = vec![0.0f32; 51_288];
    let r = bench("fusion unpack", 50, 2000, || {
        plan.unpack(&packed, &mut out).unwrap();
    });
    println!("{}", r.row());
}
