//! Bench: regenerate Figs 15/16 — residual mean/σ vs time for RMA-ARAR
//! (Fig 15) and ARAR (Fig 16) at 1, 2, 4, 8 ranks under eq (10).
//! (The paper sweeps 2..60 GPUs; scale via SAGIPS_SCALE / rank list.)

use std::path::Path;

use sagips::config::Mode;
use sagips::report::experiments::{tail_mean, weak_scaling_curves, Scale};
use sagips::runtime::RuntimePool;

fn main() {
    sagips::util::logging::init_from_env();
    let scale = Scale::from_env(Scale::smoke());
    let pool = RuntimePool::from_dir(Path::new("artifacts"), 3).expect("run `make artifacts`");
    for (fig, mode) in [("fig15", Mode::RmaArarArar), ("fig16", Mode::ArarArar)] {
        let t0 = std::time::Instant::now();
        let curves =
            weak_scaling_curves(&pool.handle(), &scale, mode, &[1, 2, 4, 8]).expect(fig);
        println!("\n{fig} regenerated in {:.1}s:", t0.elapsed().as_secs_f64());
        for (n, curve) in &curves {
            println!("  N={n}: tail mean|r̂| {:.3}", tail_mean(curve, 3));
        }
    }
    println!("\npaper shape: curves for all N descend to a consistent level; more ranks descend earlier in wall-clock");
    pool.shutdown();
}
