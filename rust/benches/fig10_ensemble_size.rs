//! Bench: regenerate Fig 10 — ensemble residual mean/σ vs ensemble size M.
//!
//! The paper grows the largest model's ensemble to M=100; default scale
//! uses a smaller pool (SAGIPS_SCALE=paper trains 100 members).

use std::path::Path;

use sagips::report::experiments::{fig10, Scale};
use sagips::runtime::RuntimePool;

fn main() {
    sagips::util::logging::init_from_env();
    let mut scale = Scale::from_env(Scale::smoke());
    // Fig 10 is specifically about ensemble growth: use a larger pool
    // than the other smoke benches.
    scale.ensemble_m = scale.ensemble_m.max(8);
    let pool = RuntimePool::from_dir(Path::new("artifacts"), 3).expect("run `make artifacts`");
    let t0 = std::time::Instant::now();
    let out = fig10(&pool.handle(), &scale).expect("fig10");
    println!("\nfig10 regenerated in {:.1}s", t0.elapsed().as_secs_f64());
    let (m0, r0, s0) = out.first().copied().unwrap();
    let (m1, r1, s1) = out.last().copied().unwrap();
    println!("M={m0}: |r̂|={r0:.3} σ={s0:.3}  ->  M={m1}: |r̂|={r1:.3} σ={s1:.3}");
    println!("paper shape: both shrink as M grows");
    pool.shutdown();
}
