//! Bench: regenerate Fig 14 — single-rank vs multi-rank residual curves
//! with the eq (10) batch reduction (RMA-ARAR).

use std::path::Path;

use sagips::config::Mode;
use sagips::report::experiments::{tail_mean, weak_scaling_curves, Scale};
use sagips::runtime::RuntimePool;

fn main() {
    sagips::util::logging::init_from_env();
    let mut scale = Scale::from_env(Scale::smoke());
    scale.ranks = 4;
    let pool = RuntimePool::from_dir(Path::new("artifacts"), 3).expect("run `make artifacts`");
    let t0 = std::time::Instant::now();
    let curves =
        weak_scaling_curves(&pool.handle(), &scale, Mode::RmaArarArar, &[1, 4]).expect("fig14");
    println!("\nfig14 regenerated in {:.1}s", t0.elapsed().as_secs_f64());
    for (n, curve) in &curves {
        println!(
            "N={n}: wall {:.1}s, tail mean|r̂| {:.3}",
            curve.last().map(|&(t, _, _)| t).unwrap_or(0.0),
            tail_mean(curve, 3)
        );
    }
    println!("paper shape: multi-rank run finishes the epoch budget sooner (smaller batch/rank), comparable convergence");
    pool.shutdown();
}
