//! Bench: regenerate Fig 11 — total training time vs ranks (4..400) for
//! conventional ARAR vs grouped ARAR vs grouped RMA-ARAR, via the
//! calibrated discrete-event simulator.

use sagips::report::experiments::fig11;
use sagips::sim::ComputeModel;
use sagips::util::bench;

fn main() {
    sagips::util::logging::init_from_env();
    let compute = ComputeModel::with_jitter(0.035, 0.15);

    // Time the sweep itself (the simulator is part of the deliverable:
    // it must stay interactive).
    let r = bench::bench("fig11 full 3-mode sweep", 1, 5, || {
        std::hint::black_box(fig11_quiet(compute));
    });
    bench::header("fig11 harness");
    println!("{}", r.row());

    let series = fig11(compute);
    // Shape assertions from the paper.
    let conv = &series[0].1;
    let grp = &series[1].1;
    let conv_growth = conv.last().unwrap().1 / conv[0].1;
    let grp_growth = grp.last().unwrap().1 / grp[0].1;
    println!("\nconv-ARAR time growth 4->400: {conv_growth:.2}x (paper: visible growth)");
    println!("grouped time growth 4->400: {grp_growth:.2}x (paper: nearly flat)");
    assert!(conv_growth > 1.5 && grp_growth < 1.4);
}

fn fig11_quiet(compute: ComputeModel) -> usize {
    use sagips::sim::sweep::{sweep_mode, PAPER_MODES, PAPER_RANKS};
    PAPER_MODES
        .iter()
        .map(|&m| sweep_mode(m, PAPER_RANKS, compute).len())
        .sum()
}
