//! Integration: elastic membership under the straggler-policy × staleness
//! test matrix.
//!
//! Every cell runs the same scripted churn — rank 1 leaves at epoch 3 and
//! rejoins at epoch 8 via checkpoint hand-off — on the native backend
//! (no artifacts, never skips), and checks the two core contracts:
//!
//! * **Replay determinism.** The scripted schedule is a pure function of
//!   the epoch and the hand-off donor is the fixed checkpoint boundary
//!   below the join epoch, so a full 12-epoch run and a 6-epoch head +
//!   resumed tail must agree bit for bit on every rank's parameters and
//!   on the final residuals — across a leave, a dormant stretch *and* a
//!   rejoin.
//! * **No lost or doubled exchanges.** `drain()` quiesces the window
//!   before every transition, so per rank `applies + skips` covers each
//!   participation epoch exactly once (dormant epochs never exchange).
//!
//! The matrix spans `on_straggler` × `staleness`. The straggler-policy
//! validation rule — non-block policies need `exchange_timeout_ms > 0`
//! **and** `staleness >= 1`, because "the blocking path has no in-flight
//! exchange to time out" — makes `skip × 0` and `late_apply × 0`
//! unconfigurable, leaving exactly seven legal cells:
//! block × {0, 1, 2}, skip × {1, 2}, late_apply × {1, 2}.
//!
//! Fault injection per policy keeps every cell deterministic: block cells
//! stall rank 0 (waited through, timing-independent), skip cells pin the
//! skip set with a budget of exactly the stalled epochs (the fault-smoke
//! determinism argument), late-apply cells run fault-free (a late apply's
//! landing epoch is timing-dependent, so a faulted late-apply run is
//! documented as best-effort, not bit-replayable).

use std::path::PathBuf;

use sagips::config::{presets, BackendKind, Mode, RunConfig, StragglerPolicy};
use sagips::coordinator::launcher::{run_training_from_config, RunResult};
use sagips::coordinator::MembershipChange;
use sagips::fault::FaultPlan;

const EPOCHS: usize = 12;
const RANKS: usize = 4;
const CUT: usize = 6; // head length == run-checkpoint cadence
const STALL_FROM: u64 = 2;
const STALL_EPOCHS: u64 = 2;
const STALL_MS: u64 = 1000;
const DEADLINE_MS: u64 = 50;

/// Rank 1 leaves at epoch 3 and rejoins at epoch 8. With `ckpt_every: 6`
/// the hand-off boundary for the join is epoch 5 — present both in a
/// full run (written at the cadence) and in a resumed tail (on disk from
/// the head run).
const SCHEDULE: &str = "leave:1@3,join:1@8";

fn stall_plan() -> String {
    format!(
        r#"{{"seed": 7, "stalls": [{{"rank": 0, "from_epoch": {STALL_FROM}, "epochs": {STALL_EPOCHS}, "stall_ms": {STALL_MS}}}]}}"#
    )
}

/// One matrix cell's config: the churn schedule armed on a small, fast
/// native run (model "small", batch 8 x 25 events, one 4-rank ring).
fn churn_cfg(policy: StragglerPolicy, staleness: usize) -> RunConfig {
    let mut cfg = presets::ci_default();
    cfg.backend = BackendKind::Native;
    cfg.artifacts_dir = "/nonexistent/so-the-synthetic-manifest-is-used".into();
    cfg.scenario = "quantile".into();
    cfg.model = "small".into();
    cfg.mode = Mode::ArarArar;
    cfg.ranks = RANKS;
    cfg.epochs = EPOCHS;
    cfg.batch = 8;
    cfg.events = 25;
    cfg.data_pool = 1600;
    cfg.checkpoint_every = 6;
    cfg.outer_freq = 5;
    cfg.staleness = staleness;
    cfg.membership = Some(SCHEDULE.into());
    cfg.allow_join = true;
    cfg.ckpt_every = CUT;
    match policy {
        StragglerPolicy::Block => {
            cfg.fault_plan = Some(stall_plan());
            // The deadline is optional under block; arm it only where a
            // windowed exchange exists for it to time out.
            if staleness >= 1 {
                cfg.exchange_timeout_ms = DEADLINE_MS;
            }
        }
        StragglerPolicy::Skip => {
            // Budget = stalled epochs pins the skip set exactly (the
            // stalled exchanges can never beat the deadline, and once
            // the budget is spent the policy degrades to blocking).
            cfg.fault_plan = Some(stall_plan());
            cfg.exchange_timeout_ms = DEADLINE_MS;
            cfg.skip_budget = STALL_EPOCHS as usize;
        }
        StragglerPolicy::LateApply => {
            cfg.exchange_timeout_ms = DEADLINE_MS;
        }
    }
    cfg.on_straggler = policy;
    cfg
}

fn ckpt_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "sagips_membership_{tag}_{}",
        std::process::id()
    ));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

/// Per-rank epochs actually trained under [`SCHEDULE`]: rank 1 sits out
/// epochs 3..8 (5 dormant epochs), everyone else trains all 12.
fn expected_participation(rank: usize) -> u64 {
    if rank == 1 {
        EPOCHS as u64 - 5
    } else {
        EPOCHS as u64
    }
}

fn assert_membership_accounting(run: &RunResult) {
    // One leave, one join, no health evictions.
    assert_eq!(run.membership_count(MembershipChange::Leave), 1);
    assert_eq!(run.membership_count(MembershipChange::Join), 1);
    assert_eq!(run.membership_count(MembershipChange::Evict), 0);
    // The rejoin restores the cohort: the latest `members` sample is 4.
    assert_eq!(run.final_members(), RANKS);
    // Drained transitions never lose or double-apply an exchange: every
    // participation epoch is exactly one apply or one (counted) skip.
    for (rank, c) in run.comm.iter().enumerate() {
        assert_eq!(
            c.participation_epochs,
            expected_participation(rank),
            "rank {rank} participation epochs"
        );
        assert_eq!(
            c.applies + c.skips,
            c.participation_epochs,
            "rank {rank}: applies ({}) + skips ({}) must cover every \
             participation epoch exactly once",
            c.applies,
            c.skips
        );
    }
}

/// The cell body: run the full 12 epochs, then a 6-epoch head and a
/// resumed tail, and demand bit-identical outcomes plus exact exchange
/// accounting on both paths.
fn run_cell(policy: StragglerPolicy, staleness: usize, tag: &str) {
    let full_dir = ckpt_dir(&format!("{tag}_full"));
    let head_dir = ckpt_dir(&format!("{tag}_head"));

    let mut full = churn_cfg(policy, staleness);
    full.ckpt_dir = full_dir.display().to_string();
    let full_run = run_training_from_config(&full).unwrap();
    assert_membership_accounting(&full_run);

    let mut head = churn_cfg(policy, staleness);
    head.epochs = CUT;
    head.ckpt_dir = head_dir.display().to_string();
    run_training_from_config(&head).unwrap();

    let mut tail = churn_cfg(policy, staleness);
    tail.ckpt_dir = head_dir.display().to_string();
    tail.resume = Some(head_dir.display().to_string());
    let resumed = run_training_from_config(&tail).unwrap();
    assert_eq!(resumed.resumed_from, Some(CUT as u64 - 1));
    // The tail crosses the dormant stretch and the epoch-8 rejoin; its
    // hand-off reads the epoch-5 boundary checkpoint the head wrote.
    assert_eq!(resumed.final_members(), RANKS);
    assert_eq!(resumed.membership_count(MembershipChange::Join), 1);

    for (rank, (a, b)) in full_run.states.iter().zip(&resumed.states).enumerate() {
        assert_eq!(a.gen, b.gen, "rank {rank} generator (policy {policy:?}, k={staleness})");
        assert_eq!(a.disc, b.disc, "rank {rank} discriminator (policy {policy:?}, k={staleness})");
    }
    assert_eq!(
        full_run.final_residuals.unwrap(),
        resumed.final_residuals.unwrap(),
        "final residuals (policy {policy:?}, k={staleness})"
    );

    std::fs::remove_dir_all(&full_dir).ok();
    std::fs::remove_dir_all(&head_dir).ok();
}

macro_rules! churn_cells {
    ($($name:ident: $policy:expr, $staleness:expr;)+) => {
        $(
            #[test]
            fn $name() {
                run_cell($policy, $staleness, stringify!($name));
            }
        )+
    };
}

churn_cells! {
    churn_block_blocking: StragglerPolicy::Block, 0;
    churn_block_overlap: StragglerPolicy::Block, 1;
    churn_block_window2: StragglerPolicy::Block, 2;
    churn_skip_overlap: StragglerPolicy::Skip, 1;
    churn_skip_window2: StragglerPolicy::Skip, 2;
    churn_late_apply_overlap: StragglerPolicy::LateApply, 1;
    churn_late_apply_window2: StragglerPolicy::LateApply, 2;
}

#[test]
fn illegal_cells_are_refused_by_validation() {
    // The two matrix holes: a straggler policy with nothing in flight.
    for policy in [StragglerPolicy::Skip, StragglerPolicy::LateApply] {
        let cfg = churn_cfg(policy, 0);
        let err = cfg.validate().unwrap_err().to_string();
        assert!(
            err.contains("staleness"),
            "policy {policy:?} x staleness 0 must be refused, got: {err}"
        );
    }
}

#[test]
fn fault_plan_is_a_pure_function_of_rank_and_epoch() {
    // The elastic protocol leans on replayable fault injection: the same
    // seeded plan must give byte-identical delays for every (rank, epoch)
    // on repeated queries — the plan carries no hidden mutable state.
    let mk = || {
        FaultPlan::new(41)
            .with_delay(1, 12.0, 0.6)
            .with_transient(2, 0.25, 40.0)
            .with_stall(0, 3, 4, 250)
    };
    let a = mk();
    let b = mk();
    for rank in 0..RANKS {
        for epoch in 0..64u64 {
            let d = a.delay_s(rank, epoch);
            // Identical across plan instances...
            assert_eq!(d.to_bits(), b.delay_s(rank, epoch).to_bits());
            // ...and across repeated queries of the same instance.
            assert_eq!(d.to_bits(), a.delay_s(rank, epoch).to_bits());
        }
    }
    // And across threads: concurrent queries of one shared plan agree
    // with the serial reference bit for bit.
    let plan = std::sync::Arc::new(mk());
    let reference: Vec<u64> = (0..RANKS)
        .flat_map(|r| (0..64u64).map(move |e| (r, e)))
        .map(|(r, e)| plan.delay_s(r, e).to_bits())
        .collect();
    let handles: Vec<_> = (0..4)
        .map(|_| {
            let plan = plan.clone();
            std::thread::spawn(move || {
                (0..RANKS)
                    .flat_map(|r| (0..64u64).map(move |e| (r, e)))
                    .map(|(r, e)| plan.delay_s(r, e).to_bits())
                    .collect::<Vec<u64>>()
            })
        })
        .collect();
    for h in handles {
        assert_eq!(h.join().unwrap(), reference);
    }
}
