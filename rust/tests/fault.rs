//! Integration: straggler-tolerant collectives under deterministic fault
//! injection.
//!
//! A seeded [`FaultPlan`] hard-stalls one of four ranks for a window of
//! epochs; the real ring collectives run under the injected delays on the
//! native backend (no artifacts, never skips). Contracts under test:
//!
//! * `on_straggler: block` keeps the paper's semantics — it *waits
//!   through* every stall (wall time bounded below by the serial stall
//!   chain, deadline misses recorded in rank health), with zero skips
//!   and zero late applies.
//! * `skip` and `late_apply` complete with the straggler outcome counted
//!   in the per-rank comm totals (`skips` / `late_applies`) and surfaced
//!   by the run summary.
//! * The skip policy is deterministic under decisive margins (stall ≫
//!   deadline ≫ healthy exchange latency): identical plan + seed give
//!   bit-identical final parameters.
//! * A drained skip-policy run checkpoint resumes bit-identically —
//!   quiescence settles abandoned exchanges too.

use std::path::PathBuf;

use sagips::config::{presets, BackendKind, Mode, RunConfig, StragglerPolicy};
use sagips::coordinator::launcher::run_training_from_config;

/// Stall window: rank 0's sends are held for `STALL_MS` during epochs
/// [2, 4). With a 50 ms deadline the margins are decisive in both
/// directions: a stalled exchange can never beat the deadline, a healthy
/// in-process exchange (microseconds) can never miss it.
const STALL_MS: u64 = 1000;
const DEADLINE_MS: u64 = 50;
const STALL_FROM: u64 = 2;
const STALL_EPOCHS: u64 = 2;
const EPOCHS: usize = 12;
const RANKS: usize = 4;

fn plan_json() -> String {
    format!(
        r#"{{"seed": 7, "stalls": [{{"rank": 0, "from_epoch": {STALL_FROM}, "epochs": {STALL_EPOCHS}, "stall_ms": {STALL_MS}}}]}}"#
    )
}

/// A small, fast native config with the stall plan armed (model "small",
/// batch 8 x 25 events, one 4-rank ring).
fn faulted_cfg(policy: StragglerPolicy) -> RunConfig {
    let mut cfg = presets::ci_default();
    cfg.backend = BackendKind::Native;
    cfg.artifacts_dir = "/nonexistent/so-the-synthetic-manifest-is-used".into();
    cfg.scenario = "quantile".into();
    cfg.model = "small".into();
    cfg.mode = Mode::ArarArar;
    cfg.ranks = RANKS;
    cfg.epochs = EPOCHS;
    cfg.batch = 8;
    cfg.events = 25;
    cfg.data_pool = 1600;
    cfg.checkpoint_every = 6;
    cfg.outer_freq = 5;
    cfg.staleness = 1;
    cfg.fault_plan = Some(plan_json());
    cfg.on_straggler = policy;
    cfg.exchange_timeout_ms = DEADLINE_MS;
    cfg
}

fn ckpt_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("sagips_fault_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

#[test]
fn block_policy_waits_through_the_stall_and_records_timeouts() {
    let run = run_training_from_config(&faulted_cfg(StragglerPolicy::Block)).unwrap();
    // Blocking semantics: every exchange applied, nothing abandoned.
    for (rank, c) in run.comm.iter().enumerate() {
        assert_eq!(c.applies, EPOCHS as u64, "rank {rank} applies");
        assert_eq!(c.skips, 0, "rank {rank} skips under block");
        assert_eq!(c.late_applies, 0, "rank {rank} late applies under block");
    }
    // The stall propagates around the ring, so the trainer waited the
    // serial stall chain out — past the deadline budget, which the
    // health tracker recorded.
    let timeouts: u64 = run.health.iter().map(|h| h.timeouts).sum();
    assert!(timeouts > 0, "block run never missed the armed deadline");
    let stall_chain_s = (STALL_EPOCHS * STALL_MS) as f64 / 1e3;
    assert!(
        run.wall_s >= 0.8 * stall_chain_s,
        "block run finished in {:.2}s — did not wait the ~{:.1}s stall chain",
        run.wall_s,
        stall_chain_s
    );
}

#[test]
fn skip_policy_completes_and_counts_every_abandoned_exchange() {
    let run = run_training_from_config(&faulted_cfg(StragglerPolicy::Skip)).unwrap();
    // The stall wraps the whole 4-rank ring, so every rank abandons at
    // least the stalled epochs' exchanges. The engine's serial worker
    // chains the stalled rings, so later epochs queued behind the
    // backlog may (deterministically — the backlog is seconds, the
    // trainer's deadline waits tens of milliseconds) miss the deadline
    // too; the invariant is exact either way: every started exchange is
    // applied or skipped, never both, never lost.
    for (rank, c) in run.comm.iter().enumerate() {
        assert!(
            c.skips >= STALL_EPOCHS,
            "rank {rank}: {} skips for {STALL_EPOCHS} stalled epochs",
            c.skips
        );
        assert_eq!(
            c.applies + c.skips,
            EPOCHS as u64,
            "rank {rank}: applies + skips must cover every epoch"
        );
        assert_eq!(c.late_applies, 0, "rank {rank} late applies under skip");
    }
    let timeouts: u64 = run.health.iter().map(|h| h.timeouts).sum();
    let skips: u64 = run.comm.iter().map(|c| c.skips).sum();
    assert!(timeouts >= skips, "every skip is preceded by a deadline miss");
    assert!(timeouts >= STALL_EPOCHS * RANKS as u64);
    // Health settles back once the stall window passes.
    for h in &run.health {
        assert!(h.settled > 0);
        assert_eq!(h.consecutive_timeouts, 0, "health did not recover");
        assert!(h.max_consecutive_timeouts >= 1);
    }
    // Finite, complete training outcome.
    assert_eq!(run.metrics.mean_series("gen_loss").len(), EPOCHS);
    let r = run.final_residuals.unwrap();
    assert!(r.iter().all(|x| x.is_finite()));
}

#[test]
fn late_apply_policy_completes_and_applies_every_exchange_eventually() {
    let run = run_training_from_config(&faulted_cfg(StragglerPolicy::LateApply)).unwrap();
    for (rank, c) in run.comm.iter().enumerate() {
        // Nothing is ever abandoned: every exchange is applied, the
        // stalled ones counted as late.
        assert_eq!(c.applies, EPOCHS as u64, "rank {rank} applies");
        assert_eq!(c.skips, 0, "rank {rank} skips under late_apply");
        assert!(
            c.late_applies >= 1 && c.late_applies <= STALL_EPOCHS,
            "rank {rank}: {} late applies for {STALL_EPOCHS} stalled epochs",
            c.late_applies
        );
    }
    let timeouts: u64 = run.health.iter().map(|h| h.timeouts).sum();
    assert!(timeouts > 0);
    let r = run.final_residuals.unwrap();
    assert!(r.iter().all(|x| x.is_finite()));
}

/// Skip config with a skip budget of exactly the stalled epochs: the
/// two stalled exchanges can never make the deadline (1000 ms stall vs
/// 50 ms deadline), so the budget is always consumed by them — and once
/// it is exhausted the policy degrades to blocking, which is
/// timing-independent. The skip *set* is therefore exactly the stalled
/// epochs on every run, making these runs fully deterministic.
fn budgeted_skip_cfg() -> RunConfig {
    let mut cfg = faulted_cfg(StragglerPolicy::Skip);
    cfg.skip_budget = STALL_EPOCHS as usize;
    cfg
}

#[test]
fn identical_plan_and_seed_give_identical_final_params_under_skip() {
    // The straggler-tolerance analogue of the windowed determinism
    // contract: identical plan + seed give bit-identical parameters.
    let a = run_training_from_config(&budgeted_skip_cfg()).unwrap();
    let b = run_training_from_config(&budgeted_skip_cfg()).unwrap();
    for (rank, (sa, sb)) in a.states.iter().zip(&b.states).enumerate() {
        assert_eq!(sa.gen, sb.gen, "rank {rank} generator");
        assert_eq!(sa.disc, sb.disc, "rank {rank} discriminator");
    }
    assert_eq!(a.final_residuals.unwrap(), b.final_residuals.unwrap());
    for (ca, cb) in a.comm.iter().zip(&b.comm) {
        // The budget pins the skip set to exactly the stalled epochs.
        assert_eq!(ca.skips, STALL_EPOCHS);
        assert_eq!(ca.applies, EPOCHS as u64 - STALL_EPOCHS);
        assert_eq!(cb.skips, STALL_EPOCHS);
        assert_eq!(cb.applies, EPOCHS as u64 - STALL_EPOCHS);
    }
}

#[test]
fn drained_skip_policy_checkpoint_resumes_bit_identically() {
    // Train 12 epochs straight vs train 7 (through the stall window),
    // stop, resume for the rest. The checkpoint drain settles abandoned
    // exchanges too, so the deposited state is fully quiescent and the
    // resumed run must agree bit for bit.
    const CUT: usize = 7;
    let full_dir = ckpt_dir("full");
    let head_dir = ckpt_dir("head");

    let mut full = budgeted_skip_cfg();
    full.ckpt_every = CUT;
    full.ckpt_dir = full_dir.display().to_string();
    let full_run = run_training_from_config(&full).unwrap();

    let mut head = budgeted_skip_cfg();
    head.epochs = CUT;
    head.ckpt_every = CUT;
    head.ckpt_dir = head_dir.display().to_string();
    run_training_from_config(&head).unwrap();

    let mut tail = budgeted_skip_cfg();
    tail.ckpt_every = CUT;
    tail.ckpt_dir = head_dir.display().to_string();
    tail.resume = Some(head_dir.display().to_string());
    let resumed = run_training_from_config(&tail).unwrap();
    assert_eq!(resumed.resumed_from, Some(CUT as u64 - 1));

    for (rank, (a, b)) in full_run.states.iter().zip(&resumed.states).enumerate() {
        assert_eq!(a.gen, b.gen, "rank {rank} generator");
        assert_eq!(a.disc, b.disc, "rank {rank} discriminator");
    }
    assert_eq!(
        full_run.final_residuals.unwrap(),
        resumed.final_residuals.unwrap()
    );

    std::fs::remove_dir_all(&full_dir).ok();
    std::fs::remove_dir_all(&head_dir).ok();
}

#[test]
fn fault_free_runs_are_untouched_by_an_armed_deadline() {
    // A deadline with no faults must not change training at all: same
    // params as the plain windowed run, zero timeouts, healthy ranks.
    let mut plain = faulted_cfg(StragglerPolicy::Block);
    plain.fault_plan = None;
    plain.exchange_timeout_ms = 0;
    let mut armed = faulted_cfg(StragglerPolicy::Skip);
    armed.fault_plan = None;
    let a = run_training_from_config(&plain).unwrap();
    let b = run_training_from_config(&armed).unwrap();
    for (sa, sb) in a.states.iter().zip(&b.states) {
        assert_eq!(sa.gen, sb.gen);
        assert_eq!(sa.disc, sb.disc);
    }
    for (c, h) in b.comm.iter().zip(&b.health) {
        assert_eq!(c.skips, 0);
        assert_eq!(h.timeouts, 0);
        assert_eq!(h.settled, EPOCHS as u64);
    }
}
