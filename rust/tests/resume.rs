//! Integration: fault-tolerant train-resume.
//!
//! The contract under test: training k epochs, checkpointing, and
//! resuming for the remaining N − k epochs produces **bit-identical**
//! final state (per-rank generator and discriminator parameters, final
//! losses, final residuals) to an uninterrupted N-epoch run — per
//! registered scenario, multi-rank, on the native backend (no artifacts,
//! never skips). Plus the failure modes: cross-scenario restores refused
//! through the scenario-identity guard, rank-count and epoch-budget
//! mismatches rejected, retention pruning.

use std::path::PathBuf;

use sagips::config::{presets, BackendKind, Mode, RunConfig};
use sagips::coordinator::launcher::run_training_from_config;
use sagips::model::checkpoint::TrainCheckpoint;

/// A small, fast native config (model "small", batch 8 x 25 events).
fn native_cfg(scenario: &str, ranks: usize, epochs: usize) -> RunConfig {
    let mut cfg = presets::ci_default();
    cfg.backend = BackendKind::Native;
    cfg.artifacts_dir = "/nonexistent/so-the-synthetic-manifest-is-used".into();
    cfg.scenario = scenario.into();
    cfg.model = "small".into();
    cfg.mode = Mode::ArarArar;
    cfg.ranks = ranks;
    cfg.epochs = epochs;
    cfg.batch = 8;
    cfg.events = 25;
    cfg.data_pool = 1600;
    cfg.checkpoint_every = 6;
    cfg.outer_freq = 5;
    cfg
}

fn ckpt_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "sagips_resume_{tag}_{}",
        std::process::id()
    ));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

#[test]
fn resume_matches_uninterrupted_run_for_every_registered_scenario() {
    const TOTAL: usize = 12;
    const CUT: usize = 7;
    for sc in sagips::scenario::registry() {
        let dir = ckpt_dir(&format!("eq_{}", sc.name()));

        // Uninterrupted reference: N epochs straight.
        let full = run_training_from_config(&native_cfg(sc.name(), 4, TOTAL))
            .unwrap_or_else(|e| panic!("{}: full run failed: {e}", sc.name()));

        // Interrupted run: k epochs with a checkpoint at the cut...
        let mut head = native_cfg(sc.name(), 4, CUT);
        head.ckpt_every = CUT;
        head.ckpt_dir = dir.display().to_string();
        run_training_from_config(&head)
            .unwrap_or_else(|e| panic!("{}: head run failed: {e}", sc.name()));
        let written = TrainCheckpoint::latest(&dir).unwrap();
        assert!(written.is_some(), "{}: no checkpoint written", sc.name());

        // ...then resume for the remaining N − k.
        let mut tail = native_cfg(sc.name(), 4, TOTAL);
        tail.resume = Some(dir.display().to_string());
        let resumed = run_training_from_config(&tail)
            .unwrap_or_else(|e| panic!("{}: resume failed: {e}", sc.name()));
        assert_eq!(resumed.resumed_from, Some(CUT as u64 - 1), "{}", sc.name());
        // The resumed run trained exactly the remaining epochs.
        assert_eq!(
            resumed.metrics.mean_series("gen_loss").len(),
            TOTAL - CUT,
            "{}",
            sc.name()
        );

        // Bit-identical final state on every rank.
        for (rank, (a, b)) in full.states.iter().zip(&resumed.states).enumerate() {
            assert_eq!(a.gen, b.gen, "{} rank {rank} generator", sc.name());
            assert_eq!(a.disc, b.disc, "{} rank {rank} discriminator", sc.name());
        }
        // Matching final losses...
        assert_eq!(
            full.metrics.mean_of_last("gen_loss"),
            resumed.metrics.mean_of_last("gen_loss"),
            "{} final gen loss",
            sc.name()
        );
        assert_eq!(
            full.metrics.mean_of_last("disc_loss"),
            resumed.metrics.mean_of_last("disc_loss"),
            "{} final disc loss",
            sc.name()
        );
        // ...and matching final residuals at the scenario's width.
        let rf = full.final_residuals.unwrap();
        let rr = resumed.final_residuals.unwrap();
        assert_eq!(rf.len(), sc.param_dim(), "{}", sc.name());
        assert_eq!(rf, rr, "{} final residuals", sc.name());
        // The resumed residual-curve clock continues past the checkpoint
        // (elapsed offset), keeping the trajectory monotone.
        for w in resumed.residual_curve.windows(2) {
            assert!(w[1].elapsed_s > w[0].elapsed_s, "{}", sc.name());
        }

        std::fs::remove_dir_all(&dir).ok();
    }
}

#[test]
fn cross_scenario_resume_is_refused_through_the_identity_guard() {
    let dir = ckpt_dir("guard");
    let mut head = native_cfg("saturation", 2, 4);
    head.ckpt_every = 4;
    head.ckpt_dir = dir.display().to_string();
    run_training_from_config(&head).unwrap();

    let mut wrong = native_cfg("quantile", 2, 8);
    wrong.resume = Some(dir.display().to_string());
    let err = run_training_from_config(&wrong).unwrap_err().to_string();
    assert!(
        err.contains("saturation") && err.contains("quantile"),
        "{err}"
    );
    assert!(err.contains("refusing"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn seed_mismatch_is_rejected() {
    // A different seed would regenerate a different data pool under the
    // restored parameters — refuse instead of silently diverging.
    let dir = ckpt_dir("seed");
    let mut head = native_cfg("quantile", 2, 4);
    head.ckpt_every = 4;
    head.ckpt_dir = dir.display().to_string();
    run_training_from_config(&head).unwrap();

    let mut wrong = native_cfg("quantile", 2, 8);
    wrong.seed += 1;
    wrong.resume = Some(dir.display().to_string());
    let err = run_training_from_config(&wrong).unwrap_err().to_string();
    assert!(err.contains("seed"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn rank_count_mismatch_is_rejected() {
    let dir = ckpt_dir("ranks");
    let mut head = native_cfg("quantile", 2, 4);
    head.ckpt_every = 4;
    head.ckpt_dir = dir.display().to_string();
    run_training_from_config(&head).unwrap();

    let mut wrong = native_cfg("quantile", 4, 8);
    wrong.resume = Some(dir.display().to_string());
    let err = run_training_from_config(&wrong).unwrap_err().to_string();
    assert!(err.contains("ranks"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn exhausted_epoch_budget_is_rejected() {
    let dir = ckpt_dir("budget");
    let mut head = native_cfg("quantile", 1, 4);
    head.mode = Mode::Ensemble;
    head.ckpt_every = 4;
    head.ckpt_dir = dir.display().to_string();
    run_training_from_config(&head).unwrap();

    // Same total epochs as the checkpoint: nothing left to train.
    let mut wrong = native_cfg("quantile", 1, 4);
    wrong.mode = Mode::Ensemble;
    wrong.resume = Some(dir.display().to_string());
    let err = run_training_from_config(&wrong).unwrap_err().to_string();
    assert!(err.contains("epoch"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn retention_keeps_only_the_newest_checkpoints() {
    let dir = ckpt_dir("keep");
    let mut cfg = native_cfg("quantile", 2, 20);
    cfg.ckpt_every = 5;
    cfg.ckpt_keep = 2;
    cfg.ckpt_dir = dir.display().to_string();
    run_training_from_config(&cfg).unwrap();
    // Cadence fires at epochs 4, 9, 14, 19; keep = 2 leaves the newest
    // two.
    let left = TrainCheckpoint::list(&dir).unwrap();
    assert_eq!(left.len(), 2, "{left:?}");
    assert!(left[0].ends_with(TrainCheckpoint::dir_name(14)));
    assert!(left[1].ends_with(TrainCheckpoint::dir_name(19)));
    // And the newest resumes cleanly.
    let mut tail = native_cfg("quantile", 2, 24);
    tail.resume = Some(dir.display().to_string());
    let run = run_training_from_config(&tail).unwrap();
    assert_eq!(run.resumed_from, Some(19));
    std::fs::remove_dir_all(&dir).ok();
}
