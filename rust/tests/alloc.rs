//! Memory-discipline proof: the steady-state exchange path allocates
//! nothing (DESIGN.md §Memory discipline).
//!
//! This binary installs its own counting `#[global_allocator]` and runs
//! the collective matrix — ring (unchunked and chunked), grouped
//! chunked, RMA-grouped chunked — at staleness k ∈ {0, 1, 2} over 4
//! rank threads. Each configuration warms up (sizing the shared
//! [`BufferPool`], the transport queues, and the engine channels), then
//! fences an allocation-count window around a block of steady-state
//! epochs with barriers: the delta across all ranks AND all comm worker
//! threads must be exactly zero.
//!
//! One `#[test]` runs the whole matrix sequentially — the counter is
//! process-global, so concurrent tests would pollute each other's
//! windows.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Barrier};

use sagips::collective::engine::CollectiveEngine;
use sagips::collective::{build_with_policy, rma_window_depth, CommStats};
use sagips::comm::{LinkModel, LocalNetwork, RmaRegion, Topology};
use sagips::config::{ChunkPolicy, Mode};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
    unsafe fn realloc(&self, p: *mut u8, l: Layout, n: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(p, l, n)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::SeqCst)
}

const N: usize = 4;
const GPUS_PER_NODE: usize = 2;
const LEN: usize = 4096;
/// Warmup epochs: enough for the pool free-lists, the transport queues,
/// the engine job/done channels, and the chunked per-pass scratch to all
/// reach their high-water marks.
const WARMUP: usize = 8;
const MEASURED: usize = 6;

/// Run one (mode, policy, staleness) configuration and return the
/// process-wide allocation count across the measured steady-state epochs.
fn measured_allocs(mode: Mode, policy: ChunkPolicy, k: usize) -> u64 {
    let topo = Topology::new(N, GPUS_PER_NODE);
    // Window depth mirrors the launcher: ring steps per epoch times the
    // staleness window, so deposits never overwrite undelivered slots.
    let region = RmaRegion::with_capacity(N, rma_window_depth(GPUS_PER_NODE, policy) * k.max(1));
    let endpoints = LocalNetwork::build(&topo, LinkModel::zero());
    let collectives = build_with_policy(mode, &topo, 1, endpoints, &region, policy).unwrap();
    let barrier = Arc::new(Barrier::new(N));
    let start = Arc::new(AtomicU64::new(0));
    let end = Arc::new(AtomicU64::new(0));
    let handles: Vec<_> = collectives
        .into_iter()
        .enumerate()
        .map(|(rank, c)| {
            let barrier = Arc::clone(&barrier);
            let start = Arc::clone(&start);
            let end = Arc::clone(&end);
            std::thread::spawn(move || {
                let mut grads = vec![rank as f32 + 1.0; LEN];
                let mut epoch = 0u64;
                if k == 0 {
                    // Blocking loop: the collective reduces in place.
                    let mut c = c;
                    for _ in 0..WARMUP {
                        c.epoch_reduce(epoch, &mut grads).unwrap();
                        epoch += 1;
                    }
                    barrier.wait();
                    if rank == 0 {
                        start.store(allocs(), Ordering::SeqCst);
                    }
                    barrier.wait();
                    for _ in 0..MEASURED {
                        c.epoch_reduce(epoch, &mut grads).unwrap();
                        epoch += 1;
                    }
                    barrier.wait();
                    if rank == 0 {
                        end.store(allocs(), Ordering::SeqCst);
                    }
                    // Hold until the window closes: thread exits and
                    // collective drops allocate/deallocate freely.
                    barrier.wait();
                } else {
                    // k-deep window on the engine with caller-side buffer
                    // rotation: checkout at submit, recycle at apply, so
                    // steady state holds exactly k+1 loaned buffers.
                    let pool = c.buffer_pool().expect("matrix modes are pooled");
                    let mut eng = CollectiveEngine::spawn_windowed(c, k).unwrap();
                    let mut stats = CommStats::default();
                    let mut step = |eng: &mut CollectiveEngine,
                                    grads: &mut Vec<f32>,
                                    epoch: u64,
                                    stats: &mut CommStats| {
                        if eng.in_flight() >= k {
                            let (buf, _) = eng.wait_reduce().unwrap();
                            grads.copy_from_slice(&buf);
                            pool.recycle(buf, stats);
                        }
                        let buf = pool.checkout_filled(grads, stats);
                        eng.start_reduce(epoch, buf).unwrap();
                    };
                    for _ in 0..WARMUP {
                        step(&mut eng, &mut grads, epoch, &mut stats);
                        epoch += 1;
                    }
                    barrier.wait();
                    if rank == 0 {
                        start.store(allocs(), Ordering::SeqCst);
                    }
                    barrier.wait();
                    for _ in 0..MEASURED {
                        step(&mut eng, &mut grads, epoch, &mut stats);
                        epoch += 1;
                    }
                    barrier.wait();
                    if rank == 0 {
                        end.store(allocs(), Ordering::SeqCst);
                    }
                    barrier.wait();
                    // Settle the window outside the measured region.
                    eng.drain().unwrap();
                    assert_eq!(stats.allocs + stats.pool_hits, (WARMUP + MEASURED) as u64);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    end.load(Ordering::SeqCst) - start.load(Ordering::SeqCst)
}

#[test]
fn steady_state_exchange_path_allocates_nothing() {
    let matrix: [(Mode, ChunkPolicy, &str); 4] = [
        (Mode::ConvArar, ChunkPolicy::Unchunked, "ring unchunked"),
        (Mode::ConvArar, ChunkPolicy::Auto, "ring chunked"),
        (Mode::ArarArar, ChunkPolicy::Auto, "grouped chunked"),
        (Mode::RmaArarArar, ChunkPolicy::Auto, "rma-grouped chunked"),
    ];
    for (mode, policy, label) in matrix {
        for k in [0usize, 1, 2] {
            let delta = measured_allocs(mode, policy, k);
            assert_eq!(
                delta, 0,
                "{label} k={k}: {delta} allocations across {MEASURED} steady-state epochs"
            );
        }
    }
}
