//! Integration: the job scheduler behind `sagips serve`.
//!
//! Uses mock [`JobRunner`]s (instant, gated, failing) so the
//! queue/scheduler contract is tested without real training: claim
//! order (priority first, FIFO within), admission refusal at capacity,
//! the cancellation matrix at the scheduler level, concurrency limits
//! and reload, and a property test that randomized concurrent
//! submit/cancel interleavings lose no job, duplicate no job, and leave
//! every job in a terminal state. Real-training behaviour (checkpoint
//! deposits, bit-identical resume) lives in `tests/serve.rs`.

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use sagips::config::{presets, RunConfig};
use sagips::coordinator::RunControl;
use sagips::service::{
    CancelOutcome, JobId, JobQueue, JobRunner, JobSpec, JobState, RunOutcome, Scheduler,
    ServeLimits,
};
use sagips::util::error::{Error, Result};

fn state_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "sagips_service_{tag}_{}",
        std::process::id()
    ));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

fn spec(priority: i64) -> JobSpec {
    let mut cfg = presets::ci_default();
    cfg.epochs = 4;
    JobSpec {
        name: format!("p{priority}"),
        priority,
        config: cfg,
    }
}

fn limits(max_concurrent_jobs: usize, max_queued: usize) -> ServeLimits {
    ServeLimits {
        max_concurrent_jobs,
        max_queued,
        default_ckpt_every: 2,
        ..ServeLimits::default()
    }
}

/// The job id the scheduler assigned, recovered from the per-job
/// checkpoint directory it normalized into the config.
fn job_id_of(cfg: &RunConfig) -> JobId {
    let dir = PathBuf::from(&cfg.ckpt_dir);
    let name = dir.file_name().unwrap().to_str().unwrap();
    name.strip_prefix("job-").unwrap().parse().unwrap()
}

fn wait_until(what: &str, deadline: Duration, mut ok: impl FnMut() -> bool) {
    let t0 = Instant::now();
    while !ok() {
        assert!(t0.elapsed() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(5));
    }
}

fn wait_all_terminal(sched: &Scheduler, n: usize) {
    wait_until("all jobs terminal", Duration::from_secs(20), || {
        let rows = sched.list();
        rows.len() == n && rows.iter().all(|r| r.state.is_terminal())
    });
}

/// Completes immediately (a tiny sleep keeps interleavings interesting).
struct InstantRunner;

impl JobRunner for InstantRunner {
    fn run(&self, cfg: &RunConfig, _control: Arc<RunControl>) -> Result<RunOutcome> {
        std::thread::sleep(Duration::from_millis(1));
        Ok(RunOutcome {
            epochs_done: cfg.epochs as u64,
            ..RunOutcome::default()
        })
    }
}

/// Blocks every job until the shared gate opens; records execution
/// order and the high-water concurrency mark. A cancel request releases
/// the job as if it stopped at checkpoint boundary 5.
struct GatedRunner {
    open: Arc<AtomicBool>,
    order: Arc<Mutex<Vec<JobId>>>,
    running_now: Arc<AtomicUsize>,
    max_seen: Arc<AtomicUsize>,
}

impl GatedRunner {
    fn new() -> GatedRunner {
        GatedRunner {
            open: Arc::new(AtomicBool::new(false)),
            order: Arc::new(Mutex::new(Vec::new())),
            running_now: Arc::new(AtomicUsize::new(0)),
            max_seen: Arc::new(AtomicUsize::new(0)),
        }
    }

    /// Shares the same gate/recorders as `self` (the scheduler takes
    /// ownership of its runner; the test keeps this handle).
    fn handle(&self) -> GatedRunner {
        GatedRunner {
            open: self.open.clone(),
            order: self.order.clone(),
            running_now: self.running_now.clone(),
            max_seen: self.max_seen.clone(),
        }
    }
}

impl JobRunner for GatedRunner {
    fn run(&self, cfg: &RunConfig, control: Arc<RunControl>) -> Result<RunOutcome> {
        let now = self.running_now.fetch_add(1, Ordering::SeqCst) + 1;
        self.max_seen.fetch_max(now, Ordering::SeqCst);
        self.order.lock().unwrap().push(job_id_of(cfg));
        let cancelled = loop {
            if control.cancel_requested() {
                break true;
            }
            if self.open.load(Ordering::SeqCst) {
                break false;
            }
            std::thread::sleep(Duration::from_millis(2));
        };
        self.running_now.fetch_sub(1, Ordering::SeqCst);
        if cancelled {
            Ok(RunOutcome {
                epochs_done: 6,
                stopped_at: Some(5),
                ..RunOutcome::default()
            })
        } else {
            Ok(RunOutcome {
                epochs_done: cfg.epochs as u64,
                ..RunOutcome::default()
            })
        }
    }
}

/// Always errors.
struct FailingRunner;

impl JobRunner for FailingRunner {
    fn run(&self, _cfg: &RunConfig, _control: Arc<RunControl>) -> Result<RunOutcome> {
        Err(Error::Runtime("mock runner exploded".into()))
    }
}

#[test]
fn claims_follow_priority_then_fifo_order() {
    let dir = state_dir("order");
    let runner = GatedRunner::new();
    let handle = runner.handle();
    let sched = Scheduler::open(&dir, limits(1, 0), Box::new(runner)).unwrap();

    // First submit is claimed immediately and parks on the gate...
    let first = sched.submit(spec(0)).unwrap();
    wait_until("first claim", Duration::from_secs(10), || {
        sched.running_count() == 1
    });
    // ...so these four queue up and are claimed strictly by
    // (priority desc, id asc) once the gate opens.
    let b = sched.submit(spec(0)).unwrap();
    let c = sched.submit(spec(5)).unwrap();
    let d = sched.submit(spec(5)).unwrap();
    let e = sched.submit(spec(-1)).unwrap();
    handle.open.store(true, Ordering::SeqCst);
    wait_all_terminal(&sched, 5);

    assert_eq!(*handle.order.lock().unwrap(), vec![first, c, d, b, e]);
    assert_eq!(handle.max_seen.load(Ordering::SeqCst), 1);
    for row in sched.list() {
        assert_eq!(row.state, JobState::Done);
    }
    sched.shutdown(false);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn admission_refuses_past_the_queue_limit_with_a_retryable_error() {
    let dir = state_dir("admission");
    let runner = GatedRunner::new();
    let handle = runner.handle();
    let sched = Scheduler::open(&dir, limits(1, 2), Box::new(runner)).unwrap();

    let running = sched.submit(spec(0)).unwrap();
    wait_until("claim", Duration::from_secs(10), || {
        sched.running_count() == 1
    });
    sched.submit(spec(0)).unwrap();
    sched.submit(spec(0)).unwrap();
    assert_eq!(sched.queued_count(), 2);

    let err = sched.submit(spec(0)).unwrap_err();
    assert!(err.is_overloaded(), "not marked retryable: {err}");
    assert!(err.to_string().contains("overloaded"), "{err}");

    // Draining the queue reopens admission.
    handle.open.store(true, Ordering::SeqCst);
    wait_all_terminal(&sched, 3);
    let again = sched.submit(spec(0)).unwrap();
    assert!(again > running);
    wait_all_terminal(&sched, 4);
    sched.shutdown(false);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn cancel_while_queued_never_starts() {
    let dir = state_dir("cancel_queued");
    let runner = GatedRunner::new();
    let handle = runner.handle();
    let sched = Scheduler::open(&dir, limits(1, 0), Box::new(runner)).unwrap();

    let blocker = sched.submit(spec(0)).unwrap();
    wait_until("claim", Duration::from_secs(10), || {
        sched.running_count() == 1
    });
    let victim = sched.submit(spec(0)).unwrap();
    assert_eq!(sched.cancel(victim).unwrap(), CancelOutcome::Dequeued);
    // Cancelling a terminal job is a reported no-op.
    assert_eq!(
        sched.cancel(victim).unwrap(),
        CancelOutcome::AlreadyTerminal(JobState::Cancelled)
    );

    handle.open.store(true, Ordering::SeqCst);
    wait_all_terminal(&sched, 2);
    assert_eq!(
        *handle.order.lock().unwrap(),
        vec![blocker],
        "the cancelled job must never reach the runner"
    );
    let st = sched.status(victim).unwrap();
    assert_eq!(st.state, JobState::Cancelled);
    assert_eq!(st.epochs_done, 0);
    assert!(st.detail.contains("queued"), "{}", st.detail);
    sched.shutdown(false);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn cancel_while_running_lands_cancelled_with_the_stop_boundary() {
    let dir = state_dir("cancel_running");
    let runner = GatedRunner::new();
    let sched = Scheduler::open(&dir, limits(1, 0), Box::new(runner)).unwrap();

    let id = sched.submit(spec(0)).unwrap();
    wait_until("claim", Duration::from_secs(10), || {
        sched.running_count() == 1
    });
    assert_eq!(sched.cancel(id).unwrap(), CancelOutcome::Stopping);
    wait_all_terminal(&sched, 1);

    let st = sched.status(id).unwrap();
    assert_eq!(st.state, JobState::Cancelled);
    assert_eq!(st.epochs_done, 6);
    assert!(st.detail.contains("boundary 5"), "{}", st.detail);
    assert!(st.detail.contains("job-000001"), "{}", st.detail);
    sched.shutdown(false);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn runner_errors_land_the_job_in_failed_with_the_message() {
    let dir = state_dir("failed");
    let sched = Scheduler::open(&dir, limits(1, 0), Box::new(FailingRunner)).unwrap();
    let id = sched.submit(spec(0)).unwrap();
    wait_all_terminal(&sched, 1);
    let st = sched.status(id).unwrap();
    assert_eq!(st.state, JobState::Failed);
    assert!(st.detail.contains("mock runner exploded"), "{}", st.detail);
    sched.shutdown(false);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn reload_raises_concurrency_without_restart() {
    let dir = state_dir("reload");
    let runner = GatedRunner::new();
    let handle = runner.handle();
    let sched = Scheduler::open(&dir, limits(1, 0), Box::new(runner)).unwrap();

    for _ in 0..3 {
        sched.submit(spec(0)).unwrap();
    }
    wait_until("one claim", Duration::from_secs(10), || {
        sched.running_count() == 1
    });
    // The single slot is saturated; the other two jobs stay queued.
    std::thread::sleep(Duration::from_millis(50));
    assert_eq!(sched.running_count(), 1);
    assert_eq!(sched.queued_count(), 2);

    sched
        .reload(ServeLimits {
            max_concurrent_jobs: 3,
            ..limits(1, 0)
        })
        .unwrap();
    wait_until("three claims", Duration::from_secs(10), || {
        sched.running_count() == 3
    });
    handle.open.store(true, Ordering::SeqCst);
    wait_all_terminal(&sched, 3);
    assert_eq!(handle.max_seen.load(Ordering::SeqCst), 3);
    sched.shutdown(false);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn elastic_membership_configs_are_refused_at_submit() {
    let dir = state_dir("elastic");
    let sched = Scheduler::open(&dir, limits(1, 0), Box::new(InstantRunner)).unwrap();
    let mut s = spec(0);
    s.config.membership = Some("leave:1@2".into());
    let err = sched.submit(s).unwrap_err().to_string();
    assert!(err.contains("elastic membership"), "{err}");
    let mut s = spec(0);
    s.config.evict_after = 3;
    s.config.exchange_timeout_ms = 50;
    let err = sched.submit(s).unwrap_err().to_string();
    assert!(err.contains("elastic membership"), "{err}");
    // Refused submits burn no ids and journal nothing.
    assert_eq!(sched.list().len(), 0);
    sched.shutdown(false);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn randomized_concurrent_submit_cancel_interleavings_lose_no_job() {
    // Property: whatever the interleaving of submits and cancels across
    // threads, every submitted job keeps exactly one record and ends in
    // a terminal state — none lost, none duplicated, none stuck — and
    // the journal replays to the same terminal picture.
    let counter = AtomicUsize::new(0);
    sagips::util::proptest::run("submit/cancel interleavings", 10, |g| {
        let case = counter.fetch_add(1, Ordering::SeqCst);
        let n_jobs = g.usize_in(3..=8);
        let max_concurrent = g.usize_in(1..=3);
        let priorities: Vec<i64> =
            (0..n_jobs).map(|_| g.usize_in(0..=4) as i64 - 2).collect();
        let cancels: Vec<JobId> = (1..=n_jobs as JobId)
            .filter(|_| g.bool())
            .collect();

        let dir = state_dir(&format!("prop{case}"));
        let sched = Arc::new(
            Scheduler::open(&dir, limits(max_concurrent, 0), Box::new(InstantRunner))
                .unwrap(),
        );

        let submitter = {
            let sched = sched.clone();
            std::thread::spawn(move || {
                for p in priorities {
                    let mut s = spec(p);
                    s.name = format!("prop-{p}");
                    sched.submit(s).unwrap();
                }
            })
        };
        let canceller = {
            let sched = sched.clone();
            std::thread::spawn(move || {
                for id in cancels {
                    // Racing ahead of the submitter ("no such job") or
                    // behind the runner (already terminal) are both
                    // legitimate outcomes here.
                    let _ = sched.cancel(id);
                }
            })
        };
        submitter.join().unwrap();
        canceller.join().unwrap();
        wait_all_terminal(&sched, n_jobs);

        let rows = sched.list();
        let ids: Vec<JobId> = rows.iter().map(|r| r.id).collect();
        assert_eq!(
            ids,
            (1..=n_jobs as JobId).collect::<Vec<_>>(),
            "exactly one record per submitted job"
        );
        for r in &rows {
            assert!(
                matches!(r.state, JobState::Done | JobState::Cancelled),
                "job {} ended {:?}",
                r.id,
                r.state
            );
        }

        // The journal replays to the same terminal picture.
        let before: Vec<(JobId, JobState)> =
            rows.iter().map(|r| (r.id, r.state)).collect();
        match Arc::try_unwrap(sched) {
            Ok(s) => s.shutdown(false),
            Err(_) => panic!("scheduler still shared after joins"),
        }
        let replayed = JobQueue::open(&dir, 0).unwrap();
        let after: Vec<(JobId, JobState)> =
            replayed.jobs().map(|j| (j.id, j.state)).collect();
        assert_eq!(after, before);
        std::fs::remove_dir_all(&dir).ok();
    });
}

#[test]
fn retention_and_compaction_keep_disk_bounded() {
    let dir = state_dir("gc_compact");
    let sched = Scheduler::open(
        &dir,
        ServeLimits {
            keep_job_checkpoints: 2,
            ..limits(1, 0)
        },
        Box::new(InstantRunner),
    )
    .unwrap();
    let ids: Vec<JobId> = (0..4).map(|_| sched.submit(spec(0)).unwrap()).collect();
    wait_all_terminal(&sched, 4);
    // InstantRunner writes no checkpoints; materialize each job's
    // normalized checkpoint dir, then let reload's GC sweep apply the
    // retention window to the now-terminal jobs.
    let dirs: Vec<PathBuf> = ids
        .iter()
        .map(|&id| PathBuf::from(sched.job_config(id).unwrap().ckpt_dir))
        .collect();
    for d in &dirs {
        std::fs::create_dir_all(d).unwrap();
    }
    sched
        .reload(ServeLimits {
            keep_job_checkpoints: 2,
            ..limits(1, 0)
        })
        .unwrap();
    assert!(!dirs[0].exists(), "oldest terminal job dir should be pruned");
    assert!(!dirs[1].exists(), "second-oldest should be pruned");
    assert!(dirs[2].exists(), "newest 2 terminal jobs keep checkpoints");
    assert!(dirs[3].exists(), "newest 2 terminal jobs keep checkpoints");

    // Compaction collapses the submit/claim/finish history (12 lines)
    // to the snapshot: one submit + one state line per job.
    let lines = sched.compact().unwrap();
    assert_eq!(lines, 8);
    sched.shutdown(false);
    let replayed = JobQueue::open(&dir, 0).unwrap();
    assert_eq!(replayed.journal_lines(), 8);
    for &id in &ids {
        assert_eq!(replayed.get(id).unwrap().state, JobState::Done);
    }
    std::fs::remove_dir_all(&dir).ok();
}
