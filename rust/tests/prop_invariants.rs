//! Property-based tests over the coordinator substrates (in-repo
//! harness, `sagips::util::proptest`): collective correctness for random
//! topologies/values, fusion-plan roundtrips, RMA semantics, topology
//! invariants, native-backend gradients vs finite differences, simulator
//! sanity, JSON roundtrips.

use sagips::collective::ring::{chunked_ring_pass, partition_bounds, ring_pass};
use sagips::comm::{
    BufferPool, GradMsg, LinkModel, LocalNetwork, MembershipView, RmaRegion, RmaWindow, Topology,
};
use sagips::config::Mode;
use sagips::model::{grad, reference};
use sagips::runtime::manifest::layout_from_sizes;
use sagips::runtime::{Kernels, LayerLayout};
use sagips::sim::{simulate, ComputeModel, SimConfig};
use sagips::tensor::fusion::{segments_from_layout, FusionPlan};
use sagips::util::json::Value;
use sagips::util::proptest::{run, Gen};

/// Random MLP: layer sizes in [1, 6], 1-3 layers, flat layout from the
/// runtime's single layout builder, params drawn from the generator.
fn random_mlp(g: &mut Gen) -> (Vec<LayerLayout>, Vec<f32>, Vec<usize>) {
    let n_layers = g.usize_in(1..=3);
    let mut sizes = vec![g.usize_in(1..=6)];
    for _ in 0..n_layers {
        sizes.push(g.usize_in(1..=6));
    }
    let (_, layout, count) = layout_from_sizes(&sizes);
    let flat: Vec<f32> = (0..count).map(|_| g.f32_in(-1.0..=1.0)).collect();
    (layout, flat, sizes)
}

#[test]
fn prop_native_mlp_backward_matches_central_differences() {
    run("analytic MLP gradients match central finite differences", 60, |g| {
        let (layout, flat, sizes) = random_mlp(g);
        let batch = g.usize_in(1..=4);
        let slope = 0.2f32;
        let d_in = sizes[0];
        let d_out_cols = *sizes.last().unwrap();
        let x: Vec<f32> = (0..batch * d_in).map(|_| g.f32_in(-1.5..=1.5)).collect();
        // Scalar loss L = Σ c ⊙ forward(x) with random cotangent c.
        let c: Vec<f32> = (0..batch * d_out_cols)
            .map(|_| g.f32_in(-1.0..=1.0))
            .collect();
        let loss = |flat: &[f32], x: &[f32]| -> f64 {
            reference::mlp_forward(flat, &layout, x, batch, slope)
                .iter()
                .zip(&c)
                .map(|(&y, &cv)| (y * cv) as f64)
                .sum()
        };

        // Random kernel variant: the scalar oracle and the blocked path
        // must both satisfy the FD contract at arbitrary (non-tile) sizes.
        let kernels = if g.bool() { Kernels::Blocked } else { Kernels::Scalar };
        let mut acts = Vec::new();
        grad::mlp_forward_cached(&flat, &layout, &x, batch, slope, kernels, &mut acts);
        let mut d_out = c.clone();
        let mut scratch = Vec::new();
        let mut d_flat = vec![0.0f32; flat.len()];
        let mut d_x = vec![0.0f32; x.len()];
        grad::mlp_backward(
            &flat,
            &layout,
            &x,
            batch,
            slope,
            kernels,
            &acts,
            &mut d_out,
            &mut scratch,
            Some(&mut d_flat),
            Some(&mut d_x),
        );

        let h = 1e-2f32;
        let close = |num: f64, ana: f64| {
            // Piecewise-linear net: central differences are exact up to
            // f32 noise unless a LeakyReLU kink sits inside ±h.
            (num - ana).abs() < 2e-2 + 0.1 * ana.abs().max(num.abs())
        };
        for k in 0..flat.len() {
            let mut fp = flat.clone();
            fp[k] += h;
            let mut fm = flat.clone();
            fm[k] -= h;
            let num = (loss(&fp, &x) - loss(&fm, &x)) / (2.0 * h as f64);
            assert!(
                close(num, d_flat[k] as f64),
                "param {k}: numeric {num} vs analytic {} (sizes {sizes:?})",
                d_flat[k]
            );
        }
        for k in 0..x.len() {
            let mut xp = x.clone();
            xp[k] += h;
            let mut xm = x.clone();
            xm[k] -= h;
            let num = (loss(&flat, &xp) - loss(&flat, &xm)) / (2.0 * h as f64);
            assert!(
                close(num, d_x[k] as f64),
                "input {k}: numeric {num} vs analytic {} (sizes {sizes:?})",
                d_x[k]
            );
        }
    });
}

#[test]
fn prop_cached_forward_matches_reference_forward() {
    run("cached forward == ping-pong reference forward", 120, |g| {
        let (layout, flat, sizes) = random_mlp(g);
        let batch = g.usize_in(1..=4);
        let x: Vec<f32> = (0..batch * sizes[0]).map(|_| g.f32_in(-2.0..=2.0)).collect();
        let mut acts = Vec::new();
        grad::mlp_forward_cached(&flat, &layout, &x, batch, 0.2, Kernels::default(), &mut acts);
        let want = reference::mlp_forward(&flat, &layout, &x, batch, 0.2);
        assert_eq!(acts[layout.len() - 1], want);
    });
}

#[test]
fn prop_pipeline_backward_matches_central_differences() {
    run("quantile pipeline VJP matches central differences", 80, |g| {
        let batch = g.usize_in(1..=3);
        let events = g.usize_in(1..=4);
        let params: Vec<f32> = (0..batch * 6).map(|_| g.f32_in(-1.0..=1.0)).collect();
        let u: Vec<f32> = (0..batch * events * 2).map(|_| g.f32_in(0.0..=1.0)).collect();
        let c: Vec<f32> = (0..batch * events * 2)
            .map(|_| g.f32_in(-1.0..=1.0))
            .collect();
        let loss = |p: &[f32]| -> f64 {
            reference::pipeline(p, &u, batch, events)
                .iter()
                .zip(&c)
                .map(|(&y, &cv)| (y * cv) as f64)
                .sum()
        };
        let mut dp = Vec::new();
        grad::pipeline_backward(&c, &u, batch, events, &mut dp);
        let h = 1e-2f32;
        for k in 0..params.len() {
            let mut pp = params.clone();
            pp[k] += h;
            let mut pm = params.clone();
            pm[k] -= h;
            let num = (loss(&pp) - loss(&pm)) / (2.0 * h as f64);
            let ana = dp[k] as f64;
            // The pipeline is quadratic in u but *linear* in params, so
            // central differences are exact up to f32 rounding.
            assert!(
                (num - ana).abs() < 1e-3 + 1e-3 * ana.abs(),
                "param {k}: numeric {num} vs analytic {ana}"
            );
        }
    });
}

#[test]
fn prop_ring_pass_averages_any_ring() {
    run("ring_pass averages arbitrary member sets", 30, |g| {
        let n = g.usize_in(2..=9);
        let len = g.usize_in(1..=64);
        let values: Vec<f32> = (0..n).map(|_| g.f32_in(-100.0..=100.0)).collect();
        let expected: f32 = values.iter().sum::<f32>() / n as f32;
        let topo = Topology::new(n, 4);
        let eps = LocalNetwork::build(&topo, LinkModel::zero());
        let members: Vec<usize> = (0..n).collect();
        let handles: Vec<_> = eps
            .into_iter()
            .map(|ep| {
                let members = members.clone();
                let v = values[ep.rank];
                std::thread::spawn(move || {
                    let mut grads = vec![v; len];
                    let pool = BufferPool::new();
                    ring_pass(&ep, &members, 0, &mut grads, &pool).unwrap();
                    grads
                })
            })
            .collect();
        for h in handles {
            let grads = h.join().unwrap();
            for got in grads {
                assert!(
                    (got - expected).abs() < 1e-2 + 1e-4 * expected.abs(),
                    "{got} vs {expected}"
                );
            }
        }
    });
}

#[test]
fn prop_fusion_roundtrip_any_layout() {
    run("fusion pack/unpack roundtrips random layouts", 200, |g| {
        // Build a random contiguous layer layout.
        let n_layers = g.usize_in(1..=6);
        let mut layout = Vec::new();
        let mut off = 0usize;
        for _ in 0..n_layers {
            let w = g.usize_in(1..=64);
            let b = g.usize_in(1..=8);
            layout.push((off, w, off + w, b));
            off += w + b;
        }
        let include_bias = g.bool();
        let bucket = *g.choose(&[0usize, 16, 64, 1024]);
        let plan = FusionPlan::build(segments_from_layout(&layout), bucket, include_bias);
        let grads: Vec<f32> = (0..off).map(|_| g.f32_in(-10.0..=10.0)).collect();
        let mut packed = Vec::new();
        plan.pack(&grads, &mut packed).unwrap();
        assert_eq!(packed.len(), plan.transfer_elems());
        let mut out = vec![f32::NAN; off];
        plan.unpack(&packed, &mut out).unwrap();
        for &i in &plan.covered_indices() {
            assert_eq!(out[i], grads[i]);
        }
        // Uncovered slots untouched.
        let covered: std::collections::HashSet<usize> =
            plan.covered_indices().into_iter().collect();
        for i in 0..off {
            if !covered.contains(&i) {
                assert!(out[i].is_nan());
            }
        }
        // Weight coverage exactly matches include_bias.
        let weight_elems: usize = layout.iter().map(|&(_, w, _, _)| w).sum();
        let bias_elems: usize = layout.iter().map(|&(_, _, _, b)| b).sum();
        let want = weight_elems + if include_bias { bias_elems } else { 0 };
        assert_eq!(plan.transfer_elems(), want);
    });
}

#[test]
fn prop_topology_groups_partition_ranks() {
    run("inner groups partition ranks; outer picks group leads", 300, |g| {
        let ranks = g.usize_in(1..=64);
        let gpn = g.usize_in(1..=8);
        let topo = Topology::new(ranks, gpn);
        let mut seen = vec![0u32; ranks];
        for node in 0..topo.nodes() {
            for r in topo.inner_group(node * gpn) {
                seen[r] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1), "not a partition");
        let outer = topo.outer_group();
        assert_eq!(outer.len(), topo.nodes());
        for &o in &outer {
            assert!(topo.is_outer_member(o));
            assert_eq!(topo.inner_group(o)[0], o);
        }
        // ring_next/prev are inverse bijections
        for r in 0..ranks {
            assert_eq!(topo.ring_prev(topo.ring_next(r)), r);
        }
    });
}

/// A random live subset of `0..total` with at least `min` members,
/// sorted ascending (the order `MembershipView` hands to collectives).
fn random_live_subset(g: &mut Gen, total: usize, min: usize) -> Vec<usize> {
    let mut live: Vec<usize> = (0..total).filter(|_| g.bool()).collect();
    while live.len() < min {
        let r = g.usize_in(0..=total - 1);
        if !live.contains(&r) {
            live.push(r);
        }
    }
    live.sort_unstable();
    live
}

/// Walk the successor relation [`Topology::ring_in`] builds for the
/// rebuilt neighbour schedule and demand one cycle covering exactly
/// `members`: every member visited once, back at the start after
/// `members.len()` hops, and prev/next mutually inverse.
fn assert_single_cycle(members: &[usize]) {
    let n = members.len();
    assert!(n >= 1);
    if n == 1 {
        return;
    }
    let mut visited = Vec::with_capacity(n);
    let mut at = members[0];
    for _ in 0..n {
        visited.push(at);
        let (next, prev) = Topology::ring_in(members, at);
        let (_, back) = Topology::ring_in(members, next);
        assert_eq!(back, at, "prev(next({at})) != {at} in {members:?}");
        let (fwd, _) = Topology::ring_in(members, prev);
        assert_eq!(fwd, at, "next(prev({at})) != {at} in {members:?}");
        at = next;
    }
    assert_eq!(at, members[0], "walk did not close after {n} hops");
    visited.sort_unstable();
    assert_eq!(visited, members, "cycle must cover exactly the live set");
}

#[test]
fn prop_reringed_topologies_form_one_live_cycle() {
    run("re-ringed ring/grouped/rma schedules are single live cycles", 200, |g| {
        let total = g.usize_in(2..=48);
        let gpn = g.usize_in(1..=8);
        let topo = Topology::new(total, gpn);
        let live = random_live_subset(g, total, 2);
        let view = MembershipView::new(g.u64() % 1000 + 1, live.clone(), total);
        assert_eq!(view.live(), &live[..]);

        // Conventional ring: one cycle over every live rank.
        assert_single_cycle(view.live());

        // Grouped (blocking and RMA share the member lists): the live
        // inner groups partition the live set, each forming its own
        // cycle; the live outer group seats exactly one live rank per
        // node that still has one, each holding its seat per
        // `is_outer_member_live`.
        let mut covered = Vec::new();
        for node in 0..topo.nodes() {
            let inner = topo.inner_group_live(node * gpn, &view);
            for &r in &inner {
                assert!(view.is_live(r));
                covered.push(r);
            }
            if !inner.is_empty() {
                assert_single_cycle(&inner);
            }
        }
        covered.sort_unstable();
        assert_eq!(covered, live, "live inner groups must partition the live set");

        let outer = topo.outer_group_live(&view);
        let nodes_alive = (0..topo.nodes())
            .filter(|&n| !topo.inner_group_live(n * gpn, &view).is_empty())
            .count();
        assert_eq!(outer.len(), nodes_alive);
        for &o in &outer {
            assert!(topo.is_outer_member_live(o, &view));
        }
        if !outer.is_empty() {
            assert_single_cycle(&outer);
        }
    });
}

#[test]
fn prop_chunked_pass_over_rering_matches_serial_reference_bitwise() {
    run("chunked reduce-scatter/all-gather over a re-ring == serial sum", 25, |g| {
        // A re-ringed subset of the launched ranks runs the chunked
        // reduce-scatter + all-gather. Each partition's sum accumulates
        // serially along the ring from a fixed start, every rank then
        // copies the single averaged partition — so all live ranks must
        // agree with the serial reference *bit for bit* (and with each
        // other), dormant ranks contributing nothing.
        let total = g.usize_in(2..=10);
        let live = random_live_subset(g, total, 2);
        let n = live.len();
        let len = g.usize_in(1..=97);
        let max_elems = *g.choose(&[0usize, 7, 32]);
        let values: Vec<Vec<f32>> = (0..total)
            .map(|_| (0..len).map(|_| g.f32_in(-50.0..=50.0)).collect())
            .collect();

        let topo = Topology::new(total, 4);
        let eps = LocalNetwork::build(&topo, LinkModel::zero());
        let handles: Vec<_> = eps
            .into_iter()
            .filter(|ep| live.binary_search(&ep.rank).is_ok())
            .map(|ep| {
                let members = live.clone();
                let mut grads = values[ep.rank].clone();
                std::thread::spawn(move || {
                    let pool = BufferPool::new();
                    chunked_ring_pass(&ep, &members, 0, &mut grads, &pool, max_elems)
                        .unwrap();
                    grads
                })
            })
            .collect();

        // Serial reference. Partition j's reduction starts at ring
        // index j and accumulates member by member in ring order (the
        // receiver-adds-arrival recurrence); the average is one f32
        // multiply by 1/n, exactly as `ops::scale` applies it.
        let parts = partition_bounds(len, n);
        let inv = 1.0 / n as f32;
        let mut want = vec![0.0f32; len];
        for (j, &(lo, hi)) in parts.iter().enumerate() {
            for e in lo..hi {
                let mut acc = values[live[j]][e];
                for k in 1..n {
                    acc += values[live[(j + k) % n]][e];
                }
                want[e] = acc * inv;
            }
        }
        for h in handles {
            let got = h.join().unwrap();
            assert_eq!(
                got.iter().map(|v| v.to_bits()).collect::<Vec<u32>>(),
                want.iter().map(|v| v.to_bits()).collect::<Vec<u32>>(),
                "live members {live:?}, len {len}, max_elems {max_elems}"
            );
        }
    });
}

#[test]
fn prop_rma_window_never_loses_and_gains_nothing() {
    run("rma window conserves deposits (reads + drops == puts)", 200, |g| {
        let cap = g.usize_in(1..=8);
        let puts = g.usize_in(0..=40);
        let w = RmaWindow::new(cap);
        for e in 0..puts {
            w.put(GradMsg::new(0, e as u64, 0, vec![]));
        }
        let mut read = 0u64;
        let mut dropped = 0u64;
        let mut last_epoch = -1i64;
        while let Some((m, d)) = w.get() {
            read += 1;
            dropped += d;
            // FIFO within the window: epochs strictly increase.
            assert!((m.epoch as i64) > last_epoch);
            last_epoch = m.epoch as i64;
        }
        assert_eq!(read + dropped, puts as u64);
        assert_eq!(w.put_count(), puts as u64);
    });
}

#[test]
fn prop_simulator_time_monotone_in_epochs_and_payload() {
    run("sim time grows with epochs and payload", 40, |g| {
        let mode = *g.choose(&[
            Mode::ConvArar,
            Mode::ArarArar,
            Mode::RmaArarArar,
            Mode::Horovod,
        ]);
        let ranks = *g.choose(&[2usize, 4, 8, 16]);
        let mk = |epochs: u64, bytes: usize| SimConfig {
            epochs,
            sim_epochs: epochs.min(64),
            grad_bytes: bytes,
            compute: ComputeModel::fixed(0.01),
            ..SimConfig::paper(mode, ranks)
        };
        let small = simulate(&mk(32, 1_000)).total_s;
        let more_epochs = simulate(&mk(64, 1_000)).total_s;
        let more_bytes = simulate(&mk(32, 4_000_000)).total_s;
        assert!(more_epochs > small);
        assert!(more_bytes >= small);
        assert!(small > 0.0);
    });
}

#[test]
fn prop_json_roundtrip_random_values() {
    run("json emit/parse roundtrips random trees", 200, |g| {
        fn build(g: &mut Gen, depth: usize) -> Value {
            if depth == 0 {
                return match g.usize_in(0..=3) {
                    0 => Value::Null,
                    1 => Value::Bool(g.bool()),
                    2 => Value::Number((g.f32_in(-1e6..=1e6) as f64 * 100.0).round() / 100.0),
                    _ => Value::String(format!("s{}", g.u64() % 1000)),
                };
            }
            match g.usize_in(0..=4) {
                0..=2 => {
                    let n = g.usize_in(0..=4);
                    Value::Array((0..n).map(|_| build(g, depth - 1)).collect())
                }
                _ => {
                    let n = g.usize_in(0..=4);
                    Value::Object(
                        (0..n)
                            .map(|i| (format!("k{i}"), build(g, depth - 1)))
                            .collect(),
                    )
                }
            }
        }
        let v = build(g, 3);
        let text = v.to_json();
        let back = Value::parse(&text).unwrap();
        assert_eq!(v, back);
        let pretty = v.to_json_pretty();
        assert_eq!(Value::parse(&pretty).unwrap(), v);
    });
}

#[test]
fn prop_rma_region_pairs_are_isolated() {
    run("messages only cross their own directed window", 50, |g| {
        let n = g.usize_in(2..=8);
        let region = RmaRegion::with_capacity(n, 4);
        let a = g.usize_in(0..=n - 1);
        let mut b = g.usize_in(0..=n - 1);
        if a == b {
            b = (b + 1) % n;
        }
        region
            .window(a, b)
            .unwrap()
            .put(GradMsg::new(a, 42, 0, vec![]));
        for w in 0..n {
            for r in 0..n {
                let win = region.window(w, r).unwrap();
                if (w, r) == (a, b) {
                    assert_eq!(win.get().unwrap().0.epoch, 42);
                } else {
                    assert!(win.get().is_none());
                }
            }
        }
    });
}
