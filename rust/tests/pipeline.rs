//! Integration: the staged bounded-staleness exchange pipeline.
//!
//! Contracts under test, per registered scenario on the native backend
//! (no artifacts, never skips):
//!
//! * `staleness: 0` through the staged pipeline is **bit-identical** to
//!   the paper's blocking semantics, re-implemented here as an
//!   independent hand-written reference loop (draw → gan_step → local
//!   disc update → generator update with the averaged gradients).
//! * A drained `staleness >= 1` run checkpoint resumes bit-identically:
//!   train N epochs straight (with the same checkpoint cadence) vs train
//!   k epochs, SIGKILL-equivalent stop, resume for N − k — same final
//!   parameters, losses, and residuals.
//! * `staleness: k > 1` trains to completion on every scenario with the
//!   mean *applied* gradient staleness bounded by k.

use std::path::PathBuf;

use sagips::config::{presets, BackendKind, Mode, RunConfig};
use sagips::coordinator::launcher::run_training_from_config;
use sagips::data::{Bootstrap, ToyDataset};
use sagips::model::gan::GanState;
use sagips::model::{StepOutput, TrainStep};
use sagips::optim::{Adam, Optimizer};
use sagips::runtime::Runtime;
use sagips::util::rng::Rng;

/// A small, fast native config (model "small", batch 8 x 25 events).
fn native_cfg(scenario: &str, ranks: usize, epochs: usize) -> RunConfig {
    let mut cfg = presets::ci_default();
    cfg.backend = BackendKind::Native;
    cfg.artifacts_dir = "/nonexistent/so-the-synthetic-manifest-is-used".into();
    cfg.scenario = scenario.into();
    cfg.model = "small".into();
    cfg.mode = Mode::ArarArar;
    cfg.ranks = ranks;
    cfg.epochs = epochs;
    cfg.batch = 8;
    cfg.events = 25;
    cfg.data_pool = 1600;
    cfg.checkpoint_every = 6;
    cfg.outer_freq = 5;
    cfg
}

fn ckpt_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "sagips_pipeline_{tag}_{}",
        std::process::id()
    ));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

#[test]
fn staleness_zero_matches_handwritten_blocking_reference_per_scenario() {
    // The reference below re-derives the paper's blocking epoch loop from
    // first principles — same data plumbing as the launcher (pool, seed
    // split, shard), then draw → gan_step → disc update → gen update —
    // without touching the pipeline, window, or collective machinery
    // (Mode::Ensemble's exchange is the identity). The staged pipeline at
    // staleness 0 must reproduce it bit for bit.
    for sc in sagips::scenario::registry() {
        let mut cfg = native_cfg(sc.name(), 1, 8);
        cfg.mode = Mode::Ensemble;
        let run = run_training_from_config(&cfg)
            .unwrap_or_else(|e| panic!("{}: pipeline run failed: {e}", sc.name()));

        let rt = Runtime::from_config(&cfg, cfg.runtime_workers).unwrap();
        let handle = rt.handle();
        let manifest = handle.manifest().clone();
        let art = ["pipeline_b256_e25", "pipeline_b1024_e100", "pipeline_b64_e25"]
            .into_iter()
            .find(|a| manifest.artifact(a).is_ok())
            .expect("no pipeline artifact in the synthetic manifest");
        let pool = ToyDataset::generate(&handle, art, cfg.data_pool, cfg.seed).unwrap();
        let mut root = Rng::new(cfg.seed);
        let mut rng = root.split(0);
        let shard = pool.shard(cfg.subsample_fraction, &mut rng);
        let mut boot = Bootstrap::new(shard);
        let meta = manifest.model(&cfg.model).unwrap().clone();
        let mut state = GanState::init(&meta, manifest.leaky_slope, &mut rng);
        let mut gen_opt = Adam::new(cfg.gen_lr, state.gen.len());
        let mut disc_opt = Adam::new(cfg.disc_lr, state.disc.len());
        let mut step = TrainStep::new(handle.clone(), &cfg.gan_step_artifact()).unwrap();
        let disc_batch = step.disc_batch();
        let mut real = Vec::new();
        let mut out = StepOutput::default();
        for _ in 0..cfg.epochs {
            boot.draw(disc_batch, &mut rng, &mut real);
            step.run_into(&state.gen, &state.disc, &real, &mut rng, &mut out)
                .unwrap();
            disc_opt.step(&mut state.disc, &out.disc_grads);
            gen_opt.step(&mut state.gen, &out.gen_grads);
        }
        rt.shutdown();

        assert_eq!(run.states[0].gen, state.gen, "{} generator", sc.name());
        assert_eq!(run.states[0].disc, state.disc, "{} discriminator", sc.name());
        // Blocking runs record zero applied staleness, one sample/epoch.
        assert_eq!(run.metrics.mean_staleness(), Some(0.0), "{}", sc.name());
    }
}

#[test]
fn blocking_multirank_run_is_invariant_to_checkpoint_cadence() {
    // At staleness 0 a drain is a no-op, so turning run checkpointing on
    // must not perturb training at all.
    let dir = ckpt_dir("inv");
    let plain = run_training_from_config(&native_cfg("quantile", 4, 12)).unwrap();
    let mut with_ckpt = native_cfg("quantile", 4, 12);
    with_ckpt.ckpt_every = 4;
    with_ckpt.ckpt_dir = dir.display().to_string();
    let ckpt = run_training_from_config(&with_ckpt).unwrap();
    for (a, b) in plain.states.iter().zip(&ckpt.states) {
        assert_eq!(a.gen, b.gen);
        assert_eq!(a.disc, b.disc);
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Train N epochs straight (checkpoint cadence on) vs train to the cut,
/// stop, resume for the rest — the two must agree bit for bit. The
/// cadence drains the exchange window before every deposit, which is
/// exactly what makes this hold for staleness >= 1.
fn assert_drained_resume_equivalence(scenario: &str, staleness: usize) {
    const TOTAL: usize = 12;
    const CUT: usize = 7;
    let full_dir = ckpt_dir(&format!("full_{scenario}_{staleness}"));
    let head_dir = ckpt_dir(&format!("head_{scenario}_{staleness}"));

    let mut full = native_cfg(scenario, 4, TOTAL);
    full.staleness = staleness;
    full.ckpt_every = CUT;
    full.ckpt_dir = full_dir.display().to_string();
    let full_run = run_training_from_config(&full)
        .unwrap_or_else(|e| panic!("{scenario} k={staleness}: full run failed: {e}"));

    let mut head = native_cfg(scenario, 4, CUT);
    head.staleness = staleness;
    head.ckpt_every = CUT;
    head.ckpt_dir = head_dir.display().to_string();
    run_training_from_config(&head)
        .unwrap_or_else(|e| panic!("{scenario} k={staleness}: head run failed: {e}"));

    let mut tail = native_cfg(scenario, 4, TOTAL);
    tail.staleness = staleness;
    tail.ckpt_every = CUT;
    tail.ckpt_dir = head_dir.display().to_string();
    tail.resume = Some(head_dir.display().to_string());
    let resumed = run_training_from_config(&tail)
        .unwrap_or_else(|e| panic!("{scenario} k={staleness}: resume failed: {e}"));
    assert_eq!(resumed.resumed_from, Some(CUT as u64 - 1));

    for (rank, (a, b)) in full_run.states.iter().zip(&resumed.states).enumerate() {
        assert_eq!(a.gen, b.gen, "{scenario} k={staleness} rank {rank} generator");
        assert_eq!(
            a.disc, b.disc,
            "{scenario} k={staleness} rank {rank} discriminator"
        );
    }
    assert_eq!(
        full_run.metrics.mean_of_last("gen_loss"),
        resumed.metrics.mean_of_last("gen_loss"),
        "{scenario} k={staleness} final gen loss"
    );
    assert_eq!(
        full_run.final_residuals.unwrap(),
        resumed.final_residuals.unwrap(),
        "{scenario} k={staleness} final residuals"
    );

    std::fs::remove_dir_all(&full_dir).ok();
    std::fs::remove_dir_all(&head_dir).ok();
}

#[test]
fn drained_overlap_checkpoint_resumes_bit_identically_per_scenario() {
    // The lifted limitation: staleness 1 (the historical overlap_comm)
    // now composes with run checkpointing on every scenario.
    for sc in sagips::scenario::registry() {
        assert_drained_resume_equivalence(sc.name(), 1);
    }
}

#[test]
fn drained_deep_window_checkpoint_resumes_bit_identically() {
    // Quiescence is window-depth-agnostic: a k-deep run drains and
    // resumes just as exactly.
    assert_drained_resume_equivalence("quantile", 2);
}

#[test]
fn deep_windows_train_every_scenario_with_bounded_mean_staleness() {
    const EPOCHS: usize = 12;
    for sc in sagips::scenario::registry() {
        for k in [2usize, 4] {
            let mut cfg = native_cfg(sc.name(), 4, EPOCHS);
            cfg.staleness = k;
            let run = run_training_from_config(&cfg)
                .unwrap_or_else(|e| panic!("{} k={k}: run failed: {e}", sc.name()));
            // Trains to completion with finite results.
            assert_eq!(
                run.metrics.mean_series("gen_loss").len(),
                EPOCHS,
                "{} k={k}",
                sc.name()
            );
            let r = run.final_residuals.unwrap();
            assert!(r.iter().all(|x| x.is_finite()), "{} k={k}", sc.name());
            // Every epoch's exchange is applied exactly once...
            for (rank, c) in run.comm.iter().enumerate() {
                assert_eq!(
                    c.applies, EPOCHS as u64,
                    "{} k={k} rank {rank} applies",
                    sc.name()
                );
                assert!(
                    c.mean_staleness() <= k as f64,
                    "{} k={k} rank {rank}: comm mean staleness {}",
                    sc.name(),
                    c.mean_staleness()
                );
            }
            // ...and the mean applied staleness is positive yet <= k.
            let ms = run.metrics.mean_staleness().expect("staleness recorded");
            assert!(
                ms > 0.0 && ms <= k as f64,
                "{} k={k}: mean applied staleness {ms}",
                sc.name()
            );
        }
    }
}

#[test]
fn windowed_runs_are_deterministic() {
    // The k-deep apply schedule depends only on the window depth, never
    // on comm-thread timing: two identical runs must agree bit for bit.
    let mut cfg = native_cfg("quantile", 4, 10);
    cfg.staleness = 2;
    let a = run_training_from_config(&cfg).unwrap();
    let b = run_training_from_config(&cfg).unwrap();
    for (sa, sb) in a.states.iter().zip(&b.states) {
        assert_eq!(sa.gen, sb.gen);
        assert_eq!(sa.disc, sb.disc);
    }
    assert_eq!(a.final_residuals.unwrap(), b.final_residuals.unwrap());
}
