//! Integration: `sagips serve` against real training.
//!
//! The contracts under test, on the native backend:
//!
//! * **cancellation matrix** — a cancel during a run stops every rank
//!   at the same consensus checkpoint boundary (deterministically
//!   provoked by pre-arming the cancel flag, so the proposal happens at
//!   the first boundary and the stop epoch is computable by hand),
//!   deposits a final full-width checkpoint there, and `--resume` of
//!   the cancelled config is **bit-identical** to an uninterrupted run
//!   of the same cadence; a cancel whose stop boundary lands past the
//!   final epoch completes normally instead.
//! * **serve smoke** — an in-process daemon running two jobs (different
//!   scenarios) produces metrics and checkpoints bit-identical to
//!   one-shot `sagips train` runs of the same configs.
//! * **protocol-level refusals** — elastic-membership configs are
//!   refused at submit, unknown verbs list the valid ones.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use sagips::config::{presets, BackendKind, Mode, RunConfig};
use sagips::coordinator::launcher::{
    run_training_from_config, run_training_from_config_controlled,
};
use sagips::coordinator::RunControl;
use sagips::model::checkpoint::TrainCheckpoint;
use sagips::service::{protocol, Daemon, JobState, ServeLimits, TrainingRunner};
use sagips::util::json::Value;

/// The resume-suite harness config: small, fast, native, deterministic.
fn native_cfg(scenario: &str, ranks: usize, epochs: usize) -> RunConfig {
    let mut cfg = presets::ci_default();
    cfg.backend = BackendKind::Native;
    cfg.artifacts_dir = "/nonexistent/so-the-synthetic-manifest-is-used".into();
    cfg.scenario = scenario.into();
    cfg.model = "small".into();
    cfg.mode = Mode::ArarArar;
    cfg.ranks = ranks;
    cfg.epochs = epochs;
    cfg.batch = 8;
    cfg.events = 25;
    cfg.data_pool = 1600;
    cfg.checkpoint_every = 6;
    cfg.outer_freq = 5;
    cfg
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "sagips_serve_{tag}_{}",
        std::process::id()
    ));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

/// Assert two run checkpoints carry bit-identical training state.
/// (`elapsed_s` is wall-clock and legitimately differs.)
fn assert_ckpt_state_eq(a: &TrainCheckpoint, b: &TrainCheckpoint, what: &str) {
    assert_eq!(a.epoch, b.epoch, "{what}: epoch");
    assert_eq!(a.seed, b.seed, "{what}: seed");
    assert_eq!(a.scenario, b.scenario, "{what}: scenario");
    assert_eq!(a.ranks.len(), b.ranks.len(), "{what}: rank count");
    for (ra, rb) in a.ranks.iter().zip(&b.ranks) {
        assert_eq!(ra, rb, "{what}: rank {} state", ra.rank);
    }
}

#[test]
fn cancel_stops_every_rank_at_the_consensus_boundary_and_resumes_bit_identically() {
    // Cadence 6 ⇒ boundaries at epochs 5, 11, 17. The cancel flag is
    // armed before the run, so the first rank to reach boundary 5
    // proposes the stop: margin = staleness(0) + 2, target =
    // (5 + 2 + 1).div_ceil(6) * 6 − 1 = 11. Every rank must stop there.
    const TOTAL: usize = 18;
    const BOUNDARY: u64 = 11;
    let dir = tmp_dir("cancel_eq");

    let mut cancelled_cfg = native_cfg("quantile", 4, TOTAL);
    cancelled_cfg.ckpt_every = 6;
    cancelled_cfg.ckpt_dir = dir.display().to_string();
    let ctl = Arc::new(RunControl::new());
    ctl.request_cancel();
    let cancelled =
        run_training_from_config_controlled(&cancelled_cfg, Some(ctl)).unwrap();
    assert_eq!(cancelled.stopped_at, Some(BOUNDARY));
    // Exactly epochs 0..=11 trained, nothing past the stop boundary.
    assert_eq!(
        cancelled.metrics.mean_series("gen_loss").len(),
        BOUNDARY as usize + 1
    );
    // The final checkpoint sits exactly at the stop boundary.
    let latest = TrainCheckpoint::latest(&dir).unwrap().expect("no checkpoint");
    assert!(latest.ends_with(TrainCheckpoint::dir_name(BOUNDARY)), "{latest:?}");

    // Resuming the cancelled config runs the remaining epochs...
    let mut tail = native_cfg("quantile", 4, TOTAL);
    tail.resume = Some(dir.display().to_string());
    let resumed = run_training_from_config(&tail).unwrap();
    assert_eq!(resumed.resumed_from, Some(BOUNDARY));
    assert_eq!(
        resumed.metrics.mean_series("gen_loss").len(),
        TOTAL - (BOUNDARY as usize + 1)
    );

    // ...and lands bit-identical to an uninterrupted 18-epoch run.
    let full = run_training_from_config(&native_cfg("quantile", 4, TOTAL)).unwrap();
    for (rank, (a, b)) in full.states.iter().zip(&resumed.states).enumerate() {
        assert_eq!(a.gen, b.gen, "rank {rank} generator");
        assert_eq!(a.disc, b.disc, "rank {rank} discriminator");
    }
    assert_eq!(
        full.metrics.mean_of_last("gen_loss"),
        resumed.metrics.mean_of_last("gen_loss")
    );
    assert_eq!(
        full.metrics.mean_of_last("disc_loss"),
        resumed.metrics.mean_of_last("disc_loss")
    );
    assert_eq!(full.final_residuals, resumed.final_residuals);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn cancel_with_a_windowed_exchange_waits_out_the_drift_margin() {
    // staleness 2 widens the stop margin to window + 2 = 4: with
    // cadence 4 (boundaries 3, 7, 11, 15) and the proposal at boundary
    // 3, target = (3 + 4 + 1).div_ceil(4) * 4 − 1 = 7 — one full
    // cadence later than the blocking case, so no rank can already be
    // past the stop when it is decided.
    let dir = tmp_dir("cancel_window");
    let mut cfg = native_cfg("saturation", 4, 16);
    cfg.staleness = 2;
    cfg.ckpt_every = 4;
    cfg.ckpt_dir = dir.display().to_string();
    let ctl = Arc::new(RunControl::new());
    ctl.request_cancel();
    let run = run_training_from_config_controlled(&cfg, Some(ctl)).unwrap();
    assert_eq!(run.stopped_at, Some(7));
    let latest = TrainCheckpoint::latest(&dir).unwrap().expect("no checkpoint");
    assert!(latest.ends_with(TrainCheckpoint::dir_name(7)), "{latest:?}");

    // The deposited checkpoint resumes cleanly for the remaining epochs.
    // (Bit-identity under a windowed exchange is not asserted: with
    // staleness ≥ 1 gradient application order is timing-dependent by
    // design; the contract here is the agreed stop and a valid resume.)
    let mut tail = native_cfg("saturation", 4, 16);
    tail.staleness = 2;
    tail.resume = Some(dir.display().to_string());
    let resumed = run_training_from_config(&tail).unwrap();
    assert_eq!(resumed.resumed_from, Some(7));
    assert_eq!(resumed.metrics.mean_series("gen_loss").len(), 8);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn cancel_whose_boundary_lands_past_the_final_epoch_completes_normally() {
    // 6 epochs at cadence 6: the only boundary is the final epoch 5,
    // and the proposal there targets epoch 11 — past the end of the
    // run. The run must complete as if never cancelled.
    let dir = tmp_dir("cancel_late");
    let mut cfg = native_cfg("quantile", 2, 6);
    cfg.ckpt_every = 6;
    cfg.ckpt_dir = dir.display().to_string();
    let ctl = Arc::new(RunControl::new());
    ctl.request_cancel();
    let run = run_training_from_config_controlled(&cfg, Some(ctl)).unwrap();
    assert_eq!(run.stopped_at, None, "run must complete normally");
    assert_eq!(run.metrics.mean_series("gen_loss").len(), 6);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn serve_smoke_jobs_are_bit_identical_to_one_shot_training() {
    // An in-process daemon, two concurrent jobs on different scenarios,
    // driven entirely through the line-JSON control channel.
    let state = tmp_dir("smoke_state");
    let daemon = Daemon::open(
        &state,
        ServeLimits {
            max_concurrent_jobs: 2,
            max_queued: 0,
            default_ckpt_every: 6,
            ..ServeLimits::default()
        },
        None,
        Box::new(TrainingRunner),
    )
    .unwrap();

    let submit = |scenario: &str| -> u64 {
        let mut cfg = native_cfg(scenario, 2, 12);
        cfg.ckpt_every = 6;
        let line = protocol::Request::Submit {
            name: scenario.to_string(),
            priority: 0,
            config: cfg,
        }
        .to_line();
        let (resp, quit) = daemon.handle_line(&line);
        assert!(!quit);
        let v = Value::parse(&resp).unwrap();
        assert_eq!(v.get("ok"), Some(&Value::Bool(true)), "{resp}");
        v.req_usize("id").unwrap() as u64
    };
    let quantile_id = submit("quantile");
    let saturation_id = submit("saturation");

    // Poll both jobs to completion over the protocol.
    let status_of = |id: u64| -> sagips::service::JobStatus {
        let (resp, _) = daemon.handle_line(
            &protocol::Request::Status { id }.to_line(),
        );
        let v = Value::parse(&resp).unwrap();
        assert_eq!(v.get("ok"), Some(&Value::Bool(true)), "{resp}");
        protocol::parse_status(v.req("job").unwrap()).unwrap()
    };
    let t0 = Instant::now();
    loop {
        let q = status_of(quantile_id);
        let s = status_of(saturation_id);
        if q.state.is_terminal() && s.state.is_terminal() {
            assert_eq!(q.state, JobState::Done, "{}", q.detail);
            assert_eq!(s.state, JobState::Done, "{}", s.detail);
            break;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(300),
            "jobs did not finish: {q:?} {s:?}"
        );
        std::thread::sleep(Duration::from_millis(50));
    }

    // Each job is bit-identical to a one-shot train of its (normalized)
    // config: same final checkpoint state, same terminal losses.
    for (id, scenario) in [(quantile_id, "quantile"), (saturation_id, "saturation")] {
        let job_cfg = daemon.scheduler().job_config(id).unwrap();
        let job_ck =
            TrainCheckpoint::load_for_scenario(&PathBuf::from(&job_cfg.ckpt_dir), scenario)
                .unwrap();
        assert_eq!(job_ck.epoch, 11, "{scenario}: final boundary checkpoint");

        let oneshot_dir = tmp_dir(&format!("smoke_oneshot_{scenario}"));
        let mut oneshot_cfg = job_cfg.clone();
        oneshot_cfg.ckpt_dir = oneshot_dir.display().to_string();
        let oneshot = run_training_from_config(&oneshot_cfg).unwrap();
        let oneshot_ck =
            TrainCheckpoint::load_for_scenario(&oneshot_dir, scenario).unwrap();
        assert_ckpt_state_eq(&job_ck, &oneshot_ck, scenario);

        let st = status_of(id);
        assert_eq!(st.epochs_done, 12, "{scenario}");
        assert_eq!(st.gen_loss, oneshot.metrics.mean_of_last("gen_loss"), "{scenario}");
        assert_eq!(
            st.disc_loss,
            oneshot.metrics.mean_of_last("disc_loss"),
            "{scenario}"
        );
        std::fs::remove_dir_all(&oneshot_dir).ok();
    }

    // list over the protocol sees both jobs done.
    let (resp, _) = daemon.handle_line(&protocol::Request::List.to_line());
    let v = Value::parse(&resp).unwrap();
    let jobs = v.req("jobs").unwrap().as_array().unwrap();
    assert_eq!(jobs.len(), 2);

    daemon.close();
    std::fs::remove_dir_all(&state).ok();
}

#[test]
fn protocol_refuses_membership_configs_and_unknown_verbs() {
    let state = tmp_dir("refusals");
    let daemon = Daemon::open(
        &state,
        ServeLimits::default(),
        None,
        Box::new(TrainingRunner),
    )
    .unwrap();

    // Elastic membership cannot compose with the cancellation consensus.
    let mut cfg = native_cfg("quantile", 4, 12);
    cfg.membership = Some("leave:3@4".into());
    let line = protocol::Request::Submit {
        name: "elastic".into(),
        priority: 0,
        config: cfg,
    }
    .to_line();
    let (resp, _) = daemon.handle_line(&line);
    let v = Value::parse(&resp).unwrap();
    assert_eq!(v.get("ok"), Some(&Value::Bool(false)), "{resp}");
    assert!(
        v.req_str("error").unwrap().contains("elastic membership"),
        "{resp}"
    );

    // Unknown verbs list every valid one.
    let (resp, _) = daemon.handle_line(r#"{"verb":"pause","id":1}"#);
    let v = Value::parse(&resp).unwrap();
    assert_eq!(v.get("ok"), Some(&Value::Bool(false)));
    let err = v.req_str("error").unwrap().to_string();
    for verb in protocol::VERBS {
        assert!(err.contains(verb), "error should list '{verb}': {err}");
    }

    // Status of a job that does not exist is a clean error, not a hang.
    let (resp, _) = daemon.handle_line(&protocol::Request::Status { id: 99 }.to_line());
    let v = Value::parse(&resp).unwrap();
    assert_eq!(v.get("ok"), Some(&Value::Bool(false)));

    daemon.close();
    std::fs::remove_dir_all(&state).ok();
}

#[test]
fn scheduler_cancel_of_a_live_training_run_leaves_a_resumable_checkpoint() {
    // Timing-dependent variant of the deterministic cancel tests: a
    // long real run is cancelled from another thread mid-flight. The
    // stop boundary is whatever the consensus picks, but the contract
    // holds regardless: terminal state `cancelled`, a checkpoint at
    // exactly the reported boundary, and a clean resume from it.
    let state = tmp_dir("live_cancel");
    let daemon = Daemon::open(
        &state,
        ServeLimits {
            max_concurrent_jobs: 1,
            max_queued: 0,
            default_ckpt_every: 6,
            ..ServeLimits::default()
        },
        None,
        Box::new(TrainingRunner),
    )
    .unwrap();
    let sched = daemon.scheduler();

    let mut cfg = native_cfg("quantile", 2, 600);
    cfg.ckpt_every = 6;
    let id = sched
        .submit(sagips::service::JobSpec {
            name: "long".into(),
            priority: 0,
            config: cfg,
        })
        .unwrap();
    // Cancel as soon as the job is claimed — long before its 600
    // epochs could finish, so it must land `cancelled`, not `done`.
    let t0 = Instant::now();
    while sched.status(id).unwrap().state != JobState::Running {
        assert!(t0.elapsed() < Duration::from_secs(60), "job never started");
        std::thread::sleep(Duration::from_millis(5));
    }
    sched.cancel(id).unwrap();
    let t0 = Instant::now();
    while !sched.status(id).unwrap().state.is_terminal() {
        assert!(t0.elapsed() < Duration::from_secs(120), "job never stopped");
        std::thread::sleep(Duration::from_millis(20));
    }
    let st = sched.status(id).unwrap();
    assert_eq!(st.state, JobState::Cancelled, "{}", st.detail);
    assert!(st.epochs_done > 0 && st.epochs_done < 600, "{}", st.epochs_done);
    let boundary = st.epochs_done - 1;

    // The final checkpoint is at exactly the reported boundary...
    let job_cfg = sched.job_config(id).unwrap();
    let ckpt_dir = PathBuf::from(&job_cfg.ckpt_dir);
    let latest = TrainCheckpoint::latest(&ckpt_dir).unwrap().expect("no checkpoint");
    assert!(
        latest.ends_with(TrainCheckpoint::dir_name(boundary)),
        "{latest:?} vs boundary {boundary}"
    );
    // ...and resuming the cancelled config trains the remaining epochs.
    let mut tail = job_cfg.clone();
    tail.epochs = st.epochs_done as usize + 6;
    tail.resume = Some(job_cfg.ckpt_dir.clone());
    let resumed = run_training_from_config(&tail).unwrap();
    assert_eq!(resumed.resumed_from, Some(boundary));
    assert_eq!(resumed.metrics.mean_series("gen_loss").len(), 6);

    daemon.close();
    std::fs::remove_dir_all(&state).ok();
}
