//! Integration: full distributed training through the real artifact set.
//!
//! These tests need `make artifacts` to have run; they skip silently when
//! the manifest is missing (e.g. docs-only checkouts) so `cargo test`
//! stays meaningful everywhere.

use std::path::{Path, PathBuf};
use std::sync::OnceLock;

use sagips::config::{presets, Mode, RunConfig};
use sagips::coordinator::launcher::{run_training, run_training_with_links};
use sagips::comm::LinkModel;
use sagips::model::residuals;
use sagips::runtime::{RuntimeHandle, RuntimePool};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

/// One shared pool across all tests in this binary (PJRT clients are
/// expensive; the pool is explicitly designed to be shared).
fn shared_handle() -> Option<RuntimeHandle> {
    static POOL: OnceLock<Option<RuntimePool>> = OnceLock::new();
    POOL.get_or_init(|| artifacts_dir().map(|d| RuntimePool::from_dir(&d, 3).unwrap()))
        .as_ref()
        .map(|p| p.handle())
}

fn quick_cfg(mode: Mode, ranks: usize, epochs: usize) -> RunConfig {
    let mut cfg = presets::ci_default();
    cfg.mode = mode;
    cfg.ranks = ranks;
    cfg.epochs = epochs;
    cfg.batch = 16;
    cfg.data_pool = 1600;
    cfg.checkpoint_every = epochs / 2;
    cfg.outer_freq = 5;
    cfg.runtime_workers = 3;
    cfg
}

#[test]
fn single_rank_training_reduces_losses() {
    let Some(h) = shared_handle() else { return };
    let run = run_training(&quick_cfg(Mode::Ensemble, 1, 30), &h).unwrap();
    // Both loss series exist and are finite.
    let g = run.metrics.mean_series("gen_loss");
    assert_eq!(g.len(), 30);
    assert!(g.values.iter().all(|v| v.is_finite()));
    assert!(run.final_residuals.is_some());
    assert!(run.wall_s > 0.0);
    assert!(run.analysis_rate() > 0.0);
}

#[test]
fn all_table2_modes_train_end_to_end() {
    let Some(h) = shared_handle() else { return };
    for mode in [
        Mode::ConvArar,
        Mode::ArarArar,
        Mode::RmaArarArar,
        Mode::Horovod,
        Mode::Hierarchical,
        Mode::DoubleBinaryTree,
    ] {
        let run = run_training(&quick_cfg(mode, 4, 12), &h)
            .unwrap_or_else(|e| panic!("{} failed: {e}", mode.name()));
        let r = run.final_residuals.unwrap();
        assert!(
            r.iter().all(|x| x.is_finite()),
            "{} produced non-finite residuals",
            mode.name()
        );
    }
}

#[test]
fn grouped_modes_communicate_less_than_conventional() {
    let Some(h) = shared_handle() else { return };
    let conv = run_training(&quick_cfg(Mode::ConvArar, 8, 10), &h).unwrap();
    let grp = run_training(&quick_cfg(Mode::ArarArar, 8, 10), &h).unwrap();
    let conv_bytes: usize = conv.comm.iter().map(|c| c.bytes_sent).sum();
    let grp_bytes: usize = grp.comm.iter().map(|c| c.bytes_sent).sum();
    assert!(
        grp_bytes < conv_bytes,
        "grouped {grp_bytes} >= conventional {conv_bytes}"
    );
}

#[test]
fn bias_gradients_stay_local_weights_are_exchanged() {
    let Some(h) = shared_handle() else { return };
    // Weight-only transfer: per-epoch payload is the weight count, not
    // the full parameter count.
    let meta = h.manifest().model("paper").unwrap().clone();
    let weights: usize = meta.gen_layout.iter().map(|l| l.w_len()).sum();
    let run = run_training(&quick_cfg(Mode::ConvArar, 2, 4), &h).unwrap();
    // ConvArar at 2 ranks: 1 message per epoch per rank of exactly the
    // packed weight payload.
    let per_rank = &run.comm[0];
    assert_eq!(per_rank.bytes_sent, 4 * weights * 4, "unexpected payload");
}

#[test]
fn distributed_run_is_seed_reproducible() {
    let Some(h) = shared_handle() else { return };
    let cfg = quick_cfg(Mode::ArarArar, 4, 8);
    let a = run_training(&cfg, &h).unwrap();
    let b = run_training(&cfg, &h).unwrap();
    // Same seed -> identical final generator parameters on every rank.
    for (sa, sb) in a.states.iter().zip(&b.states) {
        assert_eq!(sa.gen, sb.gen);
    }
    let ra = a.final_residuals.unwrap();
    let rb = b.final_residuals.unwrap();
    assert_eq!(ra, rb);
}

#[test]
fn different_seeds_differ() {
    let Some(h) = shared_handle() else { return };
    let mut cfg = quick_cfg(Mode::ArarArar, 2, 6);
    let a = run_training(&cfg, &h).unwrap();
    cfg.seed += 1;
    let b = run_training(&cfg, &h).unwrap();
    assert_ne!(a.states[0].gen, b.states[0].gen);
}

#[test]
fn horovod_ranks_see_full_data() {
    let Some(h) = shared_handle() else { return };
    // Indirect check via the recorded events metric: every mode analyzes
    // disc_batch events per epoch; horovod differs only in sharding and
    // synchronization, which must not change the event count.
    let run = run_training(&quick_cfg(Mode::Horovod, 4, 6), &h).unwrap();
    assert_eq!(run.total_events(), (4 * 6 * 16 * 25) as f64);
}

#[test]
fn injected_latency_slows_the_blocking_ring() {
    let Some(h) = shared_handle() else { return };
    let cfg = quick_cfg(Mode::ConvArar, 4, 8);
    let fast = run_training_with_links(&cfg, &h, LinkModel::zero()).unwrap();
    // Exaggerated per-message latency.
    let slow_links = {
        let mut lm = LinkModel::mpi4py_like();
        lm.inter_node.alpha_s = 3e-3;
        lm.intra_node.alpha_s = 3e-3;
        lm.with_injection(1.0)
    };
    let slow = run_training_with_links(&cfg, &h, slow_links).unwrap();
    assert!(
        slow.wall_s > fast.wall_s,
        "latency injection had no effect: {} vs {}",
        slow.wall_s,
        fast.wall_s
    );
    // And the numerics are unaffected by timing (same seed, same result).
    assert_eq!(slow.states[0].gen, fast.states[0].gen);
}

#[test]
fn residual_evaluator_matches_rust_reference_forward() {
    let Some(h) = shared_handle() else { return };
    // Cross-check the gen_predict artifact against the pure-Rust MLP.
    use sagips::model::gan::GanState;
    use sagips::model::reference;
    use sagips::util::rng::Rng;
    let meta = h.manifest().model("paper").unwrap().clone();
    let mut rng = Rng::new(5);
    let state = GanState::init(&meta, h.manifest().leaky_slope, &mut rng);
    let ev = sagips::model::Residuals::new(h.clone(), "gen_predict_paper_k256", 77).unwrap();
    let preds = ev.predict(&state.gen).unwrap();
    // Reference forward on the same fixed noise: regenerate it the same
    // way Residuals does.
    let mut rng2 = Rng::with_stream(77, 0xEE51D);
    let mut z = vec![0.0f32; 256 * h.manifest().latent_dim];
    rng2.fill_normal(&mut z);
    let want = reference::mlp_forward(
        &state.gen,
        &meta.gen_layout,
        &z,
        256,
        h.manifest().leaky_slope as f32,
    );
    assert_eq!(preds.len(), want.len());
    for (a, b) in preds.iter().zip(&want) {
        assert!((a - b).abs() < 1e-3 + 1e-3 * b.abs(), "{a} vs {b}");
    }
}

#[test]
fn weak_scaling_artifacts_exist_for_eq10() {
    let Some(h) = shared_handle() else { return };
    // eq (10) grid with base batch 64: N in {1,2,4,8,16}.
    for n in [1usize, 2, 4, 8, 16] {
        let name = format!("gan_step_paper_b{}_e25", 64 / n);
        assert!(
            h.manifest().artifact(&name).is_ok(),
            "missing weak-scaling artifact {name}"
        );
    }
}

#[test]
fn checkpoint_cadence_matches_config() {
    let Some(h) = shared_handle() else { return };
    let mut cfg = quick_cfg(Mode::Ensemble, 1, 20);
    cfg.checkpoint_every = 5;
    let run = run_training(&cfg, &h).unwrap();
    // epoch 0 + epochs 4, 9, 14, 19 -> 5 checkpoints
    assert_eq!(run.residual_curve.len(), 5);
    let epochs: Vec<u64> = run.residual_curve.iter().map(|p| p.epoch).collect();
    assert_eq!(epochs, vec![0, 4, 9, 14, 19]);
    // elapsed times strictly increasing
    for w in run.residual_curve.windows(2) {
        assert!(w[1].elapsed_s > w[0].elapsed_s);
    }
    // mean_abs helper is finite on all
    for p in &run.residual_curve {
        assert!(residuals::mean_abs(&p.residuals).is_finite());
    }
}
