//! Integration: full distributed training.
//!
//! The `native backend` tests at the bottom run *unconditionally* — the
//! native CPU backend needs no artifacts, so `cargo test` always covers
//! at least one real multi-rank training end to end. The PJRT tests need
//! `make artifacts` to have run; they skip silently when the manifest is
//! missing (e.g. docs-only checkouts).

use std::path::{Path, PathBuf};
use std::sync::OnceLock;

use sagips::config::{presets, BackendKind, Mode, RunConfig};
use sagips::coordinator::launcher::{
    run_training, run_training_from_config, run_training_with_links,
};
use sagips::comm::LinkModel;
use sagips::model::residuals;
use sagips::runtime::{NativeRuntime, RuntimeHandle, RuntimePool};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

/// One shared pool across all tests in this binary (PJRT clients are
/// expensive; the pool is explicitly designed to be shared).
fn shared_handle() -> Option<RuntimeHandle> {
    static POOL: OnceLock<Option<RuntimePool>> = OnceLock::new();
    POOL.get_or_init(|| artifacts_dir().map(|d| RuntimePool::from_dir(&d, 3).unwrap()))
        .as_ref()
        .map(|p| p.handle())
}

fn quick_cfg(mode: Mode, ranks: usize, epochs: usize) -> RunConfig {
    let mut cfg = presets::ci_default();
    cfg.mode = mode;
    cfg.ranks = ranks;
    cfg.epochs = epochs;
    cfg.batch = 16;
    cfg.data_pool = 1600;
    cfg.checkpoint_every = epochs / 2;
    cfg.outer_freq = 5;
    cfg.runtime_workers = 3;
    cfg
}

#[test]
fn single_rank_training_reduces_losses() {
    let Some(h) = shared_handle() else { return };
    let run = run_training(&quick_cfg(Mode::Ensemble, 1, 30), &h).unwrap();
    // Both loss series exist and are finite.
    let g = run.metrics.mean_series("gen_loss");
    assert_eq!(g.len(), 30);
    assert!(g.values.iter().all(|v| v.is_finite()));
    assert!(run.final_residuals.is_some());
    assert!(run.wall_s > 0.0);
    assert!(run.analysis_rate() > 0.0);
}

#[test]
fn all_table2_modes_train_end_to_end() {
    let Some(h) = shared_handle() else { return };
    for mode in [
        Mode::ConvArar,
        Mode::ArarArar,
        Mode::RmaArarArar,
        Mode::Horovod,
        Mode::Hierarchical,
        Mode::DoubleBinaryTree,
    ] {
        let run = run_training(&quick_cfg(mode, 4, 12), &h)
            .unwrap_or_else(|e| panic!("{} failed: {e}", mode.name()));
        let r = run.final_residuals.unwrap();
        assert!(
            r.iter().all(|x| x.is_finite()),
            "{} produced non-finite residuals",
            mode.name()
        );
    }
}

#[test]
fn grouped_modes_communicate_less_than_conventional() {
    let Some(h) = shared_handle() else { return };
    let conv = run_training(&quick_cfg(Mode::ConvArar, 8, 10), &h).unwrap();
    let grp = run_training(&quick_cfg(Mode::ArarArar, 8, 10), &h).unwrap();
    let conv_bytes: usize = conv.comm.iter().map(|c| c.bytes_sent).sum();
    let grp_bytes: usize = grp.comm.iter().map(|c| c.bytes_sent).sum();
    assert!(
        grp_bytes < conv_bytes,
        "grouped {grp_bytes} >= conventional {conv_bytes}"
    );
}

#[test]
fn bias_gradients_stay_local_weights_are_exchanged() {
    let Some(h) = shared_handle() else { return };
    // Weight-only transfer: per-epoch payload is the weight count, not
    // the full parameter count.
    let meta = h.manifest().model("paper").unwrap().clone();
    let weights: usize = meta.gen_layout.iter().map(|l| l.w_len()).sum();
    let run = run_training(&quick_cfg(Mode::ConvArar, 2, 4), &h).unwrap();
    // ConvArar at 2 ranks: 1 message per epoch per rank of exactly the
    // packed weight payload.
    let per_rank = &run.comm[0];
    assert_eq!(per_rank.bytes_sent, 4 * weights * 4, "unexpected payload");
}

#[test]
fn distributed_run_is_seed_reproducible() {
    let Some(h) = shared_handle() else { return };
    let cfg = quick_cfg(Mode::ArarArar, 4, 8);
    let a = run_training(&cfg, &h).unwrap();
    let b = run_training(&cfg, &h).unwrap();
    // Same seed -> identical final generator parameters on every rank.
    for (sa, sb) in a.states.iter().zip(&b.states) {
        assert_eq!(sa.gen, sb.gen);
    }
    let ra = a.final_residuals.unwrap();
    let rb = b.final_residuals.unwrap();
    assert_eq!(ra, rb);
}

#[test]
fn different_seeds_differ() {
    let Some(h) = shared_handle() else { return };
    let mut cfg = quick_cfg(Mode::ArarArar, 2, 6);
    let a = run_training(&cfg, &h).unwrap();
    cfg.seed += 1;
    let b = run_training(&cfg, &h).unwrap();
    assert_ne!(a.states[0].gen, b.states[0].gen);
}

#[test]
fn horovod_ranks_see_full_data() {
    let Some(h) = shared_handle() else { return };
    // Indirect check via the recorded events metric: every mode analyzes
    // disc_batch events per epoch; horovod differs only in sharding and
    // synchronization, which must not change the event count.
    let run = run_training(&quick_cfg(Mode::Horovod, 4, 6), &h).unwrap();
    assert_eq!(run.total_events(), (4 * 6 * 16 * 25) as f64);
}

#[test]
fn injected_latency_slows_the_blocking_ring() {
    let Some(h) = shared_handle() else { return };
    let cfg = quick_cfg(Mode::ConvArar, 4, 8);
    let fast = run_training_with_links(&cfg, &h, LinkModel::zero()).unwrap();
    // Exaggerated per-message latency.
    let slow_links = {
        let mut lm = LinkModel::mpi4py_like();
        lm.inter_node.alpha_s = 3e-3;
        lm.intra_node.alpha_s = 3e-3;
        lm.with_injection(1.0)
    };
    let slow = run_training_with_links(&cfg, &h, slow_links).unwrap();
    assert!(
        slow.wall_s > fast.wall_s,
        "latency injection had no effect: {} vs {}",
        slow.wall_s,
        fast.wall_s
    );
    // And the numerics are unaffected by timing (same seed, same result).
    assert_eq!(slow.states[0].gen, fast.states[0].gen);
}

#[test]
fn residual_evaluator_matches_rust_reference_forward() {
    let Some(h) = shared_handle() else { return };
    // Cross-check the gen_predict artifact against the pure-Rust MLP.
    use sagips::model::gan::GanState;
    use sagips::model::reference;
    use sagips::util::rng::Rng;
    let meta = h.manifest().model("paper").unwrap().clone();
    let mut rng = Rng::new(5);
    let state = GanState::init(&meta, h.manifest().leaky_slope, &mut rng);
    let ev = sagips::model::Residuals::new(h.clone(), "gen_predict_paper_k256", 77).unwrap();
    let preds = ev.predict(&state.gen).unwrap();
    // Reference forward on the same fixed noise: regenerate it the same
    // way Residuals does.
    let mut rng2 = Rng::with_stream(77, 0xEE51D);
    let mut z = vec![0.0f32; 256 * h.manifest().latent_dim];
    rng2.fill_normal(&mut z);
    let want = reference::mlp_forward(
        &state.gen,
        &meta.gen_layout,
        &z,
        256,
        h.manifest().leaky_slope as f32,
    );
    assert_eq!(preds.len(), want.len());
    for (a, b) in preds.iter().zip(&want) {
        assert!((a - b).abs() < 1e-3 + 1e-3 * b.abs(), "{a} vs {b}");
    }
}

#[test]
fn weak_scaling_artifacts_exist_for_eq10() {
    let Some(h) = shared_handle() else { return };
    // eq (10) grid with base batch 64: N in {1,2,4,8,16}.
    for n in [1usize, 2, 4, 8, 16] {
        let name = format!("gan_step_paper_b{}_e25", 64 / n);
        assert!(
            h.manifest().artifact(&name).is_ok(),
            "missing weak-scaling artifact {name}"
        );
    }
}

// ---------------------------------------------------------------------
// Native backend: these tests never skip — no artifacts required.
// ---------------------------------------------------------------------

/// A small, fast native config (model "small", batch 8 x 25 events).
fn native_cfg(mode: Mode, ranks: usize, epochs: usize) -> RunConfig {
    let mut cfg = presets::ci_default();
    cfg.backend = BackendKind::Native;
    cfg.artifacts_dir = "/nonexistent/so-the-synthetic-manifest-is-used".into();
    cfg.model = "small".into();
    cfg.mode = mode;
    cfg.ranks = ranks;
    cfg.epochs = epochs;
    cfg.batch = 8;
    cfg.events = 25;
    cfg.data_pool = 1600;
    cfg.checkpoint_every = (epochs / 2).max(1);
    cfg.outer_freq = 5;
    cfg
}

#[test]
fn native_backend_multi_rank_training_end_to_end() {
    // The formerly artifact-gated multi-rank path, un-skipped: a full
    // 4-rank grouped-ARAR training with real numerics on every epoch.
    let cfg = native_cfg(Mode::ArarArar, 4, 12);
    let run = run_training_from_config(&cfg).unwrap();
    let g = run.metrics.mean_series("gen_loss");
    assert_eq!(g.len(), 12);
    assert!(g.values.iter().all(|v| v.is_finite()));
    let d = run.metrics.mean_series("disc_loss");
    assert!(d.values.iter().all(|v| v.is_finite()));
    let r = run.final_residuals.unwrap();
    assert!(r.iter().all(|x| x.is_finite()));
    assert!(residuals::mean_abs(&r).is_finite());
    assert!(run.wall_s > 0.0);
    assert!(run.analysis_rate() > 0.0);
    assert_eq!(run.total_events(), (4 * 12 * 8 * 25) as f64);
}

#[test]
fn native_backend_all_table2_modes_train() {
    for mode in [
        Mode::Ensemble,
        Mode::ConvArar,
        Mode::ArarArar,
        Mode::RmaArarArar,
        Mode::Horovod,
        Mode::Hierarchical,
        Mode::DoubleBinaryTree,
    ] {
        let ranks = if mode == Mode::Ensemble { 1 } else { 4 };
        let run = run_training_from_config(&native_cfg(mode, ranks, 6))
            .unwrap_or_else(|e| panic!("{} failed on native backend: {e}", mode.name()));
        let r = run.final_residuals.unwrap();
        assert!(
            r.iter().all(|x| x.is_finite()),
            "{} produced non-finite residuals",
            mode.name()
        );
    }
}

#[test]
fn native_backend_trains_every_registered_scenario_multi_rank() {
    // Scenario-generality contract: each registered inverse problem (the
    // quantile proxy plus the deconvolution and saturation scenarios)
    // trains end to end, 4 ranks, in a Table II mode, no artifacts.
    for sc in sagips::scenario::registry() {
        let mut cfg = native_cfg(Mode::ArarArar, 4, 8);
        cfg.scenario = sc.name().into();
        let run = run_training_from_config(&cfg)
            .unwrap_or_else(|e| panic!("scenario {} failed: {e}", sc.name()));
        let g = run.metrics.mean_series("gen_loss");
        assert_eq!(g.len(), 8, "{}", sc.name());
        assert!(g.values.iter().all(|v| v.is_finite()), "{}", sc.name());
        let r = run.final_residuals.unwrap();
        assert!(
            r.iter().all(|x| x.is_finite()),
            "{} produced non-finite residuals",
            sc.name()
        );
        // Width generality: the residual vector is the scenario's
        // parameter count (10 for deconv, 6 for the others) — no fixed-6
        // assumption anywhere in the analysis path.
        assert_eq!(r.len(), sc.param_dim(), "{} residual width", sc.name());
        assert_eq!(
            run.total_events(),
            (4 * 8 * 8 * 25) as f64,
            "{} event accounting",
            sc.name()
        );
        // Checkpoints carry the scenario identity end to end.
        assert!(run.checkpoints[0]
            .checkpoints
            .iter()
            .all(|ck| ck.scenario == sc.name()));
    }
}

#[test]
fn scenario_mismatch_between_config_and_runtime_is_rejected() {
    use sagips::runtime::Manifest;
    let rt = NativeRuntime::new(Manifest::synthetic_for("deconv").unwrap());
    let mut cfg = native_cfg(Mode::ConvArar, 2, 2);
    cfg.scenario = "quantile".into();
    let err = run_training(&cfg, &rt.handle()).unwrap_err().to_string();
    assert!(err.contains("deconv") && err.contains("quantile"), "{err}");
}

#[test]
fn native_backend_is_seed_reproducible_and_seed_sensitive() {
    let mut cfg = native_cfg(Mode::ArarArar, 4, 8);
    let a = run_training_from_config(&cfg).unwrap();
    let b = run_training_from_config(&cfg).unwrap();
    for (sa, sb) in a.states.iter().zip(&b.states) {
        assert_eq!(sa.gen, sb.gen);
    }
    assert_eq!(a.final_residuals.unwrap(), b.final_residuals.unwrap());
    cfg.seed += 1;
    let c = run_training_from_config(&cfg).unwrap();
    assert_ne!(a.states[0].gen, c.states[0].gen);
}

#[test]
fn native_backend_intra_threads_training_matches_serial_bit_for_bit() {
    // The intra-rank parallelism determinism contract at training level:
    // the same seed with a worker pool inside gan_step reproduces the
    // serial run exactly — every rank's parameters and the residuals.
    let mut cfg = native_cfg(Mode::ArarArar, 4, 8);
    let serial = run_training_from_config(&cfg).unwrap();
    cfg.intra_threads = 3;
    cfg.validate().unwrap();
    let threaded = run_training_from_config(&cfg).unwrap();
    for (sa, sb) in serial.states.iter().zip(&threaded.states) {
        assert_eq!(sa.gen, sb.gen);
        assert_eq!(sa.disc, sb.disc);
    }
    assert_eq!(serial.final_residuals.unwrap(), threaded.final_residuals.unwrap());
}

#[test]
fn native_backend_overlap_and_chunked_engine_run() {
    // The PR-1 overlap/chunking machinery over real native numerics.
    let mut cfg = presets::throughput(&native_cfg(Mode::ConvArar, 4, 10));
    cfg.validate().unwrap();
    let run = run_training_from_config(&cfg).unwrap();
    let r = run.final_residuals.unwrap();
    assert!(r.iter().all(|x| x.is_finite()));
    // One-epoch-stale overlap: every epoch trains...
    assert_eq!(run.metrics.mean_series("gen_loss").len(), 10);
    // ...comm is recorded per epoch plus the pipeline drain's final
    // collect (one extra sample at the last epoch)...
    assert_eq!(run.metrics.mean_series("comm_s").len(), 11);
    // ...and the overlap pipeline actually hid exchange time.
    assert!(!run.metrics.mean_series("comm_hidden_s").is_empty());
}

#[test]
fn native_matches_pjrt_gan_step_when_artifacts_exist() {
    // Cross-backend contract check: identical inputs through the HLO
    // artifact and the native kernels must produce matching gradients and
    // losses (f32 tolerance — XLA reduces in a different order).
    let Some(h) = shared_handle() else { return };
    if h.manifest().artifact("gan_step_paper_b16_e25").is_err() {
        return;
    }
    let native = NativeRuntime::new(h.manifest().clone());
    let nh = native.handle();
    use sagips::model::gan::GanState;
    use sagips::util::rng::Rng;
    let meta = h.manifest().model("paper").unwrap().clone();
    let mut rng = Rng::new(99);
    let state = GanState::init(&meta, h.manifest().leaky_slope, &mut rng);
    let mut z = vec![0.0f32; 16 * h.manifest().latent_dim];
    let mut u = vec![0.0f32; 16 * 25 * 2];
    rng.fill_normal(&mut z);
    rng.fill_uniform(&mut u);
    let real = vec![0.5f32; 400 * 2];
    let inputs = vec![state.gen.clone(), state.disc.clone(), z, u, real];
    let want = h.execute("gan_step_paper_b16_e25", inputs.clone()).unwrap();
    let got = nh.execute("gan_step_paper_b16_e25", inputs).unwrap();
    for (slot, (w, g)) in want.iter().zip(&got).enumerate() {
        assert_eq!(w.len(), g.len(), "output {slot} length");
        for (a, b) in w.iter().zip(g) {
            assert!(
                (a - b).abs() < 1e-3 + 1e-3 * b.abs(),
                "output {slot}: pjrt {a} vs native {b}"
            );
        }
    }
}

#[test]
fn checkpoint_cadence_matches_config() {
    let Some(h) = shared_handle() else { return };
    let mut cfg = quick_cfg(Mode::Ensemble, 1, 20);
    cfg.checkpoint_every = 5;
    let run = run_training(&cfg, &h).unwrap();
    // epoch 0 + epochs 4, 9, 14, 19 -> 5 checkpoints
    assert_eq!(run.residual_curve.len(), 5);
    let epochs: Vec<u64> = run.residual_curve.iter().map(|p| p.epoch).collect();
    assert_eq!(epochs, vec![0, 4, 9, 14, 19]);
    // elapsed times strictly increasing
    for w in run.residual_curve.windows(2) {
        assert!(w[1].elapsed_s > w[0].elapsed_s);
    }
    // mean_abs helper is finite on all
    for p in &run.residual_curve {
        assert!(residuals::mean_abs(&p.residuals).is_finite());
    }
}
