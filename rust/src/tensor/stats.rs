//! Statistics for the convergence / ensemble analyses.
//!
//! Implements the quantities the paper reports: normalized residual means
//! and standard deviations (Figs 8, 10, 13-16), RMSE-vs-spread (Fig 9),
//! and general summaries (percentiles, histograms) used by the benches.

/// Running mean/variance (Welford). Numerically stable for the long
/// time-series the metrics recorder accumulates.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance.
    pub fn var(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

/// Mean of an f64 slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Root mean square (the paper's Fig 9 RMSE over residuals).
pub fn rms(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x * x).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Linear-interpolated percentile (p in [0,1]) of unsorted data.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = p.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = idx.floor() as usize;
    let hi = idx.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = idx - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Fixed-width histogram over [lo, hi) with `bins` buckets (under/overflow
/// clamp to the edge buckets).
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Histogram {
        assert!(hi > lo && bins > 0);
        Histogram {
            lo,
            hi,
            counts: vec![0; bins],
        }
    }

    pub fn push(&mut self, x: f64) {
        let bins = self.counts.len();
        let t = (x - self.lo) / (self.hi - self.lo);
        let idx = ((t * bins as f64).floor() as i64).clamp(0, bins as i64 - 1) as usize;
        self.counts[idx] += 1;
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Mode bucket center.
    pub fn mode_center(&self) -> f64 {
        let (i, _) = self
            .counts
            .iter()
            .enumerate()
            .max_by_key(|(_, c)| **c)
            .unwrap();
        let w = (self.hi - self.lo) / self.counts.len() as f64;
        self.lo + (i as f64 + 0.5) * w
    }
}

/// 95% confidence ellipse axes from a 2-D sample cloud ((x, y) pairs) — the
/// Fig 9 contour summary. Returns (mean_x, mean_y, semiaxis_x, semiaxis_y,
/// correlation).
pub fn confidence_ellipse_95(points: &[(f64, f64)]) -> (f64, f64, f64, f64, f64) {
    assert!(points.len() >= 2);
    let n = points.len() as f64;
    let mx = points.iter().map(|p| p.0).sum::<f64>() / n;
    let my = points.iter().map(|p| p.1).sum::<f64>() / n;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    let mut sxy = 0.0;
    for (x, y) in points {
        sxx += (x - mx) * (x - mx);
        syy += (y - my) * (y - my);
        sxy += (x - mx) * (y - my);
    }
    sxx /= n;
    syy /= n;
    sxy /= n;
    let corr = if sxx > 0.0 && syy > 0.0 {
        sxy / (sxx.sqrt() * syy.sqrt())
    } else {
        0.0
    };
    // chi2(2 dof, 95%) = 5.991; semi-axes of the axis-aligned bounding
    // ellipse.
    let k = 5.991f64.sqrt();
    (mx, my, k * sxx.sqrt(), k * syy.sqrt(), corr)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert!((w.std() - std(&xs)).abs() < 1e-12);
        assert_eq!(w.count(), 5);
    }

    #[test]
    fn rms_of_constant() {
        assert!((rms(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
        assert_eq!(rms(&[]), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 1.0) - 4.0).abs() < 1e-12);
        assert!((percentile(&xs, 0.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn histogram_counts_and_clamps() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for x in [-5.0, 0.5, 5.5, 9.9, 50.0] {
            h.push(x);
        }
        assert_eq!(h.total(), 5);
        assert_eq!(h.counts[0], 2); // -5 clamped + 0.5
        assert_eq!(h.counts[9], 2); // 9.9 + 50 clamped
    }

    #[test]
    fn ellipse_axes_scale_with_spread() {
        let tight: Vec<(f64, f64)> = (0..100)
            .map(|i| ((i % 10) as f64 * 0.01, (i / 10) as f64 * 0.01))
            .collect();
        let wide: Vec<(f64, f64)> = tight.iter().map(|(x, y)| (x * 10.0, y * 10.0)).collect();
        let t = confidence_ellipse_95(&tight);
        let w = confidence_ellipse_95(&wide);
        assert!(w.2 > t.2 * 5.0);
        assert!(w.3 > t.3 * 5.0);
    }
}
