//! Tensor fusion: packing many small gradient tensors into large transfer
//! buffers.
//!
//! The paper excludes 1-D bias gradients from the ring transfer because
//! small tensors slow the ring-all-reduce down, and names *tensor fusion*
//! ("combine small tensors into a larger one", Sec. V-C / VII) as planned
//! future work. This module implements it: a [`FusionPlan`] maps a set of
//! named gradient slices (from the manifest layer layout) into fixed-size
//! fused buckets; the collective then moves whole buckets instead of
//! individual tensors, amortizing the per-message latency `alpha` of the
//! link.

use crate::util::error::{Error, Result};

/// One logical tensor inside the flat gradient vector.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Segment {
    pub name: String,
    /// Offset into the flat gradient vector.
    pub offset: usize,
    /// Element count.
    pub len: usize,
    /// Bias segments can be excluded from transfer (paper Sec. V-C).
    pub is_bias: bool,
}

/// A fused bucket: a contiguous run of segments packed together.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Bucket {
    /// Indices into the plan's segment list.
    pub segments: Vec<usize>,
    /// Total elements in the bucket.
    pub len: usize,
}

/// A packing of segments into buckets of at most `bucket_elems` elements.
#[derive(Clone, Debug)]
pub struct FusionPlan {
    pub segments: Vec<Segment>,
    pub buckets: Vec<Bucket>,
    pub include_bias: bool,
}

impl FusionPlan {
    /// Greedy first-fit packing in segment order. `bucket_elems = 0` means
    /// a single bucket holding everything (classic "one big tensor").
    pub fn build(segments: Vec<Segment>, bucket_elems: usize, include_bias: bool) -> FusionPlan {
        let mut buckets: Vec<Bucket> = Vec::new();
        for (i, seg) in segments.iter().enumerate() {
            if seg.is_bias && !include_bias {
                continue;
            }
            let fits = buckets.last().map_or(false, |b| {
                bucket_elems == 0 || b.len + seg.len <= bucket_elems
            });
            if fits {
                let b = buckets.last_mut().unwrap();
                b.segments.push(i);
                b.len += seg.len;
            } else {
                buckets.push(Bucket {
                    segments: vec![i],
                    len: seg.len,
                });
            }
        }
        FusionPlan {
            segments,
            buckets,
            include_bias,
        }
    }

    /// Total elements that travel over the wire per ring step.
    pub fn transfer_elems(&self) -> usize {
        self.buckets.iter().map(|b| b.len).sum()
    }

    /// Number of messages per ring step (one per bucket).
    pub fn message_count(&self) -> usize {
        self.buckets.len()
    }

    /// Gather the plan's segments from a flat gradient vector into a packed
    /// transfer buffer (reused across epochs — no allocation when `out` has
    /// capacity).
    pub fn pack(&self, grads: &[f32], out: &mut Vec<f32>) -> Result<()> {
        out.clear();
        out.reserve(self.transfer_elems());
        for b in &self.buckets {
            for &si in &b.segments {
                let s = &self.segments[si];
                let end = s.offset + s.len;
                if end > grads.len() {
                    return Err(Error::Shape(format!(
                        "segment '{}' [{}, {}) out of bounds for grads of len {}",
                        s.name,
                        s.offset,
                        end,
                        grads.len()
                    )));
                }
                out.extend_from_slice(&grads[s.offset..end]);
            }
        }
        Ok(())
    }

    /// Scatter a packed transfer buffer back into the flat gradient vector.
    pub fn unpack(&self, packed: &[f32], grads: &mut [f32]) -> Result<()> {
        if packed.len() != self.transfer_elems() {
            return Err(Error::Shape(format!(
                "packed buffer has {} elements, plan expects {}",
                packed.len(),
                self.transfer_elems()
            )));
        }
        let mut pos = 0;
        for b in &self.buckets {
            for &si in &b.segments {
                let s = &self.segments[si];
                grads[s.offset..s.offset + s.len].copy_from_slice(&packed[pos..pos + s.len]);
                pos += s.len;
            }
        }
        Ok(())
    }

    /// Element indices covered by the plan (used by tests and by the
    /// "only transferred slices get averaged" logic in the trainer).
    pub fn covered_indices(&self) -> Vec<usize> {
        let mut idx = Vec::with_capacity(self.transfer_elems());
        for b in &self.buckets {
            for &si in &b.segments {
                let s = &self.segments[si];
                idx.extend(s.offset..s.offset + s.len);
            }
        }
        idx
    }
}

/// Build segments from a manifest-style layer layout.
/// `layout` entries: (w_offset, w_len, b_offset, b_len) per layer.
pub fn segments_from_layout(layout: &[(usize, usize, usize, usize)]) -> Vec<Segment> {
    let mut segs = Vec::with_capacity(layout.len() * 2);
    for (i, &(w_off, w_len, b_off, b_len)) in layout.iter().enumerate() {
        segs.push(Segment {
            name: format!("layer{i}.w"),
            offset: w_off,
            len: w_len,
            is_bias: false,
        });
        segs.push(Segment {
            name: format!("layer{i}.b"),
            offset: b_off,
            len: b_len,
            is_bias: true,
        });
    }
    segs
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layout3() -> Vec<Segment> {
        segments_from_layout(&[(0, 8, 8, 2), (10, 6, 16, 3), (19, 4, 23, 1)])
    }

    #[test]
    fn excludes_bias_by_default_like_paper() {
        let plan = FusionPlan::build(layout3(), 0, false);
        assert_eq!(plan.transfer_elems(), 8 + 6 + 4);
        assert_eq!(plan.message_count(), 1); // one big fused bucket
    }

    #[test]
    fn bucket_size_limits_fusion() {
        let plan = FusionPlan::build(layout3(), 8, false);
        // 8 fills a bucket, 6 and 4 cannot share an 8-element bucket.
        assert_eq!(plan.message_count(), 3);
        let plan = FusionPlan::build(layout3(), 10, false);
        assert_eq!(plan.message_count(), 2); // [8], [6+4]
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let plan = FusionPlan::build(layout3(), 0, true);
        let grads: Vec<f32> = (0..24).map(|x| x as f32).collect();
        let mut packed = Vec::new();
        plan.pack(&grads, &mut packed).unwrap();
        assert_eq!(packed.len(), plan.transfer_elems());
        let mut out = vec![0.0; 24];
        plan.unpack(&packed, &mut out).unwrap();
        for &i in &plan.covered_indices() {
            assert_eq!(out[i], grads[i]);
        }
    }

    #[test]
    fn unpack_only_touches_covered() {
        let plan = FusionPlan::build(layout3(), 0, false); // weights only
        let grads: Vec<f32> = (0..24).map(|x| x as f32).collect();
        let mut packed = Vec::new();
        plan.pack(&grads, &mut packed).unwrap();
        let mut out = vec![-1.0; 24];
        plan.unpack(&packed, &mut out).unwrap();
        // bias slots untouched
        assert_eq!(out[8], -1.0);
        assert_eq!(out[9], -1.0);
        assert_eq!(out[0], 0.0);
    }

    #[test]
    fn pack_bounds_checked() {
        let plan = FusionPlan::build(layout3(), 0, true);
        let short = vec![0.0; 10];
        let mut packed = Vec::new();
        assert!(plan.pack(&short, &mut packed).is_err());
    }

    #[test]
    fn unpack_length_checked() {
        let plan = FusionPlan::build(layout3(), 0, false);
        let mut out = vec![0.0; 24];
        assert!(plan.unpack(&[0.0; 3], &mut out).is_err());
    }
}
