//! Elementwise slice ops used on the coordinator hot path.
//!
//! These run on every epoch of every rank (gradient accumulation, averaging,
//! optimizer updates), so they are written as simple, auto-vectorizable
//! loops over `&[f32]` with debug-only shape checks. No allocation: callers
//! own the buffers.

/// `y += x` (ring-all-reduce accumulate step).
pub fn add_assign(y: &mut [f32], x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    for (a, b) in y.iter_mut().zip(x) {
        *a += b;
    }
}

/// `y = x` (buffer reuse without reallocating).
pub fn copy_from(y: &mut [f32], x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    y.copy_from_slice(x);
}

/// `y *= s` (gradient averaging after the ring pass).
pub fn scale(y: &mut [f32], s: f32) {
    for a in y.iter_mut() {
        *a *= s;
    }
}

/// `y += alpha * x` (SGD step, fused accumulate-scale).
pub fn axpy(y: &mut [f32], alpha: f32, x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    for (a, b) in y.iter_mut().zip(x) {
        *a += alpha * b;
    }
}

/// Dot product.
pub fn dot(x: &[f32], y: &[f32]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    x.iter().zip(y).map(|(a, b)| (*a as f64) * (*b as f64)).sum()
}

/// L2 norm.
pub fn norm2(x: &[f32]) -> f64 {
    dot(x, x).sqrt()
}

/// Max |x_i|.
pub fn max_abs(x: &[f32]) -> f32 {
    x.iter().fold(0.0f32, |m, v| m.max(v.abs()))
}

/// Elementwise allclose with absolute + relative tolerance.
pub fn allclose(x: &[f32], y: &[f32], rtol: f32, atol: f32) -> bool {
    x.len() == y.len()
        && x.iter()
            .zip(y)
            .all(|(a, b)| (a - b).abs() <= atol + rtol * b.abs())
}

/// True if every element is finite (NaN/Inf guard after a training step).
pub fn all_finite(x: &[f32]) -> bool {
    x.iter().all(|v| v.is_finite())
}

/// Mean of a slice.
pub fn mean(x: &[f32]) -> f64 {
    if x.is_empty() {
        return 0.0;
    }
    x.iter().map(|&v| v as f64).sum::<f64>() / x.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_assign_and_scale() {
        let mut y = vec![1.0, 2.0, 3.0];
        add_assign(&mut y, &[1.0, 1.0, 1.0]);
        assert_eq!(y, vec![2.0, 3.0, 4.0]);
        scale(&mut y, 0.5);
        assert_eq!(y, vec![1.0, 1.5, 2.0]);
    }

    #[test]
    fn axpy_matches_manual() {
        let mut y = vec![1.0, 1.0];
        axpy(&mut y, -0.5, &[2.0, 4.0]);
        assert_eq!(y, vec![0.0, -1.0]);
    }

    #[test]
    fn dot_and_norm() {
        assert_eq!(dot(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn allclose_tolerances() {
        assert!(allclose(&[1.0, 2.0], &[1.0 + 1e-6, 2.0], 1e-5, 1e-5));
        assert!(!allclose(&[1.0], &[1.1], 1e-5, 1e-5));
        assert!(!allclose(&[1.0], &[1.0, 2.0], 1e-5, 1e-5));
    }

    #[test]
    fn finite_guard() {
        assert!(all_finite(&[0.0, -1.0, 3.5]));
        assert!(!all_finite(&[0.0, f32::NAN]));
        assert!(!all_finite(&[f32::INFINITY]));
    }

    #[test]
    fn mean_empty_is_zero() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
    }
}
