//! Flat f32 tensors and the numeric helpers the coordinator hot path uses.
//!
//! Model parameters, gradients and event batches all live as contiguous
//! `Vec<f32>` buffers — that is what the ring-all-reduce moves, what the RMA
//! mailboxes store, and what the PJRT runtime consumes. Shapes are carried
//! separately (from the artifact manifest) and only checked at module
//! boundaries.

pub mod fusion;
pub mod ops;
pub mod stats;

use crate::util::error::{Error, Result};

/// A dense f32 tensor: contiguous data + row-major shape.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    data: Vec<f32>,
    shape: Vec<usize>,
}

impl Tensor {
    /// Build from data + shape (validates element count).
    pub fn new(data: Vec<f32>, shape: Vec<usize>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(Error::Shape(format!(
                "shape {:?} needs {} elements, got {}",
                shape,
                n,
                data.len()
            )));
        }
        Ok(Tensor { data, shape })
    }

    /// Zero-filled tensor.
    pub fn zeros(shape: &[usize]) -> Tensor {
        let n = shape.iter().product();
        Tensor {
            data: vec![0.0; n],
            shape: shape.to_vec(),
        }
    }

    /// 1-D tensor from a vec.
    pub fn from_vec(data: Vec<f32>) -> Tensor {
        let n = data.len();
        Tensor {
            data,
            shape: vec![n],
        }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Reshape in place (element count must match).
    pub fn reshape(&mut self, shape: &[usize]) -> Result<()> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            return Err(Error::Shape(format!(
                "cannot reshape {} elements to {:?}",
                self.data.len(),
                shape
            )));
        }
        self.shape = shape.to_vec();
        Ok(())
    }

    /// Row-major 2-D indexing (debug helper).
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.shape.len(), 2);
        self.data[i * self.shape[1] + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_validates_shape() {
        assert!(Tensor::new(vec![1.0; 6], vec![2, 3]).is_ok());
        assert!(Tensor::new(vec![1.0; 5], vec![2, 3]).is_err());
    }

    #[test]
    fn zeros_and_reshape() {
        let mut t = Tensor::zeros(&[4, 2]);
        assert_eq!(t.len(), 8);
        t.reshape(&[2, 4]).unwrap();
        assert_eq!(t.shape(), &[2, 4]);
        assert!(t.reshape(&[3, 3]).is_err());
    }

    #[test]
    fn at2_row_major() {
        let t = Tensor::new((0..6).map(|x| x as f32).collect(), vec![2, 3]).unwrap();
        assert_eq!(t.at2(0, 0), 0.0);
        assert_eq!(t.at2(1, 2), 5.0);
    }
}
