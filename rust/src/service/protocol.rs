//! The line-JSON control protocol between `sagips job …` clients and
//! the `sagips serve` daemon.
//!
//! One request per line, one response per line (no framing beyond
//! `\n`; both sides are the crate's own deterministic JSON emitter, so
//! responses are stable byte-for-byte for a given payload):
//!
//! ```text
//! → {"verb":"submit","name":"sweep-a","priority":0,"config":{...}}
//! ← {"id":3,"ok":true}
//! → {"verb":"status","id":3}
//! ← {"job":{"id":3,"state":"running","epochs_done":120,...},"ok":true}
//! → {"verb":"cancel","id":3}
//! ← {"ok":true,"result":"stopping"}
//! ```
//!
//! Errors come back as `{"ok":false,"error":"..."}`; admission
//! refusals additionally carry `"overloaded":true` so clients can
//! distinguish retryable backpressure from fatal rejections. An
//! unknown verb lists every valid one (the same courtesy the scenario
//! registry extends to unknown scenario names).

use crate::config::RunConfig;
use crate::util::error::{Error, Result};
use crate::util::json::{self, Value};

use super::job::{JobId, JobState, JobStatus};

/// Every verb the daemon understands, in help order.
pub const VERBS: [&str; 8] = [
    "submit", "status", "cancel", "list", "reload", "compact", "ping", "shutdown",
];

/// A parsed client request.
#[derive(Clone, Debug)]
pub enum Request {
    Submit {
        name: String,
        priority: i64,
        config: RunConfig,
    },
    Status {
        id: JobId,
    },
    Cancel {
        id: JobId,
    },
    List,
    Reload,
    /// Rewrite the queue journal as a snapshot (drops superseded
    /// state-transition lines for every job).
    Compact,
    Ping,
    Shutdown,
}

impl Request {
    /// Parse one request line.
    pub fn parse(line: &str) -> Result<Request> {
        let v = Value::parse(line.trim())?;
        if v.as_object().is_none() {
            return Err(Error::config("request must be a JSON object"));
        }
        match v.req_str("verb")? {
            "submit" => {
                let config = RunConfig::from_json(&v.req("config")?.to_json())?;
                let name = v
                    .get("name")
                    .and_then(|n| n.as_str())
                    .unwrap_or(&config.scenario)
                    .to_string();
                let priority = match v.get("priority") {
                    Some(p) => p.as_f64().ok_or_else(|| {
                        Error::config("submit 'priority' must be a number")
                    })? as i64,
                    None => 0,
                };
                Ok(Request::Submit {
                    name,
                    priority,
                    config,
                })
            }
            "status" => Ok(Request::Status { id: req_id(&v)? }),
            "cancel" => Ok(Request::Cancel { id: req_id(&v)? }),
            "list" => Ok(Request::List),
            "reload" => Ok(Request::Reload),
            "compact" => Ok(Request::Compact),
            "ping" => Ok(Request::Ping),
            "shutdown" => Ok(Request::Shutdown),
            other => Err(Error::config(format!(
                "unknown verb '{other}' — valid verbs: {}",
                VERBS.join(", ")
            ))),
        }
    }

    /// Emit the request line a client sends (inverse of `parse`).
    pub fn to_line(&self) -> String {
        let v = match self {
            Request::Submit {
                name,
                priority,
                config,
            } => json::obj(vec![
                ("verb", json::s("submit")),
                ("name", json::s(name)),
                ("priority", json::num(*priority as f64)),
                ("config", config.to_json_value()),
            ]),
            Request::Status { id } => json::obj(vec![
                ("verb", json::s("status")),
                ("id", json::num(*id as f64)),
            ]),
            Request::Cancel { id } => json::obj(vec![
                ("verb", json::s("cancel")),
                ("id", json::num(*id as f64)),
            ]),
            Request::List => json::obj(vec![("verb", json::s("list"))]),
            Request::Reload => json::obj(vec![("verb", json::s("reload"))]),
            Request::Compact => json::obj(vec![("verb", json::s("compact"))]),
            Request::Ping => json::obj(vec![("verb", json::s("ping"))]),
            Request::Shutdown => json::obj(vec![("verb", json::s("shutdown"))]),
        };
        v.to_json()
    }
}

fn req_id(v: &Value) -> Result<JobId> {
    Ok(v.req_usize("id")? as JobId)
}

/// A successful response line with extra payload fields.
pub fn ok_response(fields: Vec<(&str, Value)>) -> String {
    let mut all = vec![("ok", Value::Bool(true))];
    all.extend(fields);
    json::obj(all).to_json()
}

/// An error response line; admission refusals are marked retryable.
pub fn err_response(e: &Error) -> String {
    let mut fields = vec![
        ("ok", Value::Bool(false)),
        ("error", json::s(e.to_string())),
    ];
    if e.is_overloaded() {
        fields.push(("overloaded", Value::Bool(true)));
    }
    json::obj(fields).to_json()
}

/// The wire form of a status row.
pub fn status_value(st: &JobStatus) -> Value {
    let mut fields = vec![
        ("id", json::num(st.id as f64)),
        ("name", json::s(&st.name)),
        ("state", json::s(st.state.name())),
        ("priority", json::num(st.priority as f64)),
        ("scenario", json::s(&st.scenario)),
        ("epochs", json::num(st.epochs as f64)),
        ("epochs_done", json::num(st.epochs_done as f64)),
        ("detail", json::s(&st.detail)),
    ];
    if let Some(g) = st.gen_loss {
        fields.push(("gen_loss", json::num(g)));
    }
    if let Some(d) = st.disc_loss {
        fields.push(("disc_loss", json::num(d)));
    }
    json::obj(fields)
}

/// Parse a status row back (client side, for rendering `list` output).
pub fn parse_status(v: &Value) -> Result<JobStatus> {
    let priority = v
        .req("priority")?
        .as_f64()
        .ok_or_else(|| Error::config("status 'priority' must be a number"))? as i64;
    Ok(JobStatus {
        id: v.req_usize("id")? as JobId,
        name: v.req_str("name")?.to_string(),
        state: JobState::parse(v.req_str("state")?)?,
        priority,
        scenario: v.req_str("scenario")?.to_string(),
        epochs: v.req_usize("epochs")? as u64,
        epochs_done: v.req_usize("epochs_done")? as u64,
        gen_loss: v.get("gen_loss").and_then(|x| x.as_f64()),
        disc_loss: v.get("disc_loss").and_then(|x| x.as_f64()),
        detail: v.req_str("detail")?.to_string(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn request_lines_roundtrip() {
        let reqs = vec![
            Request::Submit {
                name: "sweep-a".into(),
                priority: -2,
                config: presets::ci_default(),
            },
            Request::Status { id: 7 },
            Request::Cancel { id: 7 },
            Request::List,
            Request::Reload,
            Request::Compact,
            Request::Ping,
            Request::Shutdown,
        ];
        for req in reqs {
            let line = req.to_line();
            let back = Request::parse(&line).unwrap();
            // Compare through re-emission (RunConfig: PartialEq, but
            // Request intentionally stays un-derived).
            assert_eq!(back.to_line(), line);
        }
    }

    #[test]
    fn submit_defaults_name_and_priority() {
        let cfg = presets::ci_default().to_json_value().to_json();
        let req =
            Request::parse(&format!(r#"{{"verb":"submit","config":{cfg}}}"#)).unwrap();
        match req {
            Request::Submit { name, priority, config } => {
                assert_eq!(name, config.scenario);
                assert_eq!(priority, 0);
            }
            other => panic!("wrong parse: {other:?}"),
        }
    }

    #[test]
    fn unknown_verb_lists_all_verbs() {
        let err = Request::parse(r#"{"verb":"pause"}"#).unwrap_err().to_string();
        for verb in VERBS {
            assert!(err.contains(verb), "error should list '{verb}': {err}");
        }
    }

    #[test]
    fn error_responses_flag_overload() {
        let line = err_response(&Error::overloaded("queue full"));
        let v = Value::parse(&line).unwrap();
        assert_eq!(v.get("ok"), Some(&Value::Bool(false)));
        assert_eq!(v.get("overloaded"), Some(&Value::Bool(true)));
        let line = err_response(&Error::config("bad"));
        let v = Value::parse(&line).unwrap();
        assert!(v.get("overloaded").is_none());
    }

    #[test]
    fn status_roundtrips_with_and_without_losses() {
        let st = JobStatus {
            id: 3,
            name: "a".into(),
            state: JobState::Running,
            priority: 1,
            scenario: "quantile".into(),
            epochs: 40,
            epochs_done: 12,
            gen_loss: Some(0.69),
            disc_loss: None,
            detail: "".into(),
        };
        let back = parse_status(&status_value(&st)).unwrap();
        assert_eq!(back, st);
    }
}
