//! The job runner: how the scheduler turns a claimed job into a
//! training run.
//!
//! A trait so the scheduler's concurrency/ordering logic is testable
//! without spinning up real training — `rust/tests/service.rs` plugs in
//! mock runners (instant, gated, failing) while the daemon uses
//! [`TrainingRunner`], which is exactly the one-shot `sagips train`
//! path ([`run_training_from_config_controlled`]) with the job's
//! [`RunControl`] attached. One runtime per job: jobs are isolated, and
//! a job's manifest/backend choice never leaks into the next.

use std::sync::Arc;

use crate::config::RunConfig;
use crate::coordinator::launcher::run_training_from_config_controlled;
use crate::coordinator::RunControl;
use crate::util::error::Result;

/// What a finished (or cancelled) run reports back to the scheduler.
#[derive(Clone, Copy, Debug, Default)]
pub struct RunOutcome {
    /// Epochs trained in total (a cancelled run: stop boundary + 1).
    pub epochs_done: u64,
    /// Final mean-of-last generator loss across ranks.
    pub gen_loss: Option<f64>,
    /// Final mean-of-last discriminator loss across ranks.
    pub disc_loss: Option<f64>,
    /// The checkpoint boundary the run was cancelled at (`None`: ran to
    /// completion).
    pub stopped_at: Option<u64>,
}

/// Runs one job's config to termination under a [`RunControl`].
pub trait JobRunner: Send + Sync {
    fn run(&self, cfg: &RunConfig, control: Arc<RunControl>) -> Result<RunOutcome>;
}

/// The real runner: a full training run, self-contained per job.
pub struct TrainingRunner;

impl JobRunner for TrainingRunner {
    fn run(&self, cfg: &RunConfig, control: Arc<RunControl>) -> Result<RunOutcome> {
        let run = run_training_from_config_controlled(cfg, Some(control))?;
        Ok(RunOutcome {
            epochs_done: run
                .stopped_at
                .map(|e| e + 1)
                .unwrap_or(cfg.epochs as u64),
            gen_loss: run.metrics.mean_of_last("gen_loss"),
            disc_loss: run.metrics.mean_of_last("disc_loss"),
            stopped_at: run.stopped_at,
        })
    }
}
