//! The persistent job queue: FIFO within priority levels, journaled to
//! disk so a restarted daemon resumes exactly where it stopped.
//!
//! # Journal
//!
//! Every mutation appends one line of JSON to `<state_dir>/journal.jsonl`:
//!
//! ```text
//! {"event":"submit","id":3,"name":"sweep-a","priority":0,"config":{...}}
//! {"event":"state","id":3,"state":"running","detail":""}
//! {"event":"state","id":3,"state":"done","detail":"","epochs_done":40,...}
//! ```
//!
//! Replay rebuilds the full map (terminal jobs included, so `status`
//! and `list` survive restarts) and continues id assignment past the
//! largest journaled id. A job the journal leaves in `running` was
//! interrupted by a daemon crash or SIGTERM: replay re-queues it with
//! `interrupted: true`, and the scheduler resumes it from its own
//! newest run checkpoint — the append-only journal plus the atomic
//! checkpoint writes are what make `kill -TERM <daemon>` lose at most
//! the epochs since the last checkpoint boundary.
//!
//! # Ordering and admission
//!
//! [`JobQueue::claim_next`] picks the highest priority first and the
//! lowest id (submission order) within a priority level. Admission
//! control is the queue's too: more than `max_queued` waiting jobs
//! refuse further submits with the retryable
//! [`Error::Overloaded`](crate::util::error::Error) — the caller is
//! told to come back, nothing is dropped.

use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};

use crate::config::RunConfig;
use crate::util::error::{Error, Result};
use crate::util::json::{self, Value};

use super::job::{Job, JobId, JobOutcome, JobSpec, JobState};

/// Journal file name inside the daemon's state directory.
pub const JOURNAL_FILE: &str = "journal.jsonl";

/// Auto-compaction threshold [`JobQueue::open`] uses: when replay reads
/// more than this many journal lines, the journal is rewritten as a
/// snapshot (≤ 2 lines per job) before the daemon starts appending.
pub const DEFAULT_JOURNAL_COMPACT_LINES: usize = 4096;

/// The in-memory queue plus its append-only on-disk journal.
pub struct JobQueue {
    jobs: BTreeMap<JobId, Job>,
    next_id: JobId,
    /// Maximum number of *waiting* jobs admitted (0 = unlimited).
    max_queued: usize,
    /// Append handle; `None` for an ephemeral (test) queue.
    journal: Option<File>,
    /// Journal path; `None` for an ephemeral (test) queue.
    path: Option<PathBuf>,
    /// Lines currently in the journal (replayed + appended since open).
    journal_lines: usize,
}

impl JobQueue {
    /// An in-memory queue with no journal (unit tests, dry runs).
    pub fn ephemeral(max_queued: usize) -> JobQueue {
        JobQueue {
            jobs: BTreeMap::new(),
            next_id: 1,
            max_queued,
            journal: None,
            path: None,
            journal_lines: 0,
        }
    }

    /// Open (or create) the journaled queue under `state_dir` with the
    /// default auto-compaction threshold. See
    /// [`JobQueue::open_with_compaction`].
    pub fn open(state_dir: &Path, max_queued: usize) -> Result<JobQueue> {
        JobQueue::open_with_compaction(state_dir, max_queued, DEFAULT_JOURNAL_COMPACT_LINES)
    }

    /// Open (or create) the journaled queue under `state_dir`,
    /// replaying any existing journal. Jobs the journal leaves in
    /// `running` are re-queued as interrupted. When the replayed
    /// journal exceeds `compact_lines` lines (0 disables), it is
    /// rewritten in place as a snapshot — replaying years of state
    /// transitions on every restart is the one place the append-only
    /// design would otherwise grow without bound.
    pub fn open_with_compaction(
        state_dir: &Path,
        max_queued: usize,
        compact_lines: usize,
    ) -> Result<JobQueue> {
        std::fs::create_dir_all(state_dir)?;
        let path = state_dir.join(JOURNAL_FILE);
        let mut q = JobQueue::ephemeral(max_queued);
        if path.exists() {
            let text = std::fs::read_to_string(&path)?;
            q.replay(&text)?;
        }
        q.journal = Some(OpenOptions::new().create(true).append(true).open(&path)?);
        q.path = Some(path);
        // Interrupted jobs: journaled running, but no daemon is running
        // them any more. Re-queue (journaled, so a second restart agrees).
        let interrupted: Vec<JobId> = q
            .jobs
            .values()
            .filter(|j| j.state == JobState::Running)
            .map(|j| j.id)
            .collect();
        for id in interrupted {
            let job = q.jobs.get_mut(&id).expect("listed above");
            job.state = JobState::Queued;
            job.interrupted = true;
            job.detail = "re-queued after daemon restart".into();
            let line = json::obj(vec![
                ("event", json::s("state")),
                ("id", json::num(id as f64)),
                ("state", json::s(JobState::Queued.name())),
                ("detail", json::s("re-queued after daemon restart")),
                ("interrupted", Value::Bool(true)),
            ]);
            q.append(&line)?;
        }
        if compact_lines > 0 && q.journal_lines > compact_lines {
            q.compact()?;
        }
        Ok(q)
    }

    /// Replay a journal text into an empty queue.
    fn replay(&mut self, text: &str) -> Result<()> {
        for (no, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            self.journal_lines += 1;
            let v = Value::parse(line).map_err(|e| {
                Error::Checkpoint(format!("journal line {}: {e}", no + 1))
            })?;
            match v.req_str("event")? {
                "submit" => {
                    let id = v.req_usize("id")? as JobId;
                    let cfg = RunConfig::from_json(&v.req("config")?.to_json())?;
                    // Priorities may be negative; as_usize would clamp.
                    let priority = v.req("priority")?.as_f64().ok_or_else(|| {
                        Error::Checkpoint(format!(
                            "journal line {}: priority is not a number",
                            no + 1
                        ))
                    })? as i64;
                    let job = Job {
                        id,
                        spec: JobSpec {
                            name: v.req_str("name")?.to_string(),
                            priority,
                            config: cfg,
                        },
                        state: JobState::Queued,
                        detail: String::new(),
                        interrupted: false,
                        outcome: None,
                    };
                    self.jobs.insert(id, job);
                    self.next_id = self.next_id.max(id + 1);
                }
                "state" => {
                    let id = v.req_usize("id")? as JobId;
                    let job = self.jobs.get_mut(&id).ok_or_else(|| {
                        Error::Checkpoint(format!(
                            "journal line {}: state event for unknown job {id}",
                            no + 1
                        ))
                    })?;
                    job.state = JobState::parse(v.req_str("state")?)?;
                    job.detail = v
                        .get("detail")
                        .and_then(|d| d.as_str())
                        .unwrap_or("")
                        .to_string();
                    if let Some(Value::Bool(true)) = v.get("interrupted") {
                        job.interrupted = true;
                    }
                    if let Some(e) = v.get("epochs_done").and_then(|e| e.as_f64()) {
                        job.outcome = Some(JobOutcome {
                            epochs_done: e as u64,
                            gen_loss: v.get("gen_loss").and_then(|x| x.as_f64()),
                            disc_loss: v.get("disc_loss").and_then(|x| x.as_f64()),
                        });
                    }
                }
                other => {
                    return Err(Error::Checkpoint(format!(
                        "journal line {}: unknown event '{other}'",
                        no + 1
                    )))
                }
            }
        }
        Ok(())
    }

    fn append(&mut self, line: &Value) -> Result<()> {
        if let Some(f) = &mut self.journal {
            f.write_all(line.to_json().as_bytes())?;
            f.write_all(b"\n")?;
            f.flush()?;
            self.journal_lines += 1;
        }
        Ok(())
    }

    /// Lines currently in the journal (0 for an ephemeral queue).
    pub fn journal_lines(&self) -> usize {
        self.journal_lines
    }

    /// Rewrite the journal as a snapshot of the current queue: one
    /// canonical submit line per job, plus one state line for any job
    /// that has moved past a fresh submission. The snapshot replays to
    /// the exact same queue — it uses the very event schema `replay`
    /// parses — so compaction is invisible to every consumer except the
    /// file's line count. Returns the number of lines in the compacted
    /// journal; no-op (returns 0) for an ephemeral queue.
    ///
    /// The rewrite is atomic: the snapshot lands in `journal.jsonl.tmp`
    /// first and is renamed over the live journal, so a crash mid-compact
    /// leaves either the old journal or the new one, never a torn file.
    pub fn compact(&mut self) -> Result<usize> {
        let path = match &self.path {
            Some(p) => p.clone(),
            None => return Ok(0),
        };
        let mut text = String::new();
        let mut lines = 0usize;
        for job in self.jobs.values() {
            let submit = json::obj(vec![
                ("event", json::s("submit")),
                ("id", json::num(job.id as f64)),
                ("name", json::s(&job.spec.name)),
                ("priority", json::num(job.spec.priority as f64)),
                ("config", job.spec.config.to_json_value()),
            ]);
            text.push_str(&submit.to_json());
            text.push('\n');
            lines += 1;
            let fresh = job.state == JobState::Queued
                && !job.interrupted
                && job.outcome.is_none()
                && job.detail.is_empty();
            if fresh {
                continue;
            }
            let mut fields = vec![
                ("event", json::s("state")),
                ("id", json::num(job.id as f64)),
                ("state", json::s(job.state.name())),
                ("detail", json::s(&job.detail)),
            ];
            if job.interrupted {
                fields.push(("interrupted", Value::Bool(true)));
            }
            if let Some(o) = &job.outcome {
                fields.push(("epochs_done", json::num(o.epochs_done as f64)));
                if let Some(g) = o.gen_loss {
                    fields.push(("gen_loss", json::num(g)));
                }
                if let Some(d) = o.disc_loss {
                    fields.push(("disc_loss", json::num(d)));
                }
            }
            text.push_str(&json::obj(fields).to_json());
            text.push('\n');
            lines += 1;
        }
        let tmp = path.with_file_name(format!("{JOURNAL_FILE}.tmp"));
        std::fs::write(&tmp, text.as_bytes())?;
        // Release the append handle before swapping the file beneath it.
        self.journal = None;
        std::fs::rename(&tmp, &path)?;
        self.journal = Some(OpenOptions::new().create(true).append(true).open(&path)?);
        self.journal_lines = lines;
        Ok(lines)
    }

    /// The id the next successful [`JobQueue::submit`] will assign.
    pub fn next_id(&self) -> JobId {
        self.next_id
    }

    /// Change the admission limit (config reload).
    pub fn set_max_queued(&mut self, max_queued: usize) {
        self.max_queued = max_queued;
    }

    /// Waiting jobs.
    pub fn queued_len(&self) -> usize {
        self.count(JobState::Queued)
    }

    /// Jobs currently claimed by workers.
    pub fn running_len(&self) -> usize {
        self.count(JobState::Running)
    }

    fn count(&self, st: JobState) -> usize {
        self.jobs.values().filter(|j| j.state == st).count()
    }

    /// Admit a job: journal it and enqueue it. Refuses with the
    /// retryable [`Error::Overloaded`] when `max_queued` jobs are
    /// already waiting.
    pub fn submit(&mut self, spec: JobSpec) -> Result<JobId> {
        if self.max_queued > 0 && self.queued_len() >= self.max_queued {
            return Err(Error::overloaded(format!(
                "job queue at capacity ({} queued, limit {}) — retry after the \
                 daemon drains",
                self.queued_len(),
                self.max_queued
            )));
        }
        let id = self.next_id;
        self.next_id += 1;
        let line = json::obj(vec![
            ("event", json::s("submit")),
            ("id", json::num(id as f64)),
            ("name", json::s(&spec.name)),
            ("priority", json::num(spec.priority as f64)),
            ("config", spec.config.to_json_value()),
        ]);
        self.append(&line)?;
        self.jobs.insert(
            id,
            Job {
                id,
                spec,
                state: JobState::Queued,
                detail: String::new(),
                interrupted: false,
                outcome: None,
            },
        );
        Ok(id)
    }

    /// Claim the next job to run — highest priority first, FIFO
    /// (lowest id) within a priority level — and mark it running.
    pub fn claim_next(&mut self) -> Result<Option<Job>> {
        let next = self
            .jobs
            .values()
            .filter(|j| j.state == JobState::Queued)
            // max_by_key returns the *last* max; compare (priority, -id)
            // via Reverse to get the earliest submission at the top
            // priority.
            .min_by_key(|j| (std::cmp::Reverse(j.spec.priority), j.id))
            .map(|j| j.id);
        match next {
            Some(id) => {
                self.set_state(id, JobState::Running, "")?;
                Ok(self.jobs.get(&id).cloned())
            }
            None => Ok(None),
        }
    }

    /// Record a state transition (journaled).
    pub fn set_state(&mut self, id: JobId, state: JobState, detail: &str) -> Result<()> {
        if !self.jobs.contains_key(&id) {
            return Err(Error::config(format!("no such job: {id}")));
        }
        let line = json::obj(vec![
            ("event", json::s("state")),
            ("id", json::num(id as f64)),
            ("state", json::s(state.name())),
            ("detail", json::s(detail)),
        ]);
        self.append(&line)?;
        let job = self.jobs.get_mut(&id).expect("checked above");
        job.state = state;
        job.detail = detail.to_string();
        Ok(())
    }

    /// Record a terminal transition with its outcome (journaled).
    pub fn finish(
        &mut self,
        id: JobId,
        state: JobState,
        detail: &str,
        outcome: JobOutcome,
    ) -> Result<()> {
        debug_assert!(state.is_terminal());
        if !self.jobs.contains_key(&id) {
            return Err(Error::config(format!("no such job: {id}")));
        }
        let mut fields = vec![
            ("event", json::s("state")),
            ("id", json::num(id as f64)),
            ("state", json::s(state.name())),
            ("detail", json::s(detail)),
            ("epochs_done", json::num(outcome.epochs_done as f64)),
        ];
        if let Some(g) = outcome.gen_loss {
            fields.push(("gen_loss", json::num(g)));
        }
        if let Some(d) = outcome.disc_loss {
            fields.push(("disc_loss", json::num(d)));
        }
        let line = json::obj(fields);
        self.append(&line)?;
        let job = self.jobs.get_mut(&id).expect("checked above");
        job.state = state;
        job.detail = detail.to_string();
        job.outcome = Some(outcome);
        Ok(())
    }

    /// Look up a job.
    pub fn get(&self, id: JobId) -> Option<&Job> {
        self.jobs.get(&id)
    }

    /// All jobs in id order (terminal ones included).
    pub fn jobs(&self) -> impl Iterator<Item = &Job> {
        self.jobs.values()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn spec(name: &str, priority: i64) -> JobSpec {
        JobSpec {
            name: name.into(),
            priority,
            config: presets::ci_default(),
        }
    }

    fn tmp_state_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "sagips_queue_{tag}_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn fifo_within_priority_highest_priority_first() {
        let mut q = JobQueue::ephemeral(0);
        let a = q.submit(spec("a", 0)).unwrap();
        let b = q.submit(spec("b", 5)).unwrap();
        let c = q.submit(spec("c", 5)).unwrap();
        let d = q.submit(spec("d", 0)).unwrap();
        let order: Vec<JobId> = std::iter::from_fn(|| q.claim_next().unwrap())
            .map(|j| j.id)
            .collect();
        assert_eq!(order, vec![b, c, a, d]);
    }

    #[test]
    fn admission_refuses_at_capacity_with_retryable_error() {
        let mut q = JobQueue::ephemeral(2);
        q.submit(spec("a", 0)).unwrap();
        q.submit(spec("b", 0)).unwrap();
        let err = q.submit(spec("c", 0)).unwrap_err();
        assert!(err.is_overloaded(), "want Overloaded, got: {err}");
        // Draining one (claim -> terminal) re-opens admission.
        let j = q.claim_next().unwrap().unwrap();
        q.finish(j.id, JobState::Done, "", JobOutcome::default())
            .unwrap();
        q.submit(spec("c", 0)).unwrap();
    }

    #[test]
    fn journal_replay_restores_jobs_and_requeues_interrupted() {
        let dir = tmp_state_dir("replay");
        let (done_id, running_id, queued_id);
        {
            let mut q = JobQueue::open(&dir, 0).unwrap();
            done_id = q.submit(spec("done-job", 0)).unwrap();
            running_id = q.submit(spec("running-job", 0)).unwrap();
            queued_id = q.submit(spec("queued-job", -3)).unwrap();
            let j = q.claim_next().unwrap().unwrap();
            assert_eq!(j.id, done_id);
            q.finish(
                done_id,
                JobState::Done,
                "",
                JobOutcome {
                    epochs_done: 40,
                    gen_loss: Some(0.5),
                    disc_loss: Some(0.25),
                },
            )
            .unwrap();
            let j = q.claim_next().unwrap().unwrap();
            assert_eq!(j.id, running_id);
            // Drop with running-job still running: simulated daemon kill.
        }
        let mut q = JobQueue::open(&dir, 0).unwrap();
        assert_eq!(q.get(done_id).unwrap().state, JobState::Done);
        assert_eq!(
            q.get(done_id).unwrap().outcome,
            Some(JobOutcome {
                epochs_done: 40,
                gen_loss: Some(0.5),
                disc_loss: Some(0.25),
            })
        );
        let interrupted = q.get(running_id).unwrap();
        assert_eq!(interrupted.state, JobState::Queued);
        assert!(interrupted.interrupted);
        assert_eq!(q.get(queued_id).unwrap().state, JobState::Queued);
        assert!(!q.get(queued_id).unwrap().interrupted);
        // The interrupted job re-runs before the lower-priority one, and
        // new ids continue past the journaled range.
        assert_eq!(q.claim_next().unwrap().unwrap().id, running_id);
        let new_id = q.submit(spec("later", 0)).unwrap();
        assert!(new_id > queued_id);
        let _ = std::fs::remove_dir_all(&dir);

        // A second restart replays its own re-queue event cleanly.
    }

    #[test]
    fn second_restart_replays_requeue_event() {
        let dir = tmp_state_dir("replay2");
        let id;
        {
            let mut q = JobQueue::open(&dir, 0).unwrap();
            id = q.submit(spec("j", 0)).unwrap();
            q.claim_next().unwrap().unwrap();
        }
        {
            let q = JobQueue::open(&dir, 0).unwrap();
            assert_eq!(q.get(id).unwrap().state, JobState::Queued);
            assert!(q.get(id).unwrap().interrupted);
        }
        // Restart again without claiming: the journaled re-queue state
        // replays; the job stays queued + interrupted.
        let q = JobQueue::open(&dir, 0).unwrap();
        assert_eq!(q.get(id).unwrap().state, JobState::Queued);
        assert!(q.get(id).unwrap().interrupted);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn submitted_config_roundtrips_through_journal() {
        let dir = tmp_state_dir("cfg");
        let mut cfg = presets::ci_default();
        cfg.scenario = "saturation".into();
        cfg.epochs = 12;
        cfg.ckpt_every = 6;
        cfg.ckpt_dir = "/tmp/whatever".into();
        cfg.seed = 777;
        let id;
        {
            let mut q = JobQueue::open(&dir, 0).unwrap();
            id = q
                .submit(JobSpec {
                    name: "cfg-job".into(),
                    priority: 2,
                    config: cfg.clone(),
                })
                .unwrap();
        }
        let q = JobQueue::open(&dir, 0).unwrap();
        let job = q.get(id).unwrap();
        assert_eq!(job.spec.config, cfg);
        assert_eq!(job.spec.priority, 2);
        assert_eq!(job.spec.name, "cfg-job");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn set_state_rejects_unknown_job() {
        let mut q = JobQueue::ephemeral(0);
        assert!(q.set_state(42, JobState::Cancelled, "").is_err());
    }

    fn journal_file_lines(dir: &Path) -> usize {
        std::fs::read_to_string(dir.join(JOURNAL_FILE))
            .unwrap()
            .lines()
            .filter(|l| !l.trim().is_empty())
            .count()
    }

    #[test]
    fn compaction_shrinks_journal_and_replays_to_identical_state() {
        let dir = tmp_state_dir("compact");
        let (done_id, cancelled_id, queued_id);
        {
            let mut q = JobQueue::open(&dir, 0).unwrap();
            done_id = q.submit(spec("done-job", 1)).unwrap();
            cancelled_id = q.submit(spec("cancelled-job", 0)).unwrap();
            queued_id = q.submit(spec("queued-job", -1)).unwrap();
            let j = q.claim_next().unwrap().unwrap();
            assert_eq!(j.id, done_id);
            q.finish(
                done_id,
                JobState::Done,
                "all epochs",
                JobOutcome {
                    epochs_done: 40,
                    gen_loss: Some(0.5),
                    disc_loss: None,
                },
            )
            .unwrap();
            q.set_state(cancelled_id, JobState::Cancelled, "operator cancel")
                .unwrap();
            // 3 submits + claim + finish + cancel = 6 lines.
            assert_eq!(q.journal_lines(), 6);
            assert_eq!(journal_file_lines(&dir), 6);
            // Snapshot: 3 submits + 2 state lines (queued-job is fresh).
            let lines = q.compact().unwrap();
            assert_eq!(lines, 5);
            assert_eq!(q.journal_lines(), 5);
            assert_eq!(journal_file_lines(&dir), 5);
            // The append handle survives the rewrite.
            q.set_state(queued_id, JobState::Cancelled, "").unwrap();
            assert_eq!(journal_file_lines(&dir), 6);
        }
        let q = JobQueue::open(&dir, 0).unwrap();
        let done = q.get(done_id).unwrap();
        assert_eq!(done.state, JobState::Done);
        assert_eq!(done.detail, "all epochs");
        assert_eq!(
            done.outcome,
            Some(JobOutcome {
                epochs_done: 40,
                gen_loss: Some(0.5),
                disc_loss: None,
            })
        );
        assert_eq!(q.get(cancelled_id).unwrap().state, JobState::Cancelled);
        assert_eq!(q.get(queued_id).unwrap().state, JobState::Cancelled);
        assert_eq!(q.next_id(), queued_id + 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn compaction_preserves_interrupted_flag() {
        let dir = tmp_state_dir("compact_intr");
        let id;
        {
            let mut q = JobQueue::open(&dir, 0).unwrap();
            id = q.submit(spec("j", 0)).unwrap();
            q.claim_next().unwrap().unwrap();
        }
        {
            // Restart re-queues as interrupted; compact the snapshot.
            let mut q = JobQueue::open(&dir, 0).unwrap();
            assert!(q.get(id).unwrap().interrupted);
            q.compact().unwrap();
        }
        let q = JobQueue::open(&dir, 0).unwrap();
        let j = q.get(id).unwrap();
        assert_eq!(j.state, JobState::Queued);
        assert!(j.interrupted, "compaction must carry the interrupted flag");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn open_auto_compacts_past_threshold() {
        let dir = tmp_state_dir("compact_auto");
        let id;
        {
            let mut q = JobQueue::open(&dir, 0).unwrap();
            id = q.submit(spec("churn", 0)).unwrap();
            // Churn state transitions to bloat the journal.
            for _ in 0..10 {
                q.set_state(id, JobState::Running, "").unwrap();
                q.set_state(id, JobState::Queued, "").unwrap();
            }
            assert_eq!(q.journal_lines(), 21);
        }
        // Below threshold: untouched (21 ≤ 25).
        drop(JobQueue::open_with_compaction(&dir, 0, 25).unwrap());
        assert_eq!(journal_file_lines(&dir), 21);
        // Above threshold: rewritten to the 1-line snapshot (the job is
        // back to a fresh queued state, so no state line).
        let q = JobQueue::open_with_compaction(&dir, 0, 10).unwrap();
        assert_eq!(q.journal_lines(), 1);
        assert_eq!(journal_file_lines(&dir), 1);
        assert_eq!(q.get(id).unwrap().state, JobState::Queued);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn ephemeral_compact_is_a_noop() {
        let mut q = JobQueue::ephemeral(0);
        q.submit(spec("a", 0)).unwrap();
        assert_eq!(q.compact().unwrap(), 0);
        assert_eq!(q.journal_lines(), 0);
    }
}
