//! Job model: what the daemon queues, runs, and reports.
//!
//! A *job* is a full [`RunConfig`] plus bookkeeping: a monotonically
//! increasing id, a priority, a human-readable name, and a state
//! machine
//!
//! ```text
//! queued → running → { done, cancelled, failed }
//!        ↘ cancelled                (cancel-while-queued never starts)
//! ```
//!
//! with one extra transition the state names don't show: a daemon
//! restart that finds a job `running` in the journal re-queues it
//! (`interrupted: true`), and the scheduler resumes it from the job's
//! own newest run checkpoint when one exists — so a SIGTERM'd daemon
//! loses at most the epochs since the last checkpoint boundary.

use crate::config::RunConfig;
use crate::util::error::{Error, Result};

/// Monotonically increasing job identifier, assigned at submit.
pub type JobId = u64;

/// The job state machine. `Done`, `Cancelled` and `Failed` are
/// terminal.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobState {
    /// Waiting in the queue (or re-queued after a daemon restart).
    Queued,
    /// Claimed by a scheduler worker; a training run is in progress.
    Running,
    /// Ran to completion.
    Done,
    /// Stopped at a checkpoint boundary by a cancel request (while
    /// running) or removed from the queue before starting (while
    /// queued).
    Cancelled,
    /// The run returned an error; `detail` carries the message.
    Failed,
}

impl JobState {
    /// Canonical wire/journal name.
    pub fn name(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Cancelled => "cancelled",
            JobState::Failed => "failed",
        }
    }

    /// Parse a wire/journal name back.
    pub fn parse(s: &str) -> Result<JobState> {
        match s {
            "queued" => Ok(JobState::Queued),
            "running" => Ok(JobState::Running),
            "done" => Ok(JobState::Done),
            "cancelled" => Ok(JobState::Cancelled),
            "failed" => Ok(JobState::Failed),
            other => Err(Error::config(format!(
                "unknown job state '{other}' (queued|running|done|cancelled|failed)"
            ))),
        }
    }

    /// Whether the job can never change state again.
    pub fn is_terminal(&self) -> bool {
        matches!(self, JobState::Done | JobState::Cancelled | JobState::Failed)
    }
}

/// What a client submits: a named, prioritized run configuration.
#[derive(Clone, Debug)]
pub struct JobSpec {
    /// Free-form label for humans (`list` output); not unique.
    pub name: String,
    /// Higher runs first; FIFO within a priority level.
    pub priority: i64,
    /// The full run configuration, normalized by the scheduler at
    /// admission (per-job checkpoint dir, guaranteed cadence).
    pub config: RunConfig,
}

/// Terminal summary of a finished (done or cancelled) run.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct JobOutcome {
    /// Epochs actually trained (cancelled runs: stop boundary + 1).
    pub epochs_done: u64,
    /// Final mean-of-last generator loss across ranks.
    pub gen_loss: Option<f64>,
    /// Final mean-of-last discriminator loss across ranks.
    pub disc_loss: Option<f64>,
}

/// A job as the queue tracks it.
#[derive(Clone, Debug)]
pub struct Job {
    pub id: JobId,
    pub spec: JobSpec,
    pub state: JobState,
    /// Human-readable detail for the current state (failure message,
    /// cancel boundary, resume note); empty when there is nothing to
    /// say.
    pub detail: String,
    /// A daemon restart found this job mid-run and re-queued it; the
    /// scheduler will resume it from its own newest checkpoint.
    pub interrupted: bool,
    /// Terminal metrics (done/cancelled runs only).
    pub outcome: Option<JobOutcome>,
}

impl Job {
    /// The status row reported over the control channel. `progress` is
    /// the live view for running jobs (epochs done so far, rank 0's
    /// latest losses); terminal jobs report their outcome instead.
    pub fn status(&self, progress: Option<crate::coordinator::ProgressSnapshot>) -> JobStatus {
        let (epochs_done, gen_loss, disc_loss) = match (&self.outcome, progress) {
            (Some(out), _) => (out.epochs_done, out.gen_loss, out.disc_loss),
            (None, Some(p)) => (
                p.epochs_done,
                p.gen_loss.is_finite().then_some(p.gen_loss),
                p.disc_loss.is_finite().then_some(p.disc_loss),
            ),
            (None, None) => (0, None, None),
        };
        JobStatus {
            id: self.id,
            name: self.spec.name.clone(),
            state: self.state,
            priority: self.spec.priority,
            scenario: self.spec.config.scenario.clone(),
            epochs: self.spec.config.epochs as u64,
            epochs_done,
            gen_loss,
            disc_loss,
            detail: self.detail.clone(),
        }
    }
}

/// One row of `sagips job status|list` output: the wire form of a job.
#[derive(Clone, Debug, PartialEq)]
pub struct JobStatus {
    pub id: JobId,
    pub name: String,
    pub state: JobState,
    pub priority: i64,
    pub scenario: String,
    /// Configured epoch count.
    pub epochs: u64,
    /// Epochs completed: live progress while running, the terminal
    /// count afterwards.
    pub epochs_done: u64,
    /// Latest (running) or final (terminal) generator loss.
    pub gen_loss: Option<f64>,
    /// Latest (running) or final (terminal) discriminator loss.
    pub disc_loss: Option<f64>,
    pub detail: String,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn state_names_roundtrip() {
        for st in [
            JobState::Queued,
            JobState::Running,
            JobState::Done,
            JobState::Cancelled,
            JobState::Failed,
        ] {
            assert_eq!(JobState::parse(st.name()).unwrap(), st);
        }
        assert!(JobState::parse("paused").is_err());
    }

    #[test]
    fn terminality() {
        assert!(!JobState::Queued.is_terminal());
        assert!(!JobState::Running.is_terminal());
        assert!(JobState::Done.is_terminal());
        assert!(JobState::Cancelled.is_terminal());
        assert!(JobState::Failed.is_terminal());
    }

    #[test]
    fn status_prefers_outcome_over_progress() {
        let job = Job {
            id: 3,
            spec: JobSpec {
                name: "t".into(),
                priority: 0,
                config: crate::config::presets::ci_default(),
            },
            state: JobState::Done,
            detail: String::new(),
            interrupted: false,
            outcome: Some(JobOutcome {
                epochs_done: 40,
                gen_loss: Some(0.7),
                disc_loss: Some(0.6),
            }),
        };
        let live = crate::coordinator::ProgressSnapshot {
            epochs_done: 39,
            gen_loss: 0.9,
            disc_loss: 0.8,
        };
        let st = job.status(Some(live));
        assert_eq!(st.epochs_done, 40);
        assert_eq!(st.gen_loss, Some(0.7));
    }

    #[test]
    fn status_drops_nan_losses() {
        let job = Job {
            id: 1,
            spec: JobSpec {
                name: "t".into(),
                priority: 0,
                config: crate::config::presets::ci_default(),
            },
            state: JobState::Running,
            detail: String::new(),
            interrupted: false,
            outcome: None,
        };
        // Before the first epoch the live losses are NaN placeholders;
        // the wire form must omit them (JSON has no NaN).
        let st = job.status(Some(crate::coordinator::ProgressSnapshot::default()));
        assert_eq!(st.gen_loss, None);
        assert_eq!(st.disc_loss, None);
    }
}
