//! The scheduler: multiplexes queued jobs over a shared pool of worker
//! threads with admission control, cooperative cancellation, and
//! reload-without-restart.
//!
//! # Concurrency model
//!
//! One OS thread per concurrency slot (`max_concurrent_jobs`). Each
//! worker claims the next job under the queue lock *only* while the
//! running-job gauge is below the limit, so lowering the limit on a
//! [`Scheduler::reload`] immediately stops new claims (surplus workers
//! idle; running jobs finish). Raising it spawns the missing workers.
//! Each claimed job runs through the pluggable [`JobRunner`] with a
//! fresh [`RunControl`] registered in the running map — that is the
//! handle `cancel` uses to request a stop at the next safe checkpoint
//! boundary.
//!
//! # Cancellation
//!
//! * queued → marked `cancelled` instantly, never starts;
//! * running → [`RunControl::request_cancel`]; the run drains its
//!   pipeline to quiescence at the consensus stop boundary, deposits a
//!   final full-width run checkpoint, and the job lands in `cancelled`
//!   with the boundary in its detail — `--resume` of the job's config
//!   continues bit-identically (proven by `rust/tests/serve.rs`);
//! * terminal → no-op, reported as such.
//!
//! A cancel that lands near the end of a run may decide a stop boundary
//! at or past the final epoch: the run then completes normally and the
//! job ends `done`, not `cancelled`.
//!
//! # Restart
//!
//! The queue journal re-queues jobs that were mid-run when the daemon
//! died (`interrupted`). Before re-running one, the worker looks for
//! the job's own newest run checkpoint and resumes from it; when the
//! checkpoint already covers the configured epochs, the job is marked
//! `done` without re-running ("nothing left to train").

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::config::RunConfig;
use crate::coordinator::RunControl;
use crate::model::checkpoint::TrainCheckpoint;
use crate::util::error::{Error, Result};
use crate::util::json::Value;

use super::job::{Job, JobId, JobOutcome, JobSpec, JobState, JobStatus};
use super::queue::{JobQueue, DEFAULT_JOURNAL_COMPACT_LINES};
use super::runner::{JobRunner, RunOutcome};

/// Serving limits, reloadable without restart (`reload` verb).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ServeLimits {
    /// Concurrency slots: jobs running at once (>= 1).
    pub max_concurrent_jobs: usize,
    /// Admission control: waiting jobs beyond this are refused with the
    /// retryable `Overloaded` error (0 = unlimited).
    pub max_queued: usize,
    /// Checkpoint cadence assigned to jobs that submit `ckpt_every: 0`
    /// — every admitted job needs a cadence, both for cancellation
    /// (stops happen only at boundaries) and restart resume.
    pub default_ckpt_every: usize,
    /// Journal auto-compaction threshold at daemon start: a replay of
    /// more than this many lines rewrites `journal.jsonl` as a snapshot
    /// (0 disables; the `compact` verb always works on demand).
    pub journal_compact_lines: usize,
    /// Checkpoint retention: keep the run-checkpoint directories of the
    /// newest N terminal jobs; older terminal jobs' `job-NNNNNN` dirs
    /// are deleted after each job finishes (0 = keep everything).
    pub keep_job_checkpoints: usize,
}

impl Default for ServeLimits {
    fn default() -> Self {
        ServeLimits {
            max_concurrent_jobs: 2,
            max_queued: 64,
            default_ckpt_every: 25,
            journal_compact_lines: DEFAULT_JOURNAL_COMPACT_LINES,
            keep_job_checkpoints: 0,
        }
    }
}

impl ServeLimits {
    /// Parse a serve-config JSON object; absent keys keep their
    /// defaults, unknown keys are rejected to catch typos.
    pub fn from_json(text: &str) -> Result<ServeLimits> {
        let v = Value::parse(text)?;
        let obj = v
            .as_object()
            .ok_or_else(|| Error::config("serve config root must be an object"))?;
        let mut lim = ServeLimits::default();
        for (k, val) in obj {
            let n = val
                .as_usize()
                .ok_or_else(|| Error::config(format!("serve config '{k}' must be a number")))?;
            match k.as_str() {
                "max_concurrent_jobs" => lim.max_concurrent_jobs = n,
                "max_queued" => lim.max_queued = n,
                "default_ckpt_every" => lim.default_ckpt_every = n,
                "journal_compact_lines" => lim.journal_compact_lines = n,
                "keep_job_checkpoints" => lim.keep_job_checkpoints = n,
                other => {
                    return Err(Error::config(format!(
                        "unknown serve config key '{other}' (max_concurrent_jobs, \
                         max_queued, default_ckpt_every, journal_compact_lines, \
                         keep_job_checkpoints)"
                    )))
                }
            }
        }
        lim.validate()?;
        Ok(lim)
    }

    /// Reject shapes that would wedge the daemon.
    pub fn validate(&self) -> Result<()> {
        if self.max_concurrent_jobs == 0 {
            return Err(Error::config("max_concurrent_jobs must be >= 1"));
        }
        if self.default_ckpt_every == 0 {
            return Err(Error::config(
                "default_ckpt_every must be >= 1: serve jobs need a checkpoint \
                 cadence for cancellation and restart resume",
            ));
        }
        Ok(())
    }
}

/// What a cancel request achieved.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CancelOutcome {
    /// The job was still queued: removed instantly, it never starts.
    Dequeued,
    /// The job is running: stop requested; it will drain and deposit a
    /// final checkpoint at the next safe boundary (or complete normally
    /// if none remains).
    Stopping,
    /// The job was already terminal; nothing to do.
    AlreadyTerminal(JobState),
}

struct Inner {
    queue: Mutex<JobQueue>,
    /// Signals work availability / limit or shutdown changes; paired
    /// with the `queue` mutex.
    work: Condvar,
    /// Controls of currently running jobs (cancel + live progress).
    /// Lock order: `queue` may be held when taking `running`, never the
    /// reverse.
    running: Mutex<BTreeMap<JobId, Arc<RunControl>>>,
    /// Jobs currently executing; mutated only while holding `queue`.
    busy: AtomicUsize,
    max_concurrent: AtomicUsize,
    default_ckpt_every: AtomicUsize,
    /// Checkpoint retention window (0 = keep everything).
    keep_job_checkpoints: AtomicUsize,
    shutdown: AtomicBool,
    state_dir: PathBuf,
    runner: Box<dyn JobRunner>,
}

/// The job scheduler (see module docs).
pub struct Scheduler {
    inner: Arc<Inner>,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl Scheduler {
    /// Open the journaled queue under `state_dir` (re-queueing
    /// interrupted jobs) and start the worker pool.
    pub fn open(
        state_dir: &Path,
        limits: ServeLimits,
        runner: Box<dyn JobRunner>,
    ) -> Result<Scheduler> {
        limits.validate()?;
        let queue = JobQueue::open_with_compaction(
            state_dir,
            limits.max_queued,
            limits.journal_compact_lines,
        )?;
        let inner = Arc::new(Inner {
            queue: Mutex::new(queue),
            work: Condvar::new(),
            running: Mutex::new(BTreeMap::new()),
            busy: AtomicUsize::new(0),
            max_concurrent: AtomicUsize::new(limits.max_concurrent_jobs),
            default_ckpt_every: AtomicUsize::new(limits.default_ckpt_every),
            keep_job_checkpoints: AtomicUsize::new(limits.keep_job_checkpoints),
            shutdown: AtomicBool::new(false),
            state_dir: state_dir.to_path_buf(),
            runner,
        });
        let sched = Scheduler {
            inner,
            workers: Mutex::new(Vec::new()),
        };
        // A restarted daemon prunes immediately: jobs that went terminal
        // in a previous life count against the retention window too.
        gc_checkpoints(&sched.inner);
        sched.ensure_workers()?;
        Ok(sched)
    }

    /// Spawn workers until one exists per concurrency slot. The pool
    /// never shrinks — surplus workers idle when the limit drops.
    fn ensure_workers(&self) -> Result<()> {
        let mut workers = self.workers.lock().expect("worker list poisoned");
        let want = self.inner.max_concurrent.load(Ordering::Acquire);
        while workers.len() < want {
            let inner = self.inner.clone();
            let idx = workers.len();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("job-worker-{idx}"))
                    .spawn(move || worker_loop(inner))
                    .map_err(Error::Io)?,
            );
        }
        Ok(())
    }

    /// Admit a job: normalize its config (per-job checkpoint dir under
    /// the state dir, guaranteed cadence), validate, journal, enqueue.
    pub fn submit(&self, mut spec: JobSpec) -> Result<JobId> {
        let mut q = self.inner.queue.lock().expect("queue poisoned");
        let id = q.next_id();
        self.normalize(&mut spec.config, id)?;
        let assigned = q.submit(spec)?;
        debug_assert_eq!(assigned, id);
        self.inner.work.notify_all();
        Ok(assigned)
    }

    /// Per-job config normalization at admission.
    fn normalize(&self, cfg: &mut RunConfig, id: JobId) -> Result<()> {
        if cfg.membership.is_some() || cfg.evict_after > 0 {
            return Err(Error::config(
                "serve jobs do not compose with elastic membership \
                 (membership / evict_after): the cancellation stop-boundary \
                 consensus assumes a fixed cohort — run those one-shot via \
                 `sagips train`",
            ));
        }
        // Each job checkpoints into its own directory so cancellation,
        // restart resume, and pruning never cross jobs.
        cfg.ckpt_dir = self
            .inner
            .state_dir
            .join(format!("job-{id:06}"))
            .to_string_lossy()
            .into_owned();
        if cfg.ckpt_every == 0 {
            let dflt = self.inner.default_ckpt_every.load(Ordering::Acquire);
            cfg.ckpt_every = dflt.min(cfg.epochs).max(1);
        }
        cfg.validate()
    }

    /// Cancel a job (see [`CancelOutcome`] for the three cases).
    pub fn cancel(&self, id: JobId) -> Result<CancelOutcome> {
        let mut q = self.inner.queue.lock().expect("queue poisoned");
        let state = q
            .get(id)
            .map(|j| j.state)
            .ok_or_else(|| Error::config(format!("no such job: {id}")))?;
        match state {
            JobState::Queued => {
                q.set_state(id, JobState::Cancelled, "cancelled while queued")?;
                Ok(CancelOutcome::Dequeued)
            }
            JobState::Running => {
                if let Some(ctl) = self
                    .inner
                    .running
                    .lock()
                    .expect("running map poisoned")
                    .get(&id)
                {
                    ctl.request_cancel();
                }
                Ok(CancelOutcome::Stopping)
            }
            st => Ok(CancelOutcome::AlreadyTerminal(st)),
        }
    }

    /// One job's status row; running jobs carry their live progress.
    pub fn status(&self, id: JobId) -> Option<JobStatus> {
        let q = self.inner.queue.lock().expect("queue poisoned");
        let job = q.get(id)?;
        Some(job.status(self.progress_of(job)))
    }

    /// Every job's status row, id order.
    pub fn list(&self) -> Vec<JobStatus> {
        let q = self.inner.queue.lock().expect("queue poisoned");
        q.jobs().map(|j| j.status(self.progress_of(j))).collect()
    }

    fn progress_of(&self, job: &Job) -> Option<crate::coordinator::ProgressSnapshot> {
        if job.state != JobState::Running {
            return None;
        }
        self.inner
            .running
            .lock()
            .expect("running map poisoned")
            .get(&job.id)
            .map(|c| c.progress())
    }

    /// The normalized config a job actually runs with.
    pub fn job_config(&self, id: JobId) -> Option<RunConfig> {
        self.inner
            .queue
            .lock()
            .expect("queue poisoned")
            .get(id)
            .map(|j| j.spec.config.clone())
    }

    /// Jobs currently executing.
    pub fn running_count(&self) -> usize {
        self.inner.busy.load(Ordering::Acquire)
    }

    /// Jobs waiting in the queue.
    pub fn queued_count(&self) -> usize {
        self.inner.queue.lock().expect("queue poisoned").queued_len()
    }

    /// Apply new limits without restart: admission and concurrency take
    /// effect immediately (the pool grows on demand; it never shrinks —
    /// surplus workers idle).
    pub fn reload(&self, limits: ServeLimits) -> Result<()> {
        limits.validate()?;
        self.inner
            .max_concurrent
            .store(limits.max_concurrent_jobs, Ordering::Release);
        self.inner
            .default_ckpt_every
            .store(limits.default_ckpt_every, Ordering::Release);
        self.inner
            .keep_job_checkpoints
            .store(limits.keep_job_checkpoints, Ordering::Release);
        self.inner
            .queue
            .lock()
            .expect("queue poisoned")
            .set_max_queued(limits.max_queued);
        // A tightened retention window takes effect now, not at the next
        // job completion.
        gc_checkpoints(&self.inner);
        self.ensure_workers()?;
        self.inner.work.notify_all();
        Ok(())
    }

    /// Rewrite the journal as a snapshot on demand (`compact` verb);
    /// returns the compacted journal's line count.
    pub fn compact(&self) -> Result<usize> {
        self.inner.queue.lock().expect("queue poisoned").compact()
    }

    /// Stop the scheduler: optionally request cancellation of every
    /// running job (each drains and deposits a resumable checkpoint),
    /// then join the workers. Queued jobs stay journaled for the next
    /// daemon start.
    pub fn shutdown(&self, cancel_running: bool) {
        if cancel_running {
            for ctl in self
                .inner
                .running
                .lock()
                .expect("running map poisoned")
                .values()
            {
                ctl.request_cancel();
            }
        }
        self.inner.shutdown.store(true, Ordering::Release);
        self.inner.work.notify_all();
        let mut workers = self.workers.lock().expect("worker list poisoned");
        for h in workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Worker thread: claim → run → record, until shutdown.
fn worker_loop(inner: Arc<Inner>) {
    loop {
        let job = {
            let mut q = inner.queue.lock().expect("queue poisoned");
            loop {
                if inner.shutdown.load(Ordering::Acquire) {
                    return;
                }
                if inner.busy.load(Ordering::Acquire)
                    < inner.max_concurrent.load(Ordering::Acquire)
                {
                    match q.claim_next() {
                        Ok(Some(job)) => {
                            inner.busy.fetch_add(1, Ordering::AcqRel);
                            break job;
                        }
                        Ok(None) => {}
                        Err(e) => {
                            crate::log_warn!("job claim failed: {e}");
                        }
                    }
                }
                // Timed wait so shutdown and limit changes are observed
                // even if a notify raced past us.
                let (guard, _) = inner
                    .work
                    .wait_timeout(q, Duration::from_millis(100))
                    .expect("queue poisoned");
                q = guard;
            }
        };
        run_one(&inner, job);
    }
}

/// How a claimed job is to be executed.
enum Prepared {
    Run(RunConfig),
    /// An interrupted job whose checkpoint already covers every
    /// configured epoch: nothing left to train.
    AlreadyComplete(u64),
}

/// Decide the config a claimed job actually runs: interrupted jobs
/// resume from their own newest checkpoint.
fn prepare(job: &Job) -> Result<Prepared> {
    let mut cfg = job.spec.config.clone();
    if job.interrupted {
        if let Some(latest) = TrainCheckpoint::latest(Path::new(&cfg.ckpt_dir))? {
            let epoch = checkpoint_epoch(&latest)?;
            if epoch + 1 >= cfg.epochs as u64 {
                return Ok(Prepared::AlreadyComplete(epoch));
            }
            crate::log_info!(
                "job {}: interrupted mid-run, resuming from epoch {epoch}",
                job.id
            );
            cfg.resume = Some(cfg.ckpt_dir.clone());
        }
    }
    Ok(Prepared::Run(cfg))
}

/// Epoch encoded in a run-checkpoint directory name (`run_e<epoch>`).
fn checkpoint_epoch(path: &Path) -> Result<u64> {
    path.file_name()
        .and_then(|n| n.to_str())
        .and_then(|n| n.strip_prefix("run_e"))
        .and_then(|digits| digits.parse::<u64>().ok())
        .ok_or_else(|| {
            Error::Checkpoint(format!(
                "{}: not a run checkpoint directory",
                path.display()
            ))
        })
}

/// Execute one claimed job and record its terminal state.
fn run_one(inner: &Arc<Inner>, job: Job) {
    let id = job.id;
    let ctl = Arc::new(RunControl::new());
    inner
        .running
        .lock()
        .expect("running map poisoned")
        .insert(id, ctl.clone());

    let result: crate::util::error::Result<(RunOutcome, String)> =
        prepare(&job).and_then(|prep| match prep {
            Prepared::AlreadyComplete(epoch) => Ok((
                RunOutcome {
                    epochs_done: epoch + 1,
                    ..RunOutcome::default()
                },
                format!("already complete at restart (checkpoint at epoch {epoch})"),
            )),
            Prepared::Run(cfg) => inner
                .runner
                .run(&cfg, ctl.clone())
                .map(|out| (out, String::new())),
        });

    inner
        .running
        .lock()
        .expect("running map poisoned")
        .remove(&id);

    let mut q = inner.queue.lock().expect("queue poisoned");
    let recorded = match result {
        Ok((out, note)) => {
            let outcome = JobOutcome {
                epochs_done: out.epochs_done,
                gen_loss: out.gen_loss,
                disc_loss: out.disc_loss,
            };
            match out.stopped_at {
                Some(b) => q.finish(
                    id,
                    JobState::Cancelled,
                    &format!(
                        "cancelled at checkpoint boundary {b}; resumable from {}",
                        job.spec.config.ckpt_dir
                    ),
                    outcome,
                ),
                None => q.finish(id, JobState::Done, &note, outcome),
            }
        }
        Err(e) => q.finish(id, JobState::Failed, &e.to_string(), JobOutcome::default()),
    };
    if let Err(e) = recorded {
        crate::log_warn!("job {id}: failed to journal terminal state: {e}");
    }
    drop(q);
    gc_checkpoints(inner);
    inner.busy.fetch_sub(1, Ordering::AcqRel);
    inner.work.notify_all();
}

/// Apply the checkpoint retention window: delete the `job-NNNNNN`
/// checkpoint directories of terminal jobs older than the newest
/// `keep_job_checkpoints` (by job id). Deletion happens outside the
/// queue lock — only the doomed-path scan holds it.
fn gc_checkpoints(inner: &Inner) {
    let keep = inner.keep_job_checkpoints.load(Ordering::Acquire);
    let doomed = {
        let q = inner.queue.lock().expect("queue poisoned");
        doomed_checkpoint_dirs(&q, &inner.state_dir, keep)
    };
    for dir in doomed {
        match std::fs::remove_dir_all(&dir) {
            Ok(()) => crate::log_info!("checkpoint gc: removed {}", dir.display()),
            Err(e) => crate::log_warn!("checkpoint gc: {}: {e}", dir.display()),
        }
    }
}

/// The checkpoint directories the retention window condemns: every
/// terminal job except the newest `keep` (0 keeps everything), filtered
/// to dirs that actually exist. Queued and running jobs are never
/// touched — their checkpoints are what cancellation and restart
/// resume depend on.
fn doomed_checkpoint_dirs(q: &JobQueue, state_dir: &Path, keep: usize) -> Vec<PathBuf> {
    if keep == 0 {
        return Vec::new();
    }
    // `jobs()` iterates in ascending id order; drop the newest `keep`.
    let mut terminal: Vec<JobId> = q
        .jobs()
        .filter(|j| j.state.is_terminal())
        .map(|j| j.id)
        .collect();
    terminal.truncate(terminal.len().saturating_sub(keep));
    terminal
        .into_iter()
        .map(|id| state_dir.join(format!("job-{id:06}")))
        .filter(|d| d.is_dir())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn limits_parse_with_defaults_and_reject_unknown() {
        let lim = ServeLimits::from_json(r#"{"max_concurrent_jobs": 4}"#).unwrap();
        assert_eq!(lim.max_concurrent_jobs, 4);
        assert_eq!(lim.max_queued, ServeLimits::default().max_queued);
        assert_eq!(lim.journal_compact_lines, DEFAULT_JOURNAL_COMPACT_LINES);
        assert_eq!(lim.keep_job_checkpoints, 0);
        let lim =
            ServeLimits::from_json(r#"{"max_queued": 0, "default_ckpt_every": 6}"#).unwrap();
        assert_eq!(lim.max_queued, 0);
        assert_eq!(lim.default_ckpt_every, 6);
        let lim = ServeLimits::from_json(
            r#"{"journal_compact_lines": 100, "keep_job_checkpoints": 3}"#,
        )
        .unwrap();
        assert_eq!(lim.journal_compact_lines, 100);
        assert_eq!(lim.keep_job_checkpoints, 3);
        assert!(ServeLimits::from_json(r#"{"max_jobs": 4}"#).is_err());
        assert!(ServeLimits::from_json(r#"{"max_concurrent_jobs": 0}"#).is_err());
        assert!(ServeLimits::from_json(r#"{"default_ckpt_every": 0}"#).is_err());
        assert!(ServeLimits::from_json(r#"[]"#).is_err());
    }

    #[test]
    fn retention_window_condemns_oldest_terminal_dirs_only() {
        use crate::config::presets;
        let state_dir = std::env::temp_dir().join(format!(
            "sagips_sched_gc_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&state_dir);
        let mut q = JobQueue::ephemeral(0);
        let spec = |name: &str| JobSpec {
            name: name.into(),
            priority: 0,
            config: presets::ci_default(),
        };
        // Jobs 1..=4 terminal, 5 queued, 6 running; every one has a dir.
        let mut ids = Vec::new();
        for i in 0..6 {
            ids.push(q.submit(spec(&format!("j{i}"))).unwrap());
        }
        for &id in &ids[..4] {
            q.set_state(id, JobState::Running, "").unwrap();
            q.finish(id, JobState::Done, "", JobOutcome::default()).unwrap();
        }
        q.set_state(ids[5], JobState::Running, "").unwrap();
        for &id in &ids {
            std::fs::create_dir_all(state_dir.join(format!("job-{id:06}"))).unwrap();
        }
        // keep=0 disables GC entirely.
        assert!(doomed_checkpoint_dirs(&q, &state_dir, 0).is_empty());
        // keep=2: the two oldest terminal jobs are condemned; queued and
        // running dirs survive regardless of age.
        let doomed = doomed_checkpoint_dirs(&q, &state_dir, 2);
        let want: Vec<PathBuf> = ids[..2]
            .iter()
            .map(|id| state_dir.join(format!("job-{id:06}")))
            .collect();
        assert_eq!(doomed, want);
        // keep >= terminal count condemns nothing.
        assert!(doomed_checkpoint_dirs(&q, &state_dir, 4).is_empty());
        // Already-deleted dirs are skipped, not re-condemned.
        std::fs::remove_dir_all(&want[0]).unwrap();
        assert_eq!(doomed_checkpoint_dirs(&q, &state_dir, 2), want[1..].to_vec());
        let _ = std::fs::remove_dir_all(&state_dir);
    }

    #[test]
    fn checkpoint_epoch_parses_dir_names() {
        use std::path::PathBuf;
        let p = PathBuf::from("/x/job-000001").join(TrainCheckpoint::dir_name(11));
        assert_eq!(checkpoint_epoch(&p).unwrap(), 11);
        assert!(checkpoint_epoch(&PathBuf::from("/x/not-a-ckpt")).is_err());
    }
}
