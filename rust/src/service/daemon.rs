//! The `sagips serve` daemon: a line-JSON control loop in front of the
//! [`Scheduler`].
//!
//! Two transports, same protocol:
//!
//! * **unix socket** (default) — `sagips job …` client verbs connect,
//!   write one request line, read one response line;
//! * **stdio** (`--stdio`) — requests on stdin, responses on stdout,
//!   one per line; handy for tests, supervisors, and piping. (All
//!   logging goes to stderr, so stdout carries protocol lines only.)
//!
//! Durability does not depend on a clean shutdown: the queue journal
//! and the atomic run checkpoints are written as the daemon goes, so
//! `kill -TERM`/`-KILL` mid-job loses at most the epochs since the last
//! checkpoint boundary — on restart the journal re-queues the
//! interrupted job and the scheduler resumes it from its own newest
//! checkpoint (exercised by the `serve-smoke` CI job). The `shutdown`
//! verb is the graceful variant: it cancels running jobs so each
//! deposits a final resumable checkpoint, then joins the workers.
//!
//! Config reload without restart: the `reload` verb re-reads the
//! `--serve-config` JSON (see [`ServeLimits::from_json`]) and applies
//! the new limits to admission and concurrency immediately.

use std::io::{BufRead, BufReader, Write};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::time::Duration;

use crate::util::error::{Error, Result};
use crate::util::json::{self, Value};

use super::job::JobSpec;
use super::protocol::{self, Request};
use super::runner::JobRunner;
use super::scheduler::{CancelOutcome, Scheduler, ServeLimits};

/// The daemon: scheduler + control-channel front end.
pub struct Daemon {
    scheduler: Scheduler,
    /// Re-read on the `reload` verb; `None` = reload keeps current
    /// limits (still re-spawns workers up to the concurrency limit).
    serve_config: Option<PathBuf>,
}

impl Daemon {
    /// Open the journaled state under `state_dir` and start the worker
    /// pool. `serve_config` is the limits file the `reload` verb
    /// re-reads.
    pub fn open(
        state_dir: &Path,
        limits: ServeLimits,
        serve_config: Option<PathBuf>,
        runner: Box<dyn JobRunner>,
    ) -> Result<Daemon> {
        Ok(Daemon {
            scheduler: Scheduler::open(state_dir, limits, runner)?,
            serve_config,
        })
    }

    /// The scheduler behind the control channel (in-process embedding,
    /// tests).
    pub fn scheduler(&self) -> &Scheduler {
        &self.scheduler
    }

    /// Handle one request line; returns the response line and whether
    /// the daemon should shut down after sending it.
    pub fn handle_line(&self, line: &str) -> (String, bool) {
        let req = match Request::parse(line) {
            Ok(r) => r,
            Err(e) => return (protocol::err_response(&e), false),
        };
        match req {
            Request::Ping => (
                protocol::ok_response(vec![
                    ("running", json::num(self.scheduler.running_count() as f64)),
                    ("queued", json::num(self.scheduler.queued_count() as f64)),
                ]),
                false,
            ),
            Request::Submit {
                name,
                priority,
                config,
            } => {
                let spec = JobSpec {
                    name,
                    priority,
                    config,
                };
                match self.scheduler.submit(spec) {
                    Ok(id) => (
                        protocol::ok_response(vec![("id", json::num(id as f64))]),
                        false,
                    ),
                    Err(e) => (protocol::err_response(&e), false),
                }
            }
            Request::Status { id } => match self.scheduler.status(id) {
                Some(st) => (
                    protocol::ok_response(vec![("job", protocol::status_value(&st))]),
                    false,
                ),
                None => (
                    protocol::err_response(&Error::config(format!("no such job: {id}"))),
                    false,
                ),
            },
            Request::Cancel { id } => match self.scheduler.cancel(id) {
                Ok(outcome) => {
                    let result = match outcome {
                        CancelOutcome::Dequeued => "dequeued",
                        CancelOutcome::Stopping => "stopping",
                        CancelOutcome::AlreadyTerminal(st) => st.name(),
                    };
                    (
                        protocol::ok_response(vec![("result", json::s(result))]),
                        false,
                    )
                }
                Err(e) => (protocol::err_response(&e), false),
            },
            Request::List => {
                let jobs: Vec<Value> = self
                    .scheduler
                    .list()
                    .iter()
                    .map(protocol::status_value)
                    .collect();
                (
                    protocol::ok_response(vec![("jobs", json::arr(jobs))]),
                    false,
                )
            }
            Request::Reload => match self.reload() {
                Ok(note) => (
                    protocol::ok_response(vec![("reloaded", json::s(note))]),
                    false,
                ),
                Err(e) => (protocol::err_response(&e), false),
            },
            Request::Compact => match self.scheduler.compact() {
                Ok(lines) => (
                    protocol::ok_response(vec![(
                        "journal_lines",
                        json::num(lines as f64),
                    )]),
                    false,
                ),
                Err(e) => (protocol::err_response(&e), false),
            },
            Request::Shutdown => (
                protocol::ok_response(vec![("shutdown", Value::Bool(true))]),
                true,
            ),
        }
    }

    /// Re-read the serve-config file (if any) and apply its limits.
    fn reload(&self) -> Result<String> {
        match &self.serve_config {
            Some(path) => {
                let text = std::fs::read_to_string(path)?;
                let limits = ServeLimits::from_json(&text)?;
                self.scheduler.reload(limits)?;
                crate::log_info!(
                    "reloaded limits from {}: {limits:?}",
                    path.display()
                );
                Ok(path.display().to_string())
            }
            None => Ok("no --serve-config given; limits unchanged".into()),
        }
    }

    /// Serve the stdin/stdout loop until EOF or a `shutdown` verb, then
    /// shut the scheduler down gracefully.
    pub fn serve_stdio(self) -> Result<()> {
        let stdin = std::io::stdin();
        let mut stdout = std::io::stdout();
        for line in stdin.lock().lines() {
            let line = line?;
            if line.trim().is_empty() {
                continue;
            }
            let (resp, quit) = self.handle_line(&line);
            stdout.write_all(resp.as_bytes())?;
            stdout.write_all(b"\n")?;
            stdout.flush()?;
            if quit {
                break;
            }
        }
        self.close();
        Ok(())
    }

    /// Serve a unix socket until a `shutdown` verb, then shut the
    /// scheduler down gracefully and remove the socket.
    ///
    /// Connections are handled serially: every client verb is one
    /// request/response exchange, so a connection is held only for the
    /// time it takes to answer one line.
    pub fn serve_unix(self, socket: &Path) -> Result<()> {
        // A stale socket file from a killed daemon would make bind fail.
        if socket.exists() {
            std::fs::remove_file(socket)?;
        }
        if let Some(parent) = socket.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let listener = UnixListener::bind(socket)?;
        // Nonblocking accept + sleep so the loop can wind down promptly
        // after a shutdown verb instead of blocking forever in accept.
        listener.set_nonblocking(true)?;
        crate::log_info!("serving on {}", socket.display());
        let mut quit = false;
        while !quit {
            match listener.accept() {
                Ok((stream, _addr)) => {
                    quit = self.serve_connection(stream)?;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(50));
                }
                Err(e) => return Err(Error::Io(e)),
            }
        }
        let _ = std::fs::remove_file(socket);
        self.close();
        Ok(())
    }

    /// Answer every line of one connection; returns true on `shutdown`.
    fn serve_connection(&self, stream: UnixStream) -> Result<bool> {
        // The accept loop is nonblocking; per-connection reads block.
        stream.set_nonblocking(false)?;
        let mut writer = stream.try_clone()?;
        let reader = BufReader::new(stream);
        for line in reader.lines() {
            let line = match line {
                Ok(l) => l,
                // A client that hung up mid-line is its problem, not ours.
                Err(_) => break,
            };
            if line.trim().is_empty() {
                continue;
            }
            let (resp, quit) = self.handle_line(&line);
            writer.write_all(resp.as_bytes())?;
            writer.write_all(b"\n")?;
            writer.flush()?;
            if quit {
                return Ok(true);
            }
        }
        Ok(false)
    }

    /// Graceful shutdown: cancel running jobs (each deposits a final
    /// resumable checkpoint at its next boundary) and join the workers.
    pub fn close(self) {
        self.scheduler.shutdown(true);
    }
}

/// One-shot client side of the unix-socket transport: send one request
/// line, read one response line.
pub fn client_roundtrip(socket: &Path, req: &Request) -> Result<Value> {
    let stream = UnixStream::connect(socket).map_err(|e| {
        Error::Runtime(format!(
            "cannot reach daemon at {} ({e}) — is `sagips serve` running?",
            socket.display()
        ))
    })?;
    let mut writer = stream.try_clone()?;
    writer.write_all(req.to_line().as_bytes())?;
    writer.write_all(b"\n")?;
    writer.flush()?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    if line.trim().is_empty() {
        return Err(Error::Runtime(
            "daemon closed the connection without responding".into(),
        ));
    }
    Value::parse(line.trim())
}
