//! The service layer: `sagips serve` turns the one-shot trainer into a
//! long-running job daemon.
//!
//! Layering, bottom-up:
//!
//! * [`job`] — job identity and lifecycle: the state machine
//!   `queued → running → {done, cancelled, failed}` plus the status row
//!   surfaced to clients.
//! * [`queue`] — the persistent FIFO-with-priorities [`JobQueue`]. Every
//!   mutation is appended to `journal.jsonl` under the state dir, so a
//!   daemon killed mid-run replays its queue on restart; jobs that were
//!   `running` at the kill re-queue as *interrupted* and resume from
//!   their newest run checkpoint.
//! * [`runner`] — the [`JobRunner`] trait ([`TrainingRunner`] = the real
//!   `sagips train` path with a [`RunControl`] attached; tests plug in
//!   mocks).
//! * [`scheduler`] — the worker pool: claims jobs in priority-then-FIFO
//!   order, enforces admission control ([`ServeLimits`]), and owns
//!   cooperative cancellation (queued jobs cancel instantly; running
//!   jobs drain to the next checkpoint boundary and deposit a final
//!   resumable checkpoint — see `coordinator::control` for the
//!   stop-boundary consensus).
//! * [`protocol`] — the line-JSON request/response wire format shared by
//!   the daemon and the `sagips job …` client verbs.
//! * [`daemon`] — the control loop itself, over a unix socket or
//!   stdin/stdout, plus config reload without restart.
//!
//! [`RunControl`]: crate::coordinator::RunControl

pub mod daemon;
pub mod job;
pub mod protocol;
pub mod queue;
pub mod runner;
pub mod scheduler;

pub use daemon::{client_roundtrip, Daemon};
pub use job::{Job, JobId, JobOutcome, JobSpec, JobState, JobStatus};
pub use queue::JobQueue;
pub use runner::{JobRunner, RunOutcome, TrainingRunner};
pub use scheduler::{CancelOutcome, Scheduler, ServeLimits};
