//! The overlap engine: a dedicated comm thread per rank with a bounded
//! exchange window.
//!
//! [`CollectiveEngine`] wraps any [`Collective`] and moves it onto a
//! worker thread, turning the trait's non-blocking `start_reduce` /
//! `poll_reduce` / `wait_reduce` / `drain` face into a genuinely
//! asynchronous one: the trainer hands packed gradient buffers over, runs
//! the next epochs' bootstrap draws and `gan_step`s while the worker
//! drives the rings, and collects averaged buffers in FIFO order up to
//! `window` epochs later (bounded-staleness gradients — the Async-RED
//! style relaxation the staged pipeline of `coordinator::pipeline` is
//! built on; see DESIGN.md §Collective engine).
//!
//! Timeline versus the paper's blocking loop (window 1):
//!
//! ```text
//! blocking:  [draw|step|-- reduce --|opt] [draw|step|-- reduce --|opt]
//! overlap:   [draw|step|opt] [draw|step|opt] [draw|step|opt]
//!              reduce(e) ---^ runs under draw/step of e+1 ^--- reduce(e+1)
//! ```
//!
//! A window of k allows k reduces to ride the worker's FIFO queue at
//! once; `start_reduce` rejects submissions beyond the window, and
//! [`Collective::drain`] settles everything outstanding at a barrier
//! (quiescence — the run-checkpoint cadence relies on it).
//!
//! The engine still implements the blocking [`Collective::epoch_reduce`]
//! (submit + wait, refused while other exchanges are in flight), so it is
//! a drop-in replacement anywhere a collective is expected.

use std::thread::JoinHandle;

use super::{Collective, CommStats, ParkedReduce};
use crate::comm::channel::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use crate::comm::{BufferPool, MembershipView};
use crate::util::error::{Error, Result};

enum Job {
    Reduce { epoch: u64, buf: Vec<f32> },
    /// Elastic re-ring: rebuild the inner collective's neighbour schedule
    /// on the worker thread (the collective lives there). Acked through
    /// the same FIFO done channel; only valid with nothing outstanding.
    Reconfigure(MembershipView),
}

struct Done {
    buf: Vec<f32>,
    stats: CommStats,
}

/// Comm-thread wrapper around an inner collective.
pub struct CollectiveEngine {
    job_tx: Option<Sender<Job>>,
    done_rx: Receiver<Result<Done>>,
    worker: Option<JoinHandle<()>>,
    /// Jobs submitted to the worker and not yet received back. Results
    /// received by `poll_reduce` move into `parked` (still uncollected).
    submitted: usize,
    /// Maximum exchanges in flight (submitted + parked) at once.
    window: usize,
    inner_name: &'static str,
    /// The inner collective's buffer pool, captured before the collective
    /// moves to the worker. Lets the blocking facade loan pooled buffers
    /// instead of `to_vec()`, and lets `drain()` trim at quiescence.
    pool: Option<BufferPool>,
    parked: ParkedReduce,
}

impl CollectiveEngine {
    /// Move `inner` onto a dedicated worker thread with a single-slot
    /// window (the classic one-epoch-stale overlap).
    pub fn spawn(inner: Box<dyn Collective>) -> Result<CollectiveEngine> {
        Self::spawn_windowed(inner, 1)
    }

    /// Move `inner` onto a dedicated worker thread accepting up to
    /// `window` in-flight exchanges (>= 1). The worker processes jobs
    /// FIFO, so results come back in submission order.
    pub fn spawn_windowed(
        mut inner: Box<dyn Collective>,
        window: usize,
    ) -> Result<CollectiveEngine> {
        let inner_name = inner.name();
        let pool = inner.buffer_pool();
        let (job_tx, job_rx) = channel::<Job>();
        let (done_tx, done_rx) = channel::<Result<Done>>();
        let worker = std::thread::Builder::new()
            .name(format!("comm-{inner_name}"))
            .spawn(move || {
                while let Ok(job) = job_rx.recv() {
                    let result = match job {
                        Job::Reduce { epoch, mut buf } => inner
                            .epoch_reduce(epoch, &mut buf)
                            .map(|stats| Done { buf, stats }),
                        Job::Reconfigure(view) => {
                            inner.set_membership(&view).map(|()| Done {
                                buf: Vec::new(),
                                stats: CommStats::default(),
                            })
                        }
                    };
                    if done_tx.send(result).is_err() {
                        return; // engine dropped
                    }
                }
            })
            .map_err(Error::Io)?;
        Ok(CollectiveEngine {
            job_tx: Some(job_tx),
            done_rx,
            worker: Some(worker),
            submitted: 0,
            window: window.max(1),
            inner_name,
            pool,
            parked: ParkedReduce::default(),
        })
    }

    /// The engine's window depth.
    pub fn window(&self) -> usize {
        self.window
    }

    fn outstanding(&self) -> usize {
        self.submitted + self.parked.len()
    }

    fn recv_one(&mut self) -> Result<(Vec<f32>, CommStats)> {
        let done = self
            .done_rx
            .recv()
            .map_err(|_| Error::comm("collective engine worker died"))?;
        self.submitted -= 1;
        let d = done?;
        Ok((d.buf, d.stats))
    }
}

impl Collective for CollectiveEngine {
    fn epoch_reduce(&mut self, epoch: u64, grads: &mut [f32]) -> Result<CommStats> {
        // Blocking facade: submit and wait. Only valid with an empty
        // window — with exchanges in flight the FIFO wait would hand back
        // an *older* epoch's result.
        if self.outstanding() > 0 {
            return Err(Error::comm(
                "epoch_reduce called with exchanges still in flight — drain() first",
            ));
        }
        // Loan a pooled buffer for the round trip instead of `to_vec()`:
        // checkout here, recycle once the averaged copy is applied, so the
        // blocking path is as allocation-free as the windowed one.
        let mut pool_stats = CommStats::default();
        let buf = match &self.pool {
            Some(pool) => pool.checkout_filled(grads, &mut pool_stats),
            None => grads.to_vec(),
        };
        self.start_reduce(epoch, buf)?;
        let (buf, mut stats) = self.wait_reduce()?;
        grads.copy_from_slice(&buf);
        if let Some(pool) = &self.pool {
            pool.recycle(buf, &mut pool_stats);
        }
        stats.allocs += pool_stats.allocs;
        stats.pool_hits += pool_stats.pool_hits;
        stats.bytes_recycled += pool_stats.bytes_recycled;
        Ok(stats)
    }

    fn name(&self) -> &'static str {
        self.inner_name
    }

    fn parked(&mut self) -> &mut ParkedReduce {
        &mut self.parked
    }

    fn start_reduce(&mut self, epoch: u64, buf: Vec<f32>) -> Result<()> {
        if self.outstanding() >= self.window {
            return Err(Error::window_full(format!(
                "start_reduce at depth {} (window {})",
                self.outstanding(),
                self.window
            )));
        }
        self.job_tx
            .as_ref()
            .expect("engine job channel present until drop")
            .send(Job::Reduce { epoch, buf })
            .map_err(|_| Error::comm("collective engine worker died"))?;
        self.submitted += 1;
        Ok(())
    }

    fn poll_reduce(&mut self) -> Result<bool> {
        if self.parked.ready() {
            return Ok(true);
        }
        if self.submitted == 0 {
            return Ok(false);
        }
        match self.done_rx.try_recv() {
            Ok(done) => {
                self.submitted -= 1;
                let d = done?;
                self.parked.park(d.buf, d.stats);
                Ok(true)
            }
            Err(TryRecvError::Empty) => Ok(false),
            Err(TryRecvError::Disconnected) => {
                Err(Error::comm("collective engine worker died"))
            }
        }
    }

    fn wait_reduce(&mut self) -> Result<(Vec<f32>, CommStats)> {
        // Parked results were received earliest, so they stay FIFO ahead
        // of anything still on the worker.
        if self.parked.ready() {
            return self.parked.take();
        }
        if self.submitted == 0 {
            return Err(Error::comm("wait_reduce called with no reduce in flight"));
        }
        self.recv_one()
    }

    fn in_flight(&mut self) -> usize {
        self.outstanding()
    }

    fn drain(&mut self) -> Result<Vec<(Vec<f32>, CommStats)>> {
        let mut out = Vec::new();
        while self.parked.ready() {
            out.push(self.parked.take()?);
        }
        while self.submitted > 0 {
            out.push(self.recv_one()?);
        }
        // Quiescence is the natural trim point: nothing is in flight, so
        // the pool's high-water marks reflect a full window's demand.
        if let Some(pool) = &self.pool {
            pool.trim();
        }
        Ok(out)
    }

    fn set_membership(&mut self, view: &MembershipView) -> Result<()> {
        // The inner collective lives on the worker, so the re-ring runs
        // there, acked synchronously through the done channel. Quiescence
        // is a precondition (the transition barrier drains first), which
        // also guarantees the ack is the next message on the channel.
        if self.outstanding() > 0 {
            return Err(Error::comm(
                "set_membership with exchanges still in flight — drain() first",
            ));
        }
        self.job_tx
            .as_ref()
            .expect("engine job channel present until drop")
            .send(Job::Reconfigure(view.clone()))
            .map_err(|_| Error::comm("collective engine worker died"))?;
        // Deliberately not counted in `submitted`: the ack is consumed
        // right here, so reduce accounting never sees it.
        let ack = self
            .done_rx
            .recv()
            .map_err(|_| Error::comm("collective engine worker died"))?;
        ack.map(|_| ())
    }

    fn buffer_pool(&self) -> Option<BufferPool> {
        self.pool.clone()
    }
}

impl Drop for CollectiveEngine {
    fn drop(&mut self) {
        // Hang up the job channel so the worker's recv() errors and it
        // exits. If reduces are still in flight, give them a bounded grace
        // period: a worker stuck in a ring whose peers died must not hang
        // process shutdown — leak the thread instead (it is detached and
        // holds no locks the trainer needs).
        drop(self.job_tx.take());
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
        let mut finished = true;
        while self.submitted > 0 {
            let left = deadline.saturating_duration_since(std::time::Instant::now());
            match self.done_rx.recv_timeout(left) {
                Ok(_) => self.submitted -= 1,
                Err(RecvTimeoutError::Timeout) => {
                    finished = false;
                    break;
                }
                // Worker already exited; nothing more will arrive.
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        if finished {
            if let Some(w) = self.worker.take() {
                let _ = w.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::grouped::GroupedArar;
    use crate::collective::ring::ConvArar;
    use crate::collective::NullCollective;
    use crate::comm::{LinkModel, LocalNetwork, Topology};
    use crate::fault::FaultPlan;
    use std::sync::Arc;

    #[test]
    fn engine_runs_null_collective_asynchronously() {
        let mut e = CollectiveEngine::spawn(Box::new(NullCollective::default())).unwrap();
        assert_eq!(e.name(), "ensemble");
        e.start_reduce(0, vec![1.0, 2.0, 3.0]).unwrap();
        let (buf, stats) = e.wait_reduce().unwrap();
        assert_eq!(buf, vec![1.0, 2.0, 3.0]);
        assert_eq!(stats.contributions, 1);
    }

    #[test]
    fn engine_rejects_window_overflow_and_empty_wait() {
        let mut e = CollectiveEngine::spawn(Box::new(NullCollective::default())).unwrap();
        assert_eq!(e.window(), 1);
        assert!(e.wait_reduce().is_err());
        e.start_reduce(0, vec![0.0]).unwrap();
        // Overflow is typed backpressure, not a generic comm fault.
        let err = e.start_reduce(1, vec![0.0]).unwrap_err();
        assert!(err.is_window_full(), "got {err}");
        e.wait_reduce().unwrap();
        // After the wait the slot is free again.
        e.start_reduce(1, vec![0.0]).unwrap();
        e.wait_reduce().unwrap();
    }

    #[test]
    fn windowed_engine_keeps_k_in_flight_and_returns_fifo() {
        let mut e =
            CollectiveEngine::spawn_windowed(Box::new(NullCollective::default()), 3).unwrap();
        assert_eq!(e.window(), 3);
        e.start_reduce(0, vec![0.5]).unwrap();
        e.start_reduce(1, vec![1.5]).unwrap();
        e.start_reduce(2, vec![2.5]).unwrap();
        assert_eq!(e.in_flight(), 3);
        // Fourth submission exceeds the window.
        assert!(e.start_reduce(3, vec![3.5]).unwrap_err().is_window_full());
        // Results come back in submission order.
        for want in [0.5f32, 1.5, 2.5] {
            let (buf, _) = e.wait_reduce().unwrap();
            assert_eq!(buf, vec![want]);
        }
        assert_eq!(e.in_flight(), 0);
    }

    #[test]
    fn drain_settles_every_in_flight_exchange() {
        let mut e =
            CollectiveEngine::spawn_windowed(Box::new(NullCollective::default()), 4).unwrap();
        for k in 0..4u64 {
            e.start_reduce(k, vec![k as f32]).unwrap();
        }
        // Mix in a poll so part of the window sits parked before the
        // drain — order must survive.
        let t0 = std::time::Instant::now();
        while !e.poll_reduce().unwrap() {
            assert!(t0.elapsed().as_secs() < 5, "worker never completed");
            std::thread::yield_now();
        }
        let settled = e.drain().unwrap();
        assert_eq!(settled.len(), 4);
        for (k, (buf, _)) in settled.iter().enumerate() {
            assert_eq!(buf.as_slice(), [k as f32]);
        }
        assert_eq!(e.in_flight(), 0);
        assert!(e.drain().unwrap().is_empty());
        // The window is fully available again.
        e.start_reduce(9, vec![9.0]).unwrap();
        e.wait_reduce().unwrap();
    }

    #[test]
    fn blocking_facade_refused_while_window_occupied() {
        let mut e =
            CollectiveEngine::spawn_windowed(Box::new(NullCollective::default()), 2).unwrap();
        e.start_reduce(0, vec![1.0]).unwrap();
        let mut grads = vec![2.0];
        assert!(e.epoch_reduce(1, &mut grads).is_err());
        e.drain().unwrap();
        e.epoch_reduce(1, &mut grads).unwrap();
    }

    #[test]
    fn poll_parks_result_until_wait() {
        let mut e = CollectiveEngine::spawn(Box::new(NullCollective::default())).unwrap();
        assert!(!e.poll_reduce().unwrap()); // nothing in flight
        e.start_reduce(3, vec![7.0]).unwrap();
        // Spin until the worker finishes; poll must never block.
        let t0 = std::time::Instant::now();
        while !e.poll_reduce().unwrap() {
            assert!(t0.elapsed().as_secs() < 5, "worker never completed");
            std::thread::yield_now();
        }
        assert!(e.poll_reduce().unwrap()); // still ready
        let (buf, _) = e.wait_reduce().unwrap();
        assert_eq!(buf, vec![7.0]);
    }

    #[test]
    fn engines_overlap_a_real_ring_across_ranks() {
        // Four ranks each wrap a ConvArar in an engine, start an epoch's
        // reduce, "compute" (sleep), then collect: the averaged result
        // must match the blocking ring's.
        let n = 4;
        let topo = Topology::new(n, 4);
        let eps = LocalNetwork::build(&topo, LinkModel::zero());
        let handles: Vec<_> = eps
            .into_iter()
            .map(|ep| {
                let v = ep.rank as f32;
                std::thread::spawn(move || {
                    let mut e = CollectiveEngine::spawn(Box::new(ConvArar::new(ep))).unwrap();
                    let mut applied = Vec::new();
                    for epoch in 0..3u64 {
                        e.start_reduce(epoch, vec![v + epoch as f32; 8]).unwrap();
                        std::thread::sleep(std::time::Duration::from_millis(2));
                        let (buf, stats) = e.wait_reduce().unwrap();
                        assert_eq!(stats.contributions, n);
                        applied.push(buf[0]);
                    }
                    applied
                })
            })
            .collect();
        for h in handles {
            let applied = h.join().unwrap();
            // mean of {0..3} = 1.5, shifted by the epoch index.
            assert_eq!(applied.len(), 3);
            for (e, v) in applied.iter().enumerate() {
                assert!((v - (1.5 + e as f32)).abs() < 1e-5, "epoch {e}: {v}");
            }
        }
    }

    #[test]
    fn windowed_engines_pipeline_a_real_ring_two_deep() {
        // A 2-deep window over a real ring: every rank keeps two epochs in
        // flight and collects FIFO; results must equal the blocking ring's
        // per-epoch averages.
        let n = 3;
        let epochs = 6u64;
        let topo = Topology::new(n, 4);
        let eps = LocalNetwork::build(&topo, LinkModel::zero());
        let handles: Vec<_> = eps
            .into_iter()
            .map(|ep| {
                let v = ep.rank as f32;
                std::thread::spawn(move || {
                    let mut e =
                        CollectiveEngine::spawn_windowed(Box::new(ConvArar::new(ep)), 2).unwrap();
                    let mut applied = Vec::new();
                    for epoch in 0..epochs {
                        while e.in_flight() >= 2 {
                            let (buf, _) = e.wait_reduce().unwrap();
                            applied.push(buf[0]);
                        }
                        e.start_reduce(epoch, vec![v + epoch as f32; 4]).unwrap();
                    }
                    for (buf, _) in e.drain().unwrap() {
                        applied.push(buf[0]);
                    }
                    applied
                })
            })
            .collect();
        for h in handles {
            let applied = h.join().unwrap();
            assert_eq!(applied.len(), epochs as usize);
            // mean of {0, 1, 2} = 1.0, shifted by the epoch index, FIFO.
            for (e, v) in applied.iter().enumerate() {
                assert!((v - (1.0 + e as f32)).abs() < 1e-5, "epoch {e}: {v}");
            }
        }
    }

    #[test]
    fn blocking_facade_matches_direct_reduce() {
        let n = 3;
        let topo = Topology::new(n, 4);
        let eps = LocalNetwork::build(&topo, LinkModel::zero());
        let handles: Vec<_> = eps
            .into_iter()
            .map(|ep| {
                let v = (ep.rank * 2) as f32;
                std::thread::spawn(move || {
                    let mut e = CollectiveEngine::spawn(Box::new(ConvArar::new(ep))).unwrap();
                    let mut grads = vec![v; 5];
                    e.epoch_reduce(0, &mut grads).unwrap();
                    grads
                })
            })
            .collect();
        for h in handles {
            let g = h.join().unwrap();
            for v in g {
                assert!((v - 2.0).abs() < 1e-5); // mean of 0, 2, 4
            }
        }
    }

    // Fill a k-deep window over a fault-delayed network, then drain:
    // results must settle in FIFO submission order and leave nothing in
    // flight. Exercises drain() while exchanges are genuinely mid-ring
    // (rank 0's sends carry injected per-epoch jitter).
    fn drain_under_injected_delays(grouped: bool, k: usize) {
        let n = 3;
        let topo = Topology::new(n, 4);
        let plan = Arc::new(FaultPlan::new(17).with_delay(0, 3.0, 0.5));
        let eps = LocalNetwork::build_with_faults(&topo, LinkModel::zero(), Some(plan));
        let handles: Vec<_> = eps
            .into_iter()
            .map(|ep| {
                let v = ep.rank as f32;
                std::thread::spawn(move || {
                    let inner: Box<dyn Collective> = if grouped {
                        Box::new(GroupedArar::new(ep, 1))
                    } else {
                        Box::new(ConvArar::new(ep))
                    };
                    let mut e = CollectiveEngine::spawn_windowed(inner, k).unwrap();
                    for epoch in 0..k as u64 {
                        e.start_reduce(epoch, vec![v + epoch as f32; 4]).unwrap();
                    }
                    assert_eq!(e.in_flight(), k);
                    let settled = e.drain().unwrap();
                    assert_eq!(e.in_flight(), 0);
                    settled.into_iter().map(|(buf, _)| buf[0]).collect::<Vec<_>>()
                })
            })
            .collect();
        for h in handles {
            let applied = h.join().unwrap();
            assert_eq!(applied.len(), k);
            // mean of {0, 1, 2} = 1.0, shifted by the epoch index, FIFO.
            for (e, v) in applied.iter().enumerate() {
                assert!((v - (1.0 + e as f32)).abs() < 1e-5, "epoch {e}: {v}");
            }
        }
    }

    #[test]
    fn ring_drain_settles_fifo_under_injected_delays() {
        for k in [1, 2, 4] {
            drain_under_injected_delays(false, k);
        }
    }

    #[test]
    fn grouped_drain_settles_fifo_under_injected_delays() {
        for k in [1, 2, 4] {
            drain_under_injected_delays(true, k);
        }
    }

    #[test]
    fn set_membership_requires_quiescence_and_acks() {
        let mut e =
            CollectiveEngine::spawn_windowed(Box::new(NullCollective::default()), 2).unwrap();
        e.start_reduce(0, vec![1.0]).unwrap();
        // Mid-flight re-ring must be refused (drain() is the barrier).
        let view = MembershipView::full(4);
        assert!(e.set_membership(&view).is_err());
        e.drain().unwrap();
        e.set_membership(&view).unwrap();
        // The ack must not disturb reduce accounting: the window is still
        // fully usable and FIFO afterwards.
        e.start_reduce(1, vec![2.0]).unwrap();
        e.start_reduce(2, vec![3.0]).unwrap();
        let (buf, _) = e.wait_reduce().unwrap();
        assert_eq!(buf, vec![2.0]);
        let (buf, _) = e.wait_reduce().unwrap();
        assert_eq!(buf, vec![3.0]);
        assert_eq!(e.in_flight(), 0);
    }

    #[test]
    fn engines_re_ring_a_real_ring_between_epochs() {
        // 4 engine-wrapped ConvArars: epoch 0 over the full ring, then a
        // drain-gated re-ring to {0,1,3}, then epoch 1 over the survivors.
        let n = 4;
        let topo = Topology::new(n, 4);
        let eps = LocalNetwork::build(&topo, LinkModel::zero());
        let view = MembershipView::new(1, vec![0, 1, 3], n);
        let handles: Vec<_> = eps
            .into_iter()
            .map(|ep| {
                let view = view.clone();
                let rank = ep.rank;
                let v = rank as f32;
                std::thread::spawn(move || {
                    let mut e = CollectiveEngine::spawn(Box::new(ConvArar::new(ep))).unwrap();
                    e.start_reduce(0, vec![v; 4]).unwrap();
                    let (full, _) = e.wait_reduce().unwrap();
                    e.drain().unwrap();
                    e.set_membership(&view).unwrap();
                    if !view.is_live(rank) {
                        return (rank, full[0], None);
                    }
                    e.start_reduce(1, vec![v; 4]).unwrap();
                    let (live, _) = e.wait_reduce().unwrap();
                    (rank, full[0], Some(live[0]))
                })
            })
            .collect();
        for h in handles {
            let (rank, full, live) = h.join().unwrap();
            assert!((full - 1.5).abs() < 1e-5, "rank {rank} epoch0: {full}");
            if let Some(live) = live {
                let want = (0.0 + 1.0 + 3.0) / 3.0;
                assert!((live - want).abs() < 1e-5, "rank {rank} epoch1: {live}");
            } else {
                assert_eq!(rank, 2);
            }
        }
    }

    #[test]
    fn drop_with_job_in_flight_shuts_down_cleanly() {
        let mut e = CollectiveEngine::spawn(Box::new(NullCollective::default())).unwrap();
        e.start_reduce(0, vec![0.0; 16]).unwrap();
        drop(e); // must join without hanging or panicking
    }
}
