//! The overlap engine: a dedicated comm thread per rank.
//!
//! [`CollectiveEngine`] wraps any [`Collective`] and moves it onto a
//! worker thread, turning the trait's non-blocking `start_reduce` /
//! `poll_reduce` / `wait_reduce` face into a genuinely asynchronous one:
//! the trainer hands the packed gradient buffer over, runs the next
//! epoch's bootstrap draw and `gan_step` while the worker drives the ring,
//! and collects the averaged buffer one epoch later (one-epoch-stale
//! gradients — the Async-RED-style relaxation the overlap mode of
//! `coordinator::rank` is built on; see DESIGN.md §Collective engine).
//!
//! Timeline versus the paper's blocking loop:
//!
//! ```text
//! blocking:  [draw|step|-- reduce --|opt] [draw|step|-- reduce --|opt]
//! overlap:   [draw|step|opt] [draw|step|opt] [draw|step|opt]
//!              reduce(e) ---^ runs under draw/step of e+1 ^--- reduce(e+1)
//! ```
//!
//! The engine still implements the blocking [`Collective::epoch_reduce`]
//! (submit + wait), so it is a drop-in replacement anywhere a collective
//! is expected. Exactly one reduce may be in flight at a time, matching
//! the fallback [`ParkedReduce`] contract.

use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::thread::JoinHandle;

use super::{Collective, CommStats, ParkedReduce};
use crate::util::error::{Error, Result};

struct Job {
    epoch: u64,
    buf: Vec<f32>,
}

struct Done {
    buf: Vec<f32>,
    stats: CommStats,
}

/// Comm-thread wrapper around an inner collective.
pub struct CollectiveEngine {
    job_tx: Option<Sender<Job>>,
    done_rx: Receiver<Result<Done>>,
    worker: Option<JoinHandle<()>>,
    in_flight: bool,
    inner_name: &'static str,
    parked: ParkedReduce,
}

impl CollectiveEngine {
    /// Move `inner` onto a dedicated worker thread.
    pub fn spawn(mut inner: Box<dyn Collective>) -> Result<CollectiveEngine> {
        let inner_name = inner.name();
        let (job_tx, job_rx) = channel::<Job>();
        let (done_tx, done_rx) = channel::<Result<Done>>();
        let worker = std::thread::Builder::new()
            .name(format!("comm-{inner_name}"))
            .spawn(move || {
                while let Ok(Job { epoch, mut buf }) = job_rx.recv() {
                    let result = inner
                        .epoch_reduce(epoch, &mut buf)
                        .map(|stats| Done { buf, stats });
                    if done_tx.send(result).is_err() {
                        return; // engine dropped
                    }
                }
            })
            .map_err(Error::Io)?;
        Ok(CollectiveEngine {
            job_tx: Some(job_tx),
            done_rx,
            worker: Some(worker),
            in_flight: false,
            inner_name,
            parked: ParkedReduce::default(),
        })
    }

    fn collect(&mut self, done: Result<Done>) -> Result<(Vec<f32>, CommStats)> {
        self.in_flight = false;
        let d = done?;
        Ok((d.buf, d.stats))
    }
}

impl Collective for CollectiveEngine {
    fn epoch_reduce(&mut self, epoch: u64, grads: &mut [f32]) -> Result<CommStats> {
        // Blocking facade: submit and wait. Keeps ordering with any prior
        // overlap-mode traffic because the worker processes jobs FIFO.
        self.start_reduce(epoch, grads.to_vec())?;
        let (buf, stats) = self.wait_reduce()?;
        grads.copy_from_slice(&buf);
        Ok(stats)
    }

    fn name(&self) -> &'static str {
        self.inner_name
    }

    fn parked(&mut self) -> &mut ParkedReduce {
        &mut self.parked
    }

    fn start_reduce(&mut self, epoch: u64, buf: Vec<f32>) -> Result<()> {
        if self.in_flight || self.parked.ready() {
            return Err(Error::comm(
                "start_reduce called with a reduce still in flight",
            ));
        }
        self.job_tx
            .as_ref()
            .expect("engine job channel present until drop")
            .send(Job { epoch, buf })
            .map_err(|_| Error::comm("collective engine worker died"))?;
        self.in_flight = true;
        Ok(())
    }

    fn poll_reduce(&mut self) -> Result<bool> {
        if self.parked.ready() {
            return Ok(true);
        }
        if !self.in_flight {
            return Ok(false);
        }
        match self.done_rx.try_recv() {
            Ok(done) => {
                let (buf, stats) = self.collect(done)?;
                self.parked.park(buf, stats)?;
                Ok(true)
            }
            Err(TryRecvError::Empty) => Ok(false),
            Err(TryRecvError::Disconnected) => {
                Err(Error::comm("collective engine worker died"))
            }
        }
    }

    fn wait_reduce(&mut self) -> Result<(Vec<f32>, CommStats)> {
        if self.parked.ready() {
            return self.parked.take();
        }
        if !self.in_flight {
            return Err(Error::comm("wait_reduce called with no reduce in flight"));
        }
        let done = self
            .done_rx
            .recv()
            .map_err(|_| Error::comm("collective engine worker died"))?;
        self.collect(done)
    }
}

impl Drop for CollectiveEngine {
    fn drop(&mut self) {
        // Hang up the job channel so the worker's recv() errors and it
        // exits. If a reduce is still in flight, give it a bounded grace
        // period: a worker stuck in a ring whose peers died must not hang
        // process shutdown — leak the thread instead (it is detached and
        // holds no locks the trainer needs).
        drop(self.job_tx.take());
        let finished = !self.in_flight
            || !matches!(
                self.done_rx.recv_timeout(std::time::Duration::from_secs(30)),
                Err(std::sync::mpsc::RecvTimeoutError::Timeout)
            );
        if finished {
            if let Some(w) = self.worker.take() {
                let _ = w.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::ring::ConvArar;
    use crate::collective::NullCollective;
    use crate::comm::{LinkModel, LocalNetwork, Topology};

    #[test]
    fn engine_runs_null_collective_asynchronously() {
        let mut e = CollectiveEngine::spawn(Box::new(NullCollective::default())).unwrap();
        assert_eq!(e.name(), "ensemble");
        e.start_reduce(0, vec![1.0, 2.0, 3.0]).unwrap();
        let (buf, stats) = e.wait_reduce().unwrap();
        assert_eq!(buf, vec![1.0, 2.0, 3.0]);
        assert_eq!(stats.contributions, 1);
    }

    #[test]
    fn engine_rejects_double_start_and_empty_wait() {
        let mut e = CollectiveEngine::spawn(Box::new(NullCollective::default())).unwrap();
        assert!(e.wait_reduce().is_err());
        e.start_reduce(0, vec![0.0]).unwrap();
        assert!(e.start_reduce(1, vec![0.0]).is_err());
        e.wait_reduce().unwrap();
        // After the wait the slot is free again.
        e.start_reduce(1, vec![0.0]).unwrap();
        e.wait_reduce().unwrap();
    }

    #[test]
    fn poll_parks_result_until_wait() {
        let mut e = CollectiveEngine::spawn(Box::new(NullCollective::default())).unwrap();
        assert!(!e.poll_reduce().unwrap()); // nothing in flight
        e.start_reduce(3, vec![7.0]).unwrap();
        // Spin until the worker finishes; poll must never block.
        let t0 = std::time::Instant::now();
        while !e.poll_reduce().unwrap() {
            assert!(t0.elapsed().as_secs() < 5, "worker never completed");
            std::thread::yield_now();
        }
        assert!(e.poll_reduce().unwrap()); // still ready
        let (buf, _) = e.wait_reduce().unwrap();
        assert_eq!(buf, vec![7.0]);
    }

    #[test]
    fn engines_overlap_a_real_ring_across_ranks() {
        // Four ranks each wrap a ConvArar in an engine, start an epoch's
        // reduce, "compute" (sleep), then collect: the averaged result
        // must match the blocking ring's.
        let n = 4;
        let topo = Topology::new(n, 4);
        let eps = LocalNetwork::build(&topo, LinkModel::zero());
        let handles: Vec<_> = eps
            .into_iter()
            .map(|ep| {
                let v = ep.rank as f32;
                std::thread::spawn(move || {
                    let mut e = CollectiveEngine::spawn(Box::new(ConvArar::new(ep))).unwrap();
                    let mut applied = Vec::new();
                    for epoch in 0..3u64 {
                        e.start_reduce(epoch, vec![v + epoch as f32; 8]).unwrap();
                        std::thread::sleep(std::time::Duration::from_millis(2));
                        let (buf, stats) = e.wait_reduce().unwrap();
                        assert_eq!(stats.contributions, n);
                        applied.push(buf[0]);
                    }
                    applied
                })
            })
            .collect();
        for h in handles {
            let applied = h.join().unwrap();
            // mean of {0..3} = 1.5, shifted by the epoch index.
            assert_eq!(applied.len(), 3);
            for (e, v) in applied.iter().enumerate() {
                assert!((v - (1.5 + e as f32)).abs() < 1e-5, "epoch {e}: {v}");
            }
        }
    }

    #[test]
    fn blocking_facade_matches_direct_reduce() {
        let n = 3;
        let topo = Topology::new(n, 4);
        let eps = LocalNetwork::build(&topo, LinkModel::zero());
        let handles: Vec<_> = eps
            .into_iter()
            .map(|ep| {
                let v = (ep.rank * 2) as f32;
                std::thread::spawn(move || {
                    let mut e = CollectiveEngine::spawn(Box::new(ConvArar::new(ep))).unwrap();
                    let mut grads = vec![v; 5];
                    e.epoch_reduce(0, &mut grads).unwrap();
                    grads
                })
            })
            .collect();
        for h in handles {
            let g = h.join().unwrap();
            for v in g {
                assert!((v - 2.0).abs() < 1e-5); // mean of 0, 2, 4
            }
        }
    }

    #[test]
    fn drop_with_job_in_flight_shuts_down_cleanly() {
        let mut e = CollectiveEngine::spawn(Box::new(NullCollective::default())).unwrap();
        e.start_reduce(0, vec![0.0; 16]).unwrap();
        drop(e); // must join without hanging or panicking
    }
}
