//! Synchronous all-reduce — the Horovod baseline of the paper's
//! convergence study (Fig 13, Table IV).
//!
//! Horovod performs a blocking, globally synchronous all-reduce every
//! training step: all ranks enter a barrier, exchange gradients, and no
//! rank proceeds until the collective completes. We reproduce that with a
//! `std::sync::Barrier` on both sides of a global ring pass — the barrier
//! is what distinguishes this from [`super::ring::ConvArar`], whose sends
//! are asynchronous and whose ranks drift apart freely.

use std::sync::{Arc, Barrier};
use std::time::Instant;

use super::ring::ring_pass;
use super::{Collective, CommStats, ParkedReduce};
use crate::comm::{BufferPool, Endpoint, MembershipView};
use crate::util::error::{Error, Result};

/// Barrier + global ring, every epoch.
pub struct SyncAllReduce {
    ep: Endpoint,
    members: Vec<usize>,
    barrier: Arc<Barrier>,
    pool: BufferPool,
    parked: ParkedReduce,
}

impl SyncAllReduce {
    pub fn new(ep: Endpoint, barrier: Arc<Barrier>) -> SyncAllReduce {
        let members = ep.topology().all_ranks();
        SyncAllReduce {
            ep,
            members,
            barrier,
            pool: BufferPool::new(),
            parked: ParkedReduce::default(),
        }
    }

    /// Share a run-wide buffer pool (see [`super::build_with_policy`]).
    pub fn with_pool(mut self, pool: BufferPool) -> SyncAllReduce {
        self.pool = pool;
        self
    }
}

impl Collective for SyncAllReduce {
    fn epoch_reduce(&mut self, epoch: u64, grads: &mut [f32]) -> Result<CommStats> {
        // Entry barrier: the slowest rank gates everyone (the synchronous
        // cost the asynchronous modes avoid).
        let t0 = Instant::now();
        self.barrier.wait();
        let mut stats = ring_pass(&self.ep, &self.members, epoch, grads, &self.pool)?;
        // Exit barrier: no rank starts the next step until the
        // collective is globally complete.
        self.barrier.wait();
        stats.wait_s += t0.elapsed().as_secs_f64() - stats.wait_s;
        Ok(stats)
    }

    fn name(&self) -> &'static str {
        "horovod"
    }

    fn parked(&mut self) -> &mut ParkedReduce {
        &mut self.parked
    }

    fn set_membership(&mut self, view: &MembershipView) -> Result<()> {
        // The shared barrier is sized for all ranks at build time; a
        // membership change would deadlock the survivors. Config
        // validation refuses elastic knobs under Horovod — this backstops
        // it for direct API users.
        if view.len() == view.total() {
            return Ok(());
        }
        Err(Error::comm(
            "horovod baseline cannot re-ring: its barrier is fixed at build time",
        ))
    }

    fn buffer_pool(&self) -> Option<BufferPool> {
        Some(self.pool.clone())
    }
}

#[cfg(test)]
mod tests {
    // Correctness across threads is covered by
    // collective::tests::horovod_matches_conv_arar_result; the barrier
    // semantics (no rank exits before all enter) is what Barrier provides
    // by contract.
}
