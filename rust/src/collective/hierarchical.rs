//! Hierarchical all-reduce baseline — Jia et al. [16], the method the
//! paper contrasts its grouping against.
//!
//! Three synchronous steps per epoch:
//!   1. intra-node reduce: every rank sends its gradients to the node
//!      master (first rank of the node), which accumulates;
//!   2. inter-node: masters run a ring-all-reduce among themselves;
//!   3. intra-node broadcast: masters send the global average back to
//!      their node's ranks.
//!
//! The paper's grouping differs exactly here: no step 3 (no broadcast, no
//! master), and step 2 runs only every `h` epochs.

use std::time::Instant;

use super::ring::ring_pass;
use super::{Collective, CommStats, ParkedReduce};
use crate::comm::{Endpoint, GradMsg};
use crate::tensor::ops;
use crate::util::error::Result;

/// The 3-step hierarchical all-reduce.
pub struct Hierarchical {
    ep: Endpoint,
    node_members: Vec<usize>,
    masters: Vec<usize>,
    my_master: usize,
    is_master: bool,
    scratch: Vec<f32>,
    parked: ParkedReduce,
}

impl Hierarchical {
    pub fn new(ep: Endpoint) -> Hierarchical {
        let topo = ep.topology().clone();
        let rank = ep.rank;
        let node_members = topo.inner_group(rank);
        let my_master = node_members[0];
        Hierarchical {
            masters: topo.outer_group(),
            node_members,
            my_master,
            is_master: topo.is_outer_member(rank),
            scratch: Vec::new(),
            parked: ParkedReduce::default(),
            ep,
        }
    }
}

impl Collective for Hierarchical {
    fn epoch_reduce(&mut self, epoch: u64, grads: &mut [f32]) -> Result<CommStats> {
        let mut stats = CommStats {
            contributions: 1,
            ..Default::default()
        };
        let n_local = self.node_members.len();
        if self.is_master {
            // Step 1: accumulate the node's gradients.
            for &r in &self.node_members {
                if r == self.ep.rank {
                    continue;
                }
                let t0 = Instant::now();
                let msg = self.ep.recv(r)?;
                stats.wait_s += t0.elapsed().as_secs_f64();
                ops::add_assign(grads, &msg.data);
                stats.contributions += 1;
            }
            // Average within the node before the inter-node ring so the
            // ring averages node-means (same weighting as the paper's
            // inner/outer scheme).
            ops::scale(grads, 1.0 / n_local as f32);
            // Step 2: ring among masters.
            let ring_stats = ring_pass(&self.ep, &self.masters, epoch, grads, &mut self.scratch)?;
            stats.merge(&ring_stats);
            // Step 3: broadcast back into the node.
            for &r in &self.node_members {
                if r == self.ep.rank {
                    continue;
                }
                self.ep
                    .isend(r, GradMsg::new(self.ep.rank, epoch, u32::MAX, grads.to_vec()))?;
                stats.messages += 1;
                stats.bytes_sent += grads.len() * 4;
            }
        } else {
            // Step 1: contribute to the master.
            self.ep.isend(
                self.my_master,
                GradMsg::new(self.ep.rank, epoch, 0, grads.to_vec()),
            )?;
            stats.messages += 1;
            stats.bytes_sent += grads.len() * 4;
            // Step 3: receive the global average.
            let t0 = Instant::now();
            let msg = self.ep.recv(self.my_master)?;
            stats.wait_s += t0.elapsed().as_secs_f64();
            grads.copy_from_slice(&msg.data);
            stats.contributions = self.ep.topology().ranks;
        }
        Ok(stats)
    }

    fn name(&self) -> &'static str {
        "hierarchical"
    }

    fn parked(&mut self) -> &mut ParkedReduce {
        &mut self.parked
    }
}

#[cfg(test)]
mod tests {
    // Cross-thread correctness covered by
    // collective::tests::hierarchical_matches_full_average.
}
