//! Hierarchical all-reduce baseline — Jia et al. [16], the method the
//! paper contrasts its grouping against.
//!
//! Three synchronous steps per epoch:
//!   1. intra-node reduce: every rank sends its gradients to the node
//!      master (first rank of the node), which accumulates;
//!   2. inter-node: masters run a ring-all-reduce among themselves;
//!   3. intra-node broadcast: masters send the global average back to
//!      their node's ranks.
//!
//! The paper's grouping differs exactly here: no step 3 (no broadcast, no
//! master), and step 2 runs only every `h` epochs.
//!
//! The broadcast fans one [`Payload::Shared`] slice out to every node
//! member — a single allocation per epoch instead of one full gradient
//! clone per receiver; contributions travel as pooled buffers the master
//! recycles on receipt.

use std::sync::Arc;
use std::time::Instant;

use super::ring::ring_pass;
use super::{Collective, CommStats, ParkedReduce};
use crate::comm::{BufferPool, Endpoint, GradMsg, Payload};
use crate::tensor::ops;
use crate::util::error::Result;

/// The 3-step hierarchical all-reduce.
pub struct Hierarchical {
    ep: Endpoint,
    node_members: Vec<usize>,
    masters: Vec<usize>,
    my_master: usize,
    is_master: bool,
    pool: BufferPool,
    parked: ParkedReduce,
}

impl Hierarchical {
    pub fn new(ep: Endpoint) -> Hierarchical {
        let topo = ep.topology().clone();
        let rank = ep.rank;
        let node_members = topo.inner_group(rank);
        let my_master = node_members[0];
        Hierarchical {
            masters: topo.outer_group(),
            node_members,
            my_master,
            is_master: topo.is_outer_member(rank),
            pool: BufferPool::new(),
            parked: ParkedReduce::default(),
            ep,
        }
    }

    /// Share a run-wide buffer pool (see [`super::build_with_policy`]).
    /// Sharing matters doubly here: the master/member buffer flows are
    /// asymmetric per rank (members check out contributions the master
    /// recycles) and only balance across the whole node.
    pub fn with_pool(mut self, pool: BufferPool) -> Hierarchical {
        self.pool = pool;
        self
    }
}

impl Collective for Hierarchical {
    fn epoch_reduce(&mut self, epoch: u64, grads: &mut [f32]) -> Result<CommStats> {
        let mut stats = CommStats {
            contributions: 1,
            ..Default::default()
        };
        let n_local = self.node_members.len();
        if self.is_master {
            // Step 1: accumulate the node's gradients, recycling each
            // contribution buffer after it is applied.
            for &r in &self.node_members {
                if r == self.ep.rank {
                    continue;
                }
                let t0 = Instant::now();
                let msg = self.ep.recv(r)?;
                stats.wait_s += t0.elapsed().as_secs_f64();
                ops::add_assign(grads, &msg.data);
                stats.contributions += 1;
                self.pool.recycle_payload(msg.data, &mut stats);
            }
            // Average within the node before the inter-node ring so the
            // ring averages node-means (same weighting as the paper's
            // inner/outer scheme).
            ops::scale(grads, 1.0 / n_local as f32);
            // Step 2: ring among masters.
            let ring_stats = ring_pass(&self.ep, &self.masters, epoch, grads, &self.pool)?;
            stats.merge(&ring_stats);
            // Step 3: broadcast back into the node — one shared slice
            // fanned out to every receiver (each isend clones the Arc,
            // not the gradient). Per-receiver wire accounting stands:
            // each link still carries the full payload.
            if n_local > 1 {
                let shared: Arc<[f32]> = Arc::from(&*grads);
                for &r in &self.node_members {
                    if r == self.ep.rank {
                        continue;
                    }
                    self.ep.isend(
                        r,
                        GradMsg::new(self.ep.rank, epoch, u32::MAX, Payload::Shared(shared.clone())),
                    )?;
                    stats.messages += 1;
                    stats.bytes_sent += grads.len() * 4;
                }
            }
        } else {
            // Step 1: contribute to the master through a pooled buffer.
            let buf = self.pool.checkout_filled(grads, &mut stats);
            self.ep
                .isend(self.my_master, GradMsg::new(self.ep.rank, epoch, 0, buf))?;
            stats.messages += 1;
            stats.bytes_sent += grads.len() * 4;
            // Step 3: receive the global average (a shared payload —
            // recycling is a no-op drop of our Arc clone).
            let t0 = Instant::now();
            let msg = self.ep.recv(self.my_master)?;
            stats.wait_s += t0.elapsed().as_secs_f64();
            grads.copy_from_slice(&msg.data);
            stats.contributions = self.ep.topology().ranks;
            self.pool.recycle_payload(msg.data, &mut stats);
        }
        Ok(stats)
    }

    fn name(&self) -> &'static str {
        "hierarchical"
    }

    fn parked(&mut self) -> &mut ParkedReduce {
        &mut self.parked
    }

    fn buffer_pool(&self) -> Option<BufferPool> {
        Some(self.pool.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{LinkModel, LocalNetwork, Topology};

    // Cross-thread correctness covered by
    // collective::tests::hierarchical_matches_full_average.

    #[test]
    fn broadcast_shares_one_payload_across_receivers() {
        // 1 node of 4 ranks: the master's step-3 broadcast must fan out
        // Shared payloads (one backing allocation), while per-receiver
        // byte accounting still counts each link's full transfer.
        let topo = Topology::new(4, 4);
        let eps = LocalNetwork::build(&topo, LinkModel::zero());
        let pool = BufferPool::new();
        let handles: Vec<_> = eps
            .into_iter()
            .map(|ep| {
                let pool = pool.clone();
                let is_master = ep.rank == 0;
                let v = ep.rank as f32;
                std::thread::spawn(move || {
                    let mut c = Hierarchical::new(ep).with_pool(pool);
                    let mut grads = vec![v; 8];
                    let s = c.epoch_reduce(0, &mut grads).unwrap();
                    (is_master, grads, s)
                })
            })
            .collect();
        for h in handles {
            let (is_master, g, s) = h.join().unwrap();
            assert_eq!(g, vec![1.5; 8]);
            if is_master {
                // 3 broadcast sends, full bytes each — but Shared
                // payloads, so nothing was recycled *from* them and only
                // the 3 member contributions returned to the pool.
                assert_eq!(s.messages, 3);
                assert_eq!(s.bytes_sent, 3 * 8 * 4);
                assert_eq!(s.bytes_recycled, 3 * 8 * 4);
            } else {
                // Members checked out one contribution; the received
                // broadcast is Shared and recycles as a no-op.
                assert_eq!(s.messages, 1);
                assert_eq!(s.allocs + s.pool_hits, 1);
            }
        }
    }
}
