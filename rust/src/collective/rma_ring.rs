//! RMA-based asynchronous ring-all-reduce (the "RMA-ARAR" inner-group
//! communication of Table II, Sec. IV-B3).
//!
//! Same ring schedule as [`super::ring`], but gradients travel through
//! remote-memory windows instead of send/recv rendezvous: each step *puts*
//! the forwarding buffer into the successor's window and *gets* whatever
//! the predecessor has deposited. A slow neighbour never blocks the writer
//! (puts overwrite), and a reader that outruns its neighbour waits with a
//! deadline and then simply proceeds with the contributions it has — the
//! bounded-staleness behaviour that motivated RMA in the paper (pipeline
//! stalls of up to ~1 min/epoch between ranks).

use std::time::{Duration, Instant};

use super::CommStats;
use crate::comm::{GradMsg, RmaRegion, RmaWindow, Topology};
use crate::tensor::ops;
use crate::util::error::Result;

/// Default deadline a reader waits for a neighbour's deposit before
/// proceeding without it. Generous compared to an epoch, tiny compared to
/// a run.
pub const DEFAULT_GET_TIMEOUT: Duration = Duration::from_secs(30);

/// The pair of windows a rank uses on a fixed ring.
pub struct RmaRing {
    pub rank: usize,
    members: Vec<usize>,
    /// Window we write (owned by successor).
    to_next: RmaWindow,
    /// Window we read (written by predecessor).
    from_prev: RmaWindow,
    pub get_timeout: Duration,
}

impl RmaRing {
    /// Wire the windows for `rank` on the ring formed by `members`.
    pub fn new(region: &RmaRegion, members: Vec<usize>, rank: usize) -> Result<RmaRing> {
        let (next, prev) = Topology::ring_in(&members, rank);
        Ok(RmaRing {
            rank,
            to_next: region.window(rank, next)?,
            from_prev: region.window(prev, rank)?,
            members,
            get_timeout: DEFAULT_GET_TIMEOUT,
        })
    }

    /// One full RMA ring pass; averages over the contributions actually
    /// received (own + successful gets).
    pub fn pass(&self, epoch: u64, grads: &mut [f32]) -> Result<CommStats> {
        let n = self.members.len();
        let mut stats = CommStats {
            contributions: 1,
            ..Default::default()
        };
        if n <= 1 {
            return Ok(stats);
        }
        let mut forward = grads.to_vec();
        for step in 0..(n - 1) as u32 {
            self.to_next
                .put(GradMsg::new(self.rank, epoch, step, forward));
            stats.messages += 1;
            stats.bytes_sent += grads.len() * 4;
            let t0 = Instant::now();
            match self.from_prev.get_wait(self.get_timeout) {
                Some((msg, skipped)) => {
                    stats.wait_s += t0.elapsed().as_secs_f64();
                    stats.stale_reads += skipped;
                    debug_assert_eq!(msg.data.len(), grads.len());
                    ops::add_assign(grads, &msg.data);
                    stats.contributions += 1;
                    forward = msg.data;
                }
                None => {
                    // Neighbour never deposited within the deadline:
                    // proceed with what we have (no rendezvous, by design).
                    stats.wait_s += t0.elapsed().as_secs_f64();
                    stats.timeouts += 1;
                    break;
                }
            }
        }
        ops::scale(grads, 1.0 / stats.contributions as f32);
        Ok(stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_rma_ring(members: Vec<usize>, values: Vec<f32>) -> Vec<(Vec<f32>, CommStats)> {
        let n = values.len();
        // One slot per ring step so same-epoch deposits are never
        // superseded even when ranks run at different speeds.
        let region = RmaRegion::with_capacity(n, members.len());
        let rings: Vec<_> = members
            .iter()
            .map(|&r| RmaRing::new(&region, members.clone(), r).unwrap())
            .collect();
        let handles: Vec<_> = rings
            .into_iter()
            .map(|ring| {
                let v = values[ring.rank];
                std::thread::spawn(move || {
                    let mut grads = vec![v; 7];
                    let stats = ring.pass(0, &mut grads).unwrap();
                    (grads, stats)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn full_ring_matches_transport_average() {
        let out = run_rma_ring(vec![0, 1, 2, 3], vec![0.0, 1.0, 2.0, 3.0]);
        for (g, s) in &out {
            for v in g {
                assert!((v - 1.5).abs() < 1e-5, "got {v}");
            }
            assert_eq!(s.contributions, 4);
            assert_eq!(s.timeouts, 0);
        }
    }

    #[test]
    fn timeout_proceeds_with_partial_average() {
        // Rank 1 never participates: rank 0's get times out and it averages
        // only its own gradient.
        let region = RmaRegion::new(2);
        let ring = RmaRing {
            get_timeout: Duration::from_millis(30),
            ..RmaRing::new(&region, vec![0, 1], 0).unwrap()
        };
        let mut grads = vec![8.0f32; 3];
        let s = ring.pass(0, &mut grads).unwrap();
        assert_eq!(s.timeouts, 1);
        assert_eq!(s.contributions, 1);
        assert_eq!(grads, vec![8.0; 3]); // own / 1
    }

    #[test]
    fn writer_never_blocks_on_dead_reader() {
        let region = RmaRegion::new(2);
        let ring = RmaRing {
            get_timeout: Duration::from_millis(10),
            ..RmaRing::new(&region, vec![0, 1], 0).unwrap()
        };
        // Many epochs with no reader on rank 1: put() must never block.
        for e in 0..50 {
            let mut grads = vec![1.0f32; 4];
            let s = ring.pass(e, &mut grads).unwrap();
            assert_eq!(s.timeouts, 1);
        }
    }

    #[test]
    fn stale_reads_detected_when_reader_lags() {
        // Writer deposits 3 epochs before the reader fetches once.
        let region = RmaRegion::new(2);
        let w = region.window(0, 1).unwrap();
        for e in 0..3 {
            w.put(GradMsg::new(0, e, 0, vec![e as f32]));
        }
        let ring = RmaRing::new(&region, vec![0, 1], 1).unwrap();
        let mut grads = vec![10.0f32];
        let s = ring.pass(7, &mut grads).unwrap();
        assert_eq!(s.stale_reads, 2); // two deposits were overwritten
        // got latest (2.0): average of own 10 and 2 = 6
        assert_eq!(grads, vec![6.0]);
    }
}
