//! RMA-based asynchronous ring-all-reduce (the "RMA-ARAR" inner-group
//! communication of Table II, Sec. IV-B3).
//!
//! Same ring schedule as [`super::ring`], but gradients travel through
//! remote-memory windows instead of send/recv rendezvous: each step *puts*
//! the forwarding buffer into the successor's window and *gets* whatever
//! the predecessor has deposited. A slow neighbour never blocks the writer
//! (puts overwrite), and a reader that outruns its neighbour waits with a
//! deadline and then simply proceeds with the contributions it has — the
//! bounded-staleness behaviour that motivated RMA in the paper (pipeline
//! stalls of up to ~1 min/epoch between ranks).
//!
//! [`RmaRing::pass_chunked`] runs the bandwidth-optimal reduce-scatter +
//! all-gather schedule over the same windows. Staleness handling carries
//! over: a timed-out or desynchronized get aborts the remaining schedule
//! and every partition is normalized by the contributions it actually
//! accumulated, so the buffer always holds a (possibly partial) average —
//! never an unscaled sum.
//!
//! Deposited payloads are checked out of the ring's [`BufferPool`] and
//! recycled back when a get consumes them; the per-partition bookkeeping
//! vectors are reused across passes, so healthy steady-state epochs
//! allocate nothing (a timeout strands its deposit in the window — the
//! buffer frees when superseded, outside the pool).

use std::time::{Duration, Instant};

use super::ring::partition_at;
use super::CommStats;
use crate::comm::{BufferPool, GradMsg, Payload, RmaRegion, RmaWindow, Topology};
use crate::tensor::ops;
use crate::util::error::Result;

/// Default deadline a reader waits for a neighbour's deposit before
/// proceeding without it. Generous compared to an epoch, tiny compared to
/// a run.
pub const DEFAULT_GET_TIMEOUT: Duration = Duration::from_secs(30);

/// The pair of windows a rank uses on a fixed ring.
pub struct RmaRing {
    pub rank: usize,
    members: Vec<usize>,
    /// Window we write (owned by successor).
    to_next: RmaWindow,
    /// Window we read (written by predecessor).
    from_prev: RmaWindow,
    pub get_timeout: Duration,
    /// Payload source/sink: puts move owned pooled buffers into the
    /// window, gets recycle them back. A run shares one pool ring-wide
    /// (see [`super::build_with_policy`]).
    pub pool: BufferPool,
    /// Reusable per-partition contribution counts (chunked pass).
    contrib: Vec<usize>,
    /// Reusable per-partition "holds a complete average" flags.
    averaged: Vec<bool>,
}

impl RmaRing {
    /// Wire the windows for `rank` on the ring formed by `members`.
    pub fn new(region: &RmaRegion, members: Vec<usize>, rank: usize) -> Result<RmaRing> {
        let (next, prev) = Topology::ring_in(&members, rank);
        Ok(RmaRing {
            rank,
            to_next: region.window(rank, next)?,
            from_prev: region.window(prev, rank)?,
            members,
            get_timeout: DEFAULT_GET_TIMEOUT,
            pool: BufferPool::new(),
            contrib: Vec::new(),
            averaged: Vec::new(),
        })
    }

    /// One full RMA ring pass; averages over the contributions actually
    /// received (own + successful gets).
    pub fn pass(&mut self, epoch: u64, grads: &mut [f32]) -> Result<CommStats> {
        let n = self.members.len();
        let mut stats = CommStats {
            contributions: 1,
            ..Default::default()
        };
        if n <= 1 {
            return Ok(stats);
        }
        // Stage our own gradient into a pooled buffer — the steady-state
        // pass performs no allocation.
        let mut forward = Some(Payload::from(self.pool.checkout_filled(grads, &mut stats)));
        for step in 0..(n - 1) as u32 {
            let payload = forward.take().expect("forward payload staged");
            self.to_next
                .put(GradMsg::new(self.rank, epoch, step, payload));
            stats.messages += 1;
            stats.bytes_sent += grads.len() * 4;
            let t0 = Instant::now();
            match self.from_prev.get_wait(self.get_timeout) {
                Some((msg, skipped)) => {
                    stats.wait_s += t0.elapsed().as_secs_f64();
                    stats.stale_reads += skipped;
                    debug_assert_eq!(msg.data.len(), grads.len());
                    ops::add_assign(grads, &msg.data);
                    stats.contributions += 1;
                    forward = Some(msg.data);
                }
                None => {
                    // Neighbour never deposited within the deadline:
                    // proceed with what we have (no rendezvous, by
                    // design). Our own deposit is stranded in the window
                    // and frees when superseded.
                    stats.wait_s += t0.elapsed().as_secs_f64();
                    stats.timeouts += 1;
                    break;
                }
            }
        }
        ops::scale(grads, 1.0 / stats.contributions as f32);
        if let Some(p) = forward {
            self.pool.recycle_payload(p, &mut stats);
        }
        Ok(stats)
    }

    /// Chunked reduce-scatter + all-gather over the RMA windows. Healthy
    /// runs produce exact averages with 2·(N-1)/N·|g| bytes per rank; a
    /// timed-out or out-of-order get aborts the remaining schedule and
    /// normalizes every partition by its actual contribution count.
    /// (`_max_msg_elems` is accepted for signature parity with the
    /// transport pass but ignored: window capacity is provisioned per ring
    /// step, so each partition travels as a single deposit.)
    pub fn pass_chunked(
        &mut self,
        epoch: u64,
        grads: &mut [f32],
        _max_msg_elems: usize,
    ) -> Result<CommStats> {
        let n = self.members.len();
        let mut stats = CommStats {
            contributions: 1,
            ..Default::default()
        };
        if n <= 1 {
            return Ok(stats);
        }
        let me = self
            .members
            .iter()
            .position(|&r| r == self.rank)
            .expect("rank not in ring");
        let len = grads.len();
        // Per-partition contribution counts; a partition not yet averaged
        // holds the raw sum of `contrib[p]` ranks' gradients. Both
        // vectors are reused fields: after the first pass, resize is a
        // no-op and the schedule allocates nothing.
        self.contrib.clear();
        self.contrib.resize(n, 1);
        self.averaged.clear();
        self.averaged.resize(n, false);
        let mut step: u32 = 0;
        let mut aborted = false;

        // Phase 1: reduce-scatter.
        for s in 0..n - 1 {
            let send_idx = (me + n - s) % n;
            let recv_idx = (me + n - s - 1) % n;
            self.put_partition(epoch, step, send_idx, partition_at(len, n, send_idx), grads, &mut stats);
            let (lo, hi) = partition_at(len, n, recv_idx);
            match self.get_partition(recv_idx, hi - lo, &mut stats) {
                Some(msg) => {
                    ops::add_assign(&mut grads[lo..hi], &msg.data);
                    self.contrib[recv_idx] = s + 2;
                    stats.contributions += 1;
                    self.pool.recycle_payload(msg.data, &mut stats);
                }
                None => {
                    aborted = true;
                    break;
                }
            }
            step += 1;
        }
        // Average every partition by what it actually accumulated. In the
        // healthy case only the own partition (contrib = n) survives into
        // the all-gather sends; the others are overwritten below.
        for p in 0..n {
            let (lo, hi) = partition_at(len, n, p);
            ops::scale(&mut grads[lo..hi], 1.0 / self.contrib[p] as f32);
        }
        let own = (me + 1) % n;
        self.averaged[own] = self.contrib[own] == n;

        // Phase 2: all-gather the averaged partitions.
        if !aborted {
            for s in 0..n - 1 {
                let send_idx = (me + n + 1 - s) % n;
                let recv_idx = (me + n - s) % n;
                self.put_partition(epoch, step, send_idx, partition_at(len, n, send_idx), grads, &mut stats);
                let (lo, hi) = partition_at(len, n, recv_idx);
                match self.get_partition(recv_idx, hi - lo, &mut stats) {
                    Some(msg) => {
                        grads[lo..hi].copy_from_slice(&msg.data);
                        self.averaged[recv_idx] = true;
                        self.pool.recycle_payload(msg.data, &mut stats);
                    }
                    None => break,
                }
                step += 1;
            }
        }
        if self.averaged.iter().all(|&a| a) {
            stats.contributions = n;
        }
        Ok(stats)
    }

    fn put_partition(
        &mut self,
        epoch: u64,
        step: u32,
        part_idx: usize,
        (lo, hi): (usize, usize),
        grads: &[f32],
        stats: &mut CommStats,
    ) {
        let buf = self.pool.checkout_filled(&grads[lo..hi], stats);
        self.to_next
            .put(GradMsg::chunked(self.rank, epoch, step, part_idx as u32, buf));
        stats.messages += 1;
        stats.bytes_sent += (hi - lo) * 4;
    }

    /// Get one partition message; `None` on timeout or desync (counted).
    fn get_partition(
        &mut self,
        part_idx: usize,
        want_len: usize,
        stats: &mut CommStats,
    ) -> Option<GradMsg> {
        let t0 = Instant::now();
        match self.from_prev.get_wait(self.get_timeout) {
            Some((msg, skipped)) => {
                stats.wait_s += t0.elapsed().as_secs_f64();
                stats.stale_reads += skipped;
                if msg.chunk as usize != part_idx || msg.data.len() != want_len {
                    // Out-of-order deposit (the neighbour dropped slots):
                    // treat like a timeout — bounded staleness by design.
                    stats.timeouts += 1;
                    self.pool.recycle_payload(msg.data, stats);
                    return None;
                }
                Some(msg)
            }
            None => {
                stats.wait_s += t0.elapsed().as_secs_f64();
                stats.timeouts += 1;
                None
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_rma_ring(members: Vec<usize>, values: Vec<f32>) -> Vec<(Vec<f32>, CommStats)> {
        let n = values.len();
        // One slot per ring step so same-epoch deposits are never
        // superseded even when ranks run at different speeds.
        let region = RmaRegion::with_capacity(n, members.len());
        let rings: Vec<_> = members
            .iter()
            .map(|&r| RmaRing::new(&region, members.clone(), r).unwrap())
            .collect();
        let handles: Vec<_> = rings
            .into_iter()
            .map(|mut ring| {
                let v = values[ring.rank];
                std::thread::spawn(move || {
                    let mut grads = vec![v; 7];
                    let stats = ring.pass(0, &mut grads).unwrap();
                    (grads, stats)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn full_ring_matches_transport_average() {
        let out = run_rma_ring(vec![0, 1, 2, 3], vec![0.0, 1.0, 2.0, 3.0]);
        for (g, s) in &out {
            for v in g {
                assert!((v - 1.5).abs() < 1e-5, "got {v}");
            }
            assert_eq!(s.contributions, 4);
            assert_eq!(s.timeouts, 0);
        }
    }

    #[test]
    fn chunked_ring_matches_transport_average() {
        let members = vec![0usize, 1, 2, 3];
        let values = [0.0f32, 1.0, 2.0, 3.0];
        let len = 11; // non-divisible by 4
        let region = RmaRegion::with_capacity(4, 2 * members.len());
        let rings: Vec<_> = members
            .iter()
            .map(|&r| RmaRing::new(&region, members.clone(), r).unwrap())
            .collect();
        let handles: Vec<_> = rings
            .into_iter()
            .map(|mut ring| {
                let v = values[ring.rank];
                std::thread::spawn(move || {
                    let mut grads = vec![v; len];
                    let stats = ring.pass_chunked(0, &mut grads, 0).unwrap();
                    (grads, stats)
                })
            })
            .collect();
        let unchunked_bytes = 3 * len * 4;
        for h in handles {
            let (g, s) = h.join().unwrap();
            for v in g {
                assert!((v - 1.5).abs() < 1e-5, "got {v}");
            }
            assert_eq!(s.contributions, 4);
            assert_eq!(s.timeouts, 0);
            assert!(s.bytes_sent < unchunked_bytes);
        }
    }

    #[test]
    fn steady_state_passes_recycle_deposits() {
        // Two healthy ranks in lockstep: after the first epoch warms the
        // pools, every pass is pool-hit only.
        let region = RmaRegion::with_capacity(2, 2);
        let rings: Vec<_> = (0..2)
            .map(|r| RmaRing::new(&region, vec![0, 1], r).unwrap())
            .collect();
        let handles: Vec<_> = rings
            .into_iter()
            .map(|mut ring| {
                std::thread::spawn(move || {
                    let mut grads = vec![1.0f32; 32];
                    for e in 0..8 {
                        let s = ring.pass(e, &mut grads).unwrap();
                        assert_eq!(s.timeouts, 0);
                        if e > 0 {
                            assert_eq!(s.allocs, 0, "epoch {e} allocated");
                            assert_eq!(s.pool_hits, 1);
                        }
                    }
                    ring.pool.stats().allocs
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), 1);
        }
    }

    #[test]
    fn timeout_proceeds_with_partial_average() {
        // Rank 1 never participates: rank 0's get times out and it averages
        // only its own gradient.
        let region = RmaRegion::new(2);
        let mut ring = RmaRing {
            get_timeout: Duration::from_millis(30),
            ..RmaRing::new(&region, vec![0, 1], 0).unwrap()
        };
        let mut grads = vec![8.0f32; 3];
        let s = ring.pass(0, &mut grads).unwrap();
        assert_eq!(s.timeouts, 1);
        assert_eq!(s.contributions, 1);
        assert_eq!(grads, vec![8.0; 3]); // own / 1
    }

    #[test]
    fn chunked_timeout_never_leaves_unscaled_sums() {
        // Rank 1 never participates: every partition must still hold a
        // *scaled* value (here: own gradient / 1), never a raw sum.
        let region = RmaRegion::with_capacity(2, 4);
        let mut ring = RmaRing {
            get_timeout: Duration::from_millis(20),
            ..RmaRing::new(&region, vec![0, 1], 0).unwrap()
        };
        let mut grads = vec![6.0f32; 5];
        let s = ring.pass_chunked(0, &mut grads, 0).unwrap();
        assert!(s.timeouts >= 1);
        assert_eq!(grads, vec![6.0; 5]);
    }

    #[test]
    fn writer_never_blocks_on_dead_reader() {
        let region = RmaRegion::new(2);
        let mut ring = RmaRing {
            get_timeout: Duration::from_millis(10),
            ..RmaRing::new(&region, vec![0, 1], 0).unwrap()
        };
        // Many epochs with no reader on rank 1: put() must never block.
        for e in 0..50 {
            let mut grads = vec![1.0f32; 4];
            let s = ring.pass(e, &mut grads).unwrap();
            assert_eq!(s.timeouts, 1);
        }
    }

    #[test]
    fn stale_reads_detected_when_reader_lags() {
        // Writer deposits 3 epochs before the reader fetches once.
        let region = RmaRegion::new(2);
        let w = region.window(0, 1).unwrap();
        for e in 0..3 {
            w.put(GradMsg::new(0, e, 0, vec![e as f32]));
        }
        let mut ring = RmaRing::new(&region, vec![0, 1], 1).unwrap();
        let mut grads = vec![10.0f32];
        let s = ring.pass(7, &mut grads).unwrap();
        assert_eq!(s.stale_reads, 2); // two deposits were overwritten
        // got latest (2.0): average of own 10 and 2 = 6
        assert_eq!(grads, vec![6.0]);
    }
}
