//! Gradient collectives: every row of the paper's Table II plus the
//! baselines and future-work extensions.
//!
//! | Mode              | Inner group            | Outer group | Module |
//! |-------------------|------------------------|-------------|--------|
//! | conventional ARAR | —                      | —           | [`ring`] over all ranks |
//! | ARAR-ARAR         | transport ring / epoch | ring every h| [`grouped`] |
//! | RMA-ARAR-ARAR     | RMA ring / epoch       | ring every h| [`grouped`] + [`rma_ring`] |
//! | Horovod baseline  | synchronous allreduce every epoch     | [`sync`] |
//! | Hierarchical [16] | 3-step reduce/ring/broadcast          | [`hierarchical`] |
//! | Double binary tree| tree reduce + broadcast (future work) | [`tree`] |
//! | Ensemble          | no communication                      | [`NullCollective`] |
//!
//! Every collective implements [`Collective::epoch_reduce`]: average the
//! rank's packed gradient buffer with its peers *in place*. The trainer
//! packs weight-only gradients through a `FusionPlan` first (the paper
//! excludes bias gradients from transfer).
//!
//! Beyond the paper, the collective layer is an *engine* with two extra
//! capabilities, both off by default so every Table II behaviour is
//! preserved bit-for-bit:
//!
//! * **Chunking** ([`crate::config::ChunkPolicy`]): the transport rings
//!   can run a bandwidth-optimal reduce-scatter + all-gather schedule
//!   ([`ring::chunked_ring_pass`]) instead of forwarding full tensors.
//! * **Bounded-staleness overlap** ([`engine::CollectiveEngine`] + the
//!   non-blocking [`Collective::start_reduce`] / [`Collective::poll_reduce`]
//!   / [`Collective::wait_reduce`] / [`Collective::drain`] API): the
//!   trainer can keep a bounded window of k exchanges in flight under
//!   compute, applying averaged gradients at most k epochs stale (FIFO)
//!   and settling the window with `drain()` wherever quiescence is
//!   needed (run checkpoints, end of training).
//! * **Pooled payloads** ([`crate::comm::BufferPool`]): every transfer
//!   buffer is checked out of one pool shared by all ranks at send and
//!   recycled at receive-apply, so steady-state epochs allocate nothing
//!   on the exchange path (DESIGN.md §Memory discipline). [`CommStats`]
//!   carries the checkout accounting (`allocs` / `pool_hits` /
//!   `bytes_recycled`).

pub mod engine;
pub mod grouped;
pub mod hierarchical;
pub mod ring;
pub mod rma_ring;
pub mod sync;
pub mod tree;

use std::sync::{Arc, Barrier};

use crate::comm::{BufferPool, Endpoint, MembershipView, RmaRegion, Topology};
use crate::config::{ChunkPolicy, Mode};
use crate::util::error::{Error, Result};

/// Per-epoch communication statistics, aggregated by the metrics recorder.
#[derive(Clone, Copy, Debug, Default)]
pub struct CommStats {
    /// Messages sent by this rank this epoch.
    pub messages: usize,
    /// Payload bytes sent by this rank this epoch.
    pub bytes_sent: usize,
    /// Seconds spent blocked waiting for peers.
    pub wait_s: f64,
    /// RMA reads that observed overwritten (stale) windows.
    pub stale_reads: u64,
    /// RMA steps that timed out and proceeded without a contribution.
    pub timeouts: u64,
    /// Gradient contributions averaged into the buffer (incl. own).
    pub contributions: usize,
    /// Sum over applied averaged gradients of their staleness — the
    /// epochs between an exchange's start and its application. Filled by
    /// the rank pipeline (the collectives don't know when their result is
    /// applied); 0 for the blocking path.
    pub staleness_sum: u64,
    /// Averaged-gradient applications accounted in `staleness_sum`.
    pub applies: u64,
    /// Exchanges abandoned past the deadline under `on_straggler: skip` —
    /// the rank kept training on stale params and discarded the result on
    /// eventual arrival. Filled by the rank pipeline.
    pub skips: u64,
    /// Exchanges that missed the deadline but were applied on eventual
    /// arrival under `on_straggler: late_apply`. Filled by the rank
    /// pipeline; their (larger) lag is included in `staleness_sum`.
    pub late_applies: u64,
    /// Epochs this rank actually participated in (was live, ran the epoch
    /// body). Equal to the run's epoch count for a fixed membership; less
    /// for a rank that left or joined mid-run. Filled by the rank
    /// pipeline — the Async-RED per-block participation bookkeeping.
    pub participation_epochs: u64,
    /// Gradient-buffer allocations on the exchange path: pool checkouts
    /// that found no recycled buffer of the right size class. Zero at
    /// steady state once the pool has warmed (DESIGN.md §Memory
    /// discipline).
    pub allocs: u64,
    /// Pool checkouts served from a recycled buffer (no allocation).
    pub pool_hits: u64,
    /// Payload bytes returned to the pool for reuse instead of freed.
    pub bytes_recycled: u64,
}

impl CommStats {
    pub fn merge(&mut self, other: &CommStats) {
        self.messages += other.messages;
        self.bytes_sent += other.bytes_sent;
        self.wait_s += other.wait_s;
        self.stale_reads += other.stale_reads;
        self.timeouts += other.timeouts;
        self.contributions += other.contributions;
        self.staleness_sum += other.staleness_sum;
        self.applies += other.applies;
        self.skips += other.skips;
        self.late_applies += other.late_applies;
        self.participation_epochs += other.participation_epochs;
        self.allocs += other.allocs;
        self.pool_hits += other.pool_hits;
        self.bytes_recycled += other.bytes_recycled;
    }

    /// Fraction of pool checkouts served without allocating (1.0 when no
    /// checkouts happened — nothing needed, nothing allocated).
    pub fn pool_hit_rate(&self) -> f64 {
        let total = self.allocs + self.pool_hits;
        if total == 0 {
            1.0
        } else {
            self.pool_hits as f64 / total as f64
        }
    }

    /// Mean applied-gradient staleness in epochs (0.0 when nothing was
    /// applied — or for a purely blocking run).
    pub fn mean_staleness(&self) -> f64 {
        if self.applies == 0 {
            0.0
        } else {
            self.staleness_sum as f64 / self.applies as f64
        }
    }
}

/// Completed-reduce FIFO backing the default (synchronous-fallback)
/// non-blocking API: collectives without a comm worker run the blocking
/// reduce inside [`Collective::start_reduce`] and park the result here
/// until [`Collective::wait_reduce`] collects it. The queue preserves
/// submission order, so a k-deep exchange window collects its results
/// FIFO whether or not a comm thread is involved.
#[derive(Default)]
pub struct ParkedReduce {
    done: std::collections::VecDeque<(Vec<f32>, CommStats)>,
}

impl ParkedReduce {
    /// Park a finished reduce behind any already waiting (FIFO).
    pub fn park(&mut self, buf: Vec<f32>, stats: CommStats) {
        self.done.push_back((buf, stats));
    }

    /// Whether a parked result is waiting.
    pub fn ready(&self) -> bool {
        !self.done.is_empty()
    }

    /// Number of parked results.
    pub fn len(&self) -> usize {
        self.done.len()
    }

    /// Whether the queue is empty.
    pub fn is_empty(&self) -> bool {
        self.done.is_empty()
    }

    /// Collect the oldest parked result.
    pub fn take(&mut self) -> Result<(Vec<f32>, CommStats)> {
        self.done
            .pop_front()
            .ok_or_else(|| Error::comm("wait_reduce called with no reduce in flight"))
    }
}

/// A per-rank gradient collective.
///
/// The blocking entry point is [`Collective::epoch_reduce`]; the
/// `start_reduce` / `poll_reduce` / `wait_reduce` triple is the
/// non-blocking face of the same operation, and several exchanges may be
/// in flight at once — results always come back in submission (FIFO)
/// order, which is what the bounded-staleness rank pipeline builds on.
/// The default implementations execute the reduce eagerly (blocking
/// inside `start_reduce`), so every collective is overlap-API-compatible;
/// [`engine::CollectiveEngine`] overrides them to run the reduces on a
/// dedicated comm thread, which is what actually hides the exchange
/// behind compute.
///
/// [`Collective::drain`] is the quiescence operation: it settles every
/// in-flight exchange at once, so callers (the run-checkpoint cadence,
/// end of training) can reach a state with nothing outstanding.
pub trait Collective: Send {
    /// Average `grads` (the packed transfer buffer) with peers in place.
    fn epoch_reduce(&mut self, epoch: u64, grads: &mut [f32]) -> Result<CommStats>;

    /// Human-readable mode name.
    fn name(&self) -> &'static str;

    /// Storage queue used by the default non-blocking implementation.
    fn parked(&mut self) -> &mut ParkedReduce;

    /// Begin reducing `buf` (ownership moves to the collective). Callers
    /// bound how many reduces they keep in flight; implementations with a
    /// fixed window reject submissions beyond it.
    fn start_reduce(&mut self, epoch: u64, mut buf: Vec<f32>) -> Result<()> {
        let stats = self.epoch_reduce(epoch, &mut buf)?;
        self.parked().park(buf, stats);
        Ok(())
    }

    /// Whether the *oldest* in-flight reduce has completed (never blocks).
    fn poll_reduce(&mut self) -> Result<bool> {
        Ok(self.parked().ready())
    }

    /// Block until the oldest in-flight reduce completes; returns the
    /// averaged buffer and its stats (FIFO order).
    fn wait_reduce(&mut self) -> Result<(Vec<f32>, CommStats)> {
        self.parked().take()
    }

    /// Exchanges started but not yet collected.
    fn in_flight(&mut self) -> usize {
        self.parked().len()
    }

    /// Quiescence: settle **every** in-flight exchange, returning the
    /// averaged buffers and stats in submission (FIFO) order. After a
    /// drain nothing is outstanding — the caller's state can be
    /// checkpointed as fully settled.
    fn drain(&mut self) -> Result<Vec<(Vec<f32>, CommStats)>> {
        let mut out = Vec::new();
        while self.in_flight() > 0 {
            out.push(self.wait_reduce()?);
        }
        Ok(out)
    }

    /// Elastic re-ring: rebuild the neighbour schedule for a new
    /// membership view. Callers must quiesce first (`drain()`), so no
    /// in-flight exchange ever straddles two rings; implementations may
    /// reject the call otherwise. The default is a no-op for collectives
    /// whose schedule does not depend on membership (ensemble/null);
    /// modes that cannot re-ring (the synchronous Horovod baseline, whose
    /// barrier is sized at build time) return an error instead.
    fn set_membership(&mut self, _view: &MembershipView) -> Result<()> {
        Ok(())
    }

    /// The buffer pool this collective checks transfer payloads out of,
    /// if it uses one. Wrappers ([`engine::CollectiveEngine`]) and the
    /// rank pipeline recycle applied buffers back into the same pool, so
    /// a staleness-k window circulates exactly k+1 buffers at steady
    /// state instead of allocating fresh ones. `None` for collectives
    /// that move no payloads (ensemble/null).
    fn buffer_pool(&self) -> Option<BufferPool> {
        None
    }
}

/// No-communication collective (ensemble analysis, single rank).
#[derive(Default)]
pub struct NullCollective {
    parked: ParkedReduce,
}

impl Collective for NullCollective {
    fn epoch_reduce(&mut self, _epoch: u64, _grads: &mut [f32]) -> Result<CommStats> {
        Ok(CommStats {
            contributions: 1,
            ..Default::default()
        })
    }

    fn name(&self) -> &'static str {
        "ensemble"
    }

    fn parked(&mut self) -> &mut ParkedReduce {
        &mut self.parked
    }
}

/// Build one collective per rank for the given mode with the paper's
/// default (unchunked) ring schedule. Consumes the endpoints (each
/// collective owns its rank's endpoint).
pub fn build(
    mode: Mode,
    topo: &Topology,
    outer_freq: usize,
    endpoints: Vec<Endpoint>,
    region: &RmaRegion,
) -> Result<Vec<Box<dyn Collective>>> {
    build_with_policy(
        mode,
        topo,
        outer_freq,
        endpoints,
        region,
        ChunkPolicy::Unchunked,
    )
}

/// Build one collective per rank with an explicit chunk policy. The
/// policy applies to the ring-structured modes (conventional, grouped,
/// RMA-grouped); the baselines keep their published schedules.
pub fn build_with_policy(
    mode: Mode,
    topo: &Topology,
    outer_freq: usize,
    endpoints: Vec<Endpoint>,
    region: &RmaRegion,
    policy: ChunkPolicy,
) -> Result<Vec<Box<dyn Collective>>> {
    let n = topo.ranks;
    let barrier = Arc::new(Barrier::new(n));
    // One pool shared by every rank: buffers migrate around the ring
    // (checked out by the sender, recycled by the receiver), so only a
    // shared free-list keeps the checkout/recycle flow balanced globally.
    let pool = BufferPool::new();
    let mut out: Vec<Box<dyn Collective>> = Vec::with_capacity(n);
    for ep in endpoints {
        let rank = ep.rank;
        let c: Box<dyn Collective> = match mode {
            Mode::Ensemble => Box::new(NullCollective::default()),
            Mode::ConvArar => {
                Box::new(ring::ConvArar::with_policy(ep, policy).with_pool(pool.clone()))
            }
            Mode::ArarArar => Box::new(
                grouped::GroupedArar::with_policy(ep, outer_freq, policy).with_pool(pool.clone()),
            ),
            Mode::RmaArarArar => Box::new(
                grouped::RmaGroupedArar::with_policy(ep, outer_freq, topo, region, rank, policy)?
                    .with_pool(pool.clone()),
            ),
            Mode::Horovod => {
                Box::new(sync::SyncAllReduce::new(ep, barrier.clone()).with_pool(pool.clone()))
            }
            Mode::Hierarchical => {
                Box::new(hierarchical::Hierarchical::new(ep).with_pool(pool.clone()))
            }
            Mode::DoubleBinaryTree => {
                Box::new(tree::TreeAllReduce::new(ep).with_pool(pool.clone()))
            }
        };
        out.push(c);
    }
    Ok(out)
}

#[cfg(test)]
pub(crate) mod testutil {
    //! Shared helpers for collective tests: run one collective per rank on
    //! its own thread with known per-rank gradients and return the reduced
    //! buffers.

    use super::*;
    use crate::comm::{LinkModel, LocalNetwork};

    /// Run `epochs` reduce rounds over `n` ranks where rank r's gradient at
    /// epoch e is `fill(r, e)`. Returns the final reduced buffer per rank
    /// and the aggregated stats.
    pub fn run_mode<F>(
        mode: Mode,
        n: usize,
        gpus_per_node: usize,
        outer_freq: usize,
        len: usize,
        epochs: u64,
        fill: F,
    ) -> (Vec<Vec<f32>>, Vec<CommStats>)
    where
        F: Fn(usize, u64) -> f32 + Send + Sync + Copy + 'static,
    {
        run_mode_with_policy(
            mode,
            n,
            gpus_per_node,
            outer_freq,
            len,
            epochs,
            ChunkPolicy::Unchunked,
            fill,
        )
    }

    /// [`run_mode`] with an explicit chunk policy.
    #[allow(clippy::too_many_arguments)]
    pub fn run_mode_with_policy<F>(
        mode: Mode,
        n: usize,
        gpus_per_node: usize,
        outer_freq: usize,
        len: usize,
        epochs: u64,
        policy: ChunkPolicy,
        fill: F,
    ) -> (Vec<Vec<f32>>, Vec<CommStats>)
    where
        F: Fn(usize, u64) -> f32 + Send + Sync + Copy + 'static,
    {
        let topo = Topology::new(n, gpus_per_node);
        let region = RmaRegion::with_capacity(n, rma_window_depth(gpus_per_node, policy));
        let endpoints = LocalNetwork::build(&topo, LinkModel::zero());
        let collectives =
            build_with_policy(mode, &topo, outer_freq, endpoints, &region, policy).unwrap();
        let handles: Vec<_> = collectives
            .into_iter()
            .enumerate()
            .map(|(rank, mut c)| {
                std::thread::spawn(move || {
                    let mut grads = vec![0.0f32; len];
                    let mut stats = CommStats::default();
                    for e in 0..epochs {
                        for g in grads.iter_mut() {
                            *g = fill(rank, e);
                        }
                        let s = c.epoch_reduce(e, &mut grads).unwrap();
                        stats.merge(&s);
                    }
                    (grads, stats)
                })
            })
            .collect();
        let mut grads = Vec::new();
        let mut stats = Vec::new();
        for h in handles {
            let (g, s) = h.join().unwrap();
            grads.push(g);
            stats.push(s);
        }
        (grads, stats)
    }
}

/// RMA window depth needed per mode: one slot per inner-ring step for the
/// unchunked schedule, twice that for the chunked reduce-scatter +
/// all-gather schedule (2·(g-1) steps per epoch).
pub fn rma_window_depth(gpus_per_node: usize, policy: ChunkPolicy) -> usize {
    let base = gpus_per_node.max(2);
    if policy.is_chunked() {
        2 * base
    } else {
        base
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;
    use testutil::{run_mode, run_mode_with_policy};

    /// Expected full average when rank r contributes value r.
    fn full_avg(n: usize) -> f32 {
        (0..n).map(|r| r as f32).sum::<f32>() / n as f32
    }

    #[test]
    fn null_collective_reports_self_contribution() {
        let mut c = NullCollective::default();
        let mut g = vec![1.0, 2.0];
        let s = c.epoch_reduce(0, &mut g).unwrap();
        assert_eq!(g, vec![1.0, 2.0]);
        assert_eq!(s.contributions, 1);
        assert_eq!(s.messages, 0);
    }

    #[test]
    fn parked_reduce_fallback_fifo_roundtrip() {
        let mut c = NullCollective::default();
        assert!(c.wait_reduce().is_err()); // nothing in flight
        c.start_reduce(0, vec![2.0, 4.0]).unwrap();
        assert!(c.poll_reduce().unwrap());
        // The eager fallback queues a second start FIFO behind the first.
        c.start_reduce(1, vec![8.0]).unwrap();
        assert_eq!(c.in_flight(), 2);
        let (buf, s) = c.wait_reduce().unwrap();
        assert_eq!(buf, vec![2.0, 4.0]); // oldest first
        assert_eq!(s.contributions, 1);
        // drain() settles whatever remains, in order.
        let rest = c.drain().unwrap();
        assert_eq!(rest.len(), 1);
        assert_eq!(rest[0].0, vec![8.0]);
        assert_eq!(c.in_flight(), 0);
        assert!(!c.poll_reduce().unwrap());
    }

    #[test]
    fn comm_stats_staleness_accounting_merges_and_averages() {
        let mut a = CommStats {
            staleness_sum: 3,
            applies: 2,
            skips: 1,
            ..Default::default()
        };
        let b = CommStats {
            staleness_sum: 1,
            applies: 2,
            late_applies: 2,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.staleness_sum, 4);
        assert_eq!(a.applies, 4);
        assert_eq!(a.skips, 1);
        assert_eq!(a.late_applies, 2);
        assert!((a.mean_staleness() - 1.0).abs() < 1e-12);
        assert_eq!(CommStats::default().mean_staleness(), 0.0);
    }

    #[test]
    fn conv_arar_reduces_to_global_average() {
        let (grads, stats) = run_mode(Mode::ConvArar, 6, 4, 1, 33, 1, |r, _| r as f32);
        for g in &grads {
            for v in g {
                assert!((v - full_avg(6)).abs() < 1e-5, "got {v}");
            }
        }
        // N-1 messages per rank per epoch, full tensor each (unchunked).
        for s in &stats {
            assert_eq!(s.messages, 5);
            assert_eq!(s.bytes_sent, 5 * 33 * 4);
            assert_eq!(s.contributions, 6);
        }
    }

    #[test]
    fn chunked_conv_arar_matches_unchunked_average() {
        let n = 6;
        let (grads, stats) =
            run_mode_with_policy(Mode::ConvArar, n, 4, 1, 33, 1, ChunkPolicy::Auto, |r, _| {
                r as f32
            });
        for g in &grads {
            for v in g {
                assert!((v - full_avg(n)).abs() < 1e-5, "got {v}");
            }
        }
        // Bandwidth-optimal: strictly below the unchunked ring's bytes for
        // N >= 4 (acceptance criterion).
        let unchunked = (n - 1) * 33 * 4;
        for s in &stats {
            assert!(s.bytes_sent < unchunked, "{} !< {unchunked}", s.bytes_sent);
            assert_eq!(s.contributions, n);
        }
    }

    #[test]
    fn chunked_grouped_modes_match_unchunked_results() {
        for mode in [Mode::ArarArar, Mode::RmaArarArar] {
            let (plain, _) = run_mode(mode, 8, 4, 1, 12, 1, |r, _| r as f32);
            let (chunked, _) =
                run_mode_with_policy(mode, 8, 4, 1, 12, 1, ChunkPolicy::Auto, |r, _| r as f32);
            for (p, c) in plain.iter().zip(&chunked) {
                for (a, b) in p.iter().zip(c) {
                    assert!((a - b).abs() < 1e-5, "mode {mode:?}: {a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn prop_chunked_ring_matches_unchunked_any_shape() {
        // Satellite: chunked reduce-scatter/all-gather must agree with the
        // paper's unchunked ring for arbitrary N, chunk caps, and
        // non-divisible tensor lengths.
        proptest::run("chunked ring == unchunked ring", 25, |g| {
            let n = g.usize_in(2..=6);
            let len = g.usize_in(1..=65);
            let max_elems = g.usize_in(0..=9);
            let policy = if max_elems == 0 {
                ChunkPolicy::Auto
            } else {
                ChunkPolicy::MaxElems(max_elems)
            };
            let seed = g.u64();
            let fill = move |r: usize, _e: u64| {
                // Deterministic pseudo-random per-rank value from the seed.
                let x = seed ^ (r as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
                ((x >> 16) % 1000) as f32 / 37.0 - 13.0
            };
            let (plain, _) = run_mode(Mode::ConvArar, n, 4, 1, len, 1, fill);
            let (chunked, _) =
                run_mode_with_policy(Mode::ConvArar, n, 4, 1, len, 1, policy, fill);
            for (p, c) in plain.iter().zip(&chunked) {
                for (a, b) in p.iter().zip(c) {
                    assert!(
                        (a - b).abs() < 1e-5,
                        "n={n} len={len} policy={policy:?}: {a} vs {b}"
                    );
                }
            }
        });
    }

    #[test]
    fn chunked_bytes_follow_two_nm1_over_n_law() {
        // CommStats satellite: the chunked ring moves 2·(N-1)/N·|g| bytes
        // per rank (exactly, for N | len) versus the ring's (N-1)·|g|.
        let n = 4;
        let len = 64; // divisible by n
        let (_, stats) =
            run_mode_with_policy(Mode::ConvArar, n, 4, 1, len, 1, ChunkPolicy::Auto, |r, _| {
                r as f32
            });
        let expect = 2 * (n - 1) * (len / n) * 4;
        for s in &stats {
            assert_eq!(s.bytes_sent, expect);
            assert_eq!(s.bytes_sent, ring::chunked_pass_bytes(len, n));
        }
    }

    #[test]
    fn horovod_matches_conv_arar_result() {
        let (grads, _) = run_mode(Mode::Horovod, 5, 4, 1, 8, 2, |r, _| r as f32);
        for g in &grads {
            for v in g {
                assert!((v - full_avg(5)).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn hierarchical_matches_full_average() {
        let (grads, _) = run_mode(Mode::Hierarchical, 8, 4, 1, 16, 1, |r, _| r as f32);
        for g in &grads {
            for v in g {
                assert!((v - full_avg(8)).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn tree_matches_full_average() {
        for n in [2, 3, 4, 7, 8] {
            let (grads, _) = run_mode(Mode::DoubleBinaryTree, n, 4, 1, 8, 1, |r, _| r as f32);
            for g in &grads {
                for v in g {
                    assert!((v - full_avg(n)).abs() < 1e-5, "n={n} got {v}");
                }
            }
        }
    }

    #[test]
    fn grouped_inner_only_averages_within_node() {
        // outer_freq = 7 with a single epoch: (0 + 1) % 7 != 0, so no
        // outer pass runs and each rank averages only its node.
        let (grads, _) = run_mode(Mode::ArarArar, 8, 4, 7, 8, 1, |r, _| r as f32);
        // node0 = {0..3} -> avg 1.5, node1 = {4..7} -> avg 5.5.
        for r in 0..4 {
            assert!((grads[r][0] - 1.5).abs() < 1e-4, "r{r} {}", grads[r][0]);
        }
        for r in 4..8 {
            assert!((grads[r][0] - 5.5).abs() < 1e-4, "r{r} {}", grads[r][0]);
        }
    }

    #[test]
    fn grouped_outer_pass_mixes_across_nodes() {
        // freq 1: the outer ring fires every epoch ((e + 1) % 1 == 0), so
        // a single epoch runs inner then outer: outer members exchange
        // their inner-averaged gradients.
        let (grads, _) = run_mode(Mode::ArarArar, 8, 4, 1, 4, 1, |r, _| r as f32);
        // inner: node0 -> 1.5, node1 -> 5.5; outer over {0,4}: (1.5+5.5)/2
        assert!((grads[0][0] - 3.5).abs() < 1e-4);
        assert!((grads[4][0] - 3.5).abs() < 1e-4);
        // non-outer ranks keep the inner average
        assert!((grads[1][0] - 1.5).abs() < 1e-4);
        assert!((grads[5][0] - 5.5).abs() < 1e-4);
    }

    #[test]
    fn rma_grouped_converges_to_same_averages() {
        let (grads, stats) = run_mode(Mode::RmaArarArar, 8, 4, 1, 4, 1, |r, _| r as f32);
        assert!((grads[0][0] - 3.5).abs() < 1e-4, "{}", grads[0][0]);
        assert!((grads[1][0] - 1.5).abs() < 1e-4, "{}", grads[1][0]);
        assert!((grads[5][0] - 5.5).abs() < 1e-4, "{}", grads[5][0]);
        // RMA mode should see no timeouts in a healthy run.
        assert!(stats.iter().all(|s| s.timeouts == 0));
    }

    #[test]
    fn single_rank_modes_are_noops() {
        for mode in [Mode::ConvArar, Mode::ArarArar, Mode::Horovod] {
            let (grads, _) = run_mode(mode, 1, 4, 1, 4, 2, |_, _| 2.0);
            assert_eq!(grads[0], vec![2.0; 4]);
        }
    }

    #[test]
    fn grouping_reduces_messages_vs_conventional() {
        // The core claim behind Fig 11/12: bounded rings send fewer
        // messages per epoch than the global ring.
        let (_, conv) = run_mode(Mode::ConvArar, 12, 4, 1000, 8, 2, |r, _| r as f32);
        let (_, grp) = run_mode(Mode::ArarArar, 12, 4, 1000, 8, 2, |r, _| r as f32);
        let conv_msgs: usize = conv.iter().map(|s| s.messages).sum();
        let grp_msgs: usize = grp.iter().map(|s| s.messages).sum();
        assert!(
            grp_msgs < conv_msgs / 2,
            "grouped {grp_msgs} vs conventional {conv_msgs}"
        );
    }

    #[test]
    fn rma_window_depth_doubles_for_chunked() {
        assert_eq!(rma_window_depth(4, ChunkPolicy::Unchunked), 4);
        assert_eq!(rma_window_depth(4, ChunkPolicy::Auto), 8);
        assert_eq!(rma_window_depth(1, ChunkPolicy::Unchunked), 2);
    }

    #[test]
    fn built_collectives_share_one_buffer_pool() {
        // Buffers checked out by a sender are recycled by the receiver,
        // so flow balance only holds when every rank draws from the same
        // free-list.
        use crate::comm::{LinkModel, LocalNetwork};
        for mode in [Mode::ConvArar, Mode::ArarArar, Mode::RmaArarArar] {
            let n = 4;
            let topo = Topology::new(n, 2);
            let region = RmaRegion::with_capacity(n, 4);
            let endpoints = LocalNetwork::build(&topo, LinkModel::zero());
            let cs = build_with_policy(mode, &topo, 1, endpoints, &region, ChunkPolicy::Unchunked)
                .unwrap();
            let first = cs[0].buffer_pool().expect("mode should expose a pool");
            for c in &cs[1..] {
                let p = c.buffer_pool().expect("mode should expose a pool");
                assert!(first.same_pool(&p), "{mode:?} ranks must share one pool");
            }
        }
        // The no-communication mode has no payloads to pool.
        assert!(NullCollective::default().buffer_pool().is_none());
    }

    #[test]
    fn pool_hit_rate_counts_checkouts() {
        let mut s = CommStats {
            allocs: 1,
            pool_hits: 3,
            bytes_recycled: 64,
            ..Default::default()
        };
        assert!((s.pool_hit_rate() - 0.75).abs() < 1e-12);
        assert_eq!(CommStats::default().pool_hit_rate(), 1.0);
        let other = CommStats {
            allocs: 1,
            pool_hits: 1,
            bytes_recycled: 16,
            ..Default::default()
        };
        s.merge(&other);
        assert_eq!(s.allocs, 2);
        assert_eq!(s.pool_hits, 4);
        assert_eq!(s.bytes_recycled, 80);
    }
}
