//! Gradient collectives: every row of the paper's Table II plus the
//! baselines and future-work extensions.
//!
//! | Mode              | Inner group            | Outer group | Module |
//! |-------------------|------------------------|-------------|--------|
//! | conventional ARAR | —                      | —           | [`ring`] over all ranks |
//! | ARAR-ARAR         | transport ring / epoch | ring every h| [`grouped`] |
//! | RMA-ARAR-ARAR     | RMA ring / epoch       | ring every h| [`grouped`] + [`rma_ring`] |
//! | Horovod baseline  | synchronous allreduce every epoch     | [`sync`] |
//! | Hierarchical [16] | 3-step reduce/ring/broadcast          | [`hierarchical`] |
//! | Double binary tree| tree reduce + broadcast (future work) | [`tree`] |
//! | Ensemble          | no communication                      | [`NullCollective`] |
//!
//! Every collective implements [`Collective::epoch_reduce`]: average the
//! rank's packed gradient buffer with its peers *in place*. The trainer
//! packs weight-only gradients through a `FusionPlan` first (the paper
//! excludes bias gradients from transfer).

pub mod grouped;
pub mod hierarchical;
pub mod ring;
pub mod rma_ring;
pub mod sync;
pub mod tree;

use std::sync::{Arc, Barrier};

use crate::comm::{Endpoint, RmaRegion, Topology};
use crate::config::Mode;
use crate::util::error::Result;

/// Per-epoch communication statistics, aggregated by the metrics recorder.
#[derive(Clone, Copy, Debug, Default)]
pub struct CommStats {
    /// Messages sent by this rank this epoch.
    pub messages: usize,
    /// Payload bytes sent by this rank this epoch.
    pub bytes_sent: usize,
    /// Seconds spent blocked waiting for peers.
    pub wait_s: f64,
    /// RMA reads that observed overwritten (stale) windows.
    pub stale_reads: u64,
    /// RMA steps that timed out and proceeded without a contribution.
    pub timeouts: u64,
    /// Gradient contributions averaged into the buffer (incl. own).
    pub contributions: usize,
}

impl CommStats {
    pub fn merge(&mut self, other: &CommStats) {
        self.messages += other.messages;
        self.bytes_sent += other.bytes_sent;
        self.wait_s += other.wait_s;
        self.stale_reads += other.stale_reads;
        self.timeouts += other.timeouts;
        self.contributions += other.contributions;
    }
}

/// A per-rank gradient collective.
pub trait Collective: Send {
    /// Average `grads` (the packed transfer buffer) with peers in place.
    fn epoch_reduce(&mut self, epoch: u64, grads: &mut [f32]) -> Result<CommStats>;

    /// Human-readable mode name.
    fn name(&self) -> &'static str;
}

/// No-communication collective (ensemble analysis, single rank).
pub struct NullCollective;

impl Collective for NullCollective {
    fn epoch_reduce(&mut self, _epoch: u64, _grads: &mut [f32]) -> Result<CommStats> {
        Ok(CommStats {
            contributions: 1,
            ..Default::default()
        })
    }

    fn name(&self) -> &'static str {
        "ensemble"
    }
}

/// Build one collective per rank for the given mode. Consumes the
/// endpoints (each collective owns its rank's endpoint).
pub fn build(
    mode: Mode,
    topo: &Topology,
    outer_freq: usize,
    endpoints: Vec<Endpoint>,
    region: &RmaRegion,
) -> Result<Vec<Box<dyn Collective>>> {
    let n = topo.ranks;
    let barrier = Arc::new(Barrier::new(n));
    let mut out: Vec<Box<dyn Collective>> = Vec::with_capacity(n);
    for ep in endpoints {
        let rank = ep.rank;
        let c: Box<dyn Collective> = match mode {
            Mode::Ensemble => Box::new(NullCollective),
            Mode::ConvArar => Box::new(ring::ConvArar::new(ep)),
            Mode::ArarArar => Box::new(grouped::GroupedArar::new(ep, outer_freq)),
            Mode::RmaArarArar => Box::new(grouped::RmaGroupedArar::new(
                ep, outer_freq, topo, region, rank,
            )?),
            Mode::Horovod => Box::new(sync::SyncAllReduce::new(ep, barrier.clone())),
            Mode::Hierarchical => Box::new(hierarchical::Hierarchical::new(ep)),
            Mode::DoubleBinaryTree => Box::new(tree::TreeAllReduce::new(ep)),
        };
        out.push(c);
    }
    Ok(out)
}

#[cfg(test)]
pub(crate) mod testutil {
    //! Shared helpers for collective tests: run one collective per rank on
    //! its own thread with known per-rank gradients and return the reduced
    //! buffers.

    use super::*;
    use crate::comm::{LinkModel, LocalNetwork};

    /// Run `epochs` reduce rounds over `n` ranks where rank r's gradient at
    /// epoch e is `fill(r, e)`. Returns the final reduced buffer per rank
    /// and the aggregated stats.
    pub fn run_mode<F>(
        mode: Mode,
        n: usize,
        gpus_per_node: usize,
        outer_freq: usize,
        len: usize,
        epochs: u64,
        fill: F,
    ) -> (Vec<Vec<f32>>, Vec<CommStats>)
    where
        F: Fn(usize, u64) -> f32 + Send + Sync + Copy + 'static,
    {
        let topo = Topology::new(n, gpus_per_node);
        let region = RmaRegion::with_capacity(n, gpus_per_node);
        let endpoints = LocalNetwork::build(&topo, LinkModel::zero());
        let collectives = build(mode, &topo, outer_freq, endpoints, &region).unwrap();
        let handles: Vec<_> = collectives
            .into_iter()
            .enumerate()
            .map(|(rank, mut c)| {
                std::thread::spawn(move || {
                    let mut grads = vec![0.0f32; len];
                    let mut stats = CommStats::default();
                    for e in 0..epochs {
                        for g in grads.iter_mut() {
                            *g = fill(rank, e);
                        }
                        let s = c.epoch_reduce(e, &mut grads).unwrap();
                        stats.merge(&s);
                    }
                    (grads, stats)
                })
            })
            .collect();
        let mut grads = Vec::new();
        let mut stats = Vec::new();
        for h in handles {
            let (g, s) = h.join().unwrap();
            grads.push(g);
            stats.push(s);
        }
        (grads, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use testutil::run_mode;

    /// Expected full average when rank r contributes value r.
    fn full_avg(n: usize) -> f32 {
        (0..n).map(|r| r as f32).sum::<f32>() / n as f32
    }

    #[test]
    fn null_collective_reports_self_contribution() {
        let mut c = NullCollective;
        let mut g = vec![1.0, 2.0];
        let s = c.epoch_reduce(0, &mut g).unwrap();
        assert_eq!(g, vec![1.0, 2.0]);
        assert_eq!(s.contributions, 1);
        assert_eq!(s.messages, 0);
    }

    #[test]
    fn conv_arar_reduces_to_global_average() {
        let (grads, stats) = run_mode(Mode::ConvArar, 6, 4, 1, 33, 1, |r, _| r as f32);
        for g in &grads {
            for v in g {
                assert!((v - full_avg(6)).abs() < 1e-5, "got {v}");
            }
        }
        // N-1 messages per rank per epoch, full tensor each (unchunked).
        for s in &stats {
            assert_eq!(s.messages, 5);
            assert_eq!(s.bytes_sent, 5 * 33 * 4);
            assert_eq!(s.contributions, 6);
        }
    }

    #[test]
    fn horovod_matches_conv_arar_result() {
        let (grads, _) = run_mode(Mode::Horovod, 5, 4, 1, 8, 2, |r, _| r as f32);
        for g in &grads {
            for v in g {
                assert!((v - full_avg(5)).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn hierarchical_matches_full_average() {
        let (grads, _) = run_mode(Mode::Hierarchical, 8, 4, 1, 16, 1, |r, _| r as f32);
        for g in &grads {
            for v in g {
                assert!((v - full_avg(8)).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn tree_matches_full_average() {
        for n in [2, 3, 4, 7, 8] {
            let (grads, _) = run_mode(Mode::DoubleBinaryTree, n, 4, 1, 8, 1, |r, _| r as f32);
            for g in &grads {
                for v in g {
                    assert!((v - full_avg(n)).abs() < 1e-5, "n={n} got {v}");
                }
            }
        }
    }

    #[test]
    fn grouped_inner_only_averages_within_node() {
        // outer_freq larger than epochs -> no outer pass at all
        // (epoch 0 triggers outer when epoch % freq == 0, so use fill
        // epochs starting at 1 via epoch offset: run 1 epoch at e=0 but
        // freq 0 is invalid; instead verify group-local averaging with
        // freq = 7 and 1 epoch -> epoch 0 DOES do outer. So check inner
        // semantics using 2 nodes and freq 7 with epochs run at e=1..2.)
        let (grads, _) = run_mode(Mode::ArarArar, 8, 4, 7, 8, 3, |r, e| {
            if e < 2 {
                0.0
            } else {
                r as f32
            }
        });
        // At the last epoch (e=2, not an outer epoch since 2 % 7 != 0),
        // each rank averages only its node: node0 avg=1.5, node1 avg=5.5.
        for r in 0..4 {
            assert!((grads[r][0] - 1.5).abs() < 1e-4, "r{r} {}", grads[r][0]);
        }
        for r in 4..8 {
            assert!((grads[r][0] - 5.5).abs() < 1e-4, "r{r} {}", grads[r][0]);
        }
    }

    #[test]
    fn grouped_outer_pass_mixes_across_nodes() {
        // epoch 0 runs inner then outer (0 % freq == 0): outer members
        // exchange their inner-averaged gradients.
        let (grads, _) = run_mode(Mode::ArarArar, 8, 4, 1, 4, 1, |r, _| r as f32);
        // inner: node0 -> 1.5, node1 -> 5.5; outer over {0,4}: (1.5+5.5)/2
        assert!((grads[0][0] - 3.5).abs() < 1e-4);
        assert!((grads[4][0] - 3.5).abs() < 1e-4);
        // non-outer ranks keep the inner average
        assert!((grads[1][0] - 1.5).abs() < 1e-4);
        assert!((grads[5][0] - 5.5).abs() < 1e-4);
    }

    #[test]
    fn rma_grouped_converges_to_same_averages() {
        let (grads, stats) = run_mode(Mode::RmaArarArar, 8, 4, 1, 4, 1, |r, _| r as f32);
        assert!((grads[0][0] - 3.5).abs() < 1e-4, "{}", grads[0][0]);
        assert!((grads[1][0] - 1.5).abs() < 1e-4, "{}", grads[1][0]);
        assert!((grads[5][0] - 5.5).abs() < 1e-4, "{}", grads[5][0]);
        // RMA mode should see no timeouts in a healthy run.
        assert!(stats.iter().all(|s| s.timeouts == 0));
    }

    #[test]
    fn single_rank_modes_are_noops() {
        for mode in [Mode::ConvArar, Mode::ArarArar, Mode::Horovod] {
            let (grads, _) = run_mode(mode, 1, 4, 1, 4, 2, |_, _| 2.0);
            assert_eq!(grads[0], vec![2.0; 4]);
        }
    }

    #[test]
    fn grouping_reduces_messages_vs_conventional() {
        // The core claim behind Fig 11/12: bounded rings send fewer
        // messages per epoch than the global ring.
        let (_, conv) = run_mode(Mode::ConvArar, 12, 4, 1000, 8, 2, |r, _| r as f32);
        let (_, grp) = run_mode(Mode::ArarArar, 12, 4, 1000, 8, 2, |r, _| r as f32);
        let conv_msgs: usize = conv.iter().map(|s| s.messages).sum();
        let grp_msgs: usize = grp.iter().map(|s| s.messages).sum();
        assert!(
            grp_msgs < conv_msgs / 2,
            "grouped {grp_msgs} vs conventional {conv_msgs}"
        );
    }
}
