//! Tree all-reduce — the paper's named future-work direction (double
//! binary trees, NCCL 2.4 [18]).
//!
//! Reduce-then-broadcast along a binary tree laid out heap-style over the
//! rank ids: leaves send up, inner nodes accumulate their subtree and
//! forward, the root averages and broadcasts the result back down. Depth
//! is O(log N) versus the ring's O(N) steps — the property [18] exploits;
//! a full "double" tree additionally splits the payload across two
//! complementary trees to use both link directions, which for the unchunked
//! tensors of this paper reduces to the same per-rank traffic, so we
//! implement the single tree and account it as such in the simulator.

use std::time::Instant;

use super::{Collective, CommStats, ParkedReduce};
use crate::comm::{Endpoint, GradMsg};
use crate::tensor::ops;
use crate::util::error::Result;

/// Heap-layout binary tree over all ranks.
pub struct TreeAllReduce {
    ep: Endpoint,
    n: usize,
    parked: ParkedReduce,
}

impl TreeAllReduce {
    pub fn new(ep: Endpoint) -> TreeAllReduce {
        let n = ep.topology().ranks;
        TreeAllReduce {
            ep,
            n,
            parked: ParkedReduce::default(),
        }
    }

    fn parent(rank: usize) -> Option<usize> {
        if rank == 0 {
            None
        } else {
            Some((rank - 1) / 2)
        }
    }

    fn children(&self, rank: usize) -> Vec<usize> {
        [2 * rank + 1, 2 * rank + 2]
            .into_iter()
            .filter(|&c| c < self.n)
            .collect()
    }
}

impl Collective for TreeAllReduce {
    fn epoch_reduce(&mut self, epoch: u64, grads: &mut [f32]) -> Result<CommStats> {
        let mut stats = CommStats {
            contributions: 1,
            ..Default::default()
        };
        if self.n <= 1 {
            return Ok(stats);
        }
        let rank = self.ep.rank;
        // Up-sweep: accumulate children's subtree sums.
        for c in self.children(rank) {
            let t0 = Instant::now();
            let msg = self.ep.recv(c)?;
            stats.wait_s += t0.elapsed().as_secs_f64();
            ops::add_assign(grads, &msg.data);
            stats.contributions += 1;
        }
        if let Some(p) = Self::parent(rank) {
            self.ep
                .isend(p, GradMsg::new(rank, epoch, 0, grads.to_vec()))?;
            stats.messages += 1;
            stats.bytes_sent += grads.len() * 4;
            // Down-sweep: receive the global average from the parent.
            let t0 = Instant::now();
            let msg = self.ep.recv(p)?;
            stats.wait_s += t0.elapsed().as_secs_f64();
            grads.copy_from_slice(&msg.data);
            stats.contributions = self.n;
        } else {
            // Root: average and start the broadcast.
            ops::scale(grads, 1.0 / self.n as f32);
            stats.contributions = self.n;
        }
        for c in self.children(rank) {
            self.ep
                .isend(c, GradMsg::new(rank, epoch, 1, grads.to_vec()))?;
            stats.messages += 1;
            stats.bytes_sent += grads.len() * 4;
        }
        Ok(stats)
    }

    fn name(&self) -> &'static str {
        "dbtree"
    }

    fn parked(&mut self) -> &mut ParkedReduce {
        &mut self.parked
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{LinkModel, LocalNetwork, Topology};

    #[test]
    fn tree_structure_is_heap() {
        let topo = Topology::new(7, 4);
        let eps = LocalNetwork::build(&topo, LinkModel::zero());
        let t = TreeAllReduce::new(eps.into_iter().next().unwrap());
        assert_eq!(TreeAllReduce::parent(0), None);
        assert_eq!(TreeAllReduce::parent(1), Some(0));
        assert_eq!(TreeAllReduce::parent(6), Some(2));
        assert_eq!(t.children(0), vec![1, 2]);
        assert_eq!(t.children(2), vec![5, 6]);
        assert_eq!(t.children(3), Vec::<usize>::new());
    }

    // Full-average correctness across odd/even rank counts is covered by
    // collective::tests::tree_matches_full_average.
}
