//! Tree all-reduce — the paper's named future-work direction (double
//! binary trees, NCCL 2.4 [18]).
//!
//! Reduce-then-broadcast along a binary tree laid out heap-style over the
//! rank ids: leaves send up, inner nodes accumulate their subtree and
//! forward, the root averages and broadcasts the result back down. Depth
//! is O(log N) versus the ring's O(N) steps — the property [18] exploits;
//! a full "double" tree additionally splits the payload across two
//! complementary trees to use both link directions, which for the unchunked
//! tensors of this paper reduces to the same per-rank traffic, so we
//! implement the single tree and account it as such in the simulator.
//!
//! Payloads ride the shared [`BufferPool`]: each rank checks out what it
//! sends (one to the parent, one per child) and recycles what it receives
//! (one per child, one from the parent) — exactly balanced per rank, so
//! steady-state sweeps allocate nothing.

use std::time::Instant;

use super::{Collective, CommStats, ParkedReduce};
use crate::comm::{BufferPool, Endpoint, GradMsg};
use crate::tensor::ops;
use crate::util::error::Result;

/// Heap-layout binary tree over all ranks.
pub struct TreeAllReduce {
    ep: Endpoint,
    n: usize,
    pool: BufferPool,
    parked: ParkedReduce,
}

impl TreeAllReduce {
    pub fn new(ep: Endpoint) -> TreeAllReduce {
        let n = ep.topology().ranks;
        TreeAllReduce {
            ep,
            n,
            pool: BufferPool::new(),
            parked: ParkedReduce::default(),
        }
    }

    /// Share a run-wide buffer pool (see [`super::build_with_policy`]).
    pub fn with_pool(mut self, pool: BufferPool) -> TreeAllReduce {
        self.pool = pool;
        self
    }

    fn parent(rank: usize) -> Option<usize> {
        if rank == 0 {
            None
        } else {
            Some((rank - 1) / 2)
        }
    }

    /// The (up to two) heap children of `rank` that exist.
    fn child_iter(n: usize, rank: usize) -> impl Iterator<Item = usize> {
        [2 * rank + 1, 2 * rank + 2]
            .into_iter()
            .filter(move |&c| c < n)
    }

    #[cfg(test)]
    fn children(&self, rank: usize) -> Vec<usize> {
        Self::child_iter(self.n, rank).collect()
    }
}

impl Collective for TreeAllReduce {
    fn epoch_reduce(&mut self, epoch: u64, grads: &mut [f32]) -> Result<CommStats> {
        let mut stats = CommStats {
            contributions: 1,
            ..Default::default()
        };
        if self.n <= 1 {
            return Ok(stats);
        }
        let rank = self.ep.rank;
        // Up-sweep: accumulate children's subtree sums, recycling each
        // payload once applied.
        for c in Self::child_iter(self.n, rank) {
            let t0 = Instant::now();
            let msg = self.ep.recv(c)?;
            stats.wait_s += t0.elapsed().as_secs_f64();
            ops::add_assign(grads, &msg.data);
            stats.contributions += 1;
            self.pool.recycle_payload(msg.data, &mut stats);
        }
        if let Some(p) = Self::parent(rank) {
            let buf = self.pool.checkout_filled(grads, &mut stats);
            self.ep.isend(p, GradMsg::new(rank, epoch, 0, buf))?;
            stats.messages += 1;
            stats.bytes_sent += grads.len() * 4;
            // Down-sweep: receive the global average from the parent.
            let t0 = Instant::now();
            let msg = self.ep.recv(p)?;
            stats.wait_s += t0.elapsed().as_secs_f64();
            grads.copy_from_slice(&msg.data);
            stats.contributions = self.n;
            self.pool.recycle_payload(msg.data, &mut stats);
        } else {
            // Root: average and start the broadcast.
            ops::scale(grads, 1.0 / self.n as f32);
            stats.contributions = self.n;
        }
        for c in Self::child_iter(self.n, rank) {
            let buf = self.pool.checkout_filled(grads, &mut stats);
            self.ep.isend(c, GradMsg::new(rank, epoch, 1, buf))?;
            stats.messages += 1;
            stats.bytes_sent += grads.len() * 4;
        }
        Ok(stats)
    }

    fn name(&self) -> &'static str {
        "dbtree"
    }

    fn parked(&mut self) -> &mut ParkedReduce {
        &mut self.parked
    }

    fn buffer_pool(&self) -> Option<BufferPool> {
        Some(self.pool.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{LinkModel, LocalNetwork, Topology};

    #[test]
    fn tree_structure_is_heap() {
        let topo = Topology::new(7, 4);
        let eps = LocalNetwork::build(&topo, LinkModel::zero());
        let t = TreeAllReduce::new(eps.into_iter().next().unwrap());
        assert_eq!(TreeAllReduce::parent(0), None);
        assert_eq!(TreeAllReduce::parent(1), Some(0));
        assert_eq!(TreeAllReduce::parent(6), Some(2));
        assert_eq!(t.children(0), vec![1, 2]);
        assert_eq!(t.children(2), vec![5, 6]);
        assert_eq!(t.children(3), Vec::<usize>::new());
    }

    // Full-average correctness across odd/even rank counts is covered by
    // collective::tests::tree_matches_full_average.
}
