//! Asynchronous ring-all-reduce over the point-to-point transport —
//! Algorithm 1 of the paper, plus the bandwidth-optimal chunked variant
//! the collective engine adds on top.
//!
//! Two pass schedules:
//!
//! * [`ring_pass`] — unchunked (the paper explicitly does not split
//!   gradient tensors into chunks): every ring step forwards the *full*
//!   tensor, so one epoch of a ring of size N moves (N-1) x |g| elements
//!   per rank. This is exactly why the conventional mode's time grows with
//!   N in Fig 11 and why grouping (bounding N to the node size) flattens
//!   it.
//! * [`chunked_ring_pass`] — reduce-scatter + all-gather over N contiguous
//!   partitions (NCCL-style): 2·(N-1) steps, each moving ~|g|/N elements,
//!   for 2·(N-1)/N x |g| elements per rank total — bandwidth-optimal, and
//!   strictly less traffic than the unchunked ring for every N >= 2.
//!
//! Sends are non-blocking (`isend`); receives block — but because every
//! member sends before receiving at each step, the pass cannot deadlock.
//! Payload buffers are checked out of the caller's [`BufferPool`] at send
//! and recycled back at receive-apply; partition and sub-message bounds
//! are computed arithmetically ([`partition_at`], [`sub_bounds_iter`])
//! rather than collected into vectors, so a steady-state pass performs no
//! allocation at all (DESIGN.md §Memory discipline).

use std::time::Instant;

use super::{Collective, CommStats, ParkedReduce};
use crate::comm::{BufferPool, Endpoint, GradMsg, MembershipView, Payload, Topology};
use crate::config::ChunkPolicy;
use crate::tensor::ops;
use crate::util::error::{Error, Result};

/// Bounds of partition `i` of `n` contiguous partitions covering
/// `0..len`, sizes differing by at most one (the first `len % n`
/// partitions get the extra element). O(1), so the chunked hot loop
/// needs no bounds vector.
#[inline]
pub fn partition_at(len: usize, n: usize, i: usize) -> (usize, usize) {
    let base = len / n;
    let extra = len % n;
    let lo = i * base + i.min(extra);
    (lo, lo + base + usize::from(i < extra))
}

/// All `n` contiguous partition bounds covering `0..len` (the collected
/// form of [`partition_at`], for callers that want the whole schedule —
/// tests and the simulator; the passes themselves stay allocation-free).
pub fn partition_bounds(len: usize, n: usize) -> Vec<(usize, usize)> {
    (0..n).map(|i| partition_at(len, n, i)).collect()
}

/// Sub-message bounds within one partition `[lo, hi)`: pieces of at most
/// `max_elems` elements (0 = one piece). An empty partition yields no
/// messages. Sender and receiver compute identical splits from the shared
/// partition bounds, so no extra framing is needed — and the iterator
/// allocates nothing.
pub fn sub_bounds_iter(
    lo: usize,
    hi: usize,
    max_elems: usize,
) -> impl Iterator<Item = (usize, usize)> {
    let step = if max_elems == 0 {
        hi.saturating_sub(lo).max(1)
    } else {
        max_elems
    };
    (lo..hi)
        .step_by(step)
        .map(move |a| (a, (a + step).min(hi)))
}

/// The collected form of [`sub_bounds_iter`].
pub fn sub_bounds(lo: usize, hi: usize, max_elems: usize) -> Vec<(usize, usize)> {
    sub_bounds_iter(lo, hi, max_elems).collect()
}

/// Bytes one rank sends through a chunked pass of `n` members over a
/// `len`-element f32 tensor: the reduce-scatter and all-gather phases each
/// send every partition except one. Shared by tests and the simulator.
pub fn chunked_pass_bytes(len: usize, n: usize) -> usize {
    if n <= 1 {
        return 0;
    }
    // Reduce-scatter: rank i sends partitions i, i-1, ... (all but one);
    // all-gather: partitions i+1, i, ... (all but one). Over both phases
    // every partition is sent exactly twice except the two skipped ones —
    // per-rank totals differ only via partition remainders, so we account
    // the two phases exactly for rank 0 (callers compare per-rank sums
    // against this only for equal-size partitions, else use stats).
    let me = 0usize;
    let mut bytes = 0;
    for s in 0..n - 1 {
        let (lo, hi) = partition_at(len, n, (me + n - s) % n);
        bytes += (hi - lo) * 4;
        let (lo, hi) = partition_at(len, n, (me + n + 1 - s) % n);
        bytes += (hi - lo) * 4;
    }
    bytes
}

/// One full ring-all-reduce pass over `members` (must contain the
/// endpoint's rank). Averages in place over all members' contributions.
/// The forwarded payload is checked out of `pool` and the final received
/// buffer is recycled back, so each pass is exactly one checkout and one
/// recycle — allocation-free once the pool is warm.
pub fn ring_pass(
    ep: &Endpoint,
    members: &[usize],
    epoch: u64,
    grads: &mut [f32],
    pool: &BufferPool,
) -> Result<CommStats> {
    let n = members.len();
    let mut stats = CommStats {
        contributions: 1,
        ..Default::default()
    };
    if n <= 1 {
        return Ok(stats);
    }
    let (next, prev) = Topology::ring_in(members, ep.rank);
    // The payload to forward: starts as our own gradient (staged into a
    // pooled buffer), then becomes whatever we received (so every rank's
    // original gradient visits the whole ring exactly once).
    let mut forward = Payload::from(pool.checkout_filled(grads, &mut stats));
    for step in 0..(n - 1) as u32 {
        ep.isend(next, GradMsg::new(ep.rank, epoch, step, forward))?;
        stats.messages += 1;
        stats.bytes_sent += grads.len() * 4;
        let t0 = Instant::now();
        let msg = ep.recv(prev)?;
        stats.wait_s += t0.elapsed().as_secs_f64();
        debug_assert_eq!(msg.data.len(), grads.len());
        ops::add_assign(grads, &msg.data);
        stats.contributions += 1;
        forward = msg.data;
    }
    ops::scale(grads, 1.0 / n as f32);
    pool.recycle_payload(forward, &mut stats);
    Ok(stats)
}

/// Bandwidth-optimal chunked ring pass: reduce-scatter then all-gather
/// over `members`, averaging `grads` in place.
///
/// The tensor is split into one contiguous partition per member
/// ([`partition_at`]); `max_msg_elems` optionally splits each
/// partition transfer into smaller chunk-indexed messages (0 = one
/// message per partition). At reduce-scatter step s, the rank at ring
/// index i sends partition (i - s) mod n and accumulates partition
/// (i - s - 1) mod n, so after n-1 steps it owns the complete sum of
/// partition (i + 1) mod n; it averages that partition and the all-gather
/// phase circulates the averaged partitions back to everyone.
pub fn chunked_ring_pass(
    ep: &Endpoint,
    members: &[usize],
    epoch: u64,
    grads: &mut [f32],
    pool: &BufferPool,
    max_msg_elems: usize,
) -> Result<CommStats> {
    let n = members.len();
    let mut stats = CommStats {
        contributions: 1,
        ..Default::default()
    };
    if n <= 1 {
        return Ok(stats);
    }
    let (next, prev) = Topology::ring_in(members, ep.rank);
    let me = members
        .iter()
        .position(|&r| r == ep.rank)
        .expect("rank not in ring");
    let len = grads.len();
    let cap = max_msg_elems;
    let mut step: u32 = 0;

    // Phase 1: reduce-scatter.
    for s in 0..n - 1 {
        let si = (me + n - s) % n;
        let ri = (me + n - s - 1) % n;
        send_partition(ep, next, epoch, step, si, partition_at(len, n, si), grads, pool, cap, &mut stats)?;
        recv_partition(ep, prev, ri, partition_at(len, n, ri), grads, pool, cap, &mut stats, true)?;
        step += 1;
    }
    // Own fully-reduced partition: average it before circulating.
    let own = (me + 1) % n;
    let (lo, hi) = partition_at(len, n, own);
    ops::scale(&mut grads[lo..hi], 1.0 / n as f32);
    stats.contributions = n;

    // Phase 2: all-gather the averaged partitions.
    for s in 0..n - 1 {
        let si = (me + n + 1 - s) % n;
        let ri = (me + n - s) % n;
        send_partition(ep, next, epoch, step, si, partition_at(len, n, si), grads, pool, cap, &mut stats)?;
        recv_partition(ep, prev, ri, partition_at(len, n, ri), grads, pool, cap, &mut stats, false)?;
        step += 1;
    }
    Ok(stats)
}

/// Send one partition of `grads` as one or more chunk-indexed messages,
/// each staged into a pooled buffer.
#[allow(clippy::too_many_arguments)]
fn send_partition(
    ep: &Endpoint,
    next: usize,
    epoch: u64,
    step: u32,
    part_idx: usize,
    (lo, hi): (usize, usize),
    grads: &[f32],
    pool: &BufferPool,
    max_msg_elems: usize,
    stats: &mut CommStats,
) -> Result<()> {
    for (a, b) in sub_bounds_iter(lo, hi, max_msg_elems) {
        let buf = pool.checkout_filled(&grads[a..b], stats);
        ep.isend(
            next,
            GradMsg::chunked(ep.rank, epoch, step, part_idx as u32, buf),
        )?;
        stats.messages += 1;
        stats.bytes_sent += (b - a) * 4;
    }
    Ok(())
}

/// Receive one partition's messages; accumulate (reduce-scatter) or copy
/// (all-gather) into `grads`, recycling payload buffers into `pool`.
#[allow(clippy::too_many_arguments)]
fn recv_partition(
    ep: &Endpoint,
    prev: usize,
    part_idx: usize,
    (lo, hi): (usize, usize),
    grads: &mut [f32],
    pool: &BufferPool,
    max_msg_elems: usize,
    stats: &mut CommStats,
    accumulate: bool,
) -> Result<()> {
    for (a, b) in sub_bounds_iter(lo, hi, max_msg_elems) {
        let t0 = Instant::now();
        let msg = ep.recv(prev)?;
        stats.wait_s += t0.elapsed().as_secs_f64();
        if msg.chunk as usize != part_idx || msg.data.len() != b - a {
            return Err(Error::comm(format!(
                "chunked ring desync: expected partition {part_idx} [{a}, {b}), \
                 got chunk {} of {} elements",
                msg.chunk,
                msg.data.len()
            )));
        }
        if accumulate {
            ops::add_assign(&mut grads[a..b], &msg.data);
        } else {
            grads[a..b].copy_from_slice(&msg.data);
        }
        pool.recycle_payload(msg.data, stats);
    }
    if accumulate {
        stats.contributions += 1;
    }
    Ok(())
}

/// Conventional ARAR: one global ring over all ranks, every epoch (the
/// "ARAR / no group" row of Table II). The chunk policy selects between
/// the paper's unchunked pass (default) and the bandwidth-optimal
/// reduce-scatter + all-gather schedule.
pub struct ConvArar {
    ep: Endpoint,
    members: Vec<usize>,
    policy: ChunkPolicy,
    pool: BufferPool,
    parked: ParkedReduce,
}

impl ConvArar {
    pub fn new(ep: Endpoint) -> ConvArar {
        Self::with_policy(ep, ChunkPolicy::Unchunked)
    }

    pub fn with_policy(ep: Endpoint, policy: ChunkPolicy) -> ConvArar {
        let members = ep.topology().all_ranks();
        ConvArar {
            ep,
            members,
            policy,
            pool: BufferPool::new(),
            parked: ParkedReduce::default(),
        }
    }

    /// Draw payload buffers from a shared pool (a run's ranks share one —
    /// see [`super::build_with_policy`] — so checkout/recycle flow
    /// balances globally as buffers migrate around the ring).
    pub fn with_pool(mut self, pool: BufferPool) -> ConvArar {
        self.pool = pool;
        self
    }
}

impl Collective for ConvArar {
    fn epoch_reduce(&mut self, epoch: u64, grads: &mut [f32]) -> Result<CommStats> {
        if self.policy.is_chunked() {
            chunked_ring_pass(
                &self.ep,
                &self.members,
                epoch,
                grads,
                &self.pool,
                self.policy.max_message_elems(),
            )
        } else {
            ring_pass(&self.ep, &self.members, epoch, grads, &self.pool)
        }
    }

    fn name(&self) -> &'static str {
        "conv-arar"
    }

    fn parked(&mut self) -> &mut ParkedReduce {
        &mut self.parked
    }

    fn set_membership(&mut self, view: &MembershipView) -> Result<()> {
        if !self.parked.is_empty() {
            return Err(Error::comm(
                "set_membership with parked results in flight: drain() first",
            ));
        }
        // A dormant rank keeps no ring; the live members form the global
        // ring (ring_in panics on non-members, and a dormant rank never
        // reduces until a later view re-admits it).
        self.members = view.live().to_vec();
        Ok(())
    }

    fn buffer_pool(&self) -> Option<BufferPool> {
        Some(self.pool.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{LinkModel, LocalNetwork};

    /// Drive a pass over a subset of ranks on threads.
    fn run_ring_with(
        n: usize,
        members: Vec<usize>,
        values: Vec<f32>,
        len: usize,
        chunked: Option<usize>,
    ) -> Vec<Vec<f32>> {
        let topo = Topology::new(n, 4);
        let endpoints = LocalNetwork::build(&topo, LinkModel::zero());
        let pool = BufferPool::new();
        let handles: Vec<_> = endpoints
            .into_iter()
            .map(|ep| {
                let members = members.clone();
                let v = values[ep.rank];
                let pool = pool.clone();
                std::thread::spawn(move || {
                    let mut grads = vec![v; len];
                    if members.contains(&ep.rank) {
                        match chunked {
                            Some(max) => {
                                chunked_ring_pass(&ep, &members, 0, &mut grads, &pool, max)
                                    .unwrap();
                            }
                            None => {
                                ring_pass(&ep, &members, 0, &mut grads, &pool).unwrap();
                            }
                        }
                    }
                    grads
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    fn run_ring(n: usize, members: Vec<usize>, values: Vec<f32>) -> Vec<Vec<f32>> {
        run_ring_with(n, members, values, 5, None)
    }

    #[test]
    fn subset_ring_only_averages_members() {
        let grads = run_ring(4, vec![0, 2], vec![1.0, 10.0, 3.0, 20.0]);
        assert_eq!(grads[0], vec![2.0; 5]);
        assert_eq!(grads[2], vec![2.0; 5]);
        // Non-members untouched.
        assert_eq!(grads[1], vec![10.0; 5]);
        assert_eq!(grads[3], vec![20.0; 5]);
    }

    #[test]
    fn ring_of_three_sums_all_originals() {
        let grads = run_ring(3, vec![0, 1, 2], vec![3.0, 6.0, 9.0]);
        for g in grads {
            assert_eq!(g, vec![6.0; 5]);
        }
    }

    #[test]
    fn singleton_ring_is_identity() {
        let grads = run_ring(2, vec![0], vec![4.0, 5.0]);
        assert_eq!(grads[0], vec![4.0; 5]);
    }

    #[test]
    fn stats_count_unchunked_traffic() {
        let topo = Topology::new(3, 4);
        let eps = LocalNetwork::build(&topo, LinkModel::zero());
        let handles: Vec<_> = eps
            .into_iter()
            .map(|ep| {
                std::thread::spawn(move || {
                    let mut grads = vec![1.0f32; 100];
                    let pool = BufferPool::new();
                    ring_pass(&ep, &[0, 1, 2], 0, &mut grads, &pool).unwrap()
                })
            })
            .collect();
        for h in handles {
            let s = h.join().unwrap();
            assert_eq!(s.messages, 2); // N-1
            assert_eq!(s.bytes_sent, 2 * 100 * 4); // full tensor each step
            assert_eq!(s.contributions, 3);
        }
    }

    #[test]
    fn pooled_buffers_are_reused_across_passes() {
        // The zero-allocation contract at the pass level: the first pass
        // allocates the one buffer it stages, every later pass is served
        // by the buffer recycled from the previous receive.
        let topo = Topology::new(2, 4);
        let eps = LocalNetwork::build(&topo, LinkModel::zero());
        let handles: Vec<_> = eps
            .into_iter()
            .map(|ep| {
                std::thread::spawn(move || {
                    let pool = BufferPool::new();
                    let mut grads = vec![1.0f32; 64];
                    ring_pass(&ep, &[0, 1], 0, &mut grads, &pool).unwrap();
                    assert_eq!(pool.stats().allocs, 1);
                    for e in 1..10 {
                        let s = ring_pass(&ep, &[0, 1], e, &mut grads, &pool).unwrap();
                        assert_eq!(s.allocs, 0, "steady-state pass allocated");
                        assert_eq!(s.pool_hits, 1);
                        assert_eq!(s.bytes_recycled, 64 * 4);
                    }
                    let st = pool.stats();
                    assert_eq!(st.allocs, 1);
                    assert_eq!(st.hits, 9);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn partition_bounds_cover_and_balance() {
        for (len, n) in [(10, 3), (7, 7), (3, 5), (0, 4), (51_206, 8)] {
            let parts = partition_bounds(len, n);
            assert_eq!(parts.len(), n);
            assert_eq!(parts[0].0, 0);
            assert_eq!(parts[n - 1].1, len);
            let mut prev_end = 0;
            let mut min = usize::MAX;
            let mut max = 0;
            for (i, &(lo, hi)) in parts.iter().enumerate() {
                assert_eq!(lo, prev_end);
                prev_end = hi;
                min = min.min(hi - lo);
                max = max.max(hi - lo);
                // The O(1) form agrees with the collected schedule.
                assert_eq!(partition_at(len, n, i), (lo, hi));
            }
            assert!(max - min <= 1, "unbalanced: len={len} n={n}");
        }
    }

    #[test]
    fn sub_bounds_split_and_degenerate_cases() {
        assert_eq!(sub_bounds(4, 10, 0), vec![(4, 10)]);
        assert_eq!(sub_bounds(4, 10, 100), vec![(4, 10)]);
        assert_eq!(sub_bounds(4, 10, 4), vec![(4, 8), (8, 10)]);
        assert_eq!(sub_bounds(5, 5, 3), Vec::<(usize, usize)>::new());
    }

    #[test]
    fn chunked_pass_matches_unchunked_average() {
        for (n, len, max) in [(4usize, 32usize, 0usize), (3, 7, 2), (5, 5, 1), (2, 9, 4)] {
            let values: Vec<f32> = (0..n).map(|r| r as f32 * 3.0 + 1.0).collect();
            let expected: f32 = values.iter().sum::<f32>() / n as f32;
            let members: Vec<usize> = (0..n).collect();
            let grads = run_ring_with(n, members, values, len, Some(max));
            for g in &grads {
                for v in g {
                    assert!((v - expected).abs() < 1e-5, "n={n} len={len} got {v}");
                }
            }
        }
    }

    #[test]
    fn chunked_subset_ring_only_touches_members() {
        let grads = run_ring_with(4, vec![1, 3], vec![1.0, 4.0, 9.0, 8.0], 11, Some(3));
        assert_eq!(grads[1], vec![6.0; 11]);
        assert_eq!(grads[3], vec![6.0; 11]);
        assert_eq!(grads[0], vec![1.0; 11]);
        assert_eq!(grads[2], vec![9.0; 11]);
    }

    #[test]
    fn chunked_stats_count_bandwidth_optimal_traffic() {
        // len divisible by n: every rank sends exactly 2·(n-1)·(len/n)
        // elements — strictly below the unchunked (n-1)·len for n >= 2.
        let n = 4;
        let len = 100;
        let topo = Topology::new(n, 4);
        let eps = LocalNetwork::build(&topo, LinkModel::zero());
        let members: Vec<usize> = (0..n).collect();
        let handles: Vec<_> = eps
            .into_iter()
            .map(|ep| {
                let members = members.clone();
                std::thread::spawn(move || {
                    let mut grads = vec![1.0f32; len];
                    let pool = BufferPool::new();
                    chunked_ring_pass(&ep, &members, 0, &mut grads, &pool, 0).unwrap()
                })
            })
            .collect();
        let unchunked_bytes = (n - 1) * len * 4;
        for h in handles {
            let s = h.join().unwrap();
            assert_eq!(s.messages, 2 * (n - 1));
            assert_eq!(s.bytes_sent, 2 * (n - 1) * (len / n) * 4);
            assert_eq!(s.bytes_sent, chunked_pass_bytes(len, n));
            assert!(s.bytes_sent < unchunked_bytes);
            assert_eq!(s.contributions, n);
        }
    }

    #[test]
    fn chunked_message_cap_raises_message_count_not_bytes() {
        let n = 2;
        let len = 10; // partitions of 5; cap 2 -> 3 messages per transfer
        let topo = Topology::new(n, 4);
        let eps = LocalNetwork::build(&topo, LinkModel::zero());
        let handles: Vec<_> = eps
            .into_iter()
            .map(|ep| {
                std::thread::spawn(move || {
                    let mut grads = vec![1.0f32; len];
                    let pool = BufferPool::new();
                    chunked_ring_pass(&ep, &[0, 1], 0, &mut grads, &pool, 2).unwrap()
                })
            })
            .collect();
        for h in handles {
            let s = h.join().unwrap();
            // 2 partition transfers of 5 elements, 3 sub-messages each.
            assert_eq!(s.messages, 6);
            assert_eq!(s.bytes_sent, 2 * 5 * 4);
        }
    }

    #[test]
    fn conv_arar_re_rings_to_the_live_subset() {
        // 4 ranks; after rank 2 leaves, the surviving ring {0,1,3} must
        // average exactly its members — the elastic re-ring contract.
        let topo = Topology::new(4, 4);
        let eps = LocalNetwork::build(&topo, LinkModel::zero());
        let view = MembershipView::new(1, vec![0, 1, 3], 4);
        let handles: Vec<_> = eps
            .into_iter()
            .map(|ep| {
                let view = view.clone();
                let rank = ep.rank;
                let v = rank as f32;
                std::thread::spawn(move || {
                    let mut c = ConvArar::new(ep);
                    c.set_membership(&view).unwrap();
                    if !view.is_live(rank) {
                        return (rank, vec![v; 5]);
                    }
                    let mut grads = vec![v; 5];
                    c.epoch_reduce(0, &mut grads).unwrap();
                    (rank, grads)
                })
            })
            .collect();
        for h in handles {
            let (rank, g) = h.join().unwrap();
            let expect = if rank == 2 { 2.0 } else { (0.0 + 1.0 + 3.0) / 3.0 };
            for v in g {
                assert!((v - expect).abs() < 1e-5, "rank {rank} got {v}");
            }
        }
    }

    #[test]
    fn conv_arar_with_policy_dispatches_chunked() {
        let topo = Topology::new(4, 4);
        let eps = LocalNetwork::build(&topo, LinkModel::zero());
        let handles: Vec<_> = eps
            .into_iter()
            .map(|ep| {
                let v = ep.rank as f32;
                std::thread::spawn(move || {
                    let mut c = ConvArar::with_policy(ep, ChunkPolicy::Auto);
                    let mut grads = vec![v; 13];
                    let s = c.epoch_reduce(0, &mut grads).unwrap();
                    (grads, s)
                })
            })
            .collect();
        for h in handles {
            let (g, s) = h.join().unwrap();
            for v in g {
                assert!((v - 1.5).abs() < 1e-5);
            }
            assert_eq!(s.messages, 6); // 2·(n-1)
        }
    }
}
