//! Asynchronous ring-all-reduce over the point-to-point transport —
//! Algorithm 1 of the paper.
//!
//! Unchunked (the paper explicitly does not split gradient tensors into
//! chunks): every ring step forwards the *full* tensor, so one epoch of a
//! ring of size N moves (N-1) x |g| elements per rank. This is exactly why
//! the conventional mode's time grows with N in Fig 11 and why grouping
//! (bounding N to the node size) flattens it.
//!
//! Sends are non-blocking (`isend`); receives block — but because every
//! member sends before receiving at each step, the pass cannot deadlock.

use std::time::Instant;

use super::{Collective, CommStats};
use crate::comm::{Endpoint, GradMsg, Topology};
use crate::tensor::ops;
use crate::util::error::Result;

/// One full ring-all-reduce pass over `members` (must contain the
/// endpoint's rank). Averages in place over all members' contributions.
pub fn ring_pass(
    ep: &Endpoint,
    members: &[usize],
    epoch: u64,
    grads: &mut [f32],
) -> Result<CommStats> {
    let n = members.len();
    let mut stats = CommStats {
        contributions: 1,
        ..Default::default()
    };
    if n <= 1 {
        return Ok(stats);
    }
    let (next, prev) = Topology::ring_in(members, ep.rank);
    // The payload to forward: starts as our own gradient, then becomes
    // whatever we received (so every rank's original gradient visits the
    // whole ring exactly once).
    let mut forward = grads.to_vec();
    for step in 0..(n - 1) as u32 {
        ep.isend(next, GradMsg::new(ep.rank, epoch, step, forward))?;
        stats.messages += 1;
        stats.bytes_sent += grads.len() * 4;
        let t0 = Instant::now();
        let msg = ep.recv(prev)?;
        stats.wait_s += t0.elapsed().as_secs_f64();
        debug_assert_eq!(msg.data.len(), grads.len());
        ops::add_assign(grads, &msg.data);
        stats.contributions += 1;
        forward = msg.data;
    }
    ops::scale(grads, 1.0 / n as f32);
    Ok(stats)
}

/// Conventional ARAR: one global ring over all ranks, every epoch (the
/// "ARAR / no group" row of Table II).
pub struct ConvArar {
    ep: Endpoint,
    members: Vec<usize>,
}

impl ConvArar {
    pub fn new(ep: Endpoint) -> ConvArar {
        let members = ep.topology().all_ranks();
        ConvArar { ep, members }
    }
}

impl Collective for ConvArar {
    fn epoch_reduce(&mut self, epoch: u64, grads: &mut [f32]) -> Result<CommStats> {
        ring_pass(&self.ep, &self.members, epoch, grads)
    }

    fn name(&self) -> &'static str {
        "conv-arar"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{LinkModel, LocalNetwork};

    /// Drive a ring pass over a subset of ranks on threads.
    fn run_ring(n: usize, members: Vec<usize>, values: Vec<f32>) -> Vec<Vec<f32>> {
        let topo = Topology::new(n, 4);
        let endpoints = LocalNetwork::build(&topo, LinkModel::zero());
        let handles: Vec<_> = endpoints
            .into_iter()
            .map(|ep| {
                let members = members.clone();
                let v = values[ep.rank];
                std::thread::spawn(move || {
                    let mut grads = vec![v; 5];
                    if members.contains(&ep.rank) {
                        ring_pass(&ep, &members, 0, &mut grads).unwrap();
                    }
                    grads
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn subset_ring_only_averages_members() {
        let grads = run_ring(4, vec![0, 2], vec![1.0, 10.0, 3.0, 20.0]);
        assert_eq!(grads[0], vec![2.0; 5]);
        assert_eq!(grads[2], vec![2.0; 5]);
        // Non-members untouched.
        assert_eq!(grads[1], vec![10.0; 5]);
        assert_eq!(grads[3], vec![20.0; 5]);
    }

    #[test]
    fn ring_of_three_sums_all_originals() {
        let grads = run_ring(3, vec![0, 1, 2], vec![3.0, 6.0, 9.0]);
        for g in grads {
            assert_eq!(g, vec![6.0; 5]);
        }
    }

    #[test]
    fn singleton_ring_is_identity() {
        let grads = run_ring(2, vec![0], vec![4.0, 5.0]);
        assert_eq!(grads[0], vec![4.0; 5]);
    }

    #[test]
    fn stats_count_unchunked_traffic() {
        let topo = Topology::new(3, 4);
        let eps = LocalNetwork::build(&topo, LinkModel::zero());
        let handles: Vec<_> = eps
            .into_iter()
            .map(|ep| {
                std::thread::spawn(move || {
                    let mut grads = vec![1.0f32; 100];
                    ring_pass(&ep, &[0, 1, 2], 0, &mut grads).unwrap()
                })
            })
            .collect();
        for h in handles {
            let s = h.join().unwrap();
            assert_eq!(s.messages, 2); // N-1
            assert_eq!(s.bytes_sent, 2 * 100 * 4); // full tensor each step
            assert_eq!(s.contributions, 3);
        }
    }
}
