//! Grouped gradient exchange (Sec. IV-B4, Fig 6, Table I).
//!
//! Ranks are divided into *inner groups* — one per node, ring-all-reduce
//! every epoch — and one *outer group* holding the first rank of each inner
//! group, ring-all-reduce every `h` epochs (`outer_freq`). Gradients reach
//! other nodes through the outer members and diffuse into each node on the
//! following inner passes; unlike the hierarchical all-reduce of [16] there
//! is no third broadcast step and no master rank.
//!
//! Two flavours, matching Table II:
//! * [`GroupedArar`]   — inner ring over the transport ("ARAR-ARAR").
//! * [`RmaGroupedArar`] — inner ring over RMA windows ("RMA-ARAR-ARAR");
//!   the outer ring stays transport-based in both (as in the paper).
//!
//! Both flavours compose with the chunked reduce-scatter + all-gather
//! schedule via [`crate::config::ChunkPolicy`]: the policy applies to the
//! inner *and* outer rings, so grouped chunked traffic is bandwidth-
//! optimal at both levels.

use super::ring::{chunked_ring_pass, ring_pass};
use super::rma_ring::RmaRing;
use super::{Collective, CommStats, ParkedReduce};
use crate::comm::{BufferPool, Endpoint, MembershipView, RmaRegion, Topology};
use crate::config::ChunkPolicy;
use crate::util::error::{Error, Result};

/// Whether epoch `e` is an outer-group exchange epoch.
///
/// The paper communicates across nodes "every h epochs". We count full
/// periods: the first outer exchange happens once `h` epochs have run
/// (epoch h-1), then every `h` epochs after. Epoch 0 therefore does *not*
/// fire for h > 1 — a frequency knob should not produce an exchange before
/// one period has elapsed.
pub fn is_outer_epoch(epoch: u64, outer_freq: usize) -> bool {
    outer_freq > 0 && (epoch + 1) % outer_freq as u64 == 0
}

/// Run one ring pass over `members` with the given chunk policy, drawing
/// payload buffers from `pool`.
fn policy_pass(
    ep: &Endpoint,
    members: &[usize],
    epoch: u64,
    grads: &mut [f32],
    policy: ChunkPolicy,
    pool: &BufferPool,
) -> Result<CommStats> {
    if policy.is_chunked() {
        chunked_ring_pass(ep, members, epoch, grads, pool, policy.max_message_elems())
    } else {
        ring_pass(ep, members, epoch, grads, pool)
    }
}

/// ARAR-ARAR: transport rings for both levels.
pub struct GroupedArar {
    ep: Endpoint,
    inner_members: Vec<usize>,
    outer_members: Vec<usize>,
    is_outer: bool,
    outer_freq: usize,
    policy: ChunkPolicy,
    pool: BufferPool,
    parked: ParkedReduce,
}

impl GroupedArar {
    pub fn new(ep: Endpoint, outer_freq: usize) -> GroupedArar {
        Self::with_policy(ep, outer_freq, ChunkPolicy::Unchunked)
    }

    pub fn with_policy(ep: Endpoint, outer_freq: usize, policy: ChunkPolicy) -> GroupedArar {
        let topo = ep.topology().clone();
        let rank = ep.rank;
        GroupedArar {
            inner_members: topo.inner_group(rank),
            outer_members: topo.outer_group(),
            is_outer: topo.is_outer_member(rank),
            outer_freq,
            policy,
            pool: BufferPool::new(),
            parked: ParkedReduce::default(),
            ep,
        }
    }

    /// Share a run-wide buffer pool (see [`super::build_with_policy`]).
    pub fn with_pool(mut self, pool: BufferPool) -> GroupedArar {
        self.pool = pool;
        self
    }
}

impl Collective for GroupedArar {
    fn epoch_reduce(&mut self, epoch: u64, grads: &mut [f32]) -> Result<CommStats> {
        // Inner-group ring every epoch.
        let mut stats = policy_pass(
            &self.ep,
            &self.inner_members,
            epoch,
            grads,
            self.policy,
            &self.pool,
        )?;
        // Outer-group ring every h epochs, members only.
        if self.is_outer && is_outer_epoch(epoch, self.outer_freq) {
            let outer = policy_pass(
                &self.ep,
                &self.outer_members,
                epoch,
                grads,
                self.policy,
                &self.pool,
            )?;
            stats.merge(&outer);
        }
        Ok(stats)
    }

    fn name(&self) -> &'static str {
        "arar-arar"
    }

    fn parked(&mut self) -> &mut ParkedReduce {
        &mut self.parked
    }

    fn set_membership(&mut self, view: &MembershipView) -> Result<()> {
        if !self.parked.is_empty() {
            return Err(Error::comm(
                "set_membership with parked results in flight: drain() first",
            ));
        }
        let topo = self.ep.topology().clone();
        let rank = self.ep.rank;
        if !view.is_live(rank) {
            // Dormant: keep the stale schedule; the rank must not reduce
            // until a later view re-admits it (and re-rings it then).
            return Ok(());
        }
        self.inner_members = topo.inner_group_live(rank, view);
        self.outer_members = topo.outer_group_live(view);
        self.is_outer = topo.is_outer_member_live(rank, view);
        Ok(())
    }

    fn buffer_pool(&self) -> Option<BufferPool> {
        Some(self.pool.clone())
    }
}

/// RMA-ARAR-ARAR: RMA windows for the inner ring, transport for the outer.
pub struct RmaGroupedArar {
    ep: Endpoint,
    inner: RmaRing,
    /// Region handle kept for elastic re-rings: windows are shared `Arc`
    /// state, so rebuilding an [`RmaRing`] over a new live subset reuses
    /// the same windows.
    region: RmaRegion,
    outer_members: Vec<usize>,
    is_outer: bool,
    outer_freq: usize,
    policy: ChunkPolicy,
    pool: BufferPool,
    parked: ParkedReduce,
}

impl RmaGroupedArar {
    pub fn new(
        ep: Endpoint,
        outer_freq: usize,
        topo: &Topology,
        region: &RmaRegion,
        rank: usize,
    ) -> Result<RmaGroupedArar> {
        Self::with_policy(ep, outer_freq, topo, region, rank, ChunkPolicy::Unchunked)
    }

    pub fn with_policy(
        ep: Endpoint,
        outer_freq: usize,
        topo: &Topology,
        region: &RmaRegion,
        rank: usize,
        policy: ChunkPolicy,
    ) -> Result<RmaGroupedArar> {
        let inner = RmaRing::new(region, topo.inner_group(rank), rank)?;
        let pool = inner.pool.clone();
        Ok(RmaGroupedArar {
            inner,
            region: region.clone(),
            outer_members: topo.outer_group(),
            is_outer: topo.is_outer_member(rank),
            outer_freq,
            policy,
            pool,
            parked: ParkedReduce::default(),
            ep,
        })
    }

    /// Share a run-wide buffer pool across the outer ring *and* the inner
    /// RMA ring (see [`super::build_with_policy`]).
    pub fn with_pool(mut self, pool: BufferPool) -> RmaGroupedArar {
        self.inner.pool = pool.clone();
        self.pool = pool;
        self
    }
}

impl Collective for RmaGroupedArar {
    fn epoch_reduce(&mut self, epoch: u64, grads: &mut [f32]) -> Result<CommStats> {
        let mut stats = if self.policy.is_chunked() {
            self.inner
                .pass_chunked(epoch, grads, self.policy.max_message_elems())?
        } else {
            self.inner.pass(epoch, grads)?
        };
        if self.is_outer && is_outer_epoch(epoch, self.outer_freq) {
            let outer = policy_pass(
                &self.ep,
                &self.outer_members,
                epoch,
                grads,
                self.policy,
                &self.pool,
            )?;
            stats.merge(&outer);
        }
        Ok(stats)
    }

    fn name(&self) -> &'static str {
        "rma-arar-arar"
    }

    fn parked(&mut self) -> &mut ParkedReduce {
        &mut self.parked
    }

    fn set_membership(&mut self, view: &MembershipView) -> Result<()> {
        if !self.parked.is_empty() {
            return Err(Error::comm(
                "set_membership with parked results in flight: drain() first",
            ));
        }
        let topo = self.ep.topology().clone();
        let rank = self.ep.rank;
        if !view.is_live(rank) {
            return Ok(());
        }
        // Rebuild the inner RMA ring over the node's live subset from the
        // shared region handle; the outer ring stays transport-based, and
        // the rebuilt ring keeps drawing from the shared pool.
        let timeout = self.inner.get_timeout;
        let mut inner = RmaRing::new(&self.region, topo.inner_group_live(rank, view), rank)?;
        inner.get_timeout = timeout;
        inner.pool = self.pool.clone();
        self.inner = inner;
        self.outer_members = topo.outer_group_live(view);
        self.is_outer = topo.is_outer_member_live(rank, view);
        Ok(())
    }

    fn buffer_pool(&self) -> Option<BufferPool> {
        Some(self.pool.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn outer_epoch_schedule_counts_full_periods() {
        // h = 1000 (paper): the first cross-node exchange happens after a
        // full period (epoch 999), then every 1000 epochs.
        assert!(!is_outer_epoch(0, 1000));
        assert!(!is_outer_epoch(1, 1000));
        assert!(is_outer_epoch(999, 1000));
        assert!(!is_outer_epoch(1000, 1000));
        assert!(is_outer_epoch(1999, 1000));
        assert!(!is_outer_epoch(5, 0)); // freq 0 = never (ungrouped modes)
        // h = 1 degenerates to every epoch, including epoch 0.
        assert!(is_outer_epoch(0, 1));
        assert!(is_outer_epoch(7, 1));
    }

    // Cross-thread behaviour of both grouped modes is covered by
    // collective::tests (grouped_inner_only_averages_within_node,
    // grouped_outer_pass_mixes_across_nodes, rma_grouped_converges...).
}
