//! Grouped gradient exchange (Sec. IV-B4, Fig 6, Table I).
//!
//! Ranks are divided into *inner groups* — one per node, ring-all-reduce
//! every epoch — and one *outer group* holding the first rank of each inner
//! group, ring-all-reduce every `h` epochs (`outer_freq`). Gradients reach
//! other nodes through the outer members and diffuse into each node on the
//! following inner passes; unlike the hierarchical all-reduce of [16] there
//! is no third broadcast step and no master rank.
//!
//! Two flavours, matching Table II:
//! * [`GroupedArar`]   — inner ring over the transport ("ARAR-ARAR").
//! * [`RmaGroupedArar`] — inner ring over RMA windows ("RMA-ARAR-ARAR");
//!   the outer ring stays transport-based in both (as in the paper).

use super::ring::ring_pass;
use super::rma_ring::RmaRing;
use super::{Collective, CommStats};
use crate::comm::{Endpoint, RmaRegion, Topology};
use crate::util::error::Result;

/// Whether epoch `e` is an outer-group exchange epoch.
/// The paper communicates across nodes "every h epochs"; epoch 0 counts.
pub fn is_outer_epoch(epoch: u64, outer_freq: usize) -> bool {
    outer_freq > 0 && epoch % outer_freq as u64 == 0
}

/// ARAR-ARAR: transport rings for both levels.
pub struct GroupedArar {
    ep: Endpoint,
    inner_members: Vec<usize>,
    outer_members: Vec<usize>,
    is_outer: bool,
    outer_freq: usize,
}

impl GroupedArar {
    pub fn new(ep: Endpoint, outer_freq: usize) -> GroupedArar {
        let topo = ep.topology().clone();
        let rank = ep.rank;
        GroupedArar {
            inner_members: topo.inner_group(rank),
            outer_members: topo.outer_group(),
            is_outer: topo.is_outer_member(rank),
            outer_freq,
            ep,
        }
    }
}

impl Collective for GroupedArar {
    fn epoch_reduce(&mut self, epoch: u64, grads: &mut [f32]) -> Result<CommStats> {
        // Inner-group ring every epoch.
        let mut stats = ring_pass(&self.ep, &self.inner_members, epoch, grads)?;
        // Outer-group ring every h epochs, members only.
        if self.is_outer && is_outer_epoch(epoch, self.outer_freq) {
            let outer = ring_pass(&self.ep, &self.outer_members, epoch, grads)?;
            stats.merge(&outer);
        }
        Ok(stats)
    }

    fn name(&self) -> &'static str {
        "arar-arar"
    }
}

/// RMA-ARAR-ARAR: RMA windows for the inner ring, transport for the outer.
pub struct RmaGroupedArar {
    ep: Endpoint,
    inner: RmaRing,
    outer_members: Vec<usize>,
    is_outer: bool,
    outer_freq: usize,
}

impl RmaGroupedArar {
    pub fn new(
        ep: Endpoint,
        outer_freq: usize,
        topo: &Topology,
        region: &RmaRegion,
        rank: usize,
    ) -> Result<RmaGroupedArar> {
        let inner = RmaRing::new(region, topo.inner_group(rank), rank)?;
        Ok(RmaGroupedArar {
            inner,
            outer_members: topo.outer_group(),
            is_outer: topo.is_outer_member(rank),
            outer_freq,
            ep,
        })
    }
}

impl Collective for RmaGroupedArar {
    fn epoch_reduce(&mut self, epoch: u64, grads: &mut [f32]) -> Result<CommStats> {
        let mut stats = self.inner.pass(epoch, grads)?;
        if self.is_outer && is_outer_epoch(epoch, self.outer_freq) {
            let outer = ring_pass(&self.ep, &self.outer_members, epoch, grads)?;
            stats.merge(&outer);
        }
        Ok(stats)
    }

    fn name(&self) -> &'static str {
        "rma-arar-arar"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn outer_epoch_schedule_matches_table1() {
        // h = 1000 (paper): epochs 0, 1000, 2000 communicate across nodes.
        assert!(is_outer_epoch(0, 1000));
        assert!(!is_outer_epoch(1, 1000));
        assert!(!is_outer_epoch(999, 1000));
        assert!(is_outer_epoch(1000, 1000));
        assert!(is_outer_epoch(2000, 1000));
        assert!(!is_outer_epoch(5, 0)); // freq 0 = never (ungrouped modes)
    }

    // Cross-thread behaviour of both grouped modes is covered by
    // collective::tests (grouped_inner_only_averages_within_node,
    // grouped_outer_pass_mixes_across_nodes, rma_grouped_converges...).
}
