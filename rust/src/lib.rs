//! # SAGIPS — Scalable Asynchronous Generative Inverse Problem Solver
//!
//! A full reproduction of the SAGIPS system (Lersch et al., CS.DC 2024) as a
//! three-layer Rust + JAX + Pallas stack, grown into a multi-workload
//! inverse-problem solver:
//!
//! * **Layer 3 (this crate)** — the paper's coordination contribution: the
//!   distributed GAN training runtime. Staged per-rank training pipelines
//!   with a bounded-staleness exchange window ([`coordinator::pipeline`],
//!   `staleness: 0 | 1 | k`), asynchronous ring-all-reduce gradient
//!   exchange (conventional, grouped, and RMA-based), gradient
//!   off-loading, bootstrap data sharding, ensemble analysis, and a
//!   calibrated discrete-event simulator for the scaling studies.
//! * **Layer 2** — the GAN + environment pipeline authored in JAX
//!   (`python/compile/`), AOT-lowered to HLO text at build time.
//! * **Layer 1** — Pallas kernels for the dense GAN layers and the
//!   inverse-CDF event sampler (`python/compile/kernels/`).
//!
//! Python never runs on the request path: the [`runtime`] module loads the
//! `artifacts/*.hlo.txt` files through the PJRT C API (`xla` crate) and the
//! coordinator executes them from Rust. The pure-Rust native backend
//! ([`runtime::native`]) needs no artifacts at all.
//!
//! The *problem being solved* is pluggable: the [`scenario`] module defines
//! the [`scenario::Scenario`] trait (forward operator, analytic VJP, ground
//! truth, shapes) plus a registry of built-in inverse problems — the
//! paper's quantile proxy app, a 10-parameter 1-D linear deconvolution,
//! and a nonlinear saturation-recovery problem — selected per run via
//! [`config::RunConfig::scenario`] / `--scenario <name>`. The residual and
//! ensemble analysis layers size themselves from the scenario's parameter
//! width, and long runs are restartable: periodic run checkpoints
//! (`ckpt_every` / `ckpt_dir`) restore bit-identically through
//! `--resume` (see `docs/checkpointing.md` at the repo root). The [`fault`]
//! module injects deterministic, seed-driven stragglers beneath the
//! transport, and the pipeline's `on_straggler` policies (skip /
//! late-apply with exchange deadlines) keep training live through them
//! (see `docs/fault-tolerance.md`). Beyond one-shot runs, the [`service`]
//! module turns the trainer into a long-running job daemon (`sagips
//! serve`): a journaled priority queue, a multiplexing scheduler with
//! admission control, and cooperative cancellation that always leaves a
//! resumable checkpoint (see `docs/serve.md`).
//!
//! # Quickstart: config to training
//!
//! A complete run on the native backend — no artifact export, no feature
//! flags — against a non-default scenario:
//!
//! ```
//! use sagips::config::presets;
//! use sagips::coordinator::launcher::run_training_from_config;
//!
//! let mut cfg = presets::ci_default();
//! cfg.scenario = "deconv".into();         // see `sagips scenarios`
//! cfg.model = "small".into();
//! cfg.ranks = 2;
//! cfg.epochs = 3;
//! cfg.batch = 4;
//! cfg.data_pool = 1600;
//! cfg.artifacts_dir = "/nonexistent".into(); // force the synthetic manifest
//!
//! let run = run_training_from_config(&cfg).unwrap();
//! assert_eq!(run.metrics.mean_series("gen_loss").len(), 3);
//! assert!(run.final_residuals.unwrap().iter().all(|r| r.is_finite()));
//! ```
//!
//! See `README.md` for the CLI quickstart and `DESIGN.md` (repo root) for
//! the paper -> module map, the collective-engine design notes, and the
//! scenario-subsystem contract; the per-figure bench binaries under
//! `benches/` regenerate the reproduced tables and figures.

pub mod collective;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod ensemble;
pub mod fault;
pub mod metrics;
pub mod model;
pub mod optim;
pub mod report;
pub mod runtime;
pub mod scenario;
pub mod service;
pub mod sim;
pub mod tensor;
pub mod util;

/// Crate-wide error type.
pub use util::error::{Error, Result};
