//! # SAGIPS — Scalable Asynchronous Generative Inverse Problem Solver
//!
//! A full reproduction of the SAGIPS system (Lersch et al., CS.DC 2024) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the paper's coordination contribution: the
//!   distributed GAN training runtime. Per-rank training loops, asynchronous
//!   ring-all-reduce gradient exchange (conventional, grouped, and
//!   RMA-based), gradient off-loading, bootstrap data sharding, ensemble
//!   analysis, and a calibrated discrete-event simulator for the scaling
//!   studies.
//! * **Layer 2** — the GAN + environment pipeline authored in JAX
//!   (`python/compile/`), AOT-lowered to HLO text at build time.
//! * **Layer 1** — Pallas kernels for the dense GAN layers and the
//!   inverse-CDF event sampler (`python/compile/kernels/`).
//!
//! Python never runs on the request path: the [`runtime`] module loads the
//! `artifacts/*.hlo.txt` files through the PJRT C API (`xla` crate) and the
//! coordinator executes them from Rust.
//!
//! See `DESIGN.md` (repo root) for the paper -> module map and the
//! collective-engine design notes; the per-figure bench binaries under
//! `benches/` regenerate the reproduced tables and figures.

pub mod collective;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod ensemble;
pub mod metrics;
pub mod model;
pub mod optim;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod tensor;
pub mod util;

/// Crate-wide error type.
pub use util::error::{Error, Result};
