//! `sagips` — the SAGIPS leader CLI.
//!
//! Subcommands:
//!   train                run one distributed training (mode/ranks/epochs)
//!   ensemble             train an ensemble and report eq (7)/(8) response
//!   simulate             scaling simulator sweep (Figs 11/12)
//!   experiment <id>      regenerate a paper figure/table (fig8..fig16, tab4)
//!   scenarios            list the registered inverse-problem scenarios
//!   validate-artifacts   load + smoke-run every artifact in the manifest
//!   serve                run the job daemon (journaled queue + scheduler)
//!   job <verb>           client verbs against a running daemon
//!                        (submit|status|cancel|list|reload|compact|ping|shutdown)
//!
//! Run `sagips help` for options.

use std::path::{Path, PathBuf};

use sagips::config::{presets, BackendKind, ChunkPolicy, Mode, RunConfig};
use sagips::coordinator::launcher::run_training;
use sagips::ensemble::analysis::EnsembleResult;
use sagips::model::residuals;
use sagips::report::experiments::{self, Scale};
use sagips::report::{format_table4, table4_paper_reference, Table4Row};
use sagips::runtime::Runtime;
use sagips::service::{client_roundtrip, protocol, Daemon, ServeLimits, TrainingRunner};
use sagips::sim::ComputeModel;
use sagips::util::json::Value;
use sagips::util::cli::{self, Args, OptSpec};
use sagips::util::error::{Error, Result};
use sagips::util::logging;

fn main() {
    logging::init_from_env();
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(&args) {
        Ok(()) => {}
        Err(Error::Usage(msg)) => {
            eprintln!("usage error: {msg}\n");
            print_help();
            std::process::exit(2);
        }
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    }
}

fn print_help() {
    println!(
        "sagips — Scalable Asynchronous Generative Inverse Problem Solver\n\n\
         subcommands:\n  \
         train                one distributed training run\n  \
         ensemble             ensemble of runs + eq (7)/(8) response\n  \
         simulate             scaling sweep (DES, Figs 11/12)\n  \
         experiment <id>      regenerate fig8..fig16 / tab4\n  \
         scenarios            list registered inverse-problem scenarios\n  \
         validate-artifacts   smoke-run every artifact\n  \
         serve                job daemon: journaled queue, scheduler, cancellation\n  \
         job <verb>           submit|status|cancel|list|reload|compact|ping|shutdown\n\n\
         common options: --scenario <name> --backend native|pjrt --artifacts <dir> \
         --workers <n> --seed <n>\n\
         engine: --chunking unchunked|auto|<elems> --staleness <k> \
         (0 = blocking, 1 = overlap, k = bounded window) \
         --intra-threads <n> (native gan_step workers, 0 = serial)\n\
         fault tolerance: --ckpt-every <n> --ckpt-dir <dir> --ckpt-keep <n> \
         --resume <path> --fault-plan <json|file> --exchange-timeout-ms <n> \
         --on-straggler block|skip|late_apply --skip-budget <n>\n\
         elastic membership: --membership \"leave:R@E,join:R@E\" --min-ranks <n> \
         --evict-after <n> --allow-join\n\
         serving: sagips serve --state-dir <dir> [--stdio] \
         --max-concurrent-jobs <n> --max-queued <n>; \
         sagips job submit --scenario <name> [--priority <p>] [--name <s>] \
         (see docs/serve.md)\n\
         (the native backend needs no artifacts and runs every scenario; \
         pjrt executes the exported HLO)\n\
         env: SAGIPS_LOG=debug, SAGIPS_SCALE=smoke|ci|paper"
    );
}

fn common_specs() -> Vec<OptSpec> {
    vec![
        cli::opt("config", "JSON config file (CLI options override it)", None),
        cli::opt(
            "scenario",
            "inverse-problem scenario (see `sagips scenarios`)",
            Some("quantile"),
        ),
        cli::opt("backend", "execution backend: native|pjrt", None),
        cli::opt("artifacts", "artifacts directory", Some("artifacts")),
        cli::opt("workers", "runtime pool workers", Some("2")),
        cli::opt("seed", "base RNG seed", Some("20240")),
        cli::opt("ranks", "number of ranks", Some("4")),
        cli::opt(
            "mode",
            "ensemble|conv-arar|arar|rma|hvd|hierarchical|dbtree",
            Some("arar"),
        ),
        cli::opt("epochs", "training epochs", Some("300")),
        cli::opt("batch", "parameter samples per epoch", Some("64")),
        cli::opt("outer-freq", "outer-group frequency h", Some("10")),
        cli::opt("members", "ensemble size M", Some("6")),
        cli::opt("gpus-per-node", "inner group size", Some("4")),
        cli::opt("step-mean", "simulator: mean epoch compute seconds", Some("0.035")),
        cli::opt("gen-lr", "generator learning rate", None),
        cli::opt("disc-lr", "discriminator learning rate", None),
        cli::opt(
            "chunking",
            "ring chunking: unchunked|auto|<max elems per message>",
            Some("unchunked"),
        ),
        cli::opt(
            "staleness",
            "exchange-window depth k: 0 = blocking, 1 = overlap, k = k-deep window",
            Some("0"),
        ),
        cli::opt(
            "intra-threads",
            "native backend: worker threads per gan_step (0 = serial; bit-identical)",
            Some("0"),
        ),
        cli::flag("overlap", "deprecated alias for --staleness 1"),
        cli::flag("paper-scale", "use the full Table III configuration"),
        cli::opt(
            "ckpt-every",
            "write a resumable run checkpoint every N epochs (0 = off)",
            Some("0"),
        ),
        cli::opt("ckpt-dir", "run-checkpoint directory", Some("checkpoints")),
        cli::opt("ckpt-keep", "retain the newest N run checkpoints", Some("3")),
        cli::opt(
            "resume",
            "resume from a run checkpoint (run_e* dir, or a ckpt root: newest wins)",
            None,
        ),
        cli::opt(
            "fault-plan",
            "deterministic fault injection: inline JSON ('{...}') or a plan file",
            None,
        ),
        cli::opt(
            "exchange-timeout-ms",
            "deadline on the oldest in-flight exchange (0 = none)",
            Some("0"),
        ),
        cli::opt(
            "on-straggler",
            "deadline-miss policy: block|skip|late_apply",
            Some("block"),
        ),
        cli::opt(
            "skip-budget",
            "max exchanges the skip policy may abandon (0 = unlimited)",
            Some("0"),
        ),
        cli::opt(
            "membership",
            "scripted membership schedule: comma-separated leave:R@E / join:R@E",
            None,
        ),
        cli::opt(
            "min-ranks",
            "never let live membership drop below N ranks",
            Some("1"),
        ),
        cli::opt(
            "evict-after",
            "evict a rank after N consecutive deadline misses (0 = never; \
             needs --exchange-timeout-ms)",
            Some("0"),
        ),
        cli::flag(
            "allow-join",
            "allow ranks to join mid-run (scripted joins, elastic resume)",
        ),
    ]
}

fn build_cfg(a: &Args) -> Result<RunConfig> {
    let mut cfg = if let Some(path) = a.get("config") {
        RunConfig::from_file(Path::new(path))?
    } else if a.flag("paper-scale") {
        presets::paper_table3()
    } else {
        presets::ci_default()
    };
    cfg.ranks = a.usize("ranks", cfg.ranks)?;
    cfg.mode = Mode::parse(a.get_or("mode", cfg.mode.name()))?;
    cfg.epochs = a.usize("epochs", cfg.epochs)?;
    cfg.batch = a.usize("batch", cfg.batch)?;
    cfg.outer_freq = a.usize("outer-freq", cfg.outer_freq)?;
    cfg.gpus_per_node = a.usize("gpus-per-node", cfg.gpus_per_node)?;
    cfg.seed = a.u64("seed", cfg.seed)?;
    cfg.gen_lr = a.f64("gen-lr", cfg.gen_lr as f64)? as f32;
    cfg.disc_lr = a.f64("disc-lr", cfg.disc_lr as f64)? as f32;
    if let Some(v) = a.get("chunking") {
        cfg.chunking = ChunkPolicy::parse_str(v)?;
    }
    if let Some(v) = a.get("scenario") {
        cfg.scenario = v.to_string();
    }
    if let Some(v) = a.get("backend") {
        cfg.backend = BackendKind::parse(v)?;
    }
    cfg.staleness = a.usize("staleness", cfg.staleness)?;
    cfg.intra_threads = a.usize("intra-threads", cfg.intra_threads)?;
    if a.flag("overlap") {
        sagips::log_warn!("--overlap is deprecated — use --staleness 1");
        // An explicit --staleness always wins over the alias (mirrors the
        // JSON precedence, where the "staleness" key beats "overlap_comm").
        if a.get("staleness").is_none() {
            cfg.staleness = cfg.staleness.max(1);
        }
    }
    cfg.artifacts_dir = a.get_or("artifacts", &cfg.artifacts_dir).to_string();
    cfg.ckpt_every = a.usize("ckpt-every", cfg.ckpt_every)?;
    cfg.ckpt_dir = a.get_or("ckpt-dir", &cfg.ckpt_dir).to_string();
    cfg.ckpt_keep = a.usize("ckpt-keep", cfg.ckpt_keep)?;
    if let Some(p) = a.get("resume") {
        cfg.resume = Some(p.to_string());
    }
    if let Some(p) = a.get("fault-plan") {
        cfg.fault_plan = Some(p.to_string());
    }
    cfg.exchange_timeout_ms = a.u64("exchange-timeout-ms", cfg.exchange_timeout_ms)?;
    if let Some(p) = a.get("on-straggler") {
        cfg.on_straggler = sagips::config::StragglerPolicy::parse(p)?;
    }
    cfg.skip_budget = a.usize("skip-budget", cfg.skip_budget)?;
    if let Some(s) = a.get("membership") {
        cfg.membership = Some(s.to_string());
    }
    cfg.min_ranks = a.usize("min-ranks", cfg.min_ranks)?;
    cfg.evict_after = a.usize("evict-after", cfg.evict_after)?;
    if a.flag("allow-join") {
        cfg.allow_join = true;
    }
    cfg.validate()?;
    Ok(cfg)
}

fn open_runtime(a: &Args, cfg: &RunConfig) -> Result<Runtime> {
    let workers = a.usize("workers", cfg.runtime_workers)?;
    Runtime::from_config(cfg, workers)
}

fn run(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_help();
        return Ok(());
    };
    let rest: Vec<String> = args[1..].to_vec();
    // serve/job have their own option sets — dispatch before the common
    // parse so their flags aren't rejected as unknown.
    match cmd.as_str() {
        "serve" => return cmd_serve(&rest),
        "job" => return cmd_job(&rest),
        _ => {}
    }
    let specs = common_specs();
    let a = Args::parse(&rest, &specs)?;
    match cmd.as_str() {
        "train" => cmd_train(&a),
        "ensemble" => cmd_ensemble(&a),
        "simulate" => cmd_simulate(&a),
        "experiment" => cmd_experiment(&a),
        "scenarios" => cmd_scenarios(),
        "validate-artifacts" => cmd_validate(&a),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => Err(Error::Usage(format!(
            "unknown subcommand '{other}' — valid subcommands: train, ensemble, \
             simulate, experiment, scenarios, validate-artifacts, serve, job, help"
        ))),
    }
}

fn cmd_scenarios() -> Result<()> {
    let rows: Vec<sagips::scenario::ScenarioInfo> = sagips::scenario::registry()
        .iter()
        .map(|s| s.info())
        .collect();
    print!("{}", sagips::report::format_scenarios(&rows));
    Ok(())
}

fn cmd_train(a: &Args) -> Result<()> {
    let cfg = build_cfg(a)?;
    let rt = open_runtime(a, &cfg)?;
    sagips::log_info!(
        "training: scenario={} backend={} mode={} ranks={} epochs={} batch={} (disc batch {}) chunking={} staleness={}",
        cfg.scenario,
        cfg.backend.name(),
        cfg.mode.name(),
        cfg.ranks,
        cfg.epochs,
        cfg.batch,
        cfg.disc_batch(),
        cfg.chunking.label(),
        cfg.staleness
    );
    let run = run_training(&cfg, &rt.handle())?;
    if let Some(e) = run.resumed_from {
        println!("resumed from epoch {e} (ran epochs {}..{})", e + 1, cfg.epochs);
    }
    println!("wall time: {:.2}s", run.wall_s);
    println!(
        "analysis rate (eq 9): {:.3e} events/s over {:.3e} events",
        run.analysis_rate(),
        run.total_events()
    );
    println!(
        "final losses: G={:.4} D={:.4}",
        run.metrics.mean_of_last("gen_loss").unwrap_or(f64::NAN),
        run.metrics.mean_of_last("disc_loss").unwrap_or(f64::NAN)
    );
    if let Some(r) = &run.final_residuals {
        println!(
            "final residuals r̂ (eq 6): {:?}",
            r.iter().map(|x| (x * 1e3).round() / 1e3).collect::<Vec<f64>>()
        );
        println!("mean |r̂|: {:.4}", residuals::mean_abs(r));
    }
    println!("\nresidual curve (rank 0 checkpoints):");
    for p in &run.residual_curve {
        println!(
            "  epoch {:>6}  t={:>8.2}s  mean|r̂|={:.4}",
            p.epoch,
            p.elapsed_s,
            residuals::mean_abs(&p.residuals)
        );
    }
    if !run.membership.is_empty() {
        println!(
            "\nmembership: {} event(s), {} rank(s) live at the end",
            run.membership.len(),
            run.final_members()
        );
        for r in &run.membership {
            println!("  epoch {:>6}  {:<5} rank {}", r.epoch, r.kind.as_str(), r.rank);
        }
    }
    experiments::run_summary(&cfg, &run);
    if cfg.exchange_timeout_ms > 0 {
        experiments::health_summary(&run);
    }
    rt.shutdown();
    Ok(())
}

fn cmd_ensemble(a: &Args) -> Result<()> {
    let cfg = build_cfg(a)?;
    let m = a.usize("members", 6)?;
    let rt = open_runtime(a, &cfg)?;
    let ens = EnsembleResult::train(&cfg, m, &rt.handle())?;
    let resp = ens.response();
    println!(
        "ensemble of {m} runs (mode {}, {} ranks)",
        cfg.mode.name(),
        cfg.ranks
    );
    let milli = |v: &[f64]| -> Vec<f64> { v.iter().map(|x| (x * 1e3).round() / 1e3).collect() };
    println!("p̂   (eq 7): {:?}", milli(&resp.p_hat));
    println!("σ    (eq 8): {:?}", milli(&resp.sigma));
    println!("truth      : {:?}", ens.true_params);
    let row = Table4Row::from_raw(cfg.mode.name(), &ens.table4_row());
    println!("\n{}", format_table4(&[row]));
    rt.shutdown();
    Ok(())
}

fn cmd_simulate(a: &Args) -> Result<()> {
    let mean = a.f64("step-mean", 0.035)?;
    let compute = ComputeModel::with_jitter(mean, 0.15);
    experiments::fig11(compute);
    experiments::fig12(compute);
    Ok(())
}

fn cmd_experiment(a: &Args) -> Result<()> {
    let id = a
        .positional()
        .first()
        .ok_or_else(|| Error::Usage("experiment needs an id (fig8..fig16, tab4)".into()))?
        .clone();
    let scale = Scale::from_env(Scale::ci());
    // Simulator-only experiments need no artifacts.
    if id == "fig11" || id == "fig12" {
        let compute = ComputeModel::with_jitter(a.f64("step-mean", 0.035)?, 0.15);
        if id == "fig11" {
            experiments::fig11(compute);
        } else {
            experiments::fig12(compute);
        }
        return Ok(());
    }
    let cfg = build_cfg(a)?;
    let rt = open_runtime(a, &cfg)?;
    let h = rt.handle();
    match id.as_str() {
        "fig8" => {
            experiments::fig8(&h, &scale)?;
        }
        "fig9" => {
            experiments::fig9(&h, &scale)?;
        }
        "fig10" => {
            experiments::fig10(&h, &scale)?;
        }
        "fig13" | "tab4" => {
            let rows = experiments::fig13_tab4(&h, &scale)?;
            let mut table: Vec<Table4Row> = rows
                .iter()
                .map(|(mode, _, raw)| Table4Row::from_raw(mode.name(), raw))
                .collect();
            table.extend(table4_paper_reference());
            println!("\n{}", format_table4(&table));
        }
        "fig14" => {
            experiments::weak_scaling_curves(&h, &scale, Mode::RmaArarArar, &[1, 4])?;
        }
        "fig15" => {
            experiments::weak_scaling_curves(&h, &scale, Mode::RmaArarArar, &[1, 2, 4, 8])?;
        }
        "fig16" => {
            experiments::weak_scaling_curves(&h, &scale, Mode::ArarArar, &[1, 2, 4, 8])?;
        }
        other => {
            return Err(Error::Usage(format!(
                "unknown experiment '{other}' (fig8..fig16, tab4)"
            )))
        }
    }
    rt.shutdown();
    Ok(())
}

fn cmd_validate(a: &Args) -> Result<()> {
    let cfg = build_cfg(a)?;
    let rt = open_runtime(a, &cfg)?;
    let h = rt.handle();
    let names: Vec<String> = h.manifest().artifacts.keys().cloned().collect();
    println!(
        "validating {} artifacts on the {} backend",
        names.len(),
        h.backend_name()
    );
    for name in names {
        let spec = h.manifest().artifact(&name)?.clone();
        let inputs: Vec<Vec<f32>> = spec
            .inputs
            .iter()
            .map(|io| vec![0.01f32; io.elems()])
            .collect();
        let t0 = std::time::Instant::now();
        let out = h.execute(&name, inputs)?;
        println!(
            "  {name:<32} ok ({} outputs, {:.1}ms incl. first-use compile)",
            out.len(),
            t0.elapsed().as_secs_f64() * 1e3
        );
    }
    rt.shutdown();
    println!("all artifacts load, compile and execute");
    Ok(())
}

const DEFAULT_SOCKET: &str = "serve-state/sagips.sock";

fn serve_specs() -> Vec<OptSpec> {
    vec![
        cli::opt(
            "state-dir",
            "daemon state dir (queue journal + per-job checkpoints)",
            Some("serve-state"),
        ),
        cli::opt(
            "socket",
            "unix socket path (default: <state-dir>/sagips.sock)",
            None,
        ),
        cli::flag("stdio", "serve line-JSON on stdin/stdout instead of a socket"),
        cli::opt("max-concurrent-jobs", "jobs training at once", None),
        cli::opt(
            "max-queued",
            "refuse submits beyond N queued jobs (0 = unlimited)",
            None,
        ),
        cli::opt(
            "default-ckpt-every",
            "checkpoint cadence applied to jobs submitted with ckpt_every 0",
            None,
        ),
        cli::opt(
            "serve-config",
            "limits JSON file; the reload verb re-reads it without a restart",
            None,
        ),
    ]
}

fn cmd_serve(args: &[String]) -> Result<()> {
    let a = Args::parse(args, &serve_specs())?;
    // Flags override the serve-config file, which overrides defaults.
    let mut limits = match a.get("serve-config") {
        Some(p) => ServeLimits::from_json(&std::fs::read_to_string(p)?)?,
        None => ServeLimits::default(),
    };
    limits.max_concurrent_jobs = a.usize("max-concurrent-jobs", limits.max_concurrent_jobs)?;
    limits.max_queued = a.usize("max-queued", limits.max_queued)?;
    limits.default_ckpt_every = a.usize("default-ckpt-every", limits.default_ckpt_every)?;
    limits.validate()?;
    let state_dir = PathBuf::from(a.get_or("state-dir", "serve-state"));
    let serve_config = a.get("serve-config").map(PathBuf::from);
    let daemon = Daemon::open(&state_dir, limits, serve_config, Box::new(TrainingRunner))?;
    if a.flag("stdio") {
        sagips::log_info!(
            "serving line-JSON on stdin/stdout (state dir {})",
            state_dir.display()
        );
        daemon.serve_stdio()
    } else {
        let socket = a
            .get("socket")
            .map(PathBuf::from)
            .unwrap_or_else(|| state_dir.join("sagips.sock"));
        daemon.serve_unix(&socket)
    }
}

fn cmd_job(args: &[String]) -> Result<()> {
    let Some(verb) = args.first().cloned() else {
        return Err(Error::Usage(format!(
            "sagips job needs a verb — valid verbs: {}",
            protocol::VERBS.join(", ")
        )));
    };
    let rest: Vec<String> = args[1..].to_vec();
    match verb.as_str() {
        "submit" => job_submit(&rest),
        "status" | "cancel" | "list" | "reload" | "compact" | "ping" | "shutdown" => {
            job_simple(&verb, &rest)
        }
        other => Err(Error::Usage(format!(
            "unknown job verb '{other}' — valid verbs: {}",
            protocol::VERBS.join(", ")
        ))),
    }
}

/// Checks a daemon response line for `"ok":true`; surfaces the error
/// (keeping admission refusals retryable/distinguishable) otherwise.
fn expect_ok(v: &Value) -> Result<()> {
    if v.get("ok") == Some(&Value::Bool(true)) {
        return Ok(());
    }
    let msg = v
        .get("error")
        .and_then(|e| e.as_str())
        .unwrap_or("malformed daemon response")
        .to_string();
    if v.get("overloaded") == Some(&Value::Bool(true)) {
        Err(Error::overloaded(msg))
    } else {
        Err(Error::Runtime(msg))
    }
}

fn job_submit(args: &[String]) -> Result<()> {
    // A submit is a full run config — same options as `sagips train`,
    // plus the queueing knobs.
    let mut specs = common_specs();
    specs.push(cli::opt("socket", "daemon unix socket", Some(DEFAULT_SOCKET)));
    specs.push(cli::opt(
        "priority",
        "scheduling priority (higher runs first; FIFO within a priority)",
        Some("0"),
    ));
    specs.push(cli::opt("name", "job display name (default: the scenario)", None));
    let a = Args::parse(args, &specs)?;
    let config = build_cfg(&a)?;
    let name = match a.get("name") {
        Some(n) => n.to_string(),
        None => config.scenario.clone(),
    };
    let priority = a.f64("priority", 0.0)? as i64;
    let socket = a.get_or("socket", DEFAULT_SOCKET).to_string();
    let resp = client_roundtrip(
        Path::new(&socket),
        &protocol::Request::Submit {
            name,
            priority,
            config,
        },
    )?;
    expect_ok(&resp)?;
    println!("submitted job {}", resp.req_usize("id")?);
    Ok(())
}

fn job_simple(verb: &str, args: &[String]) -> Result<()> {
    let specs = vec![cli::opt("socket", "daemon unix socket", Some(DEFAULT_SOCKET))];
    let a = Args::parse(args, &specs)?;
    let socket = a.get_or("socket", DEFAULT_SOCKET).to_string();
    let id = || -> Result<u64> {
        a.positional()
            .first()
            .ok_or_else(|| Error::Usage(format!("sagips job {verb} needs a job id")))?
            .parse()
            .map_err(|_| Error::Usage(format!("sagips job {verb}: bad job id")))
    };
    let req = match verb {
        "status" => protocol::Request::Status { id: id()? },
        "cancel" => protocol::Request::Cancel { id: id()? },
        "list" => protocol::Request::List,
        "reload" => protocol::Request::Reload,
        "compact" => protocol::Request::Compact,
        "ping" => protocol::Request::Ping,
        "shutdown" => protocol::Request::Shutdown,
        _ => unreachable!("cmd_job routed an unknown verb"),
    };
    let resp = client_roundtrip(Path::new(&socket), &req)?;
    expect_ok(&resp)?;
    match verb {
        "status" => {
            let st = protocol::parse_status(resp.req("job")?)?;
            print!("{}", sagips::report::format_jobs(&[st]));
        }
        "list" => {
            let rows = resp
                .req("jobs")?
                .as_array()
                .ok_or_else(|| Error::Runtime("daemon 'jobs' is not an array".into()))?
                .iter()
                .map(protocol::parse_status)
                .collect::<Result<Vec<_>>>()?;
            print!("{}", sagips::report::format_jobs(&rows));
        }
        "cancel" => println!("cancel: {}", resp.req_str("result")?),
        "reload" => println!("reloaded: {}", resp.req_str("reloaded")?),
        "compact" => println!(
            "journal compacted: {} lines",
            resp.req_usize("journal_lines")?
        ),
        "ping" => println!(
            "daemon up: {} running, {} queued",
            resp.req_usize("running")?,
            resp.req_usize("queued")?
        ),
        "shutdown" => println!("daemon shutting down"),
        _ => unreachable!(),
    }
    Ok(())
}
