//! The staged per-rank training pipeline.
//!
//! [`RankPipeline`] decomposes one rank's epoch into the six stages of
//! the SAGIPS hot path —
//!
//! ```text
//! bootstrap-draw → gan_step → offload → exchange → apply → update
//! ```
//!
//! — and composes them under a single knob, the `staleness` field of
//! [`crate::config::RunConfig`]:
//!
//! * `staleness: 0` — paper-faithful blocking: offload, exchange
//!   (`epoch_reduce`), apply and update all run inside the same epoch,
//!   so the generator sees fresh averaged gradients.
//! * `staleness: 1` — classic overlap: epoch e's exchange rides the
//!   collective engine's comm thread under epoch e+1's draw + `gan_step`
//!   and is applied there (one-epoch-stale averaged gradients).
//! * `staleness: k > 1` — a bounded window of up to k in-flight
//!   exchanges. Each epoch starts its exchange after the `gan_step`; the
//!   oldest exchange is collected and applied (strict FIFO) only once
//!   the window is full, so applied gradients are at most k epochs stale
//!   — Async-RED-style *bounded* block asynchrony, and deterministic
//!   (the apply schedule depends only on the window depth, never on
//!   comm-thread timing).
//!
//! **Quiescence.** [`RankPipeline::drain`] settles the window: every
//! in-flight exchange is collected through [`Collective::drain`] and its
//! averaged gradients applied in FIFO order, leaving nothing
//! outstanding. The pipeline drains at the run-checkpoint cadence —
//! immediately before depositing into the [`RunCheckpointer`] — so run
//! checkpoints always capture a fully settled state and a resumed
//! overlap run (any staleness) is bit-identical to an uninterrupted run
//! with the same cadence. It drains again at the end of training.
//!
//! **Staleness accounting.** Every apply records `apply_epoch −
//! start_epoch` as a `staleness` sample in the per-rank [`Recorder`]
//! (keyed by the gradient's origin epoch, one sample per epoch) and
//! accumulates it into [`CommStats::staleness_sum`] /
//! [`CommStats::applies`], so reports can surface the mean applied
//! staleness next to the hot/hidden comm split.
//!
//! **Straggler tolerance.** With `exchange_timeout_ms > 0` the wait on
//! the oldest in-flight exchange carries a deadline, and
//! `on_straggler` decides what a miss means (see
//! `docs/fault-tolerance.md`): `block` just accounts the timeout (health
//! tracking) and keeps waiting; `skip` abandons the exchange — the rank
//! trains on without that average, the result is discarded on eventual
//! arrival (FIFO, tracked by an abandoned counter), and
//! [`CommStats::skips`] counts it against the `skip_budget`;
//! `late_apply` stops blocking but applies the result whenever it lands
//! ([`CommStats::late_applies`]). A [`RankHealth`] tracker accumulates
//! deadline misses and exchange latency per rank and is surfaced in the
//! run summary plus a per-epoch `health` Recorder series. `drain()`
//! still settles *everything* — including abandoned results, which it
//! discards — so checkpoint quiescence and bit-identical resume hold
//! under every policy.
//!
//! **Elastic membership.** When a
//! [`MembershipDirector`](super::membership::MembershipDirector) is
//! armed, every epoch starts with a [`RankPipeline::transition`] check:
//! on a view-version change the rank drains its window (no in-flight
//! exchange may straddle two rings), re-rings its collective via
//! [`Collective::set_membership`], and — when (re)joining — restores
//! state from the newest checkpoint boundary before the join epoch
//! (`RunCheckpointer::wait_for`). A dormant rank idles through its
//! epochs (no draws, steps, exchanges or metrics) but keeps depositing
//! its frozen state at the checkpoint cadence, so every run checkpoint
//! stays full-width and replays stay bit-identical. Live epochs count
//! into [`CommStats::participation_epochs`] and record a `members`
//! series (Async-RED-style participation bookkeeping).
//!
//! **Cooperative cancellation.** When a
//! [`RunControl`](super::control::RunControl) is armed, the pipeline
//! consults it at every run-checkpoint boundary *after* draining and
//! depositing: the control decides one stop boundary for the whole
//! cohort (see `coordinator::control` for the consensus rule), and a
//! rank whose boundary matches simply returns from its epoch loop — the
//! window is already quiescent, the final state already on disk, so the
//! deposited checkpoint is a valid `--resume` point and every rank stops
//! at the same epoch. The control also receives per-epoch progress ticks
//! (and rank 0's losses), which is pure observation: a controlled run is
//! bit-identical to an uncontrolled one up to the stop.

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::collective::{Collective, CommStats};
use crate::comm::MembershipView;
use crate::config::{RunConfig, StragglerPolicy};
use crate::data::Bootstrap;
use crate::metrics::{Recorder, Timer};
use crate::model::checkpoint::{CheckpointSeries, RankTrainState, TrainCheckpoint};
use crate::model::gan::GanState;
use crate::model::{StepOutput, TrainStep};
use crate::optim::{Adam, Optimizer};
use crate::runtime::RuntimeHandle;
use crate::tensor::fusion::FusionPlan;
use crate::tensor::ops;
use crate::util::error::{Error, Result};
use crate::util::rng::Rng;

use super::control::RunControl;
use super::membership::MembershipDirector;
use super::offload::GradOffloader;
use super::rank::RankOutcome;
use super::resume::{RankResume, RunCheckpointer};

/// An exchange started at `epoch` whose averaged result has not been
/// applied yet. `grads` holds that epoch's full gradient vector: the
/// averaged weights are on-loaded into it at apply time, biases keep
/// their local values from the same epoch.
struct InFlight {
    epoch: u64,
    grads: Vec<f32>,
    /// When the exchange was submitted (health latency accounting).
    started: Instant,
    /// A wait on this exchange already missed the deadline (late-apply
    /// policy: apply on arrival and count it in `CommStats::late_applies`).
    timed_out: bool,
}

/// Coarse per-rank health classification from consecutive deadline
/// misses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HealthState {
    /// No outstanding run of deadline misses.
    Healthy,
    /// 1..SUSPECT_AFTER consecutive deadline misses.
    Degraded,
    /// At least [`SUSPECT_AFTER`] consecutive deadline misses — the rank's
    /// ring neighborhood looks stalled.
    Suspect,
}

/// Consecutive deadline misses after which a rank is reported suspect.
pub const SUSPECT_AFTER: u32 = 3;

/// Sleep between polls while waiting under an exchange deadline.
const POLL_INTERVAL: Duration = Duration::from_micros(200);

/// How long a joining rank waits for its hand-off checkpoint boundary.
/// Joiners race ahead of the live cohort through their dormant epochs, so
/// the boundary may be several epochs of real training away — this is a
/// liveness backstop, not a latency deadline.
const HANDOFF_TIMEOUT: Duration = Duration::from_secs(300);

impl HealthState {
    /// Numeric encoding for the per-epoch `health` Recorder series.
    pub fn as_f64(&self) -> f64 {
        match self {
            HealthState::Healthy => 0.0,
            HealthState::Degraded => 1.0,
            HealthState::Suspect => 2.0,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            HealthState::Healthy => "healthy",
            HealthState::Degraded => "degraded",
            HealthState::Suspect => "suspect",
        }
    }
}

/// Per-rank exchange health accounting: deadline misses (consecutive and
/// total) and settled-exchange latency. Kept by the pipeline, reported
/// through [`super::rank::RankOutcome`] into the coordinator's run
/// summary.
#[derive(Clone, Copy, Debug, Default)]
pub struct RankHealth {
    /// Exchanges settled (applied; skipped exchanges are not latency-
    /// accounted — their results arrive whenever the straggler recovers).
    pub settled: u64,
    /// Total deadline misses.
    pub timeouts: u64,
    /// Current run of consecutive deadline misses.
    pub consecutive_timeouts: u32,
    /// Worst run of consecutive deadline misses over the whole run.
    pub max_consecutive_timeouts: u32,
    /// Sum over settled exchanges of submit-to-apply latency (seconds).
    pub latency_sum_s: f64,
}

impl RankHealth {
    fn record_settled(&mut self, latency_s: f64) {
        self.settled += 1;
        self.latency_sum_s += latency_s;
        self.consecutive_timeouts = 0;
    }

    fn record_timeout(&mut self) {
        self.timeouts += 1;
        self.consecutive_timeouts += 1;
        self.max_consecutive_timeouts = self
            .max_consecutive_timeouts
            .max(self.consecutive_timeouts);
    }

    /// Mean submit-to-apply exchange latency in seconds (0.0 when nothing
    /// settled).
    pub fn mean_latency_s(&self) -> f64 {
        if self.settled == 0 {
            0.0
        } else {
            self.latency_sum_s / self.settled as f64
        }
    }

    /// Current health classification.
    pub fn state(&self) -> HealthState {
        if self.consecutive_timeouts >= SUSPECT_AFTER {
            HealthState::Suspect
        } else if self.consecutive_timeouts > 0 {
            HealthState::Degraded
        } else {
            HealthState::Healthy
        }
    }

    /// Worst classification reached over the run (for the summary table).
    pub fn worst_state(&self) -> HealthState {
        if self.max_consecutive_timeouts >= SUSPECT_AFTER {
            HealthState::Suspect
        } else if self.max_consecutive_timeouts > 0 {
            HealthState::Degraded
        } else {
            HealthState::Healthy
        }
    }
}

/// One rank's training loop as a staged, bounded-staleness pipeline.
pub struct RankPipeline {
    rank: usize,
    /// Exchange-window depth k (0 = blocking).
    staleness: usize,
    scenario: String,
    state: GanState,
    gen_opt: Adam,
    disc_opt: Adam,
    rng: Rng,
    shard: Bootstrap,
    step: TrainStep,
    disc_batch: usize,
    offloader: GradOffloader,
    collective: Box<dyn Collective>,
    recorder: Recorder,
    checkpoints: CheckpointSeries,
    comm_totals: CommStats,
    /// Straggler policy for deadline misses (block = paper behavior).
    policy: StragglerPolicy,
    /// Deadline for waiting on the oldest in-flight exchange.
    deadline: Option<Duration>,
    /// Skip budget (0 = unlimited) and skips consumed so far.
    skip_budget: usize,
    skips_used: u64,
    /// Exchanges abandoned under the skip policy whose results are still
    /// travelling through the collective. FIFO guarantees they surface
    /// *before* any live window entry's result, so the next `abandoned`
    /// results received are discarded.
    abandoned: usize,
    /// Exchange health accounting (deadline misses, settle latency).
    health: RankHealth,
    /// In-flight exchanges, oldest first (≤ `staleness` entries; the
    /// late-apply policy lets overdue entries ride beyond that, bounded
    /// by the engine window).
    window: VecDeque<InFlight>,
    /// Reusable step output: its gradient buffers rotate with the step
    /// executor's and the window slots, so the epoch loop performs no
    /// gradient allocation after warm-up.
    out: StepOutput,
    /// Full-gradient buffers freed by a drain, rotated back into `out`
    /// (at most `staleness` accumulate — one per settled exchange).
    grad_spares: Vec<Vec<f32>>,
    real: Vec<f32>,
    timer: Timer,
    elapsed_offset: f64,
    start_epoch: u64,
    /// Elastic membership authority (`None` = fixed cohort, zero new
    /// cost on the hot path).
    director: Option<Arc<MembershipDirector>>,
    /// The membership view this rank last re-ringed under.
    view: MembershipView,
    /// Whether this rank is currently a ring member. Dormant ranks idle
    /// through their epochs — no draws, steps, exchanges or metrics —
    /// but keep depositing their frozen state at the checkpoint cadence
    /// so run checkpoints stay full-width.
    live: bool,
    /// Consecutive deadline misses after which this rank asks the
    /// director to evict it (0 = never).
    evict_after: usize,
    /// Cooperative cancellation + progress handle (`None` = one-shot
    /// run, zero new cost on the hot path).
    control: Option<Arc<RunControl>>,
    /// The checkpoint boundary this rank stopped at, if the run was
    /// cancelled (all ranks stop at the same one).
    stopped_at: Option<u64>,
}

impl RankPipeline {
    /// Build the pipeline for one rank: model + optimizers (paper: Adam,
    /// G lr 1e-5 / D lr 1e-4) either fresh or restored from a run
    /// checkpoint, the weight-only fusion plan, the step executor, and a
    /// window sized from `cfg.staleness`. A restore replaces the RNG
    /// stream too, so every draw after the checkpoint boundary continues
    /// the original run's sequence exactly.
    pub fn new(
        rank: usize,
        cfg: &RunConfig,
        handle: RuntimeHandle,
        collective: Box<dyn Collective>,
        shard: Bootstrap,
        mut rng: Rng,
        resume: Option<RankResume>,
        director: Option<Arc<MembershipDirector>>,
        control: Option<Arc<RunControl>>,
    ) -> Result<RankPipeline> {
        let manifest = handle.manifest();
        let meta = manifest.model(&cfg.model)?.clone();
        let slope = manifest.leaky_slope;
        // Checkpoints carry the scenario identity so a restore under the
        // wrong forward operator is refused instead of silently diverging.
        let scenario = manifest.scenario.clone();

        let state;
        let start_epoch: u64;
        let elapsed_offset: f64;
        let mut gen_opt;
        let mut disc_opt;
        match resume {
            Some(r) => {
                debug_assert_eq!(r.state.rank, rank);
                state = GanState {
                    gen: r.state.gen,
                    disc: r.state.disc,
                };
                gen_opt = Adam::new(cfg.gen_lr, state.gen.len());
                gen_opt.restore(&r.state.gen_m, &r.state.gen_v, r.state.gen_t);
                disc_opt = Adam::new(cfg.disc_lr, state.disc.len());
                disc_opt.restore(&r.state.disc_m, &r.state.disc_v, r.state.disc_t);
                rng = Rng::from_snapshot(&r.state.rng);
                start_epoch = r.start_epoch;
                elapsed_offset = r.elapsed_offset;
            }
            None => {
                state = GanState::init(&meta, slope, &mut rng);
                gen_opt = Adam::new(cfg.gen_lr, state.gen.len());
                disc_opt = Adam::new(cfg.disc_lr, state.disc.len());
                start_epoch = 0;
                elapsed_offset = 0.0;
            }
        }

        // Weight-only fusion plan over the generator layout (Sec. V-C).
        // The offloader stages through the collective's own buffer pool,
        // so a k-deep staleness window holds exactly k+1 transfer buffers
        // at steady state and packs/applies allocate nothing after warmup.
        let plan = FusionPlan::build(meta.gen_segments(), cfg.fusion_bucket, cfg.include_bias);
        let offloader = match collective.buffer_pool() {
            Some(pool) => GradOffloader::new(plan).with_pool(pool),
            None => GradOffloader::new(plan),
        };

        let step = TrainStep::new(handle, &cfg.gan_step_artifact())?;
        let disc_batch = step.disc_batch();
        let real = Vec::with_capacity(step.real_len());

        // The launcher has already applied the start-epoch view to the
        // collectives; record it so transition() only reacts to version
        // *changes* past this point.
        let view = match &director {
            Some(d) => d.view_at(start_epoch),
            None => MembershipView::full(cfg.ranks),
        };
        let live = view.is_live(rank);

        Ok(RankPipeline {
            rank,
            staleness: cfg.staleness,
            scenario,
            state,
            gen_opt,
            disc_opt,
            rng,
            shard,
            step,
            disc_batch,
            offloader,
            collective,
            recorder: Recorder::new(rank),
            checkpoints: CheckpointSeries::default(),
            comm_totals: CommStats::default(),
            policy: cfg.on_straggler,
            deadline: (cfg.exchange_timeout_ms > 0)
                .then(|| Duration::from_millis(cfg.exchange_timeout_ms)),
            skip_budget: cfg.skip_budget,
            skips_used: 0,
            abandoned: 0,
            health: RankHealth::default(),
            window: VecDeque::new(),
            out: StepOutput::default(),
            grad_spares: Vec::new(),
            real,
            timer: Timer::start(),
            elapsed_offset,
            start_epoch,
            director,
            view,
            live,
            evict_after: cfg.evict_after,
            control,
            stopped_at: None,
        })
    }

    /// Run the full epoch loop: stages per epoch, analysis checkpoints at
    /// `cfg.checkpoint_every`, quiescent run-checkpoint deposits at the
    /// checkpointer's cadence, and a final drain.
    pub fn run(
        &mut self,
        cfg: &RunConfig,
        take_checkpoints: bool,
        checkpointer: Option<&Arc<RunCheckpointer>>,
    ) -> Result<()> {
        for epoch in self.start_epoch..cfg.epochs as u64 {
            // Membership transition point: drain-quiesced re-ring, leave,
            // or checkpoint hand-off join. No-op for fixed cohorts.
            self.transition(epoch, checkpointer)?;
            if self.live {
                self.run_epoch(epoch)?;
            }

            // Progress ticks for the service layer's status view. Pure
            // observation — never feeds back into training.
            if let Some(ctl) = &self.control {
                ctl.note_epoch(epoch);
                if self.rank == 0 && self.live {
                    ctl.publish_losses(self.out.gen_loss, self.out.disc_loss);
                }
            }

            // Analysis checkpoints: timestamped generator snapshots for
            // the post-training residual curves (Sec. VI-C2).
            if self.live
                && take_checkpoints
                && (epoch == 0
                    || cfg.checkpoint_every > 0
                        && (epoch + 1) % cfg.checkpoint_every as u64 == 0)
            {
                self.checkpoints.record(
                    self.rank,
                    epoch,
                    self.elapsed_offset + self.timer.elapsed_s(),
                    &self.scenario,
                    &self.state.gen,
                );
            }

            // Run-checkpoint deposit: drain to quiescence first, so the
            // checkpoint captures a fully settled state — no exchange in
            // flight, every started epoch's gradients applied. This is
            // what makes resumed overlap runs bit-identical. Dormant
            // ranks deposit too (their frozen state; the drain is a
            // no-op) so checkpoints stay full-width under churn.
            if let Some(ck) = checkpointer {
                if ck.wants(epoch) {
                    self.drain(epoch)?;
                    self.deposit(epoch, ck)?;
                    // Cooperative cancellation, only here: the window is
                    // quiescent and this boundary's deposit is already
                    // made, so stopping leaves a full-width, resumable
                    // checkpoint. The control guarantees every rank sees
                    // the same stop boundary.
                    if let Some(ctl) = &self.control {
                        if ctl.should_stop_at(epoch, ck.every() as u64) {
                            self.stopped_at = Some(epoch);
                            ctl.mark_stopped(epoch);
                            return Ok(());
                        }
                    }
                }
            }
        }
        // Settle whatever the last epochs left in flight.
        self.drain(cfg.epochs as u64 - 1)?;
        Ok(())
    }

    /// One epoch through the stages. With `staleness: 0` the exchange
    /// blocks in place; otherwise the oldest in-flight exchange is
    /// applied once the window is full and this epoch's exchange is
    /// started behind it.
    pub fn run_epoch(&mut self, epoch: u64) -> Result<()> {
        let mut lap = Timer::start();
        // Stage 1: bootstrap-draw a discriminator batch from the shard.
        self.shard.draw(self.disc_batch, &mut self.rng, &mut self.real);
        let t_draw = lap.lap_s();

        // Stage 2: gan_step (borrowed inputs, reused output buffers).
        self.step.run_into(
            &self.state.gen,
            &self.state.disc,
            &self.real,
            &mut self.rng,
            &mut self.out,
        )?;
        let t_step = lap.lap_s();
        if !ops::all_finite(&self.out.gen_grads) || !ops::all_finite(&self.out.disc_grads) {
            return Err(Error::Runtime(format!(
                "rank {}: non-finite gradients at epoch {epoch}",
                self.rank
            )));
        }

        // Local discriminator update (the paper trains one discriminator
        // per rank, autonomously — never exchanged, never stale).
        self.disc_opt.step(&mut self.state.disc, &self.out.disc_grads);

        let (t_comm, t_opt, stats) = if self.staleness == 0 {
            // Stages 3–6, blocking: off-load -> collective -> on-load ->
            // generator update, all within the epoch (paper semantics).
            let buf = self.offloader.offload(&self.out.gen_grads)?;
            let mut stats = self.collective.epoch_reduce(epoch, buf)?;
            self.offloader.onload(&mut self.out.gen_grads)?;
            let t_comm = lap.lap_s();
            self.gen_opt.step(&mut self.state.gen, &self.out.gen_grads);
            account_apply(&mut self.recorder, &mut stats, epoch, epoch);
            (t_comm, lap.lap_s(), stats)
        } else {
            let mut stats = CommStats::default();
            let mut t_comm = 0.0;
            let mut t_opt = 0.0;
            // The gradient buffer freed by a collected exchange (or by an
            // earlier drain); rotated back into `out` when this epoch's
            // grads move in flight.
            let mut recycled = self.grad_spares.pop().unwrap_or_default();
            // Overdue late-apply entries settle the moment their result
            // lands — never blocking here, strictly FIFO from the front.
            while self.window.front().is_some_and(|f| f.timed_out) {
                match self.try_recv_live()? {
                    Some(r) => {
                        let freed =
                            self.apply_result(r, epoch, &mut lap, &mut t_comm, &mut t_opt, &mut stats)?;
                        self.grad_spares.push(std::mem::replace(&mut recycled, freed));
                    }
                    None => break,
                }
            }
            // Stage 5 (apply): collect the oldest exchange(s) once the
            // window is full — FIFO, so the apply order is deterministic.
            // Only the time blocked here counts as hot-path comm. A
            // deadline miss hands the decision to the straggler policy;
            // `None` means the overdue entry stays windowed (late-apply).
            while self.window.len() >= self.staleness {
                match self.settle_oldest(epoch, &mut lap, &mut t_comm, &mut t_opt, &mut stats)? {
                    Some(freed) => {
                        self.grad_spares.push(std::mem::replace(&mut recycled, freed))
                    }
                    None => break,
                }
            }
            // Stages 3–4 (offload + exchange): pack into an owned buffer
            // and start this epoch's reduce on the engine. WindowFull is
            // retryable backpressure, not a fault — abandoned or overdue
            // exchanges still hold engine slots — so settle one
            // outstanding result and resubmit.
            loop {
                let buf = self.offloader.pack_owned(&self.out.gen_grads)?;
                match self.collective.start_reduce(epoch, buf) {
                    Ok(()) => break,
                    Err(e) if e.is_window_full() => {
                        if let Some(freed) = self
                            .free_one_slot(epoch, &mut lap, &mut t_comm, &mut t_opt, &mut stats)?
                        {
                            self.grad_spares.push(std::mem::replace(&mut recycled, freed));
                        }
                    }
                    Err(e) => return Err(e),
                }
            }
            self.window.push_back(InFlight {
                epoch,
                grads: std::mem::replace(&mut self.out.gen_grads, recycled),
                started: Instant::now(),
                timed_out: false,
            });
            t_comm += lap.lap_s();
            (t_comm, t_opt, stats)
        };
        self.comm_totals.merge(&stats);

        // Per-epoch metrics.
        self.recorder.push("gen_loss", epoch, self.out.gen_loss);
        self.recorder.push("disc_loss", epoch, self.out.disc_loss);
        self.recorder.push("draw_s", epoch, t_draw);
        self.recorder.push("step_s", epoch, t_step);
        self.recorder.push("comm_s", epoch, t_comm);
        self.recorder.push("comm_wait_s", epoch, stats.wait_s);
        self.recorder.push("optim_s", epoch, t_opt);
        self.recorder.push("events", epoch, self.disc_batch as f64);
        // Health series only when a deadline is armed — default runs keep
        // their metric set unchanged.
        if self.deadline.is_some() {
            self.recorder
                .push("health", epoch, self.health.state().as_f64());
        }
        // Participation bookkeeping (Async-RED-style per-block
        // accounting): one tick per epoch this rank actually trained.
        // Dormant epochs never reach here, so under churn each rank's
        // count is exactly its live-epoch total.
        self.comm_totals.participation_epochs += 1;
        // The live-member count this rank trained under — recorded only
        // for elastic runs so fixed cohorts keep their metric set.
        if self.director.is_some() {
            self.recorder
                .push("members", epoch, self.view.len() as f64);
        }
        // Health-driven eviction: past the configured run of consecutive
        // deadline misses, ask the director to take this rank out of the
        // ring at a common future boundary. request_leave dedups, so the
        // repeated asks of a persistent straggler are no-ops.
        if self.evict_after > 0
            && self.health.consecutive_timeouts >= self.evict_after as u32
        {
            if let Some(dir) = &self.director {
                if let Some(at) = dir.request_leave(self.rank) {
                    crate::log_info!(
                        "rank {}: requesting eviction after {} consecutive \
                         deadline misses (effective at epoch {at})",
                        self.rank,
                        self.health.consecutive_timeouts
                    );
                }
            }
        }
        Ok(())
    }

    /// Account and recycle one abandoned (skipped) exchange result: its
    /// transport stats still count toward the totals, but the average is
    /// never applied.
    fn discard_abandoned(&mut self, buf: Vec<f32>, s: &CommStats) {
        debug_assert!(self.abandoned > 0);
        self.abandoned -= 1;
        self.offloader.recycle(buf);
        self.comm_totals.merge(s);
    }

    /// Receive the next *live* collective result without blocking,
    /// discarding any abandoned results that surface first (FIFO: they
    /// always precede live ones). `Ok(None)` when nothing live is ready.
    fn try_recv_live(&mut self) -> Result<Option<(Vec<f32>, CommStats)>> {
        loop {
            if !self.collective.poll_reduce()? {
                return Ok(None);
            }
            let (buf, s) = self.collective.wait_reduce()?; // ready: no block
            if self.abandoned > 0 {
                self.discard_abandoned(buf, &s);
                continue;
            }
            return Ok(Some((buf, s)));
        }
    }

    /// Blocking receive of the next live result. Callers must hold at
    /// least one live window entry, or this waits on nothing.
    fn recv_live(&mut self) -> Result<(Vec<f32>, CommStats)> {
        loop {
            let (buf, s) = self.collective.wait_reduce()?;
            if self.abandoned > 0 {
                self.discard_abandoned(buf, &s);
                continue;
            }
            return Ok((buf, s));
        }
    }

    /// Deadline-bounded receive of the next live result: `Ok(None)` on
    /// expiry. Abandoned results discarded along the way do not extend
    /// the deadline.
    fn recv_live_deadline(
        &mut self,
        deadline: Duration,
    ) -> Result<Option<(Vec<f32>, CommStats)>> {
        let expires = Instant::now() + deadline;
        loop {
            if let Some(r) = self.try_recv_live()? {
                return Ok(Some(r));
            }
            if Instant::now() >= expires {
                return Ok(None);
            }
            std::thread::sleep(POLL_INTERVAL);
        }
    }

    /// Stage 5 + 6 for the oldest live window entry, given its averaged
    /// result: on-load, update the generator, account staleness + health.
    /// Returns the freed full-gradient buffer for rotation back into the
    /// step output.
    fn apply_result(
        &mut self,
        result: (Vec<f32>, CommStats),
        at_epoch: u64,
        lap: &mut Timer,
        t_comm: &mut f64,
        t_opt: &mut f64,
        stats: &mut CommStats,
    ) -> Result<Vec<f32>> {
        let (reduced, mut s) = result;
        let InFlight {
            epoch: pe,
            grads: mut pgrads,
            started,
            timed_out,
        } = self
            .window
            .pop_front()
            .expect("apply_result called with an empty window");
        self.offloader.onload_from(&reduced, &mut pgrads)?;
        self.offloader.recycle(reduced);
        // Only the time blocked here is hot-path comm; the worker's own
        // blocked time ran concurrently with later epochs' compute and is
        // accounted as hidden.
        *t_comm += lap.lap_s();
        self.gen_opt.step(&mut self.state.gen, &pgrads);
        *t_opt += lap.lap_s();
        self.recorder.push("comm_hidden_s", pe, s.wait_s);
        if timed_out {
            s.late_applies += 1;
        }
        self.health.record_settled(started.elapsed().as_secs_f64());
        account_apply(&mut self.recorder, &mut s, pe, at_epoch);
        stats.merge(&s);
        Ok(pgrads)
    }

    /// Collect the oldest window entry — or let the straggler policy
    /// decide on a deadline miss. `Some(buffer)` when a window slot was
    /// released (applied or skipped); `None` when the overdue entry stays
    /// windowed (late-apply).
    fn settle_oldest(
        &mut self,
        at_epoch: u64,
        lap: &mut Timer,
        t_comm: &mut f64,
        t_opt: &mut f64,
        stats: &mut CommStats,
    ) -> Result<Option<Vec<f32>>> {
        let Some(deadline) = self.deadline else {
            let r = self.recv_live()?;
            return self
                .apply_result(r, at_epoch, lap, t_comm, t_opt, stats)
                .map(Some);
        };
        if let Some(r) = self.recv_live_deadline(deadline)? {
            return self
                .apply_result(r, at_epoch, lap, t_comm, t_opt, stats)
                .map(Some);
        }
        self.health.record_timeout();
        match self.policy {
            // Paper semantics: the miss is accounting-only, keep waiting.
            StragglerPolicy::Block => {
                let r = self.recv_live()?;
                self.apply_result(r, at_epoch, lap, t_comm, t_opt, stats)
                    .map(Some)
            }
            StragglerPolicy::Skip => {
                if self.skip_budget > 0 && self.skips_used >= self.skip_budget as u64 {
                    // Budget exhausted: degrade to blocking.
                    let r = self.recv_live()?;
                    return self
                        .apply_result(r, at_epoch, lap, t_comm, t_opt, stats)
                        .map(Some);
                }
                let InFlight {
                    epoch: pe, grads, ..
                } = self
                    .window
                    .pop_front()
                    .expect("settle_oldest called with an empty window");
                self.abandoned += 1;
                self.skips_used += 1;
                stats.skips += 1;
                *t_comm += lap.lap_s();
                crate::log_info!(
                    "rank {}: skipped exchange from epoch {pe} at epoch {at_epoch} \
                     ({} skips used)",
                    self.rank,
                    self.skips_used
                );
                Ok(Some(grads))
            }
            StragglerPolicy::LateApply => {
                if let Some(front) = self.window.front_mut() {
                    front.timed_out = true;
                }
                *t_comm += lap.lap_s();
                Ok(None)
            }
        }
    }

    /// Backpressure relief when a submission hits the engine's window
    /// cap: block for the oldest outstanding result and consume it —
    /// discarded if abandoned, applied to the window front otherwise.
    fn free_one_slot(
        &mut self,
        at_epoch: u64,
        lap: &mut Timer,
        t_comm: &mut f64,
        t_opt: &mut f64,
        stats: &mut CommStats,
    ) -> Result<Option<Vec<f32>>> {
        let (buf, s) = self.collective.wait_reduce()?;
        if self.abandoned > 0 {
            self.discard_abandoned(buf, &s);
            *t_comm += lap.lap_s();
            return Ok(None);
        }
        self.apply_result((buf, s), at_epoch, lap, t_comm, t_opt, stats)
            .map(Some)
    }

    /// Epoch-boundary membership transition: consult the director and, on
    /// a view-version change, (1) drain the old ring to quiescence so no
    /// in-flight exchange straddles two rings, (2) hand off state from
    /// the boundary checkpoint when this rank is (re)joining, and (3)
    /// re-ring the collective under the new view. No-op without a
    /// director or while the version is unchanged.
    fn transition(&mut self, epoch: u64, ck: Option<&Arc<RunCheckpointer>>) -> Result<()> {
        let Some(dir) = self.director.clone() else {
            return Ok(());
        };
        if self.live {
            // Advance the eviction commit horizon: dynamic evictions land
            // at least two epochs past the furthest live rank.
            dir.entering(epoch);
        }
        let view = dir.view_at(epoch);
        if view.version() == self.view.version() {
            return Ok(());
        }
        let was_live = self.live;
        let now_live = view.is_live(self.rank);
        if was_live {
            // Quiescence barrier: every old-ring exchange settles (and is
            // applied or discarded) before the neighbor schedule changes.
            self.drain(epoch)?;
        }
        if now_live && !was_live {
            self.handoff(epoch, ck)?;
        }
        self.collective.set_membership(&view)?;
        if was_live && !now_live {
            crate::log_info!(
                "rank {}: left the ring at epoch {epoch} (view v{}, {} live)",
                self.rank,
                view.version(),
                view.len()
            );
        } else if self.rank == 0 {
            crate::log_info!(
                "membership: re-ringed at epoch {epoch} (view v{}, {} live)",
                view.version(),
                view.len()
            );
        }
        self.view = view;
        self.live = now_live;
        Ok(())
    }

    /// Checkpoint hand-off for a (re)joining rank: restore generator,
    /// discriminator and optimizer moments from the newest checkpoint
    /// boundary strictly before `epoch` — a pure function of the join
    /// epoch and the cadence, so replaying the schedule hands off
    /// identical state. The rank's own slot is preferred (in-run rejoin:
    /// its frozen state, RNG included); a true newcomer takes rank 0's
    /// donor snapshot and keeps its own seed-derived RNG stream.
    fn handoff(&mut self, epoch: u64, ck: Option<&Arc<RunCheckpointer>>) -> Result<()> {
        let Some(ck) = ck else {
            return Err(Error::config(format!(
                "rank {}: membership join at epoch {epoch} needs run \
                 checkpointing (--ckpt-every > 0) for the state hand-off",
                self.rank
            )));
        };
        let every = ck.every() as u64;
        if epoch < every {
            return Err(Error::config(format!(
                "rank {}: join at epoch {epoch} precedes the first \
                 checkpoint boundary (cadence {every})",
                self.rank
            )));
        }
        let boundary = (epoch / every) * every - 1;
        let path = ck.wait_for(boundary, HANDOFF_TIMEOUT)?;
        let tc = TrainCheckpoint::load_for_scenario(&path, &self.scenario)?;
        let donor = tc
            .ranks
            .iter()
            .find(|r| r.rank == self.rank)
            .or_else(|| tc.ranks.first())
            .ok_or_else(|| {
                Error::Checkpoint("hand-off checkpoint holds no rank states".into())
            })?;
        self.state.gen = donor.gen.clone();
        self.state.disc = donor.disc.clone();
        self.gen_opt.restore(&donor.gen_m, &donor.gen_v, donor.gen_t);
        self.disc_opt.restore(&donor.disc_m, &donor.disc_v, donor.disc_t);
        let own = donor.rank == self.rank;
        if own {
            self.rng = Rng::from_snapshot(&donor.rng);
        }
        crate::log_info!(
            "rank {}: joined at epoch {epoch} via checkpoint hand-off \
             (boundary epoch {boundary}, {} state)",
            self.rank,
            if own { "own" } else { "donor" }
        );
        Ok(())
    }

    /// Quiescence: settle every in-flight exchange through
    /// [`Collective::drain`] and apply the averaged gradients in FIFO
    /// order. Results of exchanges abandoned under the skip policy are
    /// settled too — and discarded — so nothing stays outstanding. After
    /// this the window is empty and the training state is fully settled —
    /// safe to checkpoint. `at_epoch` is the epoch the drain runs at
    /// (staleness accounting).
    pub fn drain(&mut self, at_epoch: u64) -> Result<()> {
        if self.window.is_empty() && self.abandoned == 0 {
            return Ok(());
        }
        let mut lap = Timer::start();
        let results = self.collective.drain()?;
        if results.len() != self.window.len() + self.abandoned {
            return Err(Error::comm(format!(
                "drain settled {} exchanges but {} are windowed (+{} abandoned) — \
                 collective and pipeline disagree on the in-flight set",
                results.len(),
                self.window.len(),
                self.abandoned
            )));
        }
        // The settle blocked on every outstanding exchange at once;
        // attribute an even share to each settled *live* epoch's comm_s
        // rather than spiking the oldest one.
        let settle_share = lap.lap_s() / self.window.len().max(1) as f64;
        for (reduced, mut s) in results {
            // FIFO: abandoned exchanges were started (and popped) before
            // every live window entry, so their results surface first.
            if self.abandoned > 0 {
                self.discard_abandoned(reduced, &s);
                continue;
            }
            let InFlight {
                epoch: pe,
                grads: mut pgrads,
                started,
                timed_out,
            } = self.window.pop_front().expect("window length checked");
            self.offloader.onload_from(&reduced, &mut pgrads)?;
            self.offloader.recycle(reduced);
            let t_comm = settle_share + lap.lap_s();
            self.gen_opt.step(&mut self.state.gen, &pgrads);
            self.recorder.push("comm_s", pe, t_comm);
            self.recorder.push("optim_s", pe, lap.lap_s());
            self.recorder.push("comm_hidden_s", pe, s.wait_s);
            if timed_out {
                s.late_applies += 1;
            }
            self.health.record_settled(started.elapsed().as_secs_f64());
            account_apply(&mut self.recorder, &mut s, pe, at_epoch);
            self.comm_totals.merge(&s);
            self.grad_spares.push(pgrads);
        }
        Ok(())
    }

    /// Deposit this rank's complete post-epoch state into the shared run
    /// checkpointer (the window must be drained first; `run` guarantees
    /// it). The RNG is captured exactly where epoch + 1's first draw will
    /// continue it.
    fn deposit(&mut self, epoch: u64, ck: &Arc<RunCheckpointer>) -> Result<()> {
        debug_assert!(
            self.window.is_empty() && self.abandoned == 0,
            "deposit requires a drained pipeline"
        );
        let (gm, gv, gt) = self.gen_opt.state();
        let (dm, dv, dt) = self.disc_opt.state();
        ck.deposit(
            epoch,
            self.elapsed_offset + self.timer.elapsed_s(),
            RankTrainState {
                rank: self.rank,
                gen: self.state.gen.clone(),
                disc: self.state.disc.clone(),
                gen_m: gm.to_vec(),
                gen_v: gv.to_vec(),
                gen_t: gt,
                disc_m: dm.to_vec(),
                disc_v: dv.to_vec(),
                disc_t: dt,
                rng: self.rng.snapshot(),
            },
        )?;
        Ok(())
    }

    /// Exchanges currently in flight (≤ the configured staleness).
    pub fn in_flight(&self) -> usize {
        self.window.len()
    }

    /// Tear down into the rank's outcome.
    pub fn into_outcome(mut self) -> RankOutcome {
        // Fold staging-side pool traffic into the comm totals so the run
        // summary's alloc/hit columns cover the whole exchange path.
        let staging = *self.offloader.pool_stats();
        self.comm_totals.merge(&staging);
        RankOutcome {
            rank: self.rank,
            recorder: self.recorder,
            checkpoints: self.checkpoints,
            state: self.state,
            comm_totals: self.comm_totals,
            health: self.health,
            stopped_at: self.stopped_at,
        }
    }
}

/// Record one averaged-gradient application: a `staleness` sample keyed
/// by the gradient's origin epoch, mirrored into the epoch's comm stats.
fn account_apply(
    recorder: &mut Recorder,
    stats: &mut CommStats,
    start_epoch: u64,
    apply_epoch: u64,
) {
    let lag = apply_epoch.saturating_sub(start_epoch);
    recorder.push("staleness", start_epoch, lag as f64);
    stats.staleness_sum += lag;
    stats.applies += 1;
}

#[cfg(test)]
mod tests {
    // The pipeline needs a runtime + collectives + data to run; its
    // contracts — staleness-0 equivalence with the reference blocking
    // loop, drained-checkpoint resume bit-identity per scenario, bounded
    // mean staleness for k-deep windows — are enforced end to end by
    // rust/tests/pipeline.rs. The collective-facing half (windowed FIFO,
    // drain) is covered by collective::engine::tests.
}
