//! Gradient off-loading (Sec. IV-B6).
//!
//! The paper stages generator gradients out of GPU memory into host memory
//! before communicating, and registers them back afterwards — both to free
//! GPU memory and because mpi4py moves host buffers. Here the "device"
//! side is the PJRT output buffer and the "host" side is the packed
//! transfer buffer; the staging copy goes through the weight-only
//! [`FusionPlan`] (bias gradients are excluded from transfer, Sec. V-C).
//!
//! The staging buffer is allocated once and reused — the per-epoch hot
//! path performs no allocation.

use crate::collective::CommStats;
use crate::comm::BufferPool;
use crate::tensor::fusion::FusionPlan;
use crate::util::error::Result;

/// Reusable off-/on-load stager for one rank's generator gradients.
///
/// Two staging shapes:
/// * the classic in-place pair [`GradOffloader::offload`] /
///   [`GradOffloader::onload`] for the blocking loop, and
/// * the owned pair [`GradOffloader::pack_owned`] /
///   [`GradOffloader::onload_from`] + [`GradOffloader::recycle`] for the
///   overlap pipeline, which draws packed transfer buffers from a
///   [`BufferPool`]: up to k packed buffers ride the collective engine's
///   comm thread (one per in-flight exchange of a k-deep staleness
///   window) while the next epoch packs into a recycled buffer, so a
///   window of depth k holds exactly k+1 buffers at steady state and the
///   hot path performs no allocation. Handing the offloader the
///   collective's own pool (see
///   [`crate::collective::Collective::buffer_pool`]) makes pack-side
///   checkouts and receive-side recycles flow through one shared slab.
pub struct GradOffloader {
    plan: FusionPlan,
    staging: Vec<f32>,
    /// Pool backing the owned transfer buffers of the overlap pipeline.
    pool: BufferPool,
    /// Pool traffic (allocs / hits / recycled bytes) attributable to
    /// staging; merged into the run's comm totals by the pipeline.
    stats: CommStats,
    /// Total bytes staged (both directions), for the §Perf accounting.
    pub bytes_staged: u64,
}

impl GradOffloader {
    pub fn new(plan: FusionPlan) -> GradOffloader {
        let cap = plan.transfer_elems();
        GradOffloader {
            plan,
            staging: Vec::with_capacity(cap),
            pool: BufferPool::new(),
            stats: CommStats::default(),
            bytes_staged: 0,
        }
    }

    /// Draw owned transfer buffers from `pool` instead of a private one —
    /// normally the collective's shared pool, so buffers the collective
    /// recycled at receive-apply come back as pack-side checkouts.
    pub fn with_pool(mut self, pool: BufferPool) -> GradOffloader {
        self.pool = pool;
        self
    }

    /// Pool traffic attributable to staging, for comm-total accounting.
    pub fn pool_stats(&self) -> &CommStats {
        &self.stats
    }

    /// Off-load: pack the transferable slices of `grads` into the staging
    /// buffer and return it for the collective to reduce in place.
    pub fn offload(&mut self, grads: &[f32]) -> Result<&mut [f32]> {
        // Split borrows: temporarily move staging out to satisfy the
        // borrow checker without copying twice.
        let mut staging = std::mem::take(&mut self.staging);
        self.plan.pack(grads, &mut staging)?;
        self.staging = staging;
        self.bytes_staged += (self.staging.len() * 4) as u64;
        Ok(&mut self.staging)
    }

    /// On-load: scatter the reduced staging buffer back into `grads`.
    /// Slices outside the plan (biases) keep their local values.
    pub fn onload(&mut self, grads: &mut [f32]) -> Result<()> {
        self.plan.unpack(&self.staging, grads)?;
        self.bytes_staged += (self.staging.len() * 4) as u64;
        Ok(())
    }

    /// Off-load into an *owned* buffer for the non-blocking collective
    /// API (the buffer's ownership moves into `start_reduce`). Checked
    /// out of the pool — a hit after warmup, never an allocation.
    pub fn pack_owned(&mut self, grads: &[f32]) -> Result<Vec<f32>> {
        let mut buf = self
            .pool
            .checkout(self.plan.transfer_elems(), &mut self.stats);
        self.plan.pack(grads, &mut buf)?;
        self.bytes_staged += (buf.len() * 4) as u64;
        Ok(buf)
    }

    /// On-load a reduced owned buffer (from `wait_reduce`) back into
    /// `grads`; slices outside the plan (biases) keep their local values.
    pub fn onload_from(&mut self, reduced: &[f32], grads: &mut [f32]) -> Result<()> {
        self.plan.unpack(reduced, grads)?;
        self.bytes_staged += (reduced.len() * 4) as u64;
        Ok(())
    }

    /// Return a buffer obtained from `wait_reduce` to the pool.
    pub fn recycle(&mut self, buf: Vec<f32>) {
        self.pool.recycle(buf, &mut self.stats);
    }

    /// Elements that travel per epoch.
    pub fn transfer_elems(&self) -> usize {
        self.plan.transfer_elems()
    }

    pub fn plan(&self) -> &FusionPlan {
        &self.plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::fusion::{segments_from_layout, FusionPlan};

    fn plan_weights_only() -> FusionPlan {
        // layers: W 4 elems + b 2; W 6 + b 1
        let segs = segments_from_layout(&[(0, 4, 4, 2), (6, 6, 12, 1)]);
        FusionPlan::build(segs, 0, false)
    }

    #[test]
    fn offload_excludes_biases_onload_preserves_them() {
        let mut off = GradOffloader::new(plan_weights_only());
        let grads: Vec<f32> = (0..13).map(|x| x as f32).collect();
        let staged = off.offload(&grads).unwrap();
        assert_eq!(staged.len(), 10); // 4 + 6 weights
        // Collective halves everything.
        for v in staged.iter_mut() {
            *v *= 0.5;
        }
        let mut back = grads.clone();
        off.onload(&mut back).unwrap();
        // weights averaged
        assert_eq!(back[0], 0.0);
        assert_eq!(back[3], 1.5);
        assert_eq!(back[6], 3.0);
        // biases untouched (local gradients, as in the paper)
        assert_eq!(back[4], 4.0);
        assert_eq!(back[5], 5.0);
        assert_eq!(back[12], 12.0);
    }

    #[test]
    fn staging_buffer_is_reused() {
        let mut off = GradOffloader::new(plan_weights_only());
        let grads = vec![1.0f32; 13];
        off.offload(&grads).unwrap();
        let ptr1 = off.staging.as_ptr();
        off.offload(&grads).unwrap();
        assert_eq!(ptr1, off.staging.as_ptr());
    }

    #[test]
    fn owned_pipeline_roundtrip_and_double_buffering() {
        let mut off = GradOffloader::new(plan_weights_only());
        let grads: Vec<f32> = (0..13).map(|x| x as f32).collect();
        // Epoch e packs buffer A and "starts" it on the engine.
        let a = off.pack_owned(&grads).unwrap();
        assert_eq!(a.len(), 10);
        // Epoch e+1 packs buffer B while A is still in flight.
        let b = off.pack_owned(&grads).unwrap();
        assert!(a.as_ptr() != b.as_ptr(), "buffers must not alias");
        // A returns reduced; on-load and recycle it.
        let mut back = grads.clone();
        let reduced: Vec<f32> = a.iter().map(|v| v * 0.5).collect();
        off.onload_from(&reduced, &mut back).unwrap();
        assert_eq!(back[3], 1.5); // weights halved
        assert_eq!(back[4], 4.0); // biases local
        off.recycle(a);
        // The next pack reuses the recycled storage: a pool hit, not an
        // allocation.
        let allocs_before = off.pool_stats().allocs;
        let c = off.pack_owned(&grads).unwrap();
        assert_eq!(c.len(), 10);
        assert_eq!(off.pool_stats().allocs, allocs_before);
        off.recycle(b);
        off.recycle(c);
    }

    #[test]
    fn window_depth_buffers_reach_steady_state() {
        let mut off = GradOffloader::new(plan_weights_only());
        let grads = vec![1.0f32; 13];
        // A 3-deep window keeps 3 buffers in flight + 1 packing; after
        // those first four allocations, rotation is pure hits.
        let mut in_flight: Vec<Vec<f32>> =
            (0..4).map(|_| off.pack_owned(&grads).unwrap()).collect();
        assert_eq!(off.pool_stats().allocs, 4);
        for _ in 0..8 {
            off.recycle(in_flight.remove(0));
            in_flight.push(off.pack_owned(&grads).unwrap());
        }
        assert_eq!(off.pool_stats().allocs, 4, "steady state must not allocate");
        assert_eq!(off.pool_stats().pool_hits, 8);
        assert_eq!(off.pool_stats().bytes_recycled, 8 * 10 * 4);
    }

    #[test]
    fn bytes_accounting() {
        let mut off = GradOffloader::new(plan_weights_only());
        let mut grads = vec![1.0f32; 13];
        off.offload(&grads).unwrap();
        off.onload(&mut grads).unwrap();
        assert_eq!(off.bytes_staged, 2 * 10 * 4);
        assert_eq!(off.transfer_elems(), 10);
    }
}
