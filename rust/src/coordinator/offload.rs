//! Gradient off-loading (Sec. IV-B6).
//!
//! The paper stages generator gradients out of GPU memory into host memory
//! before communicating, and registers them back afterwards — both to free
//! GPU memory and because mpi4py moves host buffers. Here the "device"
//! side is the PJRT output buffer and the "host" side is the packed
//! transfer buffer; the staging copy goes through the weight-only
//! [`FusionPlan`] (bias gradients are excluded from transfer, Sec. V-C).
//!
//! The staging buffer is allocated once and reused — the per-epoch hot
//! path performs no allocation.

use crate::tensor::fusion::FusionPlan;
use crate::util::error::Result;

/// Reusable off-/on-load stager for one rank's generator gradients.
///
/// Two staging shapes:
/// * the classic in-place pair [`GradOffloader::offload`] /
///   [`GradOffloader::onload`] for the blocking loop, and
/// * the owned pair [`GradOffloader::pack_owned`] /
///   [`GradOffloader::onload_from`] + [`GradOffloader::recycle`] for the
///   overlap pipeline, which multi-buffers: up to `spare_cap` packed
///   buffers ride the collective engine's comm thread (one per in-flight
///   exchange of a k-deep staleness window) while the next epoch packs
///   into a recycled spare, so overlapping epochs never share storage and
///   the steady-state hot path still performs no allocation.
pub struct GradOffloader {
    plan: FusionPlan,
    staging: Vec<f32>,
    /// Recycled owned transfer buffers for the overlap pipeline.
    spares: Vec<Vec<f32>>,
    /// Spare-pool bound: the window depth plus one packing buffer.
    spare_cap: usize,
    /// Total bytes staged (both directions), for the §Perf accounting.
    pub bytes_staged: u64,
}

impl GradOffloader {
    pub fn new(plan: FusionPlan) -> GradOffloader {
        let cap = plan.transfer_elems();
        GradOffloader {
            plan,
            staging: Vec::with_capacity(cap),
            spares: Vec::new(),
            spare_cap: 2,
            bytes_staged: 0,
        }
    }

    /// Size the recycled-buffer pool for a k-deep exchange window (k
    /// in-flight buffers + 1 being packed). The default pool of 2 covers
    /// the classic one-epoch-stale overlap.
    pub fn with_spare_cap(mut self, cap: usize) -> GradOffloader {
        self.spare_cap = cap.max(1);
        self
    }

    /// Off-load: pack the transferable slices of `grads` into the staging
    /// buffer and return it for the collective to reduce in place.
    pub fn offload(&mut self, grads: &[f32]) -> Result<&mut [f32]> {
        // Split borrows: temporarily move staging out to satisfy the
        // borrow checker without copying twice.
        let mut staging = std::mem::take(&mut self.staging);
        self.plan.pack(grads, &mut staging)?;
        self.staging = staging;
        self.bytes_staged += (self.staging.len() * 4) as u64;
        Ok(&mut self.staging)
    }

    /// On-load: scatter the reduced staging buffer back into `grads`.
    /// Slices outside the plan (biases) keep their local values.
    pub fn onload(&mut self, grads: &mut [f32]) -> Result<()> {
        self.plan.unpack(&self.staging, grads)?;
        self.bytes_staged += (self.staging.len() * 4) as u64;
        Ok(())
    }

    /// Off-load into an *owned* buffer for the non-blocking collective
    /// API (the buffer's ownership moves into `start_reduce`). Reuses a
    /// recycled spare when one is available.
    pub fn pack_owned(&mut self, grads: &[f32]) -> Result<Vec<f32>> {
        let mut buf = self.spares.pop().unwrap_or_default();
        self.plan.pack(grads, &mut buf)?;
        self.bytes_staged += (buf.len() * 4) as u64;
        Ok(buf)
    }

    /// On-load a reduced owned buffer (from `wait_reduce`) back into
    /// `grads`; slices outside the plan (biases) keep their local values.
    pub fn onload_from(&mut self, reduced: &[f32], grads: &mut [f32]) -> Result<()> {
        self.plan.unpack(reduced, grads)?;
        self.bytes_staged += (reduced.len() * 4) as u64;
        Ok(())
    }

    /// Return a buffer obtained from `wait_reduce` to the spare pool.
    pub fn recycle(&mut self, buf: Vec<f32>) {
        if self.spares.len() < self.spare_cap {
            self.spares.push(buf);
        }
    }

    /// Elements that travel per epoch.
    pub fn transfer_elems(&self) -> usize {
        self.plan.transfer_elems()
    }

    pub fn plan(&self) -> &FusionPlan {
        &self.plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::fusion::{segments_from_layout, FusionPlan};

    fn plan_weights_only() -> FusionPlan {
        // layers: W 4 elems + b 2; W 6 + b 1
        let segs = segments_from_layout(&[(0, 4, 4, 2), (6, 6, 12, 1)]);
        FusionPlan::build(segs, 0, false)
    }

    #[test]
    fn offload_excludes_biases_onload_preserves_them() {
        let mut off = GradOffloader::new(plan_weights_only());
        let grads: Vec<f32> = (0..13).map(|x| x as f32).collect();
        let staged = off.offload(&grads).unwrap();
        assert_eq!(staged.len(), 10); // 4 + 6 weights
        // Collective halves everything.
        for v in staged.iter_mut() {
            *v *= 0.5;
        }
        let mut back = grads.clone();
        off.onload(&mut back).unwrap();
        // weights averaged
        assert_eq!(back[0], 0.0);
        assert_eq!(back[3], 1.5);
        assert_eq!(back[6], 3.0);
        // biases untouched (local gradients, as in the paper)
        assert_eq!(back[4], 4.0);
        assert_eq!(back[5], 5.0);
        assert_eq!(back[12], 12.0);
    }

    #[test]
    fn staging_buffer_is_reused() {
        let mut off = GradOffloader::new(plan_weights_only());
        let grads = vec![1.0f32; 13];
        off.offload(&grads).unwrap();
        let ptr1 = off.staging.as_ptr();
        off.offload(&grads).unwrap();
        assert_eq!(ptr1, off.staging.as_ptr());
    }

    #[test]
    fn owned_pipeline_roundtrip_and_double_buffering() {
        let mut off = GradOffloader::new(plan_weights_only());
        let grads: Vec<f32> = (0..13).map(|x| x as f32).collect();
        // Epoch e packs buffer A and "starts" it on the engine.
        let a = off.pack_owned(&grads).unwrap();
        assert_eq!(a.len(), 10);
        // Epoch e+1 packs buffer B while A is still in flight.
        let b = off.pack_owned(&grads).unwrap();
        assert!(a.as_ptr() != b.as_ptr(), "buffers must not alias");
        // A returns reduced; on-load and recycle it.
        let mut back = grads.clone();
        let reduced: Vec<f32> = a.iter().map(|v| v * 0.5).collect();
        off.onload_from(&reduced, &mut back).unwrap();
        assert_eq!(back[3], 1.5); // weights halved
        assert_eq!(back[4], 4.0); // biases local
        off.recycle(a);
        // The next pack reuses the recycled storage: no new allocation.
        let c = off.pack_owned(&grads).unwrap();
        assert_eq!(c.len(), 10);
        off.recycle(b);
        off.recycle(c);
    }

    #[test]
    fn spare_pool_sized_for_window_depth() {
        let mut off = GradOffloader::new(plan_weights_only()).with_spare_cap(4);
        let grads = vec![1.0f32; 13];
        // A 3-deep window keeps 3 buffers in flight + 1 packing; all four
        // must fit back in the pool (a 5th is dropped).
        let bufs: Vec<Vec<f32>> = (0..5).map(|_| off.pack_owned(&grads).unwrap()).collect();
        for b in bufs {
            off.recycle(b);
        }
        assert_eq!(off.spares.len(), 4);
    }

    #[test]
    fn bytes_accounting() {
        let mut off = GradOffloader::new(plan_weights_only());
        let mut grads = vec![1.0f32; 13];
        off.offload(&grads).unwrap();
        off.onload(&mut grads).unwrap();
        assert_eq!(off.bytes_staged, 2 * 10 * 4);
        assert_eq!(off.transfer_elems(), 10);
    }
}
