//! Fault-tolerant train-resume: periodic run checkpointing and restore.
//!
//! Long asynchronous training runs on HPC systems hit walltime limits and
//! node failures; SAGIPS-class workflows therefore need to stop at an
//! arbitrary epoch and continue later as if nothing happened. This module
//! supplies the two coordinator-side halves:
//!
//! * [`RunCheckpointer`] — the write path. Each rank deposits its full
//!   [`RankTrainState`] at the configured cadence
//!   (`RunConfig::ckpt_every`); when the last rank's state for an epoch
//!   arrives, the rank-0-owned checkpointer serializes a
//!   [`TrainCheckpoint`] atomically (write-then-rename) into
//!   `RunConfig::ckpt_dir` and prunes to the newest
//!   `RunConfig::ckpt_keep` checkpoints. Ranks never block on each other:
//!   a deposit is a mutex-guarded insert, and whichever rank completes an
//!   epoch's set performs the write — the on-disk result is identical to
//!   the classic gather-to-rank-0 scheme, without stalling rank 0's epoch
//!   loop.
//! * [`prepare_resume`] — the restore path. Resolves `--resume <path>`
//!   (a specific `run_e*` directory or a checkpoint root, newest wins),
//!   loads it through [`TrainCheckpoint::load_for_scenario`] — which
//!   routes the scenario-identity guard through
//!   [`Checkpoint::load_for_scenario`][crate::model::checkpoint::Checkpoint::load_for_scenario]
//!   — and validates it against the run configuration (rank count, base
//!   seed, model parameter counts, remaining epochs, rank-id integrity).
//!   The launcher then hands each
//!   rank its own state before the first epoch (the thread-world
//!   equivalent of rank 0 broadcasting the restored state), so a resumed
//!   multi-rank run is bit-identical to an uninterrupted one. With
//!   `--allow-join` the rank-count match is relaxed: a checkpoint wider
//!   than the run is shrunk (extra ranks evicted), a narrower one grown
//!   (new ranks join on a donor snapshot of rank 0's state) — the
//!   process-restart half of elastic membership (see
//!   `docs/fault-tolerance.md`).
//!
//! See `docs/checkpointing.md` for the on-disk format and a runnable
//! save → kill → resume walkthrough.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::config::RunConfig;
use crate::model::checkpoint::{RankTrainState, TrainCheckpoint};
use crate::runtime::Manifest;
use crate::util::error::{Error, Result};

use super::membership::{MembershipChange, MembershipRecord};

/// Per-rank restore bundle handed to `run_rank` by the launcher.
#[derive(Clone, Debug)]
pub struct RankResume {
    /// First epoch the resumed loop executes (checkpoint epoch + 1).
    pub start_epoch: u64,
    /// Training seconds accumulated before the restart; added to the
    /// resumed run's timer so checkpoint timestamps stay monotone.
    pub elapsed_offset: f64,
    /// The rank's complete state at the checkpoint boundary.
    pub state: RankTrainState,
}

/// One epoch's partially assembled checkpoint.
struct PendingEpoch {
    slots: Vec<Option<RankTrainState>>,
    filled: usize,
    elapsed_s: f64,
}

/// Shared (Arc'd across rank threads) periodic checkpoint writer.
pub struct RunCheckpointer {
    dir: PathBuf,
    every: usize,
    keep: usize,
    ranks: usize,
    seed: u64,
    scenario: String,
    pending: Mutex<BTreeMap<u64, PendingEpoch>>,
    /// Checkpoints fully written by this process, epoch -> directory.
    /// Joining ranks block on `written_cv` until their hand-off boundary
    /// appears here (or on disk, when the boundary was written by a
    /// previous process of a resumed run).
    written: Mutex<BTreeMap<u64, PathBuf>>,
    written_cv: Condvar,
}

impl RunCheckpointer {
    /// `every` is the epoch cadence (must be >= 1 — a zero cadence means
    /// checkpointing is disabled and no checkpointer should exist).
    /// `seed` is the run's base RNG seed, recorded so resume can refuse a
    /// mismatching configuration. Temporary directories orphaned by a
    /// crashed writer (`.tmp_run_e*` — a kill between the staging write
    /// and the atomic rename) are swept on construction, so a restarted
    /// run never accumulates stale staging state.
    pub fn new(
        dir: &Path,
        every: usize,
        keep: usize,
        ranks: usize,
        seed: u64,
        scenario: String,
    ) -> RunCheckpointer {
        debug_assert!(every >= 1 && keep >= 1 && ranks >= 1);
        let swept = Self::sweep_orphaned_tmp(dir);
        if swept > 0 {
            crate::log_info!(
                "swept {swept} orphaned .tmp checkpoint director{} from {} \
                 (crashed writer leftovers)",
                if swept == 1 { "y" } else { "ies" },
                dir.display()
            );
        }
        RunCheckpointer {
            dir: dir.to_path_buf(),
            every,
            keep: keep.max(1),
            ranks,
            seed,
            scenario,
            pending: Mutex::new(BTreeMap::new()),
            written: Mutex::new(BTreeMap::new()),
            written_cv: Condvar::new(),
        }
    }

    /// The checkpoint cadence in epochs.
    pub fn every(&self) -> usize {
        self.every
    }

    /// Remove `.tmp_run_e*` staging directories left behind by a writer
    /// that died mid-save. Returns how many were removed. Best-effort:
    /// an unreadable directory is skipped, never fatal — the writer
    /// re-stages over any survivor by name.
    fn sweep_orphaned_tmp(dir: &Path) -> usize {
        let Ok(entries) = std::fs::read_dir(dir) else {
            return 0;
        };
        let mut swept = 0;
        for entry in entries.flatten() {
            let p = entry.path();
            if p.file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.starts_with(".tmp_run_e"))
                && std::fs::remove_dir_all(&p).is_ok()
            {
                swept += 1;
            }
        }
        swept
    }

    /// Whether the cadence fires at the end of `epoch` (same convention as
    /// the analysis checkpoints: every `every`-th *completed* epoch).
    pub fn wants(&self, epoch: u64) -> bool {
        (epoch + 1) % self.every as u64 == 0
    }

    /// Deposit one rank's state for `epoch`. When the last rank's deposit
    /// arrives, the assembled [`TrainCheckpoint`] is written atomically
    /// and old checkpoints are pruned; returns the written path in that
    /// case, `None` while the epoch set is still incomplete.
    pub fn deposit(
        &self,
        epoch: u64,
        elapsed_s: f64,
        state: RankTrainState,
    ) -> Result<Option<PathBuf>> {
        let rank = state.rank;
        if rank >= self.ranks {
            return Err(Error::Checkpoint(format!(
                "checkpoint deposit from rank {rank}, run has {}",
                self.ranks
            )));
        }
        // The lock guards only the in-memory assembly: the deposit itself
        // is a quick insert, and the rank that completes an epoch's set
        // serializes and writes *after* releasing the lock — so no rank
        // ever blocks on another checkpoint's disk I/O. Concurrent writes
        // of different epochs are safe: each uses its own temporary
        // directory, and pruning tolerates losing a removal race.
        let entry = {
            let mut pending = self
                .pending
                .lock()
                .map_err(|_| Error::Checkpoint("checkpointer mutex poisoned".into()))?;
            let entry = pending.entry(epoch).or_insert_with(|| PendingEpoch {
                slots: (0..self.ranks).map(|_| None).collect(),
                filled: 0,
                elapsed_s: 0.0,
            });
            if entry.slots[rank].replace(state).is_none() {
                entry.filled += 1;
            }
            entry.elapsed_s = entry.elapsed_s.max(elapsed_s);
            if entry.filled < self.ranks {
                return Ok(None);
            }
            pending.remove(&epoch).expect("entry just inserted")
        };
        let ck = TrainCheckpoint {
            epoch,
            elapsed_s: entry.elapsed_s,
            seed: self.seed,
            scenario: self.scenario.clone(),
            ranks: entry
                .slots
                .into_iter()
                .map(|s| s.expect("all slots filled"))
                .collect(),
        };
        let path = ck.save(&self.dir)?;
        TrainCheckpoint::prune(&self.dir, self.keep)?;
        crate::log_info!(
            "run checkpoint written: {} (epoch {epoch}, {} ranks, keep {})",
            path.display(),
            self.ranks,
            self.keep
        );
        {
            let mut written = self
                .written
                .lock()
                .map_err(|_| Error::Checkpoint("checkpointer mutex poisoned".into()))?;
            written.insert(epoch, path.clone());
        }
        self.written_cv.notify_all();
        Ok(Some(path))
    }

    /// Block until the checkpoint for `epoch` exists — written by this
    /// process (whichever rank completed the epoch's deposit set) or
    /// already on disk from a previous process (elastic tail resumes) —
    /// and return its directory. Joining ranks race ahead of the live
    /// cohort through their dormant epochs, so the hand-off boundary they
    /// need may be several epochs of real training away; `timeout` bounds
    /// that wait.
    pub fn wait_for(&self, epoch: u64, timeout: Duration) -> Result<PathBuf> {
        let on_disk = self.dir.join(TrainCheckpoint::dir_name(epoch));
        let deadline = Instant::now() + timeout;
        let mut written = self
            .written
            .lock()
            .map_err(|_| Error::Checkpoint("checkpointer mutex poisoned".into()))?;
        loop {
            if let Some(p) = written.get(&epoch) {
                return Ok(p.clone());
            }
            if on_disk.is_dir() {
                return Ok(on_disk);
            }
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                return Err(Error::Checkpoint(format!(
                    "timed out waiting for the run checkpoint at epoch {epoch} \
                     (membership hand-off boundary) — is the live cohort making \
                     progress?"
                )));
            }
            // Short waits so the on-disk probe (prior-process checkpoints
            // carry no in-process notification) stays responsive.
            let (guard, _) = self
                .written_cv
                .wait_timeout(written, left.min(Duration::from_millis(50)))
                .map_err(|_| Error::Checkpoint("checkpointer mutex poisoned".into()))?;
            written = guard;
        }
    }
}

/// Load and validate the checkpoint `cfg.resume` points at. Returns the
/// checkpoint ready for per-rank distribution, plus the membership
/// records of any elastic shrink/grow applied under `cfg.allow_join`
/// (empty when the widths already match).
pub fn prepare_resume(
    cfg: &RunConfig,
    manifest: &Manifest,
) -> Result<(TrainCheckpoint, Vec<MembershipRecord>)> {
    let path = cfg
        .resume
        .as_ref()
        .ok_or_else(|| Error::config("prepare_resume called without cfg.resume"))?;
    let mut ck = TrainCheckpoint::load_for_scenario(Path::new(path), &manifest.scenario)?;
    let mut records: Vec<MembershipRecord> = Vec::new();
    if ck.ranks.len() != cfg.ranks {
        if !cfg.allow_join {
            return Err(Error::config(format!(
                "checkpoint holds {} ranks but the run is configured for {} — \
                 resume with the same --ranks the checkpoint was written with, \
                 or pass --allow-join to shrink/grow the cohort elastically",
                ck.ranks.len(),
                cfg.ranks
            )));
        }
        // First epoch the re-shaped membership trains at.
        let effect = ck.epoch + 1;
        let was = ck.ranks.len();
        if was > cfg.ranks {
            // Shrink: the highest ranks are evicted (rank 0 — the
            // checkpoint sidecar anchor — always survives).
            for dropped in ck.ranks.drain(cfg.ranks..) {
                records.push(MembershipRecord {
                    epoch: effect,
                    rank: dropped.rank,
                    kind: MembershipChange::Evict,
                });
            }
            crate::log_info!(
                "membership: resume shrank {was} -> {} ranks \
                 (ranks {}..{} evicted at epoch {effect})",
                cfg.ranks,
                cfg.ranks,
                was - 1
            );
        } else {
            // Grow: new ranks join on a donor snapshot of rank 0's state.
            // The launcher replaces each joiner's RNG with its own
            // seed-derived stream — the donor's would collide with rank
            // 0's draws.
            let donor = ck.ranks[0].clone();
            for rank in was..cfg.ranks {
                let mut state = donor.clone();
                state.rank = rank;
                ck.ranks.push(state);
                records.push(MembershipRecord {
                    epoch: effect,
                    rank,
                    kind: MembershipChange::Join,
                });
            }
            crate::log_info!(
                "membership: resume grew {was} -> {} ranks (ranks {was}..{} \
                 join via checkpoint hand-off at epoch {effect})",
                cfg.ranks,
                cfg.ranks - 1
            );
        }
    }
    // The seed defines the data pool and the per-rank shard derivation;
    // restoring old parameters/RNG streams onto different data would
    // silently break the bit-identical contract.
    if ck.seed != cfg.seed {
        return Err(Error::config(format!(
            "checkpoint was written by a run with seed {} but the run is \
             configured with seed {} — resume with the matching --seed",
            ck.seed, cfg.seed
        )));
    }
    if ck.epoch + 1 >= cfg.epochs as u64 {
        return Err(Error::config(format!(
            "checkpoint is at epoch {} but the run is configured for {} \
             total epochs — nothing left to train (raise --epochs)",
            ck.epoch, cfg.epochs
        )));
    }
    let meta = manifest.model(&cfg.model)?;
    for (i, rs) in ck.ranks.iter().enumerate() {
        // The loader sorts by rank; anything other than exactly 0..n-1
        // (duplicates, gaps) means a corrupt file and would silently
        // mis-assign state to ranks by position.
        if rs.rank != i {
            return Err(Error::Checkpoint(format!(
                "checkpoint rank ids are not exactly 0..{} (found {} at \
                 position {i}) — checkpoint is corrupt",
                ck.ranks.len() - 1,
                rs.rank
            )));
        }
        if rs.gen.len() != meta.gen_param_count || rs.disc.len() != meta.disc_param_count {
            return Err(Error::config(format!(
                "checkpoint rank {} holds {}/{} generator/discriminator \
                 parameters, model '{}' expects {}/{} — resume with the \
                 matching --model",
                rs.rank,
                rs.gen.len(),
                rs.disc.len(),
                cfg.model,
                meta.gen_param_count,
                meta.disc_param_count
            )));
        }
        if rs.gen_m.len() != rs.gen.len()
            || rs.gen_v.len() != rs.gen.len()
            || rs.disc_m.len() != rs.disc.len()
            || rs.disc_v.len() != rs.disc.len()
        {
            return Err(Error::Checkpoint(format!(
                "checkpoint rank {} optimizer moments do not match its \
                 parameter vectors — checkpoint is corrupt",
                rs.rank
            )));
        }
    }
    Ok((ck, records))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn state(rank: usize, n: usize) -> RankTrainState {
        RankTrainState {
            rank,
            gen: vec![rank as f32; n],
            disc: vec![rank as f32 + 0.5; n],
            gen_m: vec![0.0; n],
            gen_v: vec![0.0; n],
            gen_t: 1,
            disc_m: vec![0.0; n],
            disc_v: vec![0.0; n],
            disc_t: 1,
            rng: Rng::new(rank as u64).snapshot(),
        }
    }

    #[test]
    fn cadence_matches_analysis_checkpoint_convention() {
        let dir = std::env::temp_dir().join("sagips_ckr_cadence");
        let c = RunCheckpointer::new(&dir, 10, 2, 1, 20240, "quantile".into());
        assert!(!c.wants(0));
        assert!(c.wants(9));
        assert!(c.wants(19));
        assert!(!c.wants(10));
    }

    #[test]
    fn deposit_writes_when_all_ranks_arrive_and_prunes() {
        let dir = std::env::temp_dir()
            .join(format!("sagips_ckr_dep_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let c = RunCheckpointer::new(&dir, 5, 1, 3, 20240, "quantile".into());
        // Ranks deposit out of order; the write happens on the last one.
        assert!(c.deposit(4, 1.0, state(2, 4)).unwrap().is_none());
        assert!(c.deposit(4, 1.5, state(0, 4)).unwrap().is_none());
        let p = c.deposit(4, 1.2, state(1, 4)).unwrap().expect("complete set");
        assert!(p.ends_with(TrainCheckpoint::dir_name(4)));
        // Second cadence epoch: keep=1 prunes the first.
        for r in 0..3 {
            c.deposit(9, 2.0, state(r, 4)).unwrap();
        }
        let left = TrainCheckpoint::list(&dir).unwrap();
        assert_eq!(left.len(), 1);
        assert!(left[0].ends_with(TrainCheckpoint::dir_name(9)));
        // The written checkpoint carries the max deposited elapsed time
        // and all ranks sorted.
        let ck = TrainCheckpoint::load_for_scenario(&left[0], "quantile").unwrap();
        assert_eq!(ck.epoch, 9);
        assert_eq!(ck.elapsed_s, 2.0);
        assert_eq!(
            ck.ranks.iter().map(|r| r.rank).collect::<Vec<_>>(),
            vec![0, 1, 2]
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn duplicate_deposit_does_not_double_count() {
        let dir = std::env::temp_dir()
            .join(format!("sagips_ckr_dup_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let c = RunCheckpointer::new(&dir, 1, 2, 2, 20240, "quantile".into());
        assert!(c.deposit(0, 0.1, state(0, 2)).unwrap().is_none());
        assert!(c.deposit(0, 0.2, state(0, 2)).unwrap().is_none());
        assert!(c.deposit(0, 0.3, state(1, 2)).unwrap().is_some());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn init_sweeps_orphaned_tmp_checkpoint_dirs() {
        let dir = std::env::temp_dir()
            .join(format!("sagips_ckr_sweep_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        // A complete checkpoint and a crashed writer's staging leftovers.
        let c = RunCheckpointer::new(&dir, 1, 2, 2, 20240, "quantile".into());
        c.deposit(0, 0.1, state(0, 2)).unwrap();
        c.deposit(0, 0.2, state(1, 2)).unwrap();
        let stale = dir.join(".tmp_run_e0000000005_99999");
        std::fs::create_dir_all(stale.join("nested")).unwrap();
        std::fs::write(stale.join("state.bin"), b"partial").unwrap();
        drop(c);
        // A fresh checkpointer (the restarted run) sweeps the orphan and
        // leaves the complete checkpoint alone.
        let _c2 = RunCheckpointer::new(&dir, 1, 2, 2, 20240, "quantile".into());
        assert!(!stale.exists(), "stale .tmp dir survived init");
        assert_eq!(TrainCheckpoint::list(&dir).unwrap().len(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn wait_for_returns_written_and_on_disk_checkpoints() {
        let dir = std::env::temp_dir()
            .join(format!("sagips_ckr_wait_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let c = RunCheckpointer::new(&dir, 5, 2, 2, 20240, "quantile".into());
        // Not written yet: a short wait times out.
        assert!(c.wait_for(4, Duration::from_millis(60)).is_err());
        c.deposit(4, 0.1, state(0, 2)).unwrap();
        c.deposit(4, 0.2, state(1, 2)).unwrap();
        let p = c.wait_for(4, Duration::from_millis(60)).unwrap();
        assert!(p.ends_with(TrainCheckpoint::dir_name(4)));
        // A fresh checkpointer (a resumed process) finds it on disk
        // without any in-process notification.
        let c2 = RunCheckpointer::new(&dir, 5, 2, 2, 20240, "quantile".into());
        let p2 = c2.wait_for(4, Duration::from_millis(200)).unwrap();
        assert!(p2.ends_with(TrainCheckpoint::dir_name(4)));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn out_of_range_rank_is_rejected() {
        let dir = std::env::temp_dir().join("sagips_ckr_oor");
        let c = RunCheckpointer::new(&dir, 1, 1, 2, 20240, "quantile".into());
        assert!(c.deposit(0, 0.0, state(5, 2)).is_err());
    }

    // prepare_resume's validation paths are exercised end to end (against
    // real manifests and training runs) in rust/tests/resume.rs.
}
