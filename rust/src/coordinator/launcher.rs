//! The launcher: wires the whole distributed run and joins it.
//!
//! Responsibilities (what `mpiexec` + rank 0 do in the paper's setup):
//! build the topology, the transport network, the RMA region and the
//! per-rank collectives; generate the reference data (rank 0 loads and
//! distributes the data in the paper — here the pool is generated once and
//! sharded); restore and distribute a run checkpoint when resuming; spawn
//! one thread per rank; join; then run the post-training analysis:
//! evaluate the normalized residuals over rank 0's timestamped generator
//! checkpoints (Sec. VI-C2).

use std::sync::Arc;

use crate::collective;
use crate::comm::{LinkModel, LocalNetwork, RmaRegion, Topology};
use crate::config::{Mode, RunConfig, StragglerPolicy};
use crate::data::{Bootstrap, ToyDataset};
use crate::fault::FaultPlan;
use crate::metrics::MergedMetrics;
use crate::model::checkpoint::CheckpointSeries;
use crate::model::gan::GanState;
use crate::model::residuals::{self, Residuals};
use crate::runtime::{Runtime, RuntimeHandle};
use crate::util::error::{Error, Result};
use crate::util::rng::Rng;

use super::control::RunControl;
use super::membership::{
    MembershipChange, MembershipDirector, MembershipRecord, MembershipSchedule,
};
use super::pipeline::RankHealth;
use super::rank::{run_rank, RankOutcome};
use super::resume::{prepare_resume, RankResume, RunCheckpointer};

/// One residual sample of the post-training analysis. The residual vector
/// is the scenario's parameter width (eq 6 per parameter).
#[derive(Clone, Debug)]
pub struct ResidualPoint {
    pub epoch: u64,
    pub elapsed_s: f64,
    pub residuals: Vec<f64>,
}

/// Everything a training run produces.
pub struct RunResult {
    pub wall_s: f64,
    pub metrics: MergedMetrics,
    pub checkpoints: Vec<CheckpointSeries>,
    pub states: Vec<GanState>,
    /// Residuals over rank 0's checkpoints (time-resolved convergence).
    pub residual_curve: Vec<ResidualPoint>,
    /// Final residuals (last checkpoint), one entry per scenario
    /// parameter.
    pub final_residuals: Option<Vec<f64>>,
    /// Aggregate communication stats per rank.
    pub comm: Vec<collective::CommStats>,
    /// Per-rank exchange health (deadline misses, settle latency).
    pub health: Vec<RankHealth>,
    /// Epoch the run resumed from (`None` for a fresh run).
    pub resumed_from: Option<u64>,
    /// Membership events the run observed — scripted leaves/joins,
    /// health-driven evictions, and elastic resume shrink/grow — in
    /// (epoch, rank) order. Empty for a fixed-cohort run.
    pub membership: Vec<MembershipRecord>,
    /// The checkpoint boundary the run was cancelled at via
    /// [`RunControl`], `None` when it ran to completion. When set, the
    /// run's final checkpoint on disk is at exactly this epoch and is
    /// `--resume`-able.
    pub stopped_at: Option<u64>,
}

impl RunResult {
    /// Mean |r̂| at the end of training (summary scalar).
    pub fn final_mean_abs_residual(&self) -> Option<f64> {
        self.final_residuals.as_deref().map(residuals::mean_abs)
    }

    /// Total events analyzed across ranks (numerator of eq (9)).
    pub fn total_events(&self) -> f64 {
        self.metrics.total("events")
    }

    /// Analysis rate, eq (9): events analyzed per second of wall time.
    pub fn analysis_rate(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.total_events() / self.wall_s
        } else {
            0.0
        }
    }

    /// How many membership events of `kind` the run observed.
    pub fn membership_count(&self, kind: MembershipChange) -> usize {
        self.membership.iter().filter(|r| r.kind == kind).count()
    }

    /// Live ranks at the end of the run: the latest-epoch `members`
    /// sample any rank recorded, falling back to the launched width when
    /// the series was never recorded (fixed cohort).
    pub fn final_members(&self) -> usize {
        self.metrics
            .latest("members")
            .map(|v| v as usize)
            .unwrap_or(self.states.len())
    }
}

/// Run a full distributed training per `cfg` using an existing runtime
/// handle. Optionally inject link-model latency (used by timing studies).
pub fn run_training_with_links(
    cfg: &RunConfig,
    handle: &RuntimeHandle,
    link_model: LinkModel,
) -> Result<RunResult> {
    run_training_controlled(cfg, handle, link_model, None)
}

/// Like [`run_training_with_links`], with an optional [`RunControl`]
/// attached: the caller can request cooperative cancellation (the run
/// stops at a checkpoint-cadence boundary with a resumable deposit on
/// disk) and observe live progress. This is the service layer's entry
/// point; a `None` control makes it exactly the one-shot path.
pub fn run_training_controlled(
    cfg: &RunConfig,
    handle: &RuntimeHandle,
    link_model: LinkModel,
    control: Option<Arc<RunControl>>,
) -> Result<RunResult> {
    cfg.validate()?;
    let manifest = handle.manifest();
    // The runtime's manifest is pinned to one scenario (model shapes,
    // true params, artifact shapes): a mismatching config would train the
    // wrong forward operator against the wrong reference data. Compare
    // canonical names (lookup is case-insensitive, manifests store the
    // canonical form).
    if manifest.scenario != crate::scenario::lookup(&cfg.scenario)?.name() {
        return Err(Error::config(format!(
            "config selects scenario '{}' but the runtime was built for \
             '{}' — rebuild the runtime (Runtime::from_config) with the \
             same scenario",
            cfg.scenario, manifest.scenario
        )));
    }
    // Fail fast if the artifact grid is missing this configuration.
    manifest.artifact(&cfg.gan_step_artifact())?;
    manifest.artifact(&cfg.gen_predict_artifact())?;

    let topo = Topology::new(cfg.ranks, cfg.gpus_per_node);
    // RMA windows sized for one epoch of ring steps per Sec. IV-B3
    // (chunked schedules run 2·(g-1) steps, so they get double depth).
    // A k-deep staleness window lets the comm worker run up to k epochs
    // ahead of a slow peer, so the region scales with the window depth to
    // keep deposits from overwriting undelivered slots.
    let region = RmaRegion::with_capacity(
        cfg.ranks,
        collective::rma_window_depth(cfg.gpus_per_node, cfg.chunking) * cfg.staleness.max(1),
    );
    // Deterministic fault injection: parse the plan once and share it
    // with every endpoint — senders realize the plan's delays/stalls at
    // isend time, keyed purely by (rank, epoch, plan seed).
    let fault_plan = match &cfg.fault_plan {
        Some(spec) => {
            let plan = FaultPlan::from_spec(spec)?;
            crate::log_info!(
                "fault injection armed: seed {}, {} delayed / {} transient ranks, \
                 {} stall windows",
                plan.seed,
                plan.n_delayed(),
                plan.n_transient(),
                plan.n_stalls()
            );
            Some(Arc::new(plan))
        }
        None => None,
    };
    let endpoints = LocalNetwork::build_with_faults(&topo, link_model, fault_plan);
    let mut collectives = collective::build_with_policy(
        cfg.mode,
        &topo,
        cfg.outer_freq,
        endpoints,
        &region,
        cfg.chunking,
    )?;

    // Resume: rank 0 loads the run checkpoint once (through the
    // scenario-identity guard) and the per-rank states are handed to the
    // rank threads below — the thread-world equivalent of broadcasting
    // the restored state to all ranks before the first epoch. Under
    // --allow-join the cohort may shrink or grow here; the membership
    // records say what happened.
    let (restored, resume_records) = match &cfg.resume {
        Some(_) => {
            let (ck, recs) = prepare_resume(cfg, manifest)?;
            (Some(ck), recs)
        }
        None => (None, Vec::new()),
    };
    let resumed_from = restored.as_ref().map(|ck| {
        crate::log_info!(
            "resuming from epoch {} ({} ranks, scenario {}, {:.2}s \
             accumulated): epochs {}..{} remain",
            ck.epoch,
            ck.ranks.len(),
            ck.scenario,
            ck.elapsed_s,
            ck.epoch + 1,
            cfg.epochs
        );
        ck.epoch
    });
    let start_epoch = restored.as_ref().map(|ck| ck.epoch + 1).unwrap_or(0);

    // Elastic membership: arm the shared director when a scripted
    // schedule or health-driven eviction is configured (validate() has
    // already vetted the schedule against the run shape). The start-epoch
    // view is applied to the bare collectives before any engine wrap, so
    // initially-dormant ranks never enter the first ring.
    let membership_schedule = match &cfg.membership {
        Some(spec) => MembershipSchedule::parse(spec)?,
        None => MembershipSchedule::default(),
    };
    let director = if !membership_schedule.is_empty() || cfg.evict_after > 0 {
        crate::log_info!(
            "elastic membership armed: {} scripted event(s), evict_after {}, \
             min_ranks {}",
            membership_schedule.len(),
            cfg.evict_after,
            cfg.min_ranks
        );
        Some(Arc::new(MembershipDirector::new(
            membership_schedule,
            cfg.ranks,
            cfg.min_ranks,
        )))
    } else {
        None
    };
    if let Some(dir) = &director {
        let view = dir.view_at(start_epoch);
        if view.len() < cfg.ranks {
            crate::log_info!(
                "membership: starting at epoch {start_epoch} with {}/{} live \
                 ranks (view v{})",
                view.len(),
                cfg.ranks,
                view.version()
            );
            for coll in collectives.iter_mut() {
                coll.set_membership(&view)?;
            }
        }
    }

    // Staleness >= 1: move every rank's collective onto a dedicated comm
    // thread with a window sized to the configured staleness, so the rank
    // pipeline's start_reduce/wait_reduce/drain calls genuinely overlap
    // up to k exchanges with later epochs' compute. The Horovod baseline
    // is exempt — its defining property is the globally synchronous
    // blocking all-reduce, and the simulator models it that way; hiding
    // it behind a comm thread would silently change the baseline being
    // compared against.
    //
    // Skip / late-apply need engine-window headroom beyond the staleness:
    // an abandoned (or overdue) exchange keeps its engine slot until the
    // straggler's ring finally settles, and without spare slots the very
    // next submission would block on WindowFull — re-introducing the
    // stall the policy exists to avoid. The headroom is the skip budget
    // when bounded, else a fixed allowance.
    let engine_window = match cfg.on_straggler {
        StragglerPolicy::Block => cfg.staleness,
        _ => cfg.staleness + if cfg.skip_budget > 0 { cfg.skip_budget } else { 16 },
    };
    let collectives: Vec<Box<dyn collective::Collective>> =
        if cfg.staleness >= 1 && cfg.mode != Mode::Horovod {
            collectives
                .into_iter()
                .map(|c| {
                    collective::engine::CollectiveEngine::spawn_windowed(c, engine_window)
                        .map(|e| Box::new(e) as Box<dyn collective::Collective>)
                })
                .collect::<Result<_>>()?
        } else {
            collectives
        };

    // Reference data pool (the paper: rank 0 loads + distributes; each
    // rank then trains on a random sub-fraction).
    let pipeline_artifact = pick_pipeline_artifact(handle)?;
    let pool = ToyDataset::generate(handle, &pipeline_artifact, cfg.data_pool, cfg.seed)?;

    // Periodic run checkpointing (rank-0-owned, shared across the rank
    // threads; disabled unless ckpt_every > 0).
    let checkpointer = if cfg.ckpt_every > 0 {
        Some(Arc::new(RunCheckpointer::new(
            std::path::Path::new(&cfg.ckpt_dir),
            cfg.ckpt_every,
            cfg.ckpt_keep,
            cfg.ranks,
            cfg.seed,
            manifest.scenario.clone(),
        )))
    } else {
        None
    };

    // Horovod is exempt from the engine wrap above; make the rank
    // pipeline blocking too, so its staleness semantics and
    // comm_s/comm_hidden_s accounting match the collective it actually
    // runs on (otherwise the eager start_reduce fallback would count the
    // full blocking reduce as hot comm *and* report it again as hidden,
    // with nonzero staleness and no real overlap).
    let rank_cfg = {
        let mut c = cfg.clone();
        if c.mode == Mode::Horovod {
            c.staleness = 0;
        }
        c
    };

    // Arm the cancellation control with the *effective* window depth the
    // ranks run under — the stop-boundary consensus sizes its drift
    // margin from it (see coordinator::control).
    if let Some(ctl) = &control {
        ctl.arm(rank_cfg.staleness);
    }

    // Ranks grown at resume (`--allow-join` with a narrower checkpoint):
    // they train on the donor snapshot but must draw from their own
    // seed-derived stream, not the donor's.
    let joined_at_resume: Vec<usize> = resume_records
        .iter()
        .filter(|r| r.kind == MembershipChange::Join)
        .map(|r| r.rank)
        .collect();

    let mut root_rng = Rng::new(cfg.seed);
    let timer = crate::metrics::Timer::start();
    let mut handles = Vec::with_capacity(cfg.ranks);
    for (rank, coll) in collectives.into_iter().enumerate() {
        let cfg = rank_cfg.clone();
        let handle = handle.clone();
        let mut rng = root_rng.split(rank as u64);
        // Horovod baseline: every rank sees the full data (Sec. VI-C2);
        // (RMA-)ARAR ranks train on a random sub-fraction.
        let shard = if cfg.mode == Mode::Horovod {
            pool.clone()
        } else {
            pool.shard(cfg.subsample_fraction, &mut rng)
        };
        let boot = Bootstrap::new(shard);
        let ckpt = checkpointer.clone();
        let dir = director.clone();
        let ctl = control.clone();
        let resume = restored.as_ref().map(|ck| {
            let mut state = ck.ranks[rank].clone();
            if joined_at_resume.contains(&rank) {
                state.rng = rng.snapshot();
            }
            RankResume {
                start_epoch: ck.epoch + 1,
                elapsed_offset: ck.elapsed_s,
                state,
            }
        });
        handles.push(
            std::thread::Builder::new()
                .name(format!("rank-{rank}"))
                .spawn(move || {
                    run_rank(
                        rank,
                        &cfg,
                        handle,
                        coll,
                        boot,
                        rng,
                        rank == 0,
                        ckpt,
                        resume,
                        dir,
                        ctl,
                    )
                })
                .map_err(Error::Io)?,
        );
    }

    let mut outcomes: Vec<RankOutcome> = Vec::with_capacity(cfg.ranks);
    for h in handles {
        outcomes.push(h.join().map_err(|_| {
            Error::Runtime("rank thread panicked — see stderr for the rank log".into())
        })??);
    }
    let wall_s = timer.elapsed_s();
    outcomes.sort_by_key(|o| o.rank);

    // Cancellation stop-boundary agreement: the control's consensus rule
    // guarantees every rank stops at the same checkpoint boundary. Check
    // it anyway — a disagreement would mean a torn final checkpoint, and
    // silently returning one rank's answer would hide the bug.
    let stopped_at = outcomes[0].stopped_at;
    if outcomes.iter().any(|o| o.stopped_at != stopped_at) {
        return Err(Error::Runtime(format!(
            "cancellation stop boundaries disagree across ranks: {:?}",
            outcomes.iter().map(|o| o.stopped_at).collect::<Vec<_>>()
        )));
    }

    // Post-training residual analysis over rank 0's checkpoints.
    let evaluator = Residuals::new(handle.clone(), &cfg.gen_predict_artifact(), cfg.seed)?;
    let mut residual_curve = Vec::new();
    for ck in &outcomes[0].checkpoints.checkpoints {
        residual_curve.push(ResidualPoint {
            epoch: ck.epoch,
            elapsed_s: ck.elapsed_s,
            residuals: evaluator.residuals(&ck.gen_params)?,
        });
    }
    let final_residuals = match residual_curve.last() {
        Some(p) => Some(p.residuals.clone()),
        None => Some(evaluator.residuals(&outcomes[0].state.gen)?),
    };

    // Merge the resume-time shrink/grow records with everything the
    // director observed during the run (scripted events + evictions).
    let mut membership = resume_records;
    if let Some(dir) = &director {
        membership.extend(dir.records(cfg.epochs as u64 - 1));
    }
    membership.sort_by_key(|r| (r.epoch, r.rank));

    Ok(RunResult {
        wall_s,
        metrics: MergedMetrics::new(outcomes.iter().map(|o| o.recorder.clone()).collect()),
        checkpoints: outcomes.iter().map(|o| o.checkpoints.clone()).collect(),
        comm: outcomes.iter().map(|o| o.comm_totals).collect(),
        health: outcomes.iter().map(|o| o.health).collect(),
        states: outcomes.into_iter().map(|o| o.state).collect(),
        residual_curve,
        final_residuals,
        resumed_from,
        membership,
        stopped_at,
    })
}

/// Run with the default (no latency injection) link model.
pub fn run_training(cfg: &RunConfig, handle: &RuntimeHandle) -> Result<RunResult> {
    run_training_with_links(cfg, handle, LinkModel::zero())
}

/// Self-contained entry point: build the backend the config asks for
/// (`backend: "native" | "pjrt"`), run the training, shut the runtime
/// down. On the native backend this needs no exported artifacts at all.
pub fn run_training_from_config(cfg: &RunConfig) -> Result<RunResult> {
    run_training_from_config_controlled(cfg, None)
}

/// [`run_training_from_config`] with an optional [`RunControl`] attached
/// (the service layer's self-contained runner: one runtime per job).
pub fn run_training_from_config_controlled(
    cfg: &RunConfig,
    control: Option<Arc<RunControl>>,
) -> Result<RunResult> {
    let rt = Runtime::from_config(cfg, cfg.runtime_workers)?;
    let result = run_training_controlled(cfg, &rt.handle(), LinkModel::zero(), control);
    rt.shutdown();
    result
}

/// Choose a pipeline artifact for data generation: prefer the big batch.
fn pick_pipeline_artifact(handle: &RuntimeHandle) -> Result<String> {
    for cand in ["pipeline_b256_e25", "pipeline_b1024_e100", "pipeline_b64_e25"] {
        if handle.manifest().artifact(cand).is_ok() {
            return Ok(cand.to_string());
        }
    }
    Err(Error::Manifest(
        "no pipeline artifact in manifest (need pipeline_b256_e25 or similar)".into(),
    ))
}

#[cfg(test)]
mod tests {
    // End-to-end launcher runs live in rust/tests/end2end.rs (they need
    // the artifact set and real multi-threaded training).
}
