//! Elastic membership: the epoch-boundary join/leave protocol.
//!
//! SAGIPS's rings assume a fixed rank set; this module relaxes that. The
//! membership of a run is a sequence of versioned
//! [`MembershipView`](crate::comm::MembershipView)s, advanced only at epoch
//! boundaries:
//!
//! * a **scripted schedule** (`--membership "leave:2@8,join:2@16"`) is a
//!   pure function of the epoch — replaying a run with the same schedule
//!   and seeds is bit-identical;
//! * **dynamic evictions** (`--evict-after n`) fire when a rank's own
//!   health accounting reaches `n` consecutive deadline misses (the
//!   `suspect` ladder of PR 7); they commit two epochs ahead of the
//!   highest epoch any rank has entered, so every rank observes the
//!   change at the same boundary.
//!
//! A leaving rank goes **dormant**: its thread idles through the remaining
//! epochs (no draws, no steps, no exchanges) so a later scripted `join` can
//! wake it — the in-process stand-in for a fresh worker process. A joining
//! rank restores state from the latest run checkpoint (its own slot if it
//! ever trained, else a donor snapshot from the lowest live rank), which is
//! the checkpoint hand-off of the elastic-ensembles roadmap item.
//!
//! Transitions are quiesced: the pipeline calls `Collective::drain()`
//! before applying a new view, so no in-flight exchange ever straddles two
//! rings (Async-RED's requirement that block updates stay well-defined
//! under asynchronous participation).

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::comm::MembershipView;
use crate::util::error::{Error, Result};

/// What happened to a rank at a membership boundary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MembershipChange {
    /// The rank entered (or re-entered) the run via checkpoint hand-off.
    Join,
    /// The rank left on schedule (a planned departure).
    Leave,
    /// The rank was evicted by health accounting.
    Evict,
}

impl MembershipChange {
    pub fn as_str(&self) -> &'static str {
        match self {
            MembershipChange::Join => "join",
            MembershipChange::Leave => "leave",
            MembershipChange::Evict => "evict",
        }
    }
}

/// One membership event, as recorded in the run result.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MembershipRecord {
    /// First epoch at which the new membership is in effect.
    pub epoch: u64,
    pub rank: usize,
    pub kind: MembershipChange,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct ScheduledEvent {
    epoch: u64,
    rank: usize,
    join: bool,
}

/// A scripted membership schedule: a pure function of the epoch.
///
/// Spec format: comma-separated events `leave:R@E` / `join:R@E`, e.g.
/// `"leave:2@8,join:2@16"` — rank 2 leaves at the start of epoch 8 and
/// rejoins at the start of epoch 16. A rank whose *first* event is a join
/// starts the run dormant.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct MembershipSchedule {
    /// Sorted by (epoch, rank); stable under replay.
    events: Vec<ScheduledEvent>,
}

impl MembershipSchedule {
    /// Parse a schedule spec. Empty spec = empty schedule.
    pub fn parse(spec: &str) -> Result<MembershipSchedule> {
        let mut events = Vec::new();
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (kind, rest) = part.split_once(':').ok_or_else(|| {
                Error::config(format!(
                    "membership event '{part}' is not 'leave:R@E' or 'join:R@E'"
                ))
            })?;
            let join = match kind {
                "join" => true,
                "leave" => false,
                other => {
                    return Err(Error::config(format!(
                        "membership event kind '{other}' (want 'leave' or 'join')"
                    )))
                }
            };
            let (rank, epoch) = rest.split_once('@').ok_or_else(|| {
                Error::config(format!("membership event '{part}' is missing '@epoch'"))
            })?;
            let rank: usize = rank
                .trim()
                .parse()
                .map_err(|_| Error::config(format!("bad rank in membership event '{part}'")))?;
            let epoch: u64 = epoch
                .trim()
                .parse()
                .map_err(|_| Error::config(format!("bad epoch in membership event '{part}'")))?;
            events.push(ScheduledEvent { epoch, rank, join });
        }
        events.sort_by_key(|e| (e.epoch, e.rank, e.join));
        Ok(MembershipSchedule { events })
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Number of scripted events.
    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// Ranks whose first scheduled event is a join: they start dormant.
    pub fn initially_dormant(&self) -> Vec<usize> {
        let mut first: BTreeMap<usize, bool> = BTreeMap::new();
        for e in &self.events {
            first.entry(e.rank).or_insert(e.join);
        }
        first
            .into_iter()
            .filter_map(|(rank, join)| join.then_some(rank))
            .collect()
    }

    /// Validate the schedule against a run shape. `ranks` is the launched
    /// slot count; joins need a checkpoint cadence to hand state off from.
    pub fn validate_for(
        &self,
        ranks: usize,
        min_ranks: usize,
        ckpt_every: usize,
        allow_join: bool,
    ) -> Result<()> {
        let mut live: Vec<bool> = vec![true; ranks];
        for r in self.initially_dormant() {
            if r < ranks {
                live[r] = false;
            }
        }
        let mut count = live.iter().filter(|&&l| l).count();
        if count < min_ranks {
            return Err(Error::config(format!(
                "membership schedule starts with {count} live ranks < min_ranks {min_ranks}"
            )));
        }
        for e in &self.events {
            if e.rank >= ranks {
                return Err(Error::config(format!(
                    "membership event rank {} out of range for {ranks} ranks",
                    e.rank
                )));
            }
            if e.rank == 0 && !e.join {
                return Err(Error::config(
                    "rank 0 cannot leave: it anchors the run checkpoint sidecar",
                ));
            }
            if e.join {
                if !allow_join {
                    return Err(Error::config(format!(
                        "membership event 'join:{}@{}' needs --allow-join",
                        e.rank, e.epoch
                    )));
                }
                if ckpt_every == 0 {
                    return Err(Error::config(
                        "membership joins need --ckpt-every > 0 (checkpoint hand-off)",
                    ));
                }
                if e.epoch < ckpt_every as u64 {
                    return Err(Error::config(format!(
                        "join:{}@{} precedes the first checkpoint boundary (ckpt_every {})",
                        e.rank, e.epoch, ckpt_every
                    )));
                }
            }
            if live[e.rank] == e.join {
                return Err(Error::config(format!(
                    "membership event '{}:{}@{}' repeats the rank's current state",
                    if e.join { "join" } else { "leave" },
                    e.rank,
                    e.epoch
                )));
            }
            live[e.rank] = e.join;
            count = if e.join { count + 1 } else { count - 1 };
            if count < min_ranks.max(1) {
                return Err(Error::config(format!(
                    "membership schedule drops to {count} live ranks at epoch {} \
                     (min_ranks {min_ranks})",
                    e.epoch
                )));
            }
        }
        Ok(())
    }

    /// The view in effect at the start of `epoch`: version = number of
    /// scheduled events with effect epoch <= `epoch`.
    pub fn view_at(&self, epoch: u64, total: usize) -> MembershipView {
        let mut live: Vec<bool> = vec![true; total];
        for r in self.initially_dormant() {
            if r < total {
                live[r] = false;
            }
        }
        let mut version = 0u64;
        for e in self.events.iter().filter(|e| e.epoch <= epoch) {
            live[e.rank] = e.join;
            version += 1;
        }
        let members: Vec<usize> = (0..total).filter(|&r| live[r]).collect();
        MembershipView::new(version, members, total)
    }

    /// All scheduled events with effect epoch <= `last_epoch`, as records.
    fn records_through(&self, last_epoch: u64) -> Vec<MembershipRecord> {
        self.events
            .iter()
            .filter(|e| e.epoch <= last_epoch)
            .map(|e| MembershipRecord {
                epoch: e.epoch,
                rank: e.rank,
                kind: if e.join {
                    MembershipChange::Join
                } else {
                    MembershipChange::Leave
                },
            })
            .collect()
    }
}

#[derive(Debug, Default)]
struct DirectorState {
    /// Committed dynamic evictions: (first effective epoch, rank).
    evicts: Vec<(u64, usize)>,
    /// Highest epoch any live rank has entered; eviction commit horizon.
    max_entered: u64,
    /// Ranks with an eviction already committed (dedup).
    requested: Vec<usize>,
}

/// Shared membership authority for one run.
///
/// All ranks consult the director at the top of every epoch. The scripted
/// schedule part of a view is a pure function of the epoch; dynamic
/// evictions are folded in once committed (always >= 2 epochs ahead of the
/// furthest rank, so every rank sees the transition at the same boundary).
pub struct MembershipDirector {
    schedule: MembershipSchedule,
    total: usize,
    min_ranks: usize,
    state: Mutex<DirectorState>,
}

impl MembershipDirector {
    pub fn new(
        schedule: MembershipSchedule,
        total: usize,
        min_ranks: usize,
    ) -> MembershipDirector {
        MembershipDirector {
            schedule,
            total,
            min_ranks: min_ranks.max(1),
            state: Mutex::new(DirectorState::default()),
        }
    }

    /// Note that a live rank is entering `epoch` (advances the eviction
    /// commit horizon).
    pub fn entering(&self, epoch: u64) {
        let mut st = self.state.lock().expect("membership director poisoned");
        st.max_entered = st.max_entered.max(epoch);
    }

    /// The membership in effect at the start of `epoch`.
    pub fn view_at(&self, epoch: u64) -> MembershipView {
        let st = self.state.lock().expect("membership director poisoned");
        let base = self.schedule.view_at(epoch, self.total);
        let committed: Vec<usize> = st
            .evicts
            .iter()
            .filter(|&&(at, _)| at <= epoch)
            .map(|&(_, r)| r)
            .collect();
        if committed.is_empty() {
            return base;
        }
        let live: Vec<usize> = base
            .live()
            .iter()
            .copied()
            .filter(|r| !committed.contains(r))
            .collect();
        MembershipView::new(base.version() + committed.len() as u64, live, self.total)
    }

    /// Request a dynamic eviction of `rank` (health-driven). Returns the
    /// first effective epoch if committed; `None` if the rank is already
    /// leaving or the floor (`min_ranks`) would be violated.
    pub fn request_leave(&self, rank: usize) -> Option<u64> {
        if rank == 0 {
            return None; // rank 0 anchors the checkpoint sidecar
        }
        let mut st = self.state.lock().expect("membership director poisoned");
        if st.requested.contains(&rank) {
            return None;
        }
        // Commit beyond every rank's current epoch so the transition lands
        // at one common boundary.
        let at = st.max_entered + 2;
        let base = self.schedule.view_at(at, self.total);
        let committed: Vec<usize> = st.evicts.iter().map(|&(_, r)| r).collect();
        let survivors = base
            .live()
            .iter()
            .filter(|&&r| r != rank && !committed.contains(&r))
            .count();
        if !base.is_live(rank) || survivors < self.min_ranks {
            return None;
        }
        st.requested.push(rank);
        st.evicts.push((at, rank));
        Some(at)
    }

    /// Every membership event with effect epoch <= `last_epoch`, scheduled
    /// and dynamic, ordered by epoch then rank.
    pub fn records(&self, last_epoch: u64) -> Vec<MembershipRecord> {
        let st = self.state.lock().expect("membership director poisoned");
        let mut out = self.schedule.records_through(last_epoch);
        out.extend(
            st.evicts
                .iter()
                .filter(|&&(at, _)| at <= last_epoch)
                .map(|&(at, rank)| MembershipRecord {
                    epoch: at,
                    rank,
                    kind: MembershipChange::Evict,
                }),
        );
        out.sort_by_key(|r| (r.epoch, r.rank));
        out
    }

    pub fn total(&self) -> usize {
        self.total
    }

    pub fn min_ranks(&self) -> usize {
        self.min_ranks
    }

    /// Whether anything can ever change membership (scripted or dynamic
    /// evictions armed elsewhere) — the launcher arms the director only
    /// when so, but tests may query it.
    pub fn is_scripted(&self) -> bool {
        !self.schedule.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip_and_ordering() {
        let s = MembershipSchedule::parse("join:2@16, leave:2@8").unwrap();
        let v0 = s.view_at(0, 4);
        assert_eq!(v0.live(), &[0, 1, 2, 3]);
        assert_eq!(v0.version(), 0);
        let v8 = s.view_at(8, 4);
        assert_eq!(v8.live(), &[0, 1, 3]);
        assert_eq!(v8.version(), 1);
        let v16 = s.view_at(16, 4);
        assert_eq!(v16.live(), &[0, 1, 2, 3]);
        assert_eq!(v16.version(), 2);
    }

    #[test]
    fn view_is_pure_function_of_epoch() {
        let s = MembershipSchedule::parse("leave:1@3,join:1@9,leave:3@5").unwrap();
        for e in 0..12 {
            assert_eq!(s.view_at(e, 4), s.view_at(e, 4));
        }
        // Monotone version.
        let mut last = 0;
        for e in 0..12 {
            let v = s.view_at(e, 4).version();
            assert!(v >= last);
            last = v;
        }
        assert_eq!(last, 3);
    }

    #[test]
    fn first_event_join_starts_dormant() {
        let s = MembershipSchedule::parse("join:3@10").unwrap();
        assert_eq!(s.initially_dormant(), vec![3]);
        assert_eq!(s.view_at(0, 4).live(), &[0, 1, 2]);
        assert_eq!(s.view_at(10, 4).live(), &[0, 1, 2, 3]);
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        assert!(MembershipSchedule::parse("leave:2").is_err());
        assert!(MembershipSchedule::parse("evict:2@4").is_err());
        assert!(MembershipSchedule::parse("leave:x@4").is_err());
        assert!(MembershipSchedule::parse("leave:2@x").is_err());
        assert!(MembershipSchedule::parse("").unwrap().is_empty());
    }

    #[test]
    fn validate_guards_rank0_floor_and_joins() {
        let leave0 = MembershipSchedule::parse("leave:0@4").unwrap();
        assert!(leave0.validate_for(4, 1, 0, false).is_err());

        let floor = MembershipSchedule::parse("leave:1@2,leave:2@3,leave:3@4").unwrap();
        assert!(floor.validate_for(4, 2, 0, false).is_err());
        assert!(floor.validate_for(4, 1, 0, false).is_ok());

        let join = MembershipSchedule::parse("leave:1@3,join:1@8").unwrap();
        assert!(join.validate_for(4, 1, 6, true).is_ok());
        assert!(join.validate_for(4, 1, 6, false).is_err()); // needs allow_join
        assert!(join.validate_for(4, 1, 0, true).is_err()); // needs ckpt cadence
        let early = MembershipSchedule::parse("leave:1@1,join:1@3").unwrap();
        assert!(early.validate_for(4, 1, 6, true).is_err()); // join before 1st ckpt

        let out_of_range = MembershipSchedule::parse("leave:9@3").unwrap();
        assert!(out_of_range.validate_for(4, 1, 0, false).is_err());

        let repeat = MembershipSchedule::parse("leave:1@3,leave:1@5").unwrap();
        assert!(repeat.validate_for(4, 1, 0, false).is_err());
    }

    #[test]
    fn director_commits_evictions_at_a_common_future_boundary() {
        let d = MembershipDirector::new(MembershipSchedule::default(), 4, 2);
        d.entering(5);
        let at = d.request_leave(2).expect("eviction should commit");
        assert_eq!(at, 7);
        assert!(d.view_at(6).is_live(2));
        let v7 = d.view_at(7);
        assert!(!v7.is_live(2));
        assert_eq!(v7.version(), 1);
        // Dedup: a second request for the same rank is a no-op.
        assert_eq!(d.request_leave(2), None);
        // Floor: evicting one more would leave 2 live, evicting two would
        // drop below min_ranks=2.
        assert!(d.request_leave(3).is_some());
        assert_eq!(d.request_leave(1), None);
        // Rank 0 can never be evicted.
        assert_eq!(d.request_leave(0), None);
        let recs = d.records(20);
        assert_eq!(recs.len(), 2);
        assert!(recs
            .iter()
            .all(|r| r.kind == MembershipChange::Evict && r.epoch == 7));
    }

    #[test]
    fn director_folds_schedule_and_evictions() {
        let s = MembershipSchedule::parse("leave:1@4").unwrap();
        let d = MembershipDirector::new(s, 4, 1);
        d.entering(2);
        assert_eq!(d.request_leave(3), Some(4));
        let v = d.view_at(4);
        assert_eq!(v.live(), &[0, 2]);
        assert_eq!(v.version(), 2);
        let recs = d.records(4);
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].kind, MembershipChange::Leave);
        assert_eq!(recs[1].kind, MembershipChange::Evict);
    }
}
