//! Cooperative run control: cancellation and live progress for a
//! training run driven from outside the rank threads (the service
//! layer's job scheduler, or any embedder of the launcher).
//!
//! # Why cancellation is a consensus problem
//!
//! A naive "stop when you see the flag" protocol deadlocks a multi-rank
//! run: under a k-deep staleness window ranks drift up to k epochs
//! apart, so different ranks would observe the flag at different epochs
//! — and a rank that stops early starves every ring its peers still
//! expect it in. Worse, a checkpoint assembled from ranks stopped at
//! different epochs would be torn and unusable for `--resume`.
//!
//! [`RunControl`] therefore makes cancellation take effect only at
//! **run-checkpoint cadence boundaries** — exactly the epochs where the
//! pipeline already drains its exchange window to quiescence and
//! deposits a full [`RankTrainState`](crate::model::checkpoint::RankTrainState)
//! into the shared [`RunCheckpointer`](super::resume::RunCheckpointer):
//!
//! 1. An external thread calls [`RunControl::request_cancel`] at any
//!    time. Nothing happens immediately.
//! 2. Each rank consults [`RunControl::should_stop_at`] right after its
//!    deposit at a boundary epoch `e`. The first rank to do so with the
//!    request visible CAS-decides the **stop boundary**: the smallest
//!    cadence boundary `>= e + window + 2`. The margin covers the
//!    maximum inter-rank drift (a rank can run at most `window + 1`
//!    epochs ahead of a ring peer; +1 slack), so no rank can already be
//!    past the decided boundary — every rank still has it ahead.
//! 3. Every rank stops after depositing at the decided boundary. All
//!    ranks therefore stop at the *same* epoch, the boundary's deposit
//!    set completes, and the final on-disk checkpoint is full-width and
//!    `--resume`-able — resuming the cancelled config is bit-identical
//!    to an uninterrupted run at the same cadence.
//!
//! If the decided boundary lands at or past the configured epoch count
//! the run simply completes; the job ends `done`, not `cancelled`. A
//! run without a checkpoint cadence (`ckpt_every == 0`) has no
//! boundaries and ignores cancellation — the service layer guarantees a
//! cadence for every job it admits.
//!
//! The control also carries a cheap progress view (epochs completed,
//! rank 0's latest losses) published by the pipeline every epoch and
//! read by the daemon's `status` verb. Publishing is pure observation:
//! a controlled run is bit-identical to an uncontrolled one until the
//! moment it stops.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

/// `cancel_at` value meaning "no stop boundary decided yet".
const UNDECIDED: u64 = u64::MAX;

/// Latest per-epoch progress sample published by rank 0.
#[derive(Clone, Copy, Debug)]
pub struct ProgressSnapshot {
    /// Epochs completed by the furthest rank (0 before the first epoch).
    pub epochs_done: u64,
    /// Rank 0's latest generator loss (`NaN` until the first epoch).
    pub gen_loss: f64,
    /// Rank 0's latest discriminator loss (`NaN` until the first epoch).
    pub disc_loss: f64,
}

impl Default for ProgressSnapshot {
    /// The pre-first-epoch view: nothing done, losses not yet observed.
    fn default() -> Self {
        ProgressSnapshot {
            epochs_done: 0,
            gen_loss: f64::NAN,
            disc_loss: f64::NAN,
        }
    }
}

/// Shared (Arc'd) control block linking a running training to the
/// outside world: cooperative cancellation plus a live progress view.
pub struct RunControl {
    cancel_requested: AtomicBool,
    /// The consensus stop boundary ([`UNDECIDED`] until a rank decides).
    cancel_at: AtomicU64,
    /// Exchange-window depth the run actually uses (armed by the
    /// launcher; part of the drift margin in the stop-boundary rule).
    window: AtomicU64,
    /// Max epoch any rank has entered + 1 (epochs completed, roughly).
    frontier: AtomicU64,
    /// The boundary the run actually stopped at (UNDECIDED = ran to
    /// completion or still running).
    stopped_at: AtomicU64,
    latest: Mutex<(f64, f64)>,
}

impl Default for RunControl {
    fn default() -> Self {
        Self::new()
    }
}

impl RunControl {
    pub fn new() -> RunControl {
        RunControl {
            cancel_requested: AtomicBool::new(false),
            cancel_at: AtomicU64::new(UNDECIDED),
            window: AtomicU64::new(0),
            frontier: AtomicU64::new(0),
            stopped_at: AtomicU64::new(UNDECIDED),
            latest: Mutex::new((f64::NAN, f64::NAN)),
        }
    }

    /// Arm the control with the run's exchange-window depth (the
    /// launcher calls this with the effective per-rank staleness before
    /// spawning rank threads).
    pub fn arm(&self, window: usize) {
        self.window.store(window as u64, Ordering::Release);
    }

    /// Ask the run to stop at the next safe boundary. Idempotent;
    /// callable from any thread at any time.
    pub fn request_cancel(&self) {
        self.cancel_requested.store(true, Ordering::Release);
    }

    /// Whether a cancellation has been requested (it may still be
    /// undecided, or may never take effect if the run finishes first).
    pub fn cancel_requested(&self) -> bool {
        self.cancel_requested.load(Ordering::Acquire)
    }

    /// Consulted by each rank immediately after its checkpoint deposit
    /// at cadence boundary `epoch` (an epoch where `(epoch + 1) %
    /// cadence == 0`). Returns `true` when this boundary is the decided
    /// stop boundary — the rank must stop its epoch loop *after* the
    /// deposit it just made.
    ///
    /// The first caller that observes the request proposes the stop
    /// boundary via CAS; every caller then reads the winning value, so
    /// all ranks agree on one boundary. See the module docs for why the
    /// `window + 2` drift margin makes the decided boundary reachable by
    /// every rank.
    pub fn should_stop_at(&self, epoch: u64, cadence: u64) -> bool {
        debug_assert!(cadence >= 1);
        if !self.cancel_requested() {
            return false;
        }
        let margin = self.window.load(Ordering::Acquire) + 2;
        // Smallest b with (b + 1) % cadence == 0 and b >= epoch + margin.
        let target = (epoch + margin + 1).div_ceil(cadence) * cadence - 1;
        let _ = self.cancel_at.compare_exchange(
            UNDECIDED,
            target,
            Ordering::AcqRel,
            Ordering::Acquire,
        );
        epoch >= self.cancel_at.load(Ordering::Acquire)
    }

    /// Record that the caller's rank stopped at `epoch` (the decided
    /// boundary). All ranks store the same value by construction.
    pub fn mark_stopped(&self, epoch: u64) {
        self.stopped_at.store(epoch, Ordering::Release);
    }

    /// The boundary the run stopped at, if it was cancelled.
    pub fn stopped_at(&self) -> Option<u64> {
        match self.stopped_at.load(Ordering::Acquire) {
            UNDECIDED => None,
            e => Some(e),
        }
    }

    /// Per-epoch progress tick from any rank entering `epoch`.
    pub fn note_epoch(&self, epoch: u64) {
        self.frontier.fetch_max(epoch + 1, Ordering::AcqRel);
    }

    /// Rank 0 publishes its latest losses (pure observation — never
    /// feeds back into training).
    pub fn publish_losses(&self, gen_loss: f64, disc_loss: f64) {
        if let Ok(mut l) = self.latest.lock() {
            *l = (gen_loss, disc_loss);
        }
    }

    /// Latest progress view for status reporting.
    pub fn progress(&self) -> ProgressSnapshot {
        let (gen_loss, disc_loss) = self
            .latest
            .lock()
            .map(|l| *l)
            .unwrap_or((f64::NAN, f64::NAN));
        ProgressSnapshot {
            epochs_done: self.frontier.load(Ordering::Acquire),
            gen_loss,
            disc_loss,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_request_means_no_stop() {
        let c = RunControl::new();
        for b in [4u64, 9, 14] {
            assert!(!c.should_stop_at(b, 5));
        }
        assert!(c.stopped_at().is_none());
        assert!(!c.cancel_requested());
    }

    #[test]
    fn stop_boundary_is_decided_once_with_drift_margin() {
        // Blocking run (window 0), cadence 5: a rank at boundary 4
        // proposes the smallest boundary >= 4 + 2 = 6, i.e. epoch 9.
        let c = RunControl::new();
        c.arm(0);
        c.request_cancel();
        assert!(!c.should_stop_at(4, 5), "margin must defer the stop");
        assert!(c.should_stop_at(9, 5));
        // The decision is sticky: later, larger boundaries still stop.
        assert!(c.should_stop_at(14, 5));
    }

    #[test]
    fn window_widens_the_margin() {
        // window 4, cadence 5: proposer at boundary 4 needs a boundary
        // >= 4 + 4 + 2 = 10 — epoch 14, not 9.
        let c = RunControl::new();
        c.arm(4);
        c.request_cancel();
        assert!(!c.should_stop_at(4, 5));
        assert!(!c.should_stop_at(9, 5));
        assert!(c.should_stop_at(14, 5));
    }

    #[test]
    fn all_ranks_agree_on_the_first_proposal() {
        // A laggard at boundary 9 and a leader at boundary 19 (cadence
        // 10, window 2): whoever proposes first fixes the boundary and
        // the other reads it. Proposal from 9 -> smallest boundary
        // >= 13 is 19; the leader's check at 19 stops there too.
        let c = RunControl::new();
        c.arm(2);
        c.request_cancel();
        assert!(!c.should_stop_at(9, 10)); // laggard proposes 19
        assert!(c.should_stop_at(19, 10)); // leader agrees
        assert!(c.should_stop_at(19, 10)); // laggard reaches 19 too
    }

    #[test]
    fn progress_publishes_and_reads_back() {
        let c = RunControl::new();
        assert_eq!(c.progress().epochs_done, 0);
        assert!(c.progress().gen_loss.is_nan());
        c.note_epoch(0);
        c.note_epoch(3);
        c.note_epoch(1); // out-of-order ticks never move it backwards
        c.publish_losses(0.5, 0.25);
        let p = c.progress();
        assert_eq!(p.epochs_done, 4);
        assert_eq!(p.gen_loss, 0.5);
        assert_eq!(p.disc_loss, 0.25);
    }

    #[test]
    fn stopped_at_roundtrips() {
        let c = RunControl::new();
        assert!(c.stopped_at().is_none());
        c.mark_stopped(19);
        assert_eq!(c.stopped_at(), Some(19));
    }
}
