//! The per-rank training loop: a thin driver over the staged
//! [`RankPipeline`].
//!
//! One thread per rank (= one GPU in the paper). Each epoch runs the
//! pipeline stages
//!
//! ```text
//! bootstrap-draw → gan_step → offload → exchange → apply → update
//! ```
//!
//! 1. bootstrap-draw a discriminator batch from the rank's data shard;
//! 2. execute the `gan_step` artifact (generator forward -> the
//!    scenario's forward operator -> discriminator; returns both
//!    networks' gradients and losses) and update the *local*
//!    discriminator immediately (the paper trains one discriminator per
//!    rank, autonomously);
//! 3. off-load the generator's weight gradients into the packed transfer
//!    buffer, exchange them through the rank's collective (ARAR / grouped
//!    / RMA / horovod / none), on-load the averaged result and update the
//!    generator — blocking within the epoch (`staleness: 0`, the paper's
//!    semantics) or through a bounded window of up to `staleness` k
//!    in-flight exchanges applied in FIFO order (Async-RED-style bounded
//!    block asynchrony);
//! 4. at the analysis-checkpoint cadence, snapshot the generator with a
//!    timestamp (the paper's post-training convergence methodology).
//!
//! Fault tolerance: at the run-checkpoint cadence (`RunConfig::
//! ckpt_every`) the pipeline first **drains** its exchange window to
//! quiescence, then each rank deposits its complete training state —
//! parameters, Adam moments, RNG stream — into the shared
//! [`RunCheckpointer`]; a resumed rank receives a [`RankResume`] instead
//! of initializing fresh and continues its epoch loop (and every RNG
//! draw) exactly where the checkpoint left off, for *any* staleness.

use std::sync::Arc;

use crate::collective::{Collective, CommStats};
use crate::config::RunConfig;
use crate::data::Bootstrap;
use crate::metrics::Recorder;
use crate::model::checkpoint::CheckpointSeries;
use crate::model::gan::GanState;
use crate::runtime::RuntimeHandle;
use crate::util::error::Result;
use crate::util::rng::Rng;

use super::control::RunControl;
use super::membership::MembershipDirector;
use super::pipeline::{RankHealth, RankPipeline};
use super::resume::{RankResume, RunCheckpointer};

/// Everything a rank thread produces.
pub struct RankOutcome {
    pub rank: usize,
    pub recorder: Recorder,
    pub checkpoints: CheckpointSeries,
    pub state: GanState,
    pub comm_totals: CommStats,
    /// Exchange health accounting (deadline misses, settle latency).
    pub health: RankHealth,
    /// The checkpoint boundary this rank stopped at, if the run was
    /// cancelled via [`RunControl`] (same boundary on every rank).
    pub stopped_at: Option<u64>,
}

/// Run one rank's full training loop. `shard` is this rank's data
/// sub-sample; `collective` its gradient exchanger; `rng` its private
/// stream. `checkpointer` (when run checkpointing is on) receives this
/// rank's state at the cadence; `resume` (when restoring) replaces the
/// fresh initialization with a checkpointed state; `membership` (when
/// elastic membership is armed) is the shared director the pipeline
/// consults at every epoch boundary; `control` (when the run is driven
/// by the service layer) carries cooperative cancellation and the live
/// progress view.
#[allow(clippy::too_many_arguments)]
pub fn run_rank(
    rank: usize,
    cfg: &RunConfig,
    handle: RuntimeHandle,
    collective: Box<dyn Collective>,
    shard: Bootstrap,
    rng: Rng,
    take_checkpoints: bool,
    checkpointer: Option<Arc<RunCheckpointer>>,
    resume: Option<RankResume>,
    membership: Option<Arc<MembershipDirector>>,
    control: Option<Arc<RunControl>>,
) -> Result<RankOutcome> {
    crate::util::logging::rank_scope(rank);
    let mut pipeline = RankPipeline::new(
        rank, cfg, handle, collective, shard, rng, resume, membership, control,
    )?;
    pipeline.run(cfg, take_checkpoints, checkpointer.as_ref())?;
    Ok(pipeline.into_outcome())
}

#[cfg(test)]
mod tests {
    // run_rank requires artifacts + a full network; exercised by the
    // launcher tests and the integration suites (rust/tests/end2end.rs,
    // rust/tests/pipeline.rs, rust/tests/resume.rs). The stage machine
    // itself lives in coordinator::pipeline.
}
