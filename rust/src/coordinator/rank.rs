//! The per-rank training loop.
//!
//! One thread per rank (= one GPU in the paper). Each epoch:
//!
//! 1. bootstrap-draw a discriminator batch from the rank's data shard;
//! 2. execute the `gan_step` artifact (generator forward -> the
//!    scenario's forward operator -> discriminator; returns both
//!    networks' gradients and losses);
//! 3. update the *local* discriminator immediately (the paper trains one
//!    discriminator per rank, autonomously);
//! 4. off-load the generator's weight gradients into the packed transfer
//!    buffer, exchange them through the rank's collective (ARAR / grouped
//!    / RMA / horovod / none), on-load the averaged result;
//! 5. update the generator;
//! 6. at the checkpoint cadence, snapshot the generator with a timestamp
//!    (the paper's post-training convergence methodology).
//!
//! With `RunConfig::overlap_comm` the loop pipelines step 4 through the
//! collective engine's non-blocking API: epoch e's exchange is *started*
//! after its gan_step and *collected* at epoch e+1, overlapping the ring
//! with the next bootstrap draw and gan_step. The generator then updates
//! with one-epoch-stale averaged gradients (Async-RED-style block
//! asynchrony); the paper's blocking semantics remain the default.
//!
//! Fault tolerance: at the run-checkpoint cadence (`RunConfig::
//! ckpt_every`) each rank deposits its complete training state —
//! parameters, Adam moments, RNG stream — into the shared
//! [`RunCheckpointer`]; a resumed rank receives a [`RankResume`] instead
//! of initializing fresh and continues its epoch loop (and every RNG
//! draw) exactly where the checkpoint left off.

use std::sync::Arc;

use crate::collective::{Collective, CommStats};
use crate::config::RunConfig;
use crate::data::Bootstrap;
use crate::metrics::{Recorder, Timer};
use crate::model::checkpoint::{CheckpointSeries, RankTrainState};
use crate::model::gan::GanState;
use crate::model::{StepOutput, TrainStep};
use crate::optim::{Adam, Optimizer};
use crate::runtime::RuntimeHandle;
use crate::tensor::fusion::FusionPlan;
use crate::tensor::ops;
use crate::util::error::{Error, Result};
use crate::util::rng::Rng;

use super::offload::GradOffloader;
use super::resume::{RankResume, RunCheckpointer};

/// Everything a rank thread produces.
pub struct RankOutcome {
    pub rank: usize,
    pub recorder: Recorder,
    pub checkpoints: CheckpointSeries,
    pub state: GanState,
    pub comm_totals: CommStats,
}

/// An exchange started at `epoch` whose averaged result has not been
/// applied yet (overlap mode). `grads` holds that epoch's full gradient
/// vector: the averaged weights are on-loaded into it, biases keep their
/// local values from the same epoch.
struct InFlight {
    epoch: u64,
    grads: Vec<f32>,
}

/// Run one rank's full training loop. `shard` is this rank's data
/// sub-sample; `collective` its gradient exchanger; `rng` its private
/// stream. `checkpointer` (when run checkpointing is on) receives this
/// rank's state at the cadence; `resume` (when restoring) replaces the
/// fresh initialization with a checkpointed state.
#[allow(clippy::too_many_arguments)]
pub fn run_rank(
    rank: usize,
    cfg: &RunConfig,
    handle: RuntimeHandle,
    mut collective: Box<dyn Collective>,
    shard: Bootstrap,
    mut rng: Rng,
    take_checkpoints: bool,
    checkpointer: Option<Arc<RunCheckpointer>>,
    resume: Option<RankResume>,
) -> Result<RankOutcome> {
    crate::util::logging::rank_scope(rank);
    let manifest = handle.manifest();
    let meta = manifest.model(&cfg.model)?.clone();
    let slope = manifest.leaky_slope;
    // Checkpoints carry the scenario identity so a restore under the
    // wrong forward operator is refused instead of silently diverging.
    let scenario = manifest.scenario.clone();

    // Model + optimizers (paper: Adam, G lr 1e-5 / D lr 1e-4) — either
    // fresh, or restored from a run checkpoint. The restore replaces the
    // RNG stream too: the launcher re-derives the shard with the original
    // seed-split stream *before* this point, so every draw after the
    // checkpoint boundary continues the original run's sequence exactly.
    let mut state;
    let start_epoch: u64;
    let elapsed_offset: f64;
    let mut gen_opt;
    let mut disc_opt;
    match resume {
        Some(r) => {
            debug_assert_eq!(r.state.rank, rank);
            state = GanState {
                gen: r.state.gen,
                disc: r.state.disc,
            };
            gen_opt = Adam::new(cfg.gen_lr, state.gen.len());
            gen_opt.restore(&r.state.gen_m, &r.state.gen_v, r.state.gen_t);
            disc_opt = Adam::new(cfg.disc_lr, state.disc.len());
            disc_opt.restore(&r.state.disc_m, &r.state.disc_v, r.state.disc_t);
            rng = Rng::from_snapshot(&r.state.rng);
            start_epoch = r.start_epoch;
            elapsed_offset = r.elapsed_offset;
        }
        None => {
            state = GanState::init(&meta, slope, &mut rng);
            gen_opt = Adam::new(cfg.gen_lr, state.gen.len());
            disc_opt = Adam::new(cfg.disc_lr, state.disc.len());
            start_epoch = 0;
            elapsed_offset = 0.0;
        }
    }

    // Weight-only fusion plan over the generator layout (Sec. V-C).
    let plan = FusionPlan::build(meta.gen_segments(), cfg.fusion_bucket, cfg.include_bias);
    let mut offloader = GradOffloader::new(plan);

    let mut step = TrainStep::new(handle, &cfg.gan_step_artifact())?;
    let disc_batch = step.disc_batch();

    let mut shard = shard;
    let mut real = Vec::with_capacity(step.real_len());
    let mut recorder = Recorder::new(rank);
    let mut checkpoints = CheckpointSeries::default();
    let mut comm_totals = CommStats::default();
    let mut in_flight: Option<InFlight> = None;
    // One reusable step output: its gradient buffers rotate with the step
    // executor's (and, in overlap mode, with the in-flight slot), so the
    // epoch loop performs no gradient allocation after warm-up.
    let mut out = StepOutput::default();
    let timer = Timer::start();

    for epoch in start_epoch..cfg.epochs as u64 {
        let mut lap = Timer::start();
        // 1. bootstrap draw
        shard.draw(disc_batch, &mut rng, &mut real);
        let t_draw = lap.lap_s();

        // 2. gan_step (borrowed inputs, reused output buffers)
        step.run_into(&state.gen, &state.disc, &real, &mut rng, &mut out)?;
        let t_step = lap.lap_s();
        if !ops::all_finite(&out.gen_grads) || !ops::all_finite(&out.disc_grads) {
            return Err(Error::Runtime(format!(
                "rank {rank}: non-finite gradients at epoch {epoch}"
            )));
        }

        // 3. local discriminator update (per-rank discriminator).
        disc_opt.step(&mut state.disc, &out.disc_grads);

        let (t_comm, t_opt, stats) = if cfg.overlap_comm {
            // 4/5 (overlap). Collect the *previous* epoch's exchange —
            // which ran under this epoch's draw + gan_step — apply it,
            // then launch this epoch's exchange and move on. Only the
            // time blocked here counts as hot-path comm.
            let mut stats = CommStats::default();
            let mut t_opt = 0.0;
            let mut t_comm = 0.0;
            // The gradient buffer freed by the collected exchange; rotated
            // back into `out` when this epoch's grads move in flight.
            let mut recycled = Vec::new();
            if let Some(InFlight {
                epoch: pe,
                grads: mut pgrads,
            }) = in_flight.take()
            {
                let (reduced, s) = collective.wait_reduce()?;
                offloader.onload_from(&reduced, &mut pgrads)?;
                offloader.recycle(reduced);
                // Only the time blocked here is hot-path comm; the
                // worker's own blocked time ran concurrently with this
                // epoch's compute and is accounted as hidden.
                t_comm += lap.lap_s();
                gen_opt.step(&mut state.gen, &pgrads);
                t_opt = lap.lap_s();
                recorder.push("comm_hidden_s", pe, s.wait_s);
                stats.merge(&s);
                recycled = pgrads;
            }
            let buf = offloader.pack_owned(&out.gen_grads)?;
            collective.start_reduce(epoch, buf)?;
            in_flight = Some(InFlight {
                epoch,
                grads: std::mem::replace(&mut out.gen_grads, recycled),
            });
            t_comm += lap.lap_s();
            (t_comm, t_opt, stats)
        } else {
            // 4. off-load -> collective -> on-load (paper: blocking).
            let buf = offloader.offload(&out.gen_grads)?;
            let stats = collective.epoch_reduce(epoch, buf)?;
            offloader.onload(&mut out.gen_grads)?;
            let t_comm = lap.lap_s();

            // 5. generator update with the exchanged gradients.
            gen_opt.step(&mut state.gen, &out.gen_grads);
            (t_comm, lap.lap_s(), stats)
        };
        comm_totals.merge(&stats);

        // 6. metrics + checkpoints.
        recorder.push("gen_loss", epoch, out.gen_loss);
        recorder.push("disc_loss", epoch, out.disc_loss);
        recorder.push("draw_s", epoch, t_draw);
        recorder.push("step_s", epoch, t_step);
        recorder.push("comm_s", epoch, t_comm);
        recorder.push("comm_wait_s", epoch, stats.wait_s);
        recorder.push("optim_s", epoch, t_opt);
        recorder.push("events", epoch, disc_batch as f64);
        if take_checkpoints
            && (epoch == 0
                || cfg.checkpoint_every > 0 && (epoch + 1) % cfg.checkpoint_every as u64 == 0)
        {
            checkpoints.record(
                rank,
                epoch,
                elapsed_offset + timer.elapsed_s(),
                &scenario,
                &state.gen,
            );
        }

        // Run-checkpoint deposit: the full state *after* this epoch's
        // updates, with the RNG captured exactly where epoch + 1's first
        // draw will continue it.
        if let Some(ck) = &checkpointer {
            if ck.wants(epoch) {
                let (gm, gv, gt) = gen_opt.state();
                let (dm, dv, dt) = disc_opt.state();
                ck.deposit(
                    epoch,
                    elapsed_offset + timer.elapsed_s(),
                    RankTrainState {
                        rank,
                        gen: state.gen.clone(),
                        disc: state.disc.clone(),
                        gen_m: gm.to_vec(),
                        gen_v: gv.to_vec(),
                        gen_t: gt,
                        disc_m: dm.to_vec(),
                        disc_v: dv.to_vec(),
                        disc_t: dt,
                        rng: rng.snapshot(),
                    },
                )?;
            }
        }
    }

    // Drain the pipeline: the last epoch's exchange still needs applying.
    if let Some(InFlight {
        epoch: pe,
        grads: mut pgrads,
    }) = in_flight.take()
    {
        let mut lap = Timer::start();
        let (reduced, s) = collective.wait_reduce()?;
        offloader.onload_from(&reduced, &mut pgrads)?;
        let t_comm = lap.lap_s();
        gen_opt.step(&mut state.gen, &pgrads);
        recorder.push("comm_s", pe, t_comm);
        recorder.push("optim_s", pe, lap.lap_s());
        recorder.push("comm_hidden_s", pe, s.wait_s);
        comm_totals.merge(&s);
    }

    Ok(RankOutcome {
        rank,
        recorder,
        checkpoints,
        state,
        comm_totals,
    })
}

#[cfg(test)]
mod tests {
    // run_rank requires artifacts + a full network; exercised by the
    // launcher tests and the integration suite (rust/tests/). The overlap
    // pipeline's collective-facing half is covered by
    // collective::engine::tests.
}
