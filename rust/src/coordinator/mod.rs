//! The SAGIPS coordinator: the paper's distributed-training contribution.
//!
//! * [`offload`] — gradient off-/on-loading around the collective
//!   (Sec. IV-B6), fused through the weight-only `FusionPlan`.
//! * [`pipeline`] — the staged per-rank training pipeline
//!   (bootstrap-draw → gan_step → offload → exchange → apply → update)
//!   with a bounded-staleness exchange window and drainable quiescence.
//! * [`rank`] — the per-rank thread entry point driving the pipeline.
//! * [`launcher`] — builds the topology/transports/windows, spawns one
//!   thread per rank, joins them, and runs the post-training residual
//!   analysis over the recorded checkpoints (the paper's Sec. VI-C2
//!   methodology).
//! * [`resume`] — fault tolerance: the periodic [`resume::RunCheckpointer`]
//!   that assembles per-rank training state into atomic on-disk run
//!   checkpoints, and the restore path that validates and redistributes a
//!   checkpoint so an interrupted run continues bit-identically.
//! * [`membership`] — elastic membership: the epoch-boundary protocol that
//!   lets ranks leave (on request or eviction) and join (via checkpoint
//!   hand-off) mid-run, emitting versioned [`crate::comm::MembershipView`]s
//!   that the collectives re-ring from.
//! * [`control`] — cooperative run control: cancel a live run at a safe
//!   checkpoint-cadence boundary (all ranks stop at the same epoch, the
//!   final deposit is `--resume`-able) and observe per-epoch progress;
//!   the service layer's handle into a training run.

pub mod control;
pub mod launcher;
pub mod membership;
pub mod offload;
pub mod pipeline;
pub mod rank;
pub mod resume;

pub use control::{ProgressSnapshot, RunControl};
pub use launcher::{run_training, RunResult};
pub use membership::{MembershipChange, MembershipDirector, MembershipRecord, MembershipSchedule};
pub use offload::GradOffloader;
pub use pipeline::RankPipeline;
pub use rank::RankOutcome;
pub use resume::{RankResume, RunCheckpointer};
