//! The SAGIPS coordinator: the paper's distributed-training contribution.
//!
//! * [`offload`] — gradient off-/on-loading around the collective
//!   (Sec. IV-B6), fused through the weight-only `FusionPlan`.
//! * [`rank`] — the per-rank training loop: bootstrap draw -> `gan_step`
//!   artifact -> local discriminator update -> gradient off-load ->
//!   collective exchange -> on-load -> generator update -> checkpoints.
//! * [`launcher`] — builds the topology/transports/windows, spawns one
//!   thread per rank, joins them, and runs the post-training residual
//!   analysis over the recorded checkpoints (the paper's Sec. VI-C2
//!   methodology).

pub mod launcher;
pub mod offload;
pub mod rank;

pub use launcher::{run_training, RunResult};
pub use offload::GradOffloader;
pub use rank::RankOutcome;
