//! Plain SGD with optional momentum (ablation baseline for the optimizer
//! choice).

use super::Optimizer;
use crate::tensor::ops;

/// SGD over flat parameters.
pub struct Sgd {
    lr: f32,
    momentum: f32,
    velocity: Option<Vec<f32>>,
    t: u64,
}

impl Sgd {
    pub fn new(lr: f32) -> Sgd {
        Sgd {
            lr,
            momentum: 0.0,
            velocity: None,
            t: 0,
        }
    }

    pub fn with_momentum(mut self, momentum: f32) -> Sgd {
        self.momentum = momentum;
        self
    }
}

impl Optimizer for Sgd {
    fn step(&mut self, params: &mut [f32], grads: &[f32]) {
        assert_eq!(params.len(), grads.len(), "Sgd dim mismatch");
        self.t += 1;
        if self.momentum == 0.0 {
            ops::axpy(params, -self.lr, grads);
            return;
        }
        let v = self
            .velocity
            .get_or_insert_with(|| vec![0.0; params.len()]);
        for i in 0..params.len() {
            v[i] = self.momentum * v[i] + grads[i];
            params[i] -= self.lr * v[i];
        }
    }

    fn steps(&self) -> u64 {
        self.t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::Optimizer;

    #[test]
    fn vanilla_step_is_axpy() {
        let mut s = Sgd::new(0.5);
        let mut p = vec![1.0f32, 2.0];
        s.step(&mut p, &[2.0, -2.0]);
        assert_eq!(p, vec![0.0, 3.0]);
    }

    #[test]
    fn momentum_accumulates() {
        let mut s = Sgd::new(1.0).with_momentum(0.5);
        let mut p = vec![0.0f32];
        s.step(&mut p, &[1.0]); // v=1, p=-1
        s.step(&mut p, &[1.0]); // v=1.5, p=-2.5
        assert!((p[0] + 2.5).abs() < 1e-6);
        assert_eq!(s.steps(), 2);
    }
}
