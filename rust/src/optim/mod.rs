//! Optimizers operating on flat parameter vectors.
//!
//! The paper trains both networks with per-network learning rates (G 1e-5,
//! D 1e-4, found by manual tuning). Updates run on the coordinator after
//! gradient on-loading — the optimizer state (Adam moments) never crosses
//! ranks, only gradients do, exactly as in the paper.

pub mod adam;
pub mod lr_schedule;
pub mod sgd;

pub use adam::Adam;
pub use lr_schedule::{LrSchedule, RankScaling};
pub use sgd::Sgd;

/// A first-order optimizer over a flat parameter vector.
pub trait Optimizer: Send {
    /// Apply one update step in place.
    fn step(&mut self, params: &mut [f32], grads: &[f32]);

    /// Steps taken so far.
    fn steps(&self) -> u64;
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Both optimizers minimize a convex quadratic.
    #[test]
    fn optimizers_descend_quadratic() {
        let opts: Vec<(&str, Box<dyn Optimizer>)> = vec![
            ("sgd", Box::new(Sgd::new(0.1))),
            ("adam", Box::new(Adam::new(0.1, 2))),
        ];
        for (name, mut opt) in opts {
            let mut p = vec![5.0f32, -3.0];
            for _ in 0..200 {
                let g: Vec<f32> = p.iter().map(|x| 2.0 * x).collect();
                opt.step(&mut p, &g);
            }
            assert!(
                p.iter().all(|x| x.abs() < 0.1),
                "{name} did not converge: {p:?}"
            );
            assert_eq!(opt.steps(), 200);
        }
    }
}
