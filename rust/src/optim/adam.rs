//! Adam (Kingma & Ba) with bias correction — the paper's optimizer.

use super::Optimizer;

/// Adam over a flat parameter vector of fixed length.
pub struct Adam {
    lr: f32,
    beta1: f32,
    beta2: f32,
    eps: f32,
    t: u64,
    m: Vec<f32>,
    v: Vec<f32>,
}

impl Adam {
    /// Default betas (0.9, 0.999), eps 1e-8 — PyTorch defaults, which the
    /// paper's implementation uses.
    pub fn new(lr: f32, dim: usize) -> Adam {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            t: 0,
            m: vec![0.0; dim],
            v: vec![0.0; dim],
        }
    }

    pub fn with_betas(mut self, beta1: f32, beta2: f32) -> Adam {
        self.beta1 = beta1;
        self.beta2 = beta2;
        self
    }

    pub fn lr(&self) -> f32 {
        self.lr
    }

    /// The optimizer's full state: first moments, second moments, and the
    /// step counter (train-resume checkpoints capture all three — the
    /// bias-correction terms depend on `t`, so resuming without it would
    /// re-warm-up the effective learning rate).
    pub fn state(&self) -> (&[f32], &[f32], u64) {
        (&self.m, &self.v, self.t)
    }

    /// Restore moments + step counter captured by [`Self::state`]. The
    /// dimensions must match the optimizer this state was taken from.
    pub fn restore(&mut self, m: &[f32], v: &[f32], t: u64) {
        assert_eq!(m.len(), self.m.len(), "Adam restore dim mismatch");
        assert_eq!(v.len(), self.v.len(), "Adam restore dim mismatch");
        self.m.copy_from_slice(m);
        self.v.copy_from_slice(v);
        self.t = t;
    }
}

impl Optimizer for Adam {
    fn step(&mut self, params: &mut [f32], grads: &[f32]) {
        assert_eq!(params.len(), self.m.len(), "Adam dim mismatch");
        assert_eq!(grads.len(), self.m.len(), "Adam grad dim mismatch");
        self.t += 1;
        let b1t = 1.0 - self.beta1.powi(self.t as i32);
        let b2t = 1.0 - self.beta2.powi(self.t as i32);
        for i in 0..params.len() {
            let g = grads[i];
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * g;
            self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * g * g;
            let m_hat = self.m[i] / b1t;
            let v_hat = self.v[i] / b2t;
            params[i] -= self.lr * m_hat / (v_hat.sqrt() + self.eps);
        }
    }

    fn steps(&self) -> u64 {
        self.t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::Optimizer;

    #[test]
    fn first_step_is_lr_sized() {
        // With bias correction, |Δp| of the first step ≈ lr for any
        // nonzero gradient.
        let mut a = Adam::new(0.01, 1);
        let mut p = vec![1.0f32];
        a.step(&mut p, &[123.0]);
        assert!((1.0 - p[0] - 0.01).abs() < 1e-4, "got {}", p[0]);
    }

    #[test]
    fn matches_reference_trajectory() {
        // Hand-computed two steps on f(p) = p^2 / 2 (grad = p), lr=0.1.
        let mut a = Adam::new(0.1, 1);
        let mut p = vec![1.0f32];
        let g1 = p[0];
        a.step(&mut p, &[g1]);
        // step 1: m=0.1, v=1e-3*1, m̂=1, v̂=1 -> p = 1 - 0.1*1/(1+eps)
        assert!((p[0] - 0.9).abs() < 1e-4);
        let g2 = p[0];
        a.step(&mut p, &[g2]);
        // step 2 (hand-derived): m=0.19, v=0.0018019, m̂=1.0, v̂=0.95...
        // p ≈ 0.9 - 0.1*1.0/(0.9747 + eps) ≈ 0.7974 ; allow slack
        assert!((p[0] - 0.7974).abs() < 5e-3, "got {}", p[0]);
    }

    #[test]
    fn sparse_gradient_keeps_momentum_decaying() {
        let mut a = Adam::new(0.1, 2);
        let mut p = vec![0.0f32, 0.0];
        a.step(&mut p, &[1.0, 0.0]);
        let p0_after_1 = p[0];
        // zero gradient for index 0: momentum still moves it, but less.
        a.step(&mut p, &[0.0, 0.0]);
        let delta2 = (p[0] - p0_after_1).abs();
        assert!(delta2 > 0.0 && delta2 < p0_after_1.abs());
    }

    #[test]
    fn state_restore_resumes_the_exact_trajectory() {
        // Train 5 steps straight vs 3 steps + state/restore + 2 steps:
        // identical parameters bit for bit.
        let grad = |p: &[f32]| -> Vec<f32> { p.iter().map(|x| 2.0 * x).collect() };
        let mut a = Adam::new(0.1, 2);
        let mut pa = vec![5.0f32, -3.0];
        for _ in 0..5 {
            let g = grad(&pa);
            a.step(&mut pa, &g);
        }
        let mut b = Adam::new(0.1, 2);
        let mut pb = vec![5.0f32, -3.0];
        for _ in 0..3 {
            let g = grad(&pb);
            b.step(&mut pb, &g);
        }
        let (m, v, t) = b.state();
        assert_eq!(t, 3);
        let (m, v) = (m.to_vec(), v.to_vec());
        let mut c = Adam::new(0.1, 2);
        c.restore(&m, &v, t);
        for _ in 0..2 {
            let g = grad(&pb);
            c.step(&mut pb, &g);
        }
        assert_eq!(pa, pb);
        assert_eq!(c.steps(), 5);
    }

    #[test]
    #[should_panic(expected = "dim mismatch")]
    fn dim_mismatch_panics() {
        let mut a = Adam::new(0.1, 2);
        let mut p = vec![0.0f32; 3];
        a.step(&mut p, &[0.0; 3]);
    }
}
