//! Learning-rate schedules and the rank-scaling rule ablation.
//!
//! Sec. VI-C3 of the paper: "We did explore the option to scale the
//! generator learning rate w.r.t the number of ranks, but did not observe
//! an improvement over the default settings" — the classic linear-scaling
//! rule (Goyal et al.) applied to the weak-scaling study. This module
//! implements the candidate rules so the ablation bench can reproduce
//! that negative result, plus warmup/decay schedules for general use.

/// How to derive a per-rank learning rate from the base rate.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RankScaling {
    /// Paper default: keep the base rate regardless of ranks.
    Constant,
    /// Linear-scaling rule: lr * N (classic large-batch heuristic; note
    /// that under eq (10) the *global* batch is constant, so this
    /// over-scales — one hypothesis for the paper's negative result).
    Linear,
    /// Square-root scaling: lr * sqrt(N).
    Sqrt,
}

impl RankScaling {
    pub fn apply(&self, base_lr: f32, ranks: usize) -> f32 {
        match self {
            RankScaling::Constant => base_lr,
            RankScaling::Linear => base_lr * ranks as f32,
            RankScaling::Sqrt => base_lr * (ranks as f32).sqrt(),
        }
    }

    pub fn parse(s: &str) -> Option<RankScaling> {
        match s.to_ascii_lowercase().as_str() {
            "constant" | "none" => Some(RankScaling::Constant),
            "linear" => Some(RankScaling::Linear),
            "sqrt" => Some(RankScaling::Sqrt),
            _ => None,
        }
    }
}

/// Epoch-indexed LR schedule: optional linear warmup into a base rate,
/// optional exponential decay afterwards.
#[derive(Clone, Copy, Debug)]
pub struct LrSchedule {
    pub base_lr: f32,
    /// Warmup epochs (0 = none).
    pub warmup: u64,
    /// Multiplicative decay per epoch after warmup (1.0 = none).
    pub decay: f32,
    /// Lower bound.
    pub min_lr: f32,
}

impl LrSchedule {
    pub fn constant(base_lr: f32) -> LrSchedule {
        LrSchedule {
            base_lr,
            warmup: 0,
            decay: 1.0,
            min_lr: 0.0,
        }
    }

    pub fn with_warmup(mut self, epochs: u64) -> LrSchedule {
        self.warmup = epochs;
        self
    }

    pub fn with_decay(mut self, decay: f32, min_lr: f32) -> LrSchedule {
        self.decay = decay;
        self.min_lr = min_lr;
        self
    }

    /// LR at a given epoch.
    pub fn at(&self, epoch: u64) -> f32 {
        if self.warmup > 0 && epoch < self.warmup {
            return self.base_lr * (epoch + 1) as f32 / self.warmup as f32;
        }
        let steps = epoch.saturating_sub(self.warmup) as i32;
        (self.base_lr * self.decay.powi(steps)).max(self.min_lr)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scaling_rules() {
        assert_eq!(RankScaling::Constant.apply(1e-5, 8), 1e-5);
        assert_eq!(RankScaling::Linear.apply(1e-5, 8), 8e-5);
        assert!((RankScaling::Sqrt.apply(1e-4, 4) - 2e-4).abs() < 1e-10);
        assert_eq!(RankScaling::parse("linear"), Some(RankScaling::Linear));
        assert_eq!(RankScaling::parse("none"), Some(RankScaling::Constant));
        assert_eq!(RankScaling::parse("huh"), None);
    }

    #[test]
    fn warmup_ramps_linearly_then_holds() {
        let s = LrSchedule::constant(1.0).with_warmup(4);
        assert_eq!(s.at(0), 0.25);
        assert_eq!(s.at(1), 0.5);
        assert_eq!(s.at(3), 1.0);
        assert_eq!(s.at(100), 1.0);
    }

    #[test]
    fn decay_respects_floor() {
        let s = LrSchedule::constant(1.0).with_decay(0.5, 0.1);
        assert_eq!(s.at(0), 1.0);
        assert_eq!(s.at(1), 0.5);
        assert_eq!(s.at(2), 0.25);
        assert_eq!(s.at(10), 0.1); // floored
    }

    #[test]
    fn warmup_then_decay_composes() {
        let s = LrSchedule::constant(1.0).with_warmup(2).with_decay(0.9, 0.0);
        assert_eq!(s.at(0), 0.5);
        assert_eq!(s.at(2), 1.0 * 0.9f32.powi(0));
        assert!((s.at(4) - 0.81).abs() < 1e-6);
    }
}
