//! Experiment drivers: one function per paper table/figure.
//!
//! Shared by the CLI (`sagips experiment <id>`) and the bench binaries.
//! Every driver prints the regenerated rows next to the paper's reported
//! shape so the comparison is immediate, and returns the raw data for the
//! caller (bench harness writes CSVs into `reports/`).
//!
//! Scaled-down defaults ([`Scale::ci`]) keep each experiment in the
//! seconds-to-minutes range on one CPU host; [`Scale::paper`] reproduces
//! the full Table III configuration (requires `--paper-scale` artifacts
//! and hours of compute).

use crate::config::{presets, Mode, RunConfig};
use crate::coordinator::launcher::{run_training, RunResult};
use crate::ensemble::analysis::EnsembleResult;
use crate::ensemble::sampling;
use crate::model::residuals;
use crate::runtime::RuntimeHandle;
use crate::sim::sweep::{self, PAPER_RANKS};
use crate::sim::ComputeModel;
use crate::tensor::stats;
use crate::util::bench::data_table;
use crate::util::error::Result;

/// Experiment scale knobs.
#[derive(Clone, Debug)]
pub struct Scale {
    /// Ensemble size (paper: 20, Fig 10: 100).
    pub ensemble_m: usize,
    /// Training epochs per run (paper: 100k).
    pub epochs: usize,
    /// Ranks for distributed runs (paper: 8 for Fig 13/Table IV).
    pub ranks: usize,
    /// Fig 9 resamplings (paper: 300).
    pub samplings: usize,
    /// Checkpoint cadence.
    pub checkpoint_every: usize,
}

impl Scale {
    /// CI-friendly scale: minutes for the heaviest figure.
    pub fn ci() -> Scale {
        Scale {
            ensemble_m: 6,
            epochs: 240,
            ranks: 8,
            samplings: 120,
            checkpoint_every: 30,
        }
    }

    /// Smoke scale: seconds per figure (used by `cargo bench` defaults).
    pub fn smoke() -> Scale {
        Scale {
            ensemble_m: 3,
            epochs: 60,
            ranks: 4,
            samplings: 40,
            checkpoint_every: 15,
        }
    }

    /// The paper's configuration.
    pub fn paper() -> Scale {
        Scale {
            ensemble_m: 20,
            epochs: 100_000,
            ranks: 8,
            samplings: 300,
            checkpoint_every: 5_000,
        }
    }

    /// From the SAGIPS_SCALE env var: smoke (default for benches) | ci |
    /// paper.
    pub fn from_env(default: Scale) -> Scale {
        match std::env::var("SAGIPS_SCALE").as_deref() {
            Ok("paper") => Scale::paper(),
            Ok("ci") => Scale::ci(),
            Ok("smoke") => Scale::smoke(),
            _ => default,
        }
    }

    fn base_cfg(&self, handle: &RuntimeHandle) -> RunConfig {
        let mut cfg = presets::ci_default();
        cfg.epochs = self.epochs;
        cfg.ranks = self.ranks;
        cfg.checkpoint_every = self.checkpoint_every;
        cfg.artifacts_dir = handle.manifest().dir.display().to_string();
        cfg
    }
}

// ---------------------------------------------------------------------------
// Fig 8: ensemble residual mean/sigma vs model size x data size
// ---------------------------------------------------------------------------

/// One Fig 8 configuration result.
#[derive(Clone, Debug)]
pub struct Fig8Row {
    pub model: String,
    pub batch: usize,
    pub mean_r0: f64,
    pub sigma_r0: f64,
}

/// Fig 8: ensembles over the (model size x batch size) grid; larger
/// models + more data give smaller residual and spread.
pub fn fig8(handle: &RuntimeHandle, scale: &Scale) -> Result<Vec<Fig8Row>> {
    let grid = [
        ("small", 16usize),
        ("small", 64),
        ("medium", 16),
        ("medium", 64),
        ("paper", 16),
        ("paper", 64),
    ];
    let mut rows = Vec::new();
    for (model, batch) in grid {
        let mut cfg = scale.base_cfg(handle);
        cfg.mode = Mode::Ensemble;
        cfg.ranks = 1;
        cfg.model = model.into();
        cfg.batch = batch;
        cfg.data_pool = (batch * cfg.events * 4).max(6400);
        let ens = EnsembleResult::train(&cfg, scale.ensemble_m, handle)?;
        let resp = ens.response();
        let res = resp.residuals(&ens.true_params);
        let nsig = resp.normalized_sigma(&ens.true_params);
        rows.push(Fig8Row {
            model: model.into(),
            batch,
            mean_r0: res[0],
            sigma_r0: nsig[0],
        });
    }
    let table: Vec<(f64, Vec<f64>)> = rows
        .iter()
        .enumerate()
        .map(|(i, r)| (i as f64, vec![r.batch as f64, r.mean_r0, r.sigma_r0]))
        .collect();
    data_table(
        "Fig 8 — ensemble residual r̂0 (mean, σ) per (model, batch) config",
        "config#",
        &["batch", "mean_r0", "sigma_r0"],
        &table,
    );
    println!("paper shape: larger models + larger batch -> smaller |mean| and σ");
    Ok(rows)
}

// ---------------------------------------------------------------------------
// Fig 9 / Fig 10: ensemble-size studies
// ---------------------------------------------------------------------------

/// Fig 9: RMSE vs σ for ensemble sizes 2..M over a trained pool.
pub fn fig9(handle: &RuntimeHandle, scale: &Scale) -> Result<Vec<sampling::SizeSummary>> {
    let mut cfg = scale.base_cfg(handle);
    cfg.mode = Mode::Ensemble;
    cfg.ranks = 1;
    let ens = EnsembleResult::train(&cfg, scale.ensemble_m.max(4), handle)?;
    let sizes: Vec<usize> = (2..=ens.member_preds.len()).collect();
    let mut rng = crate::util::rng::Rng::new(cfg.seed ^ 0xF19);
    let out = sampling::rmse_sigma_study(
        &ens.member_preds,
        ens.k,
        &ens.true_params,
        &sizes,
        scale.samplings,
        &mut rng,
    );
    let table: Vec<(f64, Vec<f64>)> = out
        .iter()
        .map(|s| {
            (
                s.m as f64,
                vec![s.mean_rmse, s.mean_sigma, s.semi_rmse, s.semi_sigma],
            )
        })
        .collect();
    data_table(
        "Fig 9 — RMSE vs σ, 95% contours per ensemble size M",
        "M",
        &["mean_rmse", "mean_sigma", "semi_rmse", "semi_sigma"],
        &table,
    );
    println!("paper shape: contours move toward the origin and tighten as M grows");
    Ok(out)
}

/// Fig 10: residual mean/σ vs ensemble size up to M (paper: 100).
pub fn fig10(handle: &RuntimeHandle, scale: &Scale) -> Result<Vec<(usize, f64, f64)>> {
    let mut cfg = scale.base_cfg(handle);
    cfg.mode = Mode::Ensemble;
    cfg.ranks = 1;
    let m_max = scale.ensemble_m.max(8);
    let ens = EnsembleResult::train(&cfg, m_max, handle)?;
    let sizes: Vec<usize> = [1usize, 2, 4, 8, 16, 32, 64, 100]
        .into_iter()
        .filter(|&m| m <= m_max)
        .collect();
    let out = sampling::growth_study(&ens.member_preds, ens.k, &ens.true_params, &sizes);
    let table: Vec<(f64, Vec<f64>)> = out
        .iter()
        .map(|&(m, r, s)| (m as f64, vec![r, s]))
        .collect();
    data_table(
        "Fig 10 — ensemble residual (mean |r̂|, σ) vs ensemble size M",
        "M",
        &["mean_abs_residual", "sigma"],
        &table,
    );
    println!("paper shape: both curves decrease with M");
    Ok(out)
}

// ---------------------------------------------------------------------------
// Fig 11 / Fig 12: scaling studies (simulator)
// ---------------------------------------------------------------------------

/// Fig 11: total training time vs ranks per mode (calibrated DES).
pub fn fig11(compute: ComputeModel) -> Vec<(Mode, Vec<(usize, f64)>)> {
    let mut out: Vec<(Mode, Vec<(usize, f64)>)> = Vec::new();
    for &mode in sweep::PAPER_MODES {
        let pts = sweep::sweep_mode(mode, PAPER_RANKS, compute);
        out.push((
            mode,
            pts.iter().map(|p| (p.ranks, p.result.total_s)).collect(),
        ));
    }
    for (mode, series) in &out {
        let table: Vec<(f64, Vec<f64>)> = series
            .iter()
            .map(|&(n, t)| (n as f64, vec![t / 3600.0]))
            .collect();
        data_table(
            &format!("Fig 11 — total training time [h] vs ranks ({})", mode.name()),
            "ranks",
            &["hours"],
            &table,
        );
    }
    println!("paper shape: conv-ARAR grows ~linearly (log-x), grouped modes ~flat");
    out
}

/// Fig 12: analysis rate (eq 9) vs ranks per mode + 4->400 gains.
pub fn fig12(compute: ComputeModel) -> Vec<(Mode, Vec<(usize, f64)>, f64)> {
    let single = sweep::single_gpu_rate(compute);
    println!("\nsingle-GPU reference rate: {single:.3e} events/s (dashed line)");
    let mut out = Vec::new();
    for &mode in sweep::PAPER_MODES {
        let pts = sweep::sweep_mode(mode, PAPER_RANKS, compute);
        let gain = sweep::rate_gain(&pts);
        let series: Vec<(usize, f64)> = pts
            .iter()
            .map(|p| (p.ranks, p.result.analysis_rate))
            .collect();
        let table: Vec<(f64, Vec<f64>)> = series
            .iter()
            .map(|&(n, r)| (n as f64, vec![r]))
            .collect();
        data_table(
            &format!("Fig 12 — analysis rate [events/s] vs ranks ({})", mode.name()),
            "ranks",
            &["events_per_s"],
            &table,
        );
        println!("gain 4->400 ranks ({}): {gain:.1}x", mode.name());
        out.push((mode, series, gain));
    }
    println!("paper: ~40x conventional, ~80x grouped; saturation after N≳28 for conv");
    out
}

// ---------------------------------------------------------------------------
// Fig 13 / Table IV: convergence comparison across modes (8 ranks)
// ---------------------------------------------------------------------------

/// Modes compared in Fig 13 / Table IV.
pub const TAB4_MODES: &[Mode] = &[
    Mode::Horovod,
    Mode::RmaArarArar,
    Mode::ArarArar,
    Mode::ConvArar,
];

/// Fig 13 + Table IV: per-mode ensembles of distributed runs; returns
/// (mode, residual curve (t, mean, std), table row sized to the
/// scenario's parameter count).
pub type ConvergenceRow = (Mode, Vec<(f64, f64, f64)>, Vec<(f64, f64)>);

pub fn fig13_tab4(handle: &RuntimeHandle, scale: &Scale) -> Result<Vec<ConvergenceRow>> {
    let mut out = Vec::new();
    for &mode in TAB4_MODES {
        let mut cfg = scale.base_cfg(handle);
        cfg.mode = mode;
        cfg.ranks = scale.ranks;
        let ens = EnsembleResult::train(&cfg, scale.ensemble_m, handle)?;
        let curve = ens.residual_curve();
        let row = ens.table4_row();
        let table: Vec<(f64, Vec<f64>)> = curve
            .iter()
            .map(|&(t, m, s)| (t, vec![m, s]))
            .collect();
        data_table(
            &format!(
                "Fig 13 — mean |r̂| vs accumulated time ({}, {} ranks, M={})",
                mode.name(),
                cfg.ranks,
                scale.ensemble_m
            ),
            "time_s",
            &["mean_abs_residual", "sigma"],
            &table,
        );
        out.push((mode, curve, row));
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Fig 14/15/16: weak scaling per eq (10)
// ---------------------------------------------------------------------------

/// Weak-scaling curves: per rank count, the (time, mean |r̂|, σ) residual
/// trajectory with batch = base/N (eq 10).
pub fn weak_scaling_curves(
    handle: &RuntimeHandle,
    scale: &Scale,
    mode: Mode,
    rank_counts: &[usize],
) -> Result<Vec<(usize, Vec<(f64, f64, f64)>)>> {
    let mut out = Vec::new();
    for &n in rank_counts {
        let mut cfg = scale.base_cfg(handle);
        cfg.mode = if n == 1 { Mode::Ensemble } else { mode };
        let base_batch = cfg.batch;
        let mut c = presets::weak_scaling(&cfg, n);
        // eq (10) on our scaled-down base batch.
        c.batch = (base_batch / n).max(1);
        let run = run_training(&c, handle)?;
        let curve: Vec<(f64, f64, f64)> = run
            .residual_curve
            .iter()
            .map(|p| (p.elapsed_s, residuals::mean_abs(&p.residuals), 0.0))
            .collect();
        let table: Vec<(f64, Vec<f64>)> =
            curve.iter().map(|&(t, m, _)| (t, vec![m])).collect();
        data_table(
            &format!(
                "Fig 14/15/16 — mean |r̂| vs time ({}, N={n}, batch={})",
                c.mode.name(),
                c.batch
            ),
            "time_s",
            &["mean_abs_residual"],
            &table,
        );
        out.push((n, curve));
    }
    println!("paper shape: multi-GPU curves reach low residuals earlier; convergence quality consistent with single GPU");
    Ok(out)
}

// ---------------------------------------------------------------------------
// Run summaries
// ---------------------------------------------------------------------------

/// The run-summary columns: wall time, eq (9) analysis rate, the
/// hot/hidden comm split, the mean applied-gradient staleness, the
/// straggler-policy outcomes (exchanges skipped / applied past deadline),
/// and the membership bookkeeping (live ranks at the end of the run plus
/// join/leave/evict event counts — `members` equals the launched width
/// and the counts are 0 for a fixed cohort). The trailing pair surfaces
/// the buffer-pool discipline: `comm_allocs` is total exchange-path
/// buffer allocations across ranks (warmup only, at steady state) and
/// `pool_hit_%` the share of checkouts served without allocating.
pub const RUN_SUMMARY_COLS: &[&str] = &[
    "wall_s",
    "events_per_s",
    "comm_hot_s",
    "comm_hidden_s",
    "mean_staleness",
    "skips",
    "late_applies",
    "members",
    "joins",
    "leaves",
    "evicts",
    "comm_allocs",
    "pool_hit_%",
];

/// One run-summary row (the x column is the configured staleness k, so
/// staleness sweeps stack into one readable table). `mean_staleness` is
/// the *applied* staleness the run actually observed — 0 for a blocking
/// run, ≤ k under a k-deep exchange window (drains at the checkpoint
/// cadence pull it below k). `skips`/`late_applies` sum the straggler-
/// policy outcomes across ranks (always 0 under `on_straggler: block`).
pub fn run_summary_row(cfg: &RunConfig, run: &RunResult) -> (f64, Vec<f64>) {
    use crate::coordinator::MembershipChange;
    let skips: u64 = run.comm.iter().map(|c| c.skips).sum();
    let late: u64 = run.comm.iter().map(|c| c.late_applies).sum();
    let allocs: u64 = run.comm.iter().map(|c| c.allocs).sum();
    let hits: u64 = run.comm.iter().map(|c| c.pool_hits).sum();
    let hit_pct = if allocs + hits == 0 {
        100.0
    } else {
        100.0 * hits as f64 / (allocs + hits) as f64
    };
    (
        cfg.staleness as f64,
        vec![
            run.wall_s,
            run.analysis_rate(),
            run.metrics.total("comm_s"),
            run.metrics.total("comm_hidden_s"),
            run.metrics.mean_staleness().unwrap_or(0.0),
            skips as f64,
            late as f64,
            run.final_members() as f64,
            run.membership_count(MembershipChange::Join) as f64,
            run.membership_count(MembershipChange::Leave) as f64,
            run.membership_count(MembershipChange::Evict) as f64,
            allocs as f64,
            hit_pct,
        ],
    )
}

/// Print the standard run summary for one training run (`sagips train`
/// ends with this; staleness-sweep harnesses print one row per k).
pub fn run_summary(cfg: &RunConfig, run: &RunResult) {
    data_table(
        &format!(
            "run summary — {} ranks, {} mode, chunking {}",
            cfg.ranks,
            cfg.mode.name(),
            cfg.chunking.label()
        ),
        "staleness_k",
        RUN_SUMMARY_COLS,
        &[run_summary_row(cfg, run)],
    );
}

/// The per-rank health-summary columns (printed when an exchange
/// deadline was armed): settled exchanges, deadline misses (total and
/// worst consecutive run), mean submit-to-apply latency, the worst
/// [`HealthState`](crate::coordinator::pipeline::HealthState) reached
/// (0 = healthy, 1 = degraded, 2 = suspect), and the rank's
/// participation epochs — the epochs it actually trained, which under
/// elastic membership is less than the run length for ranks that left,
/// were evicted, or joined late.
pub const HEALTH_SUMMARY_COLS: &[&str] = &[
    "settled",
    "timeouts",
    "max_consec",
    "mean_latency_s",
    "worst_state",
    "participation",
];

/// Print the per-rank exchange-health table for a run with straggler
/// tolerance armed.
pub fn health_summary(run: &RunResult) {
    let rows: Vec<(f64, Vec<f64>)> = run
        .health
        .iter()
        .enumerate()
        .map(|(rank, h)| {
            let participation = run
                .comm
                .get(rank)
                .map_or(0, |c| c.participation_epochs);
            (
                rank as f64,
                vec![
                    h.settled as f64,
                    h.timeouts as f64,
                    h.max_consecutive_timeouts as f64,
                    h.mean_latency_s(),
                    h.worst_state().as_f64(),
                    participation as f64,
                ],
            )
        })
        .collect();
    data_table(
        "rank health — deadline misses and exchange latency per rank",
        "rank",
        HEALTH_SUMMARY_COLS,
        &rows,
    );
}

/// Summary helper: time to reach a residual threshold on a curve.
pub fn time_to_threshold(curve: &[(f64, f64, f64)], threshold: f64) -> Option<f64> {
    curve
        .iter()
        .find(|&&(_, m, _)| m <= threshold)
        .map(|&(t, _, _)| t)
}

/// Mean |r| summary over the tail of a curve (robust final value).
pub fn tail_mean(curve: &[(f64, f64, f64)], tail: usize) -> f64 {
    let n = curve.len();
    if n == 0 {
        return f64::NAN;
    }
    let start = n.saturating_sub(tail);
    let vals: Vec<f64> = curve[start..].iter().map(|&(_, m, _)| m).collect();
    stats::mean(&vals)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scales_have_sane_ordering() {
        let smoke = Scale::smoke();
        let ci = Scale::ci();
        let paper = Scale::paper();
        assert!(smoke.epochs < ci.epochs && ci.epochs < paper.epochs);
        assert_eq!(paper.ensemble_m, 20);
        assert_eq!(paper.checkpoint_every, 5000);
    }

    #[test]
    fn time_to_threshold_finds_first_crossing() {
        let curve = vec![(0.0, 1.0, 0.0), (1.0, 0.5, 0.0), (2.0, 0.1, 0.0)];
        assert_eq!(time_to_threshold(&curve, 0.5), Some(1.0));
        assert_eq!(time_to_threshold(&curve, 0.01), None);
    }

    #[test]
    fn tail_mean_handles_short_curves() {
        let curve = vec![(0.0, 1.0, 0.0), (1.0, 3.0, 0.0)];
        assert_eq!(tail_mean(&curve, 10), 2.0);
        assert!(tail_mean(&[], 3).is_nan());
    }

    #[test]
    fn run_summary_row_surfaces_mean_staleness() {
        use crate::coordinator::{MembershipChange, MembershipRecord};
        use crate::metrics::{MergedMetrics, Recorder};
        let mut r = Recorder::new(0);
        r.push("staleness", 0, 2.0);
        r.push("staleness", 1, 2.0);
        r.push("comm_s", 0, 0.5);
        r.push("comm_hidden_s", 0, 1.5);
        r.push("members", 1, 3.0);
        let mut comm_a = crate::collective::CommStats::default();
        comm_a.skips = 2;
        comm_a.allocs = 3;
        comm_a.pool_hits = 7;
        let mut comm_b = crate::collective::CommStats::default();
        comm_b.skips = 1;
        comm_b.late_applies = 3;
        comm_b.allocs = 1;
        comm_b.pool_hits = 5;
        let run = RunResult {
            wall_s: 2.0,
            metrics: MergedMetrics::new(vec![r]),
            checkpoints: Vec::new(),
            states: Vec::new(),
            residual_curve: Vec::new(),
            final_residuals: None,
            comm: vec![comm_a, comm_b],
            health: Vec::new(),
            resumed_from: None,
            membership: vec![
                MembershipRecord {
                    epoch: 1,
                    rank: 3,
                    kind: MembershipChange::Leave,
                },
                MembershipRecord {
                    epoch: 4,
                    rank: 2,
                    kind: MembershipChange::Evict,
                },
                MembershipRecord {
                    epoch: 8,
                    rank: 3,
                    kind: MembershipChange::Join,
                },
            ],
            stopped_at: None,
        };
        let mut cfg = presets::ci_default();
        cfg.staleness = 2;
        let (k, cols) = run_summary_row(&cfg, &run);
        assert_eq!(k, 2.0);
        assert_eq!(cols.len(), RUN_SUMMARY_COLS.len());
        assert_eq!(cols[2], 0.5); // comm_hot_s
        assert_eq!(cols[3], 1.5); // comm_hidden_s
        assert_eq!(cols[4], 2.0); // mean applied staleness
        assert_eq!(cols[5], 3.0); // skips summed across ranks
        assert_eq!(cols[6], 3.0); // late applies summed across ranks
        assert_eq!(cols[7], 3.0); // members: latest-epoch sample
        assert_eq!(cols[8], 1.0); // joins
        assert_eq!(cols[9], 1.0); // leaves
        assert_eq!(cols[10], 1.0); // evicts
        assert_eq!(cols[11], 4.0); // comm_allocs summed across ranks
        assert_eq!(cols[12], 75.0); // pool_hit_%: 12 hits of 16 checkouts
    }

    #[test]
    fn fig11_12_run_without_artifacts() {
        // Simulator-only figures must not need the runtime.
        let compute = ComputeModel::fixed(0.001);
        let f11 = fig11(compute);
        assert_eq!(f11.len(), 3);
        let f12 = fig12(compute);
        assert_eq!(f12.len(), 3);
        // grouped gain exceeds conventional gain
        let conv_gain = f12[0].2;
        let grp_gain = f12[1].2;
        assert!(grp_gain > conv_gain);
    }
}
