//! Report generation: turn run/simulation outputs into the paper's
//! tables and figures (text + CSV).

pub mod experiments;
pub mod tables;

pub use tables::{format_jobs, format_scenarios, format_table4, table4_paper_reference, Table4Row};
