//! Table formatting — Table IV (final residuals per mode), the
//! scenario-registry listing (`sagips scenarios`), and the job table
//! (`sagips job list`).

use crate::scenario::ScenarioInfo;
use crate::service::JobStatus;
use crate::tensor::stats;

/// One method column of Table IV: per-parameter (mean, sigma) residuals,
/// in the paper's 10^-3 units. The row width follows the scenario's
/// parameter count (6 for the paper's proxy app, whatever `param_dim`
/// says elsewhere).
#[derive(Clone, Debug)]
pub struct Table4Row {
    pub method: String,
    /// (mean, sigma) * 10^3 per parameter.
    pub residuals: Vec<(f64, f64)>,
}

impl Table4Row {
    /// Build from raw residual (mean, sigma) pairs (natural units), at
    /// whatever width the scenario has.
    pub fn from_raw(method: &str, raw: &[(f64, f64)]) -> Table4Row {
        Table4Row {
            method: method.to_string(),
            residuals: raw.iter().map(|&(m, s)| (m * 1e3, s * 1e3)).collect(),
        }
    }

    /// Mean |residual| across parameters (summary scalar, 10^-3 units).
    pub fn mean_abs(&self) -> f64 {
        let vals: Vec<f64> = self.residuals.iter().map(|(m, _)| m.abs()).collect();
        stats::mean(&vals)
    }
}

/// The paper's reported Table IV (units of 10^-3), for side-by-side
/// comparison in the bench output.
pub fn table4_paper_reference() -> Vec<Table4Row> {
    let rows = [
        ("hvd (paper)", [(95.0, 53.0), (94.0, 54.0), (26.0, 17.0), (212.0, 128.0), (138.0, 85.0), (99.0, 60.0)]),
        ("RMA-ARAR (paper)", [(5.0, 9.0), (6.0, 14.0), (1.0, 10.0), (24.0, 21.0), (17.0, 22.0), (11.0, 8.0)]),
        ("ARAR (paper)", [(3.0, 14.0), (8.0, 12.0), (0.0, 16.0), (20.0, 19.0), (14.0, 23.0), (9.0, 9.0)]),
        ("Conv. ARAR (paper)", [(2.0, 9.0), (3.0, 13.0), (0.0, 9.0), (26.0, 18.0), (18.0, 20.0), (11.0, 7.0)]),
    ];
    rows.iter()
        .map(|(m, r)| Table4Row {
            method: m.to_string(),
            residuals: r.to_vec(),
        })
        .collect()
}

/// Render Table IV rows (measured + reference) in the paper's format. The
/// column count follows the widest row, so mixed-width tables (e.g. a
/// measured 10-parameter scenario next to the paper's 6-parameter
/// reference) still line up.
pub fn format_table4(rows: &[Table4Row]) -> String {
    let width = rows.iter().map(|r| r.residuals.len()).max().unwrap_or(6);
    let mut s = String::new();
    s.push_str(&format!("{:<22}", "Residual [10^-3]"));
    for i in 0..width {
        s.push_str(&format!(" {:>14}", format!("r{i}")));
    }
    s.push('\n');
    s.push_str(&"-".repeat(22 + width * 15));
    s.push('\n');
    for row in rows {
        s.push_str(&format!("{:<22}", row.method));
        for (m, sg) in &row.residuals {
            s.push_str(&format!(" {:>14}", format!("{m:.0} ± {sg:.0}")));
        }
        s.push('\n');
    }
    s
}

/// Render the scenario registry as a table: one row per registered
/// inverse problem, with its operator shape and description.
pub fn format_scenarios(rows: &[ScenarioInfo]) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "{:<12} {:>6} {:>6} {:>6}  {}\n",
        "scenario", "P", "D", "K", "description"
    ));
    s.push_str(&"-".repeat(78));
    s.push('\n');
    for r in rows {
        s.push_str(&format!(
            "{:<12} {:>6} {:>6} {:>6}  {}\n",
            r.name, r.param_dim, r.event_dim, r.noise_dim, r.description
        ));
    }
    s.push_str("(P = parameters, D = floats per event, K = uniform draws per event)\n");
    s
}

/// Render daemon job rows (`sagips job list` / `status`) as a table, in
/// the registry-listing style: one row per job, id order.
pub fn format_jobs(rows: &[JobStatus]) -> String {
    let loss = |v: Option<f64>| match v {
        Some(x) => format!("{x:.4}"),
        None => "-".to_string(),
    };
    let mut s = String::new();
    s.push_str(&format!(
        "{:>4} {:<10} {:>5} {:<16} {:<10} {:>11} {:>9} {:>9}  {}\n",
        "id", "state", "prio", "name", "scenario", "epochs", "G loss", "D loss", "detail"
    ));
    s.push_str(&"-".repeat(96));
    s.push('\n');
    for r in rows {
        s.push_str(&format!(
            "{:>4} {:<10} {:>5} {:<16} {:<10} {:>11} {:>9} {:>9}  {}\n",
            r.id,
            r.state.name(),
            r.priority,
            r.name,
            r.scenario,
            format!("{}/{}", r.epochs_done, r.epochs),
            loss(r.gen_loss),
            loss(r.disc_loss),
            r.detail
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scenario_table_lists_every_registered_scenario() {
        let rows: Vec<ScenarioInfo> =
            crate::scenario::registry().iter().map(|s| s.info()).collect();
        let t = format_scenarios(&rows);
        for name in crate::scenario::names() {
            assert!(t.contains(name), "{t}");
        }
        assert!(t.contains("description"));
    }

    #[test]
    fn from_raw_scales_to_milli() {
        let raw = [(0.005, 0.009); 6];
        let row = Table4Row::from_raw("x", &raw);
        assert!((row.residuals[0].0 - 5.0).abs() < 1e-9);
        assert!((row.residuals[0].1 - 9.0).abs() < 1e-9);
        assert!((row.mean_abs() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn paper_reference_has_four_methods() {
        let rows = table4_paper_reference();
        assert_eq!(rows.len(), 4);
        // hvd is an order of magnitude worse than the async methods (the
        // paper's core convergence claim).
        assert!(rows[0].mean_abs() > 5.0 * rows[2].mean_abs());
    }

    #[test]
    fn format_contains_all_methods_and_columns() {
        let t = format_table4(&table4_paper_reference());
        assert!(t.contains("hvd (paper)"));
        assert!(t.contains("r5"));
        assert!(t.contains("±"));
    }

    #[test]
    fn job_table_lists_every_row() {
        use crate::service::JobState;
        let rows = vec![
            JobStatus {
                id: 1,
                name: "sweep-a".into(),
                state: JobState::Running,
                priority: 5,
                scenario: "quantile".into(),
                epochs: 40,
                epochs_done: 12,
                gen_loss: Some(0.6931),
                disc_loss: None,
                detail: "".into(),
            },
            JobStatus {
                id: 2,
                name: "sweep-b".into(),
                state: JobState::Queued,
                priority: -1,
                scenario: "deconv".into(),
                epochs: 40,
                epochs_done: 0,
                gen_loss: None,
                disc_loss: None,
                detail: "".into(),
            },
        ];
        let t = format_jobs(&rows);
        assert!(t.contains("sweep-a") && t.contains("sweep-b"), "{t}");
        assert!(t.contains("running") && t.contains("queued"), "{t}");
        assert!(t.contains("12/40") && t.contains("0/40"), "{t}");
        assert!(t.contains("0.6931"), "{t}");
    }

    #[test]
    fn rows_follow_the_scenario_width() {
        // A 10-parameter row renders 10 columns; mixed widths take the max.
        let wide = Table4Row::from_raw("deconv", &vec![(0.001, 0.002); 10]);
        assert_eq!(wide.residuals.len(), 10);
        let t = format_table4(&[wide.clone()]);
        assert!(t.contains("r9") && !t.contains("r10"), "{t}");
        let mut mixed = table4_paper_reference();
        mixed.push(wide);
        let t = format_table4(&mixed);
        assert!(t.contains("r9"), "{t}");
    }
}
