//! GAN parameter state and initialization.
//!
//! The paper: LeakyReLU hidden activations with Kaiming-normal weight
//! initialization. The flat layout matches `python/compile/nets.py`
//! ([W0, b0, W1, b1, ...], W row-major (In, Out)) — the manifest's layer
//! layout is the single source of truth, validated at parse time.

use crate::runtime::manifest::{LayerLayout, ModelMeta};
use crate::util::rng::Rng;

/// Flat generator + discriminator parameters for one rank's GAN copy.
#[derive(Clone, Debug)]
pub struct GanState {
    pub gen: Vec<f32>,
    pub disc: Vec<f32>,
}

impl GanState {
    /// Kaiming-normal initialization (fan-in mode, LeakyReLU gain) with
    /// zero biases, matching the paper's setup.
    pub fn init(meta: &ModelMeta, leaky_slope: f64, rng: &mut Rng) -> GanState {
        GanState {
            gen: init_flat(&meta.gen_layout, meta.gen_param_count, leaky_slope, rng),
            disc: init_flat(&meta.disc_layout, meta.disc_param_count, leaky_slope, rng),
        }
    }

    /// Total parameter count (diagnostics).
    pub fn param_count(&self) -> usize {
        self.gen.len() + self.disc.len()
    }
}

/// Kaiming-normal std for a layer: gain / sqrt(fan_in) with the LeakyReLU
/// gain sqrt(2 / (1 + slope^2)).
pub fn kaiming_std(fan_in: usize, leaky_slope: f64) -> f32 {
    let gain = (2.0 / (1.0 + leaky_slope * leaky_slope)).sqrt();
    (gain / (fan_in as f64).sqrt()) as f32
}

fn init_flat(
    layout: &[LayerLayout],
    param_count: usize,
    leaky_slope: f64,
    rng: &mut Rng,
) -> Vec<f32> {
    let mut flat = vec![0.0f32; param_count];
    for layer in layout {
        let std = kaiming_std(layer.w_rows, leaky_slope);
        for w in flat[layer.w_offset..layer.w_offset + layer.w_len()].iter_mut() {
            *w = rng.normal_f32(0.0, std);
        }
        // biases stay zero
    }
    flat
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::LayerLayout;

    fn meta2() -> ModelMeta {
        ModelMeta {
            gen_dims: vec![(16, 64), (64, 6)],
            disc_dims: vec![(2, 8), (8, 1)],
            gen_param_count: 16 * 64 + 64 + 64 * 6 + 6,
            disc_param_count: 2 * 8 + 8 + 8 + 1,
            gen_layout: vec![
                LayerLayout {
                    w_offset: 0,
                    w_rows: 16,
                    w_cols: 64,
                    b_offset: 1024,
                    b_len: 64,
                },
                LayerLayout {
                    w_offset: 1088,
                    w_rows: 64,
                    w_cols: 6,
                    b_offset: 1472,
                    b_len: 6,
                },
            ],
            disc_layout: vec![
                LayerLayout {
                    w_offset: 0,
                    w_rows: 2,
                    w_cols: 8,
                    b_offset: 16,
                    b_len: 8,
                },
                LayerLayout {
                    w_offset: 24,
                    w_rows: 8,
                    w_cols: 1,
                    b_offset: 32,
                    b_len: 1,
                },
            ],
        }
    }

    #[test]
    fn kaiming_std_formula() {
        // slope 0 -> ReLU gain sqrt(2): std = sqrt(2/fan_in)
        assert!((kaiming_std(8, 0.0) - (2.0f64 / 8.0).sqrt() as f32).abs() < 1e-7);
        // larger slope -> smaller gain
        assert!(kaiming_std(8, 0.5) < kaiming_std(8, 0.0));
    }

    #[test]
    fn init_shapes_and_bias_zero() {
        let meta = meta2();
        let mut rng = Rng::new(1);
        let s = GanState::init(&meta, 0.2, &mut rng);
        assert_eq!(s.gen.len(), meta.gen_param_count);
        assert_eq!(s.disc.len(), meta.disc_param_count);
        // biases zero
        for i in 1024..1088 {
            assert_eq!(s.gen[i], 0.0);
        }
        assert_eq!(s.gen[1472], 0.0);
        // weights not all zero
        assert!(s.gen[..1024].iter().any(|&w| w != 0.0));
    }

    #[test]
    fn init_statistics_match_kaiming() {
        let meta = meta2();
        let mut rng = Rng::new(7);
        let s = GanState::init(&meta, 0.2, &mut rng);
        let w = &s.gen[..1024]; // layer 0: fan_in 16
        let mean: f64 = w.iter().map(|&x| x as f64).sum::<f64>() / w.len() as f64;
        let var: f64 =
            w.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / w.len() as f64;
        let expect = kaiming_std(16, 0.2) as f64;
        assert!(mean.abs() < 0.05);
        assert!((var.sqrt() - expect).abs() / expect < 0.15);
    }

    #[test]
    fn different_seeds_different_weights() {
        let meta = meta2();
        let a = GanState::init(&meta, 0.2, &mut Rng::new(1));
        let b = GanState::init(&meta, 0.2, &mut Rng::new(2));
        assert_ne!(a.gen, b.gen);
        let a2 = GanState::init(&meta, 0.2, &mut Rng::new(1));
        assert_eq!(a.gen, a2.gen);
        assert_eq!(a.param_count(), a.gen.len() + a.disc.len());
    }
}
