//! Checkpoints: analysis snapshots and resumable training state.
//!
//! Two kinds of state are stored here, for two different jobs:
//!
//! * [`Checkpoint`] — the paper's *analysis* checkpoint. The convergence
//!   analysis is post-training: generator states are stored "at the first
//!   epoch and every other 5 k epochs (resulting in 21 generator
//!   checkpoints)" together with time stamps, and residual curves are
//!   computed afterwards from the checkpoints (Sec. VI-C2). Flat f32
//!   parameters (little-endian binary) plus a JSON sidecar with epoch and
//!   elapsed seconds.
//! * [`TrainCheckpoint`] — a *resumable* run checkpoint: every rank's
//!   complete training state (generator + discriminator parameters, Adam
//!   moments and step counters, RNG stream) at one epoch boundary, written
//!   atomically (write-then-rename) with a retain-last-N policy. A run
//!   restored from one continues **bit-identically** to an uninterrupted
//!   run of the same total epochs. See `docs/checkpointing.md` for the
//!   on-disk format and the crash-recovery semantics.
//!
//! Both carry the scenario identity, and both restore paths route through
//! [`Checkpoint::load_for_scenario`]: restoring a generator under a
//! different forward operator than it was trained on is refused instead of
//! silently diverging.

use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use crate::util::error::{Error, Result};
use crate::util::json::{self, Value};
use crate::util::rng::{RngSnapshot, RNG_SNAPSHOT_BYTES};

/// One stored generator state.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub rank: usize,
    pub epoch: u64,
    /// Seconds of accumulated training time when the checkpoint was taken.
    pub elapsed_s: f64,
    /// Scenario the generator was trained on. Restoring under a different
    /// scenario is refused ([`Checkpoint::load_for_scenario`]): the flat
    /// parameters would silently parameterize the wrong forward operator.
    pub scenario: String,
    pub gen_params: Vec<f32>,
}

const MAGIC: &[u8; 8] = b"SAGIPS01";

impl Checkpoint {
    /// Serialize to `<dir>/ckpt_r<rank>_e<epoch>.bin` (+ `.json` meta).
    pub fn save(&self, dir: &Path) -> Result<PathBuf> {
        std::fs::create_dir_all(dir)?;
        let stem = format!("ckpt_r{}_e{}", self.rank, self.epoch);
        let bin_path = dir.join(format!("{stem}.bin"));
        let mut f = std::fs::File::create(&bin_path)?;
        f.write_all(MAGIC)?;
        f.write_all(&(self.gen_params.len() as u64).to_le_bytes())?;
        // Params as raw little-endian f32.
        let mut bytes = Vec::with_capacity(self.gen_params.len() * 4);
        for v in &self.gen_params {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        f.write_all(&bytes)?;
        let meta = json::obj(vec![
            ("rank", json::num(self.rank as f64)),
            ("epoch", json::num(self.epoch as f64)),
            ("elapsed_s", json::num(self.elapsed_s)),
            ("scenario", json::s(self.scenario.clone())),
            ("params", json::num(self.gen_params.len() as f64)),
        ]);
        std::fs::write(dir.join(format!("{stem}.json")), meta.to_json_pretty())?;
        Ok(bin_path)
    }

    /// Load from a `.bin` path written by [`Checkpoint::save`], without
    /// checking what the generator was trained on — callers restoring
    /// parameters into a run must use [`Checkpoint::load_for_scenario`]
    /// so a cross-scenario restore is refused instead of diverging.
    pub fn load(bin_path: &Path) -> Result<Checkpoint> {
        let mut f = std::fs::File::open(bin_path)?;
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            return Err(Error::Checkpoint(format!(
                "{}: bad magic",
                bin_path.display()
            )));
        }
        let mut len_bytes = [0u8; 8];
        f.read_exact(&mut len_bytes)?;
        let n = u64::from_le_bytes(len_bytes) as usize;
        let mut raw = vec![0u8; n * 4];
        f.read_exact(&mut raw)?;
        let gen_params: Vec<f32> = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        // Sidecar meta.
        let meta_path = bin_path.with_extension("json");
        let meta_text = std::fs::read_to_string(&meta_path)
            .map_err(|e| Error::Checkpoint(format!("{}: {e}", meta_path.display())))?;
        let meta = Value::parse(&meta_text)?;
        Ok(Checkpoint {
            rank: meta.req_usize("rank")?,
            epoch: meta.req_usize("epoch")? as u64,
            elapsed_s: meta
                .req("elapsed_s")?
                .as_f64()
                .ok_or_else(|| Error::Checkpoint("elapsed_s not a number".into()))?,
            // Checkpoints from before the scenario subsystem carry no
            // scenario key; they were all trained on the proxy app.
            scenario: meta
                .get("scenario")
                .and_then(|s| s.as_str())
                .unwrap_or("quantile")
                .to_string(),
            gen_params,
        })
    }

    /// Load a checkpoint *for a specific scenario*: restoring a generator
    /// under a different scenario than it was trained on is refused with
    /// a clear error instead of silently diverging on the wrong forward
    /// operator.
    pub fn load_for_scenario(bin_path: &Path, scenario: &str) -> Result<Checkpoint> {
        let ck = Self::load(bin_path)?;
        // Case-insensitive, like every other scenario entry point
        // (scenario::lookup canonicalizes user-cased names).
        if !ck.scenario.eq_ignore_ascii_case(scenario) {
            return Err(Error::Checkpoint(format!(
                "{}: trained on scenario '{}' but the run is configured \
                 for '{scenario}' — refusing to restore (pass the matching \
                 --scenario to resume this checkpoint)",
                bin_path.display(),
                ck.scenario
            )));
        }
        Ok(ck)
    }

    /// List all checkpoints in a directory, sorted by (rank, epoch).
    pub fn list(dir: &Path) -> Result<Vec<PathBuf>> {
        let mut out = Vec::new();
        if !dir.exists() {
            return Ok(out);
        }
        for entry in std::fs::read_dir(dir)? {
            let p = entry?.path();
            if p.extension().is_some_and(|e| e == "bin")
                && p.file_name()
                    .and_then(|n| n.to_str())
                    .is_some_and(|n| n.starts_with("ckpt_"))
            {
                out.push(p);
            }
        }
        out.sort();
        Ok(out)
    }
}

/// In-memory checkpoint series for one rank (used when the analysis runs
/// in the same process and disk round-trips are unnecessary).
#[derive(Clone, Debug, Default)]
pub struct CheckpointSeries {
    pub checkpoints: Vec<Checkpoint>,
}

impl CheckpointSeries {
    pub fn record(
        &mut self,
        rank: usize,
        epoch: u64,
        elapsed_s: f64,
        scenario: &str,
        gen_params: &[f32],
    ) {
        self.checkpoints.push(Checkpoint {
            rank,
            epoch,
            elapsed_s,
            scenario: scenario.to_string(),
            gen_params: gen_params.to_vec(),
        });
    }

    pub fn len(&self) -> usize {
        self.checkpoints.len()
    }

    pub fn is_empty(&self) -> bool {
        self.checkpoints.is_empty()
    }
}

// ---------------------------------------------------------------------------
// Resumable run checkpoints
// ---------------------------------------------------------------------------

/// The complete training state of one rank at an epoch boundary: model
/// parameters, both optimizers' moments and step counters, and the rank's
/// RNG stream. Everything the epoch loop reads — so restoring it resumes
/// the run bit-identically.
#[derive(Clone, Debug, PartialEq)]
pub struct RankTrainState {
    pub rank: usize,
    pub gen: Vec<f32>,
    pub disc: Vec<f32>,
    pub gen_m: Vec<f32>,
    pub gen_v: Vec<f32>,
    pub gen_t: u64,
    pub disc_m: Vec<f32>,
    pub disc_v: Vec<f32>,
    pub disc_t: u64,
    pub rng: RngSnapshot,
}

/// A resumable run checkpoint: every rank's [`RankTrainState`] after
/// `epoch` completed (resume starts at `epoch + 1`).
///
/// On disk, one checkpoint is a directory `run_e<epoch>/` holding
///
/// * `ckpt_r0_e<epoch>.bin` + `.json` — rank 0's generator as a standard
///   [`Checkpoint`]. This is the **scenario-identity sidecar**: resume
///   loads it through [`Checkpoint::load_for_scenario`], so a checkpoint
///   trained on one scenario refuses to restore into a run configured for
///   another. It also doubles as a plain analysis checkpoint.
/// * `state.bin` — the per-rank training state (binary, little-endian,
///   magic `SAGIPSR2`).
///
/// Writes are atomic: the directory is assembled under a dot-prefixed
/// temporary name and `rename`d into place, so a crash mid-write never
/// leaves a checkpoint that [`TrainCheckpoint::list`] would offer for
/// resume.
#[derive(Clone, Debug, PartialEq)]
pub struct TrainCheckpoint {
    /// Last completed epoch (0-based).
    pub epoch: u64,
    /// Accumulated training seconds when the checkpoint was taken (resume
    /// continues the clock from here, keeping residual-curve timestamps
    /// monotone across the restart).
    pub elapsed_s: f64,
    /// Base RNG seed of the run that wrote the checkpoint. Resuming under
    /// a different seed would regenerate a different data pool and
    /// per-rank shards while restoring the old parameters/RNG streams —
    /// silently breaking the bit-identical contract — so the restore path
    /// rejects a mismatch.
    pub seed: u64,
    pub scenario: String,
    /// One entry per rank, sorted by rank.
    pub ranks: Vec<RankTrainState>,
}

const STATE_MAGIC: &[u8; 8] = b"SAGIPSR2";

impl TrainCheckpoint {
    /// Directory name of the checkpoint for `epoch` (zero-padded so
    /// lexicographic order is epoch order).
    pub fn dir_name(epoch: u64) -> String {
        format!("run_e{epoch:010}")
    }

    /// Write atomically into `<dir>/run_e<epoch>/`. Returns the final
    /// checkpoint directory path.
    pub fn save(&self, dir: &Path) -> Result<PathBuf> {
        if self.ranks.is_empty() {
            return Err(Error::Checkpoint("cannot save an empty run checkpoint".into()));
        }
        std::fs::create_dir_all(dir)?;
        let final_dir = dir.join(Self::dir_name(self.epoch));
        let tmp = dir.join(format!(
            ".tmp_{}_{}",
            Self::dir_name(self.epoch),
            std::process::id()
        ));
        if tmp.exists() {
            std::fs::remove_dir_all(&tmp)?;
        }
        std::fs::create_dir_all(&tmp)?;
        // Scenario-identity sidecar: rank 0's generator as a standard
        // analysis checkpoint.
        Checkpoint {
            rank: 0,
            epoch: self.epoch,
            elapsed_s: self.elapsed_s,
            scenario: self.scenario.clone(),
            gen_params: self.ranks[0].gen.clone(),
        }
        .save(&tmp)?;
        // Per-rank training state.
        let mut buf = Vec::new();
        buf.extend_from_slice(STATE_MAGIC);
        buf.extend_from_slice(&self.epoch.to_le_bytes());
        buf.extend_from_slice(&self.elapsed_s.to_le_bytes());
        buf.extend_from_slice(&self.seed.to_le_bytes());
        buf.extend_from_slice(&(self.ranks.len() as u64).to_le_bytes());
        for rs in &self.ranks {
            buf.extend_from_slice(&(rs.rank as u64).to_le_bytes());
            buf.extend_from_slice(&rs.gen_t.to_le_bytes());
            buf.extend_from_slice(&rs.disc_t.to_le_bytes());
            buf.extend_from_slice(&rs.rng.to_bytes());
            for v in [&rs.gen, &rs.disc, &rs.gen_m, &rs.gen_v, &rs.disc_m, &rs.disc_v] {
                write_f32_section(&mut buf, v);
            }
        }
        std::fs::write(tmp.join("state.bin"), &buf)?;
        // Atomic publish: a reader either sees the complete directory or
        // nothing. Re-saving the same epoch replaces the old copy.
        if final_dir.exists() {
            std::fs::remove_dir_all(&final_dir)?;
        }
        std::fs::rename(&tmp, &final_dir)?;
        Ok(final_dir)
    }

    /// Load a run checkpoint *for a specific scenario*. `path` is either a
    /// `run_e*` checkpoint directory or a checkpoint root, in which case
    /// the newest complete checkpoint is used. The scenario guard runs
    /// through [`Checkpoint::load_for_scenario`] on the embedded rank-0
    /// generator sidecar, so a cross-scenario resume is refused with the
    /// same clear error as any other cross-scenario restore.
    pub fn load_for_scenario(path: &Path, scenario: &str) -> Result<TrainCheckpoint> {
        let dir = Self::resolve(path)?;
        let sidecars = Checkpoint::list(&dir)?;
        let sidecar = sidecars.first().ok_or_else(|| {
            Error::Checkpoint(format!(
                "{}: no generator sidecar checkpoint in run checkpoint",
                dir.display()
            ))
        })?;
        let gen_ck = Checkpoint::load_for_scenario(sidecar, scenario)?;
        let tc = Self::read_state(&dir.join("state.bin"), gen_ck.scenario.clone())?;
        // Cross-checks: the sidecar and the state file were written
        // together; disagreement means a corrupt or hand-edited directory.
        if tc.epoch != gen_ck.epoch || tc.ranks[0].gen != gen_ck.gen_params {
            return Err(Error::Checkpoint(format!(
                "{}: state.bin disagrees with the generator sidecar — \
                 checkpoint is corrupt",
                dir.display()
            )));
        }
        Ok(tc)
    }

    /// All complete run checkpoints under `dir`, sorted oldest → newest.
    pub fn list(dir: &Path) -> Result<Vec<PathBuf>> {
        let mut out = Vec::new();
        if !dir.exists() {
            return Ok(out);
        }
        for entry in std::fs::read_dir(dir)? {
            let p = entry?.path();
            let is_run = p
                .file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.starts_with("run_e"));
            // Only complete checkpoints count: the atomic rename publishes
            // state.bin together with the sidecar or not at all.
            if is_run && p.join("state.bin").exists() {
                out.push(p);
            }
        }
        out.sort();
        Ok(out)
    }

    /// The newest complete run checkpoint under `dir`, if any.
    pub fn latest(dir: &Path) -> Result<Option<PathBuf>> {
        Ok(Self::list(dir)?.pop())
    }

    /// Retain only the newest `keep` checkpoints; returns how many were
    /// removed. Abandoned temporary directories from a crashed writer
    /// (`.tmp_run_e*`) are cleaned up too.
    pub fn prune(dir: &Path, keep: usize) -> Result<usize> {
        let runs = Self::list(dir)?;
        let mut removed = 0;
        if runs.len() > keep {
            for p in &runs[..runs.len() - keep] {
                // Tolerate a concurrent pruner having won the race.
                match std::fs::remove_dir_all(p) {
                    Ok(()) => removed += 1,
                    Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
                    Err(e) => return Err(e.into()),
                }
            }
        }
        if dir.exists() {
            for entry in std::fs::read_dir(dir)? {
                let p = entry?.path();
                if p.file_name()
                    .and_then(|n| n.to_str())
                    .is_some_and(|n| n.starts_with(".tmp_run_e"))
                {
                    std::fs::remove_dir_all(&p).ok();
                }
            }
        }
        Ok(removed)
    }

    fn resolve(path: &Path) -> Result<PathBuf> {
        if path.join("state.bin").exists() {
            return Ok(path.to_path_buf());
        }
        Self::latest(path)?.ok_or_else(|| {
            Error::Checkpoint(format!(
                "{}: no run checkpoints found (expected a run_e* directory \
                 or a checkpoint root containing one)",
                path.display()
            ))
        })
    }

    fn read_state(path: &Path, scenario: String) -> Result<TrainCheckpoint> {
        let bytes = std::fs::read(path)
            .map_err(|e| Error::Checkpoint(format!("{}: {e}", path.display())))?;
        let mut r = ByteReader::new(&bytes, path);
        let magic = r.take(8)?;
        if magic != STATE_MAGIC {
            return Err(r.corrupt("bad magic"));
        }
        let epoch = r.u64()?;
        let elapsed_s = f64::from_bits(r.u64()?);
        let seed = r.u64()?;
        let n_ranks = r.u64()? as usize;
        if n_ranks == 0 || n_ranks > 1 << 20 {
            return Err(r.corrupt("implausible rank count"));
        }
        let mut ranks = Vec::with_capacity(n_ranks);
        for _ in 0..n_ranks {
            let rank = r.u64()? as usize;
            let gen_t = r.u64()?;
            let disc_t = r.u64()?;
            let rng_bytes: [u8; RNG_SNAPSHOT_BYTES] = r
                .take(RNG_SNAPSHOT_BYTES)?
                .try_into()
                .expect("take returns the requested length");
            let rng = RngSnapshot::from_bytes(&rng_bytes);
            let gen = r.f32_section()?;
            let disc = r.f32_section()?;
            let gen_m = r.f32_section()?;
            let gen_v = r.f32_section()?;
            let disc_m = r.f32_section()?;
            let disc_v = r.f32_section()?;
            ranks.push(RankTrainState {
                rank,
                gen,
                disc,
                gen_m,
                gen_v,
                gen_t,
                disc_m,
                disc_v,
                disc_t,
                rng,
            });
        }
        ranks.sort_by_key(|rs| rs.rank);
        Ok(TrainCheckpoint {
            epoch,
            elapsed_s,
            seed,
            scenario,
            ranks,
        })
    }
}

fn write_f32_section(buf: &mut Vec<u8>, v: &[f32]) {
    buf.extend_from_slice(&(v.len() as u64).to_le_bytes());
    for x in v {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

/// Bounds-checked little-endian reader with path-qualified errors.
struct ByteReader<'a> {
    bytes: &'a [u8],
    pos: usize,
    path: &'a Path,
}

impl<'a> ByteReader<'a> {
    fn new(bytes: &'a [u8], path: &'a Path) -> Self {
        ByteReader { bytes, pos: 0, path }
    }

    fn corrupt(&self, msg: &str) -> Error {
        Error::Checkpoint(format!("{}: {msg} (at byte {})", self.path.display(), self.pos))
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.bytes.len() {
            return Err(self.corrupt("truncated"));
        }
        let s = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes(b.try_into().unwrap()))
    }

    fn f32_section(&mut self) -> Result<Vec<f32>> {
        let n = self.u64()? as usize;
        if n > 1 << 31 {
            return Err(self.corrupt("implausible section length"));
        }
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join(format!("sagips_ckpt_{}", std::process::id()));
        let ck = Checkpoint {
            rank: 3,
            epoch: 5000,
            elapsed_s: 12.5,
            scenario: "deconv".into(),
            gen_params: (0..100).map(|i| i as f32 * 0.25 - 10.0).collect(),
        };
        let path = ck.save(&dir).unwrap();
        let loaded = Checkpoint::load(&path).unwrap();
        assert_eq!(loaded, ck);
        let listed = Checkpoint::list(&dir).unwrap();
        assert_eq!(listed, vec![path]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn restore_under_wrong_scenario_fails_with_clear_error() {
        let dir =
            std::env::temp_dir().join(format!("sagips_ckpt_scen_{}", std::process::id()));
        let ck = Checkpoint {
            rank: 0,
            epoch: 10,
            elapsed_s: 1.0,
            scenario: "saturation".into(),
            gen_params: vec![1.0, 2.0, 3.0],
        };
        let path = ck.save(&dir).unwrap();
        // Matching scenario restores fine.
        let ok = Checkpoint::load_for_scenario(&path, "saturation").unwrap();
        assert_eq!(ok, ck);
        // Mismatch is refused, naming both scenarios.
        let err = Checkpoint::load_for_scenario(&path, "quantile")
            .unwrap_err()
            .to_string();
        assert!(err.contains("saturation") && err.contains("quantile"), "{err}");
        assert!(err.contains("refusing"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sidecar_without_scenario_defaults_to_quantile() {
        // Back-compat: checkpoints written before the scenario subsystem.
        let dir =
            std::env::temp_dir().join(format!("sagips_ckpt_old_{}", std::process::id()));
        let ck = Checkpoint {
            rank: 1,
            epoch: 2,
            elapsed_s: 0.5,
            scenario: "quantile".into(),
            gen_params: vec![0.5; 4],
        };
        let path = ck.save(&dir).unwrap();
        // Rewrite the sidecar without the scenario key, as an old writer
        // would have produced it.
        let meta_path = path.with_extension("json");
        std::fs::write(
            &meta_path,
            r#"{"rank": 1, "epoch": 2, "elapsed_s": 0.5, "params": 4}"#,
        )
        .unwrap();
        let loaded = Checkpoint::load(&path).unwrap();
        assert_eq!(loaded.scenario, "quantile");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = std::env::temp_dir().join(format!("sagips_ckpt_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("ckpt_r0_e0.bin");
        std::fs::write(&p, b"NOTSAGIPS-GARBAGE").unwrap();
        assert!(Checkpoint::load(&p).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn list_empty_or_missing_dir() {
        let dir = std::env::temp_dir().join("sagips_ckpt_definitely_missing");
        assert!(Checkpoint::list(&dir).unwrap().is_empty());
    }

    fn rank_state(rank: usize, fill: f32) -> RankTrainState {
        let mut rng = crate::util::rng::Rng::new(rank as u64 + 1);
        rng.normal(); // cache a Box-Muller spare so it roundtrips too
        RankTrainState {
            rank,
            gen: vec![fill; 8],
            disc: vec![fill + 1.0; 5],
            gen_m: vec![0.1 * fill; 8],
            gen_v: vec![0.2 * fill; 8],
            gen_t: 17,
            disc_m: vec![0.3 * fill; 5],
            disc_v: vec![0.4 * fill; 5],
            disc_t: 23,
            rng: rng.snapshot(),
        }
    }

    fn train_ck(epoch: u64, scenario: &str, ranks: usize) -> TrainCheckpoint {
        TrainCheckpoint {
            epoch,
            elapsed_s: 3.25,
            seed: 20240,
            scenario: scenario.into(),
            ranks: (0..ranks).map(|r| rank_state(r, r as f32 + 0.5)).collect(),
        }
    }

    #[test]
    fn train_checkpoint_roundtrip_and_latest() {
        let dir = std::env::temp_dir().join(format!("sagips_runck_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let a = train_ck(9, "deconv", 3);
        let pa = a.save(&dir).unwrap();
        let b = train_ck(19, "deconv", 3);
        let pb = b.save(&dir).unwrap();
        assert_eq!(TrainCheckpoint::list(&dir).unwrap(), vec![pa.clone(), pb.clone()]);
        assert_eq!(TrainCheckpoint::latest(&dir).unwrap(), Some(pb.clone()));
        // Load from the root picks the newest; from an explicit directory
        // picks that one.
        let latest = TrainCheckpoint::load_for_scenario(&dir, "deconv").unwrap();
        assert_eq!(latest, b);
        let older = TrainCheckpoint::load_for_scenario(&pa, "deconv").unwrap();
        assert_eq!(older, a);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn train_checkpoint_refuses_wrong_scenario_through_the_guard() {
        let dir =
            std::env::temp_dir().join(format!("sagips_runck_sc_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        train_ck(4, "saturation", 2).save(&dir).unwrap();
        let err = TrainCheckpoint::load_for_scenario(&dir, "quantile")
            .unwrap_err()
            .to_string();
        // The error comes from Checkpoint::load_for_scenario and names
        // both scenarios.
        assert!(err.contains("saturation") && err.contains("quantile"), "{err}");
        assert!(err.contains("refusing"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn train_checkpoint_prune_keeps_newest_and_clears_tmp() {
        let dir =
            std::env::temp_dir().join(format!("sagips_runck_pr_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        for e in [5u64, 15, 25, 35] {
            train_ck(e, "quantile", 1).save(&dir).unwrap();
        }
        // A crashed writer's leftover temporary directory.
        std::fs::create_dir_all(dir.join(".tmp_run_e0000000045_1")).unwrap();
        let removed = TrainCheckpoint::prune(&dir, 2).unwrap();
        assert_eq!(removed, 2);
        let left = TrainCheckpoint::list(&dir).unwrap();
        assert_eq!(left.len(), 2);
        assert!(left[0].ends_with(TrainCheckpoint::dir_name(25)));
        assert!(left[1].ends_with(TrainCheckpoint::dir_name(35)));
        assert!(!dir.join(".tmp_run_e0000000045_1").exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn incomplete_checkpoint_directories_are_invisible() {
        // A run_e* directory without state.bin (e.g. a partially deleted
        // checkpoint) must not be offered for resume.
        let dir =
            std::env::temp_dir().join(format!("sagips_runck_inc_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(dir.join("run_e0000000007")).unwrap();
        assert!(TrainCheckpoint::list(&dir).unwrap().is_empty());
        let err = TrainCheckpoint::load_for_scenario(&dir, "quantile")
            .unwrap_err()
            .to_string();
        assert!(err.contains("no run checkpoints"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_state_file_is_rejected() {
        let dir =
            std::env::temp_dir().join(format!("sagips_runck_bad_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let p = train_ck(3, "quantile", 2).save(&dir).unwrap();
        // Truncate the state file: load must fail cleanly.
        let state = std::fs::read(p.join("state.bin")).unwrap();
        std::fs::write(p.join("state.bin"), &state[..state.len() / 2]).unwrap();
        assert!(TrainCheckpoint::load_for_scenario(&p, "quantile").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn series_records_in_order() {
        let mut s = CheckpointSeries::default();
        assert!(s.is_empty());
        s.record(0, 0, 0.0, "quantile", &[1.0]);
        s.record(0, 25, 1.0, "quantile", &[2.0]);
        assert_eq!(s.len(), 2);
        assert_eq!(s.checkpoints[1].gen_params, vec![2.0]);
        assert_eq!(s.checkpoints[0].scenario, "quantile");
    }
}
