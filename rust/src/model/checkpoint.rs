//! Generator checkpoints with timestamps.
//!
//! The paper's convergence analysis is *post-training*: generator states
//! are stored "at the first epoch and every other 5 k epochs (resulting in
//! 21 generator checkpoints)" together with time stamps, and residual
//! curves are computed afterwards from the checkpoints (Sec. VI-C2). This
//! module stores exactly that: flat f32 parameters (little-endian binary)
//! plus a JSON sidecar with epoch and elapsed seconds.

use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use crate::util::error::{Error, Result};
use crate::util::json::{self, Value};

/// One stored generator state.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub rank: usize,
    pub epoch: u64,
    /// Seconds of accumulated training time when the checkpoint was taken.
    pub elapsed_s: f64,
    /// Scenario the generator was trained on. Restoring under a different
    /// scenario is refused ([`Checkpoint::load_for_scenario`]): the flat
    /// parameters would silently parameterize the wrong forward operator.
    pub scenario: String,
    pub gen_params: Vec<f32>,
}

const MAGIC: &[u8; 8] = b"SAGIPS01";

impl Checkpoint {
    /// Serialize to `<dir>/ckpt_r<rank>_e<epoch>.bin` (+ `.json` meta).
    pub fn save(&self, dir: &Path) -> Result<PathBuf> {
        std::fs::create_dir_all(dir)?;
        let stem = format!("ckpt_r{}_e{}", self.rank, self.epoch);
        let bin_path = dir.join(format!("{stem}.bin"));
        let mut f = std::fs::File::create(&bin_path)?;
        f.write_all(MAGIC)?;
        f.write_all(&(self.gen_params.len() as u64).to_le_bytes())?;
        // Params as raw little-endian f32.
        let mut bytes = Vec::with_capacity(self.gen_params.len() * 4);
        for v in &self.gen_params {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        f.write_all(&bytes)?;
        let meta = json::obj(vec![
            ("rank", json::num(self.rank as f64)),
            ("epoch", json::num(self.epoch as f64)),
            ("elapsed_s", json::num(self.elapsed_s)),
            ("scenario", json::s(self.scenario.clone())),
            ("params", json::num(self.gen_params.len() as f64)),
        ]);
        std::fs::write(dir.join(format!("{stem}.json")), meta.to_json_pretty())?;
        Ok(bin_path)
    }

    /// Load from a `.bin` path written by [`Checkpoint::save`], without
    /// checking what the generator was trained on — callers restoring
    /// parameters into a run must use [`Checkpoint::load_for_scenario`]
    /// so a cross-scenario restore is refused instead of diverging.
    pub fn load(bin_path: &Path) -> Result<Checkpoint> {
        let mut f = std::fs::File::open(bin_path)?;
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            return Err(Error::Checkpoint(format!(
                "{}: bad magic",
                bin_path.display()
            )));
        }
        let mut len_bytes = [0u8; 8];
        f.read_exact(&mut len_bytes)?;
        let n = u64::from_le_bytes(len_bytes) as usize;
        let mut raw = vec![0u8; n * 4];
        f.read_exact(&mut raw)?;
        let gen_params: Vec<f32> = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        // Sidecar meta.
        let meta_path = bin_path.with_extension("json");
        let meta_text = std::fs::read_to_string(&meta_path)
            .map_err(|e| Error::Checkpoint(format!("{}: {e}", meta_path.display())))?;
        let meta = Value::parse(&meta_text)?;
        Ok(Checkpoint {
            rank: meta.req_usize("rank")?,
            epoch: meta.req_usize("epoch")? as u64,
            elapsed_s: meta
                .req("elapsed_s")?
                .as_f64()
                .ok_or_else(|| Error::Checkpoint("elapsed_s not a number".into()))?,
            // Checkpoints from before the scenario subsystem carry no
            // scenario key; they were all trained on the proxy app.
            scenario: meta
                .get("scenario")
                .and_then(|s| s.as_str())
                .unwrap_or("quantile")
                .to_string(),
            gen_params,
        })
    }

    /// Load a checkpoint *for a specific scenario*: restoring a generator
    /// under a different scenario than it was trained on is refused with
    /// a clear error instead of silently diverging on the wrong forward
    /// operator.
    pub fn load_for_scenario(bin_path: &Path, scenario: &str) -> Result<Checkpoint> {
        let ck = Self::load(bin_path)?;
        // Case-insensitive, like every other scenario entry point
        // (scenario::lookup canonicalizes user-cased names).
        if !ck.scenario.eq_ignore_ascii_case(scenario) {
            return Err(Error::Checkpoint(format!(
                "{}: trained on scenario '{}' but the run is configured \
                 for '{scenario}' — refusing to restore (pass the matching \
                 --scenario to resume this checkpoint)",
                bin_path.display(),
                ck.scenario
            )));
        }
        Ok(ck)
    }

    /// List all checkpoints in a directory, sorted by (rank, epoch).
    pub fn list(dir: &Path) -> Result<Vec<PathBuf>> {
        let mut out = Vec::new();
        if !dir.exists() {
            return Ok(out);
        }
        for entry in std::fs::read_dir(dir)? {
            let p = entry?.path();
            if p.extension().is_some_and(|e| e == "bin")
                && p.file_name()
                    .and_then(|n| n.to_str())
                    .is_some_and(|n| n.starts_with("ckpt_"))
            {
                out.push(p);
            }
        }
        out.sort();
        Ok(out)
    }
}

/// In-memory checkpoint series for one rank (used when the analysis runs
/// in the same process and disk round-trips are unnecessary).
#[derive(Clone, Debug, Default)]
pub struct CheckpointSeries {
    pub checkpoints: Vec<Checkpoint>,
}

impl CheckpointSeries {
    pub fn record(
        &mut self,
        rank: usize,
        epoch: u64,
        elapsed_s: f64,
        scenario: &str,
        gen_params: &[f32],
    ) {
        self.checkpoints.push(Checkpoint {
            rank,
            epoch,
            elapsed_s,
            scenario: scenario.to_string(),
            gen_params: gen_params.to_vec(),
        });
    }

    pub fn len(&self) -> usize {
        self.checkpoints.len()
    }

    pub fn is_empty(&self) -> bool {
        self.checkpoints.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join(format!("sagips_ckpt_{}", std::process::id()));
        let ck = Checkpoint {
            rank: 3,
            epoch: 5000,
            elapsed_s: 12.5,
            scenario: "deconv".into(),
            gen_params: (0..100).map(|i| i as f32 * 0.25 - 10.0).collect(),
        };
        let path = ck.save(&dir).unwrap();
        let loaded = Checkpoint::load(&path).unwrap();
        assert_eq!(loaded, ck);
        let listed = Checkpoint::list(&dir).unwrap();
        assert_eq!(listed, vec![path]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn restore_under_wrong_scenario_fails_with_clear_error() {
        let dir =
            std::env::temp_dir().join(format!("sagips_ckpt_scen_{}", std::process::id()));
        let ck = Checkpoint {
            rank: 0,
            epoch: 10,
            elapsed_s: 1.0,
            scenario: "saturation".into(),
            gen_params: vec![1.0, 2.0, 3.0],
        };
        let path = ck.save(&dir).unwrap();
        // Matching scenario restores fine.
        let ok = Checkpoint::load_for_scenario(&path, "saturation").unwrap();
        assert_eq!(ok, ck);
        // Mismatch is refused, naming both scenarios.
        let err = Checkpoint::load_for_scenario(&path, "quantile")
            .unwrap_err()
            .to_string();
        assert!(err.contains("saturation") && err.contains("quantile"), "{err}");
        assert!(err.contains("refusing"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sidecar_without_scenario_defaults_to_quantile() {
        // Back-compat: checkpoints written before the scenario subsystem.
        let dir =
            std::env::temp_dir().join(format!("sagips_ckpt_old_{}", std::process::id()));
        let ck = Checkpoint {
            rank: 1,
            epoch: 2,
            elapsed_s: 0.5,
            scenario: "quantile".into(),
            gen_params: vec![0.5; 4],
        };
        let path = ck.save(&dir).unwrap();
        // Rewrite the sidecar without the scenario key, as an old writer
        // would have produced it.
        let meta_path = path.with_extension("json");
        std::fs::write(
            &meta_path,
            r#"{"rank": 1, "epoch": 2, "elapsed_s": 0.5, "params": 4}"#,
        )
        .unwrap();
        let loaded = Checkpoint::load(&path).unwrap();
        assert_eq!(loaded.scenario, "quantile");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = std::env::temp_dir().join(format!("sagips_ckpt_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("ckpt_r0_e0.bin");
        std::fs::write(&p, b"NOTSAGIPS-GARBAGE").unwrap();
        assert!(Checkpoint::load(&p).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn list_empty_or_missing_dir() {
        let dir = std::env::temp_dir().join("sagips_ckpt_definitely_missing");
        assert!(Checkpoint::list(&dir).unwrap().is_empty());
    }

    #[test]
    fn series_records_in_order() {
        let mut s = CheckpointSeries::default();
        assert!(s.is_empty());
        s.record(0, 0, 0.0, "quantile", &[1.0]);
        s.record(0, 25, 1.0, "quantile", &[2.0]);
        assert_eq!(s.len(), 2);
        assert_eq!(s.checkpoints[1].gen_params, vec![2.0]);
        assert_eq!(s.checkpoints[0].scenario, "quantile");
    }
}
