//! Pure-Rust reference implementation of the forward computations.
//!
//! A second, independent implementation of the generator forward pass and
//! the quantile pipeline, used to cross-check the HLO artifacts end to end
//! (Rust reference vs Python-lowered XLA execution) and to run
//! artifact-free unit tests of the residual/ensemble machinery.

use crate::runtime::manifest::LayerLayout;

/// LeakyReLU.
pub fn leaky_relu(x: f32, slope: f32) -> f32 {
    if x >= 0.0 {
        x
    } else {
        slope * x
    }
}

/// Forward an MLP over flat params: `x` is (batch, d_in) row-major; returns
/// (batch, d_out). Hidden layers use LeakyReLU, the last layer is linear —
/// matching `python/compile/nets.py`.
pub fn mlp_forward(
    flat: &[f32],
    layout: &[LayerLayout],
    x: &[f32],
    batch: usize,
    slope: f32,
) -> Vec<f32> {
    let mut h = x.to_vec();
    let mut h_cols = layout[0].w_rows;
    for (li, layer) in layout.iter().enumerate() {
        debug_assert_eq!(h.len(), batch * layer.w_rows);
        let (rows, cols) = (layer.w_rows, layer.w_cols);
        let w = &flat[layer.w_offset..layer.w_offset + rows * cols];
        let b = &flat[layer.b_offset..layer.b_offset + layer.b_len];
        let activate = li + 1 < layout.len();
        let mut out = vec![0.0f32; batch * cols];
        for r in 0..batch {
            let xin = &h[r * rows..(r + 1) * rows];
            let orow = &mut out[r * cols..(r + 1) * cols];
            orow.copy_from_slice(b);
            for (i, &xi) in xin.iter().enumerate() {
                if xi == 0.0 {
                    continue;
                }
                let wrow = &w[i * cols..(i + 1) * cols];
                for (o, &wv) in orow.iter_mut().zip(wrow) {
                    *o += xi * wv;
                }
            }
            if activate {
                for o in orow.iter_mut() {
                    *o = leaky_relu(*o, slope);
                }
            }
        }
        h = out;
        h_cols = cols;
    }
    debug_assert_eq!(h.len(), batch * h_cols);
    h
}

/// The 1-D proxy quantile: `q(u; a, b, c) = a + b u + c u^2`.
pub fn quantile(u: f32, a: f32, b: f32, c: f32) -> f32 {
    a + b * u + c * u * u
}

/// The environment pipeline: params (B, 6) + uniforms (B, E, 2) -> events
/// ((B*E), 2) flat, identical to `python/compile/pipeline.py`.
pub fn pipeline(params: &[f32], u: &[f32], batch: usize, events: usize) -> Vec<f32> {
    debug_assert_eq!(params.len(), batch * 6);
    debug_assert_eq!(u.len(), batch * events * 2);
    let mut out = vec![0.0f32; batch * events * 2];
    for bi in 0..batch {
        let p = &params[bi * 6..bi * 6 + 6];
        for e in 0..events {
            let idx = (bi * events + e) * 2;
            out[idx] = quantile(u[idx], p[0], p[1], p[2]);
            out[idx + 1] = quantile(u[idx + 1], p[3], p[4], p[5]);
        }
    }
    out
}

/// Closed-form mean of the quantile distribution: E[y] = a + b/2 + c/3 for
/// u ~ U(0,1) (used by data-sanity tests).
pub fn quantile_mean(a: f32, b: f32, c: f32) -> f32 {
    a + b / 2.0 + c / 3.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::LayerLayout;

    #[test]
    fn quantile_evaluates_polynomial() {
        assert_eq!(quantile(0.0, 1.0, 2.0, 3.0), 1.0);
        assert_eq!(quantile(1.0, 1.0, 2.0, 3.0), 6.0);
        assert_eq!(quantile(0.5, 0.0, 2.0, 4.0), 2.0);
    }

    #[test]
    fn pipeline_layout_is_event_major() {
        let params = [1.0, 0.0, 0.0, 2.0, 0.0, 0.0, /* row 2 */ 3.0, 0.0, 0.0, 4.0, 0.0, 0.0];
        let u = [0.5f32; 2 * 2 * 2];
        let ev = pipeline(&params, &u, 2, 2);
        assert_eq!(ev, vec![1.0, 2.0, 1.0, 2.0, 3.0, 4.0, 3.0, 4.0]);
    }

    #[test]
    fn mlp_identity_layer() {
        // One linear layer with identity weights reproduces the input.
        let layout = vec![LayerLayout {
            w_offset: 0,
            w_rows: 2,
            w_cols: 2,
            b_offset: 4,
            b_len: 2,
        }];
        let flat = vec![1.0, 0.0, 0.0, 1.0, 0.5, -0.5];
        let x = vec![3.0, 4.0, -1.0, 2.0];
        let y = mlp_forward(&flat, &layout, &x, 2, 0.2);
        assert_eq!(y, vec![3.5, 3.5, -0.5, 1.5]);
    }

    #[test]
    fn mlp_hidden_layer_applies_leaky_relu() {
        // 1 -> 1 -> 1 with w=1, b=0 twice; input -2 passes the hidden
        // LeakyReLU (slope 0.5): -2 -> -1 -> -1 (output layer linear).
        let layout = vec![
            LayerLayout {
                w_offset: 0,
                w_rows: 1,
                w_cols: 1,
                b_offset: 1,
                b_len: 1,
            },
            LayerLayout {
                w_offset: 2,
                w_rows: 1,
                w_cols: 1,
                b_offset: 3,
                b_len: 1,
            },
        ];
        let flat = vec![1.0, 0.0, 1.0, 0.0];
        let y = mlp_forward(&flat, &layout, &[-2.0], 1, 0.5);
        assert_eq!(y, vec![-1.0]);
    }

    #[test]
    fn quantile_mean_closed_form() {
        let (a, b, c) = (1.0, 0.5, 0.3);
        let n = 200_000;
        let mut rng = crate::util::rng::Rng::new(3);
        let mut s = 0.0f64;
        for _ in 0..n {
            s += quantile(rng.uniform_f32(), a, b, c) as f64;
        }
        assert!((s / n as f64 - quantile_mean(a, b, c) as f64).abs() < 2e-3);
    }
}
