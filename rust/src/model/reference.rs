//! Pure-Rust reference implementation of the forward computations.
//!
//! A second, independent implementation of the generator forward pass and
//! the quantile pipeline. It cross-checks the HLO artifacts end to end
//! (Rust reference vs Python-lowered XLA execution), runs artifact-free
//! unit tests of the residual/ensemble machinery, and is the forward half
//! of the native CPU backend (`runtime::native`; the backward half lives
//! in `model::grad`).
//!
//! The kernels here are written for the hot path: branch-free inner loops
//! over contiguous rows (so the compiler can auto-vectorize), and
//! caller-provided ping-pong scratch buffers instead of per-layer
//! allocation.

use crate::runtime::kernels::Kernels;
use crate::runtime::manifest::LayerLayout;

/// LeakyReLU. Written as a select (not `max`/`min` arithmetic, which
/// would map NaN to 0.0 and mask divergence from the trainer's
/// non-finite-gradient guard): NaN propagates, and the compiler lowers
/// the select to a branch-free SIMD blend in the activation loops.
pub fn leaky_relu(x: f32, slope: f32) -> f32 {
    if x >= 0.0 {
        x
    } else {
        slope * x
    }
}

/// One dense layer over the flat parameter vector: `out = x W + b`, with
/// optional LeakyReLU. `x` is (batch, rows) row-major, `out` (batch, cols);
/// both contiguous. The matmul dispatches through
/// [`crate::runtime::kernels`]; both kernel variants accumulate each
/// output element in the same ascending-k order, so the result is
/// bit-identical across [`Kernels::Scalar`] and [`Kernels::Blocked`].
#[allow(clippy::too_many_arguments)]
pub fn layer_forward(
    flat: &[f32],
    layer: &LayerLayout,
    x: &[f32],
    batch: usize,
    slope: f32,
    activate: bool,
    kernels: Kernels,
    out: &mut [f32],
) {
    let (rows, cols) = (layer.w_rows, layer.w_cols);
    debug_assert_eq!(x.len(), batch * rows);
    debug_assert_eq!(out.len(), batch * cols);
    let w = &flat[layer.w_offset..layer.w_offset + rows * cols];
    let b = &flat[layer.b_offset..layer.b_offset + layer.b_len];
    kernels.matmul_bias(x, w, Some(b), batch, rows, cols, out);
    if activate {
        for o in out.iter_mut() {
            *o = leaky_relu(*o, slope);
        }
    }
}

/// Reusable ping-pong scratch for [`mlp_forward_into`]. Buffers grow on
/// demand and stay warm across calls, so steady-state forwards are
/// allocation-free; [`MlpScratch::trim`] caps the high-water mark.
#[derive(Clone, Debug, Default)]
pub struct MlpScratch {
    a: Vec<f32>,
    b: Vec<f32>,
}

impl MlpScratch {
    /// Release excess capacity (see [`trim_vec`]): buffers far above their
    /// last-used size shrink back, everything else keeps its storage.
    pub fn trim(&mut self, floor: usize) {
        trim_vec(&mut self.a, floor);
        trim_vec(&mut self.b, floor);
    }

    /// Total f32 capacity currently held (memory diagnostics).
    pub fn capacity(&self) -> usize {
        self.a.capacity() + self.b.capacity()
    }
}

/// High-water-mark cap for reusable buffers: shrink `v` back to
/// `max(v.len(), floor)` when its capacity exceeds 4x that bound. The 4x
/// hysteresis keeps steady-state workloads (sizes oscillating within a
/// small band) allocation-free, while a one-off large run — e.g. one big
/// scenario in a long multi-scenario process — no longer pins its peak
/// footprint forever.
pub(crate) fn trim_vec(v: &mut Vec<f32>, floor: usize) {
    let want = v.len().max(floor);
    if v.capacity() > 4 * want {
        v.shrink_to(want);
    }
}

/// Forward an MLP over flat params into a caller-provided output buffer:
/// `x` is (batch, d_in) row-major; `out` is resized to (batch, d_out).
/// Hidden layers use LeakyReLU, the last layer is linear — matching
/// `python/compile/nets.py`. Intermediate activations ping-pong through
/// `scratch` — no per-layer allocation.
#[allow(clippy::too_many_arguments)]
pub fn mlp_forward_into(
    flat: &[f32],
    layout: &[LayerLayout],
    x: &[f32],
    batch: usize,
    slope: f32,
    kernels: Kernels,
    scratch: &mut MlpScratch,
    out: &mut Vec<f32>,
) {
    let nl = layout.len();
    debug_assert!(nl > 0);
    debug_assert_eq!(x.len(), batch * layout[0].w_rows);
    // Single layer: straight into `out`.
    if nl == 1 {
        fit(out, batch * layout[0].w_cols);
        layer_forward(flat, &layout[0], x, batch, slope, false, kernels, out);
        return;
    }
    // Hidden layers ping-pong between the two scratch buffers; the last
    // layer writes `out`.
    let (mut cur, mut next) = (&mut scratch.a, &mut scratch.b);
    for (li, layer) in layout.iter().enumerate() {
        let last = li + 1 == nl;
        let dst: &mut Vec<f32> = if last { &mut *out } else { &mut *next };
        fit(dst, batch * layer.w_cols);
        let input: &[f32] = if li == 0 { x } else { cur.as_slice() };
        layer_forward(flat, layer, input, batch, slope, !last, kernels, dst);
        if !last {
            std::mem::swap(&mut cur, &mut next);
        }
    }
}

/// Owned-result convenience wrapper around [`mlp_forward_into`] (blocked
/// kernels — the default execution path).
pub fn mlp_forward(
    flat: &[f32],
    layout: &[LayerLayout],
    x: &[f32],
    batch: usize,
    slope: f32,
) -> Vec<f32> {
    let mut scratch = MlpScratch::default();
    let mut out = Vec::new();
    mlp_forward_into(
        flat,
        layout,
        x,
        batch,
        slope,
        Kernels::default(),
        &mut scratch,
        &mut out,
    );
    out
}

/// Resize a reusable buffer to `len` zeros without shrinking capacity.
pub(crate) fn fit(v: &mut Vec<f32>, len: usize) {
    v.clear();
    v.resize(len, 0.0);
}

/// The 1-D proxy quantile: `q(u; a, b, c) = a + b u + c u^2`.
pub fn quantile(u: f32, a: f32, b: f32, c: f32) -> f32 {
    a + b * u + c * u * u
}

/// The environment pipeline into a caller-provided buffer: params (B, 6) +
/// uniforms (B, E, 2) -> events ((B*E), 2) flat, identical to
/// `python/compile/pipeline.py`.
pub fn pipeline_into(params: &[f32], u: &[f32], batch: usize, events: usize, out: &mut Vec<f32>) {
    debug_assert_eq!(params.len(), batch * 6);
    debug_assert_eq!(u.len(), batch * events * 2);
    fit(out, batch * events * 2);
    for bi in 0..batch {
        let p = &params[bi * 6..bi * 6 + 6];
        for e in 0..events {
            let idx = (bi * events + e) * 2;
            out[idx] = quantile(u[idx], p[0], p[1], p[2]);
            out[idx + 1] = quantile(u[idx + 1], p[3], p[4], p[5]);
        }
    }
}

/// Owned-result convenience wrapper around [`pipeline_into`].
pub fn pipeline(params: &[f32], u: &[f32], batch: usize, events: usize) -> Vec<f32> {
    let mut out = Vec::new();
    pipeline_into(params, u, batch, events, &mut out);
    out
}

/// Closed-form mean of the quantile distribution: E[y] = a + b/2 + c/3 for
/// u ~ U(0,1) (used by data-sanity tests).
pub fn quantile_mean(a: f32, b: f32, c: f32) -> f32 {
    a + b / 2.0 + c / 3.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::LayerLayout;

    #[test]
    fn quantile_evaluates_polynomial() {
        assert_eq!(quantile(0.0, 1.0, 2.0, 3.0), 1.0);
        assert_eq!(quantile(1.0, 1.0, 2.0, 3.0), 6.0);
        assert_eq!(quantile(0.5, 0.0, 2.0, 4.0), 2.0);
    }

    #[test]
    fn pipeline_layout_is_event_major() {
        let params = [1.0, 0.0, 0.0, 2.0, 0.0, 0.0, /* row 2 */ 3.0, 0.0, 0.0, 4.0, 0.0, 0.0];
        let u = [0.5f32; 2 * 2 * 2];
        let ev = pipeline(&params, &u, 2, 2);
        assert_eq!(ev, vec![1.0, 2.0, 1.0, 2.0, 3.0, 4.0, 3.0, 4.0]);
    }

    #[test]
    fn mlp_identity_layer() {
        // One linear layer with identity weights reproduces the input.
        let layout = vec![LayerLayout {
            w_offset: 0,
            w_rows: 2,
            w_cols: 2,
            b_offset: 4,
            b_len: 2,
        }];
        let flat = vec![1.0, 0.0, 0.0, 1.0, 0.5, -0.5];
        let x = vec![3.0, 4.0, -1.0, 2.0];
        let y = mlp_forward(&flat, &layout, &x, 2, 0.2);
        assert_eq!(y, vec![3.5, 3.5, -0.5, 1.5]);
    }

    #[test]
    fn mlp_hidden_layer_applies_leaky_relu() {
        // 1 -> 1 -> 1 with w=1, b=0 twice; input -2 passes the hidden
        // LeakyReLU (slope 0.5): -2 -> -1 -> -1 (output layer linear).
        let layout = vec![
            LayerLayout {
                w_offset: 0,
                w_rows: 1,
                w_cols: 1,
                b_offset: 1,
                b_len: 1,
            },
            LayerLayout {
                w_offset: 2,
                w_rows: 1,
                w_cols: 1,
                b_offset: 3,
                b_len: 1,
            },
        ];
        let flat = vec![1.0, 0.0, 1.0, 0.0];
        let y = mlp_forward(&flat, &layout, &[-2.0], 1, 0.5);
        assert_eq!(y, vec![-1.0]);
    }

    #[test]
    fn forward_into_reuses_buffers_across_calls() {
        let layout = vec![
            LayerLayout {
                w_offset: 0,
                w_rows: 2,
                w_cols: 3,
                b_offset: 6,
                b_len: 3,
            },
            LayerLayout {
                w_offset: 9,
                w_rows: 3,
                w_cols: 2,
                b_offset: 15,
                b_len: 2,
            },
        ];
        let flat: Vec<f32> = (0..17).map(|i| (i as f32) * 0.1 - 0.8).collect();
        let x = vec![0.3f32, -0.7, 1.2, 0.4];
        let mut scratch = MlpScratch::default();
        let mut out = Vec::new();
        let kn = Kernels::default();
        mlp_forward_into(&flat, &layout, &x, 2, 0.2, kn, &mut scratch, &mut out);
        let first = out.clone();
        let ptr = out.as_ptr();
        mlp_forward_into(&flat, &layout, &x, 2, 0.2, kn, &mut scratch, &mut out);
        assert_eq!(out, first);
        assert_eq!(out.as_ptr(), ptr, "output buffer must be reused");
        // And the zero-branch removal did not change semantics: explicit
        // zeros in the input are handled like any other value.
        let xz = vec![0.0f32, 0.0, 0.0, 0.0];
        let yz = mlp_forward(&flat, &layout, &xz, 2, 0.2);
        assert_eq!(yz.len(), 4);
        assert!(yz.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn trim_vec_caps_high_water_mark_with_hysteresis() {
        // A buffer sized for a big run then reused small shrinks back...
        let mut v = vec![0.0f32; 100_000];
        v.truncate(64);
        trim_vec(&mut v, 256);
        assert!(v.capacity() <= 100_000 / 4, "capacity {}", v.capacity());
        assert_eq!(v.len(), 64);
        // ...but a warm buffer within the 4x band keeps its storage
        // (steady state stays allocation-free).
        let mut w = vec![0.0f32; 1000];
        w.truncate(300);
        let cap = w.capacity();
        trim_vec(&mut w, 0);
        assert_eq!(w.capacity(), cap);
        // The floor protects small-but-hot buffers from churn.
        let mut s = vec![0.0f32; 1024];
        s.truncate(1);
        let cap = s.capacity();
        trim_vec(&mut s, 4096);
        assert_eq!(s.capacity(), cap);
    }

    #[test]
    fn scalar_and_blocked_layer_forward_are_bit_identical() {
        // The forward numerics contract: kernel choice must not change a
        // single bit (ascending-k accumulation in both variants).
        let layout = LayerLayout {
            w_offset: 0,
            w_rows: 7,
            w_cols: 5,
            b_offset: 35,
            b_len: 5,
        };
        let mut rng = crate::util::rng::Rng::new(17);
        let mut flat = vec![0.0f32; 40];
        rng.fill_normal(&mut flat);
        let mut x = vec![0.0f32; 6 * 7];
        rng.fill_normal(&mut x);
        let mut a = vec![0.0f32; 6 * 5];
        let mut b = vec![0.0f32; 6 * 5];
        layer_forward(&flat, &layout, &x, 6, 0.2, true, Kernels::Scalar, &mut a);
        layer_forward(&flat, &layout, &x, 6, 0.2, true, Kernels::Blocked, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn quantile_mean_closed_form() {
        let (a, b, c) = (1.0, 0.5, 0.3);
        let n = 200_000;
        let mut rng = crate::util::rng::Rng::new(3);
        let mut s = 0.0f64;
        for _ in 0..n {
            s += quantile(rng.uniform_f32(), a, b, c) as f64;
        }
        assert!((s / n as f64 - quantile_mean(a, b, c) as f64).abs() < 2e-3);
    }
}
