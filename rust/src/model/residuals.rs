//! Normalized parameter residuals — eq (6), the paper's convergence
//! metric.
//!
//! `r̂_i = (p_i − p̂_i) / p_i`, where `p̂` is the generator's mean
//! prediction over a fixed batch of noise vectors. The paper found this a
//! far better convergence indicator than the GAN losses (the losses settle
//! while the parameters are still off — Sec. VI).

use crate::runtime::RuntimeHandle;
use crate::util::error::Result;
use crate::util::rng::Rng;

/// Residual evaluator with a *fixed* noise batch (so the metric is
/// comparable across epochs and ranks).
pub struct Residuals {
    handle: RuntimeHandle,
    artifact: String,
    z: Vec<f32>,
    k: usize,
    true_params: Vec<f32>,
}

impl Residuals {
    /// `seed` fixes the evaluation noise batch; all ranks of a run share
    /// it.
    ///
    /// The residual summary is fixed-width (six parameters, like every
    /// registered scenario); a future wider scenario needs this analysis
    /// layer generalized first, so reject it loudly here.
    pub fn new(handle: RuntimeHandle, artifact: &str, seed: u64) -> Result<Residuals> {
        if handle.manifest().true_params.len() != 6 {
            return Err(crate::util::error::Error::Runtime(format!(
                "residual analysis supports 6-parameter scenarios, manifest \
                 scenario '{}' has {}",
                handle.manifest().scenario,
                handle.manifest().true_params.len()
            )));
        }
        let spec = handle.manifest().artifact(artifact)?;
        let k = spec.outputs[0].shape[0];
        let latent = handle.manifest().latent_dim;
        let mut rng = Rng::with_stream(seed, 0xEE51D);
        let mut z = vec![0.0f32; k * latent];
        rng.fill_normal(&mut z);
        Ok(Residuals {
            artifact: artifact.to_string(),
            z,
            k,
            true_params: handle.manifest().true_params.clone(),
            handle,
        })
    }

    /// Generator predictions over the fixed noise batch: (k, 6) flat.
    /// Inputs are borrowed — no parameter or noise clones per evaluation.
    pub fn predict(&self, gen_params: &[f32]) -> Result<Vec<f32>> {
        let mut out = Vec::new();
        self.handle
            .execute_into(&self.artifact, &[gen_params, &self.z], &mut out)?;
        out.into_iter()
            .next()
            .ok_or_else(|| crate::util::error::Error::Runtime(
                "gen_predict returned no outputs".into(),
            ))
    }

    /// Mean prediction per parameter: p̂ (6,).
    pub fn mean_prediction(&self, gen_params: &[f32]) -> Result<[f64; 6]> {
        let preds = self.predict(gen_params)?;
        Ok(mean_per_param(&preds, self.k))
    }

    /// Normalized residuals r̂ (6,) per eq (6).
    pub fn residuals(&self, gen_params: &[f32]) -> Result<[f64; 6]> {
        let p_hat = self.mean_prediction(gen_params)?;
        Ok(normalized_residuals(&self.true_params, &p_hat))
    }

    /// Number of noise vectors in the fixed batch.
    pub fn noise_batch(&self) -> usize {
        self.k
    }
}

/// Column means of a flat (k, 6) prediction matrix.
pub fn mean_per_param(preds: &[f32], k: usize) -> [f64; 6] {
    debug_assert_eq!(preds.len(), k * 6);
    let mut m = [0.0f64; 6];
    for row in preds.chunks(6) {
        for (mi, &v) in m.iter_mut().zip(row) {
            *mi += v as f64;
        }
    }
    for mi in m.iter_mut() {
        *mi /= k as f64;
    }
    m
}

/// eq (6): r̂_i = (p_i − p̂_i) / p_i.
pub fn normalized_residuals(true_params: &[f32], p_hat: &[f64; 6]) -> [f64; 6] {
    let mut r = [0.0f64; 6];
    for i in 0..6 {
        let p = true_params[i] as f64;
        r[i] = (p - p_hat[i]) / p;
    }
    r
}

/// Mean |r̂| over the six parameters (the summary curve of Figs 15/16).
pub fn mean_abs(r: &[f64; 6]) -> f64 {
    r.iter().map(|x| x.abs()).sum::<f64>() / 6.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn residuals_zero_at_truth() {
        let truth = [1.0f32, 0.5, 0.3, -0.5, 1.2, 0.4];
        let p_hat = [1.0f64, 0.5, 0.3, -0.5, 1.2, 0.4];
        // f32 truth vs f64 prediction: agreement to f32 precision.
        let r = normalized_residuals(&truth, &p_hat);
        assert!(r.iter().all(|x| x.abs() < 1e-6));
        assert!(mean_abs(&r) < 1e-6);
    }

    #[test]
    fn residuals_are_normalized() {
        let truth = [2.0f32, 0.5, 0.3, -0.5, 1.2, 0.4];
        let mut p_hat = [2.0f64, 0.5, 0.3, -0.5, 1.2, 0.4];
        p_hat[0] = 1.0; // off by 1 on a parameter of value 2 -> r = 0.5
        let r = normalized_residuals(&truth, &p_hat);
        assert!((r[0] - 0.5).abs() < 1e-12);
        // negative parameter: sign handled by the division
        let mut p_hat2 = p_hat;
        p_hat2[0] = 2.0;
        p_hat2[3] = -1.0; // truth -0.5: r = (-0.5 - -1.0)/-0.5 = -1.0
        let r2 = normalized_residuals(&truth, &p_hat2);
        assert!((r2[3] + 1.0).abs() < 1e-12);
    }

    #[test]
    fn mean_per_param_averages_rows() {
        let preds = vec![
            1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0, //
            3.0, 4.0, 5.0, 6.0, 7.0, 8.0,
        ];
        let m = mean_per_param(&preds, 2);
        assert_eq!(m, [2.0, 3.0, 4.0, 5.0, 6.0, 7.0]);
    }
}
