//! Normalized parameter residuals — eq (6), the paper's convergence
//! metric — for scenarios of **any parameter width**.
//!
//! `r̂_i = (p_i − p̂_i) / p_i`, where `p̂` is the generator's mean
//! prediction over a fixed batch of noise vectors. The paper found this a
//! far better convergence indicator than the GAN losses (the losses settle
//! while the parameters are still off — Sec. VI).
//!
//! Nothing here assumes the proxy app's six parameters: the evaluator
//! takes its width from the manifest's `true_params` (which the scenario
//! registry sizes via `param_dim`), and the free functions operate on
//! slices. A 10-parameter deconvolution run gets exactly the same analysis
//! as the paper's 6-parameter quantile run.
//!
//! # Examples
//!
//! The pure helpers compose into a residual summary at any width — here a
//! 4-parameter problem, no runtime required:
//!
//! ```
//! use sagips::model::residuals::{mean_per_param, mean_abs, normalized_residuals};
//!
//! // Two predictions over a 4-parameter problem (flat (k = 2, p = 4)).
//! let preds = [1.0f32, 2.0, 4.0, 0.5,
//!              3.0,     2.0, 4.0, 0.5];
//! let p_hat = mean_per_param(&preds, 2, 4);
//! assert_eq!(p_hat, vec![2.0, 2.0, 4.0, 0.5]);
//!
//! let truth = [4.0f32, 2.0, 4.0, 0.5];
//! let r = normalized_residuals(&truth, &p_hat);
//! assert_eq!(r.len(), 4);
//! assert_eq!(r[0], 0.5);            // off by 2 on a parameter of 4
//! assert_eq!(mean_abs(&r), 0.125);  // only r0 is nonzero
//! ```

use crate::runtime::RuntimeHandle;
use crate::util::error::Result;
use crate::util::rng::Rng;

/// Residual evaluator with a *fixed* noise batch (so the metric is
/// comparable across epochs and ranks).
pub struct Residuals {
    handle: RuntimeHandle,
    artifact: String,
    z: Vec<f32>,
    k: usize,
    /// Parameter width of the scenario (`true_params.len()`).
    p: usize,
    true_params: Vec<f32>,
}

impl Residuals {
    /// `seed` fixes the evaluation noise batch; all ranks of a run share
    /// it. The parameter width comes from the manifest's ground truth, so
    /// any registered scenario — six parameters or sixty — is analyzed
    /// the same way.
    pub fn new(handle: RuntimeHandle, artifact: &str, seed: u64) -> Result<Residuals> {
        let spec = handle.manifest().artifact(artifact)?;
        let k = spec.outputs[0].shape[0];
        let latent = handle.manifest().latent_dim;
        let mut rng = Rng::with_stream(seed, 0xEE51D);
        let mut z = vec![0.0f32; k * latent];
        rng.fill_normal(&mut z);
        let true_params = handle.manifest().true_params.clone();
        Ok(Residuals {
            artifact: artifact.to_string(),
            z,
            k,
            p: true_params.len(),
            true_params,
            handle,
        })
    }

    /// Generator predictions over the fixed noise batch: (k, p) flat.
    /// Inputs are borrowed — no parameter or noise clones per evaluation.
    pub fn predict(&self, gen_params: &[f32]) -> Result<Vec<f32>> {
        let mut out = Vec::new();
        self.handle
            .execute_into(&self.artifact, &[gen_params, &self.z], &mut out)?;
        out.into_iter()
            .next()
            .ok_or_else(|| crate::util::error::Error::Runtime(
                "gen_predict returned no outputs".into(),
            ))
    }

    /// Mean prediction per parameter: p̂ (p,).
    pub fn mean_prediction(&self, gen_params: &[f32]) -> Result<Vec<f64>> {
        let preds = self.predict(gen_params)?;
        Ok(mean_per_param(&preds, self.k, self.p))
    }

    /// Normalized residuals r̂ (p,) per eq (6).
    pub fn residuals(&self, gen_params: &[f32]) -> Result<Vec<f64>> {
        let p_hat = self.mean_prediction(gen_params)?;
        Ok(normalized_residuals(&self.true_params, &p_hat))
    }

    /// Number of noise vectors in the fixed batch.
    pub fn noise_batch(&self) -> usize {
        self.k
    }

    /// Parameter width of the scenario under analysis.
    pub fn param_dim(&self) -> usize {
        self.p
    }
}

/// Column means of a flat (k, p) prediction matrix.
pub fn mean_per_param(preds: &[f32], k: usize, p: usize) -> Vec<f64> {
    debug_assert_eq!(preds.len(), k * p);
    let mut m = vec![0.0f64; p];
    for row in preds.chunks(p) {
        for (mi, &v) in m.iter_mut().zip(row) {
            *mi += v as f64;
        }
    }
    for mi in m.iter_mut() {
        *mi /= k as f64;
    }
    m
}

/// eq (6): r̂_i = (p_i − p̂_i) / p_i, at whatever width the scenario has.
pub fn normalized_residuals(true_params: &[f32], p_hat: &[f64]) -> Vec<f64> {
    assert_eq!(
        true_params.len(),
        p_hat.len(),
        "residual width mismatch: {} true params vs {} predictions",
        true_params.len(),
        p_hat.len()
    );
    true_params
        .iter()
        .zip(p_hat)
        .map(|(&p, &hat)| {
            let p = p as f64;
            (p - hat) / p
        })
        .collect()
}

/// Mean |r̂| over the parameters (the summary curve of Figs 15/16).
pub fn mean_abs(r: &[f64]) -> f64 {
    if r.is_empty() {
        return 0.0;
    }
    r.iter().map(|x| x.abs()).sum::<f64>() / r.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn residuals_zero_at_truth() {
        let truth = [1.0f32, 0.5, 0.3, -0.5, 1.2, 0.4];
        let p_hat = vec![1.0f64, 0.5, 0.3, -0.5, 1.2, 0.4];
        // f32 truth vs f64 prediction: agreement to f32 precision.
        let r = normalized_residuals(&truth, &p_hat);
        assert!(r.iter().all(|x| x.abs() < 1e-6));
        assert!(mean_abs(&r) < 1e-6);
    }

    #[test]
    fn residuals_are_normalized() {
        let truth = [2.0f32, 0.5, 0.3, -0.5, 1.2, 0.4];
        let mut p_hat = vec![2.0f64, 0.5, 0.3, -0.5, 1.2, 0.4];
        p_hat[0] = 1.0; // off by 1 on a parameter of value 2 -> r = 0.5
        let r = normalized_residuals(&truth, &p_hat);
        assert!((r[0] - 0.5).abs() < 1e-12);
        // negative parameter: sign handled by the division
        let mut p_hat2 = p_hat.clone();
        p_hat2[0] = 2.0;
        p_hat2[3] = -1.0; // truth -0.5: r = (-0.5 - -1.0)/-0.5 = -1.0
        let r2 = normalized_residuals(&truth, &p_hat2);
        assert!((r2[3] + 1.0).abs() < 1e-12);
    }

    #[test]
    fn mean_per_param_averages_rows() {
        let preds = vec![
            1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0, //
            3.0, 4.0, 5.0, 6.0, 7.0, 8.0,
        ];
        let m = mean_per_param(&preds, 2, 6);
        assert_eq!(m, vec![2.0, 3.0, 4.0, 5.0, 6.0, 7.0]);
    }

    #[test]
    fn widths_other_than_six_work_end_to_end() {
        // A 4-parameter and a 10-parameter summary through the same
        // helpers — no fixed-width assumption anywhere.
        for p in [4usize, 10] {
            let k = 3;
            let preds: Vec<f32> = (0..k * p).map(|i| (i % p) as f32 + 1.0).collect();
            let m = mean_per_param(&preds, k, p);
            assert_eq!(m.len(), p);
            for (j, mj) in m.iter().enumerate() {
                assert!((mj - (j as f64 + 1.0)).abs() < 1e-9);
            }
            let truth: Vec<f32> = (0..p).map(|j| (j as f32 + 1.0) * 2.0).collect();
            let r = normalized_residuals(&truth, &m);
            assert_eq!(r.len(), p);
            // Every prediction is half the truth: r = 0.5 everywhere.
            assert!(r.iter().all(|x| (x - 0.5).abs() < 1e-9));
            assert!((mean_abs(&r) - 0.5).abs() < 1e-9);
        }
    }

    #[test]
    #[should_panic(expected = "width mismatch")]
    fn width_mismatch_panics() {
        normalized_residuals(&[1.0f32; 6], &[1.0f64; 5]);
    }

    #[test]
    fn evaluator_width_follows_the_scenario() {
        use crate::runtime::{Manifest, NativeRuntime};
        for sc in crate::scenario::registry() {
            let rt = NativeRuntime::new(Manifest::synthetic_for(sc.name()).unwrap());
            let h = rt.handle();
            let ev = Residuals::new(h.clone(), "gen_predict_small_k256", 7).unwrap();
            assert_eq!(ev.param_dim(), sc.param_dim(), "{}", sc.name());
            let n = h.manifest().model("small").unwrap().gen_param_count;
            let r = ev.residuals(&vec![0.01f32; n]).unwrap();
            assert_eq!(r.len(), sc.param_dim(), "{}", sc.name());
            assert!(r.iter().all(|x| x.is_finite()), "{}", sc.name());
        }
    }
}
