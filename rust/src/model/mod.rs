//! The GAN model as the coordinator sees it: flat parameter vectors,
//! initialization, train-step assembly, residual diagnostics, checkpoints,
//! and a pure-Rust reference implementation — forward ([`reference`]) and
//! analytic backward ([`grad`]) — that cross-checks the HLO artifacts and
//! powers the native CPU execution backend.

pub mod checkpoint;
pub mod gan;
pub mod grad;
pub mod reference;
pub mod residuals;
pub mod step;

pub use gan::GanState;
pub use residuals::Residuals;
pub use step::{StepOutput, TrainStep};
