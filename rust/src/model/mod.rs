//! The GAN model as the coordinator sees it: flat parameter vectors,
//! initialization, train-step assembly, residual diagnostics, checkpoints,
//! and a pure-Rust reference implementation for cross-checking the HLO
//! artifacts.

pub mod checkpoint;
pub mod gan;
pub mod reference;
pub mod residuals;
pub mod step;

pub use gan::GanState;
pub use residuals::Residuals;
pub use step::{StepOutput, TrainStep};
