//! Train-step assembly: the bridge between the coordinator's state and the
//! `gan_step` HLO artifact.
//!
//! The coordinator owns all randomness: noise `z` and sampler uniforms `u`
//! are drawn from the rank's PRNG stream and passed to the artifact as
//! inputs, so an epoch is a pure function of (params, rng state, data).
//! Buffers are preallocated once and reused every epoch — the hot path does
//! not allocate.

use crate::runtime::{ArtifactSpec, RuntimeHandle};
use crate::util::error::{Error, Result};
use crate::util::rng::Rng;

/// Outputs of one GAN step.
#[derive(Clone, Debug)]
pub struct StepOutput {
    pub gen_grads: Vec<f32>,
    pub disc_grads: Vec<f32>,
    pub gen_loss: f64,
    pub disc_loss: f64,
}

/// Reusable train-step executor for one rank.
pub struct TrainStep {
    handle: RuntimeHandle,
    artifact: String,
    pub batch: usize,
    pub events: usize,
    pub latent_dim: usize,
    // Preallocated input staging buffers.
    z: Vec<f32>,
    u: Vec<f32>,
}

impl TrainStep {
    /// Build for a specific `gan_step_*` artifact.
    pub fn new(handle: RuntimeHandle, artifact: &str) -> Result<TrainStep> {
        let spec: &ArtifactSpec = handle.manifest().artifact(artifact)?;
        if spec.kind != "gan_step" {
            return Err(Error::Runtime(format!(
                "artifact '{artifact}' is a '{}', expected gan_step",
                spec.kind
            )));
        }
        let batch = spec
            .batch
            .ok_or_else(|| Error::Manifest("gan_step artifact missing batch".into()))?;
        let events = spec
            .events
            .ok_or_else(|| Error::Manifest("gan_step artifact missing events".into()))?;
        let latent_dim = handle.manifest().latent_dim;
        Ok(TrainStep {
            artifact: artifact.to_string(),
            batch,
            events,
            latent_dim,
            z: vec![0.0; batch * latent_dim],
            u: vec![0.0; batch * events * 2],
            handle,
        })
    }

    /// Discriminator batch size (events per step).
    pub fn disc_batch(&self) -> usize {
        self.batch * self.events
    }

    /// Run one step. `real` must hold `disc_batch() * 2` floats (the
    /// bootstrap sample drawn by the caller).
    pub fn run(
        &mut self,
        gen_params: &[f32],
        disc_params: &[f32],
        real: &[f32],
        rng: &mut Rng,
    ) -> Result<StepOutput> {
        if real.len() != self.disc_batch() * 2 {
            return Err(Error::Runtime(format!(
                "real batch has {} floats, expected {}",
                real.len(),
                self.disc_batch() * 2
            )));
        }
        rng.fill_normal(&mut self.z);
        rng.fill_uniform(&mut self.u);
        let outputs = self.handle.execute(
            &self.artifact,
            vec![
                gen_params.to_vec(),
                disc_params.to_vec(),
                self.z.clone(),
                self.u.clone(),
                real.to_vec(),
            ],
        )?;
        let [gen_grads, disc_grads, gen_loss, disc_loss]: [Vec<f32>; 4] = outputs
            .try_into()
            .map_err(|_| Error::Runtime("gan_step must return 4 outputs".into()))?;
        Ok(StepOutput {
            gen_grads,
            disc_grads,
            gen_loss: gen_loss[0] as f64,
            disc_loss: disc_loss[0] as f64,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::gan::GanState;
    use crate::runtime::RuntimePool;
    use crate::util::rng::Rng;
    use std::path::Path;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn step_runs_and_losses_start_near_log2() {
        let Some(dir) = artifacts_dir() else { return };
        let pool = RuntimePool::from_dir(&dir, 1).unwrap();
        let h = pool.handle();
        if h.manifest().artifact("gan_step_paper_b16_e25").is_err() {
            return;
        }
        let meta = h.manifest().model("paper").unwrap().clone();
        let slope = h.manifest().leaky_slope;
        let mut rng = Rng::new(11);
        let state = GanState::init(&meta, slope, &mut rng);
        let mut step = TrainStep::new(h, "gan_step_paper_b16_e25").unwrap();
        assert_eq!(step.disc_batch(), 400);
        let real = vec![0.5f32; 400 * 2];
        let out = step.run(&state.gen, &state.disc, &real, &mut rng).unwrap();
        assert_eq!(out.gen_grads.len(), state.gen.len());
        assert_eq!(out.disc_grads.len(), state.disc.len());
        // Untrained GAN: losses near the uninformative point (the random
        // Kaiming discriminator emits nonzero logits, so allow a broad
        // band around log 2 / 2 log 2).
        assert!((0.1..3.0).contains(&out.gen_loss), "{}", out.gen_loss);
        assert!((0.5..3.5).contains(&out.disc_loss), "{}", out.disc_loss);
        pool.shutdown();
    }

    #[test]
    fn step_rejects_bad_real_batch() {
        let Some(dir) = artifacts_dir() else { return };
        let pool = RuntimePool::from_dir(&dir, 1).unwrap();
        let h = pool.handle();
        if h.manifest().artifact("gan_step_paper_b16_e25").is_err() {
            return;
        }
        let mut step = TrainStep::new(h, "gan_step_paper_b16_e25").unwrap();
        let mut rng = Rng::new(0);
        let err = step.run(&[0.0; 10], &[0.0; 10], &[0.0; 3], &mut rng);
        assert!(err.is_err());
        pool.shutdown();
    }

    #[test]
    fn non_gan_step_artifact_rejected() {
        let Some(dir) = artifacts_dir() else { return };
        let pool = RuntimePool::from_dir(&dir, 1).unwrap();
        let h = pool.handle();
        if h.manifest().artifact("pipeline_b64_e25").is_ok() {
            assert!(TrainStep::new(h, "pipeline_b64_e25").is_err());
        }
        pool.shutdown();
    }
}
