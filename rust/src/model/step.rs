//! Train-step assembly: the bridge between the coordinator's state and the
//! `gan_step` computation (PJRT artifact or native backend).
//!
//! The coordinator owns all randomness: noise `z` and sampler uniforms `u`
//! are drawn from the rank's PRNG stream and passed to the backend as
//! inputs, so an epoch is a pure function of (params, rng state, data).
//! The hot path is zero-copy: parameter and data inputs are *borrowed*
//! (no per-epoch clones), and the gradient/loss output buffers rotate
//! between the step executor and the caller's [`StepOutput`], so
//! steady-state epochs allocate nothing.

use crate::runtime::{ArtifactSpec, RuntimeHandle};
use crate::util::error::{Error, Result};
use crate::util::rng::Rng;

/// Outputs of one GAN step. The gradient buffers are reusable: pass the
/// same `StepOutput` back into [`TrainStep::run_into`] every epoch and the
/// storage rotates instead of reallocating.
#[derive(Clone, Debug, Default)]
pub struct StepOutput {
    pub gen_grads: Vec<f32>,
    pub disc_grads: Vec<f32>,
    pub gen_loss: f64,
    pub disc_loss: f64,
}

/// Reusable train-step executor for one rank.
pub struct TrainStep {
    handle: RuntimeHandle,
    artifact: String,
    pub batch: usize,
    pub events: usize,
    pub latent_dim: usize,
    // Preallocated input staging buffers (spec-shaped: `u` holds
    // `noise_dim` uniforms per event for the manifest's scenario).
    z: Vec<f32>,
    u: Vec<f32>,
    // Expected length of the caller's `real` batch (disc_batch * event_dim).
    real_len: usize,
    // Reusable output slots (gen_grads, disc_grads, gen_loss, disc_loss);
    // the gradient slots swap with the caller's StepOutput after every
    // execution, so both sides keep reusing warm storage.
    outs: Vec<Vec<f32>>,
}

impl TrainStep {
    /// Build for a specific `gan_step_*` artifact. Staging buffers are
    /// sized from the artifact's input shapes, so scenario-specific noise
    /// and event dimensions flow through without special cases.
    pub fn new(handle: RuntimeHandle, artifact: &str) -> Result<TrainStep> {
        let spec: &ArtifactSpec = handle.manifest().artifact(artifact)?;
        if spec.kind != "gan_step" {
            return Err(Error::Runtime(format!(
                "artifact '{artifact}' is a '{}', expected gan_step",
                spec.kind
            )));
        }
        if spec.inputs.len() != 5 {
            return Err(Error::Manifest(format!(
                "gan_step artifact '{artifact}' must declare 5 inputs, has {}",
                spec.inputs.len()
            )));
        }
        let batch = spec
            .batch
            .ok_or_else(|| Error::Manifest("gan_step artifact missing batch".into()))?;
        let events = spec
            .events
            .ok_or_else(|| Error::Manifest("gan_step artifact missing events".into()))?;
        let latent_dim = handle.manifest().latent_dim;
        let z_len = spec.inputs[2].elems();
        let u_len = spec.inputs[3].elems();
        let real_len = spec.inputs[4].elems();
        Ok(TrainStep {
            artifact: artifact.to_string(),
            batch,
            events,
            latent_dim,
            z: vec![0.0; z_len],
            u: vec![0.0; u_len],
            real_len,
            outs: Vec::new(),
            handle,
        })
    }

    /// Discriminator batch size (events per step).
    pub fn disc_batch(&self) -> usize {
        self.batch * self.events
    }

    /// Floats one bootstrap batch must hold (`disc_batch() * event_dim`).
    pub fn real_len(&self) -> usize {
        self.real_len
    }

    /// Run one step into a reusable [`StepOutput`]. `real` must hold
    /// [`Self::real_len`] floats (the bootstrap sample drawn by the
    /// caller). All inputs are borrowed — nothing is cloned — and `out`'s
    /// gradient buffers are reused across epochs.
    pub fn run_into(
        &mut self,
        gen_params: &[f32],
        disc_params: &[f32],
        real: &[f32],
        rng: &mut Rng,
        out: &mut StepOutput,
    ) -> Result<()> {
        if real.len() != self.real_len {
            return Err(Error::Runtime(format!(
                "real batch has {} floats, expected {}",
                real.len(),
                self.real_len
            )));
        }
        rng.fill_normal(&mut self.z);
        rng.fill_uniform(&mut self.u);
        let inputs: [&[f32]; 5] = [gen_params, disc_params, &self.z, &self.u, real];
        self.handle
            .execute_into(&self.artifact, &inputs, &mut self.outs)?;
        if self.outs.len() != 4 {
            return Err(Error::Runtime(format!(
                "gan_step must return 4 outputs, got {}",
                self.outs.len()
            )));
        }
        out.gen_loss = self.outs[2]
            .first()
            .copied()
            .ok_or_else(|| Error::Runtime("gan_step returned empty gen_loss".into()))?
            as f64;
        out.disc_loss = self.outs[3]
            .first()
            .copied()
            .ok_or_else(|| Error::Runtime("gan_step returned empty disc_loss".into()))?
            as f64;
        // Rotate the freshly written gradient buffers out to the caller
        // and take the caller's previous buffers as next epoch's slots.
        std::mem::swap(&mut self.outs[0], &mut out.gen_grads);
        std::mem::swap(&mut self.outs[1], &mut out.disc_grads);
        Ok(())
    }

    /// Owned-output convenience wrapper around [`Self::run_into`].
    pub fn run(
        &mut self,
        gen_params: &[f32],
        disc_params: &[f32],
        real: &[f32],
        rng: &mut Rng,
    ) -> Result<StepOutput> {
        let mut out = StepOutput::default();
        self.run_into(gen_params, disc_params, real, rng, &mut out)?;
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::gan::GanState;
    use crate::runtime::{Manifest, NativeRuntime, RuntimePool};
    use crate::util::rng::Rng;
    use std::path::Path;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn step_runs_and_losses_start_near_log2() {
        let Some(dir) = artifacts_dir() else { return };
        let pool = RuntimePool::from_dir(&dir, 1).unwrap();
        let h = pool.handle();
        if h.manifest().artifact("gan_step_paper_b16_e25").is_err() {
            return;
        }
        let meta = h.manifest().model("paper").unwrap().clone();
        let slope = h.manifest().leaky_slope;
        let mut rng = Rng::new(11);
        let state = GanState::init(&meta, slope, &mut rng);
        let mut step = TrainStep::new(h, "gan_step_paper_b16_e25").unwrap();
        assert_eq!(step.disc_batch(), 400);
        let real = vec![0.5f32; 400 * 2];
        let out = step.run(&state.gen, &state.disc, &real, &mut rng).unwrap();
        assert_eq!(out.gen_grads.len(), state.gen.len());
        assert_eq!(out.disc_grads.len(), state.disc.len());
        // Untrained GAN: losses near the uninformative point (the random
        // Kaiming discriminator emits nonzero logits, so allow a broad
        // band around log 2 / 2 log 2).
        assert!((0.1..3.0).contains(&out.gen_loss), "{}", out.gen_loss);
        assert!((0.5..3.5).contains(&out.disc_loss), "{}", out.disc_loss);
        pool.shutdown();
    }

    #[test]
    fn step_rejects_bad_real_batch_native() {
        let h = NativeRuntime::new(Manifest::synthetic()).handle();
        let mut step = TrainStep::new(h, "gan_step_small_b16_e25").unwrap();
        let mut rng = Rng::new(0);
        assert!(step.run(&[0.0; 10], &[0.0; 10], &[0.0; 3], &mut rng).is_err());
    }

    #[test]
    fn non_gan_step_artifact_rejected() {
        let h = NativeRuntime::new(Manifest::synthetic()).handle();
        assert!(TrainStep::new(h, "pipeline_b256_e25").is_err());
    }

    #[test]
    fn run_into_reuses_gradient_buffers_across_epochs() {
        let h = NativeRuntime::new(Manifest::synthetic()).handle();
        let meta = h.manifest().model("small").unwrap().clone();
        let slope = h.manifest().leaky_slope;
        let mut rng = Rng::new(13);
        let state = GanState::init(&meta, slope, &mut rng);
        let mut step = TrainStep::new(h, "gan_step_small_b16_e25").unwrap();
        let real = vec![0.5f32; step.disc_batch() * 2];
        let mut out = StepOutput::default();
        let mut ptrs = std::collections::HashSet::new();
        for _ in 0..6 {
            step.run_into(&state.gen, &state.disc, &real, &mut rng, &mut out)
                .unwrap();
            assert_eq!(out.gen_grads.len(), state.gen.len());
            assert!(out.gen_grads.iter().all(|v| v.is_finite()));
            ptrs.insert(out.gen_grads.as_ptr() as usize);
        }
        // The gradient storage rotates between at most two warm buffers —
        // no per-epoch allocation.
        assert!(
            ptrs.len() <= 2,
            "gen_grads buffer reallocated: {} distinct buffers",
            ptrs.len()
        );
    }
}
