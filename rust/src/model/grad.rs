//! Analytic gradients for the reference MLP and the GAN losses — the
//! backward half of the native CPU backend.
//!
//! Mirrors the JAX graph in `python/compile/model.py::gan_step`: LeakyReLU
//! MLPs over the flat parameter layout, the quantile event pipeline, and
//! the non-saturating BCE-with-logits losses. Everything operates on
//! caller-provided buffers (parameter gradients *accumulate*, so the
//! discriminator's real + fake branches sum naturally), and every dense
//! mat-op dispatches through [`crate::runtime::kernels`] — callers pick
//! the scalar oracle or the blocked SIMD-friendly path per call.

use crate::runtime::kernels::Kernels;
use crate::runtime::manifest::LayerLayout;

use super::reference::{self, fit};

/// Numerically stable sigmoid.
#[inline]
pub fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// Numerically stable softplus: `log(1 + e^x) = max(x, 0) + log1p(e^-|x|)`.
#[inline]
pub fn softplus(x: f32) -> f32 {
    x.max(0.0) + (-x.abs()).exp().ln_1p()
}

/// Forward an MLP keeping every layer's post-activation output in
/// `acts[li]` (resized in place; the outer `Vec` grows only on first
/// use). `acts.last()` is the network output. The cached activations are
/// exactly what [`mlp_backward`] needs — LeakyReLU's derivative is
/// recoverable from the *post*-activation sign because the slope is
/// positive.
pub fn mlp_forward_cached(
    flat: &[f32],
    layout: &[LayerLayout],
    x: &[f32],
    batch: usize,
    slope: f32,
    kernels: Kernels,
    acts: &mut Vec<Vec<f32>>,
) {
    let nl = layout.len();
    while acts.len() < nl {
        acts.push(Vec::new());
    }
    // Drop leftover layers from a previous (deeper) network so
    // `acts.last()` is always *this* forward's output.
    acts.truncate(nl);
    for li in 0..nl {
        let (before, rest) = acts.split_at_mut(li);
        let input: &[f32] = if li == 0 { x } else { before[li - 1].as_slice() };
        let layer = &layout[li];
        let out = &mut rest[0];
        fit(out, batch * layer.w_cols);
        reference::layer_forward(flat, layer, input, batch, slope, li + 1 < nl, kernels, out);
    }
}

/// Backward pass through an MLP forwarded by [`mlp_forward_cached`].
///
/// * `d_out` — dL/d(network output), (batch, d_out) row-major. Consumed
///   as scratch: its contents are clobbered during backprop.
/// * `scratch` — second ping-pong buffer for the inter-layer gradients.
/// * `d_flat` — when present, parameter gradients are **accumulated**
///   (`+=`) into it at the layout's offsets; zero it first for a plain
///   gradient.
/// * `d_x` — when present, receives dL/dx (batch, d_in), overwritten.
#[allow(clippy::too_many_arguments)]
pub fn mlp_backward(
    flat: &[f32],
    layout: &[LayerLayout],
    x: &[f32],
    batch: usize,
    slope: f32,
    kernels: Kernels,
    acts: &[Vec<f32>],
    d_out: &mut Vec<f32>,
    scratch: &mut Vec<f32>,
    mut d_flat: Option<&mut [f32]>,
    mut d_x: Option<&mut [f32]>,
) {
    let nl = layout.len();
    debug_assert!(nl > 0);
    debug_assert!(acts.len() >= nl);
    debug_assert_eq!(d_out.len(), batch * layout[nl - 1].w_cols);
    let (mut cur, mut next) = (d_out, scratch);
    for li in (0..nl).rev() {
        let layer = &layout[li];
        let (rows, cols) = (layer.w_rows, layer.w_cols);
        // Activation gradient (hidden layers only; the output layer is
        // linear). Post-activation sign equals pre-activation sign for a
        // positive slope, so the cached output is enough.
        if li + 1 < nl {
            for (dv, &hv) in cur.iter_mut().zip(&acts[li]) {
                *dv *= if hv >= 0.0 { 1.0 } else { slope };
            }
        }
        let xin: &[f32] = if li == 0 { x } else { acts[li - 1].as_slice() };
        debug_assert_eq!(xin.len(), batch * rows);

        // Parameter gradients: dW += xᵀ dPre (row i of dW is contiguous),
        // db += column sums of dPre. Both kernel variants accumulate in
        // ascending batch order, so the kernel choice is bit-invisible
        // here too.
        if let Some(df) = d_flat.as_deref_mut() {
            let (dw, db) = layer_grads_mut(df, layer);
            kernels.matmul_at_b_acc(xin, cur, batch, rows, cols, dw);
            kernels.col_sums_acc(cur, batch, cols, db);
        }

        // Input gradients: dX = dPre Wᵀ (dot over the contiguous weight
        // row). Needed for every layer but the first, and for the first
        // only when the caller asked for dL/dx.
        let w = &flat[layer.w_offset..layer.w_offset + rows * cols];
        if li > 0 {
            fit(next, batch * rows);
            kernels.matmul_abt(cur, w, batch, cols, rows, next);
            std::mem::swap(&mut cur, &mut next);
        } else if let Some(dx) = d_x.take() {
            debug_assert_eq!(dx.len(), batch * rows);
            kernels.matmul_abt(cur, w, batch, cols, rows, dx);
        }
    }
}

/// Disjoint mutable views of one layer's weight and bias gradient regions
/// inside the flat gradient vector. Relies on the [W, b] ordering the
/// layout builder guarantees (bias immediately after its weights).
fn layer_grads_mut<'a>(df: &'a mut [f32], layer: &LayerLayout) -> (&'a mut [f32], &'a mut [f32]) {
    debug_assert!(layer.b_offset >= layer.w_offset + layer.w_len());
    let (head, tail) = df.split_at_mut(layer.b_offset);
    let dw = &mut head[layer.w_offset..layer.w_offset + layer.w_len()];
    let db = &mut tail[..layer.b_len];
    (dw, db)
}

/// Backward through the quantile pipeline: given dL/d(events) (B·E, 2)
/// and the sampler uniforms u (B, E, 2), accumulate dL/d(params) (B, 6)
/// into `d_params` (overwritten). `∂q(u; a,b,c)/∂(a,b,c) = (1, u, u²)`.
/// This is the VJP of the `quantile` scenario (`crate::scenario`); other
/// scenarios supply their own `backward_params`.
pub fn pipeline_backward(
    d_events: &[f32],
    u: &[f32],
    batch: usize,
    events: usize,
    d_params: &mut Vec<f32>,
) {
    debug_assert_eq!(d_events.len(), batch * events * 2);
    debug_assert_eq!(u.len(), batch * events * 2);
    fit(d_params, batch * 6);
    for bi in 0..batch {
        let dp = &mut d_params[bi * 6..bi * 6 + 6];
        for e in 0..events {
            let idx = (bi * events + e) * 2;
            let (d0, d1) = (d_events[idx], d_events[idx + 1]);
            let (u0, u1) = (u[idx], u[idx + 1]);
            dp[0] += d0;
            dp[1] += d0 * u0;
            dp[2] += d0 * u0 * u0;
            dp[3] += d1;
            dp[4] += d1 * u1;
            dp[5] += d1 * u1 * u1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// A random layout over the given layer sizes, plus matching random
    /// parameters (layout from the runtime's single layout builder).
    fn random_net(sizes: &[usize], rng: &mut Rng) -> (Vec<LayerLayout>, Vec<f32>) {
        let (_, layout, count) = crate::runtime::manifest::layout_from_sizes(sizes);
        let flat: Vec<f32> = (0..count).map(|_| rng.normal_f32(0.0, 0.5)).collect();
        (layout, flat)
    }

    /// Scalar test loss: L = Σ c ⊙ forward(x), with fixed random c.
    fn loss(flat: &[f32], layout: &[LayerLayout], x: &[f32], batch: usize, c: &[f32]) -> f64 {
        let y = reference::mlp_forward(flat, layout, x, batch, 0.2);
        y.iter().zip(c).map(|(&yv, &cv)| (yv * cv) as f64).sum()
    }

    /// FD check of the analytic gradients for one kernel variant — the
    /// blocked path must satisfy the same finite-difference contract as
    /// the scalar oracle, at sizes that don't divide the tile widths.
    fn check_backward_against_finite_differences(kernels: Kernels) {
        let mut rng = Rng::new(42);
        for &sizes in &[&[3usize, 4, 2][..], &[2, 5, 3, 1][..], &[4, 4][..]] {
            let (layout, flat) = random_net(sizes, &mut rng);
            let batch = 3;
            let x: Vec<f32> = (0..batch * sizes[0])
                .map(|_| rng.normal_f32(0.0, 1.0))
                .collect();
            let d_out_dim = batch * sizes[sizes.len() - 1];
            let c: Vec<f32> = (0..d_out_dim).map(|_| rng.normal_f32(0.0, 1.0)).collect();

            // Analytic gradient.
            let mut acts = Vec::new();
            mlp_forward_cached(&flat, &layout, &x, batch, 0.2, kernels, &mut acts);
            let mut d_out = c.clone();
            let mut scratch = Vec::new();
            let mut d_flat = vec![0.0f32; flat.len()];
            let mut d_x = vec![0.0f32; x.len()];
            mlp_backward(
                &flat,
                &layout,
                &x,
                batch,
                0.2,
                kernels,
                &acts,
                &mut d_out,
                &mut scratch,
                Some(&mut d_flat),
                Some(&mut d_x),
            );

            // Central finite differences on a sample of parameters.
            let h = 1e-2f32;
            for k in (0..flat.len()).step_by(flat.len() / 7 + 1) {
                let mut fp = flat.clone();
                fp[k] += h;
                let mut fm = flat.clone();
                fm[k] -= h;
                let num =
                    (loss(&fp, &layout, &x, batch, &c) - loss(&fm, &layout, &x, batch, &c))
                        / (2.0 * h as f64);
                let ana = d_flat[k] as f64;
                // The net is piecewise linear, so central differences are
                // exact up to f32 noise unless a LeakyReLU kink sits
                // inside the ±h interval — hence the small absolute term.
                assert!(
                    (num - ana).abs() < 2e-2 + 0.1 * ana.abs().max(num.abs()),
                    "param {k}: numeric {num} vs analytic {ana} (sizes {sizes:?})"
                );
            }
            // And on the inputs.
            for k in (0..x.len()).step_by(x.len() / 5 + 1) {
                let mut xp = x.clone();
                xp[k] += h;
                let mut xm = x.clone();
                xm[k] -= h;
                let num = (loss(&flat, &layout, &xp, batch, &c)
                    - loss(&flat, &layout, &xm, batch, &c))
                    / (2.0 * h as f64);
                let ana = d_x[k] as f64;
                assert!(
                    (num - ana).abs() < 2e-2 + 0.1 * ana.abs().max(num.abs()),
                    "input {k}: numeric {num} vs analytic {ana} (sizes {sizes:?})"
                );
            }
        }
    }

    #[test]
    fn backward_matches_finite_differences() {
        check_backward_against_finite_differences(Kernels::Scalar);
        check_backward_against_finite_differences(Kernels::Blocked);
    }

    #[test]
    fn scalar_and_blocked_param_grads_are_bit_identical() {
        // dW and db accumulate in ascending batch order under both kernel
        // variants — the determinism contract the chunked reduction in
        // `runtime::native` builds on. Odd sizes exercise the tile tails.
        let mut rng = Rng::new(23);
        let (layout, flat) = random_net(&[5, 7, 3], &mut rng);
        let batch = 5;
        let x: Vec<f32> = (0..batch * 5).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let c: Vec<f32> = (0..batch * 3).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let grads = |kernels: Kernels| {
            let mut acts = Vec::new();
            mlp_forward_cached(&flat, &layout, &x, batch, 0.2, kernels, &mut acts);
            let mut d_out = c.clone();
            let mut scratch = Vec::new();
            let mut d_flat = vec![0.0f32; flat.len()];
            mlp_backward(
                &flat,
                &layout,
                &x,
                batch,
                0.2,
                kernels,
                &acts,
                &mut d_out,
                &mut scratch,
                Some(&mut d_flat),
                None,
            );
            d_flat
        };
        // The last-layer dW/db see the unmodified cotangent, and the
        // forward is bit-identical, so those regions must match exactly.
        let a = grads(Kernels::Scalar);
        let b = grads(Kernels::Blocked);
        let last = &layout[layout.len() - 1];
        let lo = last.w_offset;
        assert_eq!(a[lo..], b[lo..], "last-layer dW/db diverged across kernels");
    }

    #[test]
    fn param_grads_accumulate_across_calls() {
        let mut rng = Rng::new(7);
        let (layout, flat) = random_net(&[2, 3, 1], &mut rng);
        let x: Vec<f32> = (0..4).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut acts = Vec::new();
        mlp_forward_cached(&flat, &layout, &x, 2, 0.2, Kernels::default(), &mut acts);
        let run = |d_flat: &mut [f32]| {
            let mut d_out = vec![1.0f32; 2];
            let mut scratch = Vec::new();
            mlp_backward(
                &flat,
                &layout,
                &x,
                2,
                0.2,
                Kernels::default(),
                &acts,
                &mut d_out,
                &mut scratch,
                Some(d_flat),
                None,
            );
        };
        let mut once = vec![0.0f32; flat.len()];
        run(&mut once);
        let mut twice = vec![0.0f32; flat.len()];
        run(&mut twice);
        run(&mut twice);
        for (a, b) in once.iter().zip(&twice) {
            assert!((2.0 * a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn pipeline_backward_matches_quantile_partials() {
        // One batch row, two events, hand-checkable uniforms.
        let u = vec![0.5f32, 0.25, 1.0, 0.0];
        let d_events = vec![1.0f32, 2.0, 3.0, 4.0];
        let mut dp = Vec::new();
        pipeline_backward(&d_events, &u, 1, 2, &mut dp);
        // dp0 = 1 + 3; dp1 = 1*0.5 + 3*1.0; dp2 = 1*0.25 + 3*1.0
        assert_eq!(&dp[..3], &[4.0, 3.5, 3.25]);
        // dp3 = 2 + 4; dp4 = 2*0.25 + 4*0; dp5 = 2*0.0625
        assert_eq!(&dp[3..], &[6.0, 0.5, 0.125]);
    }

    #[test]
    fn stable_loss_helpers() {
        assert!((softplus(0.0) - std::f32::consts::LN_2).abs() < 1e-6);
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-7);
        // No overflow at extremes.
        assert!(softplus(100.0).is_finite() && (softplus(100.0) - 100.0).abs() < 1e-3);
        assert!(softplus(-100.0).abs() < 1e-6);
        assert!(sigmoid(-100.0) >= 0.0 && sigmoid(100.0) <= 1.0);
    }

    #[test]
    fn forward_cached_matches_reference_forward() {
        let mut rng = Rng::new(9);
        let (layout, flat) = random_net(&[3, 5, 4, 2], &mut rng);
        let x: Vec<f32> = (0..9).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut acts = Vec::new();
        mlp_forward_cached(&flat, &layout, &x, 3, 0.2, Kernels::default(), &mut acts);
        let want = reference::mlp_forward(&flat, &layout, &x, 3, 0.2);
        assert_eq!(acts.last().unwrap(), &want);
    }
}
