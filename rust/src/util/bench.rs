//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Provides warmup + timed iterations with percentile reporting, and a
//! table printer shared by the per-figure bench binaries so every bench
//! emits the same `name  p50  p90  mean  iters` row format plus
//! figure-style data tables for the paper-reproduction reports.

use std::time::{Duration, Instant};

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p90: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl BenchResult {
    pub fn row(&self) -> String {
        format!(
            "{:<44} {:>10} {:>10} {:>10} {:>8}",
            self.name,
            fmt_dur(self.p50),
            fmt_dur(self.p90),
            fmt_dur(self.mean),
            self.iters
        )
    }
}

/// Human duration formatting (ns/µs/ms/s).
pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns}ns")
    } else if ns < 1_000_000 {
        format!("{:.2}µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else {
        format!("{:.3}s", ns as f64 / 1e9)
    }
}

/// Bench runner: warms up for `warmup` iterations then times `iters`.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    summarize(name, samples)
}

/// Time-budgeted runner: iterates until `budget` elapses (at least once).
pub fn bench_for<F: FnMut()>(name: &str, warmup: usize, budget: Duration, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    loop {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
        if start.elapsed() >= budget {
            break;
        }
    }
    summarize(name, samples)
}

fn summarize(name: &str, mut samples: Vec<Duration>) -> BenchResult {
    samples.sort();
    let iters = samples.len();
    let total: Duration = samples.iter().sum();
    let pct = |p: f64| samples[((iters as f64 - 1.0) * p) as usize];
    BenchResult {
        name: name.to_string(),
        iters,
        mean: total / iters as u32,
        p50: pct(0.50),
        p90: pct(0.90),
        min: samples[0],
        max: samples[iters - 1],
    }
}

/// Print a standard bench table header.
pub fn header(title: &str) {
    println!("\n=== {title} ===");
    println!(
        "{:<44} {:>10} {:>10} {:>10} {:>8}",
        "benchmark", "p50", "p90", "mean", "iters"
    );
    println!("{}", "-".repeat(88));
}

/// Print a figure-style data table (series of (x, columns...) rows) in a
/// format that is easy to diff against the paper's plots.
pub fn data_table(title: &str, x_label: &str, col_labels: &[&str], rows: &[(f64, Vec<f64>)]) {
    println!("\n--- {title} ---");
    print!("{x_label:>12}");
    for c in col_labels {
        print!(" {c:>16}");
    }
    println!();
    for (x, cols) in rows {
        print!("{x:>12.3}");
        for v in cols {
            print!(" {v:>16.6}");
        }
        println!();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let r = bench("noop-ish", 2, 32, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert_eq!(r.iters, 32);
        assert!(r.min <= r.p50 && r.p50 <= r.p90 && r.p90 <= r.max);
        assert!(r.mean >= r.min && r.mean <= r.max);
    }

    #[test]
    fn bench_for_respects_budget_loosely() {
        let t0 = Instant::now();
        let r = bench_for("sleepy", 0, Duration::from_millis(20), || {
            std::thread::sleep(Duration::from_millis(2));
        });
        assert!(r.iters >= 1);
        assert!(t0.elapsed() < Duration::from_secs(2));
    }

    #[test]
    fn fmt_dur_units() {
        assert_eq!(fmt_dur(Duration::from_nanos(12)), "12ns");
        assert!(fmt_dur(Duration::from_micros(12)).ends_with("µs"));
        assert!(fmt_dur(Duration::from_millis(12)).ends_with("ms"));
        assert!(fmt_dur(Duration::from_secs(2)).ends_with('s'));
    }
}
