//! Minimal JSON parser + emitter (serde is unavailable offline).
//!
//! Covers the full JSON grammar the project needs: the artifact manifest
//! written by `python/compile/aot.py`, run configuration files, checkpoint
//! metadata and report outputs. Numbers are kept as f64 (the manifest only
//! contains shapes, counts and hyper-parameters — all exactly representable).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use super::error::{Error, Result};

/// A JSON value. Object keys are sorted (BTreeMap) so emission is
/// deterministic — handy for golden tests and diffable reports.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<Value>),
    Object(BTreeMap<String, Value>),
}

impl Value {
    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Value> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn as_object(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Object(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Number(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::String(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.as_object().and_then(|m| m.get(key))
    }

    /// Required object field with a descriptive error.
    pub fn req(&self, key: &str) -> Result<&Value> {
        self.get(key)
            .ok_or_else(|| Error::Manifest(format!("missing field '{key}'")))
    }

    /// Convenience: required usize field.
    pub fn req_usize(&self, key: &str) -> Result<usize> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| Error::Manifest(format!("field '{key}' is not a number")))
    }

    /// Convenience: required str field.
    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| Error::Manifest(format!("field '{key}' is not a string")))
    }

    /// Convenience: vec of usize from an array field.
    pub fn usize_array(&self) -> Result<Vec<usize>> {
        self.as_array()
            .ok_or_else(|| Error::Manifest("expected array".into()))?
            .iter()
            .map(|v| {
                v.as_usize()
                    .ok_or_else(|| Error::Manifest("expected number in array".into()))
            })
            .collect()
    }

    /// Convenience: vec of f64 from an array field.
    pub fn f64_array(&self) -> Result<Vec<f64>> {
        self.as_array()
            .ok_or_else(|| Error::Manifest("expected array".into()))?
            .iter()
            .map(|v| {
                v.as_f64()
                    .ok_or_else(|| Error::Manifest("expected number in array".into()))
            })
            .collect()
    }

    // -- emission ----------------------------------------------------------

    /// Serialize to a compact JSON string.
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_json_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Number(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Value::String(s) => write_escaped(out, s),
            Value::Array(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Value::Object(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Builder helpers for emitting reports.
pub fn obj(fields: Vec<(&str, Value)>) -> Value {
    Value::Object(
        fields
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect(),
    )
}

pub fn num(n: f64) -> Value {
    Value::Number(n)
}

pub fn s(v: impl Into<String>) -> Value {
    Value::String(v.into())
}

pub fn arr(items: Vec<Value>) -> Value {
    Value::Array(items)
}

pub fn f64s(xs: &[f64]) -> Value {
    Value::Array(xs.iter().map(|&x| Value::Number(x)).collect())
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Json {
            offset: self.pos,
            message: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::String(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Object(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(a));
        }
        loop {
            let v = self.value()?;
            a.push(v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Array(a)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pairs.
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("expected low surrogate"));
                            }
                            let lo = self.hex4()?;
                            let combined =
                                0x10000 + (((cp - 0xD800) as u32) << 10) + (lo - 0xDC00) as u32;
                            char::from_u32(combined)
                        } else {
                            char::from_u32(cp as u32)
                        };
                        s.push(c.ok_or_else(|| self.err("invalid unicode escape"))?);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control character in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c);
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u16> {
        let mut v: u16 = 0;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u"))?;
            let d = (c as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            v = (v << 4) | d as u16;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Number)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse("-12.5e2").unwrap(), Value::Number(-1250.0));
        assert_eq!(
            Value::parse("\"a\\nb\"").unwrap(),
            Value::String("a\nb".into())
        );
    }

    #[test]
    fn parse_nested() {
        let v = Value::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_array().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_array().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("c")
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"shapes": [[16, 154], [154, 6]], "lr": 1e-05, "name": "gan", "ok": true}"#;
        let v = Value::parse(src).unwrap();
        let emitted = v.to_json();
        let v2 = Value::parse(&emitted).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn unicode_escapes() {
        let v = Value::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
    }

    #[test]
    fn utf8_passthrough() {
        let v = Value::parse("\"héllo — ok\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo — ok"));
    }

    #[test]
    fn errors_have_offsets() {
        let e = Value::parse("{\"a\": }").unwrap_err();
        match e {
            Error::Json { offset, .. } => assert!(offset > 0),
            _ => panic!("wrong error type"),
        }
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Value::parse("1 2").is_err());
    }

    #[test]
    fn integers_emit_without_fraction() {
        assert_eq!(num(42.0).to_json(), "42");
        assert_eq!(num(0.5).to_json(), "0.5");
    }

    #[test]
    fn req_helpers() {
        let v = Value::parse(r#"{"n": 3, "s": "x"}"#).unwrap();
        assert_eq!(v.req_usize("n").unwrap(), 3);
        assert_eq!(v.req_str("s").unwrap(), "x");
        assert!(v.req("missing").is_err());
    }

    #[test]
    fn typed_arrays() {
        let v = Value::parse("[1, 2, 3]").unwrap();
        assert_eq!(v.usize_array().unwrap(), vec![1, 2, 3]);
        assert_eq!(v.f64_array().unwrap(), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn parses_real_manifest_if_present() {
        // Golden test against the actual exporter output when artifacts
        // have been built.
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let v = Value::parse(&text).unwrap();
            assert!(v.get("artifacts").is_some());
            assert_eq!(v.req("true_params").unwrap().f64_array().unwrap().len(), 6);
        }
    }
}
