//! In-repo property-based testing harness (proptest is unavailable offline).
//!
//! A property is a closure over inputs drawn from [`Gen`] strategies. The
//! runner executes many random cases; on failure it *shrinks* the failing
//! input by re-running the property on progressively simpler candidates
//! produced by the strategy's shrinker, then panics with the minimal case
//! and the seed needed to reproduce it.
//!
//! ```no_run
//! // (no_run: doctest binaries lack the xla_extension rpath in this
//! // offline image; the same property runs in the unit tests below.)
//! use sagips::util::proptest::{run, Gen};
//! run("reverse twice is identity", 200, |g| {
//!     let xs = g.vec_f32(0..=32, -1e3..=1e3);
//!     let mut ys = xs.clone();
//!     ys.reverse();
//!     ys.reverse();
//!     assert_eq!(xs, ys);
//! });
//! ```

use std::ops::RangeInclusive;
use std::panic::{catch_unwind, AssertUnwindSafe};

use super::rng::Rng;

/// Random input source handed to properties. Records every draw so the
/// runner can replay and shrink.
pub struct Gen {
    rng: Rng,
    /// Shrink pass: when set, sizes are scaled down toward minimal cases.
    shrink_factor: f64,
}

impl Gen {
    fn new(seed: u64, shrink_factor: f64) -> Self {
        Gen {
            rng: Rng::new(seed),
            shrink_factor,
        }
    }

    fn scale(&self, n: usize, min: usize) -> usize {
        if self.shrink_factor >= 1.0 {
            return n;
        }
        let span = n.saturating_sub(min) as f64;
        min + (span * self.shrink_factor).floor() as usize
    }

    /// usize in an inclusive range (shrinks toward the lower bound).
    pub fn usize_in(&mut self, range: RangeInclusive<usize>) -> usize {
        let (lo, hi) = (*range.start(), *range.end());
        let raw = lo + self.rng.below((hi - lo + 1) as u64) as usize;
        self.scale(raw, lo)
    }

    /// u64 uniform.
    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    /// f32 in an inclusive range (shrinks toward the middle of the range).
    pub fn f32_in(&mut self, range: RangeInclusive<f32>) -> f32 {
        let (lo, hi) = (*range.start(), *range.end());
        let u = self.rng.uniform_f32();
        let v = lo + u * (hi - lo);
        if self.shrink_factor >= 1.0 {
            v
        } else {
            let mid = 0.5 * (lo + hi);
            mid + (v - mid) * self.shrink_factor as f32
        }
    }

    /// f64 in a range.
    pub fn f64_in(&mut self, range: RangeInclusive<f64>) -> f64 {
        let (lo, hi) = (*range.start(), *range.end());
        lo + self.rng.uniform() * (hi - lo)
    }

    /// bool with probability 1/2.
    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Vec<f32> with random length from `len` and values from `vals`.
    pub fn vec_f32(
        &mut self,
        len: RangeInclusive<usize>,
        vals: RangeInclusive<f32>,
    ) -> Vec<f32> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.f32_in(vals.clone())).collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len() as u64) as usize]
    }

    /// Access the underlying RNG for custom draws.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run a property over `cases` random inputs. Panics (with reproduction
/// info) on the first failure after attempting to shrink it.
pub fn run<F>(name: &str, cases: u64, prop: F)
where
    F: Fn(&mut Gen) + std::panic::RefUnwindSafe,
{
    // Env-overridable base seed for reproduction.
    let base_seed: u64 = std::env::var("SAGIPS_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5A61_7069_7321);
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case.wrapping_mul(0x9E3779B97F4A7C15));
        let failed = catch_unwind(AssertUnwindSafe(|| {
            let mut g = Gen::new(seed, 1.0);
            prop(&mut g);
        }))
        .is_err();
        if failed {
            // Shrink: re-run with progressively smaller size scaling and
            // report the smallest factor that still fails.
            let mut minimal = 1.0f64;
            for &factor in &[0.0, 0.1, 0.25, 0.5, 0.75] {
                let fails = catch_unwind(AssertUnwindSafe(|| {
                    let mut g = Gen::new(seed, factor);
                    prop(&mut g);
                }))
                .is_err();
                if fails {
                    minimal = factor;
                    break;
                }
            }
            // Re-run the minimal case outside catch_unwind so the original
            // assertion message propagates.
            eprintln!(
                "property '{name}' failed: case {case}, seed {seed}, shrink {minimal}. \
                 Reproduce with SAGIPS_PROP_SEED={base_seed}."
            );
            let mut g = Gen::new(seed, minimal);
            prop(&mut g);
            unreachable!("property failed under catch_unwind but passed on replay");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        run("sum is commutative", 64, |g| {
            let a = g.f32_in(-100.0..=100.0);
            let b = g.f32_in(-100.0..=100.0);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic]
    fn failing_property_panics() {
        run("all vectors are short", 64, |g| {
            let v = g.vec_f32(0..=64, 0.0..=1.0);
            assert!(v.len() < 8, "len={}", v.len());
        });
    }

    #[test]
    fn ranges_respected() {
        run("ranges", 128, |g| {
            let n = g.usize_in(3..=9);
            assert!((3..=9).contains(&n));
            let x = g.f32_in(-1.0..=1.0);
            assert!((-1.0..=1.0).contains(&x));
        });
    }

    #[test]
    fn deterministic_given_env_seed() {
        let mut g1 = Gen::new(99, 1.0);
        let mut g2 = Gen::new(99, 1.0);
        assert_eq!(g1.u64(), g2.u64());
        assert_eq!(g1.vec_f32(0..=8, 0.0..=1.0), g2.vec_f32(0..=8, 0.0..=1.0));
    }
}
