//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value` options and
//! positional arguments, with typed accessors and generated usage text.

use std::collections::BTreeMap;

use super::error::{Error, Result};

/// Declarative option spec used for usage text.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

/// Parsed arguments.
#[derive(Clone, Debug, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse a raw arg list (no program name). `specs` identifies which
    /// `--name`s are flags (no value).
    pub fn parse(raw: &[String], specs: &[OptSpec]) -> Result<Args> {
        let is_flag = |name: &str| {
            specs
                .iter()
                .any(|s| s.is_flag && s.name == name)
        };
        let known = |name: &str| specs.iter().any(|s| s.name == name);
        let mut out = Args::default();
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    if !known(k) {
                        return Err(Error::Usage(format!("unknown option --{k}")));
                    }
                    out.opts.insert(k.to_string(), v.to_string());
                } else if is_flag(body) {
                    out.flags.push(body.to_string());
                } else if known(body) {
                    i += 1;
                    let v = raw
                        .get(i)
                        .ok_or_else(|| Error::Usage(format!("--{body} needs a value")))?;
                    out.opts.insert(body.to_string(), v.clone());
                } else {
                    return Err(Error::Usage(format!("unknown option --{body}")));
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Usage(format!("--{name} expects an integer, got '{v}'"))),
        }
    }

    pub fn f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Usage(format!("--{name} expects a number, got '{v}'"))),
        }
    }

    pub fn u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Usage(format!("--{name} expects an integer, got '{v}'"))),
        }
    }

    /// Comma-separated usize list.
    pub fn usize_list(&self, name: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|t| {
                    t.trim()
                        .parse()
                        .map_err(|_| Error::Usage(format!("--{name}: bad integer '{t}'")))
                })
                .collect(),
        }
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

/// Render usage text for a subcommand.
pub fn usage(cmd: &str, about: &str, specs: &[OptSpec]) -> String {
    let mut s = format!("{cmd} — {about}\n\noptions:\n");
    for spec in specs {
        let head = if spec.is_flag {
            format!("  --{}", spec.name)
        } else {
            format!("  --{} <v>", spec.name)
        };
        s.push_str(&format!("{head:<28}{}", spec.help));
        if let Some(d) = spec.default {
            s.push_str(&format!(" [default: {d}]"));
        }
        s.push('\n');
    }
    s
}

/// Shorthand for building an option spec.
pub const fn opt(name: &'static str, help: &'static str, default: Option<&'static str>) -> OptSpec {
    OptSpec {
        name,
        help,
        default,
        is_flag: false,
    }
}

/// Shorthand for building a flag spec.
pub const fn flag(name: &'static str, help: &'static str) -> OptSpec {
    OptSpec {
        name,
        help,
        default: None,
        is_flag: true,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<OptSpec> {
        vec![
            opt("ranks", "number of ranks", Some("8")),
            opt("mode", "training mode", Some("arar")),
            flag("paper-scale", "full Table III config"),
        ]
    }

    fn parse(args: &[&str]) -> Result<Args> {
        let raw: Vec<String> = args.iter().map(|s| s.to_string()).collect();
        Args::parse(&raw, &specs())
    }

    #[test]
    fn parses_options_and_flags() {
        let a = parse(&["--ranks", "16", "--mode=rma", "--paper-scale", "extra"]).unwrap();
        assert_eq!(a.usize("ranks", 8).unwrap(), 16);
        assert_eq!(a.get("mode"), Some("rma"));
        assert!(a.flag("paper-scale"));
        assert_eq!(a.positional(), &["extra".to_string()]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[]).unwrap();
        assert_eq!(a.usize("ranks", 8).unwrap(), 8);
        assert!(!a.flag("paper-scale"));
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(parse(&["--bogus", "1"]).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(parse(&["--ranks"]).is_err());
    }

    #[test]
    fn bad_int_rejected() {
        let a = parse(&["--ranks", "abc"]).unwrap();
        assert!(a.usize("ranks", 8).is_err());
    }

    #[test]
    fn usize_list() {
        let a = parse(&["--ranks", "1"]).unwrap();
        assert_eq!(a.usize_list("missing", &[2, 4]).unwrap(), vec![2, 4]);
        let raw: Vec<String> = vec!["--mode".into(), "2, 4,8".into()];
        let a = Args::parse(&raw, &specs()).unwrap();
        assert_eq!(a.usize_list("mode", &[]).unwrap(), vec![2, 4, 8]);
    }

    #[test]
    fn usage_mentions_options() {
        let u = usage("train", "train the GAN", &specs());
        assert!(u.contains("--ranks"));
        assert!(u.contains("default: 8"));
    }
}
