//! Minimal leveled logger with wall-clock timestamps.
//!
//! Configured once per process via [`init`] (or the `SAGIPS_LOG` env var:
//! `error|warn|info|debug|trace`). Rank threads prefix messages with their
//! rank id through [`rank_scope`].

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

/// Log verbosity, ordered.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

thread_local! {
    static RANK: std::cell::Cell<Option<usize>> = const { std::cell::Cell::new(None) };
}

/// Set the global verbosity.
pub fn init(level: Level) {
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Initialize from the `SAGIPS_LOG` environment variable (default `info`).
pub fn init_from_env() {
    let level = std::env::var("SAGIPS_LOG")
        .ok()
        .and_then(|s| Level::parse(&s))
        .unwrap_or(Level::Info);
    init(level);
}

/// Tag the current thread's log lines with a rank id.
pub fn rank_scope(rank: usize) {
    RANK.with(|r| r.set(Some(rank)));
}

/// Whether a level is currently enabled.
pub fn enabled(level: Level) -> bool {
    (level as u8) <= MAX_LEVEL.load(Ordering::Relaxed)
}

/// Core log routine; prefer the macros.
pub fn log(level: Level, args: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let now = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .unwrap_or_default();
    let secs = now.as_secs();
    let millis = now.subsec_millis();
    let rank = RANK.with(|r| r.get());
    let mut out = std::io::stderr().lock();
    let _ = match rank {
        Some(r) => writeln!(
            out,
            "[{secs}.{millis:03} {} r{r}] {args}",
            level.tag().trim_end()
        ),
        None => writeln!(out, "[{secs}.{millis:03} {}] {args}", level.tag().trim_end()),
    };
}

#[macro_export]
macro_rules! log_error { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_warn { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_info { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_debug { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_trace { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Trace, format_args!($($t)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parse() {
        assert_eq!(Level::parse("debug"), Some(Level::Debug));
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("nope"), None);
    }

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Trace);
    }

    #[test]
    fn enabled_respects_init() {
        init(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        init(Level::Info); // restore default for other tests
    }
}
