//! Foundational substrates built in-repo (the build is fully offline, so
//! there is no serde / clap / rand / criterion / proptest — each is replaced
//! by a small, tested, purpose-built module).

pub mod bench;
pub mod cli;
pub mod error;
pub mod json;
pub mod logging;
pub mod proptest;
pub mod rng;
