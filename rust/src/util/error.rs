//! Crate-wide error type.

use thiserror::Error;

/// Errors surfaced by the SAGIPS library.
#[derive(Error, Debug)]
pub enum Error {
    /// I/O failures (artifact files, checkpoints, CSV outputs).
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    /// JSON parse errors (manifest, configs).
    #[error("json error at byte {offset}: {message}")]
    Json { offset: usize, message: String },

    /// Configuration validation failures.
    #[error("config error: {0}")]
    Config(String),

    /// PJRT / XLA runtime failures.
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Artifact manifest inconsistencies.
    #[error("manifest error: {0}")]
    Manifest(String),

    /// Communication layer failures (disconnected transport, poisoned
    /// window, topology misconfiguration).
    #[error("comm error: {0}")]
    Comm(String),

    /// The collective engine's exchange window is full: `start_reduce`
    /// would exceed the configured in-flight depth. Retryable backpressure
    /// — settle an outstanding exchange and resubmit — unlike the fatal
    /// [`Error::Comm`] faults.
    #[error("collective window full: {0}")]
    WindowFull(String),

    /// Shape mismatches in tensor operations.
    #[error("shape error: {0}")]
    Shape(String),

    /// Checkpoint encode/decode failures.
    #[error("checkpoint error: {0}")]
    Checkpoint(String),

    /// CLI usage errors.
    #[error("usage error: {0}")]
    Usage(String),

    /// Service admission control refused the request: the job queue is
    /// at capacity. Retryable backpressure — resubmit once the daemon
    /// drains — unlike the fatal [`Error::Config`] rejections.
    #[error("service overloaded: {0}")]
    Overloaded(String),
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}

#[cfg(not(feature = "pjrt"))]
impl From<crate::runtime::xla_stub::Error> for Error {
    fn from(e: crate::runtime::xla_stub::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}

impl Error {
    /// Shorthand constructor for comm errors.
    pub fn comm(msg: impl Into<String>) -> Self {
        Error::Comm(msg.into())
    }
    /// Shorthand constructor for config errors.
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }
    /// Shorthand constructor for window-full backpressure.
    pub fn window_full(msg: impl Into<String>) -> Self {
        Error::WindowFull(msg.into())
    }
    /// Whether this error is retryable collective backpressure.
    pub fn is_window_full(&self) -> bool {
        matches!(self, Error::WindowFull(_))
    }
    /// Shorthand constructor for service admission refusals.
    pub fn overloaded(msg: impl Into<String>) -> Self {
        Error::Overloaded(msg.into())
    }
    /// Whether this error is a retryable service admission refusal.
    pub fn is_overloaded(&self) -> bool {
        matches!(self, Error::Overloaded(_))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats() {
        let e = Error::Config("bad ranks".into());
        assert_eq!(e.to_string(), "config error: bad ranks");
        let e = Error::Json {
            offset: 12,
            message: "unexpected token".into(),
        };
        assert!(e.to_string().contains("byte 12"));
    }

    #[test]
    fn window_full_is_distinguishable() {
        let e = Error::window_full("depth 2 reached");
        assert!(e.is_window_full());
        assert_eq!(e.to_string(), "collective window full: depth 2 reached");
        assert!(!Error::comm("ring broke").is_window_full());
    }

    #[test]
    fn overloaded_is_distinguishable() {
        let e = Error::overloaded("queue full (4 jobs)");
        assert!(e.is_overloaded());
        assert_eq!(e.to_string(), "service overloaded: queue full (4 jobs)");
        assert!(!Error::config("bad ranks").is_overloaded());
    }

    #[test]
    fn io_conversion() {
        let ioe = std::io::Error::new(std::io::ErrorKind::NotFound, "nope");
        let e: Error = ioe.into();
        assert!(matches!(e, Error::Io(_)));
    }
}
