//! Deterministic pseudo-random number generation.
//!
//! The coordinator owns *all* stochasticity of the workflow (noise vectors,
//! sampler uniforms, bootstrap draws, rank jitter in the simulator), so runs
//! are reproducible given a seed and the exported HLO artifacts stay pure.
//!
//! The generator is PCG64 (O'Neill's PCG XSL-RR 128/64) seeded through
//! SplitMix64 — small, fast, and statistically solid for simulation work.

/// SplitMix64: seed expander / cheap stateless stream splitter.
#[derive(Clone, Copy, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// New stream from a seed.
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    /// Next 64 random bits.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// PCG64 (XSL-RR 128/64).
#[derive(Clone, Debug)]
pub struct Rng {
    state: u128,
    inc: u128,
    /// Cached second normal from Box-Muller.
    spare_normal: Option<f64>,
}

/// The complete serializable state of an [`Rng`] — PCG64 state and stream
/// increment plus the cached Box-Muller spare. Restoring a snapshot
/// resumes the stream exactly where it was taken, which is what makes
/// train-resume checkpoints bit-identical to an uninterrupted run
/// (`model::checkpoint`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RngSnapshot {
    pub state: u128,
    pub inc: u128,
    pub spare_normal: Option<f64>,
}

/// Serialized size of an [`RngSnapshot`] in bytes (16 + 16 + 1 + 8).
pub const RNG_SNAPSHOT_BYTES: usize = 41;

impl RngSnapshot {
    /// Fixed-width little-endian encoding (checkpoint files).
    pub fn to_bytes(&self) -> [u8; RNG_SNAPSHOT_BYTES] {
        let mut b = [0u8; RNG_SNAPSHOT_BYTES];
        b[0..16].copy_from_slice(&self.state.to_le_bytes());
        b[16..32].copy_from_slice(&self.inc.to_le_bytes());
        b[32] = self.spare_normal.is_some() as u8;
        b[33..41].copy_from_slice(&self.spare_normal.unwrap_or(0.0).to_le_bytes());
        b
    }

    /// Inverse of [`Self::to_bytes`].
    pub fn from_bytes(b: &[u8; RNG_SNAPSHOT_BYTES]) -> RngSnapshot {
        let state = u128::from_le_bytes(b[0..16].try_into().unwrap());
        let inc = u128::from_le_bytes(b[16..32].try_into().unwrap());
        let spare = f64::from_le_bytes(b[33..41].try_into().unwrap());
        RngSnapshot {
            state,
            inc,
            spare_normal: (b[32] != 0).then_some(spare),
        }
    }
}

const PCG_MULT: u128 = 0x2360ED051FC65DA44385DF649FCCF645;

impl Rng {
    /// Deterministic RNG from a seed.
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xDA3E39CB94B95BDB)
    }

    /// Deterministic RNG with an explicit stream id (used to derive
    /// independent per-rank streams).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s0 = sm.next_u64();
        let s1 = sm.next_u64();
        let mut sm2 = SplitMix64::new(stream);
        let i0 = sm2.next_u64();
        let i1 = sm2.next_u64();
        let mut rng = Rng {
            state: ((s0 as u128) << 64) | s1 as u128,
            inc: ((((i0 as u128) << 64) | i1 as u128) << 1) | 1,
            spare_normal: None,
        };
        // Decorrelate from seed structure.
        rng.next_u64();
        rng.next_u64();
        rng
    }

    /// Capture the complete stream state (train-resume checkpoints).
    pub fn snapshot(&self) -> RngSnapshot {
        RngSnapshot {
            state: self.state,
            inc: self.inc,
            spare_normal: self.spare_normal,
        }
    }

    /// Rebuild an RNG that continues exactly where `snap` was taken.
    pub fn from_snapshot(snap: &RngSnapshot) -> Rng {
        Rng {
            state: snap.state,
            inc: snap.inc,
            spare_normal: snap.spare_normal,
        }
    }

    /// Derive an independent child stream (e.g. one per rank).
    pub fn split(&mut self, tag: u64) -> Rng {
        let seed = self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15);
        let stream = self.next_u64() ^ tag.rotate_left(17);
        Rng::with_stream(seed, stream)
    }

    /// Next 64 random bits.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        // 53 mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn uniform_f32(&mut self) -> f32 {
        self.uniform() as f32
    }

    /// Uniform integer in [0, n) (Lemire's method, unbiased).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Standard normal (Box-Muller with caching).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Normal f32 with mean/std.
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Lognormal sample with the given *underlying* mu/sigma.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Fill a slice with standard normals (f32).
    pub fn fill_normal(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.normal() as f32;
        }
    }

    /// Fill a slice with uniforms in [0,1) (f32).
    pub fn fill_uniform(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.uniform_f32();
        }
    }

    /// Bootstrap draw: `k` indices sampled with replacement from [0, n).
    pub fn bootstrap_indices(&mut self, n: usize, k: usize, out: &mut Vec<usize>) {
        out.clear();
        out.reserve(k);
        for _ in 0..k {
            out.push(self.below(n as u64) as usize);
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher-Yates).
    pub fn sample_without_replacement(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample {k} from {n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_different_streams() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn split_streams_are_independent() {
        let mut root = Rng::new(42);
        let mut c1 = root.split(0);
        let mut c2 = root.split(1);
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut rng = Rng::new(3);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Rng::new(4);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(5);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = rng.normal();
            s += z;
            s2 += z * z;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn lognormal_positive() {
        let mut rng = Rng::new(6);
        for _ in 0..1000 {
            assert!(rng.lognormal(-1.0, 0.5) > 0.0);
        }
    }

    #[test]
    fn bootstrap_draws_in_range() {
        let mut rng = Rng::new(8);
        let mut idx = Vec::new();
        rng.bootstrap_indices(100, 250, &mut idx);
        assert_eq!(idx.len(), 250);
        assert!(idx.iter().all(|&i| i < 100));
    }

    #[test]
    fn sample_without_replacement_distinct() {
        let mut rng = Rng::new(9);
        let s = rng.sample_without_replacement(20, 10);
        let mut sorted = s.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 10);
    }

    #[test]
    fn snapshot_restores_the_exact_stream() {
        let mut a = Rng::new(123);
        // Burn an odd number of normals so the Box-Muller spare is cached.
        for _ in 0..7 {
            a.normal();
        }
        let snap = a.snapshot();
        let mut b = Rng::from_snapshot(&snap);
        for _ in 0..64 {
            assert_eq!(a.normal().to_bits(), b.normal().to_bits());
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn snapshot_byte_roundtrip() {
        let mut rng = Rng::new(9);
        rng.normal(); // populate the spare
        let snap = rng.snapshot();
        assert_eq!(RngSnapshot::from_bytes(&snap.to_bytes()), snap);
        // And without a spare.
        let snap2 = Rng::new(10).snapshot();
        assert_eq!(snap2.spare_normal, None);
        assert_eq!(RngSnapshot::from_bytes(&snap2.to_bytes()), snap2);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(10);
        let mut xs: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
