//! α-β link cost model.
//!
//! Message cost = `alpha + beta * bytes` — the standard latency/bandwidth
//! decomposition. Two uses:
//!
//! 1. **Injection** in real runs: [`LinkModel::delay_for`] computes a
//!    delivery delay applied to in-process messages so the single-host
//!    topology exhibits network-like timing (intra-node links are cheaper
//!    than inter-node ones, like NVLink vs Slingshot on Polaris).
//! 2. **Accounting** in the discrete-event simulator (`sim::network`),
//!    which uses the same constants to cost the communication schedules of
//!    Figs 11/12.
//!
//! Defaults are Slingshot-11-like for inter-node (α ≈ 2 µs, ~25 GB/s per
//! direction effective) and NVLink-like intra-node (α ≈ 0.7 µs, ~160 GB/s);
//! both include a CPU-staging penalty because the paper off-loads gradients
//! to host memory before communicating (Sec. IV-B6).

use std::time::Duration;

/// Cost constants for one link class.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkCost {
    /// Fixed per-message latency (seconds).
    pub alpha_s: f64,
    /// Per-byte cost (seconds/byte) = 1 / bandwidth.
    pub beta_s_per_byte: f64,
}

impl LinkCost {
    pub fn time_for_bytes(&self, bytes: usize) -> f64 {
        self.alpha_s + self.beta_s_per_byte * bytes as f64
    }
}

/// Link model distinguishing intra-node and inter-node hops.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkModel {
    pub intra_node: LinkCost,
    pub inter_node: LinkCost,
    /// Host staging cost per byte (gradient off-/on-load, Sec. IV-B6).
    pub staging_s_per_byte: f64,
    /// Scale factor on injected delays (0 disables injection in real runs
    /// while keeping accounting available).
    pub injection_scale: f64,
}

impl Default for LinkModel {
    fn default() -> Self {
        LinkModel::polaris_like()
    }
}

impl LinkModel {
    /// Slingshot-11 + NVLink-like constants (see module docs).
    pub fn polaris_like() -> LinkModel {
        LinkModel {
            intra_node: LinkCost {
                alpha_s: 0.7e-6,
                beta_s_per_byte: 1.0 / 160.0e9,
            },
            inter_node: LinkCost {
                alpha_s: 2.0e-6,
                beta_s_per_byte: 1.0 / 25.0e9,
            },
            staging_s_per_byte: 1.0 / 30.0e9,
            injection_scale: 0.0,
        }
    }

    /// Effective constants of the *paper's software stack*: mpi4py moving
    /// pickled numpy gradients through CPU staging. Per-message overheads
    /// are orders of magnitude above raw Slingshot (serialization, Python
    /// call overhead, eager-protocol copies), which is what makes the
    /// unchunked ring visibly expensive at 400 ranks in Fig 11. Constants
    /// are calibrated so the simulated conventional-ARAR 4->400 analysis-
    /// rate gain lands near the paper's ~40x (Fig 12).
    pub fn mpi4py_like() -> LinkModel {
        LinkModel {
            intra_node: LinkCost {
                alpha_s: 20e-6,
                beta_s_per_byte: 1.0 / 5.0e9,
            },
            inter_node: LinkCost {
                alpha_s: 60e-6,
                beta_s_per_byte: 1.0 / 2.0e9,
            },
            staging_s_per_byte: 1.0 / 10.0e9,
            injection_scale: 0.0,
        }
    }

    /// Model with no costs at all (pure in-process runs, unit tests).
    pub fn zero() -> LinkModel {
        LinkModel {
            intra_node: LinkCost {
                alpha_s: 0.0,
                beta_s_per_byte: 0.0,
            },
            inter_node: LinkCost {
                alpha_s: 0.0,
                beta_s_per_byte: 0.0,
            },
            staging_s_per_byte: 0.0,
            injection_scale: 0.0,
        }
    }

    /// Enable latency injection at the given scale (1.0 = modelled cost).
    pub fn with_injection(mut self, scale: f64) -> LinkModel {
        self.injection_scale = scale;
        self
    }

    /// Modelled transfer time between two ranks for a payload.
    pub fn transfer_s(&self, same_node: bool, bytes: usize) -> f64 {
        let link = if same_node {
            &self.intra_node
        } else {
            &self.inter_node
        };
        link.time_for_bytes(bytes)
    }

    /// Staging (off-load + on-load) time for a payload.
    pub fn staging_s(&self, bytes: usize) -> f64 {
        2.0 * self.staging_s_per_byte * bytes as f64
    }

    /// Modelled time for a payload split into `messages` chunks on one
    /// link: the per-message latency α is paid per chunk while the
    /// bandwidth term depends only on the total bytes. `messages = 1`
    /// reduces to [`LinkModel::transfer_s`]; this is the per-chunk
    /// accounting the chunked collectives and the simulator share.
    pub fn chunked_transfer_s(&self, same_node: bool, bytes: usize, messages: usize) -> f64 {
        let link = if same_node {
            &self.intra_node
        } else {
            &self.inter_node
        };
        messages.max(1) as f64 * link.alpha_s + link.beta_s_per_byte * bytes as f64
    }

    /// Delay to inject on a real in-process message (None when injection
    /// is disabled).
    pub fn delay_for(&self, same_node: bool, bytes: usize) -> Option<Duration> {
        if self.injection_scale <= 0.0 {
            return None;
        }
        let s = self.transfer_s(same_node, bytes) * self.injection_scale;
        Some(Duration::from_secs_f64(s))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alpha_beta_decomposition() {
        let c = LinkCost {
            alpha_s: 1e-6,
            beta_s_per_byte: 1e-9,
        };
        assert!((c.time_for_bytes(0) - 1e-6).abs() < 1e-15);
        assert!((c.time_for_bytes(1000) - 2e-6).abs() < 1e-15);
    }

    #[test]
    fn inter_node_slower_than_intra() {
        let m = LinkModel::polaris_like();
        let bytes = 200_000; // ~50k f32 gradients
        assert!(m.transfer_s(false, bytes) > m.transfer_s(true, bytes));
        assert!(m.transfer_s(false, 0) > m.transfer_s(true, 0)); // alpha too
    }

    #[test]
    fn injection_disabled_by_default() {
        let m = LinkModel::polaris_like();
        assert!(m.delay_for(false, 1 << 20).is_none());
        let m = m.with_injection(1.0);
        let d = m.delay_for(false, 1 << 20).unwrap();
        assert!(d > Duration::from_micros(30));
    }

    #[test]
    fn zero_model_costs_nothing() {
        let m = LinkModel::zero();
        assert_eq!(m.transfer_s(true, 12345), 0.0);
        assert_eq!(m.staging_s(999), 0.0);
        assert!(m.delay_for(true, 1).is_none());
    }

    #[test]
    fn chunked_transfer_pays_alpha_per_chunk() {
        let m = LinkModel::polaris_like();
        let bytes = 1 << 20;
        let one = m.chunked_transfer_s(false, bytes, 1);
        assert!((one - m.transfer_s(false, bytes)).abs() < 1e-15);
        let eight = m.chunked_transfer_s(false, bytes, 8);
        // 7 extra α terms, identical bandwidth term.
        let expect = one + 7.0 * m.inter_node.alpha_s;
        assert!((eight - expect).abs() < 1e-15);
        // messages = 0 is clamped to one message.
        assert_eq!(m.chunked_transfer_s(false, bytes, 0), one);
    }

    #[test]
    fn staging_counts_both_directions() {
        let m = LinkModel::polaris_like();
        let one_way = m.staging_s_per_byte * 100.0;
        assert!((m.staging_s(100) - 2.0 * one_way).abs() < 1e-18);
    }
}
