//! Remote Memory Access (RMA) mailboxes.
//!
//! Sec. IV-B3 / Fig 5 of the paper: instead of send/recv rendezvous, a rank
//! *puts* its gradients into a window in the neighbour's memory and the
//! neighbour *gets* them whenever it is ready — so neither side ever waits
//! for the other to finish its (possibly slow, pipeline-stalled) epoch.
//!
//! A window holds `capacity` deposit slots, mirroring a real MPI window
//! sized for one epoch's worth of ring steps (each ring step writes to its
//! own offset within the window). Semantics:
//!
//! * `put` never blocks. If all slots are occupied the *oldest* deposit is
//!   superseded — the staleness the paper accepts by design: a reader that
//!   lags more than a full window behind simply misses those gradients.
//! * `get` fetches deposits in FIFO order if any are present; `get_wait`
//!   spins with a deadline so a reader never deadlocks on a dead/slow
//!   neighbour.
//! * Dropped (superseded) deposits are counted and reported on the next
//!   `get` so the trainer can account staleness (`CommStats::stale_reads`).

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::message::GradMsg;
use crate::util::error::{Error, Result};

/// Single-writer single-reader deposit window.
struct Slot {
    queue: VecDeque<GradMsg>,
    capacity: usize,
    /// Deposits superseded before being read, since the last get.
    dropped: u64,
    /// Monotone count of puts (diagnostics).
    puts: u64,
}

/// Shared window handle. Writer calls [`RmaWindow::put`], reader calls
/// [`RmaWindow::get`] / [`RmaWindow::get_wait`].
#[derive(Clone)]
pub struct RmaWindow {
    inner: Arc<(Mutex<Slot>, Condvar)>,
}

impl Default for RmaWindow {
    fn default() -> Self {
        Self::new(1)
    }
}

impl RmaWindow {
    /// Window with `capacity` deposit slots (>= 1). Capacity 1 gives pure
    /// MPI_Put overwrite semantics; a ring uses one slot per ring step.
    pub fn new(capacity: usize) -> RmaWindow {
        assert!(capacity >= 1);
        RmaWindow {
            inner: Arc::new((
                Mutex::new(Slot {
                    queue: VecDeque::with_capacity(capacity),
                    capacity,
                    dropped: 0,
                    puts: 0,
                }),
                Condvar::new(),
            )),
        }
    }

    /// Deposit gradients; supersedes the oldest unread deposit when the
    /// window is full. Never blocks.
    pub fn put(&self, msg: GradMsg) {
        let (lock, cv) = &*self.inner;
        let mut slot = lock.lock().expect("rma window poisoned");
        if slot.queue.len() == slot.capacity {
            slot.queue.pop_front();
            slot.dropped += 1;
        }
        slot.queue.push_back(msg);
        slot.puts += 1;
        cv.notify_all();
    }

    /// Fetch the oldest unread deposit if present. Never blocks. Returns
    /// `(msg, dropped)` where `dropped` counts deposits superseded unseen
    /// since the previous get.
    pub fn get(&self) -> Option<(GradMsg, u64)> {
        let (lock, _) = &*self.inner;
        let mut slot = lock.lock().expect("rma window poisoned");
        slot.queue.pop_front().map(|m| {
            let d = slot.dropped;
            slot.dropped = 0;
            (m, d)
        })
    }

    /// Fetch, waiting up to `timeout` for a deposit to appear.
    pub fn get_wait(&self, timeout: Duration) -> Option<(GradMsg, u64)> {
        let deadline = Instant::now() + timeout;
        let (lock, cv) = &*self.inner;
        let mut slot = lock.lock().expect("rma window poisoned");
        loop {
            if let Some(m) = slot.queue.pop_front() {
                let d = slot.dropped;
                slot.dropped = 0;
                return Some((m, d));
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (s, _timed_out) = cv
                .wait_timeout(slot, deadline - now)
                .expect("rma window poisoned");
            slot = s;
        }
    }

    /// Number of puts that have occurred (diagnostics).
    pub fn put_count(&self) -> u64 {
        self.inner.0.lock().expect("rma window poisoned").puts
    }

    /// Deposits currently waiting to be read.
    pub fn pending(&self) -> usize {
        self.inner.0.lock().expect("rma window poisoned").queue.len()
    }
}

/// The region: one window per directed (writer -> reader) neighbour pair.
/// Built once by the launcher; ranks clone their handles. Cloning the
/// region clones window *handles* (shared `Arc` state), so a collective can
/// keep a region handle and re-derive rings from it after a membership
/// change without re-allocating any windows.
#[derive(Clone)]
pub struct RmaRegion {
    ranks: usize,
    capacity: usize,
    /// windows[writer][reader]
    windows: Vec<Vec<RmaWindow>>,
}

impl RmaRegion {
    /// Windows with capacity 1 (pure overwrite semantics).
    pub fn new(ranks: usize) -> RmaRegion {
        Self::with_capacity(ranks, 1)
    }

    /// Windows sized for `capacity` outstanding deposits — a ring of size
    /// g wants capacity g-1 (one deposit per ring step of an epoch) so
    /// same-epoch deposits are never superseded.
    pub fn with_capacity(ranks: usize, capacity: usize) -> RmaRegion {
        RmaRegion {
            ranks,
            capacity: capacity.max(1),
            windows: (0..ranks)
                .map(|_| (0..ranks).map(|_| RmaWindow::new(capacity.max(1))).collect())
                .collect(),
        }
    }

    /// Window written by `writer`, read by `reader`.
    pub fn window(&self, writer: usize, reader: usize) -> Result<RmaWindow> {
        if writer >= self.ranks || reader >= self.ranks {
            return Err(Error::comm(format!(
                "window ({writer}, {reader}) out of range for {} ranks",
                self.ranks
            )));
        }
        Ok(self.windows[writer][reader].clone())
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_roundtrip() {
        let w = RmaWindow::new(1);
        w.put(GradMsg::new(0, 3, 0, vec![1.0]));
        let (m, dropped) = w.get().unwrap();
        assert_eq!(m.epoch, 3);
        assert_eq!(dropped, 0);
        assert!(w.get().is_none());
    }

    #[test]
    fn capacity1_put_overwrites_latest_wins() {
        let w = RmaWindow::new(1);
        for e in 0..100 {
            w.put(GradMsg::new(0, e, 0, vec![e as f32]));
        }
        let (m, dropped) = w.get().unwrap();
        assert_eq!(m.epoch, 99); // latest wins
        assert_eq!(dropped, 99);
        assert_eq!(w.put_count(), 100);
    }

    #[test]
    fn capacity_n_preserves_fifo_within_window() {
        let w = RmaWindow::new(3);
        for e in 0..3 {
            w.put(GradMsg::new(0, e, e as u32, vec![]));
        }
        for e in 0..3 {
            let (m, dropped) = w.get().unwrap();
            assert_eq!(m.epoch, e);
            assert_eq!(dropped, 0);
        }
    }

    #[test]
    fn overflow_supersedes_oldest() {
        let w = RmaWindow::new(2);
        for e in 0..4 {
            w.put(GradMsg::new(0, e, 0, vec![]));
        }
        let (m, dropped) = w.get().unwrap();
        assert_eq!(m.epoch, 2); // 0 and 1 superseded
        assert_eq!(dropped, 2);
        let (m, dropped) = w.get().unwrap();
        assert_eq!(m.epoch, 3);
        assert_eq!(dropped, 0);
    }

    #[test]
    fn get_wait_times_out() {
        let w = RmaWindow::new(1);
        let t0 = Instant::now();
        assert!(w.get_wait(Duration::from_millis(20)).is_none());
        assert!(t0.elapsed() >= Duration::from_millis(18));
    }

    #[test]
    fn get_wait_wakes_on_put() {
        let w = RmaWindow::new(1);
        let w2 = w.clone();
        let h = std::thread::spawn(move || w2.get_wait(Duration::from_secs(5)));
        std::thread::sleep(Duration::from_millis(10));
        w.put(GradMsg::new(1, 9, 0, vec![2.5]));
        let (m, _) = h.join().unwrap().unwrap();
        assert_eq!(m.epoch, 9);
        assert_eq!(m.data, vec![2.5]);
    }

    #[test]
    fn region_windows_are_directed() {
        let r = RmaRegion::new(3);
        let w01 = r.window(0, 1).unwrap();
        let w10 = r.window(1, 0).unwrap();
        w01.put(GradMsg::new(0, 1, 0, vec![]));
        assert!(w10.get().is_none());
        assert!(w01.get().is_some());
        assert!(r.window(0, 3).is_err());
    }

    #[test]
    fn pending_tracks_queue_depth() {
        let w = RmaWindow::new(4);
        assert_eq!(w.pending(), 0);
        w.put(GradMsg::new(0, 0, 0, vec![]));
        w.put(GradMsg::new(0, 1, 0, vec![]));
        assert_eq!(w.pending(), 2);
        w.get();
        assert_eq!(w.pending(), 1);
    }

    #[test]
    fn concurrent_writer_reader_no_deadlock() {
        let w = RmaWindow::new(2);
        let writer = {
            let w = w.clone();
            std::thread::spawn(move || {
                for e in 0..1000u64 {
                    w.put(GradMsg::new(0, e, 0, vec![e as f32; 8]));
                }
            })
        };
        let reader = {
            let w = w.clone();
            std::thread::spawn(move || {
                let mut last = -1i64;
                let mut reads = 0;
                while reads < 50 {
                    if let Some((m, _)) = w.get_wait(Duration::from_millis(100)) {
                        assert!(m.epoch as i64 > last, "epochs must move forward");
                        last = m.epoch as i64;
                        reads += 1;
                    } else {
                        break; // writer finished
                    }
                }
            })
        };
        writer.join().unwrap();
        reader.join().unwrap();
    }
}
