//! Shared checkout/recycle pool for gradient payload buffers.
//!
//! The exchange hot path used to allocate a fresh `Vec<f32>` for every
//! message it sent: each [`GradMsg`](crate::comm::GradMsg) payload, the
//! blocking facade's `to_vec()`, the chunked ring's per-call spares.
//! [`BufferPool`] replaces all of that with one slab shared across a
//! run's collectives, engine, and pipeline: buffers are *checked out*
//! at send, travel inside messages, and are *recycled* at
//! receive-apply, so a steady-state epoch touches the allocator zero
//! times (proven by `rust/tests/alloc.rs` and the
//! `benches/micro_collective.rs` counting allocator).
//!
//! # Size classes
//!
//! Buffers are bucketed by the largest power of two ≤ their capacity,
//! and a checkout for `len` elements draws from the bucket of
//! `len.next_power_of_two()` — every buffer in that bucket is
//! guaranteed to hold `len` without reallocating. A miss allocates a
//! buffer of exactly `len.next_power_of_two()` capacity, so it lands
//! back in the same bucket on recycle and the pool converges to one
//! stable working set per size class (full tensors and ring-chunk
//! partitions occupy different classes and never fight each other).
//!
//! # Flow balance
//!
//! In a ring pass every rank sends and receives the same number of
//! buffers, so checkouts and recycles balance per pool even when each
//! rank holds its own pool and buffers migrate around the ring inside
//! messages. [`build_with_policy`](crate::collective::build_with_policy)
//! shares a single pool across all of a run's ranks, which makes the
//! balance global and unconditional (the hierarchical mode's
//! master/member flows are asymmetric per rank but symmetric overall).
//!
//! # Trim policy
//!
//! Mirroring the runtime scratch discipline (DESIGN.md §Throughput),
//! the pool tracks the peak number of concurrently checked-out buffers
//! per bucket and [`trim`](BufferPool::trim) — called at quiescence
//! points such as [`Collective::drain`](crate::collective::Collective)
//! — drops free buffers beyond `4 × peak` (hysteresis so a drain never
//! sheds the working set the next epoch needs). A hard per-bucket cap
//! bounds retention even if trim is never called.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::collective::CommStats;

use super::message::Payload;

/// Power-of-two size-class buckets: `2^0 ..= 2^32` element capacities
/// (a 2^32-f32 gradient is 16 GiB; nothing here gets close).
const BUCKETS: usize = 33;

/// Trim keeps up to `TRIM_HYSTERESIS × peak` free buffers per bucket.
const TRIM_HYSTERESIS: usize = 4;

/// Hard cap on free buffers retained per bucket, enforced at recycle
/// (bounds memory even for asymmetric flows that never drain).
const MAX_FREE_PER_BUCKET: usize = 64;

/// Cumulative pool counters (atomic snapshot; see [`BufferPool::stats`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Checkouts that had to allocate (pool miss).
    pub allocs: u64,
    /// Checkouts served from the free list (pool hit).
    pub hits: u64,
    /// Bytes of buffers returned to the pool.
    pub bytes_recycled: u64,
    /// Free buffers dropped by trim or the per-bucket cap.
    pub trimmed: u64,
    /// Free buffers currently retained.
    pub retained: usize,
    /// Bytes currently retained on the free lists.
    pub retained_bytes: usize,
}

impl PoolStats {
    /// Fraction of checkouts served without allocating (1.0 when the
    /// pool has never missed; 0.0 before the first checkout).
    pub fn hit_rate(&self) -> f64 {
        let total = self.allocs + self.hits;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

#[derive(Default)]
struct Bucket {
    free: Vec<Vec<f32>>,
    /// Checkouts minus recycles; negative when buffers checked out of a
    /// *different* pool were recycled here (per-rank pools in a ring).
    outstanding: i64,
    /// Peak positive `outstanding` since the last trim.
    peak: i64,
}

struct Inner {
    buckets: Mutex<Vec<Bucket>>,
    allocs: AtomicU64,
    hits: AtomicU64,
    bytes_recycled: AtomicU64,
    trimmed: AtomicU64,
}

/// The shared buffer pool (cheaply cloneable handle; clones share one
/// slab). See the module docs for the size-class, balance, and trim
/// contracts.
#[derive(Clone)]
pub struct BufferPool {
    inner: Arc<Inner>,
}

impl Default for BufferPool {
    fn default() -> Self {
        BufferPool::new()
    }
}

impl std::fmt::Debug for BufferPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("BufferPool").field("stats", &self.stats()).finish()
    }
}

/// Bucket a checkout of `len` elements draws from: the class of
/// `len.next_power_of_two()`.
fn checkout_bucket(len: usize) -> usize {
    len.next_power_of_two().trailing_zeros() as usize
}

/// Bucket a buffer of `cap` capacity is retained in: the largest power
/// of two ≤ `cap`, so every retained buffer satisfies its bucket's
/// checkout size without reallocating.
fn recycle_bucket(cap: usize) -> Option<usize> {
    if cap == 0 {
        return None;
    }
    Some((usize::BITS - 1 - cap.leading_zeros()) as usize)
}

impl BufferPool {
    /// An empty pool.
    pub fn new() -> BufferPool {
        let mut buckets = Vec::with_capacity(BUCKETS);
        buckets.resize_with(BUCKETS, Bucket::default);
        BufferPool {
            inner: Arc::new(Inner {
                buckets: Mutex::new(buckets),
                allocs: AtomicU64::new(0),
                hits: AtomicU64::new(0),
                bytes_recycled: AtomicU64::new(0),
                trimmed: AtomicU64::new(0),
            }),
        }
    }

    /// Whether two handles share one slab.
    pub fn same_pool(&self, other: &BufferPool) -> bool {
        Arc::ptr_eq(&self.inner, &other.inner)
    }

    /// Check out a cleared buffer with capacity ≥ `len`. Pool hits and
    /// misses are counted into `stats` (and the cumulative totals).
    pub fn checkout(&self, len: usize, stats: &mut CommStats) -> Vec<f32> {
        if len == 0 {
            stats.pool_hits += 1;
            self.inner.hits.fetch_add(1, Ordering::Relaxed);
            return Vec::new();
        }
        let b = checkout_bucket(len);
        {
            let mut buckets = self.inner.buckets.lock().expect("buffer pool poisoned");
            let bucket = &mut buckets[b];
            bucket.outstanding += 1;
            bucket.peak = bucket.peak.max(bucket.outstanding);
            if let Some(mut buf) = bucket.free.pop() {
                debug_assert!(buf.capacity() >= len);
                buf.clear();
                stats.pool_hits += 1;
                self.inner.hits.fetch_add(1, Ordering::Relaxed);
                return buf;
            }
        }
        stats.allocs += 1;
        self.inner.allocs.fetch_add(1, Ordering::Relaxed);
        Vec::with_capacity(len.next_power_of_two())
    }

    /// Check out a buffer pre-filled with a copy of `src`.
    pub fn checkout_filled(&self, src: &[f32], stats: &mut CommStats) -> Vec<f32> {
        let mut buf = self.checkout(src.len(), stats);
        buf.extend_from_slice(src);
        buf
    }

    /// Return a buffer to the pool. Zero-capacity buffers are dropped;
    /// buffers beyond the per-bucket hard cap are dropped and counted
    /// as trimmed.
    pub fn recycle(&self, buf: Vec<f32>, stats: &mut CommStats) {
        let cap = buf.capacity();
        let Some(b) = recycle_bucket(cap) else {
            return;
        };
        stats.bytes_recycled += (buf.len() * 4) as u64;
        self.inner
            .bytes_recycled
            .fetch_add((buf.len() * 4) as u64, Ordering::Relaxed);
        let mut buckets = self.inner.buckets.lock().expect("buffer pool poisoned");
        let bucket = &mut buckets[b];
        bucket.outstanding -= 1;
        if bucket.free.len() >= MAX_FREE_PER_BUCKET {
            self.inner.trimmed.fetch_add(1, Ordering::Relaxed);
            return; // drop `buf`
        }
        bucket.free.push(buf);
    }

    /// Recycle a message payload: owned buffers return to the pool,
    /// shared ([`Arc`]) payloads are just dropped (the backing slice is
    /// freed when the last receiver drops its clone).
    pub fn recycle_payload(&self, payload: Payload, stats: &mut CommStats) {
        if let Some(buf) = payload.take_owned() {
            self.recycle(buf, stats);
        }
    }

    /// High-water-mark trim (call at quiescence points such as
    /// `drain()`): per bucket, drop free buffers beyond
    /// `4 × peak-outstanding-since-last-trim`, then reset the peak.
    /// The hysteresis keeps the steady-state working set resident so
    /// the epochs after a drain stay allocation-free.
    pub fn trim(&self) {
        let mut buckets = self.inner.buckets.lock().expect("buffer pool poisoned");
        for bucket in buckets.iter_mut() {
            let keep = (bucket.peak.max(1) as usize).saturating_mul(TRIM_HYSTERESIS);
            while bucket.free.len() > keep {
                bucket.free.pop();
                self.inner.trimmed.fetch_add(1, Ordering::Relaxed);
            }
            bucket.peak = bucket.outstanding.max(0);
        }
    }

    /// Snapshot the cumulative counters plus current retention.
    pub fn stats(&self) -> PoolStats {
        let (retained, retained_bytes) = {
            let buckets = self.inner.buckets.lock().expect("buffer pool poisoned");
            let mut n = 0usize;
            let mut bytes = 0usize;
            for b in buckets.iter() {
                n += b.free.len();
                for buf in &b.free {
                    bytes += buf.capacity() * 4;
                }
            }
            (n, bytes)
        };
        PoolStats {
            allocs: self.inner.allocs.load(Ordering::Relaxed),
            hits: self.inner.hits.load(Ordering::Relaxed),
            bytes_recycled: self.inner.bytes_recycled.load(Ordering::Relaxed),
            trimmed: self.inner.trimmed.load(Ordering::Relaxed),
            retained,
            retained_bytes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checkout_miss_then_hit_round_trips_one_buffer() {
        let pool = BufferPool::new();
        let mut stats = CommStats::default();
        let buf = pool.checkout_filled(&[1.0, 2.0, 3.0], &mut stats);
        assert_eq!(buf, vec![1.0, 2.0, 3.0]);
        assert_eq!(stats.allocs, 1);
        assert_eq!(stats.pool_hits, 0);
        let cap = buf.capacity();
        pool.recycle(buf, &mut stats);
        assert_eq!(stats.bytes_recycled, 12);
        // Same size class: served from the free list, same backing
        // capacity, cleared.
        let again = pool.checkout(3, &mut stats);
        assert_eq!(stats.pool_hits, 1);
        assert_eq!(stats.allocs, 1);
        assert!(again.is_empty());
        assert_eq!(again.capacity(), cap);
    }

    #[test]
    fn size_classes_never_serve_undersized_buffers() {
        let pool = BufferPool::new();
        let mut stats = CommStats::default();
        // A foreign buffer with an off-class capacity (100) is retained
        // in the largest class it fully covers (64), so it serves
        // requests up to 64 elements but never a 65..128 request.
        pool.recycle(Vec::with_capacity(100), &mut stats);
        let big = pool.checkout(100, &mut stats);
        assert!(big.capacity() >= 100);
        assert_eq!(stats.allocs, 1, "100-elem checkout must not hit the 64 class");
        let small = pool.checkout(64, &mut stats);
        assert!(small.capacity() >= 64);
        assert_eq!(stats.pool_hits, 1);
        // Pool-allocated buffers are rounded to the class size, so they
        // round-trip into the class that produced them.
        pool.recycle(big, &mut stats);
        let again = pool.checkout(128, &mut stats);
        assert!(again.capacity() >= 128);
        assert_eq!(stats.allocs, 1);
        assert_eq!(stats.pool_hits, 2);
    }

    #[test]
    fn trim_keeps_the_working_set_with_hysteresis() {
        let pool = BufferPool::new();
        let mut stats = CommStats::default();
        // Working set of 2 concurrent buffers, plus 10 idle extras.
        let a = pool.checkout(16, &mut stats);
        let b = pool.checkout(16, &mut stats);
        let extras: Vec<_> = (0..10).map(|_| pool.checkout(16, &mut stats)).collect();
        for e in extras {
            pool.recycle(e, &mut stats);
        }
        pool.recycle(a, &mut stats);
        pool.recycle(b, &mut stats);
        assert_eq!(pool.stats().retained, 12);
        pool.trim(); // peak outstanding was 12 -> nothing to drop
        assert_eq!(pool.stats().retained, 12);
        // Second trim interval only sees a 1-deep working set.
        let c = pool.checkout(16, &mut stats);
        pool.recycle(c, &mut stats);
        pool.trim(); // keep 4 x peak(1) = 4
        let st = pool.stats();
        assert_eq!(st.retained, 4);
        assert_eq!(st.trimmed, 8);
        // The surviving set still serves the next epoch without allocs.
        let before = pool.stats().allocs;
        let d = pool.checkout(16, &mut stats);
        pool.recycle(d, &mut stats);
        assert_eq!(pool.stats().allocs, before);
    }

    #[test]
    fn hard_cap_bounds_retention_without_trim() {
        let pool = BufferPool::new();
        let mut stats = CommStats::default();
        for _ in 0..(MAX_FREE_PER_BUCKET + 10) {
            let buf = pool.checkout(8, &mut stats);
            // Recycle a *second* allocation each round so the free list
            // only ever grows: simulate an asymmetric receiver.
            pool.recycle(buf, &mut stats);
            pool.recycle(Vec::with_capacity(8), &mut stats);
        }
        let st = pool.stats();
        assert!(st.retained <= MAX_FREE_PER_BUCKET);
        assert!(st.trimmed > 0);
    }

    #[test]
    fn shared_payloads_recycle_as_noop() {
        let pool = BufferPool::new();
        let mut stats = CommStats::default();
        let shared = Payload::from(std::sync::Arc::<[f32]>::from(vec![1.0f32; 4]));
        pool.recycle_payload(shared, &mut stats);
        assert_eq!(stats.bytes_recycled, 0);
        assert_eq!(pool.stats().retained, 0);
        let owned = Payload::from(vec![1.0f32; 4]);
        pool.recycle_payload(owned, &mut stats);
        assert_eq!(stats.bytes_recycled, 16);
        assert_eq!(pool.stats().retained, 1);
    }

    #[test]
    fn hit_rate_and_clone_share_one_slab() {
        let pool = BufferPool::new();
        let handle = pool.clone();
        assert!(pool.same_pool(&handle));
        let mut stats = CommStats::default();
        let buf = handle.checkout(4, &mut stats);
        pool.recycle(buf, &mut stats);
        let _ = pool.checkout(4, &mut stats);
        let st = pool.stats();
        assert_eq!((st.allocs, st.hits), (1, 1));
        assert!((st.hit_rate() - 0.5).abs() < 1e-12);
    }
}
