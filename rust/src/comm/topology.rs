//! Rank topology: ring neighbourhoods and the inner/outer grouping.
//!
//! Sec. IV-B4 of the paper: ranks are divided into *inner groups* — one per
//! physical node, sized by the GPUs on that node (4 on Polaris) — which run
//! a ring-all-reduce every epoch, and one *outer group* holding rank 0 of
//! every inner group, which runs a ring-all-reduce every `h` epochs so
//! gradients also flow across nodes (Fig 6, Table I).

/// A versioned snapshot of which ranks are live.
///
/// Elastic membership (ranks joining or leaving mid-run) is expressed as a
/// sequence of `MembershipView`s: every view carries a monotonically
/// increasing `version` plus the sorted set of live ranks out of `total`
/// launched slots. Collectives rebuild their neighbour schedule from a view
/// (see `Collective::set_membership`), and the pipeline uses the version to
/// detect when a quiesce-and-re-ring transition is due. Version 0 is always
/// the full membership the run started with.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MembershipView {
    version: u64,
    live: Vec<usize>,
    total: usize,
}

impl MembershipView {
    /// The full membership: every launched rank live, version 0.
    pub fn full(total: usize) -> MembershipView {
        assert!(total > 0);
        MembershipView {
            version: 0,
            live: (0..total).collect(),
            total,
        }
    }

    /// A view over an explicit live set. The set is sorted and deduplicated;
    /// it must be non-empty and every rank must be `< total`.
    pub fn new(version: u64, mut live: Vec<usize>, total: usize) -> MembershipView {
        live.sort_unstable();
        live.dedup();
        assert!(!live.is_empty(), "membership view must keep >= 1 rank");
        assert!(live.iter().all(|&r| r < total), "live rank out of range");
        MembershipView {
            version,
            live,
            total,
        }
    }

    pub fn version(&self) -> u64 {
        self.version
    }

    /// Live ranks in ascending order.
    pub fn live(&self) -> &[usize] {
        &self.live
    }

    /// Number of live ranks.
    pub fn len(&self) -> usize {
        self.live.len()
    }

    pub fn is_empty(&self) -> bool {
        self.live.is_empty()
    }

    /// Total launched rank slots (live or not).
    pub fn total(&self) -> usize {
        self.total
    }

    pub fn is_live(&self, rank: usize) -> bool {
        self.live.binary_search(&rank).is_ok()
    }
}

/// Immutable description of the rank layout.
#[derive(Clone, Debug)]
pub struct Topology {
    pub ranks: usize,
    pub gpus_per_node: usize,
}

impl Topology {
    pub fn new(ranks: usize, gpus_per_node: usize) -> Topology {
        assert!(ranks > 0 && gpus_per_node > 0);
        Topology {
            ranks,
            gpus_per_node,
        }
    }

    /// Number of nodes (== number of inner groups), last one possibly
    /// partial.
    pub fn nodes(&self) -> usize {
        self.ranks.div_ceil(self.gpus_per_node)
    }

    /// Node (inner group) index of a rank.
    pub fn node_of(&self, rank: usize) -> usize {
        rank / self.gpus_per_node
    }

    /// Global ring successor (Fig 4).
    pub fn ring_next(&self, rank: usize) -> usize {
        (rank + 1) % self.ranks
    }

    /// Global ring predecessor.
    pub fn ring_prev(&self, rank: usize) -> usize {
        (rank + self.ranks - 1) % self.ranks
    }

    /// Members of the inner group containing `rank`, in ring order.
    pub fn inner_group(&self, rank: usize) -> Vec<usize> {
        let node = self.node_of(rank);
        let start = node * self.gpus_per_node;
        let end = ((node + 1) * self.gpus_per_node).min(self.ranks);
        (start..end).collect()
    }

    /// Members of the outer group: the paper fixes these to the first rank
    /// of each inner group ("the rank chosen from each inner group ... is
    /// fixed to be rank 0").
    pub fn outer_group(&self) -> Vec<usize> {
        (0..self.nodes()).map(|n| n * self.gpus_per_node).collect()
    }

    /// Whether `rank` participates in the outer-group ring.
    pub fn is_outer_member(&self, rank: usize) -> bool {
        rank % self.gpus_per_node == 0
    }

    /// All ranks in global ring order.
    pub fn all_ranks(&self) -> Vec<usize> {
        (0..self.ranks).collect()
    }

    /// Members of the inner group containing `rank`, restricted to the
    /// ranks live in `view`, in ring order. May be empty if the whole node
    /// has left.
    pub fn inner_group_live(&self, rank: usize, view: &MembershipView) -> Vec<usize> {
        self.inner_group(rank)
            .into_iter()
            .filter(|&r| view.is_live(r))
            .collect()
    }

    /// The outer group under `view`: the *lowest live* rank of each inner
    /// group (the paper fixes the representative to local rank 0; when that
    /// rank has left, its successor on the node inherits the seat).
    pub fn outer_group_live(&self, view: &MembershipView) -> Vec<usize> {
        (0..self.nodes())
            .filter_map(|n| {
                self.inner_group(n * self.gpus_per_node)
                    .into_iter()
                    .find(|&r| view.is_live(r))
            })
            .collect()
    }

    /// Whether `rank` holds its node's outer-ring seat under `view`.
    pub fn is_outer_member_live(&self, rank: usize, view: &MembershipView) -> bool {
        view.is_live(rank)
            && self
                .inner_group(rank)
                .into_iter()
                .find(|&r| view.is_live(r))
                == Some(rank)
    }

    /// Ring successor/predecessor *within* an ordered member list.
    /// Panics if `rank` is not a member.
    pub fn ring_in(members: &[usize], rank: usize) -> (usize, usize) {
        let idx = members
            .iter()
            .position(|&r| r == rank)
            .expect("rank not in ring");
        let n = members.len();
        (members[(idx + 1) % n], members[(idx + n - 1) % n])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_example_12_ranks_3_nodes() {
        // Fig 6: 12 ranks, 4 GPUs/node -> 3 inner groups + outer {0,4,8}.
        let t = Topology::new(12, 4);
        assert_eq!(t.nodes(), 3);
        assert_eq!(t.inner_group(0), vec![0, 1, 2, 3]);
        assert_eq!(t.inner_group(6), vec![4, 5, 6, 7]);
        assert_eq!(t.inner_group(11), vec![8, 9, 10, 11]);
        assert_eq!(t.outer_group(), vec![0, 4, 8]);
        assert!(t.is_outer_member(4));
        assert!(!t.is_outer_member(5));
    }

    #[test]
    fn global_ring_wraps() {
        let t = Topology::new(5, 4);
        assert_eq!(t.ring_next(4), 0);
        assert_eq!(t.ring_prev(0), 4);
        assert_eq!(t.ring_next(2), 3);
    }

    #[test]
    fn partial_last_node() {
        let t = Topology::new(6, 4);
        assert_eq!(t.nodes(), 2);
        assert_eq!(t.inner_group(5), vec![4, 5]);
        assert_eq!(t.outer_group(), vec![0, 4]);
    }

    #[test]
    fn ring_in_members() {
        let members = vec![0, 4, 8];
        assert_eq!(Topology::ring_in(&members, 4), (8, 0));
        assert_eq!(Topology::ring_in(&members, 8), (0, 4));
    }

    #[test]
    fn single_rank_ring_is_self() {
        let t = Topology::new(1, 4);
        assert_eq!(t.ring_next(0), 0);
        assert_eq!(t.inner_group(0), vec![0]);
    }

    #[test]
    fn full_view_matches_static_topology() {
        let t = Topology::new(12, 4);
        let v = MembershipView::full(12);
        assert_eq!(v.version(), 0);
        assert_eq!(v.len(), 12);
        assert_eq!(t.inner_group_live(6, &v), t.inner_group(6));
        assert_eq!(t.outer_group_live(&v), t.outer_group());
        for r in 0..12 {
            assert_eq!(t.is_outer_member_live(r, &v), t.is_outer_member(r));
        }
    }

    #[test]
    fn leaving_the_node_representative_promotes_the_next_live_rank() {
        // Rank 4 (node 1's seat) leaves: rank 5 inherits the outer seat.
        let t = Topology::new(12, 4);
        let v = MembershipView::new(1, (0..12).filter(|&r| r != 4).collect(), 12);
        assert_eq!(t.inner_group_live(5, &v), vec![5, 6, 7]);
        assert_eq!(t.outer_group_live(&v), vec![0, 5, 8]);
        assert!(t.is_outer_member_live(5, &v));
        assert!(!t.is_outer_member_live(4, &v));
        assert!(!t.is_outer_member_live(6, &v));
    }

    #[test]
    fn whole_node_gone_drops_its_outer_seat() {
        let t = Topology::new(12, 4);
        let v = MembershipView::new(4, (0..12).filter(|&r| r / 4 != 1).collect(), 12);
        assert_eq!(t.inner_group_live(6, &v), Vec::<usize>::new());
        assert_eq!(t.outer_group_live(&v), vec![0, 8]);
    }

    #[test]
    fn view_sorts_and_dedups() {
        let v = MembershipView::new(3, vec![2, 0, 2, 1], 4);
        assert_eq!(v.live(), &[0, 1, 2]);
        assert!(v.is_live(1));
        assert!(!v.is_live(3));
    }

    #[test]
    fn every_rank_in_exactly_one_inner_group() {
        let t = Topology::new(13, 4);
        let mut seen = vec![0u32; 13];
        for node in 0..t.nodes() {
            for r in t.inner_group(node * 4) {
                seen[r] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1));
    }
}
