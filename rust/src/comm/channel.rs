//! A recycling MPSC channel for the exchange hot path.
//!
//! `std::sync::mpsc` allocates a linked-list block roughly every 32
//! messages, which would leave the "steady-state epochs allocate
//! nothing" contract (DESIGN.md §Memory discipline) unprovable no
//! matter how disciplined the payload buffers are. This channel backs
//! the queue with a `VecDeque` that *retains its capacity* across
//! sends, so after the warmup epochs have sized it, enqueue/dequeue
//! never touches the allocator. Endpoint queues
//! ([`transport`](crate::comm::transport)) and the
//! [`CollectiveEngine`](crate::collective::engine::CollectiveEngine)
//! job/done channels both ride on it.
//!
//! The API mirrors the `std::sync::mpsc` subset the comm layer uses —
//! `send` / `recv` / `try_recv` / `recv_timeout`, with the same
//! disconnect semantics (a send to a dropped receiver returns the
//! message; a receive from dropped senders drains the queue first,
//! then errors).

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Send failed: the receiver is gone. Carries the unsent message back.
pub struct SendError<T>(pub T);

impl<T> std::fmt::Debug for SendError<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("SendError(..)")
    }
}

/// Blocking receive failed: every sender is gone and the queue is empty.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RecvError;

/// Non-blocking receive outcome when no message is ready.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TryRecvError {
    /// No message queued right now; senders still live.
    Empty,
    /// Queue empty and every sender is gone.
    Disconnected,
}

/// Timed receive outcome when no message arrived.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RecvTimeoutError {
    /// The timeout elapsed; senders still live.
    Timeout,
    /// Queue empty and every sender is gone.
    Disconnected,
}

struct State<T> {
    queue: VecDeque<T>,
    senders: usize,
    receiver_alive: bool,
}

struct Shared<T> {
    state: Mutex<State<T>>,
    ready: Condvar,
}

/// The sending half (cloneable).
pub struct Sender<T> {
    shared: Arc<Shared<T>>,
}

/// The receiving half.
pub struct Receiver<T> {
    shared: Arc<Shared<T>>,
}

/// Create a connected channel pair.
pub fn channel<T>() -> (Sender<T>, Receiver<T>) {
    let shared = Arc::new(Shared {
        state: Mutex::new(State {
            queue: VecDeque::new(),
            senders: 1,
            receiver_alive: true,
        }),
        ready: Condvar::new(),
    });
    (
        Sender {
            shared: shared.clone(),
        },
        Receiver { shared },
    )
}

impl<T> Sender<T> {
    /// Enqueue a message; never blocks. Errors (returning the message)
    /// once the receiver has been dropped.
    pub fn send(&self, value: T) -> Result<(), SendError<T>> {
        let mut st = self.shared.state.lock().expect("channel poisoned");
        if !st.receiver_alive {
            return Err(SendError(value));
        }
        st.queue.push_back(value);
        drop(st);
        self.shared.ready.notify_one();
        Ok(())
    }
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        self.shared.state.lock().expect("channel poisoned").senders += 1;
        Sender {
            shared: self.shared.clone(),
        }
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        let mut st = self.shared.state.lock().expect("channel poisoned");
        st.senders -= 1;
        let last = st.senders == 0;
        drop(st);
        if last {
            // Wake a blocked receiver so it observes the hangup.
            self.shared.ready.notify_all();
        }
    }
}

impl<T> Receiver<T> {
    /// Blocking receive: waits until a message arrives or every sender
    /// is dropped (queued messages drain before the hangup surfaces).
    pub fn recv(&self) -> Result<T, RecvError> {
        let mut st = self.shared.state.lock().expect("channel poisoned");
        loop {
            if let Some(v) = st.queue.pop_front() {
                return Ok(v);
            }
            if st.senders == 0 {
                return Err(RecvError);
            }
            st = self.shared.ready.wait(st).expect("channel poisoned");
        }
    }

    /// Non-blocking receive.
    pub fn try_recv(&self) -> Result<T, TryRecvError> {
        let mut st = self.shared.state.lock().expect("channel poisoned");
        if let Some(v) = st.queue.pop_front() {
            return Ok(v);
        }
        if st.senders == 0 {
            Err(TryRecvError::Disconnected)
        } else {
            Err(TryRecvError::Empty)
        }
    }

    /// Receive with a deadline.
    pub fn recv_timeout(&self, timeout: Duration) -> Result<T, RecvTimeoutError> {
        let deadline = Instant::now() + timeout;
        let mut st = self.shared.state.lock().expect("channel poisoned");
        loop {
            if let Some(v) = st.queue.pop_front() {
                return Ok(v);
            }
            if st.senders == 0 {
                return Err(RecvTimeoutError::Disconnected);
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(RecvTimeoutError::Timeout);
            }
            let (guard, _timed_out) = self
                .shared
                .ready
                .wait_timeout(st, deadline - now)
                .expect("channel poisoned");
            st = guard;
        }
    }
}

impl<T> Drop for Receiver<T> {
    fn drop(&mut self) {
        let mut st = self.shared.state.lock().expect("channel poisoned");
        st.receiver_alive = false;
        // Undelivered messages drop with the shared state.
        st.queue.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn send_recv_fifo() {
        let (tx, rx) = channel();
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        assert_eq!(rx.recv(), Ok(1));
        assert_eq!(rx.try_recv(), Ok(2));
        assert_eq!(rx.try_recv(), Err(TryRecvError::Empty));
    }

    #[test]
    fn hangup_semantics_match_mpsc() {
        let (tx, rx) = channel();
        tx.send(7).unwrap();
        drop(tx);
        // Queued messages drain before the disconnect surfaces.
        assert_eq!(rx.recv(), Ok(7));
        assert_eq!(rx.recv(), Err(RecvError));
        assert_eq!(rx.try_recv(), Err(TryRecvError::Disconnected));

        let (tx, rx) = channel();
        drop(rx);
        let SendError(back) = tx.send(9).unwrap_err();
        assert_eq!(back, 9);
    }

    #[test]
    fn cloned_senders_keep_the_channel_open() {
        let (tx, rx) = channel();
        let tx2 = tx.clone();
        drop(tx);
        tx2.send(3).unwrap();
        assert_eq!(rx.recv(), Ok(3));
        drop(tx2);
        assert_eq!(rx.recv(), Err(RecvError));
    }

    #[test]
    fn recv_timeout_times_out_then_delivers() {
        let (tx, rx) = channel::<u32>();
        assert_eq!(
            rx.recv_timeout(Duration::from_millis(10)),
            Err(RecvTimeoutError::Timeout)
        );
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            tx.send(5).unwrap();
        });
        assert_eq!(rx.recv_timeout(Duration::from_secs(10)), Ok(5));
        h.join().unwrap();
        assert_eq!(
            rx.recv_timeout(Duration::from_millis(5)),
            Err(RecvTimeoutError::Disconnected)
        );
    }

    #[test]
    fn blocking_recv_wakes_on_cross_thread_send() {
        let (tx, rx) = channel();
        let h = std::thread::spawn(move || rx.recv());
        std::thread::sleep(Duration::from_millis(10));
        tx.send(42).unwrap();
        assert_eq!(h.join().unwrap(), Ok(42));
    }

    #[test]
    fn queue_capacity_is_retained_across_epochs() {
        // The zero-allocation contract: once warmed, a send/recv cycle
        // must not grow the backing queue again.
        let (tx, rx) = channel();
        for i in 0..64 {
            tx.send(i).unwrap();
        }
        let cap = tx.shared.state.lock().unwrap().queue.capacity();
        for _ in 0..64 {
            rx.recv().unwrap();
        }
        for _ in 0..10 {
            for i in 0..64 {
                tx.send(i).unwrap();
            }
            for _ in 0..64 {
                rx.recv().unwrap();
            }
        }
        assert_eq!(tx.shared.state.lock().unwrap().queue.capacity(), cap);
    }
}
