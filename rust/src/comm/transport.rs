//! Addressed point-to-point transport between in-process ranks.
//!
//! Reproduces the mpi4py primitives the paper uses: `isend` never blocks
//! (unbounded buffered links, like MPI eager sends of the ~200 KB gradient
//! messages), `recv(from)` blocks until a message from that specific
//! sender arrives, `try_recv(from)` polls. Every ordered rank pair gets a
//! dedicated FIFO link, so per-sender ordering matches MPI's non-overtaking
//! guarantee. Links ride the capacity-retaining
//! [`channel`](super::channel) (not `std::sync::mpsc`, which allocates a
//! block per ~32 messages), so a warmed link enqueues without touching
//! the allocator — half of the zero-allocation steady-state contract,
//! the pooled payloads being the other half.

use std::collections::HashMap;
use std::sync::Arc;

use super::channel::{channel, Receiver, Sender, TryRecvError};

use super::link_model::LinkModel;
use super::message::GradMsg;
use super::topology::Topology;
use crate::fault::FaultPlan;
use crate::util::error::{Error, Result};

/// One rank's view of the network.
pub struct Endpoint {
    pub rank: usize,
    topo: Topology,
    link_model: LinkModel,
    /// Deterministic fault injection, keyed by (sender rank, msg epoch).
    faults: Option<Arc<FaultPlan>>,
    /// Senders to every peer: `tx[to]` is the link (self -> to).
    tx: HashMap<usize, Sender<GradMsg>>,
    /// Receivers from every peer: `rx[from]` is the link (from -> self).
    rx: HashMap<usize, Receiver<GradMsg>>,
}

impl Endpoint {
    /// Non-blocking send (MPI isend). Applies the link model's injected
    /// delay — plus any fault-plan delay for (sender, epoch) — as a
    /// delivery timestamp realized on the receiver side, so a stalled or
    /// jittery sender never blocks its own compute, only its peers' view
    /// of it.
    pub fn isend(&self, to: usize, mut msg: GradMsg) -> Result<()> {
        msg.from = self.rank;
        let same_node = self.topo.node_of(self.rank) == self.topo.node_of(to);
        let mut delay = self
            .link_model
            .delay_for(same_node, msg.bytes())
            .unwrap_or_default();
        if let Some(plan) = &self.faults {
            if let Some(d) = plan.send_delay(self.rank, msg.epoch) {
                delay += d;
            }
        }
        if !delay.is_zero() {
            msg.deliver_at = Some(std::time::Instant::now() + delay);
        }
        self.tx
            .get(&to)
            .ok_or_else(|| Error::comm(format!("rank {} has no link to {}", self.rank, to)))?
            .send(msg)
            .map_err(|_| Error::comm(format!("link {} -> {} disconnected", self.rank, to)))
    }

    /// Blocking receive from a specific sender (MPI recv with source).
    pub fn recv(&self, from: usize) -> Result<GradMsg> {
        let msg = self
            .rx
            .get(&from)
            .ok_or_else(|| Error::comm(format!("rank {} has no link from {}", self.rank, from)))?
            .recv()
            .map_err(|_| Error::comm(format!("link {} -> {} disconnected", from, self.rank)))?;
        msg.wait_delivery();
        Ok(msg)
    }

    /// Non-blocking receive (MPI iprobe + recv). Returns `Ok(None)` when
    /// no message is waiting. An undelivered (still "in flight" per the
    /// link model) message is *not* returned early.
    pub fn try_recv(&self, from: usize) -> Result<Option<GradMsg>> {
        let rx = self
            .rx
            .get(&from)
            .ok_or_else(|| Error::comm(format!("rank {} has no link from {}", self.rank, from)))?;
        match rx.try_recv() {
            Ok(msg) => {
                msg.wait_delivery();
                Ok(Some(msg))
            }
            Err(TryRecvError::Empty) => Ok(None),
            Err(TryRecvError::Disconnected) => Err(Error::comm(format!(
                "link {} -> {} disconnected",
                from, self.rank
            ))),
        }
    }

    /// Drain everything currently queued from `from`, returning the last
    /// (most recent) message — used by staleness-tolerant readers.
    pub fn recv_latest(&self, from: usize) -> Result<Option<GradMsg>> {
        let mut latest = None;
        while let Some(m) = self.try_recv(from)? {
            latest = Some(m);
        }
        Ok(latest)
    }

    pub fn topology(&self) -> &Topology {
        &self.topo
    }
}

/// Builder for the full in-process network: one [`Endpoint`] per rank with
/// dedicated links between every ordered pair.
pub struct LocalNetwork;

impl LocalNetwork {
    /// Create endpoints for `topo.ranks` ranks.
    pub fn build(topo: &Topology, link_model: LinkModel) -> Vec<Endpoint> {
        Self::build_with_faults(topo, link_model, None)
    }

    /// Create endpoints with a shared deterministic [`FaultPlan`] injected
    /// beneath every rank's sends.
    pub fn build_with_faults(
        topo: &Topology,
        link_model: LinkModel,
        faults: Option<Arc<FaultPlan>>,
    ) -> Vec<Endpoint> {
        let n = topo.ranks;
        // channels[from][to]
        let mut senders: Vec<HashMap<usize, Sender<GradMsg>>> =
            (0..n).map(|_| HashMap::new()).collect();
        let mut receivers: Vec<HashMap<usize, Receiver<GradMsg>>> =
            (0..n).map(|_| HashMap::new()).collect();
        for from in 0..n {
            for to in 0..n {
                if from == to {
                    continue;
                }
                let (tx, rx) = channel();
                senders[from].insert(to, tx);
                receivers[to].insert(from, rx);
            }
        }
        senders
            .into_iter()
            .zip(receivers)
            .enumerate()
            .map(|(rank, (tx, rx))| Endpoint {
                rank,
                topo: topo.clone(),
                link_model,
                faults: faults.clone(),
                tx,
                rx,
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn net(n: usize) -> Vec<Endpoint> {
        LocalNetwork::build(&Topology::new(n, 4), LinkModel::zero())
    }

    #[test]
    fn send_recv_roundtrip() {
        let eps = net(2);
        eps[0]
            .isend(1, GradMsg::new(0, 7, 0, vec![1.0, 2.0]))
            .unwrap();
        let m = eps[1].recv(0).unwrap();
        assert_eq!(m.from, 0);
        assert_eq!(m.epoch, 7);
        assert_eq!(m.data, vec![1.0, 2.0]);
    }

    #[test]
    fn per_sender_fifo_ordering() {
        let eps = net(2);
        for i in 0..10 {
            eps[0]
                .isend(1, GradMsg::new(0, i, 0, vec![i as f32]))
                .unwrap();
        }
        for i in 0..10 {
            assert_eq!(eps[1].recv(0).unwrap().epoch, i);
        }
    }

    #[test]
    fn try_recv_polls() {
        let eps = net(2);
        assert!(eps[1].try_recv(0).unwrap().is_none());
        eps[0].isend(1, GradMsg::new(0, 0, 0, vec![])).unwrap();
        assert!(eps[1].try_recv(0).unwrap().is_some());
        assert!(eps[1].try_recv(0).unwrap().is_none());
    }

    #[test]
    fn recv_latest_drains() {
        let eps = net(2);
        for i in 0..5 {
            eps[0].isend(1, GradMsg::new(0, i, 0, vec![])).unwrap();
        }
        let m = eps[1].recv_latest(0).unwrap().unwrap();
        assert_eq!(m.epoch, 4);
        assert!(eps[1].recv_latest(0).unwrap().is_none());
    }

    #[test]
    fn isend_never_blocks() {
        // Thousands of queued messages with no receiver progress.
        let eps = net(2);
        for i in 0..5_000 {
            eps[0]
                .isend(1, GradMsg::new(0, i, 0, vec![0.0; 16]))
                .unwrap();
        }
    }

    #[test]
    fn addressed_links_are_isolated() {
        let eps = net(3);
        eps[0].isend(2, GradMsg::new(0, 1, 0, vec![])).unwrap();
        eps[1].isend(2, GradMsg::new(1, 2, 0, vec![])).unwrap();
        // Receiving from 1 does not consume 0's message.
        assert_eq!(eps[2].recv(1).unwrap().epoch, 2);
        assert_eq!(eps[2].recv(0).unwrap().epoch, 1);
    }

    #[test]
    fn missing_link_is_error() {
        let eps = net(2);
        assert!(eps[0].isend(0, GradMsg::new(0, 0, 0, vec![])).is_err());
        assert!(eps[0].recv(5).is_err());
    }

    #[test]
    fn threaded_ring_pass() {
        // 4 ranks forward a token around the ring concurrently.
        let eps = net(4);
        let handles: Vec<_> = eps
            .into_iter()
            .map(|ep| {
                std::thread::spawn(move || {
                    let n = 4;
                    let next = (ep.rank + 1) % n;
                    let prev = (ep.rank + n - 1) % n;
                    ep.isend(next, GradMsg::new(ep.rank, 0, 0, vec![ep.rank as f32]))
                        .unwrap();
                    let got = ep.recv(prev).unwrap();
                    assert_eq!(got.from, prev);
                    got.data[0]
                })
            })
            .collect();
        let sum: f32 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(sum, 0.0 + 1.0 + 2.0 + 3.0);
    }

    #[test]
    fn fault_plan_stall_delays_only_the_stalled_sender() {
        let topo = Topology::new(2, 4);
        let plan = Arc::new(FaultPlan::new(5).with_stall(0, 3, 1, 40));
        let eps = LocalNetwork::build_with_faults(&topo, LinkModel::zero(), Some(plan));
        // Epoch outside the stall window: immediate.
        eps[0].isend(1, GradMsg::new(0, 0, 0, vec![])).unwrap();
        let t0 = std::time::Instant::now();
        eps[1].recv(0).unwrap();
        assert!(t0.elapsed() < std::time::Duration::from_millis(20));
        // Stalled epoch: held for the stall duration.
        eps[0].isend(1, GradMsg::new(0, 3, 0, vec![])).unwrap();
        let t0 = std::time::Instant::now();
        assert_eq!(eps[1].recv(0).unwrap().epoch, 3);
        assert!(t0.elapsed() >= std::time::Duration::from_millis(30));
        // The healthy rank is untouched at the same epoch.
        eps[1].isend(0, GradMsg::new(1, 3, 0, vec![])).unwrap();
        let t0 = std::time::Instant::now();
        eps[0].recv(1).unwrap();
        assert!(t0.elapsed() < std::time::Duration::from_millis(20));
    }

    #[test]
    fn injected_latency_delays_delivery() {
        let topo = Topology::new(2, 1); // 2 nodes -> inter-node link
        let lm = LinkModel {
            inter_node: super::super::link_model::LinkCost {
                alpha_s: 0.02,
                beta_s_per_byte: 0.0,
            },
            ..LinkModel::zero()
        }
        .with_injection(1.0);
        let eps = LocalNetwork::build(&topo, lm);
        eps[0].isend(1, GradMsg::new(0, 0, 0, vec![])).unwrap();
        let t0 = std::time::Instant::now();
        eps[1].recv(0).unwrap();
        assert!(t0.elapsed() >= std::time::Duration::from_millis(15));
    }
}
