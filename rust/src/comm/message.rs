//! Gradient messages moved between ranks.

use std::ops::Deref;
use std::sync::Arc;
use std::time::Instant;

/// A message payload: either an owned buffer (checked out of the
/// [`BufferPool`](crate::comm::BufferPool) at send, recycled at
/// receive-apply) or a shared slice (one allocation fanned out to many
/// receivers, e.g. the hierarchical master's broadcast). Derefs to
/// `[f32]`, so receive paths read `&msg.data` exactly as before.
#[derive(Clone, Debug)]
pub enum Payload {
    /// Exclusively owned buffer; recyclable into the pool.
    Owned(Vec<f32>),
    /// Reference-counted slice shared across receivers; dropped, not
    /// recycled (the backing allocation frees with the last clone).
    Shared(Arc<[f32]>),
}

impl Payload {
    /// The owned buffer, if this payload is exclusively owned.
    /// `Shared` payloads return `None` (they cannot be recycled).
    pub fn take_owned(self) -> Option<Vec<f32>> {
        match self {
            Payload::Owned(v) => Some(v),
            Payload::Shared(_) => None,
        }
    }

    /// The payload as a slice (also available through `Deref`).
    pub fn as_slice(&self) -> &[f32] {
        self
    }
}

impl Deref for Payload {
    type Target = [f32];

    fn deref(&self) -> &[f32] {
        match self {
            Payload::Owned(v) => v,
            Payload::Shared(s) => s,
        }
    }
}

impl From<Vec<f32>> for Payload {
    fn from(v: Vec<f32>) -> Payload {
        Payload::Owned(v)
    }
}

impl From<Arc<[f32]>> for Payload {
    fn from(s: Arc<[f32]>) -> Payload {
        Payload::Shared(s)
    }
}

impl PartialEq for Payload {
    fn eq(&self, other: &Payload) -> bool {
        **self == **other
    }
}

impl PartialEq<Vec<f32>> for Payload {
    fn eq(&self, other: &Vec<f32>) -> bool {
        **self == other[..]
    }
}

impl PartialEq<[f32]> for Payload {
    fn eq(&self, other: &[f32]) -> bool {
        **self == *other
    }
}

/// A gradient transfer: the packed (fusion-planned) gradient buffer plus
/// the metadata needed for staleness accounting and delivery modelling.
#[derive(Clone, Debug)]
pub struct GradMsg {
    /// Sender rank.
    pub from: usize,
    /// Training epoch the gradients belong to.
    pub epoch: u64,
    /// Ring step within the epoch (disambiguates the N-1 messages of one
    /// ring pass; chunked passes use 2·(N-1) steps).
    pub step: u32,
    /// Partition (chunk) index the payload belongs to. Unchunked passes
    /// always send chunk 0 = the whole tensor; chunked reduce-scatter /
    /// all-gather passes send one partition per message so the receiver
    /// can place (and sanity-check) the slice it accumulates.
    pub chunk: u32,
    /// Earliest wall-clock instant the receiver may observe the message
    /// (link-model latency injection; `None` = immediate).
    pub deliver_at: Option<Instant>,
    /// Packed gradient payload (owned/pooled or shared; see [`Payload`]).
    pub data: Payload,
}

impl GradMsg {
    pub fn new(from: usize, epoch: u64, step: u32, data: impl Into<Payload>) -> GradMsg {
        GradMsg {
            from,
            epoch,
            step,
            chunk: 0,
            deliver_at: None,
            data: data.into(),
        }
    }

    /// A chunk-indexed message (one partition of a chunked ring pass).
    pub fn chunked(
        from: usize,
        epoch: u64,
        step: u32,
        chunk: u32,
        data: impl Into<Payload>,
    ) -> GradMsg {
        GradMsg {
            chunk,
            ..GradMsg::new(from, epoch, step, data)
        }
    }

    /// Payload size in bytes (f32).
    pub fn bytes(&self) -> usize {
        self.data.len() * 4
    }

    /// Block the calling thread until the delivery instant has passed
    /// (receiver-side latency realization).
    pub fn wait_delivery(&self) {
        if let Some(at) = self.deliver_at {
            let now = Instant::now();
            if at > now {
                std::thread::sleep(at - now);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn bytes_counts_payload() {
        let m = GradMsg::new(0, 1, 2, vec![0.0; 10]);
        assert_eq!(m.bytes(), 40);
        assert_eq!(m.from, 0);
        assert_eq!(m.epoch, 1);
        assert_eq!(m.step, 2);
        assert_eq!(m.chunk, 0); // unchunked = whole tensor
    }

    #[test]
    fn chunked_constructor_carries_partition_index() {
        let m = GradMsg::chunked(3, 7, 5, 2, vec![1.0; 4]);
        assert_eq!(m.from, 3);
        assert_eq!(m.epoch, 7);
        assert_eq!(m.step, 5);
        assert_eq!(m.chunk, 2);
        assert_eq!(m.bytes(), 16);
    }

    #[test]
    fn wait_delivery_blocks_until_instant() {
        let mut m = GradMsg::new(0, 0, 0, Vec::new());
        m.deliver_at = Some(Instant::now() + Duration::from_millis(10));
        let t0 = Instant::now();
        m.wait_delivery();
        assert!(t0.elapsed() >= Duration::from_millis(9));
        // No deliver_at: returns immediately.
        let m2 = GradMsg::new(0, 0, 0, Vec::new());
        let t1 = Instant::now();
        m2.wait_delivery();
        assert!(t1.elapsed() < Duration::from_millis(5));
    }

    #[test]
    fn shared_payloads_read_like_owned_ones() {
        let shared: Arc<[f32]> = Arc::from(vec![1.0f32, 2.0, 3.0]);
        let m = GradMsg::new(1, 0, 0, shared.clone());
        assert_eq!(m.bytes(), 12);
        assert_eq!(m.data[1], 2.0);
        assert_eq!(m.data, vec![1.0, 2.0, 3.0]);
        // Shared payloads cannot be reclaimed as owned buffers...
        assert!(m.data.take_owned().is_none());
        // ...owned ones can, without copying.
        let m = GradMsg::new(1, 0, 0, vec![5.0f32; 4]);
        assert_eq!(m.data.take_owned(), Some(vec![5.0; 4]));
    }
}
