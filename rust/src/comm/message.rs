//! Gradient messages moved between ranks.

use std::time::Instant;

/// A gradient transfer: the packed (fusion-planned) gradient buffer plus
/// the metadata needed for staleness accounting and delivery modelling.
#[derive(Clone, Debug)]
pub struct GradMsg {
    /// Sender rank.
    pub from: usize,
    /// Training epoch the gradients belong to.
    pub epoch: u64,
    /// Ring step within the epoch (disambiguates the N-1 messages of one
    /// ring pass; chunked passes use 2·(N-1) steps).
    pub step: u32,
    /// Partition (chunk) index the payload belongs to. Unchunked passes
    /// always send chunk 0 = the whole tensor; chunked reduce-scatter /
    /// all-gather passes send one partition per message so the receiver
    /// can place (and sanity-check) the slice it accumulates.
    pub chunk: u32,
    /// Earliest wall-clock instant the receiver may observe the message
    /// (link-model latency injection; `None` = immediate).
    pub deliver_at: Option<Instant>,
    /// Packed gradient payload.
    pub data: Vec<f32>,
}

impl GradMsg {
    pub fn new(from: usize, epoch: u64, step: u32, data: Vec<f32>) -> GradMsg {
        GradMsg {
            from,
            epoch,
            step,
            chunk: 0,
            deliver_at: None,
            data,
        }
    }

    /// A chunk-indexed message (one partition of a chunked ring pass).
    pub fn chunked(from: usize, epoch: u64, step: u32, chunk: u32, data: Vec<f32>) -> GradMsg {
        GradMsg {
            chunk,
            ..GradMsg::new(from, epoch, step, data)
        }
    }

    /// Payload size in bytes (f32).
    pub fn bytes(&self) -> usize {
        self.data.len() * 4
    }

    /// Block the calling thread until the delivery instant has passed
    /// (receiver-side latency realization).
    pub fn wait_delivery(&self) {
        if let Some(at) = self.deliver_at {
            let now = Instant::now();
            if at > now {
                std::thread::sleep(at - now);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn bytes_counts_payload() {
        let m = GradMsg::new(0, 1, 2, vec![0.0; 10]);
        assert_eq!(m.bytes(), 40);
        assert_eq!(m.from, 0);
        assert_eq!(m.epoch, 1);
        assert_eq!(m.step, 2);
        assert_eq!(m.chunk, 0); // unchunked = whole tensor
    }

    #[test]
    fn chunked_constructor_carries_partition_index() {
        let m = GradMsg::chunked(3, 7, 5, 2, vec![1.0; 4]);
        assert_eq!(m.from, 3);
        assert_eq!(m.epoch, 7);
        assert_eq!(m.step, 5);
        assert_eq!(m.chunk, 2);
        assert_eq!(m.bytes(), 16);
    }

    #[test]
    fn wait_delivery_blocks_until_instant() {
        let mut m = GradMsg::new(0, 0, 0, vec![]);
        m.deliver_at = Some(Instant::now() + Duration::from_millis(10));
        let t0 = Instant::now();
        m.wait_delivery();
        assert!(t0.elapsed() >= Duration::from_millis(9));
        // No deliver_at: returns immediately.
        let m2 = GradMsg::new(0, 0, 0, vec![]);
        let t1 = Instant::now();
        m2.wait_delivery();
        assert!(t1.elapsed() < Duration::from_millis(5));
    }
}
