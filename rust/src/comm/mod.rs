//! The communication substrate: simulated MPI ranks.
//!
//! The paper drives gradient exchange through mpi4py (non-blocking
//! point-to-point isend/irecv, plus RMA windows). Here each rank is an
//! in-process thread and this module reproduces those primitives with the
//! same observable semantics:
//!
//! * [`transport`] — addressed point-to-point links: non-blocking `isend`,
//!   blocking `recv`, polling `try_recv` (mpi4py's isend/recv pair).
//! * [`rma`] — remote-memory-access mailboxes: `put` overwrites the target
//!   window without any rendezvous, `get` fetches the latest value if one
//!   is present (MPI_Put/MPI_Get on a window, Fig 5 of the paper).
//! * [`link_model`] — an α-β cost model that can inject per-message
//!   latency so single-host runs exhibit network-like timing.
//! * [`topology`] — ring neighbourhoods and the inner/outer grouping of
//!   Sec. IV-B4.
//! * [`pool`] — the shared checkout/recycle buffer pool that keeps the
//!   steady-state exchange path allocation-free, and [`channel`], the
//!   capacity-retaining queue beneath the transports (DESIGN.md
//!   §Memory discipline).

pub mod channel;
pub mod link_model;
pub mod message;
pub mod pool;
pub mod rma;
pub mod topology;
pub mod transport;

pub use link_model::LinkModel;
pub use message::{GradMsg, Payload};
pub use pool::{BufferPool, PoolStats};
pub use rma::{RmaRegion, RmaWindow};
pub use topology::{MembershipView, Topology};
pub use transport::{Endpoint, LocalNetwork};
