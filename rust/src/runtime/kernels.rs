//! Cache-blocked, autovectorizer-friendly matrix kernels for the native
//! backend's hot path.
//!
//! Every dense mat-op the native backend performs — the layer forward
//! `out = xW + b`, the weight gradient `dW += XᵀdPre`, the input gradient
//! `dX = dPre·Wᵀ`, and the bias column sums — routes through this module
//! (`model::reference` forward, `model::grad` backward, and therefore the
//! fused `gan_step`). Two implementations sit behind the [`Kernels`]
//! selector:
//!
//! * [`Kernels::Scalar`] — the frozen naive triple loops the backend
//!   shipped with. Kept verbatim as the parity oracle for tests and the
//!   bench's baseline column; never tuned further.
//! * [`Kernels::Blocked`] — register-tiled ([`MR`] rows share one weight
//!   row load) and cache-blocked ([`KC`] k-panel, [`IC`] output-row panel)
//!   loops whose inner statements are unit-stride slice zips with no
//!   data-dependent branches, so the autovectorizer lowers them to SIMD.
//!
//! Numerics contract (load-bearing for seed reproducibility and for the
//! `intra_threads` determinism guarantee in `runtime::native`):
//!
//! * [`matmul_bias`] and [`matmul_at_b_acc`] accumulate each output
//!   element in **ascending k / ascending r order** — exactly the order
//!   the scalar loops use — so the blocked forward and weight-gradient
//!   paths are *bit-identical* to the scalar ones. Tiling only reorders
//!   *across* independent output elements, never within one.
//! * [`matmul_abt`] replaces the scalar single-accumulator dot with a
//!   fixed 8-lane accumulation ([`dot8`]); its results differ from the
//!   scalar path by f32 rounding only, but the lane split and the final
//!   reduction order are fixed, so the blocked path is deterministic and
//!   independent of batch chunking or thread count.
//! * f32 accumulate throughout; the loss reductions stay f64 in
//!   `runtime::native` (unchanged by this module).
//!
//! The module also owns the FLOP accounting ([`gan_step_flops`] et al.)
//! used by `benches/micro_runtime.rs` to turn step latencies into GFLOP/s.

use super::manifest::{LayerLayout, ModelMeta};

/// Register tile height: output rows computed together in the blocked
/// kernels, sharing each loaded weight/cotangent row.
pub const MR: usize = 4;

/// k-dimension panel for [`matmul_bias`]: the `x` columns consumed per
/// sweep stay resident while [`MR`] output rows accumulate.
pub const KC: usize = 256;

/// Output-row panel for [`matmul_at_b_acc`]: at most this many rows of
/// `dW` (each `n` wide) are kept hot while sweeping the batch, so the
/// accumulator panel fits L1 for the model widths this repo uses.
pub const IC: usize = 32;

/// Which kernel implementation the native backend executes.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Kernels {
    /// Frozen naive loops: parity oracle and bench baseline.
    Scalar,
    /// Register-tiled + cache-blocked loops (the default).
    #[default]
    Blocked,
}

impl Kernels {
    /// Label for bench rows and logs.
    pub fn name(self) -> &'static str {
        match self {
            Kernels::Scalar => "scalar",
            Kernels::Blocked => "blocked",
        }
    }

    /// `out = x·W (+ bias)`: `x` (m, k), `w` (k, n), `out` (m, n)
    /// overwritten. Row-major, contiguous.
    pub fn matmul_bias(
        self,
        x: &[f32],
        w: &[f32],
        bias: Option<&[f32]>,
        m: usize,
        k: usize,
        n: usize,
        out: &mut [f32],
    ) {
        match self {
            Kernels::Scalar => scalar::matmul_bias(x, w, bias, m, k, n, out),
            Kernels::Blocked => matmul_bias(x, w, bias, m, k, n, out),
        }
    }

    /// `c += aᵀ·b`: `a` (m, k), `b` (m, n), `c` (k, n) accumulated —
    /// the weight gradient `dW += XᵀdPre`.
    pub fn matmul_at_b_acc(
        self,
        a: &[f32],
        b: &[f32],
        m: usize,
        k: usize,
        n: usize,
        c: &mut [f32],
    ) {
        match self {
            Kernels::Scalar => scalar::matmul_at_b_acc(a, b, m, k, n, c),
            Kernels::Blocked => matmul_at_b_acc(a, b, m, k, n, c),
        }
    }

    /// `c = a·bᵀ`: `a` (m, k), `b` (n, k), `c` (m, n) overwritten —
    /// the input gradient `dX = dPre·Wᵀ` (each `c` element is a dot of
    /// two contiguous rows).
    pub fn matmul_abt(self, a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
        match self {
            Kernels::Scalar => scalar::matmul_abt(a, b, m, k, n, c),
            Kernels::Blocked => matmul_abt(a, b, m, k, n, c),
        }
    }

    /// `db[j] += Σ_r b[r, j]` — the bias gradient column sums.
    pub fn col_sums_acc(self, b: &[f32], m: usize, n: usize, db: &mut [f32]) {
        // One unit-stride accumulation loop serves both variants; there
        // is nothing to block.
        scalar::col_sums_acc(b, m, n, db);
    }
}

// ---------------------------------------------------------------------
// Blocked implementations
// ---------------------------------------------------------------------

/// Blocked `out = x·W (+ bias)`. Per output element the k accumulation
/// order is ascending — bit-identical to [`scalar::matmul_bias`].
pub fn matmul_bias(
    x: &[f32],
    w: &[f32],
    bias: Option<&[f32]>,
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    match bias {
        Some(b) => {
            debug_assert_eq!(b.len(), n);
            for orow in out.chunks_exact_mut(n) {
                orow.copy_from_slice(b);
            }
        }
        None => out.fill(0.0),
    }
    if m == 0 || k == 0 || n == 0 {
        return;
    }
    let mut k0 = 0;
    while k0 < k {
        let kb = KC.min(k - k0);
        let mut r = 0;
        // MR-row register tile: four output rows accumulate against one
        // streamed weight row, so each `w` row is loaded once per tile
        // instead of once per output row.
        while r + MR <= m {
            let tile = &mut out[r * n..(r + MR) * n];
            let (o0, rest) = tile.split_at_mut(n);
            let (o1, rest) = rest.split_at_mut(n);
            let (o2, o3) = rest.split_at_mut(n);
            for kk in k0..k0 + kb {
                let wrow = &w[kk * n..kk * n + n];
                let x0 = x[r * k + kk];
                let x1 = x[(r + 1) * k + kk];
                let x2 = x[(r + 2) * k + kk];
                let x3 = x[(r + 3) * k + kk];
                for ((((o0v, o1v), o2v), o3v), &wv) in o0
                    .iter_mut()
                    .zip(o1.iter_mut())
                    .zip(o2.iter_mut())
                    .zip(o3.iter_mut())
                    .zip(wrow)
                {
                    *o0v += x0 * wv;
                    *o1v += x1 * wv;
                    *o2v += x2 * wv;
                    *o3v += x3 * wv;
                }
            }
            r += MR;
        }
        // Tail rows (m % MR).
        while r < m {
            let orow = &mut out[r * n..(r + 1) * n];
            for kk in k0..k0 + kb {
                let wrow = &w[kk * n..kk * n + n];
                let xv = x[r * k + kk];
                for (o, &wv) in orow.iter_mut().zip(wrow) {
                    *o += xv * wv;
                }
            }
            r += 1;
        }
        k0 += kb;
    }
}

/// Blocked `c += aᵀ·b`. Per `c` element the batch (r) accumulation order
/// is ascending — bit-identical to [`scalar::matmul_at_b_acc`]. The `c`
/// rows are processed in [`IC`]-row panels so the accumulator working set
/// stays cache-resident while the batch streams past.
pub fn matmul_at_b_acc(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), m * n);
    debug_assert_eq!(c.len(), k * n);
    if m == 0 || k == 0 || n == 0 {
        return;
    }
    let mut i0 = 0;
    while i0 < k {
        let ib = IC.min(k - i0);
        let cpanel = &mut c[i0 * n..(i0 + ib) * n];
        for r in 0..m {
            let brow = &b[r * n..r * n + n];
            let arow = &a[r * k + i0..r * k + i0 + ib];
            let mut i = 0;
            while i + MR <= ib {
                let tile = &mut cpanel[i * n..(i + MR) * n];
                let (c0, rest) = tile.split_at_mut(n);
                let (c1, rest) = rest.split_at_mut(n);
                let (c2, c3) = rest.split_at_mut(n);
                let a0 = arow[i];
                let a1 = arow[i + 1];
                let a2 = arow[i + 2];
                let a3 = arow[i + 3];
                for ((((c0v, c1v), c2v), c3v), &bv) in c0
                    .iter_mut()
                    .zip(c1.iter_mut())
                    .zip(c2.iter_mut())
                    .zip(c3.iter_mut())
                    .zip(brow)
                {
                    *c0v += a0 * bv;
                    *c1v += a1 * bv;
                    *c2v += a2 * bv;
                    *c3v += a3 * bv;
                }
                i += MR;
            }
            while i < ib {
                let crow = &mut cpanel[i * n..(i + 1) * n];
                let ai = arow[i];
                for (cv, &bv) in crow.iter_mut().zip(brow) {
                    *cv += ai * bv;
                }
                i += 1;
            }
        }
        i0 += ib;
    }
}

/// Blocked `c = a·bᵀ` via the fixed-order 8-lane dot ([`dot8`]). Results
/// differ from [`scalar::matmul_abt`] by f32 rounding (the lane split
/// reassociates the sum) but are deterministic: the decomposition depends
/// only on `k`.
pub fn matmul_abt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    for (crow, arow) in c.chunks_exact_mut(n).zip(a.chunks_exact(k)) {
        for (cv, brow) in crow.iter_mut().zip(b.chunks_exact(k)) {
            *cv = dot8(arow, brow);
        }
    }
}

/// Dot product with eight independent accumulator lanes so the compiler
/// can keep the loop in SIMD registers (a single-accumulator dot is a
/// serial dependency chain the autovectorizer must not reassociate). The
/// lane split and the final pairwise reduction order are fixed, so the
/// result is a deterministic function of the inputs.
#[inline]
pub fn dot8(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 8];
    let mut ca = a.chunks_exact(8);
    let mut cb = b.chunks_exact(8);
    for (xa, xb) in (&mut ca).zip(&mut cb) {
        for (l, accv) in acc.iter_mut().enumerate() {
            *accv += xa[l] * xb[l];
        }
    }
    let mut tail = 0.0f32;
    for (&xa, &xb) in ca.remainder().iter().zip(cb.remainder()) {
        tail += xa * xb;
    }
    let head = ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
    head + tail
}

// ---------------------------------------------------------------------
// Scalar (frozen baseline) implementations
// ---------------------------------------------------------------------

/// The naive loops the native backend originally shipped with, kept
/// bit-for-bit as the parity oracle and the bench's `serial-scalar`
/// baseline. Do not tune these.
pub mod scalar {
    /// Naive `out = x·W (+ bias)`: per output row, copy the bias then
    /// accumulate weight rows in ascending k order.
    pub fn matmul_bias(
        x: &[f32],
        w: &[f32],
        bias: Option<&[f32]>,
        m: usize,
        k: usize,
        n: usize,
        out: &mut [f32],
    ) {
        debug_assert_eq!(x.len(), m * k);
        debug_assert_eq!(w.len(), k * n);
        debug_assert_eq!(out.len(), m * n);
        for r in 0..m {
            let xrow = &x[r * k..(r + 1) * k];
            let orow = &mut out[r * n..(r + 1) * n];
            match bias {
                Some(b) => orow.copy_from_slice(b),
                None => orow.fill(0.0),
            }
            for (i, &xi) in xrow.iter().enumerate() {
                let wrow = &w[i * n..(i + 1) * n];
                for (o, &wv) in orow.iter_mut().zip(wrow) {
                    *o += xi * wv;
                }
            }
        }
    }

    /// Naive `c += aᵀ·b`: batch-major sweep, ascending r per element.
    pub fn matmul_at_b_acc(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(b.len(), m * n);
        debug_assert_eq!(c.len(), k * n);
        for r in 0..m {
            let brow = &b[r * n..(r + 1) * n];
            let arow = &a[r * k..(r + 1) * k];
            for (i, &ai) in arow.iter().enumerate() {
                let crow = &mut c[i * n..(i + 1) * n];
                for (cv, &bv) in crow.iter_mut().zip(brow) {
                    *cv += ai * bv;
                }
            }
        }
    }

    /// Naive `c = a·bᵀ`: single-accumulator dot per element.
    pub fn matmul_abt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(b.len(), n * k);
        debug_assert_eq!(c.len(), m * n);
        for (crow, arow) in c.chunks_exact_mut(n).zip(a.chunks_exact(k)) {
            for (cv, brow) in crow.iter_mut().zip(b.chunks_exact(k)) {
                let mut acc = 0.0f32;
                for (&av, &bv) in arow.iter().zip(brow) {
                    acc += av * bv;
                }
                *cv = acc;
            }
        }
    }

    /// `db[j] += Σ_r b[r, j]`.
    pub fn col_sums_acc(b: &[f32], m: usize, n: usize, db: &mut [f32]) {
        debug_assert_eq!(b.len(), m * n);
        debug_assert_eq!(db.len(), n);
        for brow in b.chunks_exact(n) {
            for (d, &v) in db.iter_mut().zip(brow) {
                *d += v;
            }
        }
    }
}

// ---------------------------------------------------------------------
// FLOP accounting (bench: GFLOP/s)
// ---------------------------------------------------------------------

/// Mat-op FLOPs of one MLP forward over `batch` rows: `2·m·r·c` per
/// layer. Bias adds and activations are excluded (O(m·c) noise next to
/// the GEMM terms) — this is the standard "model FLOPs" lower bound.
pub fn mlp_forward_flops(layout: &[LayerLayout], batch: usize) -> f64 {
    layout
        .iter()
        .map(|l| 2.0 * batch as f64 * l.w_rows as f64 * l.w_cols as f64)
        .sum()
}

/// Mat-op FLOPs of one MLP backward over `batch` rows, matching what
/// `model::grad::mlp_backward` actually computes: `dW` (`2·m·r·c` per
/// layer) only when parameter gradients are requested, `dX` (`2·m·r·c`)
/// for every layer past the first and for the first only when the caller
/// asks for input gradients. Bias column sums and activation-derivative
/// scaling are excluded as above.
pub fn mlp_backward_flops(
    layout: &[LayerLayout],
    batch: usize,
    param_grads: bool,
    input_grads: bool,
) -> f64 {
    let mut flops = 0.0;
    for (li, l) in layout.iter().enumerate() {
        let gemm = 2.0 * batch as f64 * l.w_rows as f64 * l.w_cols as f64;
        if param_grads {
            flops += gemm;
        }
        if li > 0 || input_grads {
            flops += gemm;
        }
    }
    flops
}

/// Mat-op FLOPs of one fused `gan_step` (see `runtime::native`): the
/// generator forward over `batch` rows, two discriminator forwards over
/// `batch·events` rows (fake + real), the discriminator input-gradient
/// backward for the generator loss, the generator parameter backward, and
/// the two discriminator parameter backwards. The scenario's forward
/// operator and VJP are excluded — they are O(batch·events·P) element
/// ops, not GEMMs — so reported GFLOP/s is a lower bound on sustained
/// arithmetic throughput.
pub fn gan_step_flops(meta: &ModelMeta, batch: usize, events: usize) -> f64 {
    let n = batch * events;
    mlp_forward_flops(&meta.gen_layout, batch)
        + 2.0 * mlp_forward_flops(&meta.disc_layout, n)
        + mlp_backward_flops(&meta.disc_layout, n, false, true)
        + mlp_backward_flops(&meta.gen_layout, batch, true, false)
        + 2.0 * mlp_backward_flops(&meta.disc_layout, n, true, false)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn filled(len: usize, rng: &mut Rng) -> Vec<f32> {
        (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect()
    }

    /// Shapes chosen to exercise every tile-tail combination: m not a
    /// multiple of MR, k crossing KC, n odd, plus degenerate edges.
    const SHAPES: &[(usize, usize, usize)] = &[
        (1, 1, 1),
        (2, 3, 5),
        (4, 8, 8),
        (5, 7, 9),
        (7, 13, 11),
        (33, 300, 17),
        (16, 6, 157),
    ];

    #[test]
    fn blocked_matmul_bias_is_bit_identical_to_scalar() {
        let mut rng = Rng::new(101);
        for &(m, k, n) in SHAPES {
            let x = filled(m * k, &mut rng);
            let w = filled(k * n, &mut rng);
            let b = filled(n, &mut rng);
            for bias in [Some(b.as_slice()), None] {
                let mut got = vec![f32::NAN; m * n];
                let mut want = vec![f32::NAN; m * n];
                matmul_bias(&x, &w, bias, m, k, n, &mut got);
                scalar::matmul_bias(&x, &w, bias, m, k, n, &mut want);
                assert_eq!(got, want, "matmul_bias ({m},{k},{n}) bias={}", bias.is_some());
            }
        }
    }

    #[test]
    fn blocked_matmul_at_b_acc_is_bit_identical_to_scalar() {
        let mut rng = Rng::new(102);
        for &(m, k, n) in SHAPES {
            let a = filled(m * k, &mut rng);
            let b = filled(m * n, &mut rng);
            // Nonzero start: += semantics must match too.
            let init = filled(k * n, &mut rng);
            let mut got = init.clone();
            let mut want = init;
            matmul_at_b_acc(&a, &b, m, k, n, &mut got);
            scalar::matmul_at_b_acc(&a, &b, m, k, n, &mut want);
            assert_eq!(got, want, "matmul_at_b_acc ({m},{k},{n})");
        }
    }

    #[test]
    fn blocked_matmul_abt_matches_scalar_within_rounding() {
        let mut rng = Rng::new(103);
        for &(m, k, n) in SHAPES {
            let a = filled(m * k, &mut rng);
            let b = filled(n * k, &mut rng);
            let mut got = vec![f32::NAN; m * n];
            let mut want = vec![f32::NAN; m * n];
            matmul_abt(&a, &b, m, k, n, &mut got);
            scalar::matmul_abt(&a, &b, m, k, n, &mut want);
            for (g, w) in got.iter().zip(&want) {
                assert!(
                    (g - w).abs() <= 1e-4 + 1e-4 * w.abs().max(g.abs()),
                    "matmul_abt ({m},{k},{n}): {g} vs {w}"
                );
            }
            // And the blocked path is itself deterministic.
            let mut again = vec![f32::NAN; m * n];
            matmul_abt(&a, &b, m, k, n, &mut again);
            assert_eq!(got, again);
        }
    }

    #[test]
    fn dot8_matches_f64_reference() {
        let mut rng = Rng::new(104);
        for len in [0usize, 1, 7, 8, 9, 16, 31, 64, 157] {
            let a = filled(len, &mut rng);
            let b = filled(len, &mut rng);
            let want: f64 = a.iter().zip(&b).map(|(&x, &y)| (x as f64) * (y as f64)).sum();
            let got = dot8(&a, &b) as f64;
            assert!(
                (got - want).abs() <= 1e-4 + 1e-5 * want.abs(),
                "len {len}: {got} vs {want}"
            );
        }
    }

    #[test]
    fn col_sums_accumulate() {
        let b = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let mut db = vec![10.0f32, 20.0];
        Kernels::Blocked.col_sums_acc(&b, 3, 2, &mut db);
        assert_eq!(db, vec![10.0 + 1.0 + 3.0 + 5.0, 20.0 + 2.0 + 4.0 + 6.0]);
    }

    #[test]
    fn kernels_selector_dispatches_both_paths() {
        let mut rng = Rng::new(105);
        let (m, k, n) = (5, 6, 7);
        let x = filled(m * k, &mut rng);
        let w = filled(k * n, &mut rng);
        for kind in [Kernels::Scalar, Kernels::Blocked] {
            let mut out = vec![0.0f32; m * n];
            kind.matmul_bias(&x, &w, None, m, k, n, &mut out);
            assert!(out.iter().all(|v| v.is_finite()), "{}", kind.name());
        }
        assert_eq!(Kernels::default(), Kernels::Blocked);
        assert_eq!(Kernels::Scalar.name(), "scalar");
    }

    #[test]
    fn flop_counts_match_hand_computation() {
        // One 3 -> 4 -> 2 net, batch 5.
        let (_, layout, _) = crate::runtime::manifest::layout_from_sizes(&[3, 4, 2]);
        let fwd = mlp_forward_flops(&layout, 5);
        assert_eq!(fwd, 2.0 * 5.0 * (3.0 * 4.0 + 4.0 * 2.0));
        // Full backward: dW everywhere + dX everywhere.
        let bwd = mlp_backward_flops(&layout, 5, true, true);
        assert_eq!(bwd, 2.0 * fwd);
        // Input-only backward skips dW; first-layer dX still counted.
        assert_eq!(mlp_backward_flops(&layout, 5, false, true), fwd);
        // Param-only backward: dW everywhere, dX for hidden layers only.
        let param_only = mlp_backward_flops(&layout, 5, true, false);
        assert_eq!(param_only, fwd + 2.0 * 5.0 * (4.0 * 2.0));
    }

    #[test]
    fn gan_step_flops_are_positive_and_scale_with_batch() {
        let m = crate::runtime::manifest::Manifest::synthetic();
        let meta = m.model("paper").unwrap();
        let f1 = gan_step_flops(meta, 16, 25);
        let f2 = gan_step_flops(meta, 32, 25);
        assert!(f1 > 0.0);
        assert!((f2 / f1 - 2.0).abs() < 1e-9, "{f2} vs {f1}");
    }
}
