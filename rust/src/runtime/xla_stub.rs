//! Build-anywhere stand-in for the `xla` crate's PJRT surface.
//!
//! The real runtime links xla_extension through the `xla` crate (enable
//! the `pjrt` cargo feature). Without it, this stub keeps the crate —
//! collectives, coordinator, simulator, benches — compiling and testable:
//! every entry point that would touch PJRT returns a descriptive error,
//! and the artifact-driven integration tests skip themselves before ever
//! constructing a client. The API mirrors exactly the subset
//! `runtime::pool` uses.

use std::fmt;

/// Stub error: always "runtime unavailable".
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable() -> Error {
    Error("PJRT runtime unavailable: build with `--features pjrt` (requires xla_extension)".into())
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(unavailable())
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable())
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable())
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable())
    }
}

pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Ok(Literal)
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        Err(unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(unavailable())
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(unavailable())
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_surfaces_a_clear_error() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(e.to_string().contains("pjrt"));
        let err: crate::util::error::Error = e.into();
        assert!(matches!(err, crate::util::error::Error::Runtime(_)));
    }
}
